"""L1 Pallas kernel: stochastic number generation (BtoS, §2.3 step 1).

Models the MTJ stochastic write pulse: each cell of an input column
switches with probability equal to the binary value. As a kernel:
bit[i, t] = (u[i, t] < value[i]), one comparator per cell — the same
comparison the BtoS memory's pulse realizes physically.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gate_plane import TILE_BL, TILE_LANES


def _sng_kernel(v_ref, u_ref, o_ref):
    # v block: [tl, 1] values; u block: [tl, tb] uniforms.
    v = v_ref[...]
    u = u_ref[...]
    o_ref[...] = (u < v).astype(jnp.uint8)


@jax.jit
def sng(values, uniforms):
    """values: [lanes] f32; uniforms: [lanes, bl] f32 → [lanes, bl] u8."""
    lanes, bl = uniforms.shape
    tl = min(TILE_LANES, lanes)
    tb = min(TILE_BL, bl)
    grid = (pl.cdiv(lanes, tl), pl.cdiv(bl, tb))
    return pl.pallas_call(
        _sng_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tl, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tl, tb), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tl, tb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((lanes, bl), jnp.uint8),
        interpret=True,
    )(values[:, None], uniforms)
