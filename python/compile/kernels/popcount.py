"""L1 Pallas kernel: StoB popcount (§2.3 step 3 / §4.3 accumulators).

Two-level reduction mirroring the architecture's local (per-group) and
global accumulator tree: each grid step popcounts one [tl, tb] block
into a partial (local accumulator), accumulated across the bl axis into
the output (global accumulator). n+m-step semantics, n×m work.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gate_plane import TILE_BL, TILE_LANES


def _popcount_kernel(bits_ref, o_ref):
    j = pl.program_id(1)
    # Local accumulation of this block.
    partial = jnp.sum(bits_ref[...].astype(jnp.int32), axis=-1, keepdims=True)
    # Global accumulation across bl blocks (grid is sequential in
    # interpret mode, matching the architecture's step-wise global sum).
    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        o_ref[...] = o_ref[...] + partial


@jax.jit
def popcount(bits):
    """bits: [lanes, bl] u8 → ones per lane [lanes, 1] i32."""
    lanes, bl = bits.shape
    tl = min(TILE_LANES, lanes)
    tb = min(TILE_BL, bl)
    grid = (pl.cdiv(lanes, tl), pl.cdiv(bl, tb))
    return pl.pallas_call(
        _popcount_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tl, tb), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tl, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lanes, 1), jnp.int32),
        interpret=True,
    )(bits)
