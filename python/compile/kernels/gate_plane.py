"""L1 Pallas kernel: one bit-parallel IMC logic cycle over cell planes.

The hot spot of Stoch-IMC value computation: every scheduled cycle
applies ONE gate type across all active rows at aligned columns
(paper §4.2 constraints). On a [lanes, bl] plane that is a pure
elementwise op — the TPU adaptation tiles the plane into VMEM-sized
blocks with the bitstream axis minor (vector lanes), one grid step per
block (DESIGN.md §Hardware-Adaptation).

interpret=True always: the CPU PJRT plugin cannot run Mosaic
custom-calls; lowering through interpret mode emits plain HLO that the
Rust runtime executes. Real-TPU performance is estimated from the
BlockSpec footprint in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# VMEM-friendly tile: 8×512 uint8 = 4 KiB/operand; lanes×bl planes of
# 256×256 fit in 13 grid steps along lanes with full rows resident.
TILE_LANES = 8
TILE_BL = 512


def _unary_kernel(op, a_ref, o_ref):
    a = a_ref[...]
    o_ref[...] = ref.gate_plane(op, a)


def _binary_kernel(op, a_ref, b_ref, o_ref):
    o_ref[...] = ref.gate_plane(op, a_ref[...], b_ref[...])


def _mux_kernel(s_ref, a_ref, b_ref, o_ref):
    o_ref[...] = ref.mux_plane(s_ref[...], a_ref[...], b_ref[...])


def _grid_spec(shape, n_operands):
    lanes, bl = shape
    tl = min(TILE_LANES, lanes)
    tb = min(TILE_BL, bl)
    grid = (pl.cdiv(lanes, tl), pl.cdiv(bl, tb))
    spec = pl.BlockSpec((tl, tb), lambda i, j: (i, j))
    return grid, [spec] * n_operands, spec


@functools.partial(jax.jit, static_argnums=0)
def gate_plane(op: int, a, b=None):
    """Apply gate `op` bit-parallel over uint8 planes [lanes, bl]."""
    a = a.astype(jnp.uint8)
    operands = (a,) if b is None else (a, b.astype(jnp.uint8))
    grid, in_specs, out_spec = _grid_spec(a.shape, len(operands))
    kernel = (
        functools.partial(_unary_kernel, op)
        if b is None
        else functools.partial(_binary_kernel, op)
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.uint8),
        interpret=True,
    )(*operands)


@jax.jit
def mux_plane(s, a, b):
    """MUX (scaled addition select) bit-parallel over planes."""
    s = s.astype(jnp.uint8)
    a = a.astype(jnp.uint8)
    b = b.astype(jnp.uint8)
    grid, in_specs, out_spec = _grid_spec(s.shape, 3)
    return pl.pallas_call(
        _mux_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(s.shape, jnp.uint8),
        interpret=True,
    )(s, a, b)
