"""Pure-jnp oracles for the Pallas kernels (L1 correctness baseline).

Planes are uint8 {0,1} arrays of shape [lanes, bl]: lane = subarray row
(bit of the bitstream), bl = bitstream position. One IMC logic cycle is
one elementwise gate over aligned planes — exactly what the hardware does
across rows of a subarray group (paper §4.2/§4.3).
"""

import jax.numpy as jnp

# Gate opcodes shared with gate_plane.py (compile-time constants).
OP_NOT = 0
OP_AND = 1
OP_NAND = 2
OP_OR = 3
OP_NOR = 4
OP_XOR = 5
OP_BUFF = 6

OP_NAMES = {
    OP_NOT: "not",
    OP_AND: "and",
    OP_NAND: "nand",
    OP_OR: "or",
    OP_NOR: "nor",
    OP_XOR: "xor",
    OP_BUFF: "buff",
}


def gate_plane(op: int, a, b=None):
    """Oracle for one bit-parallel gate cycle over uint8 {0,1} planes."""
    a = a.astype(jnp.uint8)
    if b is not None:
        b = b.astype(jnp.uint8)
    one = jnp.uint8(1)
    if op == OP_NOT:
        return one - a
    if op == OP_BUFF:
        return a
    if op == OP_AND:
        return a & b
    if op == OP_NAND:
        return one - (a & b)
    if op == OP_OR:
        return a | b
    if op == OP_NOR:
        return one - (a | b)
    if op == OP_XOR:
        return a ^ b
    raise ValueError(f"unknown opcode {op}")


def mux_plane(s, a, b):
    """MUX oracle: out = s ? a : b (scaled addition, Fig 4a)."""
    s = s.astype(jnp.uint8)
    return (s & a.astype(jnp.uint8)) | ((1 - s) & b.astype(jnp.uint8))


def sng(values, uniforms):
    """SNG oracle: bit[i, t] = uniforms[i, t] < values[i] (§2.3 step 1).

    values: [lanes] float32 in [0,1]; uniforms: [lanes, bl] float32.
    Models the MTJ stochastic write: P(bit=1) = value.
    """
    return (uniforms < values[:, None]).astype(jnp.uint8)


def popcount(bits):
    """StoB oracle: ones count per lane (§2.3 step 3).

    bits: [lanes, bl] uint8 → [lanes] int32.
    """
    return jnp.sum(bits.astype(jnp.int32), axis=-1)
