"""L2 — JAX compute graphs of the stochastic operations and the four
applications, composed from the L1 Pallas kernels.

Every public graph is a *batch value evaluator*: it takes binary input
values (f32 in [0,1], shape [B, n_inputs]) plus an int32 seed, performs
SNG → bit-parallel stochastic circuit → StoB popcount entirely inside
the graph (bits never cross the boundary), and returns the output values
(f32 [B]). This is exactly the work one subarray-group wave performs in
the architecture; the Rust coordinator batches workload instances into
these artifacts.

Sequential circuits (scaled division's JK flip-flop, the square root
ADDIE) use lax.scan over the bit axis — the same semantics as the Rust
functional simulator (rust/src/sc/ops.rs).
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import ref
from .kernels.gate_plane import gate_plane, mux_plane
from .kernels.popcount import popcount
from .kernels.sng import sng

BL = 256  # default bitstream length (2^8 resolution, §5.1)


# ---- stream helpers -----------------------------------------------------


def _uniforms(key, shape):
    return jax.random.uniform(key, shape, dtype=jnp.float32)


def streams(key, values, bl):
    """Independent SNs: values [B] → bits [B, bl] uint8."""
    u = _uniforms(key, (values.shape[0], bl))
    return sng(values, u)


def correlated_pair(key, a_vals, b_vals, bl):
    """Maximally-correlated SN pair (shared uniforms — §4.1 abs-sub)."""
    u = _uniforms(key, (a_vals.shape[0], bl))
    return sng(a_vals, u), sng(b_vals, u)


def to_value(bits):
    """StoB: popcount / bl."""
    bl = bits.shape[-1]
    return popcount(bits)[:, 0].astype(jnp.float32) / jnp.float32(bl)


# ---- arithmetic operations (Fig 5) --------------------------------------


def op_multiply(values, seed, bl=BL):
    """values [B,2] → a·b. AND = NOT(NAND) over the reliable gate set."""
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    a = streams(k1, values[:, 0], bl)
    b = streams(k2, values[:, 1], bl)
    nand = gate_plane(ref.OP_NAND, a, b)
    out = gate_plane(ref.OP_NOT, nand)
    return (to_value(out),)


def op_scaled_add(values, seed, bl=BL):
    """values [B,2] → (a+b)/2 via MUX with an s=0.5 stream."""
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    a = streams(k1, values[:, 0], bl)
    b = streams(k2, values[:, 1], bl)
    s = streams(k3, jnp.full((values.shape[0],), 0.5, jnp.float32), bl)
    return (to_value(mux_plane(s, a, b)),)


def op_abs_subtract(values, seed, bl=BL):
    """values [B,2] → |a−b| via XOR of correlated streams."""
    key = jax.random.key(seed)
    a, b = correlated_pair(key, values[:, 0], values[:, 1], bl)
    return (to_value(gate_plane(ref.OP_XOR, a, b)),)


def _divide_bits(a, b):
    """JK divider over planes: out_t = Q_t; Q' = (a·Q̄)+(b̄·Q), Q0=0."""

    def step(q, ab):
        a_t, b_t = ab
        out = q
        q_next = (a_t & (1 - q)) | ((1 - b_t) & q)
        return q_next, out

    q0 = jnp.zeros((a.shape[0],), jnp.uint8)
    _, outs = lax.scan(step, q0, (a.T, b.T))
    return outs.T  # [B, bl]


def op_scaled_divide(values, seed, bl=BL):
    """values [B,2] → a/(a+b) via the JK feedback divider."""
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    a = streams(k1, values[:, 0], bl)
    b = streams(k2, values[:, 1], bl)
    return (to_value(_divide_bits(a, b)),)


def _addie_sqrt_bits(key, x1, x2, counter_bits=6):
    """ADDIE integrator over alternating copies (rust sc::ops::Addie)."""
    bl = x1.shape[1]
    maxc = jnp.int32(1 << counter_bits)
    u = _uniforms(key, (x1.shape[0], bl, 2))

    def step(c, inp):
        x1_t, x2_t, u_t, t = inp
        y = (u_t[:, 0] * maxc.astype(jnp.float32)) < c.astype(jnp.float32)
        y2 = (u_t[:, 1] * maxc.astype(jnp.float32)) < c.astype(jnp.float32)
        x = jnp.where(t % 2 == 0, x1_t, x2_t).astype(jnp.bool_)
        c = jnp.clip(
            c + x.astype(jnp.int32) - (y & y2).astype(jnp.int32), 0, maxc
        )
        return c, y.astype(jnp.uint8)

    c0 = jnp.full((x1.shape[0],), (1 << counter_bits) // 2, jnp.int32)
    ts = jnp.arange(bl)
    _, outs = lax.scan(step, c0, (x1.T, x2.T, jnp.swapaxes(u, 0, 1), ts))
    return outs.T


def op_square_root(values, seed, bl=BL):
    """values [B,1] → √a (two independent copies + ADDIE, Fig 5e)."""
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    a1 = streams(k1, values[:, 0], bl)
    a2 = streams(k2, values[:, 0], bl)
    return (to_value(_addie_sqrt_bits(k3, a1, a2)),)


def _exp_bits(key, x_vals, c, bl):
    """e^{−cx} bits via the 5-stage Maclaurin/Horner circuit (Fig 5f)."""
    b = x_vals.shape[0]
    keys = jax.random.split(key, 10)
    acc = None
    for k in range(4, -1, -1):
        a_k = streams(keys[k], x_vals, bl)
        c_k = streams(
            keys[5 + k], jnp.full((b,), c / (k + 1), jnp.float32), bl
        )
        if acc is None:  # innermost: 1 − u5 = NAND(a5, c5)
            acc = gate_plane(ref.OP_NAND, a_k, c_k)
        else:
            u = gate_plane(ref.OP_NOT, gate_plane(ref.OP_NAND, a_k, c_k))
            acc = gate_plane(ref.OP_NAND, u, acc)
    return acc


def op_exponential(values, seed, c=1.0, bl=BL):
    """values [B,1] → e^{−c·a}, 0 < c ≤ 1."""
    key = jax.random.key(seed)
    return (to_value(_exp_bits(key, values[:, 0], c, bl)),)


# ---- applications (Fig 9) -----------------------------------------------


def app_ol(values, seed, bl=BL):
    """Object location: values [B,6] → Π p_i (AND tree)."""
    key = jax.random.key(seed)
    keys = jax.random.split(key, 6)
    acc = streams(keys[0], values[:, 0], bl)
    for i in range(1, 6):
        s = streams(keys[i], values[:, i], bl)
        acc = gate_plane(ref.OP_NOT, gate_plane(ref.OP_NAND, acc, s))
    return (to_value(acc),)


def app_hdp(values, seed, bl=BL):
    """Heart-disaster prediction: values [B,8] = [BP, CP, E, D, t_ED,
    t_ED̄, t_ĒD, t_ĒD̄] → P(HD) (Eqs 8–9)."""
    key = jax.random.key(seed)
    keys = jax.random.split(key, 8)
    bp = streams(keys[0], values[:, 0], bl)
    cp = streams(keys[1], values[:, 1], bl)
    e = streams(keys[2], values[:, 2], bl)
    d = streams(keys[3], values[:, 3], bl)
    t = [streams(keys[4 + i], values[:, 4 + i], bl) for i in range(4)]
    hi = mux_plane(d, t[0], t[1])
    lo = mux_plane(d, t[2], t[3])
    h = mux_plane(e, hi, lo)
    band = gate_plane(ref.OP_NOT, gate_plane(ref.OP_NAND, bp, cp))
    n = gate_plane(ref.OP_NOT, gate_plane(ref.OP_NAND, band, h))
    bp_n = gate_plane(ref.OP_NOT, bp)
    cp_n = gate_plane(ref.OP_NOT, cp)
    h_n = gate_plane(ref.OP_NOT, h)
    bcn = gate_plane(ref.OP_NOT, gate_plane(ref.OP_NAND, bp_n, cp_n))
    m = gate_plane(ref.OP_NOT, gate_plane(ref.OP_NAND, bcn, h_n))
    return (to_value(_divide_bits(n, m)),)


def _mean_tree(planes, key, bl):
    """Balanced MUX tree (pads to a power of two with zero planes)."""
    level = list(planes)
    target = 1 << (len(level) - 1).bit_length()
    while len(level) < target:
        level.append(jnp.zeros_like(level[0]))
    i = 0
    while len(level) > 1:
        nxt = []
        for j in range(0, len(level), 2):
            key, sub = jax.random.split(key)
            s = streams(
                sub, jnp.full((level[0].shape[0],), 0.5, jnp.float32), bl
            )
            nxt.append(mux_plane(s, level[j], level[j + 1]))
            i += 1
        level = nxt
    return level[0]


def app_lit(values, seed, bl=BL, pixels=64):
    """Local image thresholding: values [B,64] (8×8 window) → T.

    Three in-memory stages with StoB→BtoS regeneration between them
    (DESIGN.md §7): trees → correlated |σ²| → √ and final product.
    """
    key = jax.random.key(seed)
    ks = jax.random.split(key, 8)
    # Stage 1: two mean trees, squares tree.
    set1 = [streams(jax.random.fold_in(ks[0], i), values[:, i], bl) for i in range(pixels)]
    set2 = [streams(jax.random.fold_in(ks[1], i), values[:, i], bl) for i in range(pixels)]
    set3 = [streams(jax.random.fold_in(ks[2], i), values[:, i], bl) for i in range(pixels)]
    set4 = [streams(jax.random.fold_in(ks[3], i), values[:, i], bl) for i in range(pixels)]
    mean1 = _mean_tree(set1, ks[4], bl)
    mean2 = _mean_tree(set2, ks[5], bl)
    squares = [
        gate_plane(ref.OP_NOT, gate_plane(ref.OP_NAND, a, b))
        for a, b in zip(set3, set4)
    ]
    mean_sq = _mean_tree(squares, ks[6], bl)
    m2sq = gate_plane(ref.OP_NOT, gate_plane(ref.OP_NAND, mean1, mean2))
    v_mean = to_value(mean1)
    v_meansq = to_value(mean_sq)
    v_m2 = to_value(m2sq)
    # Stage 2: correlated regeneration → |σ²|.
    k_a, k_b, k_c, k_d, k_e = jax.random.split(ks[7], 5)
    ca, cb = correlated_pair(k_a, v_meansq, v_m2, bl)
    var = gate_plane(ref.OP_XOR, ca, cb)
    v_var = to_value(var)
    # Stage 3: √ → (σ+1)/2 → × mean.
    a1 = streams(k_b, v_var, bl)
    a2 = streams(k_c, v_var, bl)
    sigma = _addie_sqrt_bits(k_d, a1, a2)
    ones = jnp.ones_like(sigma)
    k_s, k_m = jax.random.split(k_e)
    sel = streams(k_s, jnp.full((values.shape[0],), 0.5, jnp.float32), bl)
    half = mux_plane(sel, sigma, ones)
    mean_r = streams(k_m, v_mean, bl)
    t = gate_plane(ref.OP_NOT, gate_plane(ref.OP_NAND, mean_r, half))
    return (to_value(t),)


def app_kde(values, seed, bl=BL, history=8, c=4.0):
    """KDE: values [B, 1+history] = [X_t, X_{t−1}..] → PDF(X_t) (Eq 10)."""
    key = jax.random.key(seed)
    frames = []
    for i in range(1, history + 1):
        key, k_corr, k_exp = jax.random.split(key, 3)
        a, b = correlated_pair(k_corr, values[:, 0], values[:, i], bl)
        d = gate_plane(ref.OP_XOR, a, b)
        v_d = to_value(d)  # StoB, then regenerate for the exp stages
        prod = None
        for s in range(5):
            k_exp, sub = jax.random.split(k_exp)
            e = _exp_bits(sub, v_d, c / 5.0, bl)
            if prod is None:
                prod = e
            else:
                prod = gate_plane(ref.OP_NOT, gate_plane(ref.OP_NAND, prod, e))
        frames.append(prod)
    key, k_tree = jax.random.split(key)
    return (to_value(_mean_tree(frames, k_tree, bl)),)


# ---- artifact registry (consumed by aot.py and the Rust runtime) --------

# name → (fn, n_inputs). All artifacts share the (values [B, n], seed)
# calling convention.
ARTIFACTS = {
    "op_multiply": (op_multiply, 2),
    "op_scaled_add": (op_scaled_add, 2),
    "op_abs_subtract": (op_abs_subtract, 2),
    "op_scaled_divide": (op_scaled_divide, 2),
    "op_square_root": (op_square_root, 1),
    "op_exponential": (op_exponential, 1),
    "app_ol": (app_ol, 6),
    "app_hdp": (app_hdp, 8),
    "app_lit": (app_lit, 64),
    "app_kde": (app_kde, 9),
}
