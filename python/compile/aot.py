"""AOT lowering: JAX graphs → HLO *text* artifacts for the Rust runtime.

HLO text, NOT serialized protos: jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` 0.1.6 crate binds) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts [--batch 64] [--bl 256]

Each artifact `<name>.hlo.txt` takes (values f32[B, n], seed i32) and
returns a 1-tuple (f32[B],). A manifest `manifest.txt` lists
name, n_inputs, batch, bl per line for the Rust artifact registry.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS, BL


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(name: str, batch: int, bl: int) -> str:
    fn, n_inputs = ARTIFACTS[name]

    def wrapped(values, seed):
        return fn(values, seed, bl=bl)

    values_spec = jax.ShapeDtypeStruct((batch, n_inputs), jnp.float32)
    seed_spec = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jax.jit(wrapped).lower(values_spec, seed_spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--bl", type=int, default=BL)
    ap.add_argument(
        "--only", nargs="*", default=None, help="subset of artifact names"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = args.only or list(ARTIFACTS)
    manifest = []
    for name in names:
        text = lower_artifact(name, args.batch, args.bl)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        n_inputs = ARTIFACTS[name][1]
        manifest.append(f"{name} {n_inputs} {args.batch} {args.bl}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
