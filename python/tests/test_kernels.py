"""L1 correctness: Pallas kernels vs pure-jnp oracles (exact), with
hypothesis sweeping shapes and dtypes-of-input edge cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gate_plane import gate_plane, mux_plane
from compile.kernels.popcount import popcount
from compile.kernels.sng import sng

BINARY_OPS = [ref.OP_AND, ref.OP_NAND, ref.OP_OR, ref.OP_NOR, ref.OP_XOR]
UNARY_OPS = [ref.OP_NOT, ref.OP_BUFF]


def rand_plane(key, shape):
    return jax.random.bernoulli(key, 0.5, shape).astype(jnp.uint8)


@pytest.mark.parametrize("op", BINARY_OPS, ids=lambda o: ref.OP_NAMES[o])
def test_binary_gates_match_ref(op):
    key = jax.random.key(op)
    k1, k2 = jax.random.split(key)
    a = rand_plane(k1, (64, 256))
    b = rand_plane(k2, (64, 256))
    np.testing.assert_array_equal(gate_plane(op, a, b), ref.gate_plane(op, a, b))


@pytest.mark.parametrize("op", UNARY_OPS, ids=lambda o: ref.OP_NAMES[o])
def test_unary_gates_match_ref(op):
    a = rand_plane(jax.random.key(9), (64, 256))
    np.testing.assert_array_equal(gate_plane(op, a), ref.gate_plane(op, a))


def test_mux_matches_ref():
    key = jax.random.key(1)
    k1, k2, k3 = jax.random.split(key, 3)
    s = rand_plane(k1, (32, 512))
    a = rand_plane(k2, (32, 512))
    b = rand_plane(k3, (32, 512))
    np.testing.assert_array_equal(mux_plane(s, a, b), ref.mux_plane(s, a, b))


@settings(max_examples=20, deadline=None)
@given(
    lanes=st.sampled_from([1, 3, 8, 17, 64]),
    bl=st.sampled_from([8, 64, 256, 500, 512]),
    op=st.sampled_from(BINARY_OPS),
    seed=st.integers(0, 2**31 - 1),
)
def test_gate_plane_shape_sweep(lanes, bl, op, seed):
    """Odd shapes exercise BlockSpec padding/tiling edges."""
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    a = rand_plane(k1, (lanes, bl))
    b = rand_plane(k2, (lanes, bl))
    got = gate_plane(op, a, b)
    assert got.shape == (lanes, bl)
    assert got.dtype == jnp.uint8
    np.testing.assert_array_equal(got, ref.gate_plane(op, a, b))


@settings(max_examples=20, deadline=None)
@given(
    lanes=st.sampled_from([1, 5, 8, 33, 64]),
    bl=st.sampled_from([16, 256, 777, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sng_matches_ref_sweep(lanes, bl, seed):
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    values = jax.random.uniform(k1, (lanes,))
    uniforms = jax.random.uniform(k2, (lanes, bl))
    np.testing.assert_array_equal(sng(values, uniforms), ref.sng(values, uniforms))


def test_sng_statistics():
    key = jax.random.key(3)
    values = jnp.array([0.1, 0.5, 0.9], jnp.float32)
    uniforms = jax.random.uniform(jax.random.key(4), (3, 1 << 16))
    bits = sng(values, uniforms)
    rates = np.asarray(bits).mean(axis=1)
    np.testing.assert_allclose(rates, np.asarray(values), atol=0.01)


@settings(max_examples=20, deadline=None)
@given(
    lanes=st.sampled_from([1, 8, 31, 64]),
    bl=st.sampled_from([8, 256, 500, 2048]),
    seed=st.integers(0, 2**31 - 1),
)
def test_popcount_matches_ref_sweep(lanes, bl, seed):
    bits = rand_plane(jax.random.key(seed), (lanes, bl))
    got = popcount(bits)[:, 0]
    np.testing.assert_array_equal(got, ref.popcount(bits))


def test_popcount_extremes():
    zeros = jnp.zeros((8, 256), jnp.uint8)
    ones = jnp.ones((8, 256), jnp.uint8)
    assert int(popcount(zeros).sum()) == 0
    assert int(popcount(ones).sum()) == 8 * 256
