"""L2 correctness: op/app graphs converge to their closed forms, and the
artifact registry lowers to HLO text."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

BL = 2048  # longer streams for tighter tolerances in tests

unit = st.floats(0.05, 0.95, allow_nan=False)


@settings(max_examples=10, deadline=None)
@given(a=unit, b=unit, seed=st.integers(0, 2**31 - 1))
def test_multiply(a, b, seed):
    vals = jnp.array([[a, b]], jnp.float32)
    (out,) = model.op_multiply(vals, seed, bl=BL)
    assert abs(float(out[0]) - a * b) < 0.05


@settings(max_examples=10, deadline=None)
@given(a=unit, b=unit, seed=st.integers(0, 2**31 - 1))
def test_scaled_add(a, b, seed):
    vals = jnp.array([[a, b]], jnp.float32)
    (out,) = model.op_scaled_add(vals, seed, bl=BL)
    assert abs(float(out[0]) - (a + b) / 2) < 0.05


@settings(max_examples=10, deadline=None)
@given(a=unit, b=unit, seed=st.integers(0, 2**31 - 1))
def test_abs_subtract(a, b, seed):
    vals = jnp.array([[a, b]], jnp.float32)
    (out,) = model.op_abs_subtract(vals, seed, bl=BL)
    assert abs(float(out[0]) - abs(a - b)) < 0.05


@settings(max_examples=8, deadline=None)
@given(a=unit, b=unit, seed=st.integers(0, 2**31 - 1))
def test_scaled_divide(a, b, seed):
    vals = jnp.array([[a, b]], jnp.float32)
    (out,) = model.op_scaled_divide(vals, seed, bl=BL)
    assert abs(float(out[0]) - a / (a + b)) < 0.06


@settings(max_examples=8, deadline=None)
@given(a=st.floats(0.1, 0.9), seed=st.integers(0, 2**31 - 1))
def test_square_root(a, seed):
    vals = jnp.array([[a]], jnp.float32)
    (out,) = model.op_square_root(vals, seed, bl=4096)
    assert abs(float(out[0]) - a**0.5) < 0.08


@settings(max_examples=8, deadline=None)
@given(a=unit, seed=st.integers(0, 2**31 - 1))
def test_exponential(a, seed):
    vals = jnp.array([[a]], jnp.float32)
    (out,) = model.op_exponential(vals, seed, c=0.8, bl=BL)
    want = float(np.exp(-0.8 * a))
    assert abs(float(out[0]) - want) < 0.05


def test_batch_dimension_independent():
    vals = jnp.array([[0.2, 0.5], [0.8, 0.5], [0.5, 0.5]], jnp.float32)
    (out,) = model.op_multiply(vals, 7, bl=BL)
    np.testing.assert_allclose(
        np.asarray(out), [0.1, 0.4, 0.25], atol=0.04
    )


def test_app_ol():
    x = np.array([[0.9, 0.8, 0.95, 0.7, 0.85, 0.9]], np.float32)
    (out,) = model.app_ol(jnp.asarray(x), 11, bl=BL)
    assert abs(float(out[0]) - float(np.prod(x))) < 0.05


def test_app_hdp():
    x = np.array([[0.6, 0.5, 0.7, 0.6, 0.2, 0.4, 0.35, 0.8]], np.float32)
    bp, cp, e, d = x[0, :4]
    t = x[0, 4:]
    h = (t[0] * d + t[1] * (1 - d)) * e + (t[2] * d + t[3] * (1 - d)) * (1 - e)
    n = bp * cp * h
    m = (1 - bp) * (1 - cp) * (1 - h)
    want = n / (n + m)
    (out,) = model.app_hdp(jnp.asarray(x), 13, bl=4096)
    assert abs(float(out[0]) - want) < 0.06


def test_app_lit():
    rng = np.random.default_rng(0)
    w = rng.uniform(0.1, 0.9, (1, 64)).astype(np.float32)
    mean = w.mean()
    sigma = np.sqrt(abs((w**2).mean() - mean**2))
    want = mean * (sigma + 1) / 2
    (out,) = model.app_lit(jnp.asarray(w), 17, bl=1024)
    assert abs(float(out[0]) - want) < 0.08, (float(out[0]), want)


def test_app_kde():
    rng = np.random.default_rng(1)
    x = rng.uniform(0.2, 0.8, (1, 9)).astype(np.float32)
    want = np.mean(np.exp(-4.0 * np.abs(x[0, 0] - x[0, 1:])))
    (out,) = model.app_kde(jnp.asarray(x), 19, bl=1024)
    assert abs(float(out[0]) - want) < 0.1, (float(out[0]), want)


@pytest.mark.parametrize("name", list(model.ARTIFACTS))
def test_artifacts_lower_to_hlo_text(name):
    from compile.aot import lower_artifact

    text = lower_artifact(name, batch=4, bl=64)
    assert "HloModule" in text
    assert "f32[4" in text  # batched input present
