//! E7 — regenerate paper Table 4: output error under bitflip injection.
use stoch_imc::config::Config;
use stoch_imc::report;

fn main() {
    let cfg = Config::default();
    let rates = [0.0, 0.05, 0.10, 0.15, 0.20];
    let (t, secs) = stoch_imc::util::timed(|| report::table4(&cfg, &rates, 24));
    println!("# Table 4 — average output error (%) vs injected bitflip rate");
    println!("{:<6} | {:>37} | {:>37}", "app", "binary-IMC @ 0/5/10/15/20%", "Stoch-IMC @ 0/5/10/15/20%");
    for app in ["lit", "ol", "hdp", "kde"] {
        let (b, s) = &t[app];
        let f = |v: &Vec<f64>| v.iter().map(|x| format!("{x:6.2}")).collect::<Vec<_>>().join(" ");
        println!("{:<6} | {:>37} | {:>37}", app, f(b), f(s));
        // Paper shape: at 20% injection binary error ≫ stochastic error.
        assert!(b[4] > s[4], "{app}: binary should degrade more at 20%");
    }
    println!("# paper shape: stoch ≤ ~7% even at 20%; binary degrades steeply; crossover ≈ 5%");
    println!("# generated in {secs:.1}s");
}
