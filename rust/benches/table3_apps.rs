//! E4 — regenerate paper Table 3: the four applications + headline
//! geomeans (135.7×/124.2×/1.5× in the paper).
use stoch_imc::config::Config;
use stoch_imc::report;

fn main() {
    let cfg = Config::default();
    let (rows, secs) = stoch_imc::util::timed(|| report::table3(&cfg));
    println!("# Table 3 — applications (normalized to binary IMC)");
    println!(
        "{:<6} {:>11} {:>9} | {:>9} {:>9} | {:>10} {:>10} | {:>8} {:>8}",
        "app", "bin subarr", "stoch", "area[22]", "areaS", "time[22]", "timeS", "en[22]", "enS"
    );
    for r in &rows {
        println!(
            "{:<6} {:>11} {:>9} | {:>9.3} {:>9.3} | {:>10.3} {:>10.4} | {:>8.3} {:>8.3}",
            r.app,
            format!("{}x{}", r.binary_subarray.0, r.binary_subarray.1),
            format!("{}x{}", r.stoch_subarray.0, r.stoch_subarray.1),
            r.area_sc_cram, r.area_stoch, r.time_sc_cram, r.time_stoch,
            r.energy_sc_cram, r.energy_stoch
        );
    }
    let (vs_bin, vs_scc, en) = report::headline(&rows);
    println!("\nheadline geomeans:");
    println!("  speedup vs binary IMC : {vs_bin:>9.1}x   (paper 135.7x)");
    println!("  speedup vs [22]       : {vs_scc:>9.1}x   (paper 124.2x)");
    println!("  energy vs binary IMC  : {:>9.2}x   (paper 1.5x reduction)", 1.0 / en);
    assert!(vs_bin > 10.0, "stoch must dominate binary on time");
    assert!(vs_scc > 10.0, "stoch must dominate [22] on time");
    println!("# generated in {secs:.1}s");
}
