//! E5 — regenerate paper Fig 10: energy breakdown per method per app,
//! plus the peripheral-constant sensitivity ablation (DESIGN.md §6).
use stoch_imc::config::Config;
use stoch_imc::report;

fn main() {
    let cfg = Config::default();
    let rows = report::table3(&cfg);
    println!("# Fig 10 — energy breakdown (%) [logic | preset/reset | input-init | peripheral]");
    for r in &rows {
        for (m, b) in [
            ("binary", &r.binary_energy_breakdown),
            ("[22]", &r.sc_cram_energy_breakdown),
            ("stoch", &r.stoch_energy_breakdown),
        ] {
            let p = b.percentages();
            println!(
                "{:<6} {:<7} | {:>6.1} {:>6.1} {:>6.1} {:>6.1}",
                r.app, m, p[0], p[1], p[2], p[3]
            );
        }
    }
    // Paper shape: logic+preset dominate for the compute-heavy apps;
    // OL's 10-gate circuit is legitimately accumulator-dominated.
    for r in &rows {
        let p = r.stoch_energy_breakdown.percentages();
        if r.app != "ol" {
            assert!(p[0] + p[1] > 50.0, "{}: logic+preset should dominate", r.app);
        }
    }
    // Sensitivity: ×4 peripheral constants must keep peripheral a minority.
    let mut cfg4 = Config::default();
    cfg4.energy.e_acc_local *= 4.0;
    cfg4.energy.e_acc_global *= 4.0;
    cfg4.energy.e_driver_cycle *= 4.0;
    println!("\n## ablation: peripheral constants ×4");
    for r in report::table3(&cfg4) {
        let p = r.stoch_energy_breakdown.percentages();
        println!("{:<6} stoch peripheral = {:>5.1}%", r.app, p[3]);
    }
}
