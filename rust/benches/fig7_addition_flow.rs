//! E2 — regenerate paper Fig 7: 4-bit in-memory addition, binary vs
//! stochastic, including the full per-cycle schedule dump.
use stoch_imc::baseline::{binary_op_netlist, BinaryOp};
use stoch_imc::netlist::{ops, replicate::replicate};
use stoch_imc::report;
use stoch_imc::scheduler::algorithm1::{schedule, Mode, Options};

fn main() {
    let (b, s) = report::fig7();
    println!("# Fig 7 — 4-bit in-memory addition sequence flow");
    println!("binary: {b} cycles (paper 9)   stochastic: {s} cycles (paper 4)");
    assert_eq!((b, s), (9, 4), "Fig 7 cycle counts regressed");

    println!("\n## binary RCA schedule (Fig 7a)");
    let nl = binary_op_netlist(BinaryOp::Add, 4, 4);
    let sch = schedule(&nl, &Options::default());
    for (t, step) in sch.steps.iter().enumerate() {
        println!(
            "  t{:<2} {:<8} ×{} rows={:?}",
            t + 1,
            format!("{:?}", step.ops[0].kind),
            step.ops.len(),
            step.ops.iter().map(|o| o.out.row).collect::<Vec<_>>()
        );
    }
    println!("\n## stochastic scaled-add schedule, 4 lanes (Fig 7b)");
    let rep = replicate(&ops::scaled_add(), 4);
    let sch = schedule(&rep, &Options::default());
    for (t, step) in sch.steps.iter().enumerate() {
        println!(
            "  t{:<2} {:<8} ×{} (all lanes simultaneously)",
            t + 1,
            format!("{:?}", step.ops[0].kind),
            step.ops.len()
        );
    }
    // Scheduler-mode ablation (design-choice bench, DESIGN.md §7).
    println!("\n## ablation: ASAP vs the paper's layer-strict Algorithm 1");
    for (name, nl) in [
        ("binary_add4", binary_op_netlist(BinaryOp::Add, 4, 4)),
        ("stoch_add×256", replicate(&ops::scaled_add(), 256)),
        ("stoch_exp×256", replicate(&ops::exponential(), 256)),
    ] {
        let a = schedule(&nl, &Options { mode: Mode::Asap }).logic_cycles();
        let l = schedule(&nl, &Options { mode: Mode::LayerStrict }).logic_cycles();
        println!("  {name:<14} asap={a:<4} layer-strict={l}");
    }
}
