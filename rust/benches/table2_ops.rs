//! E3 — regenerate paper Table 2: the six arithmetic operations.
use stoch_imc::config::Config;
use stoch_imc::report;

fn main() {
    let cfg = Config::default();
    let (rows, secs) = stoch_imc::util::timed(|| report::table2(&cfg));
    println!("# Table 2 — arithmetic operations (normalized to binary IMC)");
    println!(
        "{:<18} {:>11} {:>8} {:>8} | {:>9} {:>9} | {:>9} {:>9} | {:>8}",
        "operation", "bin array", "[22]", "stoch", "area[22]", "areaS", "time[22]", "timeS", "energyS"
    );
    for r in &rows {
        println!(
            "{:<18} {:>11} {:>8} {:>8} | {:>9.3} {:>9.3} | {:>9.3} {:>9.4} | {:>8.3}",
            r.op,
            format!("{}x{}", r.binary_array.0, r.binary_array.1),
            format!("{}x{}", r.sc_cram_array.0, r.sc_cram_array.1),
            format!("{}x{}", r.stoch_array.0, r.stoch_array.1),
            r.area_sc_cram, r.area_stoch, r.time_sc_cram, r.time_stoch, r.energy_stoch
        );
    }
    println!("# paper shapes: stoch time ≪ 1 everywhere; add/sub area > 1; sqrt/exp area ≪ 1");
    println!("# generated in {secs:.1}s");
}
