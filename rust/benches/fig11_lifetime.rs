//! E6 — regenerate paper Fig 11: lifetime improvement (Eq 11).
use stoch_imc::config::Config;
use stoch_imc::report;
use stoch_imc::util::stats::geomean;

fn main() {
    let cfg = Config::default();
    let rows = report::table3(&cfg);
    println!("# Fig 11 — lifetime improvement over binary IMC (Eq 11, used-cell capacity / write traffic)");
    let mut st = Vec::new();
    let mut ratio = Vec::new();
    for (app, s, c) in report::fig11(&rows) {
        println!("{app:<6}  Stoch-IMC {s:>10.2}x    [22] {c:>10.4}x");
        assert!(s > c, "{app}: Stoch-IMC must outlive the bit-serial [22]");
        st.push(s);
        ratio.push(s / c);
    }
    println!("\ngeomean Stoch-IMC vs binary : {:>8.1}x (paper 4.9x)", geomean(&st));
    println!("geomean Stoch-IMC vs [22]   : {:>8.1}x (paper 216.3x)", geomean(&ratio));
}
