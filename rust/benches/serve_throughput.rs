//! §Perf — serve-layer throughput: single-shard vs. multi-shard serving
//! and sequential vs. row-parallel wave execution, on the committed
//! artifact set. Emits machine-readable ops/sec into `BENCH_serve.json`
//! (merged, so `perf_hotpath` numbers accumulate in the same file) for
//! cross-PR perf tracking.
//!
//! Run: cargo bench --bench serve_throughput

use std::path::Path;
use std::time::Instant;

use stoch_imc::coordinator::BatcherConfig;
use stoch_imc::runtime::InterpEngine;
use stoch_imc::serve::{Server, ServerConfig};
use stoch_imc::util::benchjson;

/// The mixed serving workload: two ops and two apps, exercising both
/// cheap and heavy kernels (app_hdp runs BL=1024 per the manifest).
const APPS: &[(&str, usize)] =
    &[("op_multiply", 2), ("op_scaled_add", 2), ("app_ol", 6), ("app_hdp", 8)];

fn workload(n_inputs: usize, n: usize) -> Vec<Vec<f64>> {
    (0..n).map(|i| vec![0.15 + 0.05 * (i % 14) as f64; n_inputs]).collect()
}

/// Drive all four workloads through a server from one caller thread per
/// app (the multi-bank serving pattern); returns aggregate instances/s.
fn drive(server: &Server, per_app: usize) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for &(name, n_inputs) in APPS {
            s.spawn(move || {
                let w = workload(n_inputs, per_app);
                server.run_workload(name, &w).expect("workload");
            });
        }
    });
    (APPS.len() * per_app) as f64 / t0.elapsed().as_secs_f64()
}

fn server(shards: usize, row_threads: usize) -> Server {
    Server::start(
        Path::new("artifacts"),
        ServerConfig {
            shards,
            row_threads,
            batcher: BatcherConfig::default(),
            ..ServerConfig::default()
        },
    )
    .expect("server start")
}

fn main() {
    if !Path::new("artifacts/manifest.txt").exists() {
        println!("(artifacts not built — skipping serve benches)");
        return;
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("# §Perf — serve-layer throughput (cores={cores})");
    let per_app = 512;
    let mut results: Vec<(String, f64)> = Vec::new();

    // The serving matrix: shards × row-parallelism. single+seq is the
    // old Coordinator topology; multi+par is the full bank-parallel
    // path. "auto" row workers resolve to cores ÷ shards inside the
    // pool, so on few-core machines the multi-shard rows_par config
    // degenerates to rows_seq by design (shard parallelism already
    // covers the cores) — the single-shard pair isolates the row win.
    for (label, shards, row_threads) in [
        ("serve_single_shard_rows_seq", 1usize, 1usize),
        ("serve_single_shard_rows_par", 1, 0),
        ("serve_multi_shard_rows_seq", 0, 1),
        ("serve_multi_shard_rows_par", 0, 0),
    ] {
        let srv = server(shards, row_threads);
        drive(&srv, 64); // warmup
        let ops = drive(&srv, per_app);
        let rows = if row_threads == 0 { "auto".to_string() } else { row_threads.to_string() };
        println!(
            "{label:<30} shards={} rows={rows} {ops:>10.0} instances/s",
            srv.n_shards(),
        );
        results.push((label.to_string(), ops));
    }

    // Row-parallel wave execution in isolation: one heavy wave (app_hdp,
    // BL=1024, batch 64) on the bare interpreter — the acceptance check
    // that the scoped row pool beats the sequential path.
    let engine = InterpEngine::load(Path::new("artifacts")).expect("engine");
    if let Some(spec) = engine.spec("app_hdp") {
        let (batch, n_inputs) = (spec.batch, spec.n_inputs);
        let values: Vec<f32> = (0..batch * n_inputs)
            .map(|i| 0.2 + 0.05 * (i % 12) as f32)
            .collect();
        let reps = 24;
        let mut per_cfg = Vec::new();
        for (label, threads) in
            [("interp_rows_seq_hdp_wave", 1usize), ("interp_rows_par_hdp_wave", 0)]
        {
            // Warmup.
            engine.execute_rows("app_hdp", &values, 1, batch, threads).expect("wave");
            let t0 = Instant::now();
            for rep in 0..reps {
                engine
                    .execute_rows("app_hdp", &values, rep as i32, batch, threads)
                    .expect("wave");
            }
            let rows_per_s = (reps * batch) as f64 / t0.elapsed().as_secs_f64();
            println!("{label:<30} {rows_per_s:>10.0} rows/s");
            per_cfg.push(rows_per_s);
            results.push((label.to_string(), rows_per_s));
        }
        println!(
            "row-parallel speedup on a {batch}-row wave: {:.2}x over sequential",
            per_cfg[1] / per_cfg[0]
        );

        // Degraded-mode throughput: the same heavy wave two ladder steps
        // down (BL 1024 → 256). An absolute rows/s number, deliberately
        // not a *_speedup key — it tracks what a shard buys by degrading
        // under overload, not a path-vs-path regression gate.
        engine
            .execute_rows_degraded("app_hdp", &values, 1, batch, 0, 0, None, None, 2)
            .expect("wave");
        let t0 = Instant::now();
        for rep in 0..reps {
            engine
                .execute_rows_degraded("app_hdp", &values, rep as i32, batch, 0, 0, None, None, 2)
                .expect("wave");
        }
        let degraded_rows_per_s = (reps * batch) as f64 / t0.elapsed().as_secs_f64();
        println!("{:<30} {degraded_rows_per_s:>10.0} rows/s", "serve_degraded_rows_per_s");
        results.push(("serve_degraded_rows_per_s".to_string(), degraded_rows_per_s));
    }

    // The TCP front door on loopback: the full wire path (encode →
    // socket → decode → shard pool → response) with one retrying
    // client per app. An absolute rows/s number, deliberately not a
    // *_speedup key — loopback TCP always costs something over the
    // in-process path; this tracks *how much*, not a gate.
    {
        use std::sync::Arc;
        use stoch_imc::serve::net::{Client, ClientConfig};
        use stoch_imc::serve::{TcpFront, TcpFrontConfig};

        let srv = Arc::new(
            Server::start(Path::new("artifacts"), ServerConfig::default()).expect("server start"),
        );
        let front = TcpFront::start(
            srv,
            TcpFrontConfig { addr: "127.0.0.1:0".into(), ..TcpFrontConfig::default() },
        )
        .expect("tcp front start");
        let addr = front.local_addr().to_string();
        let per_app = 256;
        let run = |per_app: usize| {
            let t0 = Instant::now();
            std::thread::scope(|s| {
                for &(name, n_inputs) in APPS {
                    let addr = addr.clone();
                    s.spawn(move || {
                        let mut client = Client::new(addr, ClientConfig::default());
                        for x in workload(n_inputs, per_app) {
                            client.call(name, &x).expect("loopback call");
                        }
                    });
                }
            });
            (APPS.len() * per_app) as f64 / t0.elapsed().as_secs_f64()
        };
        run(32); // warmup
        let rows_per_s = run(per_app);
        println!("{:<30} {rows_per_s:>10.0} rows/s", "serve_tcp_loopback_rows_per_s");
        results.push(("serve_tcp_loopback_rows_per_s".to_string(), rows_per_s));
    }

    let out = Path::new(benchjson::BENCH_FILE);
    benchjson::merge_and_write(out, &results).expect("writing bench json");
    println!("wrote {} keys to {}", results.len(), out.display());
}
