//! E1 — regenerate paper Fig 3: P_sw vs V_p for t_p ∈ {3..10} ns.
use stoch_imc::config::Config;
use stoch_imc::report;

fn main() {
    let cfg = Config::default();
    let (series, secs) = stoch_imc::util::timed(|| report::fig3(&cfg.device));
    println!("# Fig 3 — MTJ switching probability (Eqs 1–2, Table 1 + DESIGN.md §6 calibration)");
    print!("{:>6}", "V_p");
    for (tp, _) in &series {
        print!(" {:>8}", format!("{tp}ns"));
    }
    println!();
    for i in 0..series[0].1.len() {
        print!("{:>6.3}", series[0].1[i].0);
        for (_, s) in &series {
            print!(" {:>8.4}", s[i].1);
        }
        println!();
    }
    println!("# anchor: P_sw(0.310V, 4ns) should be 0.70 (paper §2.3)");
    println!("# generated in {secs:.3}s");
}
