//! E8 — §Perf: hot-path microbenchmarks for the three layers' L3-side
//! components plus the end-to-end PJRT wave throughput.
//!
//! L3 hot paths: packed-bitstream gate ops (64 lanes/word), the
//! scheduler on large netlists, and the coordinator wave loop. Each is
//! timed over enough iterations for stable numbers; results are logged
//! in EXPERIMENTS.md §Perf (before/after the optimization pass).
use std::collections::HashMap;
use std::time::Instant;

use stoch_imc::netlist::{ops, replicate::replicate};
use stoch_imc::sc::bitstream::Bitstream;
use stoch_imc::scheduler::algorithm1::{schedule, Options};
use stoch_imc::util::prng::Xoshiro256;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>12.3} µs/iter", per * 1e6);
    per
}

fn main() {
    println!("# §Perf — hot-path microbenchmarks");
    let mut rng = Xoshiro256::seeded(1);

    // L3a: packed bitstream ops (the functional simulator's hot loop).
    let a = Bitstream::sample(0.5, 65536, &mut rng);
    let b = Bitstream::sample(0.5, 65536, &mut rng);
    let and_t = bench("bitstream AND 64k bits", 10_000, || {
        std::hint::black_box(a.and(&b));
    });
    println!(
        "{:<44} {:>12.1} Gbit/s",
        "  → elementwise gate throughput",
        65536.0 / and_t / 1e9
    );
    bench("bitstream popcount 64k bits", 10_000, || {
        std::hint::black_box(a.popcount());
    });
    bench("SNG sample 64k bits", 100, || {
        std::hint::black_box(Bitstream::sample(0.5, 65536, &mut rng));
    });

    // L3b: scheduler on a large replicated netlist (exp × 256 lanes).
    let rep = replicate(&ops::exponential(), 256);
    bench("Algorithm 1 (ASAP) exp×256 (3328 gates)", 20, || {
        std::hint::black_box(schedule(&rep, &Options::default()));
    });

    // L3c: sequential divider scan (the one bit-serial code path).
    bench("JK divider scan 64k bits", 1_000, || {
        std::hint::black_box(stoch_imc::sc::ops::scaled_divide(&a, &b));
    });

    // End-to-end: PJRT wave throughput per artifact (needs artifacts).
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        use stoch_imc::coordinator::{BatcherConfig, Coordinator};
        println!("\n# end-to-end PJRT wave throughput (batch=64, BL=256)");
        let coord = Coordinator::start(dir, BatcherConfig::default()).expect("coordinator");
        let mut results: HashMap<String, f64> = HashMap::new();
        // app_lit/app_kde excluded: their XLA compiles take minutes and
        // the examples cover them end-to-end (EXPERIMENTS.md).
        for (name, n_in, waves) in [
            ("op_multiply", 2usize, 40usize),
            ("op_scaled_divide", 2, 40),
            ("app_ol", 6, 20),
            ("app_hdp", 8, 20),
        ] {
            let batch: Vec<Vec<f64>> =
                (0..64).map(|i| vec![0.3 + 0.005 * i as f64; n_in]).collect();
            // Warmup (compilation already done at load).
            let _ = coord.run_workload(name, &batch).unwrap();
            let t0 = Instant::now();
            for _ in 0..waves {
                let _ = coord.run_workload(name, &batch).unwrap();
            }
            let dt = t0.elapsed().as_secs_f64();
            let inst_per_s = (waves * 64) as f64 / dt;
            println!("{name:<18} {:>10.0} instances/s ({:.2} ms/wave)", inst_per_s, dt * 1e3 / waves as f64);
            results.insert(name.to_string(), inst_per_s);
        }
    } else {
        println!("\n(artifacts not built — skipping end-to-end PJRT benches)");
    }
}
