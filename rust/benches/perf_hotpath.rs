//! E8 — §Perf: hot-path microbenchmarks for the three layers' L3-side
//! components plus the end-to-end coordinator wave throughput.
//!
//! L3 hot paths: packed-bitstream gate ops (64 lanes/word), the
//! scheduler on large netlists, scalar-vs-word-parallel netlist waves
//! (the transposed lane-block engine), and the coordinator wave loop.
//! Each is
//! timed over enough iterations for stable numbers; results are logged
//! in EXPERIMENTS.md §Perf and merged into `BENCH_serve.json` (shared
//! with `serve_throughput`; ops/sec per key, plus dimensionless
//! `*_speedup` ratios) so the perf trajectory is tracked across PRs.
use std::time::Instant;

use stoch_imc::netlist::{ops, replicate::replicate};
use stoch_imc::sc::bitstream::Bitstream;
use stoch_imc::scheduler::algorithm1::{schedule, Options};
use stoch_imc::util::benchjson;
use stoch_imc::util::prng::Xoshiro256;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>12.3} µs/iter", per * 1e6);
    per
}

fn main() {
    println!("# §Perf — hot-path microbenchmarks");
    let mut rng = Xoshiro256::seeded(1);
    let mut results: Vec<(String, f64)> = Vec::new();

    // L3a: packed bitstream ops (the functional simulator's hot loop).
    let a = Bitstream::sample(0.5, 65536, &mut rng);
    let b = Bitstream::sample(0.5, 65536, &mut rng);
    let and_t = bench("bitstream AND 64k bits", 10_000, || {
        std::hint::black_box(a.and(&b));
    });
    println!(
        "{:<44} {:>12.1} Gbit/s",
        "  → elementwise gate throughput",
        65536.0 / and_t / 1e9
    );
    results.push(("hotpath_bitstream_and_64k_ops_per_s".to_string(), 1.0 / and_t));
    let pop_t = bench("bitstream popcount 64k bits", 10_000, || {
        std::hint::black_box(a.popcount());
    });
    results.push(("hotpath_popcount_64k_ops_per_s".to_string(), 1.0 / pop_t));
    let sng_t = bench("SNG sample 64k bits", 100, || {
        std::hint::black_box(Bitstream::sample(0.5, 65536, &mut rng));
    });
    results.push(("hotpath_sng_64k_ops_per_s".to_string(), 1.0 / sng_t));

    // L3a': wave-shaped SNG — scalar per-row bitstreams (one PRNG per
    // row, the pre-lane-major wave path) vs the lane-major RNG-bank
    // path packing 256 rows into u64×4 lane words, vs the counter-based
    // stateless path (the default generator since PR 8). Each family
    // generates its own pinned bits; the ratios isolate generation
    // cost — the dominant wave cost once gate eval is word-parallel.
    {
        use stoch_imc::sc::bitplane::LaneBlock;
        use stoch_imc::sc::sng;
        use stoch_imc::util::prng::{counter_node_part, fnv1a, CounterBank, RngBank};
        const ROWS: usize = 256;
        const BL: usize = 256;
        let h = fnv1a("bench_sng");
        let vals: Vec<f64> = (0..ROWS).map(|i| 0.05 + 0.9 * (i as f64) / ROWS as f64).collect();
        let mut cutoffs = Vec::new();
        sng::load_cutoffs(&vals, &mut cutoffs);
        let sng_scalar_t = bench("SNG scalar 256 rows × BL=256", 1_000, || {
            for (row, &v) in vals.iter().enumerate() {
                let mut row_rng = Xoshiro256::seeded(h ^ ((row as u64) << 32));
                std::hint::black_box(Bitstream::sample(v, BL, &mut row_rng));
            }
        });
        let mut bank = RngBank::new();
        let mut scratch = sng::SngScratch::default();
        let mut block: LaneBlock<4> = LaneBlock::zeros(0, 0);
        let sng_lane_t = bench("SNG lane-major 256 rows × BL=256", 1_000, || {
            bank.reseed_with(ROWS, |l| h ^ ((l as u64) << 32));
            sng::sample_block(&cutoffs, BL, &mut bank, &mut scratch, &mut block);
            std::hint::black_box(block.word(BL - 1));
        });
        let sng_speedup = sng_scalar_t / sng_lane_t;
        println!("{:<44} {:>11.2}x", "  → lane-major SNG speedup", sng_speedup);
        results.push(("hotpath_sng_scalar_rows_per_s".to_string(), ROWS as f64 / sng_scalar_t));
        results.push(("hotpath_sng_lanemajor_rows_per_s".to_string(), ROWS as f64 / sng_lane_t));
        results.push(("hotpath_sng_lanemajor_speedup".to_string(), sng_speedup));
        // Counter path, same wave shape (reseed inside the loop both
        // ways, so per-wave key setup is costed symmetrically).
        let mut ctr = CounterBank::new();
        let node = sng::sng_node(sng::NODE_INPUT, 0, 0);
        let sng_counter_t = bench("SNG counter 256 rows × BL=256", 1_000, || {
            ctr.reseed_with(ROWS, |l| h ^ ((l as u64) << 32));
            sng::sample_block_counter(&cutoffs, BL, &ctr, node, &mut scratch, &mut block);
            std::hint::black_box(block.word(BL - 1));
        });
        let counter_speedup = sng_lane_t / sng_counter_t;
        println!("{:<44} {:>11.2}x", "  → counter vs lockstep-xoshiro SNG", counter_speedup);
        results.push(("hotpath_sng_counter_rows_per_s".to_string(), ROWS as f64 / sng_counter_t));
        results.push(("hotpath_sng_counter_speedup".to_string(), counter_speedup));
        // Raw counter draw throughput (the mix64 kernel the simd
        // feature vectorizes): one 256-key bank swept over 256 steps.
        let np = counter_node_part(node);
        let mut buf = vec![0u64; ROWS];
        let draw_t = bench("counter RNG raw draws 256 keys × 256 steps", 2_000, || {
            for t in 0..BL as u64 {
                ctr.draws_at_into(np, t, &mut buf);
            }
            std::hint::black_box(buf[ROWS - 1]);
        });
        results.push((
            "hotpath_rng_draw_words_per_s".to_string(),
            (ROWS * BL) as f64 / draw_t,
        ));
    }

    // L3b: scheduler on a large replicated netlist (exp × 256 lanes).
    let rep = replicate(&ops::exponential(), 256);
    let sched_t = bench("Algorithm 1 (ASAP) exp×256 (3328 gates)", 20, || {
        std::hint::black_box(schedule(&rep, &Options::default()));
    });
    results.push(("hotpath_schedule_exp256_ops_per_s".to_string(), 1.0 / sched_t));

    // L3c: sequential divider scan (the one bit-serial code path).
    let div_t = bench("JK divider scan 64k bits", 1_000, || {
        std::hint::black_box(stoch_imc::sc::ops::scaled_divide(&a, &b));
    });
    results.push(("hotpath_jk_divider_64k_ops_per_s".to_string(), 1.0 / div_t));

    // L3d: scalar per-row vs word-parallel lane-block netlist waves —
    // the acceptance lever for the lane-major wave engine. Both paths
    // run single-threaded so the ratio isolates the lane pipeline
    // (RNG-bank SNG → packed gate eval → vertical-counter StoB) from
    // thread parallelism; both produce bit-identical outputs, so the
    // speedup is what a serving wave actually sees.
    {
        use stoch_imc::runtime::InterpEngine;
        let dir = std::env::temp_dir().join("stoch_imc_perf_wordpar");
        std::fs::create_dir_all(&dir).expect("tmp dir");
        std::fs::write(
            dir.join("manifest.txt"),
            "op_multiply 2 128 256\nop_exponential 1 128 256\napp_hdp 8 128 1024\n",
        )
        .expect("manifest");
        let e = InterpEngine::load(&dir).expect("interp engine");
        println!("\n# scalar vs word-parallel netlist waves (128 live rows, 1 thread)");
        for (name, n_in, iters) in
            [("op_multiply", 2usize, 40usize), ("op_exponential", 1, 30), ("app_hdp", 8, 10)]
        {
            let mut values = vec![0.0f32; 128 * n_in];
            for (i, v) in values.iter_mut().enumerate() {
                *v = 0.05 + 0.9 * ((i * 37) % 101) as f32 / 101.0;
            }
            let scalar_t = bench(&format!("{name} scalar wave (128 rows)"), iters, || {
                std::hint::black_box(e.execute_rows_scalar(name, &values, 3, 128, 1).unwrap());
            });
            let word_t = bench(&format!("{name} word-parallel wave (128 rows)"), iters * 4, || {
                std::hint::black_box(e.execute_rows(name, &values, 3, 128, 1).unwrap());
            });
            let speedup = scalar_t / word_t;
            println!("{:<44} {:>11.2}x", format!("  → {name} word-parallel speedup"), speedup);
            results.push((format!("hotpath_scalar_{name}_rows_per_s"), 128.0 / scalar_t));
            results.push((format!("hotpath_wordpar_{name}_rows_per_s"), 128.0 / word_t));
            results.push((format!("hotpath_wordpar_{name}_speedup"), speedup));
        }
    }

    // L3e: staged-app waves — the multi-stage pipelines (LIT: trees →
    // correlated XOR → ADDIE √; KDE: correlated XORs → 5-stage
    // exponential products) through the scalar staged reference vs the
    // lane-major staged executor with in-lane StoB→BtoS regeneration.
    // Single-threaded both ways, so the ratio isolates the staged lane
    // pipeline; bit-identical outputs (tests/staged.rs), so the
    // speedup is what a serving wave actually sees.
    {
        use stoch_imc::runtime::InterpEngine;
        let dir = std::env::temp_dir().join("stoch_imc_perf_staged");
        std::fs::create_dir_all(&dir).expect("tmp dir");
        std::fs::write(dir.join("manifest.txt"), "app_lit 64 128 256\napp_kde 9 128 256\n")
            .expect("manifest");
        let e = InterpEngine::load(&dir).expect("interp engine");
        println!("\n# scalar vs lane-major staged app waves (128 live rows, 1 thread)");
        for (name, short, n_in) in [("app_lit", "lit", 64usize), ("app_kde", "kde", 9)] {
            let mut values = vec![0.0f32; 128 * n_in];
            for (i, v) in values.iter_mut().enumerate() {
                *v = 0.05 + 0.9 * ((i * 41) % 103) as f32 / 103.0;
            }
            let scalar_t = bench(&format!("{name} scalar staged wave (128 rows)"), 3, || {
                std::hint::black_box(e.execute_rows_scalar(name, &values, 5, 128, 1).unwrap());
            });
            let lane_t = bench(&format!("{name} lane-major staged wave (128 rows)"), 12, || {
                std::hint::black_box(e.execute_rows(name, &values, 5, 128, 1).unwrap());
            });
            let speedup = scalar_t / lane_t;
            println!("{:<44} {:>11.2}x", format!("  → {name} staged lane speedup"), speedup);
            results.push((format!("hotpath_staged_{short}_scalar_rows_per_s"), 128.0 / scalar_t));
            results.push((format!("hotpath_staged_{short}_lanemajor_rows_per_s"), 128.0 / lane_t));
            results.push((format!("hotpath_staged_{short}_lanemajor_speedup"), speedup));
        }
    }

    // L3f: SNG block cache — a steady-state serving shape (the same
    // wave re-executed under one seed, e.g. a replayed benchmark batch)
    // where every block comes out of the engine-level cache instead of
    // being regenerated. The hit rate lands in BENCH_serve.json so the
    // cache's effectiveness is tracked alongside its speed.
    {
        use stoch_imc::runtime::InterpEngine;
        use stoch_imc::util::prng::RngMode;
        let dir = std::env::temp_dir().join("stoch_imc_perf_sngcache");
        std::fs::create_dir_all(&dir).expect("tmp dir");
        std::fs::write(dir.join("manifest.txt"), "op_multiply 2 256 256\n").expect("manifest");
        let e = InterpEngine::load(&dir).expect("interp engine");
        let mut values = vec![0.0f32; 256 * 2];
        for i in 0..256 {
            values[2 * i] = 0.6;
            values[2 * i + 1] = 0.3;
        }
        let run = || {
            e.execute_rows_tuned("op_multiply", &values, 3, 256, 1, 0, Some(RngMode::Counter), None)
                .unwrap()
        };
        let (_, cold) = run();
        let warm_t = bench("op_multiply cached wave (256 rows, repeat)", 200, || {
            std::hint::black_box(run());
        });
        let (_, warm) = run();
        println!(
            "{:<44} {:>10.0}% (cold {:.0}%)",
            "  → SNG block-cache hit rate (warm)",
            100.0 * warm.cache.hit_rate(),
            100.0 * cold.cache.hit_rate()
        );
        results.push(("hotpath_sng_cache_hit_rate".to_string(), warm.cache.hit_rate()));
        results.push(("hotpath_sng_cached_wave_rows_per_s".to_string(), 256.0 / warm_t));
    }

    // End-to-end: coordinator wave throughput per artifact on whichever
    // backend STOCH_IMC_BACKEND selects (needs artifacts/manifest.txt).
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        use stoch_imc::coordinator::{BatcherConfig, Coordinator};
        println!("\n# end-to-end coordinator wave throughput (batch=64)");
        let coord = Coordinator::start(dir, BatcherConfig::default()).expect("coordinator");
        // app_lit/app_kde excluded: their XLA compiles take minutes and
        // the examples cover them end-to-end (EXPERIMENTS.md).
        for (name, n_in, waves) in [
            ("op_multiply", 2usize, 40usize),
            ("op_scaled_divide", 2, 40),
            ("app_ol", 6, 20),
            ("app_hdp", 8, 20),
        ] {
            let batch: Vec<Vec<f64>> =
                (0..64).map(|i| vec![0.3 + 0.005 * i as f64; n_in]).collect();
            // Warmup (compilation already done at load).
            let _ = coord.run_workload(name, &batch).unwrap();
            let t0 = Instant::now();
            for _ in 0..waves {
                let _ = coord.run_workload(name, &batch).unwrap();
            }
            let dt = t0.elapsed().as_secs_f64();
            let inst_per_s = (waves * 64) as f64 / dt;
            println!(
                "{name:<18} {:>10.0} instances/s ({:.2} ms/wave)",
                inst_per_s,
                dt * 1e3 / waves as f64
            );
            results.push((format!("hotpath_e2e_{name}_inst_per_s"), inst_per_s));
        }
    } else {
        println!("\n(artifacts not built — skipping end-to-end benches)");
    }

    let out = std::path::Path::new(benchjson::BENCH_FILE);
    benchjson::merge_and_write(out, &results).expect("writing bench json");
    println!("\nwrote {} keys to {}", results.len(), out.display());
}
