//! Minimal TOML-subset parser (serde is not in the offline crate set).
//!
//! Supported: `[section]` / `[section.sub]` headers, `key = value` with
//! integers, floats, booleans, quoted strings, and flat arrays of those;
//! `#` comments; blank lines. That is all the config files here use.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Dotted-key → value map, e.g. `"arch.groups" → Int(16)`.
pub type Table = BTreeMap<String, Value>;

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

fn parse_scalar(s: &str, line: usize) -> Result<Value, ParseError> {
    let s = s.trim();
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ParseError { line, message: format!("cannot parse value `{s}`") })
}

/// Parse a TOML-subset document into a flat dotted-key table.
pub fn parse(text: &str) -> Result<Table, ParseError> {
    let mut table = Table::new();
    let mut section = String::new();
    for (i, raw) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = match raw.find('#') {
            // Only strip comments outside quotes (our strings never
            // contain '#'; keep the parser simple).
            Some(pos) if !raw[..pos].contains('"') || raw[..pos].matches('"').count() % 2 == 0 => {
                &raw[..pos]
            }
            _ => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(ParseError { line: line_no, message: "unterminated section".into() });
            }
            section = line[1..line.len() - 1].trim().to_string();
            if section.is_empty() {
                return Err(ParseError { line: line_no, message: "empty section name".into() });
            }
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(ParseError { line: line_no, message: format!("expected key=value, got `{line}`") });
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(ParseError { line: line_no, message: "empty key".into() });
        }
        let raw_val = line[eq + 1..].trim();
        let value = if raw_val.starts_with('[') {
            if !raw_val.ends_with(']') {
                return Err(ParseError { line: line_no, message: "unterminated array".into() });
            }
            let inner = &raw_val[1..raw_val.len() - 1];
            let items: Result<Vec<Value>, ParseError> = inner
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| parse_scalar(s, line_no))
                .collect();
            Value::Array(items?)
        } else {
            parse_scalar(raw_val, line_no)?
        };
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        table.insert(full_key, value);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let t = parse(
            r#"
# top comment
title = "stoch-imc"
[arch]
groups = 16
subarrays = 16   # per group
rows = 256
[device]
delta = 40.0
calibrate = true
pulse_ns = [3, 4.5, 10]
"#,
        )
        .unwrap();
        assert_eq!(t["title"].as_str(), Some("stoch-imc"));
        assert_eq!(t["arch.groups"].as_usize(), Some(16));
        assert_eq!(t["device.delta"].as_f64(), Some(40.0));
        assert_eq!(t["device.calibrate"].as_bool(), Some(true));
        match &t["device.pulse_ns"] {
            Value::Array(xs) => assert_eq!(xs.len(), 3),
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn error_reports_line() {
        let err = parse("a = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn rejects_bad_value() {
        assert!(parse("x = what").is_err());
    }

    #[test]
    fn underscored_numbers() {
        let t = parse("n = 1_000_000\n").unwrap();
        assert_eq!(t["n"].as_usize(), Some(1_000_000));
    }
}
