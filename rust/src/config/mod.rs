//! S16 — configuration system.
//!
//! A hand-rolled TOML-subset parser (`toml.rs`) plus typed config structs
//! for the architecture, device, energy model and application workloads.
//! `configs/default.toml` holds the paper's evaluation setup (§5.1):
//! one bank, n=16 groups × m=16 subarrays of 256×256 cells, BL=256,
//! 8-bit resolution, pipeline policy.

pub mod toml;

use std::path::Path;

use crate::device::MtjParams;
use crate::energy::EnergyParams;
use toml::{parse, Table, Value};

/// Bitstream distribution policy when BL > n×m (§4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Reuse one bank over sub-bitstream pairs (min area, more latency).
    Pipeline,
    /// Spread over parallel banks (min latency, more area).
    Parallel,
}

/// Architecture configuration ([n, m] of §4.3).
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// Groups per bank (n).
    pub groups: usize,
    /// Subarrays per group (m).
    pub subarrays_per_group: usize,
    pub subarray_rows: usize,
    pub subarray_cols: usize,
    /// Bitstream length (2^resolution).
    pub bitstream_len: usize,
    /// Binary resolution in bits.
    pub resolution: u32,
    pub policy: Policy,
    /// Banks (the paper evaluates 1 for fairness with [22]).
    pub banks: usize,
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self {
            groups: 16,
            subarrays_per_group: 16,
            subarray_rows: 256,
            subarray_cols: 256,
            bitstream_len: 256,
            resolution: 8,
            policy: Policy::Pipeline,
            banks: 1,
        }
    }
}

impl ArchConfig {
    /// Total subarrays n×m per bank.
    pub fn total_subarrays(&self) -> usize {
        self.groups * self.subarrays_per_group
    }

    /// BtoS memory size in bytes: 2^resolution entries (§4.3).
    pub fn btos_bytes(&self) -> usize {
        1usize << self.resolution
    }
}

/// Full run configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub arch: ArchConfig,
    pub device: MtjParams,
    pub energy: EnergyParams,
    pub seed: u64,
}

fn get_usize(t: &Table, key: &str, default: usize) -> usize {
    t.get(key).and_then(Value::as_usize).unwrap_or(default)
}

fn get_f64(t: &Table, key: &str, default: f64) -> f64 {
    t.get(key).and_then(Value::as_f64).unwrap_or(default)
}

impl Config {
    /// Parse from TOML-subset text; unknown keys are ignored, missing
    /// keys take paper defaults.
    pub fn from_text(text: &str) -> Result<Self, toml::ParseError> {
        let t = parse(text)?;
        let mut cfg = Config { seed: get_usize(&t, "seed", 0x570C41) as u64, ..Config::default() };

        let a = &mut cfg.arch;
        a.groups = get_usize(&t, "arch.groups", a.groups);
        a.subarrays_per_group = get_usize(&t, "arch.subarrays_per_group", a.subarrays_per_group);
        a.subarray_rows = get_usize(&t, "arch.subarray_rows", a.subarray_rows);
        a.subarray_cols = get_usize(&t, "arch.subarray_cols", a.subarray_cols);
        a.bitstream_len = get_usize(&t, "arch.bitstream_len", a.bitstream_len);
        a.resolution = get_usize(&t, "arch.resolution", a.resolution as usize) as u32;
        a.banks = get_usize(&t, "arch.banks", a.banks);
        if let Some(p) = t.get("arch.policy").and_then(Value::as_str) {
            a.policy = match p {
                "pipeline" => Policy::Pipeline,
                "parallel" => Policy::Parallel,
                other => {
                    return Err(toml::ParseError {
                        line: 0,
                        message: format!("unknown policy `{other}`"),
                    })
                }
            };
        }

        let d = &mut cfg.device;
        d.delta = get_f64(&t, "device.delta", d.delta);
        d.tau_0 = get_f64(&t, "device.tau_0", d.tau_0);
        d.v_c0 = get_f64(&t, "device.v_c0", d.v_c0);
        d.r_p = get_f64(&t, "device.r_p", d.r_p);
        d.r_ap = get_f64(&t, "device.r_ap", d.r_ap);

        let e = &mut cfg.energy;
        e.e_sbg = get_f64(&t, "energy.e_sbg", e.e_sbg);
        e.e_write = get_f64(&t, "energy.e_write", e.e_write);
        e.e_acc_local = get_f64(&t, "energy.e_acc_local", e.e_acc_local);
        e.e_acc_global = get_f64(&t, "energy.e_acc_global", e.e_acc_global);
        e.e_driver_cycle = get_f64(&t, "energy.e_driver_cycle", e.e_driver_cycle);
        e.e_btos_lookup = get_f64(&t, "energy.e_btos_lookup", e.e_btos_lookup);

        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Self, Box<dyn std::error::Error>> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::from_text(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_setup() {
        let c = Config::default();
        assert_eq!(c.arch.groups, 16);
        assert_eq!(c.arch.subarrays_per_group, 16);
        assert_eq!(c.arch.subarray_rows, 256);
        assert_eq!(c.arch.bitstream_len, 256);
        assert_eq!(c.arch.total_subarrays(), 256);
        assert_eq!(c.arch.btos_bytes(), 256);
    }

    #[test]
    fn overrides_apply() {
        let c = Config::from_text(
            "[arch]\ngroups = 8\npolicy = \"parallel\"\n[energy]\ne_sbg = 1e-18\n",
        )
        .unwrap();
        assert_eq!(c.arch.groups, 8);
        assert_eq!(c.arch.policy, Policy::Parallel);
        assert_eq!(c.energy.e_sbg, 1e-18);
        // Untouched keys keep defaults.
        assert_eq!(c.arch.subarrays_per_group, 16);
    }

    #[test]
    fn bad_policy_rejected() {
        assert!(Config::from_text("[arch]\npolicy = \"zigzag\"\n").is_err());
    }
}
