//! Dynamic batcher: groups per-instance requests into artifact-sized
//! waves (one wave = one subarray-group execution). A wave closes when
//! full or when the oldest request has waited `max_wait`; partial waves
//! are zero-padded (padded slots are wasted subarray capacity, a metric
//! the coordinator reports).

use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Wave size = artifact batch dimension.
    pub batch: usize,
    /// Close a partial wave after this wait.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// One pending request: flattened inputs + the response channel.
pub struct Pending {
    pub inputs: Vec<f32>,
    pub respond: Sender<f32>,
    pub enqueued: Instant,
}

/// A closed wave ready for execution.
pub struct Batch {
    /// Row-major [batch, n_inputs], zero-padded.
    pub values: Vec<f32>,
    /// Response channels for the live (non-padding) rows.
    pub responders: Vec<Sender<f32>>,
    pub padded: usize,
}

/// Accumulates pending requests into waves.
pub struct Batcher {
    cfg: BatcherConfig,
    n_inputs: usize,
    pending: Vec<Pending>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig, n_inputs: usize) -> Self {
        Self { cfg, n_inputs, pending: Vec::new() }
    }

    pub fn push(&mut self, p: Pending) {
        assert_eq!(p.inputs.len(), self.n_inputs, "input arity mismatch");
        self.pending.push(p);
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Whether a wave should close now.
    pub fn ready(&self, now: Instant) -> bool {
        if self.pending.len() >= self.cfg.batch {
            return true;
        }
        match self.pending.first() {
            Some(p) => now.duration_since(p.enqueued) >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Close and return one wave (up to `batch` requests, zero-padded).
    pub fn drain(&mut self) -> Batch {
        let take = self.pending.len().min(self.cfg.batch);
        let live: Vec<Pending> = self.pending.drain(..take).collect();
        let mut values = vec![0.0f32; self.cfg.batch * self.n_inputs];
        let mut responders = Vec::with_capacity(live.len());
        for (i, p) in live.into_iter().enumerate() {
            values[i * self.n_inputs..(i + 1) * self.n_inputs].copy_from_slice(&p.inputs);
            responders.push(p.respond);
        }
        let padded = self.cfg.batch - responders.len();
        Batch { values, responders, padded }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn pending(vals: &[f32]) -> (Pending, std::sync::mpsc::Receiver<f32>) {
        let (tx, rx) = channel();
        (Pending { inputs: vals.to_vec(), respond: tx, enqueued: Instant::now() }, rx)
    }

    #[test]
    fn full_wave_closes_immediately() {
        let mut b = Batcher::new(BatcherConfig { batch: 2, max_wait: Duration::from_secs(10) }, 2);
        let (p1, _r1) = pending(&[0.1, 0.2]);
        let (p2, _r2) = pending(&[0.3, 0.4]);
        b.push(p1);
        assert!(!b.ready(Instant::now()));
        b.push(p2);
        assert!(b.ready(Instant::now()));
        let wave = b.drain();
        assert_eq!(wave.padded, 0);
        assert_eq!(wave.values, vec![0.1, 0.2, 0.3, 0.4]);
    }

    #[test]
    fn partial_wave_closes_on_timeout_with_padding() {
        let mut b = Batcher::new(BatcherConfig { batch: 4, max_wait: Duration::ZERO }, 1);
        let (p1, _r1) = pending(&[0.9]);
        b.push(p1);
        assert!(b.ready(Instant::now()));
        let wave = b.drain();
        assert_eq!(wave.padded, 3);
        assert_eq!(wave.values, vec![0.9, 0.0, 0.0, 0.0]);
        assert_eq!(wave.responders.len(), 1);
    }

    #[test]
    fn oversized_queue_drains_in_waves() {
        let mut b = Batcher::new(BatcherConfig { batch: 2, max_wait: Duration::ZERO }, 1);
        for i in 0..5 {
            let (p, _r) = pending(&[i as f32]);
            b.push(p);
            std::mem::forget(_r);
        }
        assert_eq!(b.drain().responders.len(), 2);
        assert_eq!(b.drain().responders.len(), 2);
        assert_eq!(b.drain().responders.len(), 1);
        assert!(b.is_empty());
    }
}
