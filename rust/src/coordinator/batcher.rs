//! Dynamic batcher: groups per-instance requests into artifact-sized
//! waves (one wave = one subarray-group execution). A wave closes when
//! full or when the oldest request has waited `max_wait`; partial waves
//! are zero-padded (padded slots are wasted subarray capacity, a metric
//! the coordinator reports).

use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

use crate::serve::resilience::Reply;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Wave size = artifact batch dimension.
    pub batch: usize,
    /// Close a partial wave after this wait.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// One pending request: flattened inputs + the response channel.
pub struct Pending {
    pub inputs: Vec<f32>,
    pub respond: Sender<Reply>,
    pub enqueued: Instant,
    /// Absolute request deadline; `None` = unbounded. Expired entries
    /// are answered `Err(Timeout)` by [`Batcher::expire`] or at wave
    /// close instead of occupying subarray rows.
    pub deadline: Option<Instant>,
}

/// A closed wave ready for execution.
pub struct Batch {
    /// Row-major [batch, n_inputs], zero-padded.
    pub values: Vec<f32>,
    /// Response channels for the live (non-padding) rows.
    pub responders: Vec<Sender<Reply>>,
    /// Submit timestamps aligned with `responders` — the executor turns
    /// these into queue-wait samples (submit → wave start).
    pub enqueued: Vec<Instant>,
    /// Per-row deadlines aligned with `responders`, re-checked when the
    /// wave completes (a slow wave can outlive a row's budget).
    pub deadlines: Vec<Option<Instant>>,
    pub padded: usize,
}

/// Accumulates pending requests into waves.
pub struct Batcher {
    cfg: BatcherConfig,
    n_inputs: usize,
    pending: Vec<Pending>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig, n_inputs: usize) -> Self {
        Self { cfg, n_inputs, pending: Vec::new() }
    }

    pub fn push(&mut self, p: Pending) {
        assert_eq!(p.inputs.len(), self.n_inputs, "input arity mismatch");
        self.pending.push(p);
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Whether a full wave is pending — when [`Batcher::ready`] holds,
    /// this separates the capacity close from the deadline close.
    pub fn is_full(&self) -> bool {
        self.pending.len() >= self.cfg.batch
    }

    /// Whether a wave should close now.
    pub fn ready(&self, now: Instant) -> bool {
        if self.is_full() {
            return true;
        }
        match self.pending.first() {
            Some(p) => now.duration_since(p.enqueued) >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Remove and return every pending request whose deadline has
    /// already expired at `now`, preserving arrival order of the
    /// survivors. The caller answers the expired entries `Err(Timeout)`
    /// — they never occupy wave rows. Fast path: no deadlines pending →
    /// no allocation, no shuffle.
    pub fn expire(&mut self, now: Instant) -> Vec<Pending> {
        if !self.pending.iter().any(|p| p.deadline.is_some_and(|d| d <= now)) {
            return Vec::new();
        }
        let drained = std::mem::take(&mut self.pending);
        let (expired, live): (Vec<Pending>, Vec<Pending>) =
            drained.into_iter().partition(|p| p.deadline.is_some_and(|d| d <= now));
        self.pending = live;
        expired
    }

    /// Close and return one wave (up to `batch` requests, zero-padded).
    pub fn drain(&mut self) -> Batch {
        let take = self.pending.len().min(self.cfg.batch);
        let live: Vec<Pending> = self.pending.drain(..take).collect();
        let mut values = vec![0.0f32; self.cfg.batch * self.n_inputs];
        let mut responders = Vec::with_capacity(live.len());
        let mut enqueued = Vec::with_capacity(live.len());
        let mut deadlines = Vec::with_capacity(live.len());
        for (i, p) in live.into_iter().enumerate() {
            values[i * self.n_inputs..(i + 1) * self.n_inputs].copy_from_slice(&p.inputs);
            responders.push(p.respond);
            enqueued.push(p.enqueued);
            deadlines.push(p.deadline);
        }
        let padded = self.cfg.batch - responders.len();
        Batch { values, responders, enqueued, deadlines, padded }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn pending(vals: &[f32]) -> (Pending, std::sync::mpsc::Receiver<Reply>) {
        let (tx, rx) = channel();
        let p = Pending {
            inputs: vals.to_vec(),
            respond: tx,
            enqueued: Instant::now(),
            deadline: None,
        };
        (p, rx)
    }

    #[test]
    fn full_wave_closes_immediately() {
        let mut b = Batcher::new(BatcherConfig { batch: 2, max_wait: Duration::from_secs(10) }, 2);
        let (p1, _r1) = pending(&[0.1, 0.2]);
        let (p2, _r2) = pending(&[0.3, 0.4]);
        b.push(p1);
        assert!(!b.ready(Instant::now()));
        b.push(p2);
        assert!(b.ready(Instant::now()));
        let wave = b.drain();
        assert_eq!(wave.padded, 0);
        assert_eq!(wave.values, vec![0.1, 0.2, 0.3, 0.4]);
    }

    #[test]
    fn partial_wave_closes_on_timeout_with_padding() {
        let mut b = Batcher::new(BatcherConfig { batch: 4, max_wait: Duration::ZERO }, 1);
        let (p1, _r1) = pending(&[0.9]);
        b.push(p1);
        assert!(b.ready(Instant::now()));
        let wave = b.drain();
        assert_eq!(wave.padded, 3);
        assert_eq!(wave.values, vec![0.9, 0.0, 0.0, 0.0]);
        assert_eq!(wave.responders.len(), 1);
    }

    #[test]
    fn deadline_triggers_partial_drain_exactly_at_max_wait() {
        // `ready` flips when the OLDEST pending request has waited
        // `max_wait` — the deadline path that closes partial waves.
        let wait = Duration::from_millis(5);
        let mut b = Batcher::new(BatcherConfig { batch: 8, max_wait: wait }, 1);
        let (p, _r) = pending(&[0.5]);
        let enqueued = p.enqueued;
        b.push(p);
        assert!(!b.ready(enqueued), "fresh request must not close a wave");
        assert!(!b.ready(enqueued + wait / 2), "before the deadline");
        assert!(b.ready(enqueued + wait), "at the deadline");
        assert!(b.ready(enqueued + wait * 2), "after the deadline");
        let wave = b.drain();
        assert_eq!(wave.responders.len(), 1);
        assert_eq!(wave.padded, 7);
        assert!(b.is_empty(), "deadline drain leaves the batcher empty");
    }

    #[test]
    fn deadline_is_keyed_to_oldest_not_newest() {
        let wait = Duration::from_millis(5);
        let mut b = Batcher::new(BatcherConfig { batch: 8, max_wait: wait }, 1);
        let (p1, _r1) = pending(&[0.1]);
        let oldest = p1.enqueued;
        b.push(p1);
        // A second request arriving later must not reset the clock.
        let (mut p2, _r2) = pending(&[0.2]);
        p2.enqueued = oldest + wait; // newest is fresh at the deadline
        b.push(p2);
        assert!(b.ready(oldest + wait), "oldest request's wait governs");
        let wave = b.drain();
        assert_eq!(wave.responders.len(), 2, "the partial drain takes everything pending");
    }

    #[test]
    fn empty_batcher_is_never_ready() {
        let b = Batcher::new(BatcherConfig { batch: 4, max_wait: Duration::ZERO }, 1);
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
        // Even with max_wait ZERO there is no oldest request to expire.
        assert!(!b.ready(Instant::now()));
    }

    #[test]
    fn drain_on_empty_yields_all_padding_wave() {
        // Callers guard with is_empty(); if they don't, the wave is
        // well-formed anyway: zero responders, full padding.
        let mut b = Batcher::new(BatcherConfig { batch: 4, max_wait: Duration::ZERO }, 2);
        let wave = b.drain();
        assert!(wave.responders.is_empty());
        assert_eq!(wave.padded, 4);
        assert_eq!(wave.values, vec![0.0; 8]);
    }

    #[test]
    fn ready_then_drain_cycle_after_refill() {
        // The empty → push → drain → empty cycle leaves no stale state.
        let mut b = Batcher::new(BatcherConfig { batch: 2, max_wait: Duration::ZERO }, 1);
        for round in 0..3 {
            assert!(!b.ready(Instant::now()), "round {round}: empty never ready");
            let (p1, _r1) = pending(&[0.1]);
            let (p2, _r2) = pending(&[0.2]);
            b.push(p1);
            b.push(p2);
            assert!(b.ready(Instant::now()), "round {round}: full wave ready");
            let wave = b.drain();
            assert_eq!(wave.padded, 0, "round {round}");
            assert!(b.is_empty(), "round {round}");
        }
    }

    #[test]
    fn expire_removes_only_overdue_entries_in_order() {
        let mut b = Batcher::new(BatcherConfig { batch: 8, max_wait: Duration::from_secs(10) }, 1);
        let now = Instant::now();
        let (mut p1, _r1) = pending(&[0.1]); // overdue
        let (p2, _r2) = pending(&[0.2]); // no deadline — never expires
        let (mut p3, _r3) = pending(&[0.3]); // future deadline — survives
        let (mut p4, _r4) = pending(&[0.4]); // overdue
        p1.deadline = Some(now);
        p3.deadline = Some(now + Duration::from_secs(60));
        p4.deadline = Some(now - Duration::from_millis(1));
        for p in [p1, p2, p3, p4] {
            b.push(p);
        }
        let expired = b.expire(now);
        assert_eq!(expired.len(), 2);
        assert_eq!(expired[0].inputs, vec![0.1]);
        assert_eq!(expired[1].inputs, vec![0.4]);
        assert_eq!(b.len(), 2, "survivors stay pending");
        let wave = b.drain();
        assert_eq!(wave.values[..2], [0.2, 0.3], "survivor order preserved");
        assert_eq!(wave.deadlines.len(), 2);
        assert!(wave.deadlines[0].is_none() && wave.deadlines[1].is_some());
    }

    #[test]
    fn expire_without_deadlines_is_a_noop() {
        let mut b = Batcher::new(BatcherConfig { batch: 4, max_wait: Duration::from_secs(10) }, 1);
        let (p1, _r1) = pending(&[0.5]);
        b.push(p1);
        assert!(b.expire(Instant::now() + Duration::from_secs(60)).is_empty());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn oversized_queue_drains_in_waves() {
        let mut b = Batcher::new(BatcherConfig { batch: 2, max_wait: Duration::ZERO }, 1);
        for i in 0..5 {
            let (p, _r) = pending(&[i as f32]);
            b.push(p);
            std::mem::forget(_r);
        }
        assert_eq!(b.drain().responders.len(), 2);
        assert_eq!(b.drain().responders.len(), 2);
        assert_eq!(b.drain().responders.len(), 1);
        assert!(b.is_empty());
    }
}
