//! S15 — the bank-controller coordinator (L3 request path).
//!
//! The Stoch-IMC bank controller (§4.3) owns the request loop: workload
//! instances arrive as requests, the batcher groups them to the
//! artifact's wave size (the subarray-group capacity the L2 graph was
//! lowered for), an executor thread drives the engine, and results fan
//! back out to waiters. Python is never on this path.
//!
//! This module keeps the shared building blocks — [`Batcher`] and
//! [`Metrics`] — plus [`Coordinator`], the single-shard convenience
//! wrapper. The bank-parallel serving path (N controller shards, one
//! per artifact, bounded admission queues) lives in [`crate::serve`]
//! and reuses these same pieces.

pub mod batcher;
pub mod engine;
pub mod metrics;

pub use batcher::{Batch, Batcher, BatcherConfig, Pending};
pub use engine::Coordinator;
pub use metrics::{Metrics, WaveClose};
