//! The coordinator proper: request intake → batcher → executor thread
//! (owns the execution engine) → response fan-out.
//!
//! Thread topology: callers submit on a channel; one controller thread
//! runs the batching loop per artifact and drives the [`Engine`] (a
//! wave executes all batch rows like a subarray group firing all its
//! rows in one cycle). `shutdown` drains cleanly.

use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::bail;
use crate::error::{Context, Result};

use super::batcher::{Batcher, BatcherConfig, Pending};
use super::metrics::Metrics;
use crate::runtime::Engine;

enum Msg {
    Request { app: String, inputs: Vec<f32>, respond: Sender<f32> },
    Flush,
    Shutdown,
}

pub struct Coordinator {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<HashMap<String, Metrics>>>,
    specs: HashMap<String, (usize, usize)>, // name → (n_inputs, batch)
}

impl Coordinator {
    /// Load all artifacts from `dir` and start the controller thread.
    /// The engine is constructed *inside* the controller thread — the
    /// PJRT backend's xla handles are not `Send` (the interpreter would
    /// not need this, but the topology is backend-agnostic).
    pub fn start(dir: &Path, cfg: BatcherConfig) -> Result<Self> {
        let metrics: Arc<Mutex<HashMap<String, Metrics>>> = Arc::default();
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        // The manifest is parsed once, by the engine; the controller
        // reports the resulting specs back so submit() validates
        // against exactly what the engine will execute.
        let (ready_tx, ready_rx) = channel::<Result<HashMap<String, (usize, usize)>>>();
        let m2 = Arc::clone(&metrics);
        let dir2 = dir.to_path_buf();
        let handle = std::thread::Builder::new()
            .name("stoch-imc-controller".into())
            .spawn(move || {
                let engine = match Engine::load(&dir2) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let specs: HashMap<String, (usize, usize)> = engine
                    .artifact_names()
                    .into_iter()
                    .filter_map(|n| {
                        engine.spec(n).map(|s| (s.name.clone(), (s.n_inputs, s.batch)))
                    })
                    .collect();
                let _ = ready_tx.send(Ok(specs.clone()));
                controller_loop(engine, rx, m2, specs, cfg)
            })
            .context("spawning controller")?;
        let specs = ready_rx.recv().context("controller died during load")??;
        Ok(Self { tx, handle: Some(handle), metrics, specs })
    }

    pub fn apps(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn n_inputs(&self, app: &str) -> Option<usize> {
        self.specs.get(app).map(|(n, _)| *n)
    }

    /// Submit one instance; returns the receiver for its result.
    pub fn submit(&self, app: &str, inputs: &[f64]) -> Result<Receiver<f32>> {
        let Some(&(n, _)) = self.specs.get(app) else {
            bail!("unknown app `{app}` (have: {:?})", self.apps());
        };
        if inputs.len() != n {
            bail!("app `{app}` expects {n} inputs, got {}", inputs.len());
        }
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Request {
                app: app.to_string(),
                inputs: inputs.iter().map(|&v| v as f32).collect(),
                respond: rtx,
            })
            .ok()
            .context("controller gone")?;
        Ok(rrx)
    }

    /// Run a whole workload synchronously; returns outputs in order.
    pub fn run_workload(&self, app: &str, instances: &[Vec<f64>]) -> Result<Vec<f64>> {
        let t0 = Instant::now();
        let receivers: Result<Vec<Receiver<f32>>> =
            instances.iter().map(|x| self.submit(app, x)).collect();
        let receivers = receivers?;
        self.tx.send(Msg::Flush).ok().context("controller gone")?;
        let mut out = Vec::with_capacity(receivers.len());
        for r in receivers {
            out.push(r.recv().context("result dropped")? as f64);
        }
        if let Ok(mut m) = self.metrics.lock() {
            m.entry(app.to_string()).or_default().total_time += t0.elapsed();
        }
        Ok(out)
    }

    pub fn metrics(&self, app: &str) -> Metrics {
        self.metrics.lock().unwrap().get(app).cloned().unwrap_or_default()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn controller_loop(
    engine: Engine,
    rx: Receiver<Msg>,
    metrics: Arc<Mutex<HashMap<String, Metrics>>>,
    specs: HashMap<String, (usize, usize)>,
    cfg: BatcherConfig,
) {
    let mut batchers: HashMap<String, Batcher> = HashMap::new();
    let mut seed: i32 = 0x5eed;
    loop {
        // Wait for work (bounded, so timeouts can close partial waves).
        let msg = rx.recv_timeout(cfg.max_wait);
        match msg {
            Ok(Msg::Request { app, inputs, respond }) => {
                let (n, batch) = specs[&app];
                let b = batchers.entry(app.clone()).or_insert_with(|| {
                    Batcher::new(BatcherConfig { batch, max_wait: cfg.max_wait }, n)
                });
                b.push(Pending { inputs, respond, enqueued: Instant::now() });
            }
            Ok(Msg::Flush) => {
                for (app, b) in batchers.iter_mut() {
                    while !b.is_empty() {
                        execute_wave(&engine, app, b, &metrics, &mut seed);
                    }
                }
                continue;
            }
            Ok(Msg::Shutdown) => {
                for (app, b) in batchers.iter_mut() {
                    while !b.is_empty() {
                        execute_wave(&engine, app, b, &metrics, &mut seed);
                    }
                }
                return;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
        }
        // Close any ready waves.
        let now = Instant::now();
        for (app, b) in batchers.iter_mut() {
            while b.ready(now) {
                execute_wave(&engine, app, b, &metrics, &mut seed);
            }
        }
    }
}

fn execute_wave(
    engine: &Engine,
    app: &str,
    b: &mut Batcher,
    metrics: &Arc<Mutex<HashMap<String, Metrics>>>,
    seed: &mut i32,
) {
    let wave = b.drain();
    *seed = seed.wrapping_mul(0x343FD).wrapping_add(0x269EC3);
    let t0 = Instant::now();
    match engine.execute(app, &wave.values, *seed, wave.responders.len()) {
        Ok(outs) => {
            let dt = t0.elapsed();
            for (i, r) in wave.responders.iter().enumerate() {
                let _ = r.send(outs[i]);
            }
            if let Ok(mut m) = metrics.lock() {
                let e = m.entry(app.to_string()).or_default();
                e.record_wave(wave.responders.len(), wave.padded, dt);
                for _ in 0..wave.responders.len() {
                    e.record_latency(dt);
                }
            }
        }
        Err(err) => {
            // Surface the failure by dropping responders (recv() errors).
            eprintln!("wave execution failed for `{app}`: {err:#}");
        }
    }
}
