//! The coordinator: the original single-controller request path, now a
//! thin single-shard wrapper over the bank-parallel serving subsystem
//! ([`crate::serve::Server`]).
//!
//! Kept because its API is the simplest way to drive one artifact
//! directory — one call site, blocking workloads, per-app metrics — and
//! because the examples/tests that predate `serve::` use it. All the
//! actual batching/execution machinery lives in `serve::shard`; the
//! coordinator simply pins `shards = 1`, which reproduces the old
//! topology exactly (one controller thread, per-app batchers, drain on
//! shutdown).

use std::path::Path;
use std::sync::mpsc::Receiver;

use crate::error::Result;
use crate::serve::{Reply, Server, ServerConfig};

use super::batcher::BatcherConfig;
use super::metrics::Metrics;

/// Single-shard serving front: submit / run_workload / metrics over one
/// controller thread. See [`crate::serve`] for the sharded version.
pub struct Coordinator {
    server: Server,
}

impl Coordinator {
    /// Load all artifacts from `dir` and start the (single) controller
    /// shard. The engine is shared `Arc` with the shard thread — see
    /// [`Server::start`] for the backend `Send + Sync` caveat.
    pub fn start(dir: &Path, cfg: BatcherConfig) -> Result<Self> {
        let server = Server::start(
            dir,
            ServerConfig { shards: 1, batcher: cfg, ..ServerConfig::default() },
        )?;
        Ok(Self { server })
    }

    pub fn apps(&self) -> Vec<String> {
        self.server.apps()
    }

    pub fn n_inputs(&self, app: &str) -> Option<usize> {
        self.server.n_inputs(app)
    }

    /// Submit one instance; returns the receiver for its terminal
    /// [`Reply`] (value or typed error — see [`crate::serve::ServeError`]).
    pub fn submit(&self, app: &str, inputs: &[f64]) -> Result<Receiver<Reply>> {
        self.server.submit(app, inputs)
    }

    /// Run a whole workload synchronously; returns outputs in order.
    pub fn run_workload(&self, app: &str, instances: &[Vec<f64>]) -> Result<Vec<f64>> {
        self.server.run_workload(app, instances)
    }

    pub fn metrics(&self, app: &str) -> Metrics {
        self.server.metrics(app)
    }

    /// Flat exposition snapshot (see [`Server::snapshot`]).
    pub fn snapshot(&self) -> crate::obs::MetricsSnapshot {
        self.server.snapshot()
    }
}
