//! Coordinator metrics: waves, padding waste, latency and throughput —
//! plus the reliability instrumentation the executor streams back per
//! wave (Eq 4 operation counters, Eq 11 wear).

use std::time::Duration;

use crate::energy::{EnergyBreakdown, EnergyParams, OpCounters};
use crate::lifetime::WearProfile;
use crate::runtime::WaveStats;

#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests: u64,
    pub waves: u64,
    pub padded_slots: u64,
    pub exec_time: Duration,
    pub total_time: Duration,
    /// Eq 4 operation counters summed over every wave recorded here
    /// (price with [`Metrics::energy`]).
    pub ops: OpCounters,
    /// Eq 11 wear of the subarray rows these waves kept re-writing.
    pub wear: WearProfile,
    latencies_us: Vec<u64>,
}

impl Metrics {
    pub fn record_wave(&mut self, live: usize, padded: usize, exec: Duration) {
        self.requests += live as u64;
        self.waves += 1;
        self.padded_slots += padded as u64;
        self.exec_time += exec;
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.latencies_us.push(d.as_micros() as u64);
    }

    /// Fold one executed wave's instrumentation in: counters sum; wear
    /// *absorbs* — every wave of the same app re-writes the same
    /// subarray rows, so capacity is a max while traffic accumulates.
    pub fn record_stats(&mut self, stats: &WaveStats) {
        self.ops.add(&stats.ops);
        self.wear.absorb_wave(&stats.wear);
    }

    /// Fold another metrics snapshot into this one — the pool-wide
    /// aggregation across apps/shards. Latency samples concatenate, so
    /// percentiles stay exact; `total_time` sums wall-clock per app
    /// (shards overlap in time, so the pool total is an upper bound).
    /// Wear merges as *disjoint* banks: capacity and traffic sum, the
    /// pool's hottest cell is the max of the parts.
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.waves += other.waves;
        self.padded_slots += other.padded_slots;
        self.exec_time += other.exec_time;
        self.total_time += other.total_time;
        self.ops.add(&other.ops);
        self.wear.merge(&other.wear);
        self.latencies_us.extend_from_slice(&other.latencies_us);
    }

    /// Executor-side Eq 4 energy of everything recorded here.
    pub fn energy(&self, params: &EnergyParams) -> EnergyBreakdown {
        self.ops.energy(params)
    }

    /// Requests per second over the recorded total time.
    pub fn throughput(&self) -> f64 {
        if self.total_time.is_zero() {
            return 0.0;
        }
        self.requests as f64 / self.total_time.as_secs_f64()
    }

    /// Fraction of executed slots wasted on padding.
    pub fn padding_waste(&self) -> f64 {
        let total = self.requests + self.padded_slots;
        if total == 0 {
            return 0.0;
        }
        self.padded_slots as f64 / total as f64
    }

    /// Latency percentile in microseconds (p in [0,100]).
    pub fn latency_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx]
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} waves={} waste={:.1}% thru={:.0} req/s p50={}µs p99={}µs",
            self.requests,
            self.waves,
            100.0 * self.padding_waste(),
            self.throughput(),
            self.latency_us(50.0),
            self.latency_us(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_waste_computed() {
        let mut m = Metrics::default();
        m.record_wave(48, 16, Duration::from_millis(1));
        assert!((m.padding_waste() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles() {
        let mut m = Metrics::default();
        for us in [100u64, 200, 300, 400, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        assert_eq!(m.latency_us(50.0), 300);
        assert_eq!(m.latency_us(100.0), 1000);
    }

    #[test]
    fn throughput_zero_without_time() {
        let m = Metrics::default();
        assert_eq!(m.throughput(), 0.0);
    }

    #[test]
    fn wave_stats_absorb_per_app_and_merge_disjoint() {
        let stats = WaveStats {
            ops: OpCounters { sbg_writes: 10, presets: 10, ..OpCounters::default() },
            wear: WearProfile { used_cells: 8, writes: 20, max_cell_writes: 4 },
        };
        // Two waves of the same app: ops sum, cells re-written (max),
        // hottest cell accumulates.
        let mut a = Metrics::default();
        a.record_stats(&stats);
        a.record_stats(&stats);
        assert_eq!(a.ops.sbg_writes, 20);
        assert_eq!(a.wear, WearProfile { used_cells: 8, writes: 40, max_cell_writes: 8 });
        // Another app's bank merges disjointly: capacity sums, the
        // pool's hottest cell is the max of the parts.
        let mut b = Metrics::default();
        b.record_stats(&stats);
        a.merge(&b);
        assert_eq!(a.ops.sbg_writes, 30);
        assert_eq!(a.wear, WearProfile { used_cells: 16, writes: 60, max_cell_writes: 8 });
    }

    #[test]
    fn merge_aggregates_counts_and_latencies() {
        let mut a = Metrics::default();
        a.record_wave(4, 0, Duration::from_millis(2));
        a.record_latency(Duration::from_micros(100));
        let mut b = Metrics::default();
        b.record_wave(3, 1, Duration::from_millis(1));
        b.record_latency(Duration::from_micros(300));
        b.record_latency(Duration::from_micros(500));
        a.merge(&b);
        assert_eq!(a.requests, 7);
        assert_eq!(a.waves, 2);
        assert_eq!(a.padded_slots, 1);
        assert_eq!(a.exec_time, Duration::from_millis(3));
        assert_eq!(a.latency_us(100.0), 500);
        assert_eq!(a.latency_us(0.0), 100);
    }
}
