//! Coordinator metrics: waves, padding waste, latency and throughput —
//! plus the reliability instrumentation the executor streams back per
//! wave (Eq 4 operation counters, Eq 11 wear) and the observability
//! layer (fixed-memory latency / queue-wait / queue-depth / wave-size
//! histograms, per-stage span timing, admission-control counters).
//!
//! All distributions live in bounded-memory [`Histogram`]s: recording
//! is O(1), merging across shards is exact, and percentile queries
//! carry a ≤ 1/32 relative-error bound — `Metrics` no longer buffers
//! per-sample vectors no matter how much traffic flows through.

use std::time::Duration;

use crate::energy::{EnergyBreakdown, EnergyParams, OpCounters};
use crate::lifetime::WearProfile;
use crate::obs::{Histogram, MetricsSnapshot, StageSpans};
use crate::runtime::WaveStats;
use crate::sc::sng::SngCacheStats;

/// Why a wave left the batcher — admission-control telemetry that
/// separates saturated shards (full waves) from latency-bound ones
/// (deadline drains) and shutdown flushes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaveClose {
    /// The wave filled every row slot before the deadline.
    Full,
    /// `max_wait` expired on the oldest pending request.
    Deadline,
    /// Explicit flush/shutdown drained a partial wave.
    Flush,
}

#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests: u64,
    pub waves: u64,
    /// Waves closed because every row slot filled.
    pub waves_full: u64,
    /// Waves closed by the batcher deadline.
    pub waves_deadline: u64,
    /// Waves closed by an explicit flush or shutdown drain.
    pub waves_flush: u64,
    pub padded_slots: u64,
    pub exec_time: Duration,
    pub total_time: Duration,
    /// Submissions that blocked on a full admission queue.
    pub backpressure_blocks: u64,
    /// `try_submit` requests shed on a full admission queue.
    pub shed: u64,
    /// Times the shard supervisor caught an executor panic and
    /// respawned the loop (attributed to the in-flight app, else the
    /// shard's first home app).
    pub executor_restarts: u64,
    /// Requests answered `Err(Timeout)` — deadline expired at dequeue,
    /// at wave close, or at completion.
    pub deadline_timeouts: u64,
    /// Requests answered with a terminal error (executor panic, engine
    /// failure, or dead shard) — never silently dropped.
    pub failed_requests: u64,
    /// Waves executed below full bitstream length by the overload
    /// controller.
    pub degraded_waves: u64,
    /// Current degradation-ladder level (gauge: 0 = full BL; pool merge
    /// takes the max across apps/shards).
    pub bl_level: u64,
    /// Eq 4 operation counters summed over every wave recorded here
    /// (price with [`Metrics::energy`]).
    pub ops: OpCounters,
    /// Eq 11 wear of the subarray rows these waves kept re-writing.
    pub wear: WearProfile,
    /// Wall-clock attributed per engine stage (SNG/gate/regen/StoB),
    /// summed across workers — shares are the meaningful signal.
    pub spans: StageSpans,
    /// SNG block-cache and per-wave cutoff-memo hit/miss counters,
    /// summed over every wave recorded here (counter-RNG waves only —
    /// the xoshiro compat path bypasses both caches).
    pub cache: SngCacheStats,
    latency: Histogram,
    queue_wait: Histogram,
    queue_depth: Histogram,
    wave_sizes: Histogram,
    #[cfg(test)]
    exact_latencies_us: Vec<u64>,
}

impl Metrics {
    pub fn record_wave(&mut self, live: usize, padded: usize, exec: Duration) {
        self.requests += live as u64;
        self.waves += 1;
        self.padded_slots += padded as u64;
        self.exec_time += exec;
        self.wave_sizes.record(live as u64);
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.latency.record(d.as_micros() as u64);
        #[cfg(test)]
        self.exact_latencies_us.push(d.as_micros() as u64);
    }

    /// Time a request spent between submission and wave execution
    /// (admission channel + batcher residence).
    pub fn record_queue_wait(&mut self, d: Duration) {
        self.queue_wait.record(d.as_micros() as u64);
    }

    /// Admission-queue depth observed at an enqueue or dequeue edge.
    pub fn record_queue_depth(&mut self, depth: u64) {
        self.queue_depth.record(depth);
    }

    /// Count why a wave was closed out of the batcher.
    pub fn record_drain(&mut self, close: WaveClose) {
        match close {
            WaveClose::Full => self.waves_full += 1,
            WaveClose::Deadline => self.waves_deadline += 1,
            WaveClose::Flush => self.waves_flush += 1,
        }
    }

    /// Fold one executed wave's instrumentation in: counters and spans
    /// sum; wear *absorbs* — every wave of the same app re-writes the
    /// same subarray rows, so capacity is a max while traffic
    /// accumulates.
    pub fn record_stats(&mut self, stats: &WaveStats) {
        self.ops.add(&stats.ops);
        self.wear.absorb_wave(&stats.wear);
        self.spans.add(&stats.spans);
        self.cache.add(&stats.cache);
    }

    /// Fold another metrics snapshot into this one — the pool-wide
    /// aggregation across apps/shards. Histograms merge exactly
    /// (bucket tables add), so pool percentiles equal those of the
    /// concatenated sample streams within bucket resolution;
    /// `total_time` sums wall-clock per app (shards overlap in time,
    /// so the pool total is an upper bound). Wear merges as *disjoint*
    /// banks: capacity and traffic sum, the pool's hottest cell is the
    /// max of the parts.
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.waves += other.waves;
        self.waves_full += other.waves_full;
        self.waves_deadline += other.waves_deadline;
        self.waves_flush += other.waves_flush;
        self.padded_slots += other.padded_slots;
        self.exec_time += other.exec_time;
        self.total_time += other.total_time;
        self.backpressure_blocks += other.backpressure_blocks;
        self.shed += other.shed;
        self.executor_restarts += other.executor_restarts;
        self.deadline_timeouts += other.deadline_timeouts;
        self.failed_requests += other.failed_requests;
        self.degraded_waves += other.degraded_waves;
        // Gauge, not a counter: the pool-wide level is the deepest
        // ladder step any app/shard is currently at.
        self.bl_level = self.bl_level.max(other.bl_level);
        self.ops.add(&other.ops);
        self.wear.merge(&other.wear);
        self.spans.add(&other.spans);
        self.cache.add(&other.cache);
        self.latency.merge(&other.latency);
        self.queue_wait.merge(&other.queue_wait);
        self.queue_depth.merge(&other.queue_depth);
        self.wave_sizes.merge(&other.wave_sizes);
        #[cfg(test)]
        self.exact_latencies_us.extend_from_slice(&other.exact_latencies_us);
    }

    /// Executor-side Eq 4 energy of everything recorded here.
    pub fn energy(&self, params: &EnergyParams) -> EnergyBreakdown {
        self.ops.energy(params)
    }

    /// Requests per second over the recorded total time.
    pub fn throughput(&self) -> f64 {
        if self.total_time.is_zero() {
            return 0.0;
        }
        self.requests as f64 / self.total_time.as_secs_f64()
    }

    /// Fraction of executed slots wasted on padding.
    pub fn padding_waste(&self) -> f64 {
        let total = self.requests + self.padded_slots;
        if total == 0 {
            return 0.0;
        }
        self.padded_slots as f64 / total as f64
    }

    /// Request-latency percentile in microseconds (`p` clamped into
    /// `[0, 100]`; `p≤0`/`p≥100` give the exact min/max, interior
    /// percentiles carry the histogram's ≤ 1/32 relative-error bound).
    pub fn latency_us(&self, p: f64) -> u64 {
        self.latency.percentile(p)
    }

    /// Queue-wait percentile in microseconds (same conventions as
    /// [`Metrics::latency_us`]).
    pub fn queue_wait_us(&self, p: f64) -> u64 {
        self.queue_wait.percentile(p)
    }

    /// Queue-depth percentile in requests.
    pub fn queue_depth(&self, p: f64) -> u64 {
        self.queue_depth.percentile(p)
    }

    /// Exact nearest-rank percentile over the raw sample list — test
    /// oracle for the histogram's error bound; the per-sample buffer
    /// exists only under `cfg(test)`.
    #[cfg(test)]
    pub fn exact_latency_us(&self, p: f64) -> u64 {
        if self.exact_latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.exact_latencies_us.clone();
        v.sort_unstable();
        let p = p.clamp(0.0, 100.0);
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx]
    }

    /// Export every counter, percentile and stage share into `out`
    /// under `serve_<scope>_*` keys. Every key is emitted even when
    /// zero, so consumers (and `stats --check`) see a stable schema.
    pub fn snapshot_into(&self, scope: &str, out: &mut MetricsSnapshot) {
        let pre = format!("serve_{scope}_");
        let mut put = |suffix: &str, v: f64| out.push(format!("{pre}{suffix}"), v);
        put("requests", self.requests as f64);
        put("waves", self.waves as f64);
        put("waves_full", self.waves_full as f64);
        put("waves_deadline", self.waves_deadline as f64);
        put("waves_flush", self.waves_flush as f64);
        put("padded_slots", self.padded_slots as f64);
        put("padding_waste_pct", 100.0 * self.padding_waste());
        put("throughput_rps", self.throughput());
        put("backpressure_blocks", self.backpressure_blocks as f64);
        put("shed_total", self.shed as f64);
        put("executor_restarts", self.executor_restarts as f64);
        put("deadline_timeouts", self.deadline_timeouts as f64);
        put("failed_requests", self.failed_requests as f64);
        put("degraded_waves", self.degraded_waves as f64);
        put("bl_level", self.bl_level as f64);
        put("latency_us_p50", self.latency.percentile(50.0) as f64);
        put("latency_us_p90", self.latency.percentile(90.0) as f64);
        put("latency_us_p95", self.latency.percentile(95.0) as f64);
        put("latency_us_p99", self.latency.percentile(99.0) as f64);
        put("latency_us_p999", self.latency.percentile(99.9) as f64);
        put("latency_us_mean", self.latency.mean());
        put("latency_us_max", self.latency.max() as f64);
        put("queue_wait_us_p50", self.queue_wait.percentile(50.0) as f64);
        put("queue_wait_us_p95", self.queue_wait.percentile(95.0) as f64);
        put("queue_wait_us_p99", self.queue_wait.percentile(99.0) as f64);
        put("queue_wait_us_max", self.queue_wait.max() as f64);
        put("queue_depth_p50", self.queue_depth.percentile(50.0) as f64);
        put("queue_depth_p95", self.queue_depth.percentile(95.0) as f64);
        put("queue_depth_p99", self.queue_depth.percentile(99.0) as f64);
        put("queue_depth_max", self.queue_depth.max() as f64);
        put("wave_live_rows_p50", self.wave_sizes.percentile(50.0) as f64);
        put("wave_live_rows_p95", self.wave_sizes.percentile(95.0) as f64);
        put("wave_live_rows_max", self.wave_sizes.max() as f64);
        let shares = self.spans.shares();
        put("stage_sng_share", shares[0]);
        put("stage_gate_share", shares[1]);
        put("stage_regen_share", shares[2]);
        put("stage_stob_share", shares[3]);
        put("stage_total_ms", self.spans.total_ns() as f64 / 1e6);
        put("wear_writes", self.wear.writes as f64);
        put("sng_cache_hits", self.cache.hits as f64);
        put("sng_cache_misses", self.cache.misses as f64);
        put("sng_cache_hit_rate", self.cache.hit_rate());
        put("sng_cutoff_hits", self.cache.cutoff_hits as f64);
        put("sng_cutoff_misses", self.cache.cutoff_misses as f64);
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} waves={} waste={:.1}% thru={:.0} req/s p50={}µs p95={}µs p99={}µs",
            self.requests,
            self.waves,
            100.0 * self.padding_waste(),
            self.throughput(),
            self.latency_us(50.0),
            self.latency_us(95.0),
            self.latency_us(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_waste_computed() {
        let mut m = Metrics::default();
        m.record_wave(48, 16, Duration::from_millis(1));
        assert!((m.padding_waste() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles() {
        let mut m = Metrics::default();
        for us in [100u64, 200, 300, 400, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        assert_eq!(m.latency_us(50.0), 300);
        assert_eq!(m.latency_us(100.0), 1000);
        // Out-of-range p clamps instead of indexing out of bounds.
        assert_eq!(m.latency_us(250.0), 1000);
        assert_eq!(m.latency_us(-10.0), 100);
    }

    #[test]
    fn histogram_percentiles_track_exact_path() {
        // The cfg(test)-only exact sort bounds the histogram error:
        // within 1/32 relative at every queried percentile.
        let mut m = Metrics::default();
        let mut x = 0x0123_4567_89AB_CDEFu64;
        for _ in 0..2000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            m.record_latency(Duration::from_micros(x % 250_000));
        }
        for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
            let exact = m.exact_latency_us(p);
            let est = m.latency_us(p);
            let err = est.abs_diff(exact) as f64;
            assert!(err <= exact as f64 / 32.0 + 1.0, "p{p}: est {est} exact {exact}");
        }
    }

    #[test]
    fn throughput_zero_without_time() {
        let m = Metrics::default();
        assert_eq!(m.throughput(), 0.0);
    }

    #[test]
    fn wave_stats_absorb_per_app_and_merge_disjoint() {
        let stats = WaveStats {
            ops: OpCounters { sbg_writes: 10, presets: 10, ..OpCounters::default() },
            wear: WearProfile { used_cells: 8, writes: 20, max_cell_writes: 4 },
            spans: StageSpans { sng_ns: 100, gate_ns: 200, regen_ns: 0, stob_ns: 100 },
            cache: SngCacheStats { hits: 3, misses: 1, cutoff_hits: 0, cutoff_misses: 4 },
        };
        // Two waves of the same app: ops sum, cells re-written (max),
        // hottest cell accumulates, spans sum.
        let mut a = Metrics::default();
        a.record_stats(&stats);
        a.record_stats(&stats);
        assert_eq!(a.ops.sbg_writes, 20);
        assert_eq!(a.wear, WearProfile { used_cells: 8, writes: 40, max_cell_writes: 8 });
        assert_eq!(a.spans.total_ns(), 800);
        assert_eq!((a.cache.hits, a.cache.misses, a.cache.cutoff_misses), (6, 2, 8));
        // Another app's bank merges disjointly: capacity sums, the
        // pool's hottest cell is the max of the parts.
        let mut b = Metrics::default();
        b.record_stats(&stats);
        a.merge(&b);
        assert_eq!(a.ops.sbg_writes, 30);
        assert_eq!(a.wear, WearProfile { used_cells: 16, writes: 60, max_cell_writes: 8 });
        assert_eq!(a.spans.total_ns(), 1200);
    }

    #[test]
    fn merge_aggregates_counts_and_latencies() {
        let mut a = Metrics::default();
        a.record_wave(4, 0, Duration::from_millis(2));
        a.record_latency(Duration::from_micros(100));
        let mut b = Metrics::default();
        b.record_wave(3, 1, Duration::from_millis(1));
        b.record_latency(Duration::from_micros(300));
        b.record_latency(Duration::from_micros(500));
        a.merge(&b);
        assert_eq!(a.requests, 7);
        assert_eq!(a.waves, 2);
        assert_eq!(a.padded_slots, 1);
        assert_eq!(a.exec_time, Duration::from_millis(3));
        assert_eq!(a.latency_us(100.0), 500);
        assert_eq!(a.latency_us(0.0), 100);
    }

    #[test]
    fn queue_and_drain_telemetry_merge() {
        let mut a = Metrics::default();
        a.record_queue_wait(Duration::from_micros(50));
        a.record_queue_depth(3);
        a.record_drain(WaveClose::Full);
        a.record_drain(WaveClose::Deadline);
        a.backpressure_blocks = 2;
        a.shed = 1;
        let mut b = Metrics::default();
        b.record_queue_wait(Duration::from_micros(150));
        b.record_queue_depth(9);
        b.record_drain(WaveClose::Flush);
        b.shed = 4;
        a.merge(&b);
        assert_eq!(a.waves_full, 1);
        assert_eq!(a.waves_deadline, 1);
        assert_eq!(a.waves_flush, 1);
        assert_eq!(a.backpressure_blocks, 2);
        assert_eq!(a.shed, 5);
        assert_eq!(a.queue_wait_us(0.0), 50);
        assert_eq!(a.queue_wait_us(100.0), 150);
        assert_eq!(a.queue_depth(100.0), 9);
    }

    #[test]
    fn resilience_counters_merge_and_bl_level_is_a_gauge() {
        let mut a = Metrics::default();
        a.executor_restarts = 1;
        a.deadline_timeouts = 2;
        a.failed_requests = 3;
        a.degraded_waves = 4;
        a.bl_level = 1;
        let mut b = Metrics::default();
        b.executor_restarts = 2;
        b.deadline_timeouts = 1;
        b.degraded_waves = 6;
        b.bl_level = 2;
        a.merge(&b);
        assert_eq!(a.executor_restarts, 3);
        assert_eq!(a.deadline_timeouts, 3);
        assert_eq!(a.failed_requests, 3);
        assert_eq!(a.degraded_waves, 10);
        assert_eq!(a.bl_level, 2, "gauge merges as max, not sum");
    }

    #[test]
    fn snapshot_emits_stable_schema() {
        let m = Metrics::default();
        let mut snap = MetricsSnapshot::default();
        m.snapshot_into("pool", &mut snap);
        // Every key present even on an empty metrics object.
        for key in [
            "serve_pool_requests",
            "serve_pool_latency_us_p50",
            "serve_pool_latency_us_p999",
            "serve_pool_queue_wait_us_p99",
            "serve_pool_queue_depth_p99",
            "serve_pool_shed_total",
            "serve_pool_backpressure_blocks",
            "serve_pool_executor_restarts",
            "serve_pool_deadline_timeouts",
            "serve_pool_failed_requests",
            "serve_pool_degraded_waves",
            "serve_pool_bl_level",
            "serve_pool_stage_sng_share",
            "serve_pool_stage_stob_share",
            "serve_pool_waves_deadline",
            "serve_pool_wear_writes",
            "serve_pool_sng_cache_hits",
            "serve_pool_sng_cache_hit_rate",
            "serve_pool_sng_cutoff_hits",
        ] {
            assert!(snap.get(key).is_some(), "missing {key}");
        }
        assert_eq!(snap.get("serve_pool_requests"), Some(0.0));
    }
}
