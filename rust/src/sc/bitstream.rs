//! Packed bitstream representation for stochastic numbers.
//!
//! A stochastic number (SN) in unipolar encoding is a bitstream whose
//! fraction of 1s equals its value (§2.3). We pack 64 bits per word so
//! the L3 functional simulator's logic ops run 64 lanes per instruction —
//! this is the Rust-side analogue of the paper's bit-parallel subarrays
//! and is the hot path of the fault-injection and accuracy experiments.

use crate::util::prng::Xoshiro256;

/// A fixed-length packed bitstream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitstream {
    len: usize,
    words: Vec<u64>,
}

impl Bitstream {
    /// All-zero bitstream of length `len`.
    pub fn zeros(len: usize) -> Self {
        Self { len, words: vec![0; len.div_ceil(64)] }
    }

    /// All-one bitstream of length `len`.
    pub fn ones(len: usize) -> Self {
        let mut bs = Self::zeros(len);
        for w in bs.words.iter_mut() {
            *w = u64::MAX;
        }
        bs.mask_tail();
        bs
    }

    /// Build from a bool slice.
    pub fn from_bits(bits: &[bool]) -> Self {
        let mut bs = Self::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                bs.set(i, true);
            }
        }
        bs
    }

    /// Bernoulli-sample a bitstream of value `p` (this models the MTJ
    /// stochastic write: each cell switches independently with P_sw = p).
    /// Words are assembled in a register and stored once — same RNG call
    /// sequence and same bits as the per-bit `set` formulation (pinned
    /// by a test), without `len` read-modify-write round trips.
    pub fn sample(p: f64, len: usize, rng: &mut Xoshiro256) -> Self {
        let mut words = Vec::with_capacity(len.div_ceil(64));
        let mut base = 0;
        while base < len {
            let n = (len - base).min(64);
            let mut w = 0u64;
            for b in 0..n {
                if rng.bernoulli(p) {
                    w |= 1u64 << b;
                }
            }
            words.push(w);
            base += n;
        }
        Self { len, words }
    }

    /// Build from pre-packed words (LSB-first within each word); tail
    /// bits beyond `len` are masked off. Crate-internal: the lane
    /// transposer (`sc::bitplane`) assembles rows word-wise.
    pub(crate) fn from_words(len: usize, words: Vec<u64>) -> Self {
        debug_assert_eq!(words.len(), len.div_ceil(64));
        let mut bs = Self { len, words };
        bs.mask_tail();
        bs
    }

    /// Sample using shared uniforms (for *correlated* bitstreams: two SNs
    /// generated from the same uniform sequence have maximal positive
    /// correlation, which the absolute-value subtractor requires, §4.1).
    /// Words are assembled in a register like [`Bitstream::sample`]
    /// (same bits as the per-bit `set` formulation, pinned by a test).
    pub fn from_uniforms(p: f64, uniforms: &[f64]) -> Self {
        let len = uniforms.len();
        let mut words = Vec::with_capacity(len.div_ceil(64));
        for chunk in uniforms.chunks(64) {
            let mut w = 0u64;
            for (b, &u) in chunk.iter().enumerate() {
                w |= ((u < p) as u64) << b;
            }
            words.push(w);
        }
        Self { len, words }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1u64 << b;
        } else {
            self.words[w] &= !(1u64 << b);
        }
    }

    /// Flip bit `i` (used by the fault injector).
    #[inline]
    pub fn flip(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] ^= 1u64 << (i % 64);
    }

    /// Number of 1s (the StoB conversion of §2.3 step 3).
    pub fn popcount(&self) -> u64 {
        self.words.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Unipolar value = popcount / len.
    pub fn value(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.popcount() as f64 / self.len as f64
    }

    /// Zero any bits beyond `len` in the last word (keeps popcount exact).
    fn mask_tail(&mut self) {
        let tail = self.len % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    fn zip_with(&self, other: &Self, f: impl Fn(u64, u64) -> u64) -> Self {
        assert_eq!(self.len, other.len, "bitstream length mismatch");
        let words = self
            .words
            .iter()
            .zip(&other.words)
            .map(|(&a, &b)| f(a, b))
            .collect();
        let mut out = Self { len: self.len, words };
        out.mask_tail();
        out
    }

    /// AND — stochastic multiplication of independent unipolar SNs.
    pub fn and(&self, other: &Self) -> Self {
        self.zip_with(other, |a, b| a & b)
    }

    /// OR.
    pub fn or(&self, other: &Self) -> Self {
        self.zip_with(other, |a, b| a | b)
    }

    /// XOR — absolute-value subtraction for *correlated* unipolar SNs.
    pub fn xor(&self, other: &Self) -> Self {
        self.zip_with(other, |a, b| a ^ b)
    }

    /// NAND (`zip_with` already masks the tail the complement sets).
    pub fn nand(&self, other: &Self) -> Self {
        self.zip_with(other, |a, b| !(a & b))
    }

    /// NOR (`zip_with` already masks the tail the complement sets).
    pub fn nor(&self, other: &Self) -> Self {
        self.zip_with(other, |a, b| !(a | b))
    }

    /// NOT — complement (1 - x in unipolar).
    pub fn not(&self) -> Self {
        let words = self.words.iter().map(|&a| !a).collect();
        let mut out = Self { len: self.len, words };
        out.mask_tail();
        out
    }

    /// MUX(select, a, b) = select ? a : b — scaled addition
    /// s·a + (1-s)·b when `select` is an SN of value s (§2.3 Fig 4a).
    pub fn mux(select: &Self, a: &Self, b: &Self) -> Self {
        assert_eq!(select.len, a.len);
        assert_eq!(select.len, b.len);
        let words = select
            .words
            .iter()
            .zip(a.words.iter().zip(&b.words))
            .map(|(&s, (&x, &y))| (s & x) | (!s & y))
            .collect();
        let mut out = Self { len: select.len, words };
        out.mask_tail();
        out
    }

    /// Iterate bits as bools (for scan-style sequential circuits).
    pub fn iter_bits(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn zeros_ones_values() {
        assert_eq!(Bitstream::zeros(100).value(), 0.0);
        assert_eq!(Bitstream::ones(100).value(), 1.0);
        assert_eq!(Bitstream::ones(100).popcount(), 100);
    }

    #[test]
    fn tail_masking_exact() {
        // Non-multiple-of-64 lengths must not leak tail bits.
        for len in [1, 63, 64, 65, 127, 255, 256, 1000] {
            let bs = Bitstream::ones(len);
            assert_eq!(bs.popcount() as usize, len, "len={len}");
            let notted = Bitstream::zeros(len).not();
            assert_eq!(notted.popcount() as usize, len);
        }
    }

    #[test]
    fn set_get_flip_roundtrip() {
        let mut bs = Bitstream::zeros(130);
        bs.set(0, true);
        bs.set(64, true);
        bs.set(129, true);
        assert!(bs.get(0) && bs.get(64) && bs.get(129));
        assert_eq!(bs.popcount(), 3);
        bs.flip(64);
        assert!(!bs.get(64));
        assert_eq!(bs.popcount(), 2);
    }

    #[test]
    fn sample_word_assembly_matches_per_bit_set() {
        // `sample` builds each word in a register; this pins it against
        // the original per-bit `set` formulation: same RNG call
        // sequence, same bits, for ragged and word-aligned lengths.
        for (seed, len, p) in
            [(1u64, 1usize, 0.3), (2, 63, 0.5), (3, 64, 0.9), (4, 65, 0.1), (5, 1000, 0.7)]
        {
            let mut rng_a = Xoshiro256::seeded(seed);
            let mut rng_b = rng_a.clone();
            let fast = Bitstream::sample(p, len, &mut rng_a);
            let mut slow = Bitstream::zeros(len);
            for i in 0..len {
                if rng_b.bernoulli(p) {
                    slow.set(i, true);
                }
            }
            assert_eq!(fast, slow, "len={len} p={p}");
            // Both paths must leave the RNGs in the same state too.
            assert_eq!(rng_a.next_u64(), rng_b.next_u64());
        }
    }

    #[test]
    fn from_uniforms_word_assembly_matches_per_bit_set() {
        // `from_uniforms` builds each word in a register; this pins it
        // against the per-bit `set` formulation for ragged and aligned
        // lengths (and the empty stream).
        let mut rng = Xoshiro256::seeded(0xF00D);
        for len in [0usize, 1, 63, 64, 65, 1000] {
            let mut us = vec![0.0; len];
            rng.fill_f64(&mut us);
            for p in [0.0, 0.3, 1.0] {
                let fast = Bitstream::from_uniforms(p, &us);
                let mut slow = Bitstream::zeros(len);
                for (i, &u) in us.iter().enumerate() {
                    if u < p {
                        slow.set(i, true);
                    }
                }
                assert_eq!(fast, slow, "len={len} p={p}");
            }
        }
    }

    #[test]
    fn sample_value_close_to_p() {
        let mut rng = Xoshiro256::seeded(17);
        for &p in &[0.1, 0.5, 0.9] {
            let bs = Bitstream::sample(p, 65536, &mut rng);
            assert!((bs.value() - p).abs() < 0.01, "p={p} got={}", bs.value());
        }
    }

    #[test]
    fn and_multiplies_independent() {
        forall(0xB17, 50, |g| {
            let pa = g.f64_in(0.05, 0.95);
            let pb = g.f64_in(0.05, 0.95);
            let mut rng = Xoshiro256::seeded(g.u64_below(u64::MAX - 1));
            let a = Bitstream::sample(pa, 32768, &mut rng);
            let b = Bitstream::sample(pb, 32768, &mut rng);
            let prod = a.and(&b).value();
            assert!((prod - pa * pb).abs() < 0.02, "pa={pa} pb={pb} prod={prod}");
        });
    }

    #[test]
    fn xor_correlated_is_abs_difference() {
        forall(0x5E1, 50, |g| {
            let pa = g.f64_in(0.0, 1.0);
            let pb = g.f64_in(0.0, 1.0);
            let mut rng = Xoshiro256::seeded(g.u64_below(u64::MAX - 1));
            let mut us = vec![0.0; 32768];
            rng.fill_f64(&mut us);
            let a = Bitstream::from_uniforms(pa, &us);
            let b = Bitstream::from_uniforms(pb, &us);
            let d = a.xor(&b).value();
            assert!((d - (pa - pb).abs()).abs() < 0.02);
        });
    }

    #[test]
    fn mux_is_scaled_addition() {
        forall(0x3A2, 50, |g| {
            let pa = g.f64_in(0.0, 1.0);
            let pb = g.f64_in(0.0, 1.0);
            let mut rng = Xoshiro256::seeded(g.u64_below(u64::MAX - 1));
            let s = Bitstream::sample(0.5, 32768, &mut rng);
            let a = Bitstream::sample(pa, 32768, &mut rng);
            let b = Bitstream::sample(pb, 32768, &mut rng);
            let sum = Bitstream::mux(&s, &a, &b).value();
            assert!((sum - 0.5 * (pa + pb)).abs() < 0.02);
        });
    }

    #[test]
    fn not_is_complement() {
        let mut rng = Xoshiro256::seeded(23);
        let a = Bitstream::sample(0.3, 32768, &mut rng);
        assert!((a.not().value() - (1.0 - a.value())).abs() < 1e-12);
    }

    #[test]
    fn demorgan_nand_nor() {
        let mut rng = Xoshiro256::seeded(29);
        let a = Bitstream::sample(0.4, 1024, &mut rng);
        let b = Bitstream::sample(0.6, 1024, &mut rng);
        assert_eq!(a.nand(&b), a.and(&b).not());
        assert_eq!(a.nor(&b), a.or(&b).not());
    }
}
