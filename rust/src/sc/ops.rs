//! Functional (bitstream-level) models of the six stochastic arithmetic
//! operations of paper Fig 4/Fig 5. These are the *oracles* for the
//! in-memory implementations: the netlists of `netlist::ops` scheduled by
//! Algorithm 1 and executed on the `imc` subarray simulator must produce
//! the same values; the JAX/Pallas artifacts must agree too.
//!
//! Feed-forward ops are pure word-parallel bit ops. Scaled division and
//! square root contain feedback (state across bit positions) and are
//! evaluated bit-sequentially, exactly as `lax.scan` does on the L2 side.
//!
//! Divider derivation: a JK flip-flop with J=a_i, K=b_i has characteristic
//! Q' = J·Q̄ + K̄·Q; its two-state Markov chain moves up from 0 w.p. P(a)
//! and down from 1 w.p. P(b), so the stationary P(Q=1) = a/(a+b) — the
//! scaled division the paper's HDP application needs (Eq 8).
//!
//! Square-root derivation (ADDIE, Gaines): integrator value v, output
//! y_i ~ Bernoulli(v), update ΔC = x_i − y_i·y'_i with independent output
//! samples y, y'; E[ΔC] = x − v² = 0 ⇒ v = √x. The paper's Fig 5e circuit
//! (from [16,20]) uses two independently generated copies A1, A2 of x and
//! two constant streams; we keep the same input signature.

use super::bitstream::Bitstream;
use crate::util::prng::Xoshiro256;

/// Scaled addition (Fig 4a/5a): out = s·a + (1-s)·b via MUX.
/// `s` is usually a 0.5-valued SN.
pub fn scaled_add(a: &Bitstream, b: &Bitstream, s: &Bitstream) -> Bitstream {
    Bitstream::mux(s, a, b)
}

/// Multiplication (Fig 4b/5b): AND of independent SNs.
pub fn multiply(a: &Bitstream, b: &Bitstream) -> Bitstream {
    a.and(b)
}

/// Absolute-value subtraction (Fig 4c/5c): XOR of *correlated* SNs.
/// In the gate-level realization XOR = OR(AND(a, NOT b), AND(NOT a, b)).
pub fn abs_subtract_correlated(a: &Bitstream, b: &Bitstream) -> Bitstream {
    a.xor(b)
}

/// Scaled division (Fig 4d/5d): out = a/(a+b) via the JK feedback
/// circuit, Q' = (a AND NOT Q) OR (NOT b AND Q), Q0 = 0 (the paper:
/// "Q should be initially set to zero").
pub fn scaled_divide(a: &Bitstream, b: &Bitstream) -> Bitstream {
    assert_eq!(a.len(), b.len());
    let mut q = false;
    let mut out = Bitstream::zeros(a.len());
    for i in 0..a.len() {
        out.set(i, q);
        q = (a.get(i) && !q) || (!b.get(i) && q);
    }
    out
}

/// ADDIE (adaptive digital element, Gaines): a saturating counter whose
/// normalized value v is emitted as Bernoulli(v) samples. With the update
/// ΔC = x − y·y′ it settles at v = √E[x]. Shared between the functional
/// oracle below and the netlist evaluator's `Addie` macro node so both
/// produce bit-identical outputs.
#[derive(Debug, Clone)]
pub struct Addie {
    max: u64,
    c: u64,
    rng: Xoshiro256,
}

impl Addie {
    pub fn new(counter_bits: u32, seed: u64) -> Self {
        let max = 1u64 << counter_bits;
        Self { max, c: max / 2, rng: Xoshiro256::seeded(seed) }
    }

    /// Feed one input bit, emit one output bit.
    pub fn step(&mut self, x: bool) -> bool {
        let y = self.rng.next_below(self.max) < self.c;
        let y2 = self.rng.next_below(self.max) < self.c;
        if x && self.c < self.max {
            self.c += 1;
        }
        if y && y2 && self.c > 0 {
            self.c -= 1;
        }
        y
    }

    /// Current integrator value in [0,1].
    pub fn value(&self) -> f64 {
        self.c as f64 / self.max as f64
    }
}

/// Default ADDIE seed: keeps oracle and netlist evaluation bit-identical.
pub const ADDIE_SEED: u64 = 0x5137_1A57;

/// Square root (Fig 5e): out = sqrt(A) via an ADDIE integrator. `a1` and
/// `a2` are two independently generated SNs of the same value (the
/// paper's note on Fig 5e); the two copies are consumed alternately. The
/// integrator resolution is `counter_bits` (10 via [`square_root`]).
pub fn square_root_with(a1: &Bitstream, a2: &Bitstream, counter_bits: u32, seed: u64) -> Bitstream {
    assert_eq!(a1.len(), a2.len());
    let mut addie = Addie::new(counter_bits, seed);
    let mut out = Bitstream::zeros(a1.len());
    for i in 0..a1.len() {
        let x = if i % 2 == 0 { a1.get(i) } else { a2.get(i) };
        out.set(i, addie.step(x));
    }
    out
}

/// Square root with the default 10-bit integrator (deterministic seed).
pub fn square_root(a1: &Bitstream, a2: &Bitstream) -> Bitstream {
    square_root_with(a1, a2, 10, ADDIE_SEED)
}

/// Exponential e^{-cA}, 0 < c ≤ 1, via the 5th-order Maclaurin expansion
/// (paper Fig 5f, citing [20]):
///   e^{-cx} ≈ 1 - cx(1 - (cx/2)(1 - (cx/3)(1 - (cx/4)(1 - cx/5))))
/// Each Horner stage is 1 - u·v = NOT(AND(u, v)) with independent
/// streams. `a[k]` are five independent SNs of value A and `c_streams[k]`
/// five independent SNs of value c/(k+1).
pub fn exponential(a: &[Bitstream; 5], c_streams: &[Bitstream; 5]) -> Bitstream {
    let len = a[0].len();
    let mut acc = Bitstream::ones(len); // innermost "1"
    for k in (0..5).rev() {
        let cx = a[k].and(&c_streams[k]); // value = A·c/(k+1)
        acc = cx.and(&acc).not(); // 1 - (A·c/(k+1))·acc
    }
    acc
}

/// Generate the five constant streams C_k = c/(k+1) for e^{-cA}.
pub fn exp_constant_streams(c: f64, len: usize, rng: &mut Xoshiro256) -> [Bitstream; 5] {
    std::array::from_fn(|k| Bitstream::sample(c / (k as f64 + 1.0), len, rng))
}

/// Five independent SNs of the same value (exponential inputs).
pub fn independent_copies(p: f64, len: usize, rng: &mut Xoshiro256) -> [Bitstream; 5] {
    std::array::from_fn(|_| Bitstream::sample(p, len, rng))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    const LEN: usize = 65536;

    #[test]
    fn scaled_add_converges() {
        forall(0xADD, 30, |g| {
            let (pa, pb) = (g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0));
            let mut rng = Xoshiro256::seeded(g.u64_below(1 << 62));
            let a = Bitstream::sample(pa, LEN, &mut rng);
            let b = Bitstream::sample(pb, LEN, &mut rng);
            let s = Bitstream::sample(0.5, LEN, &mut rng);
            let got = scaled_add(&a, &b, &s).value();
            assert!((got - 0.5 * (pa + pb)).abs() < 0.015);
        });
    }

    #[test]
    fn multiply_converges() {
        forall(0x301, 30, |g| {
            let (pa, pb) = (g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0));
            let mut rng = Xoshiro256::seeded(g.u64_below(1 << 62));
            let a = Bitstream::sample(pa, LEN, &mut rng);
            let b = Bitstream::sample(pb, LEN, &mut rng);
            assert!((multiply(&a, &b).value() - pa * pb).abs() < 0.015);
        });
    }

    #[test]
    fn divide_converges_to_a_over_a_plus_b() {
        forall(0xD1, 30, |g| {
            let pa = g.f64_in(0.05, 0.95);
            let pb = g.f64_in(0.05, 0.95);
            let mut rng = Xoshiro256::seeded(g.u64_below(1 << 62));
            let a = Bitstream::sample(pa, LEN, &mut rng);
            let b = Bitstream::sample(pb, LEN, &mut rng);
            let got = scaled_divide(&a, &b).value();
            let want = pa / (pa + pb);
            assert!((got - want).abs() < 0.03, "pa={pa} pb={pb} got={got} want={want}");
        });
    }

    #[test]
    fn divide_symmetric_inputs_give_half() {
        let mut rng = Xoshiro256::seeded(31);
        let a = Bitstream::sample(0.8, LEN, &mut rng);
        let b = Bitstream::sample(0.8, LEN, &mut rng);
        assert!((scaled_divide(&a, &b).value() - 0.5).abs() < 0.02);
    }

    #[test]
    fn sqrt_converges() {
        forall(0x509, 30, |g| {
            let p = g.f64_in(0.02, 0.98);
            let mut rng = Xoshiro256::seeded(g.u64_below(1 << 62));
            let a1 = Bitstream::sample(p, LEN, &mut rng);
            let a2 = Bitstream::sample(p, LEN, &mut rng);
            let got = square_root(&a1, &a2).value();
            assert!((got - p.sqrt()).abs() < 0.05, "p={p} got={got} want={}", p.sqrt());
        });
    }

    #[test]
    fn exponential_converges() {
        forall(0xE4, 30, |g| {
            let p = g.f64_in(0.0, 1.0);
            let c = g.f64_in(0.2, 1.0);
            let mut rng = Xoshiro256::seeded(g.u64_below(1 << 62));
            let a = independent_copies(p, LEN, &mut rng);
            let cs = exp_constant_streams(c, LEN, &mut rng);
            let got = exponential(&a, &cs).value();
            let want = (-c * p).exp();
            assert!((got - want).abs() < 0.03, "p={p} c={c} got={got} want={want}");
        });
    }

    #[test]
    fn exponential_maclaurin_truncation_behaviour() {
        // At c=1, p=1 the 5th-order expansion overshoots e^{-1} slightly;
        // check we match the *expansion*, not the true exponential.
        let mut rng = Xoshiro256::seeded(77);
        let a = independent_copies(1.0, LEN, &mut rng);
        let cs = exp_constant_streams(1.0, LEN, &mut rng);
        let got = exponential(&a, &cs).value();
        let expansion = 1.0 - 1.0 * (1.0 - 0.5 * (1.0 - (1.0 / 3.0) * (1.0 - 0.25 * (1.0 - 0.2))));
        assert!((got - expansion).abs() < 0.02, "got={got} want={expansion}");
    }
}
