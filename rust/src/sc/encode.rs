//! Binary ↔ stochastic conversion (steps 1 and 3 of an SC system, §2.3).
//!
//! Step 1 (BtoS) in Stoch-IMC is performed by the intrinsic stochastic
//! switching of the MTJ: the bank's BtoS memory maps an 8-bit binary
//! value to a (V_p, t_p) pulse whose switching probability equals the
//! value (see `arch::btos`). Functionally that is a Bernoulli sample per
//! cell, which is what [`encode`] does. Step 3 (StoB) is a popcount.

use super::bitstream::Bitstream;
use crate::util::prng::Xoshiro256;

/// Quantize a real value in [0,1] to `resolution`-bit fixed point, the
/// precision the paper's 8-bit binary baseline uses.
pub fn quantize(value: f64, resolution: u32) -> f64 {
    let steps = (1u64 << resolution) as f64;
    (value.clamp(0.0, 1.0) * steps).round() / steps
}

/// Encode a value in [0,1] as an SN of length `len` (independent draw).
pub fn encode(value: f64, len: usize, rng: &mut Xoshiro256) -> Bitstream {
    Bitstream::sample(value.clamp(0.0, 1.0), len, rng)
}

/// Encode several values against a *shared* uniform sequence, producing
/// maximally-correlated bitstreams (required by absolute-value
/// subtraction, §4.1).
pub fn encode_correlated(values: &[f64], len: usize, rng: &mut Xoshiro256) -> Vec<Bitstream> {
    let mut us = vec![0.0; len];
    rng.fill_f64(&mut us);
    values
        .iter()
        .map(|&v| Bitstream::from_uniforms(v.clamp(0.0, 1.0), &us))
        .collect()
}

/// StoB: decode an SN to its unipolar value (popcount / len).
pub fn decode(bs: &Bitstream) -> f64 {
    bs.value()
}

/// Stochastic correlation coefficient (SCC, Alaghi & Hayes) between two
/// bitstreams — used by tests to verify correlated vs independent
/// generation. SCC = +1 for maximally correlated, ~0 for independent.
pub fn scc(a: &Bitstream, b: &Bitstream) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let p_a = a.value();
    let p_b = b.value();
    let p_ab = a.and(b).popcount() as f64 / n;
    let delta = p_ab - p_a * p_b;
    if delta.abs() < 1e-12 {
        return 0.0;
    }
    if delta > 0.0 {
        delta / (p_a.min(p_b) - p_a * p_b).max(1e-12)
    } else {
        delta / (p_a * p_b - (p_a + p_b - 1.0).max(0.0)).max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn quantize_8bit() {
        assert_eq!(quantize(0.5, 8), 0.5);
        assert!((quantize(0.7, 8) - 0.69921875).abs() < 1e-9);
        assert_eq!(quantize(-0.1, 8), 0.0);
        assert_eq!(quantize(1.5, 8), 1.0);
    }

    #[test]
    fn encode_decode_roundtrip_statistical() {
        forall(0xE2C, 40, |g| {
            let v = g.f64_in(0.0, 1.0);
            let mut rng = Xoshiro256::seeded(g.u64_below(1 << 62));
            let bs = encode(v, 65536, &mut rng);
            assert!((decode(&bs) - v).abs() < 0.01);
        });
    }

    #[test]
    fn correlated_streams_have_scc_one() {
        let mut rng = Xoshiro256::seeded(41);
        let vs = encode_correlated(&[0.3, 0.7], 65536, &mut rng);
        let s = scc(&vs[0], &vs[1]);
        assert!(s > 0.95, "scc={s}");
    }

    #[test]
    fn independent_streams_have_scc_near_zero() {
        let mut rng = Xoshiro256::seeded(43);
        let a = encode(0.5, 65536, &mut rng);
        let b = encode(0.5, 65536, &mut rng);
        let s = scc(&a, &b);
        assert!(s.abs() < 0.05, "scc={s}");
    }

    #[test]
    fn correlated_values_exact_ordering() {
        // With shared uniforms, the smaller-valued stream is a subset of
        // the larger one: AND(a,b) == min-stream exactly.
        let mut rng = Xoshiro256::seeded(47);
        let vs = encode_correlated(&[0.2, 0.9], 4096, &mut rng);
        assert_eq!(vs[0].and(&vs[1]), vs[0]);
    }
}
