//! Transposed lane-major bit planes for word-parallel batch evaluation.
//!
//! [`Bitstream`] packs one stochastic number's *time* dimension 64 bits
//! per word. That layout is ideal for the functional oracles (one SN,
//! all bits at once) but wrong for the wave hot path, where many *batch
//! rows* run the same circuit in lock-step: there each time step needs
//! one bit from every row. [`LaneBlock`] stores the transposed layout —
//! one `[u64; W]` lane word per time step whose bit `l` is batch row
//! `l`'s bit — so a single bitwise instruction (per word of the lane
//! word) evaluates one gate for up to `64·W` rows at once, the software
//! analogue of a subarray group firing all its rows in one cycle (paper
//! §4.1, Fig 7b). `W ∈ {1, 2, 4, 8}` widens the block to 64/128/256/512
//! rows; the words of one lane word are contiguous, so the
//! per-instruction loops are autovectorizable (and W = 8 is exactly one
//! AVX-512 register per time step).
//!
//! Since the lane-major SNG pipeline (`sc::sng`) generates input blocks
//! directly in this layout and the vertical-counter readout
//! ([`LaneBlock::lane_popcounts_into`]) converts outputs without
//! leaving it, the row↔lane transposition ([`LaneBlock::from_rows`] /
//! [`LaneBlock::to_rows`], the classic 64×64 bit-matrix transpose) is
//! now a test/debug conversion only — the wave hot path never
//! transposes.

use super::bitstream::Bitstream;

/// Number of batch rows one `u64` of a lane word carries.
pub const LANES: usize = 64;

/// Widest supported lane word, in `u64`s (512 rows per block).
pub const MAX_LANE_WORDS: usize = 8;

/// In-place 64×64 bit-matrix transpose over LSB-first words: afterwards
/// bit `r` of `a[c]` is what bit `c` of `a[r]` was. Hacker's Delight
/// §7-3 adapted to 64-bit words and LSB-first column numbering.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j: u32 = 32;
    let mut m: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k: usize = 0;
        while k < 64 {
            let t = ((a[k] >> j) ^ a[k + j as usize]) & m;
            a[k] ^= t << j;
            a[k + j as usize] ^= t;
            k = (k + j as usize + 1) & !(j as usize);
        }
        j >>= 1;
        m ^= m << j;
    }
}

/// Up to `64·W` batch rows of equal-length bitstreams in transposed,
/// lane-major layout: `word(t)` holds time step `t` across all rows,
/// row `l` in bit lane `l % 64` of word `l / 64`. Lanes at index ≥
/// `lanes` are dead and always read 0 (writes are masked), so per-lane
/// popcounts stay exact for ragged blocks (`live % (64·W) != 0`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneBlock<const W: usize> {
    len: usize,
    lanes: usize,
    words: Vec<[u64; W]>,
}

/// The original single-word lane block (64 rows) — the default width,
/// and the layout every pre-width API keeps using.
pub type LaneMatrix = LaneBlock<1>;

impl<const W: usize> LaneBlock<W> {
    /// All-zero block of `len` time steps across `lanes` live rows.
    pub fn zeros(len: usize, lanes: usize) -> Self {
        assert!(
            (1..=MAX_LANE_WORDS).contains(&W),
            "lane words per step must be in 1..={MAX_LANE_WORDS}"
        );
        assert!(lanes <= W * LANES, "at most {} lanes per block", W * LANES);
        Self { len, lanes, words: vec![[0u64; W]; len] }
    }

    /// Reshape in place to an all-zero `len × lanes` block, reusing the
    /// word allocation — the workspace-reuse primitive the wave path
    /// calls once per lane block instead of allocating a fresh block.
    pub fn reset(&mut self, len: usize, lanes: usize) {
        assert!(lanes <= W * LANES, "at most {} lanes per block", W * LANES);
        self.len = len;
        self.lanes = lanes;
        self.words.clear();
        self.words.resize(len, [0u64; W]);
    }

    /// Transpose `rows` (≤ `64·W` equal-length bitstreams) into
    /// lane-major layout: lane `l` carries `rows[l]`. Test/debug
    /// conversion — the wave hot path generates blocks directly via
    /// `sc::sng`.
    pub fn from_rows(rows: &[Bitstream]) -> Self {
        let lanes = rows.len();
        assert!(lanes <= W * LANES, "at most {} lanes per block", W * LANES);
        let len = rows.first().map_or(0, |b| b.len());
        for r in rows {
            assert_eq!(r.len(), len, "row bitstream length mismatch");
        }
        let mut out = Self::zeros(len, lanes);
        let mut block = [0u64; 64];
        for g in 0..lanes.div_ceil(LANES) {
            let g0 = g * LANES;
            let g1 = (g0 + LANES).min(lanes);
            for chunk in 0..len.div_ceil(64) {
                for (lane, row) in block.iter_mut().zip(&rows[g0..g1]) {
                    *lane = row.words()[chunk];
                }
                block[g1 - g0..].fill(0);
                transpose64(&mut block);
                let base = chunk * 64;
                let n = (len - base).min(64);
                for (t_off, &w) in block[..n].iter().enumerate() {
                    out.words[base + t_off][g] = w;
                }
            }
        }
        out
    }

    /// Time steps (the bitstream length BL).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Live rows in this block (≤ `64·W`).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Mask with a 1 in every live lane, per word of the lane word.
    #[inline]
    pub fn lane_mask(&self) -> [u64; W] {
        let mut m = [0u64; W];
        for (k, mk) in m.iter_mut().enumerate() {
            let lo = k * LANES;
            *mk = if self.lanes >= lo + LANES {
                u64::MAX
            } else if self.lanes > lo {
                (1u64 << (self.lanes - lo)) - 1
            } else {
                0
            };
        }
        m
    }

    /// All live lanes' bits at time step `t`.
    #[inline]
    pub fn word(&self, t: usize) -> [u64; W] {
        self.words[t]
    }

    /// Store all lanes' bits for time step `t`; dead lanes are masked
    /// off so popcounts never see garbage from word-wide gate ops.
    #[inline]
    pub fn set_word(&mut self, t: usize, w: [u64; W]) {
        let m = self.lane_mask();
        self.words[t] = std::array::from_fn(|k| w[k] & m[k]);
    }

    /// XOR a fault mask into time step `t`, masked to live lanes — the
    /// lane-word fault-injection primitive (`fault::FaultCutoffs`
    /// builds `mask`; dead lanes must stay zero for exact ragged-block
    /// popcounts, so the mask is clipped like [`LaneBlock::set_word`]).
    #[inline]
    pub fn xor_word(&mut self, t: usize, mask: [u64; W]) {
        let m = self.lane_mask();
        let w = &mut self.words[t];
        for k in 0..W {
            w[k] ^= mask[k] & m[k];
        }
    }

    /// Transpose back into one time-major [`Bitstream`] per live lane —
    /// the inverse of [`LaneBlock::from_rows`]. Test/debug conversion;
    /// the wave hot path reads outputs with the vertical counter
    /// ([`LaneBlock::lane_popcounts_into`]) instead.
    pub fn to_rows(&self) -> Vec<Bitstream> {
        let n_chunks = self.len.div_ceil(64);
        let mut per_row: Vec<Vec<u64>> = vec![vec![0u64; n_chunks]; self.lanes];
        let mut block = [0u64; 64];
        for g in 0..self.lanes.div_ceil(LANES) {
            let g0 = g * LANES;
            let g1 = (g0 + LANES).min(self.lanes);
            for chunk in 0..n_chunks {
                let base = chunk * 64;
                let n = (self.len - base).min(64);
                for (t_off, slot) in block[..n].iter_mut().enumerate() {
                    *slot = self.words[base + t_off][g];
                }
                block[n..].fill(0);
                transpose64(&mut block);
                for (l, row) in per_row[g0..g1].iter_mut().enumerate() {
                    row[chunk] = block[l];
                }
            }
        }
        per_row.into_iter().map(|w| Bitstream::from_words(self.len, w)).collect()
    }

    /// Extract lane `l` back into time-major [`Bitstream`] layout
    /// (differential tests and debugging; not on the wave hot path).
    pub fn lane(&self, l: usize) -> Bitstream {
        assert!(l < self.lanes, "lane {l} out of {}", self.lanes);
        let bits: Vec<bool> =
            self.words.iter().map(|w| (w[l / LANES] >> (l % LANES)) & 1 == 1).collect();
        Bitstream::from_bits(&bits)
    }

    /// Number of 1s in lane `l` — one row's StoB popcount (test/debug;
    /// the wave path uses the vertical counter for all lanes at once).
    pub fn lane_popcount(&self, l: usize) -> u64 {
        assert!(l < self.lanes, "lane {l} out of {}", self.lanes);
        self.words.iter().map(|w| (w[l / LANES] >> (l % LANES)) & 1).sum()
    }

    /// Unipolar value of lane `l` = popcount / len, exactly matching
    /// [`Bitstream::value`] on the same bits.
    pub fn lane_value(&self, l: usize) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.lane_popcount(l) as f64 / self.len as f64
    }

    /// Vertical-counter StoB readout: every live lane's popcount in one
    /// pass, without transposing back to rows. `planes` is a carry-save
    /// bit-sliced counter — `planes[k]` holds bit `k` of every lane's
    /// running count — so adding one time step is a ripple-carry over at
    /// most `log₂(len)+1` lane words, and the whole readout costs
    /// O(len · log len) word ops *for all `64·W` lanes together*
    /// (amortized ~2 plane updates per step), versus O(len) word ops
    /// *per lane* for row-wise popcounts. Both scratch buffers are
    /// caller-owned so repeated readouts reuse their allocations;
    /// `counts` is resized to `lanes`.
    pub fn lane_popcounts_into(&self, planes: &mut Vec<[u64; W]>, counts: &mut Vec<u32>) {
        debug_assert!(self.len < (1 << 31), "lane counts overflow u32");
        planes.clear();
        for w in &self.words {
            // Add the step's 1-bits into the counter: carry-save ripple.
            let mut carry = *w;
            let mut k = 0;
            while carry != [0u64; W] {
                if k == planes.len() {
                    planes.push(carry);
                    break;
                }
                let p = &mut planes[k];
                let sum: [u64; W] = std::array::from_fn(|i| p[i] ^ carry[i]);
                let next: [u64; W] = std::array::from_fn(|i| p[i] & carry[i]);
                *p = sum;
                carry = next;
                k += 1;
            }
        }
        counts.clear();
        counts.resize(self.lanes, 0);
        for (k, p) in planes.iter().enumerate() {
            for (l, c) in counts.iter_mut().enumerate() {
                *c += (((p[l / LANES] >> (l % LANES)) & 1) as u32) << k;
            }
        }
    }

    /// Allocating convenience wrapper over
    /// [`LaneBlock::lane_popcounts_into`].
    pub fn lane_popcounts(&self) -> Vec<u32> {
        let mut planes = Vec::new();
        let mut counts = Vec::new();
        self.lane_popcounts_into(&mut planes, &mut counts);
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn transpose64_matches_naive() {
        let mut rng = Xoshiro256::seeded(0xBEEF);
        for _ in 0..10 {
            let orig: [u64; 64] = std::array::from_fn(|_| rng.next_u64());
            let mut t = orig;
            transpose64(&mut t);
            for r in 0..64 {
                for c in 0..64 {
                    assert_eq!((t[c] >> r) & 1, (orig[r] >> c) & 1, "({r},{c})");
                }
            }
            // Involution: transposing twice restores the input.
            transpose64(&mut t);
            assert_eq!(t, orig);
        }
    }

    fn roundtrip_cases<const W: usize>(cases: &[(usize, usize)], seed: u64) {
        let mut rng = Xoshiro256::seeded(seed);
        for &(len, lanes) in cases {
            let rows: Vec<Bitstream> =
                (0..lanes).map(|_| Bitstream::sample(0.4, len, &mut rng)).collect();
            let m = LaneBlock::<W>::from_rows(&rows);
            assert_eq!(m.len(), len);
            assert_eq!(m.lanes(), lanes);
            assert_eq!(m.to_rows(), rows, "W={W} len={len} lanes={lanes}");
            for (l, row) in rows.iter().enumerate() {
                assert_eq!(&m.lane(l), row, "W={W} len={len} lanes={lanes} lane={l}");
                assert_eq!(m.lane_popcount(l), row.popcount());
                assert_eq!(m.lane_value(l), row.value());
            }
            // Vertical-counter readout equals the per-lane popcounts.
            let counts = m.lane_popcounts();
            assert_eq!(counts.len(), lanes);
            for (l, row) in rows.iter().enumerate() {
                assert_eq!(counts[l] as u64, row.popcount(), "W={W} lane {l}");
            }
        }
    }

    #[test]
    fn from_rows_round_trips_every_lane() {
        roundtrip_cases::<1>(&[(1, 1), (63, 5), (64, 64), (65, 63), (100, 17), (256, 64)], 7);
    }

    #[test]
    fn wide_blocks_round_trip_every_lane() {
        // W ∈ {2, 4, 8} with lane counts walking the per-word
        // boundaries (64, 65, …, 256, 257, 512) and ragged lengths.
        roundtrip_cases::<2>(&[(100, 65), (64, 128), (65, 127), (1, 2)], 11);
        roundtrip_cases::<4>(&[(100, 129), (256, 256), (63, 200), (65, 65)], 13);
        roundtrip_cases::<8>(&[(100, 257), (64, 512), (65, 449), (63, 300)], 17);
    }

    #[test]
    fn dead_lanes_stay_masked() {
        let mut m = LaneMatrix::zeros(10, 3);
        for t in 0..10 {
            m.set_word(t, [u64::MAX]);
        }
        assert_eq!(m.word(0), [0b111]);
        for l in 0..3 {
            assert_eq!(m.lane_popcount(l), 10);
        }
        // Wide block: the mask covers partial words past the first.
        let mut m = LaneBlock::<4>::zeros(5, 130);
        for t in 0..5 {
            m.set_word(t, [u64::MAX; 4]);
        }
        assert_eq!(m.word(0), [u64::MAX, u64::MAX, 0b11, 0]);
        let counts = m.lane_popcounts();
        assert_eq!(counts.len(), 130);
        assert!(counts.iter().all(|&c| c == 5));
    }

    #[test]
    fn xor_word_flips_live_lanes_only() {
        let mut m = LaneBlock::<2>::zeros(3, 70);
        m.set_word(1, [u64::MAX; 2]);
        m.xor_word(1, [0b101, u64::MAX]);
        // Live lanes flipped; dead lanes (≥70) stayed zero.
        assert_eq!(m.word(1), [u64::MAX ^ 0b101, 0]);
        m.xor_word(0, [u64::MAX; 2]);
        assert_eq!(m.word(0), [u64::MAX, (1u64 << 6) - 1]);
        assert_eq!(m.lane_popcount(69), 1);
    }

    #[test]
    fn word_layout_is_lane_major() {
        // Two rows: row 0 = 1010…, row 1 = all ones.
        let r0 = Bitstream::from_bits(&[true, false, true, false]);
        let r1 = Bitstream::from_bits(&[true, true, true, true]);
        let m = LaneMatrix::from_rows(&[r0, r1]);
        assert_eq!(m.word(0), [0b11]);
        assert_eq!(m.word(1), [0b10]);
        assert_eq!(m.word(2), [0b11]);
        assert_eq!(m.word(3), [0b10]);
    }

    #[test]
    fn reset_reuses_and_zeroes() {
        let mut m = LaneBlock::<2>::zeros(4, 100);
        m.set_word(0, [u64::MAX; 2]);
        m.reset(6, 70);
        assert_eq!(m.len(), 6);
        assert_eq!(m.lanes(), 70);
        for t in 0..6 {
            assert_eq!(m.word(t), [0, 0], "stale bits at t={t}");
        }
        assert_eq!(m.lane_mask(), [u64::MAX, (1u64 << 6) - 1]);
    }

    #[test]
    fn vertical_counter_matches_naive_on_random_blocks() {
        let mut rng = Xoshiro256::seeded(0xC0DE);
        for &(len, lanes) in &[(1usize, 1usize), (100, 100), (256, 256), (1023, 77)] {
            let mut m = LaneBlock::<4>::zeros(len, lanes);
            for t in 0..len {
                m.set_word(t, std::array::from_fn(|_| rng.next_u64()));
            }
            let counts = m.lane_popcounts();
            for l in 0..lanes {
                assert_eq!(counts[l] as u64, m.lane_popcount(l), "len={len} lanes={lanes} l={l}");
            }
        }
    }
}
