//! Transposed lane-major bit planes for word-parallel batch evaluation.
//!
//! [`Bitstream`] packs one stochastic number's *time* dimension 64 bits
//! per word. That layout is ideal for the functional oracles (one SN,
//! all bits at once) but wrong for the wave hot path, where up to 64
//! *batch rows* run the same circuit in lock-step: there each time step
//! needs one bit from every row. [`LaneMatrix`] stores the transposed
//! layout — one `u64` per time step whose bit `l` is batch row `l`'s
//! bit — so a single bitwise instruction evaluates one gate for 64 rows
//! at once, the software analogue of a subarray group firing all its
//! rows in one cycle (paper §4.1, Fig 7b).
//!
//! The row↔lane transposition itself is the classic 64×64 bit-matrix
//! transpose (recursive masked block swaps, log₂ 64 passes), so moving a
//! block between layouts costs O(64·log 64) word ops per 64 time steps —
//! negligible next to gate evaluation.

use super::bitstream::Bitstream;

/// Number of batch rows one machine word carries, one per bit lane.
pub const LANES: usize = 64;

/// In-place 64×64 bit-matrix transpose over LSB-first words: afterwards
/// bit `r` of `a[c]` is what bit `c` of `a[r]` was. Hacker's Delight
/// §7-3 adapted to 64-bit words and LSB-first column numbering.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j: u32 = 32;
    let mut m: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k: usize = 0;
        while k < 64 {
            let t = ((a[k] >> j) ^ a[k + j as usize]) & m;
            a[k] ^= t << j;
            a[k + j as usize] ^= t;
            k = (k + j as usize + 1) & !(j as usize);
        }
        j >>= 1;
        m ^= m << j;
    }
}

/// Up to 64 batch rows of equal-length bitstreams in transposed,
/// lane-major layout: `word(t)` holds time step `t` across all rows,
/// row `l` in bit lane `l`. Lanes at index ≥ `lanes` are dead and
/// always read 0 (writes are masked), so per-lane popcounts stay exact
/// for ragged blocks (`live % 64 != 0`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneMatrix {
    len: usize,
    lanes: usize,
    words: Vec<u64>,
}

impl LaneMatrix {
    /// All-zero matrix of `len` time steps across `lanes` live rows.
    pub fn zeros(len: usize, lanes: usize) -> Self {
        assert!(lanes <= LANES, "at most {LANES} lanes per word");
        Self { len, lanes, words: vec![0; len] }
    }

    /// Transpose `rows` (≤ 64 equal-length bitstreams) into lane-major
    /// layout: lane `l` carries `rows[l]`.
    pub fn from_rows(rows: &[Bitstream]) -> Self {
        let lanes = rows.len();
        assert!(lanes <= LANES, "at most {LANES} lanes per word");
        let len = rows.first().map_or(0, |b| b.len());
        for r in rows {
            assert_eq!(r.len(), len, "row bitstream length mismatch");
        }
        let mut out = Self::zeros(len, lanes);
        let mut block = [0u64; 64];
        for chunk in 0..len.div_ceil(64) {
            for (lane, row) in block.iter_mut().zip(rows) {
                *lane = row.words()[chunk];
            }
            block[lanes..].fill(0);
            transpose64(&mut block);
            let base = chunk * 64;
            let n = (len - base).min(64);
            out.words[base..base + n].copy_from_slice(&block[..n]);
        }
        out
    }

    /// Time steps (the bitstream length BL).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Live rows in this block (≤ 64).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Mask with a 1 in every live lane.
    #[inline]
    pub fn lane_mask(&self) -> u64 {
        if self.lanes == LANES {
            u64::MAX
        } else {
            (1u64 << self.lanes) - 1
        }
    }

    /// All live lanes' bits at time step `t`.
    #[inline]
    pub fn word(&self, t: usize) -> u64 {
        self.words[t]
    }

    /// Store all lanes' bits for time step `t`; dead lanes are masked
    /// off so popcounts never see garbage from word-wide gate ops.
    #[inline]
    pub fn set_word(&mut self, t: usize, w: u64) {
        self.words[t] = w & self.lane_mask();
    }

    /// Transpose back into one time-major [`Bitstream`] per live lane —
    /// the inverse of [`LaneMatrix::from_rows`], used to read a wave's
    /// outputs row-wise (per-row StoB popcounts then run 64 bits per
    /// `count_ones` instead of per-bit shift-and-sum).
    pub fn to_rows(&self) -> Vec<Bitstream> {
        let n_chunks = self.len.div_ceil(64);
        let mut per_row: Vec<Vec<u64>> = vec![vec![0u64; n_chunks]; self.lanes];
        let mut block = [0u64; 64];
        for chunk in 0..n_chunks {
            let base = chunk * 64;
            let n = (self.len - base).min(64);
            block[..n].copy_from_slice(&self.words[base..base + n]);
            block[n..].fill(0);
            transpose64(&mut block);
            for (l, row) in per_row.iter_mut().enumerate() {
                row[chunk] = block[l];
            }
        }
        per_row.into_iter().map(|w| Bitstream::from_words(self.len, w)).collect()
    }

    /// Extract lane `l` back into time-major [`Bitstream`] layout
    /// (differential tests and debugging; not on the wave hot path).
    pub fn lane(&self, l: usize) -> Bitstream {
        assert!(l < self.lanes, "lane {l} out of {}", self.lanes);
        let bits: Vec<bool> = self.words.iter().map(|&w| (w >> l) & 1 == 1).collect();
        Bitstream::from_bits(&bits)
    }

    /// Number of 1s in lane `l` — the per-row StoB popcount.
    pub fn lane_popcount(&self, l: usize) -> u64 {
        assert!(l < self.lanes, "lane {l} out of {}", self.lanes);
        self.words.iter().map(|&w| (w >> l) & 1).sum()
    }

    /// Unipolar value of lane `l` = popcount / len, exactly matching
    /// [`Bitstream::value`] on the same bits.
    pub fn lane_value(&self, l: usize) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.lane_popcount(l) as f64 / self.len as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn transpose64_matches_naive() {
        let mut rng = Xoshiro256::seeded(0xBEEF);
        for _ in 0..10 {
            let orig: [u64; 64] = std::array::from_fn(|_| rng.next_u64());
            let mut t = orig;
            transpose64(&mut t);
            for r in 0..64 {
                for c in 0..64 {
                    assert_eq!((t[c] >> r) & 1, (orig[r] >> c) & 1, "({r},{c})");
                }
            }
            // Involution: transposing twice restores the input.
            transpose64(&mut t);
            assert_eq!(t, orig);
        }
    }

    #[test]
    fn from_rows_round_trips_every_lane() {
        let mut rng = Xoshiro256::seeded(7);
        for (len, lanes) in [(1, 1), (63, 5), (64, 64), (65, 63), (100, 17), (256, 64)] {
            let rows: Vec<Bitstream> =
                (0..lanes).map(|_| Bitstream::sample(0.4, len, &mut rng)).collect();
            let m = LaneMatrix::from_rows(&rows);
            assert_eq!(m.len(), len);
            assert_eq!(m.lanes(), lanes);
            assert_eq!(m.to_rows(), rows, "len={len} lanes={lanes}");
            for (l, row) in rows.iter().enumerate() {
                assert_eq!(&m.lane(l), row, "len={len} lanes={lanes} lane={l}");
                assert_eq!(m.lane_popcount(l), row.popcount());
                assert_eq!(m.lane_value(l), row.value());
            }
        }
    }

    #[test]
    fn dead_lanes_stay_masked() {
        let mut m = LaneMatrix::zeros(10, 3);
        for t in 0..10 {
            m.set_word(t, u64::MAX);
        }
        assert_eq!(m.word(0), 0b111);
        for l in 0..3 {
            assert_eq!(m.lane_popcount(l), 10);
        }
    }

    #[test]
    fn word_layout_is_lane_major() {
        // Two rows: row 0 = 1010…, row 1 = all ones.
        let r0 = Bitstream::from_bits(&[true, false, true, false]);
        let r1 = Bitstream::from_bits(&[true, true, true, true]);
        let m = LaneMatrix::from_rows(&[r0, r1]);
        assert_eq!(m.word(0), 0b11);
        assert_eq!(m.word(1), 0b10);
        assert_eq!(m.word(2), 0b11);
        assert_eq!(m.word(3), 0b10);
    }
}
