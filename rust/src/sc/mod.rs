//! S2 — stochastic computing library.
//!
//! Unipolar-encoded stochastic numbers as packed bitstreams (§2.3),
//! the six arithmetic operations (Fig 4/5), binary↔stochastic
//! conversion helpers, and the transposed lane-major bit planes
//! (`bitplane`) the word-parallel wave engine evaluates 64 batch rows
//! per word on. This is the bit-exact functional model that the
//! in-memory implementations (S6/S7) and the JAX artifacts (S18) are
//! validated against.

pub mod bitplane;
pub mod bitstream;
pub mod encode;
pub mod ops;

pub use bitplane::LaneMatrix;
pub use bitstream::Bitstream;
