//! S2 — stochastic computing library.
//!
//! Unipolar-encoded stochastic numbers as packed bitstreams (§2.3),
//! the six arithmetic operations (Fig 4/5), binary↔stochastic
//! conversion helpers, the transposed lane-major bit planes
//! (`bitplane`) the word-parallel wave engine evaluates up to 256
//! batch rows per `u64×W` lane word on, and the lane-major SNG
//! (`sng`) that generates those blocks directly from a lockstep RNG
//! bank — the whole wave pipeline (generation → gates → StoB readout)
//! stays in the parallel domain. This is the bit-exact functional
//! model that the in-memory implementations (S6/S7) and the JAX
//! artifacts (S18) are validated against.

pub mod bitplane;
pub mod bitstream;
pub mod encode;
pub mod ops;
pub mod sng;

pub use bitplane::{LaneBlock, LaneMatrix};
pub use bitstream::Bitstream;
