//! Lane-major stochastic number generation (SNG).
//!
//! The scalar SNG samples one row at a time ([`Bitstream::sample`]):
//! `bl` Bernoulli draws from that row's PRNG stream, packed along the
//! *time* axis. The wave engine wants the transposed layout — for each
//! time step, one packed lane word holding every row's bit — and it
//! used to get there by generating per-row bitstreams and transposing.
//! This module generates the lane-major words **directly**: an
//! [`RngBank`] steps every row's PRNG in lockstep, each time step
//! compares all lanes' raw draws against their per-lane thresholds, and
//! the comparison bits are packed into one `[u64; W]` lane word — no
//! per-row intermediates, no transpose.
//!
//! Comparisons are **integer**: the scalar path's Bernoulli test
//! `next_f64() < v` is `(x >> 11)·2⁻⁵³ < v` for the raw draw `x`,
//! which is equivalent to the pure-integer `(x >> 11) < ⌈v·2⁵³⌉`
//! (see [`cutoff`]). The per-lane cutoffs are computed **once per
//! input block** instead of converting every draw of every lane to
//! `f64`, and bit-identity with the scalar comparison is pinned by
//! tests below.
//!
//! Draw-order contract (what keeps outputs bit-identical to the scalar
//! path): lane `l` of the bank is seeded exactly like the scalar row
//! PRNG, and each generation call consumes draws in the same order the
//! scalar path would — [`sample_block`] draws `bl` raw u64s per lane
//! (like [`Bitstream::sample`]'s `bl` `next_f64` calls),
//! [`fill_draw_block`] draws the `bl` shared raws of a correlated
//! group per lane (like `Xoshiro256::fill_f64`), and
//! [`threshold_block`] draws nothing (like
//! [`Bitstream::from_uniforms`]). Callers replay inputs in netlist
//! node-id order, so the interleaving across inputs matches too.
//!
//! Fault injection (the paper's SNG-output flip site) happens strictly
//! *downstream* of this module: the executor XORs stateless
//! [`FaultCutoffs`](crate::fault::FaultCutoffs) masks into the
//! generated lane words after the comparison, so a faulty campaign
//! consumes the exact same PRNG draws as a clean one and the draw-order
//! contract above is never disturbed.
//!
//! [`Bitstream::sample`]: crate::sc::bitstream::Bitstream::sample
//! [`Bitstream::from_uniforms`]: crate::sc::bitstream::Bitstream::from_uniforms

use super::bitplane::{LaneBlock, LANES};
use crate::util::prng::RngBank;

/// Integer SNG threshold of value `v`: the smallest `n` such that
/// `(x >> 11) < n ⇔ (x >> 11)·2⁻⁵³ < v` for every raw draw `x`.
///
/// Exactness: `next_f64` is `k·2⁻⁵³` with `k = x >> 11 < 2⁵³`, so
/// `k·2⁻⁵³ < v ⇔ k < v·2⁵³` over the reals. `v·2⁵³` is computed
/// exactly in f64 (a power-of-two scale never rounds), `ceil` of an
/// exact f64 is exact, and for integer `k`, `k < y ⇔ k < ⌈y⌉`. The
/// result fits u64 for `v ≤ 1` (`⌈1·2⁵³⌉ = 2⁵³`); the saturating
/// `as u64` maps negative/NaN inputs to 0 (a never-firing threshold),
/// matching the clamped domain callers feed in.
#[inline]
pub fn cutoff(v: f64) -> u64 {
    (v * (1u64 << 53) as f64).ceil() as u64
}

/// Reusable scratch for lane-major SNG generation: one raw draw and one
/// integer cutoff per lane. Caller-owned so a wave worker allocates
/// once and reuses it for every input block of every lane block.
#[derive(Debug, Default)]
pub struct SngScratch {
    /// One raw u64 draw per lane ([`sample_block`]'s per-step scratch).
    draws: Vec<u64>,
    /// Per-lane integer thresholds for the input being generated.
    cutoffs: Vec<u64>,
}

/// Load every lane's integer threshold (one [`cutoff`] per value).
fn load_cutoffs(values: &[f64], cutoffs: &mut Vec<u64>) {
    cutoffs.clear();
    cutoffs.extend(values.iter().map(|&v| cutoff(v)));
}

/// Pack one time step's comparison bits: bit `l` of the lane word is
/// `(draws[l] >> 11) < cutoffs[l]` — the integer form of the strict
/// `u < v` in `Xoshiro256::bernoulli` and `Bitstream::from_uniforms`.
#[inline]
fn pack_lt<const W: usize>(draws: &[u64], cutoffs: &[u64]) -> [u64; W] {
    let mut w = [0u64; W];
    for (l, (&x, &c)) in draws.iter().zip(cutoffs).enumerate() {
        w[l / LANES] |= (((x >> 11) < c) as u64) << (l % LANES);
    }
    w
}

/// Bernoulli-sample one lane-major input block: lane `l` compares its
/// own stream's next `bl` draws against threshold `values[l]` (models
/// the MTJ stochastic write, P_sw = value, across a whole subarray row
/// group at once). The per-lane bit sequence — and the number of draws
/// consumed — is identical to `Bitstream::sample(values[l], bl,
/// lane_rng)`.
///
/// `out` is reshaped to `bl × values.len()` in place, reusing its
/// allocation across blocks; `scratch` likewise.
pub fn sample_block<const W: usize>(
    values: &[f64],
    bl: usize,
    rngs: &mut RngBank,
    scratch: &mut SngScratch,
    out: &mut LaneBlock<W>,
) {
    let lanes = values.len();
    assert_eq!(rngs.len(), lanes, "one RNG stream per lane");
    load_cutoffs(values, &mut scratch.cutoffs);
    out.reset(bl, lanes);
    scratch.draws.clear();
    scratch.draws.resize(lanes, 0);
    for t in 0..bl {
        rngs.next_u64_into(&mut scratch.draws);
        out.set_word(t, pack_lt(&scratch.draws, &scratch.cutoffs));
    }
}

/// Draw a correlated group's shared raw draws for every lane,
/// lane-major (`draws[t * lanes + l]` is lane `l`'s draw at step `t`).
/// Per lane this consumes exactly the `bl` draws the scalar path's
/// `fill_f64` would, so later inputs of the group can threshold against
/// the same numbers (maximal positive correlation, §4.1).
pub fn fill_draw_block(lanes: usize, bl: usize, rngs: &mut RngBank, draws: &mut Vec<u64>) {
    assert_eq!(rngs.len(), lanes, "one RNG stream per lane");
    draws.clear();
    draws.resize(lanes * bl, 0);
    for t in 0..bl {
        rngs.next_u64_into(&mut draws[t * lanes..(t + 1) * lanes]);
    }
}

/// Threshold a pre-drawn lane-major raw-draw block (from
/// [`fill_draw_block`]) against per-lane values — the correlated
/// counterpart of [`sample_block`], consuming no RNG draws, exactly
/// like `Bitstream::from_uniforms` per lane.
pub fn threshold_block<const W: usize>(
    values: &[f64],
    bl: usize,
    draws: &[u64],
    scratch: &mut SngScratch,
    out: &mut LaneBlock<W>,
) {
    let lanes = values.len();
    assert_eq!(draws.len(), lanes * bl, "draw block shape mismatch");
    load_cutoffs(values, &mut scratch.cutoffs);
    out.reset(bl, lanes);
    for t in 0..bl {
        out.set_word(t, pack_lt(&draws[t * lanes..(t + 1) * lanes], &scratch.cutoffs));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::bitstream::Bitstream;
    use crate::util::prng::Xoshiro256;

    fn lane_seed(l: usize) -> u64 {
        0x5135_u64 ^ ((l as u64) << 32) ^ (l as u64)
    }

    fn lane_values(lanes: usize) -> Vec<f64> {
        (0..lanes).map(|l| (0.03 + 0.94 * l as f64 / lanes.max(1) as f64).clamp(0.0, 1.0)).collect()
    }

    #[test]
    fn integer_cutoff_matches_f64_comparison() {
        // The satellite contract: for every threshold v and every
        // possible shifted draw k, (k < cutoff(v)) == (k·2⁻⁵³ < v).
        // Walk k across the cutoff boundary for awkward v's (f32
        // artifacts, thirds, denormal-ish, exact endpoints).
        let scale = 1.0 / (1u64 << 53) as f64;
        let vs = [
            0.0,
            1.0,
            0.5,
            1.0 / 3.0,
            0.3f32 as f64,
            0.7f32 as f64,
            1e-18,
            1.0 - f64::EPSILON,
            f64::EPSILON,
            0.999_999_999,
        ];
        for &v in &vs {
            let c = cutoff(v);
            assert!(c <= 1u64 << 53, "cutoff({v}) = {c} out of range");
            for k in [c.saturating_sub(2), c.saturating_sub(1), c, c + 1, 0, (1 << 53) - 1] {
                let k = k.min((1 << 53) - 1);
                assert_eq!(k < c, (k as f64 * scale) < v, "v={v} k={k} cutoff={c}");
            }
        }
        // Degenerate inputs saturate to a never-firing threshold.
        assert_eq!(cutoff(-0.5), 0);
        assert_eq!(cutoff(f64::NAN), 0);
        // Random draws against random thresholds, full-width check.
        let mut rng = Xoshiro256::seeded(0x51C0);
        for _ in 0..2000 {
            let v = rng.next_f64();
            let x = rng.next_u64();
            let k = x >> 11;
            assert_eq!(k < cutoff(v), (k as f64 * scale) < v, "v={v} x={x}");
        }
    }

    #[test]
    fn sample_block_matches_scalar_sng_per_lane() {
        // Every lane of the packed block must equal Bitstream::sample
        // run on a standalone PRNG with the same seed — including the
        // RNG end state (same number of draws consumed).
        for (lanes, bl) in [(1usize, 100usize), (63, 64), (64, 65), (130, 100), (256, 256)] {
            let values = lane_values(lanes);
            let mut bank = RngBank::new();
            bank.reseed_with(lanes, lane_seed);
            let mut scratch = SngScratch::default();
            let mut block: LaneBlock<4> = LaneBlock::zeros(0, 0);
            sample_block(&values, bl, &mut bank, &mut scratch, &mut block);
            assert_eq!(block.len(), bl);
            assert_eq!(block.lanes(), lanes);
            let mut probe = vec![0u64; lanes];
            bank.next_u64_into(&mut probe);
            for l in 0..lanes {
                let mut rng = Xoshiro256::seeded(lane_seed(l));
                let want = Bitstream::sample(values[l], bl, &mut rng);
                assert_eq!(block.lane(l), want, "lanes={lanes} bl={bl} lane={l}");
                assert_eq!(probe[l], rng.next_u64(), "draw count differs at lane {l}");
            }
        }
    }

    #[test]
    fn correlated_blocks_match_scalar_uniform_path() {
        // fill + threshold must reproduce fill_f64 + from_uniforms per
        // lane: same shared draws, different thresholds → maximally
        // correlated streams, and no extra draws for later inputs.
        let (lanes, bl) = (100usize, 128usize);
        let va = lane_values(lanes);
        let vb: Vec<f64> = va.iter().map(|v| 1.0 - *v).collect();
        let mut bank = RngBank::new();
        bank.reseed_with(lanes, lane_seed);
        let mut draws = Vec::new();
        fill_draw_block(lanes, bl, &mut bank, &mut draws);
        let mut scratch = SngScratch::default();
        let mut a: LaneBlock<2> = LaneBlock::zeros(0, 0);
        let mut b: LaneBlock<2> = LaneBlock::zeros(0, 0);
        threshold_block(&va, bl, &draws, &mut scratch, &mut a);
        threshold_block(&vb, bl, &draws, &mut scratch, &mut b);
        let mut probe = vec![0u64; lanes];
        bank.next_u64_into(&mut probe);
        for l in 0..lanes {
            let mut rng = Xoshiro256::seeded(lane_seed(l));
            let mut us = vec![0.0; bl];
            rng.fill_f64(&mut us);
            assert_eq!(a.lane(l), Bitstream::from_uniforms(va[l], &us), "a lane {l}");
            assert_eq!(b.lane(l), Bitstream::from_uniforms(vb[l], &us), "b lane {l}");
            assert_eq!(probe[l], rng.next_u64(), "draw count differs at lane {l}");
        }
    }

    #[test]
    fn sample_block_reuses_buffers() {
        // Back-to-back generations into the same scratch must not leak
        // bits between blocks (reset() zeroes the reused words).
        let mut bank = RngBank::new();
        let mut scratch = SngScratch::default();
        let mut block: LaneBlock<1> = LaneBlock::zeros(0, 0);
        bank.reseed_with(10, lane_seed);
        sample_block(&[1.0; 10], 50, &mut bank, &mut scratch, &mut block);
        assert!((0..10).all(|l| block.lane_popcount(l) == 50));
        bank.reseed_with(7, lane_seed);
        sample_block(&[0.0; 7], 30, &mut bank, &mut scratch, &mut block);
        assert_eq!(block.len(), 30);
        assert_eq!(block.lanes(), 7);
        assert!((0..7).all(|l| block.lane_popcount(l) == 0));
    }
}
