//! Lane-major stochastic number generation (SNG).
//!
//! The scalar SNG samples one row at a time ([`Bitstream::sample`]):
//! `bl` Bernoulli draws from that row's PRNG stream, packed along the
//! *time* axis. The wave engine wants the transposed layout — for each
//! time step, one packed lane word holding every row's bit — and it
//! used to get there by generating per-row bitstreams and transposing.
//! This module generates the lane-major words **directly**, from either
//! of the engine's two generators:
//!
//! * the lockstep [`RngBank`] compatibility path ([`sample_block`] /
//!   [`fill_draw_block`]): every row's xoshiro stream steps in draw
//!   order, bit-identical to the original scalar engine;
//! * the counter path ([`sample_block_counter`] /
//!   [`fill_draw_block_counter`]): draws come from the stateless
//!   [`CounterBank`], addressed by `(lane, node, step)` — step-major
//!   strides with no loop-carried state, O(1)-seekable, and the
//!   substrate for the packed-word [`SngCache`] below.
//!
//! Comparisons are **integer**: the scalar path's Bernoulli test
//! `next_f64() < v` is `(x >> 11)·2⁻⁵³ < v` for the raw draw `x`,
//! which is equivalent to the pure-integer `(x >> 11) < ⌈v·2⁵³⌉`
//! (see [`cutoff`]). The per-lane cutoffs are computed **once per
//! input block** via [`load_cutoffs`] (and reused across a wave's
//! blocks by [`CutoffCache`] when the values repeat), and bit-identity
//! with the scalar comparison is pinned by tests below.
//!
//! Draw-order contract for the xoshiro path (what keeps outputs
//! bit-identical to the scalar path): lane `l` of the bank is seeded
//! exactly like the scalar row PRNG, and each generation call consumes
//! draws in the same order the scalar path would — [`sample_block`]
//! draws `bl` raw u64s per lane (like [`Bitstream::sample`]'s `bl`
//! `next_f64` calls), [`fill_draw_block`] draws the `bl` shared raws of
//! a correlated group per lane (like `Xoshiro256::fill_f64`), and
//! [`threshold_block`] draws nothing (like
//! [`Bitstream::from_uniforms`]). Callers replay inputs in netlist
//! node-id order, so the interleaving across inputs matches too.
//!
//! The counter path replaces the *order* contract with an *addressing*
//! contract: draw `t` of input site `node` in row `l` is
//! `CounterRng::keyed(row_seed(l), node).draw_at(t)`, a pure function,
//! so scalar and lane-word engines agree by construction no matter what
//! stride either uses. Input sites are numbered by [`sng_node`]
//! (independent inputs by binding position, correlated groups by group
//! id), so distinct inputs of one stage — and the same input across
//! stages — never share a stream.
//!
//! Fault injection (the paper's SNG-output flip site) happens strictly
//! *downstream* of this module: the executor XORs stateless
//! [`FaultCutoffs`](crate::fault::FaultCutoffs) masks into the
//! generated lane words after the comparison (and after any
//! [`SngCache`] fetch), so a faulty campaign consumes the exact same
//! draws as a clean one and neither contract above is disturbed.
//!
//! [`Bitstream::sample`]: crate::sc::bitstream::Bitstream::sample
//! [`Bitstream::from_uniforms`]: crate::sc::bitstream::Bitstream::from_uniforms

use std::collections::HashMap;
use std::sync::Mutex;

use super::bitplane::{LaneBlock, LANES};
use crate::util::prng::{counter_node_part, CounterBank, RngBank};

/// Integer SNG threshold of value `v`: the smallest `n` such that
/// `(x >> 11) < n ⇔ (x >> 11)·2⁻⁵³ < v` for every raw draw `x`.
///
/// Exactness: `next_f64` is `k·2⁻⁵³` with `k = x >> 11 < 2⁵³`, so
/// `k·2⁻⁵³ < v ⇔ k < v·2⁵³` over the reals. `v·2⁵³` is computed
/// exactly in f64 (a power-of-two scale never rounds), `ceil` of an
/// exact f64 is exact, and for integer `k`, `k < y ⇔ k < ⌈y⌉`. The
/// result fits u64 for `v ≤ 1` (`⌈1·2⁵³⌉ = 2⁵³`); the saturating
/// `as u64` maps negative/NaN inputs to 0 (a never-firing threshold),
/// matching the clamped domain callers feed in.
#[inline]
pub fn cutoff(v: f64) -> u64 {
    (v * (1u64 << 53) as f64).ceil() as u64
}

// ---- SNG input-site ids (counter stream keying) ------------------------

/// Node-id class for an independent input stream (index = the input's
/// binding position within its stage).
pub const NODE_INPUT: u64 = 1 << 60;

/// Node-id class for a correlated group's shared draw stream (index =
/// the group id).
pub const NODE_GROUP: u64 = 2 << 60;

/// Pack an SNG input-site id from (class, stage, index) — the same
/// 20-stage-bit / 40-index-bit layout as `fault`'s injection sites, so
/// every generated stream in a staged pipeline has a unique counter
/// key.
#[inline]
pub fn sng_node(class: u64, stage: usize, index: usize) -> u64 {
    class | ((stage as u64) << 40) | index as u64
}

/// Reusable scratch for lane-major SNG generation: one raw draw per
/// lane. Caller-owned so a wave worker allocates once and reuses it for
/// every input block of every lane block.
#[derive(Debug, Default)]
pub struct SngScratch {
    /// One raw u64 draw per lane (the per-step scratch row).
    draws: Vec<u64>,
}

/// Load every lane's integer threshold (one [`cutoff`] per value).
pub fn load_cutoffs(values: &[f64], cutoffs: &mut Vec<u64>) {
    cutoffs.clear();
    cutoffs.extend(values.iter().map(|&v| cutoff(v)));
}

/// Per-wave cutoff memo: one slot per (stage, input) position of the
/// compiled pipeline, holding the last values vector seen there and its
/// cutoffs. A wave's blocks walk the same input positions with
/// often-identical values (constants always; batch columns whenever the
/// batch repeats values), and recomputing `⌈v·2⁵³⌉` per lane per block
/// was pure waste — the fix the hit/miss counters make observable.
#[derive(Debug, Default)]
pub struct CutoffCache {
    slots: Vec<(Vec<f64>, Vec<u64>)>,
    hits: u64,
    misses: u64,
}

impl CutoffCache {
    /// The cutoffs for input slot `slot` under `values`: reuses the
    /// memoized vector when the values match the previous block's
    /// exactly (bitwise f64 comparison via `==`; NaN never occurs in
    /// the clamped domain), recomputes otherwise.
    pub fn cutoffs(&mut self, slot: usize, values: &[f64]) -> &[u64] {
        if self.slots.len() <= slot {
            self.slots.resize_with(slot + 1, Default::default);
        }
        let (vals, cuts) = &mut self.slots[slot];
        if vals.as_slice() == values && !values.is_empty() {
            self.hits += 1;
        } else {
            self.misses += 1;
            vals.clear();
            vals.extend_from_slice(values);
            load_cutoffs(values, cuts);
        }
        &self.slots[slot].1
    }

    /// (hits, misses) since construction.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

// ---- packed-word SNG block cache ---------------------------------------

/// Hit/miss counters for the SNG caches, folded into `WaveStats` and
/// the `obs` snapshots. `hits`/`misses` count packed-block lookups in
/// [`SngCache`]; `cutoff_hits`/`cutoff_misses` count [`CutoffCache`]
/// slot lookups.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SngCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub cutoff_hits: u64,
    pub cutoff_misses: u64,
}

impl SngCacheStats {
    pub fn add(&mut self, other: &SngCacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.cutoff_hits += other.cutoff_hits;
        self.cutoff_misses += other.cutoff_misses;
    }

    /// Block-cache hit rate in [0, 1]; 0 when no lookups ran.
    pub fn hit_rate(&self) -> f64 {
        let n = self.hits + self.misses;
        if n == 0 {
            0.0
        } else {
            self.hits as f64 / n as f64
        }
    }
}

/// Exact identity of one generated SNG block. `epoch` fingerprints the
/// (wave seed, artifact name) pair so reseeding invalidates everything;
/// `node` is the [`sng_node`] input site; `row0`/`lanes` pin the batch
/// rows the block's lanes carry; `bl`/`w` pin the shape and the
/// flattened word layout.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SngKey {
    pub epoch: u64,
    pub node: u64,
    pub row0: u64,
    pub lanes: u32,
    pub bl: u32,
    pub w: u32,
}

#[derive(Debug)]
struct SngEntry {
    /// The per-lane cutoffs the cached words were generated under —
    /// verified in full on every hit, because the key does not encode
    /// the input values.
    cutoffs: Vec<u64>,
    /// `bl × W` packed lane words, time-major.
    words: Vec<u64>,
}

/// Bound on retained blocks; the map is cleared wholesale when full
/// (generation is cheap enough that eviction policy isn't worth state).
const SNG_CACHE_CAP: usize = 512;

/// Packed-word SNG block cache. Counter-path only: a cached block is a
/// pure function of its [`SngKey`] plus the cutoff vector, which holds
/// for counter streams (stateless addressing) but not for xoshiro
/// streams (a draw's value depends on every preceding draw of the
/// wave). Within one wave every generated block is unique — distinct
/// rows or distinct nodes — so hits come from *repeated executions*:
/// re-served identical waves, bench iterations, repeated-value batches
/// re-submitted under one seed. Shared across an engine's workers via a
/// mutex; the lock is taken once per block, not per step.
#[derive(Debug, Default)]
pub struct SngCache {
    inner: Mutex<HashMap<SngKey, SngEntry>>,
}

impl SngCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Look `key` up and, when present *with exactly these cutoffs*,
    /// deposit the cached words into `out` (reshaped in place) and
    /// return true. A key match with different cutoffs is a miss — the
    /// batch's values changed at the same rows — and the store after
    /// regeneration overwrites the stale entry.
    pub fn fetch<const W: usize>(
        &self,
        key: &SngKey,
        cutoffs: &[u64],
        out: &mut LaneBlock<W>,
    ) -> bool {
        debug_assert_eq!(key.w as usize, W);
        let map = self.inner.lock().unwrap();
        let Some(entry) = map.get(key) else { return false };
        if entry.cutoffs != cutoffs {
            return false;
        }
        let (bl, lanes) = (key.bl as usize, key.lanes as usize);
        debug_assert_eq!(entry.words.len(), bl * W);
        out.reset(bl, lanes);
        for t in 0..bl {
            out.set_word(t, std::array::from_fn(|k| entry.words[t * W + k]));
        }
        true
    }

    /// Insert the freshly generated `block` under `key`. Blocks are
    /// stored pre-fault (the executor XORs masks in afterwards), so a
    /// hit replays the clean generation exactly.
    pub fn store<const W: usize>(&self, key: SngKey, cutoffs: &[u64], block: &LaneBlock<W>) {
        debug_assert_eq!(key.w as usize, W);
        let bl = block.len();
        let mut words = Vec::with_capacity(bl * W);
        for t in 0..bl {
            words.extend_from_slice(&block.word(t));
        }
        let mut map = self.inner.lock().unwrap();
        if map.len() >= SNG_CACHE_CAP && !map.contains_key(&key) {
            map.clear();
        }
        map.insert(key, SngEntry { cutoffs: cutoffs.to_vec(), words });
    }

    /// Number of cached blocks (tests/debug).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---- packing -----------------------------------------------------------

/// Pack one time step's comparison bits: bit `l` of the lane word is
/// `(draws[l] >> 11) < cutoffs[l]` — the integer form of the strict
/// `u < v` in `Xoshiro256::bernoulli` and `Bitstream::from_uniforms`.
#[cfg(not(feature = "simd"))]
#[inline]
fn pack_lt<const W: usize>(draws: &[u64], cutoffs: &[u64]) -> [u64; W] {
    let mut w = [0u64; W];
    for (l, (&x, &c)) in draws.iter().zip(cutoffs).enumerate() {
        w[l / LANES] |= (((x >> 11) < c) as u64) << (l % LANES);
    }
    w
}

/// `std::simd` variant of the scalar `pack_lt` above, bit-identical:
/// 8-lane compare-to-bitmask chunks (aligned to multiples of 8, so a
/// chunk never straddles a 64-bit lane-word boundary) plus a scalar
/// tail.
#[cfg(feature = "simd")]
#[inline]
fn pack_lt<const W: usize>(draws: &[u64], cutoffs: &[u64]) -> [u64; W] {
    use std::simd::cmp::SimdPartialOrd;
    use std::simd::u64x8;
    let mut w = [0u64; W];
    let n = draws.len().min(cutoffs.len());
    let mut l = 0;
    while l + 8 <= n {
        let x = u64x8::from_slice(&draws[l..l + 8]) >> u64x8::splat(11);
        let c = u64x8::from_slice(&cutoffs[l..l + 8]);
        w[l / LANES] |= x.simd_lt(c).to_bitmask() << (l % LANES);
        l += 8;
    }
    while l < n {
        w[l / LANES] |= (((draws[l] >> 11) < cutoffs[l]) as u64) << (l % LANES);
        l += 1;
    }
    w
}

// ---- xoshiro (lockstep compatibility) path -----------------------------

/// Bernoulli-sample one lane-major input block: lane `l` compares its
/// own stream's next `bl` draws against threshold `cutoffs[l]` (models
/// the MTJ stochastic write, P_sw = value, across a whole subarray row
/// group at once). The per-lane bit sequence — and the number of draws
/// consumed — is identical to `Bitstream::sample(values[l], bl,
/// lane_rng)` for `cutoffs` from [`load_cutoffs`].
///
/// `out` is reshaped to `bl × cutoffs.len()` in place, reusing its
/// allocation across blocks; `scratch` likewise.
pub fn sample_block<const W: usize>(
    cutoffs: &[u64],
    bl: usize,
    rngs: &mut RngBank,
    scratch: &mut SngScratch,
    out: &mut LaneBlock<W>,
) {
    let lanes = cutoffs.len();
    assert_eq!(rngs.len(), lanes, "one RNG stream per lane");
    out.reset(bl, lanes);
    scratch.draws.clear();
    scratch.draws.resize(lanes, 0);
    for t in 0..bl {
        rngs.next_u64_into(&mut scratch.draws);
        out.set_word(t, pack_lt(&scratch.draws, cutoffs));
    }
}

/// Draw a correlated group's shared raw draws for every lane,
/// lane-major (`draws[t * lanes + l]` is lane `l`'s draw at step `t`).
/// Per lane this consumes exactly the `bl` draws the scalar path's
/// `fill_f64` would, so later inputs of the group can threshold against
/// the same numbers (maximal positive correlation, §4.1).
pub fn fill_draw_block(lanes: usize, bl: usize, rngs: &mut RngBank, draws: &mut Vec<u64>) {
    assert_eq!(rngs.len(), lanes, "one RNG stream per lane");
    draws.clear();
    draws.resize(lanes * bl, 0);
    for t in 0..bl {
        rngs.next_u64_into(&mut draws[t * lanes..(t + 1) * lanes]);
    }
}

/// Threshold a pre-drawn lane-major raw-draw block (from
/// [`fill_draw_block`] or [`fill_draw_block_counter`]) against per-lane
/// cutoffs — the correlated counterpart of [`sample_block`], consuming
/// no RNG draws, exactly like `Bitstream::from_uniforms` per lane.
pub fn threshold_block<const W: usize>(
    cutoffs: &[u64],
    bl: usize,
    draws: &[u64],
    out: &mut LaneBlock<W>,
) {
    let lanes = cutoffs.len();
    assert_eq!(draws.len(), lanes * bl, "draw block shape mismatch");
    out.reset(bl, lanes);
    for t in 0..bl {
        out.set_word(t, pack_lt(&draws[t * lanes..(t + 1) * lanes], cutoffs));
    }
}

// ---- counter (stateless) path ------------------------------------------

/// Counter-path [`sample_block`]: lane `l`'s bit at step `t` is
/// `(bank.stream(l, node_part).draw_at(t) >> 11) < cutoffs[l]` — pure
/// addressing, no draw-order bookkeeping. The per-lane bit sequence is
/// identical to thresholding `CounterRng::keyed(row_seed(l), node)`'s
/// stream, which is what the scalar counter reference does.
pub fn sample_block_counter<const W: usize>(
    cutoffs: &[u64],
    bl: usize,
    bank: &CounterBank,
    node: u64,
    scratch: &mut SngScratch,
    out: &mut LaneBlock<W>,
) {
    let lanes = cutoffs.len();
    assert_eq!(bank.len(), lanes, "one counter key per lane");
    let node_part = counter_node_part(node);
    out.reset(bl, lanes);
    scratch.draws.clear();
    scratch.draws.resize(lanes, 0);
    for t in 0..bl {
        bank.draws_at_into(node_part, t as u64, &mut scratch.draws);
        out.set_word(t, pack_lt(&scratch.draws, cutoffs));
    }
}

/// Counter-path [`fill_draw_block`]: materialize a correlated group's
/// shared raw draws lane-major from the group's counter stream
/// (`node` = `sng_node(NODE_GROUP, stage, group)`).
pub fn fill_draw_block_counter(
    lanes: usize,
    bl: usize,
    bank: &CounterBank,
    node: u64,
    draws: &mut Vec<u64>,
) {
    assert_eq!(bank.len(), lanes, "one counter key per lane");
    let node_part = counter_node_part(node);
    draws.clear();
    draws.resize(lanes * bl, 0);
    for t in 0..bl {
        bank.draws_at_into(node_part, t as u64, &mut draws[t * lanes..(t + 1) * lanes]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::bitstream::Bitstream;
    use crate::util::prng::{CounterRng, Xoshiro256};

    fn lane_seed(l: usize) -> u64 {
        0x5135_u64 ^ ((l as u64) << 32) ^ (l as u64)
    }

    fn lane_values(lanes: usize) -> Vec<f64> {
        (0..lanes).map(|l| (0.03 + 0.94 * l as f64 / lanes.max(1) as f64).clamp(0.0, 1.0)).collect()
    }

    fn cutoffs_of(values: &[f64]) -> Vec<u64> {
        let mut c = Vec::new();
        load_cutoffs(values, &mut c);
        c
    }

    #[test]
    fn integer_cutoff_matches_f64_comparison() {
        // The satellite contract: for every threshold v and every
        // possible shifted draw k, (k < cutoff(v)) == (k·2⁻⁵³ < v).
        // Walk k across the cutoff boundary for awkward v's (f32
        // artifacts, thirds, denormal-ish, exact endpoints).
        let scale = 1.0 / (1u64 << 53) as f64;
        let vs = [
            0.0,
            1.0,
            0.5,
            1.0 / 3.0,
            0.3f32 as f64,
            0.7f32 as f64,
            1e-18,
            1.0 - f64::EPSILON,
            f64::EPSILON,
            0.999_999_999,
        ];
        for &v in &vs {
            let c = cutoff(v);
            assert!(c <= 1u64 << 53, "cutoff({v}) = {c} out of range");
            for k in [c.saturating_sub(2), c.saturating_sub(1), c, c + 1, 0, (1 << 53) - 1] {
                let k = k.min((1 << 53) - 1);
                assert_eq!(k < c, (k as f64 * scale) < v, "v={v} k={k} cutoff={c}");
            }
        }
        // Degenerate inputs saturate to a never-firing threshold.
        assert_eq!(cutoff(-0.5), 0);
        assert_eq!(cutoff(f64::NAN), 0);
        // Random draws against random thresholds, full-width check.
        let mut rng = Xoshiro256::seeded(0x51C0);
        for _ in 0..2000 {
            let v = rng.next_f64();
            let x = rng.next_u64();
            let k = x >> 11;
            assert_eq!(k < cutoff(v), (k as f64 * scale) < v, "v={v} x={x}");
        }
    }

    #[test]
    fn sample_block_matches_scalar_sng_per_lane() {
        // Every lane of the packed block must equal Bitstream::sample
        // run on a standalone PRNG with the same seed — including the
        // RNG end state (same number of draws consumed).
        for (lanes, bl) in [(1usize, 100usize), (63, 64), (64, 65), (130, 100), (256, 256)] {
            let values = lane_values(lanes);
            let mut bank = RngBank::new();
            bank.reseed_with(lanes, lane_seed);
            let mut scratch = SngScratch::default();
            let mut block: LaneBlock<4> = LaneBlock::zeros(0, 0);
            sample_block(&cutoffs_of(&values), bl, &mut bank, &mut scratch, &mut block);
            assert_eq!(block.len(), bl);
            assert_eq!(block.lanes(), lanes);
            let mut probe = vec![0u64; lanes];
            bank.next_u64_into(&mut probe);
            for l in 0..lanes {
                let mut rng = Xoshiro256::seeded(lane_seed(l));
                let want = Bitstream::sample(values[l], bl, &mut rng);
                assert_eq!(block.lane(l), want, "lanes={lanes} bl={bl} lane={l}");
                assert_eq!(probe[l], rng.next_u64(), "draw count differs at lane {l}");
            }
        }
    }

    #[test]
    fn correlated_blocks_match_scalar_uniform_path() {
        // fill + threshold must reproduce fill_f64 + from_uniforms per
        // lane: same shared draws, different thresholds → maximally
        // correlated streams, and no extra draws for later inputs.
        let (lanes, bl) = (100usize, 128usize);
        let va = lane_values(lanes);
        let vb: Vec<f64> = va.iter().map(|v| 1.0 - *v).collect();
        let mut bank = RngBank::new();
        bank.reseed_with(lanes, lane_seed);
        let mut draws = Vec::new();
        fill_draw_block(lanes, bl, &mut bank, &mut draws);
        let mut a: LaneBlock<2> = LaneBlock::zeros(0, 0);
        let mut b: LaneBlock<2> = LaneBlock::zeros(0, 0);
        threshold_block(&cutoffs_of(&va), bl, &draws, &mut a);
        threshold_block(&cutoffs_of(&vb), bl, &draws, &mut b);
        let mut probe = vec![0u64; lanes];
        bank.next_u64_into(&mut probe);
        for l in 0..lanes {
            let mut rng = Xoshiro256::seeded(lane_seed(l));
            let mut us = vec![0.0; bl];
            rng.fill_f64(&mut us);
            assert_eq!(a.lane(l), Bitstream::from_uniforms(va[l], &us), "a lane {l}");
            assert_eq!(b.lane(l), Bitstream::from_uniforms(vb[l], &us), "b lane {l}");
            assert_eq!(probe[l], rng.next_u64(), "draw count differs at lane {l}");
        }
    }

    #[test]
    fn sample_block_reuses_buffers() {
        // Back-to-back generations into the same scratch must not leak
        // bits between blocks (reset() zeroes the reused words).
        let mut bank = RngBank::new();
        let mut scratch = SngScratch::default();
        let mut block: LaneBlock<1> = LaneBlock::zeros(0, 0);
        bank.reseed_with(10, lane_seed);
        sample_block(&cutoffs_of(&[1.0; 10]), 50, &mut bank, &mut scratch, &mut block);
        assert!((0..10).all(|l| block.lane_popcount(l) == 50));
        bank.reseed_with(7, lane_seed);
        sample_block(&cutoffs_of(&[0.0; 7]), 30, &mut bank, &mut scratch, &mut block);
        assert_eq!(block.len(), 30);
        assert_eq!(block.lanes(), 7);
        assert!((0..7).all(|l| block.lane_popcount(l) == 0));
    }

    #[test]
    fn counter_sample_block_matches_stream_reference() {
        // Lane l of the counter-generated block must equal thresholding
        // CounterRng::keyed(seed_of(l), node)'s stream bit by bit —
        // the addressing contract the scalar counter reference uses.
        let node = sng_node(NODE_INPUT, 3, 2);
        for (lanes, bl) in [(1usize, 100usize), (63, 64), (130, 100), (300, 64), (512, 33)] {
            let values = lane_values(lanes);
            let mut bank = CounterBank::new();
            bank.reseed_with(lanes, lane_seed);
            let mut scratch = SngScratch::default();
            let mut block: LaneBlock<8> = LaneBlock::zeros(0, 0);
            sample_block_counter(&cutoffs_of(&values), bl, &bank, node, &mut scratch, &mut block);
            assert_eq!(block.len(), bl);
            assert_eq!(block.lanes(), lanes);
            for l in 0..lanes {
                let stream = CounterRng::keyed(lane_seed(l), node);
                let bits: Vec<bool> =
                    (0..bl).map(|t| (stream.draw_at(t as u64) >> 11) < cutoff(values[l])).collect();
                assert_eq!(block.lane(l), Bitstream::from_bits(&bits), "lanes={lanes} lane={l}");
            }
        }
    }

    #[test]
    fn counter_correlated_path_shares_draws() {
        // fill_draw_block_counter + threshold_block: two inputs of one
        // group threshold the same group-stream draws.
        let (lanes, bl) = (70usize, 96usize);
        let node = sng_node(NODE_GROUP, 0, 1);
        let va = lane_values(lanes);
        let vb: Vec<f64> = va.iter().map(|v| 1.0 - *v).collect();
        let mut bank = CounterBank::new();
        bank.reseed_with(lanes, lane_seed);
        let mut draws = Vec::new();
        fill_draw_block_counter(lanes, bl, &bank, node, &mut draws);
        let mut a: LaneBlock<2> = LaneBlock::zeros(0, 0);
        let mut b: LaneBlock<2> = LaneBlock::zeros(0, 0);
        threshold_block(&cutoffs_of(&va), bl, &draws, &mut a);
        threshold_block(&cutoffs_of(&vb), bl, &draws, &mut b);
        for l in 0..lanes {
            let stream = CounterRng::keyed(lane_seed(l), node);
            for t in 0..bl {
                let x = stream.draw_at(t as u64) >> 11;
                assert_eq!(draws[t * lanes + l] >> 11, x);
                assert_eq!(a.lane(l).get(t), x < cutoff(va[l]), "a lane {l} t {t}");
                assert_eq!(b.lane(l).get(t), x < cutoff(vb[l]), "b lane {l} t {t}");
            }
        }
    }

    #[test]
    fn cutoff_cache_reuses_repeated_values() {
        let mut cache = CutoffCache::default();
        let va = lane_values(10);
        let vb = lane_values(7);
        assert_eq!(cache.cutoffs(0, &va), cutoffs_of(&va).as_slice());
        assert_eq!(cache.counters(), (0, 1));
        // Same slot, same values: hit, same cutoffs.
        assert_eq!(cache.cutoffs(0, &va), cutoffs_of(&va).as_slice());
        assert_eq!(cache.counters(), (1, 1));
        // Same slot, new values: miss, recomputed.
        assert_eq!(cache.cutoffs(0, &vb), cutoffs_of(&vb).as_slice());
        assert_eq!(cache.counters(), (1, 2));
        // Distinct slots don't interfere.
        assert_eq!(cache.cutoffs(3, &va), cutoffs_of(&va).as_slice());
        assert_eq!(cache.cutoffs(3, &va), cutoffs_of(&va).as_slice());
        assert_eq!(cache.counters(), (2, 3));
    }

    #[test]
    fn sng_cache_roundtrip_and_cutoff_verification() {
        let (lanes, bl) = (70usize, 40usize);
        let values = lane_values(lanes);
        let cuts = cutoffs_of(&values);
        let mut bank = CounterBank::new();
        bank.reseed_with(lanes, lane_seed);
        let mut scratch = SngScratch::default();
        let mut block: LaneBlock<2> = LaneBlock::zeros(0, 0);
        let node = sng_node(NODE_INPUT, 0, 0);
        sample_block_counter(&cuts, bl, &bank, node, &mut scratch, &mut block);

        let cache = SngCache::new();
        let key = SngKey { epoch: 9, node, row0: 0, lanes: lanes as u32, bl: bl as u32, w: 2 };
        let mut fetched: LaneBlock<2> = LaneBlock::zeros(0, 0);
        assert!(!cache.fetch(&key, &cuts, &mut fetched), "empty cache must miss");
        cache.store(key.clone(), &cuts, &block);
        assert_eq!(cache.len(), 1);
        assert!(cache.fetch(&key, &cuts, &mut fetched));
        assert_eq!(fetched, block, "fetched block must be bit-identical");
        // Same key, different cutoffs: the full-vector verification
        // rejects the entry instead of serving stale bits.
        let other = cutoffs_of(&lane_values(lanes).iter().map(|v| v * 0.5).collect::<Vec<_>>());
        assert!(!cache.fetch(&key, &other, &mut fetched));
        // Different key fields miss outright.
        let mut k2 = key.clone();
        k2.epoch = 10;
        assert!(!cache.fetch(&k2, &cuts, &mut fetched));
    }
}
