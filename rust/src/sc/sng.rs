//! Lane-major stochastic number generation (SNG).
//!
//! The scalar SNG samples one row at a time ([`Bitstream::sample`]):
//! `bl` Bernoulli draws from that row's PRNG stream, packed along the
//! *time* axis. The wave engine wants the transposed layout — for each
//! time step, one packed lane word holding every row's bit — and it
//! used to get there by generating per-row bitstreams and transposing.
//! This module generates the lane-major words **directly**: an
//! [`RngBank`] steps every row's PRNG in lockstep, each time step
//! compares all lanes' uniforms against their per-lane thresholds, and
//! the comparison bits are packed into one `[u64; W]` lane word — no
//! per-row intermediates, no transpose.
//!
//! Draw-order contract (what keeps outputs bit-identical to the scalar
//! path): lane `l` of the bank is seeded exactly like the scalar row
//! PRNG, and each generation call consumes draws in the same order the
//! scalar path would — [`sample_block`] draws `bl` uniforms per lane
//! (like [`Bitstream::sample`]), [`fill_uniform_block`] draws the `bl`
//! shared uniforms of a correlated group per lane (like
//! `Xoshiro256::fill_f64`), and [`threshold_block`] draws nothing (like
//! [`Bitstream::from_uniforms`]). Callers replay inputs in netlist
//! node-id order, so the interleaving across inputs matches too.
//!
//! [`Bitstream::sample`]: crate::sc::bitstream::Bitstream::sample
//! [`Bitstream::from_uniforms`]: crate::sc::bitstream::Bitstream::from_uniforms

use super::bitplane::{LaneBlock, LANES};
use crate::util::prng::RngBank;

/// Pack one time step's comparison bits: bit `l` of the lane word is
/// `draws[l] < values[l]` — the same strict `<` as `Xoshiro256::
/// bernoulli` and `Bitstream::from_uniforms`.
#[inline]
fn pack_lt<const W: usize>(draws: &[f64], values: &[f64]) -> [u64; W] {
    let mut w = [0u64; W];
    for (l, (&u, &v)) in draws.iter().zip(values).enumerate() {
        w[l / LANES] |= ((u < v) as u64) << (l % LANES);
    }
    w
}

/// Bernoulli-sample one lane-major input block: lane `l` compares its
/// own stream's next `bl` uniforms against threshold `values[l]`
/// (models the MTJ stochastic write, P_sw = value, across a whole
/// subarray row group at once). The per-lane draw sequence is identical
/// to `Bitstream::sample(values[l], bl, lane_rng)`.
///
/// `draws` is caller-owned scratch (resized to one uniform per lane);
/// `out` is reshaped to `bl × values.len()` in place, reusing its
/// allocation across blocks.
pub fn sample_block<const W: usize>(
    values: &[f64],
    bl: usize,
    rngs: &mut RngBank,
    draws: &mut Vec<f64>,
    out: &mut LaneBlock<W>,
) {
    let lanes = values.len();
    assert_eq!(rngs.len(), lanes, "one RNG stream per lane");
    out.reset(bl, lanes);
    draws.clear();
    draws.resize(lanes, 0.0);
    for t in 0..bl {
        rngs.next_f64_into(draws);
        out.set_word(t, pack_lt(draws, values));
    }
}

/// Draw a correlated group's shared uniforms for every lane, lane-major
/// (`uniforms[t * lanes + l]` is lane `l`'s uniform at step `t`). Per
/// lane this consumes exactly the `bl` draws the scalar path's
/// `fill_f64` would, so later inputs of the group can threshold against
/// the same numbers (maximal positive correlation, §4.1).
pub fn fill_uniform_block(lanes: usize, bl: usize, rngs: &mut RngBank, uniforms: &mut Vec<f64>) {
    assert_eq!(rngs.len(), lanes, "one RNG stream per lane");
    uniforms.clear();
    uniforms.resize(lanes * bl, 0.0);
    for t in 0..bl {
        rngs.next_f64_into(&mut uniforms[t * lanes..(t + 1) * lanes]);
    }
}

/// Threshold a pre-drawn lane-major uniform block (from
/// [`fill_uniform_block`]) against per-lane values — the correlated
/// counterpart of [`sample_block`], consuming no RNG draws, exactly
/// like `Bitstream::from_uniforms` per lane.
pub fn threshold_block<const W: usize>(
    values: &[f64],
    bl: usize,
    uniforms: &[f64],
    out: &mut LaneBlock<W>,
) {
    let lanes = values.len();
    assert_eq!(uniforms.len(), lanes * bl, "uniform block shape mismatch");
    out.reset(bl, lanes);
    for t in 0..bl {
        out.set_word(t, pack_lt(&uniforms[t * lanes..(t + 1) * lanes], values));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::bitstream::Bitstream;
    use crate::util::prng::Xoshiro256;

    fn lane_seed(l: usize) -> u64 {
        0x5135_u64 ^ ((l as u64) << 32) ^ (l as u64)
    }

    fn lane_values(lanes: usize) -> Vec<f64> {
        (0..lanes).map(|l| (0.03 + 0.94 * l as f64 / lanes.max(1) as f64).clamp(0.0, 1.0)).collect()
    }

    #[test]
    fn sample_block_matches_scalar_sng_per_lane() {
        // Every lane of the packed block must equal Bitstream::sample
        // run on a standalone PRNG with the same seed — including the
        // RNG end state (same number of draws consumed).
        for (lanes, bl) in [(1usize, 100usize), (63, 64), (64, 65), (130, 100), (256, 256)] {
            let values = lane_values(lanes);
            let mut bank = RngBank::new();
            bank.reseed_with(lanes, lane_seed);
            let mut draws = Vec::new();
            let mut block: LaneBlock<4> = LaneBlock::zeros(0, 0);
            sample_block(&values, bl, &mut bank, &mut draws, &mut block);
            assert_eq!(block.len(), bl);
            assert_eq!(block.lanes(), lanes);
            let mut probe = vec![0u64; lanes];
            bank.next_u64_into(&mut probe);
            for l in 0..lanes {
                let mut rng = Xoshiro256::seeded(lane_seed(l));
                let want = Bitstream::sample(values[l], bl, &mut rng);
                assert_eq!(block.lane(l), want, "lanes={lanes} bl={bl} lane={l}");
                assert_eq!(probe[l], rng.next_u64(), "draw count differs at lane {l}");
            }
        }
    }

    #[test]
    fn correlated_blocks_match_scalar_uniform_path() {
        // fill + threshold must reproduce fill_f64 + from_uniforms per
        // lane: same shared uniforms, different thresholds → maximally
        // correlated streams, and no extra draws for later inputs.
        let (lanes, bl) = (100usize, 128usize);
        let va = lane_values(lanes);
        let vb: Vec<f64> = va.iter().map(|v| 1.0 - *v).collect();
        let mut bank = RngBank::new();
        bank.reseed_with(lanes, lane_seed);
        let mut uniforms = Vec::new();
        fill_uniform_block(lanes, bl, &mut bank, &mut uniforms);
        let mut a: LaneBlock<2> = LaneBlock::zeros(0, 0);
        let mut b: LaneBlock<2> = LaneBlock::zeros(0, 0);
        threshold_block(&va, bl, &uniforms, &mut a);
        threshold_block(&vb, bl, &uniforms, &mut b);
        let mut probe = vec![0u64; lanes];
        bank.next_u64_into(&mut probe);
        for l in 0..lanes {
            let mut rng = Xoshiro256::seeded(lane_seed(l));
            let mut us = vec![0.0; bl];
            rng.fill_f64(&mut us);
            assert_eq!(a.lane(l), Bitstream::from_uniforms(va[l], &us), "a lane {l}");
            assert_eq!(b.lane(l), Bitstream::from_uniforms(vb[l], &us), "b lane {l}");
            assert_eq!(probe[l], rng.next_u64(), "draw count differs at lane {l}");
        }
    }

    #[test]
    fn sample_block_reuses_buffers() {
        // Back-to-back generations into the same scratch must not leak
        // bits between blocks (reset() zeroes the reused words).
        let mut bank = RngBank::new();
        let mut draws = Vec::new();
        let mut block: LaneBlock<1> = LaneBlock::zeros(0, 0);
        bank.reseed_with(10, lane_seed);
        sample_block(&[1.0; 10], 50, &mut bank, &mut draws, &mut block);
        assert!((0..10).all(|l| block.lane_popcount(l) == 50));
        bank.reseed_with(7, lane_seed);
        sample_block(&[0.0; 7], 30, &mut bank, &mut draws, &mut block);
        assert_eq!(block.len(), 30);
        assert_eq!(block.lanes(), 7);
        assert!((0..7).all(|l| block.lane_popcount(l) == 0));
    }
}
