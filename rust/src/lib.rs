//! Stoch-IMC: bit-parallel stochastic in-memory computing (STT-MRAM).
#![allow(clippy::needless_range_loop)]
pub mod device;
pub mod netlist;
pub mod runtime;
pub mod sc;
pub mod scheduler;
pub mod util;
pub mod imc;
pub mod config;
pub mod energy;
pub mod fault;
pub mod lifetime;
pub mod arch;
pub mod baseline;
pub mod apps;
pub mod coordinator;
pub mod report;
