//! **Stoch-IMC** — a reproduction of *"Stoch-IMC: A Bit-Parallel
//! Stochastic In-Memory Computing Architecture Based on STT-MRAM"*
//! (cs.AR 2024), grown toward a production-scale simulator and serving
//! stack.
//!
//! The crate models the paper's full stack, from the MTJ device physics
//! up to a batched request coordinator:
//!
//! | Module | Purpose (paper section) |
//! |---|---|
//! | [`device`] | MTJ thermal-switching model, Eqs 1–2 / Table 1 (§2.1–2.3) |
//! | [`sc`] | Packed bitstreams + the six stochastic arithmetic ops (Fig 4/5) |
//! | [`netlist`] | Gate-level IR, op/binary circuit builders, functional eval |
//! | [`scheduler`] | Algorithm 1 co-scheduling/mapping + ASAP refinement (§4.2) |
//! | [`imc`] | Cycle-level 2T-1MTJ subarray simulator (§2.2) |
//! | [`arch`] | BtoS memory, accumulator tree, `[n, m]` cost engine (§4.3) |
//! | [`baseline`] | Binary-IMC circuits and the bit-serial SC-CRAM model (§5) |
//! | [`energy`] | Energy model, Eqs 3–4 + SPICE constants (§5.1, Fig 10) |
//! | [`lifetime`] | Endurance/lifetime model, Eq 11 (Fig 11) |
//! | [`fault`] | Bitflip fault injection (Table 4) |
//! | [`apps`] | The four evaluation applications: LIT, OL, HDP, KDE (Fig 9) |
//! | [`config`] | TOML-subset config for architecture/device/energy (§5.1) |
//! | [`runtime`] | Artifact registry + pluggable [`runtime::Engine`] backends |
//! | [`coordinator`] | Request batcher + single-shard wrapper, metrics (§4.3 bank controller) |
//! | [`serve`] | Sharded bank-parallel serving: `BankPool`, `Server`, admission control |
//! | [`obs`] | Observability: fixed-memory histograms, stage spans, stats exposition |
//! | [`report`] | Generators for the paper's tables/figures |
//! | [`error`] | Dependency-free `anyhow`-style error type and macros |
//! | [`util`] | PRNGs (counter-mode SplitMix64, xoshiro256**), stats, property-test helper |
//!
//! # Backends
//!
//! The default build is dependency-free: the coordinator executes
//! artifacts on the pure-Rust bit-plane interpreter
//! ([`runtime::InterpEngine`]). The `xla-runtime` cargo feature gates
//! the PJRT/XLA client for the AOT HLO artifacts; see `rust/Cargo.toml`
//! for how to link it.
#![allow(clippy::needless_range_loop)]
// The off-by-default `simd` feature vectorizes the counter-RNG/SNG hot
// loops via `std::simd`, which is nightly-only; stable builds never see
// this attribute.
#![cfg_attr(feature = "simd", feature(portable_simd))]
// `xla_available` is a user-provided cfg (set via RUSTFLAGS when the
// PJRT `xla` crate is vendored); silence check-cfg on toolchains that
// know the lint, and the unknown-lint warning on those that don't.
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]

pub mod error;

pub mod device;
pub mod netlist;
pub mod runtime;
pub mod sc;
pub mod scheduler;
pub mod util;
pub mod imc;
pub mod config;
pub mod energy;
pub mod fault;
pub mod lifetime;
pub mod arch;
pub mod baseline;
pub mod apps;
pub mod coordinator;
pub mod obs;
pub mod report;
pub mod serve;
