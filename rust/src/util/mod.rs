//! Shared utilities: deterministic PRNG, statistics, property-test helper,
//! a tiny wall-clock timer, and the flat-JSON bench reporter used by the
//! bench harnesses.

pub mod benchjson;
pub mod check;
pub mod prng;
pub mod stats;

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Format a count with thousands separators for bench output.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_count_groups() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1,000");
        assert_eq!(fmt_count(1234567), "1,234,567");
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
