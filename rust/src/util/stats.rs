//! Small statistics helpers used by the evaluation harnesses.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (the paper reports geometric means across applications).
/// All inputs must be positive.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    assert!(xs.iter().all(|&x| x > 0.0), "geomean requires positive values");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len().max(1) as f64).sqrt()
}

/// Mean absolute error between two equal-length slices.
pub fn mae(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>() / a.len() as f64
}

/// Mean relative error (%) with an absolute floor to avoid blowups near 0.
/// This is the "average output error (%)" metric of the paper's Table 4.
pub fn mean_error_pct(reference: &[f64], measured: &[f64]) -> f64 {
    assert_eq!(reference.len(), measured.len());
    if reference.is_empty() {
        return 0.0;
    }
    let s: f64 = reference
        .iter()
        .zip(measured)
        .map(|(r, m)| (r - m).abs() / r.abs().max(1e-3))
        .sum();
    100.0 * s / reference.len() as f64
}

/// Range-normalized mean error (%): |ref − got| averaged, divided by the
/// max |ref| of the workload. The Table 4 metric — plain relative error
/// explodes on near-zero outputs (OL's probability field), which the
/// paper's sub-1% OL numbers rule out.
pub fn range_error_pct(reference: &[f64], measured: &[f64]) -> f64 {
    assert_eq!(reference.len(), measured.len());
    if reference.is_empty() {
        return 0.0;
    }
    let scale = reference.iter().fold(0.0f64, |m, &r| m.max(r.abs())).max(1e-6);
    let s: f64 = reference.iter().zip(measured).map(|(r, m)| (r - m).abs()).sum();
    100.0 * s / (reference.len() as f64 * scale)
}

/// Median of a slice (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn mae_basic() {
        assert!((mae(&[1.0, 2.0], &[2.0, 4.0]) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn error_pct_zero_when_equal() {
        assert_eq!(mean_error_pct(&[0.5, 0.7], &[0.5, 0.7]), 0.0);
    }
}
