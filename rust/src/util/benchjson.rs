//! Machine-readable bench reporting without serde: a flat JSON object
//! mapping configuration name → metric value (ops/sec for throughput
//! keys, a dimensionless ratio for `*_speedup` keys), written to
//! `BENCH_serve.json`.
//!
//! Each bench harness merges its own keys into the existing file, so one
//! `cargo bench` pass accumulates the full perf picture and the perf
//! trajectory can be diffed across PRs. The parser accepts exactly the
//! flat `{ "key": number, ... }` shape [`render`] emits (the offline
//! crate set has no serde; this is not a general JSON parser).

use std::collections::BTreeMap;
use std::io;
use std::path::Path;

/// The canonical bench-report file name, shared by the harnesses.
pub const BENCH_FILE: &str = "BENCH_serve.json";

/// Merge `entries` into the flat JSON object at `path` (created if
/// missing, unreadable content treated as empty) and rewrite it with
/// sorted keys.
pub fn merge_and_write(path: &Path, entries: &[(String, f64)]) -> io::Result<()> {
    let mut map: BTreeMap<String, f64> = match std::fs::read_to_string(path) {
        Ok(text) => parse_flat(&text).into_iter().collect(),
        Err(_) => BTreeMap::new(),
    };
    for (k, v) in entries {
        map.insert(k.clone(), *v);
    }
    std::fs::write(path, render(&map))
}

/// Parse the flat `{ "key": number, ... }` shape. Key strings honor
/// JSON backslash escapes (the inverse of [`escape`]); unparseable
/// values are skipped rather than failing the bench run.
pub fn parse_flat(text: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(q0) = rest.find('"') {
        let after_open = &rest[q0 + 1..];
        let Some((key, consumed)) = scan_string(after_open) else { break };
        let after_key = &after_open[consumed..];
        let Some(colon) = after_key.find(':') else { break };
        let val_text = after_key[colon + 1..].trim_start();
        let end = val_text
            .find(|c: char| c == ',' || c == '}' || c == '\n')
            .unwrap_or(val_text.len());
        if let Ok(v) = val_text[..end].trim().parse::<f64>() {
            out.push((key, v));
        }
        rest = &val_text[end..];
    }
    out
}

/// Walk a JSON string body (opening quote already consumed), honoring
/// backslash escapes. Returns the unescaped content and the number of
/// bytes consumed *including* the closing quote, or `None` when the
/// string never closes.
fn scan_string(s: &str) -> Option<(String, usize)> {
    let mut key = String::new();
    let mut chars = s.char_indices();
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => return Some((key, i + 1)),
            '\\' => match chars.next() {
                Some((_, 'n')) => key.push('\n'),
                Some((_, 'r')) => key.push('\r'),
                Some((_, 't')) => key.push('\t'),
                Some((_, esc)) => key.push(esc), // \", \\, \/ and friends
                None => return None,
            },
            _ => key.push(c),
        }
    }
    None
}

/// Escape a key for embedding in a JSON string literal.
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            _ => out.push(c),
        }
    }
    out
}

/// Render the map as a stable, diff-friendly flat JSON object.
pub fn render(map: &BTreeMap<String, f64>) -> String {
    let mut s = String::from("{\n");
    for (i, (k, v)) in map.iter().enumerate() {
        let comma = if i + 1 < map.len() { "," } else { "" };
        s.push_str(&format!("  \"{}\": {v:.3}{comma}\n", escape(k)));
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_then_parse_round_trips() {
        let mut map = BTreeMap::new();
        map.insert("serve_multi_shard".to_string(), 12345.678);
        map.insert("hotpath_and64k".to_string(), 0.5);
        let text = render(&map);
        assert!(text.starts_with("{\n"), "{text}");
        assert!(text.ends_with("}\n"), "{text}");
        let parsed = parse_flat(&text);
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].0, "hotpath_and64k"); // BTreeMap order
        assert!((parsed[0].1 - 0.5).abs() < 1e-9);
        assert!((parsed[1].1 - 12345.678).abs() < 1e-3);
    }

    #[test]
    fn merge_updates_existing_file() {
        let dir = std::env::temp_dir().join("stoch_imc_benchjson_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(BENCH_FILE);
        let _ = std::fs::remove_file(&path);
        merge_and_write(&path, &[("a".to_string(), 1.0), ("b".to_string(), 2.0)]).unwrap();
        // Second harness overwrites one key, adds another, keeps the rest.
        merge_and_write(&path, &[("b".to_string(), 3.0), ("c".to_string(), 4.0)]).unwrap();
        let got: BTreeMap<String, f64> =
            parse_flat(&std::fs::read_to_string(&path).unwrap()).into_iter().collect();
        assert_eq!(got.len(), 3);
        assert_eq!(got["a"], 1.0);
        assert_eq!(got["b"], 3.0);
        assert_eq!(got["c"], 4.0);
    }

    #[test]
    fn parse_skips_garbage_values() {
        let parsed = parse_flat("{\n  \"ok\": 1.5,\n  \"bad\": oops,\n  \"also_ok\": 2\n}\n");
        assert_eq!(parsed, vec![("ok".to_string(), 1.5), ("also_ok".to_string(), 2.0)]);
    }

    #[test]
    fn parse_empty_and_malformed() {
        assert!(parse_flat("").is_empty());
        assert!(parse_flat("{}").is_empty());
        assert!(parse_flat("\"dangling").is_empty());
        assert!(parse_flat("\"never closes \\").is_empty());
    }

    #[test]
    fn escaped_keys_round_trip() {
        let mut map = BTreeMap::new();
        map.insert("plain_key".to_string(), 1.0);
        map.insert("quote\"in\"key".to_string(), 2.0);
        map.insert("back\\slash".to_string(), 3.0);
        map.insert("tab\tand\nnewline".to_string(), 4.0);
        let text = render(&map);
        // The rendered form stays one entry per line: escapes keep
        // raw newlines/quotes out of the serialized text.
        assert_eq!(text.lines().count(), map.len() + 2, "{text}");
        let got: BTreeMap<String, f64> = parse_flat(&text).into_iter().collect();
        assert_eq!(got.len(), map.len(), "{text}");
        for (k, v) in &map {
            assert_eq!(got.get(k), Some(v), "key {k:?} lost in {text}");
        }
    }
}
