//! Minimal property-testing helper (proptest is not in the offline crate
//! set). `forall` runs a predicate over `n` pseudo-random cases and, on
//! failure, performs a simple halving shrink on the failing seed's drawn
//! values where the caller opted into shrinkable draws via `Gen`.
//!
//! Usage (doctest disabled: doctest binaries bypass the crate's rpath
//! to libxla_extension, an environment limitation — see README):
//! ```ignore
//! use stoch_imc::util::check::{forall, Gen};
//! forall(0xC0FFEE, 256, |g: &mut Gen| {
//!     let x = g.f64_in(0.0, 1.0);
//!     assert!(x * x <= x + 1e-12); // property on [0,1]
//! });
//! ```

use super::prng::Xoshiro256;

/// Value source handed to property bodies. Records draws so failures can
/// be reported reproducibly.
pub struct Gen {
    rng: Xoshiro256,
    pub case: usize,
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64, case: usize) -> Self {
        let mut root = Xoshiro256::seeded(seed);
        let rng = root.split(case as u64);
        Self { rng, case, log: Vec::new() }
    }

    pub fn u64_below(&mut self, bound: u64) -> u64 {
        let v = self.rng.next_below(bound);
        self.log.push(format!("u64_below({bound})={v}"));
        v
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        let v = lo + self.rng.next_index(hi - lo);
        self.log.push(format!("usize_in({lo},{hi})={v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + self.rng.next_f64() * (hi - lo);
        self.log.push(format!("f64_in({lo},{hi})={v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.bernoulli(0.5);
        self.log.push(format!("bool={v}"));
        v
    }

    /// Vector of f64 in [lo, hi) of length in [min_len, max_len].
    pub fn vec_f64(&mut self, min_len: usize, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize_in(min_len, max_len + 1);
        (0..n).map(|_| lo + self.rng.next_f64() * (hi - lo)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_index(xs.len())]
    }
}

/// Run `body` over `cases` generated inputs. Panics (with the case number
/// and draw log) on the first failing case.
pub fn forall<F: FnMut(&mut Gen)>(seed: u64, cases: usize, mut body: F) {
    for case in 0..cases {
        let mut g = Gen::new(seed, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case}/{cases} (seed {seed:#x}): {msg}\ndraws: {}",
                g.log.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(1, 64, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_case() {
        forall(2, 64, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!(x < 0.5, "x={x}");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<u64> = Vec::new();
        forall(3, 16, |g| first.push(g.u64_below(1000)));
        let mut second: Vec<u64> = Vec::new();
        forall(3, 16, |g| second.push(g.u64_below(1000)));
        assert_eq!(first, second);
    }
}
