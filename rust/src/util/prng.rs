//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we implement the two standard
//! small generators ourselves: SplitMix64 (seeding / stream splitting) and
//! xoshiro256** (bulk generation). Both match the published reference
//! implementations (Blackman & Vigna), which we verify in the tests below
//! against known vectors.
//!
//! Determinism matters here: every experiment in EXPERIMENTS.md is keyed
//! by an explicit seed so results are exactly reproducible.

/// FNV-1a over a string: the repo's cheap *stable* hash for deriving
/// seeds and routing keys from names. Stability matters — per-row seed
/// derivation (`runtime::interp`) and app→shard routing (`serve::pool`)
/// must not depend on `RandomState`.
pub fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the repo-wide bulk PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference recommendation.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (e.g. one per worker / per lane).
    pub fn split(&mut self, stream: u64) -> Self {
        let mix = self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        Self::seeded(mix)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, bound) without modulo bias (Lemire rejection).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn next_index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fill a slice with uniform f64s.
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.next_f64();
        }
    }
}

/// A bank of independent [`Xoshiro256`] streams in structure-of-arrays
/// layout, stepped in lockstep — the software analogue of one SNG
/// comparator per subarray row all firing in the same cycle.
///
/// Lane `l`'s draw sequence is **bit-identical** to a standalone
/// `Xoshiro256::seeded(seed_of(l))` stream: seeding expands each lane's
/// seed through SplitMix64 exactly as [`Xoshiro256::seeded`] does, and
/// the lockstep step applies the reference xoshiro256** update per
/// lane. That equivalence is what lets the lane-major SNG pipeline
/// (which draws uniforms via [`RngBank::next_f64_into`]) replace
/// per-row generation without changing a single output bit.
/// [`RngBank::next_below_each`] extends the same contract to bounded
/// draws for per-lane counter circuits: Lemire rejection is resolved
/// *per lane* (a rejecting lane redraws alone; the others do not
/// step), so rejection never couples lanes.
#[derive(Debug, Clone, Default)]
pub struct RngBank {
    s0: Vec<u64>,
    s1: Vec<u64>,
    s2: Vec<u64>,
    s3: Vec<u64>,
}

impl RngBank {
    /// An empty bank; call [`RngBank::reseed_with`] before drawing.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of lanes (independent streams) currently seeded.
    pub fn len(&self) -> usize {
        self.s0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.s0.is_empty()
    }

    /// Re-seed the bank to `n` lanes, lane `l` from `seed_of(l)`,
    /// exactly as `Xoshiro256::seeded(seed_of(l))` would. Reuses the
    /// state allocations, so a per-block reseed costs only the
    /// SplitMix64 expansion.
    pub fn reseed_with(&mut self, n: usize, seed_of: impl Fn(usize) -> u64) {
        self.s0.clear();
        self.s1.clear();
        self.s2.clear();
        self.s3.clear();
        for l in 0..n {
            let mut sm = SplitMix64::new(seed_of(l));
            self.s0.push(sm.next_u64());
            self.s1.push(sm.next_u64());
            self.s2.push(sm.next_u64());
            self.s3.push(sm.next_u64());
        }
    }

    /// One xoshiro256** step for lane `l` (reference update order).
    #[inline(always)]
    fn step_lane(&mut self, l: usize) -> u64 {
        let s1 = self.s1[l];
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s1 << 17;
        self.s2[l] ^= self.s0[l];
        self.s3[l] ^= s1;
        self.s1[l] = s1 ^ self.s2[l];
        self.s0[l] ^= self.s3[l];
        self.s2[l] ^= t;
        self.s3[l] = self.s3[l].rotate_left(45);
        result
    }

    /// Step every lane once: `out[l]` gets lane `l`'s next u64. The SoA
    /// state walk is a flat loop over four contiguous arrays, which is
    /// what lets the compiler vectorize the whole bank step.
    #[inline]
    pub fn next_u64_into(&mut self, out: &mut [u64]) {
        assert_eq!(out.len(), self.len(), "lane count mismatch");
        for (l, slot) in out.iter_mut().enumerate() {
            *slot = self.step_lane(l);
        }
    }

    /// Step every lane once: `out[l]` gets lane `l`'s next uniform f64
    /// in [0, 1), identical to [`Xoshiro256::next_f64`] per lane.
    #[inline]
    pub fn next_f64_into(&mut self, out: &mut [f64]) {
        assert_eq!(out.len(), self.len(), "lane count mismatch");
        for (l, slot) in out.iter_mut().enumerate() {
            *slot = (self.step_lane(l) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        }
    }

    /// Per-lane `next_below`: `out[l]` gets lane `l`'s next uniform u64
    /// in [0, bound) via Lemire rejection. Rejection is resolved inside
    /// each lane's own stream — a rejecting lane consumes extra raw
    /// draws exactly like a standalone [`Xoshiro256::next_below`], and
    /// the other lanes' states are untouched by it.
    pub fn next_below_each(&mut self, bound: u64, out: &mut [u64]) {
        assert!(bound > 0, "next_below_each(0)");
        assert_eq!(out.len(), self.len(), "lane count mismatch");
        for l in 0..out.len() {
            out[l] = loop {
                let x = self.step_lane(l);
                let m = (x as u128) * (bound as u128);
                let (hi, lo) = ((m >> 64) as u64, m as u64);
                if lo >= bound || lo >= bound.wrapping_neg() % bound {
                    break hi;
                }
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference vector for seed 1234567 (first outputs of the
        // canonical C implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        // Known first two outputs for seed 0.
        assert_eq!(a, 0xE220_A839_7B1D_CDAF);
        assert_eq!(b, 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn xoshiro_distinct_streams() {
        let mut root = Xoshiro256::seeded(42);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut rng = Xoshiro256::seeded(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased_bounds() {
        let mut rng = Xoshiro256::seeded(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Xoshiro256::seeded(5);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn determinism_same_seed() {
        let mut a = Xoshiro256::seeded(99);
        let mut b = Xoshiro256::seeded(99);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    /// Seeds spanning several lanes, deliberately non-uniform so lane
    /// index and seed are distinguishable in failures.
    fn bank_seeds(n: usize) -> Vec<u64> {
        (0..n).map(|l| 0xD1CE_u64 ^ ((l as u64) << 7) ^ ((l as u64).wrapping_mul(0x9E37))).collect()
    }

    #[test]
    fn rng_bank_u64_matches_independent_streams_exactly() {
        // The whole contract: lane l of the bank == a standalone
        // Xoshiro256 seeded the same way, u64 for u64.
        let seeds = bank_seeds(67);
        let mut bank = RngBank::new();
        bank.reseed_with(seeds.len(), |l| seeds[l]);
        assert_eq!(bank.len(), 67);
        assert!(!bank.is_empty());
        let mut solo: Vec<Xoshiro256> = seeds.iter().map(|&s| Xoshiro256::seeded(s)).collect();
        let mut out = vec![0u64; seeds.len()];
        for step in 0..200 {
            bank.next_u64_into(&mut out);
            for (l, r) in solo.iter_mut().enumerate() {
                assert_eq!(out[l], r.next_u64(), "lane {l} step {step}");
            }
        }
    }

    #[test]
    fn rng_bank_f64_matches_independent_streams_exactly() {
        let seeds = bank_seeds(64);
        let mut bank = RngBank::new();
        bank.reseed_with(seeds.len(), |l| seeds[l]);
        let mut solo: Vec<Xoshiro256> = seeds.iter().map(|&s| Xoshiro256::seeded(s)).collect();
        let mut out = vec![0.0f64; seeds.len()];
        for step in 0..100 {
            bank.next_f64_into(&mut out);
            for (l, r) in solo.iter_mut().enumerate() {
                // Exact bit equality, not approximate: same raw u64,
                // same conversion.
                assert_eq!(out[l].to_bits(), r.next_f64().to_bits(), "lane {l} step {step}");
            }
        }
    }

    #[test]
    fn rng_bank_lemire_rejection_diverges_per_lane() {
        // A bound just above 2^63 rejects ≈ half the raw draws, so
        // different lanes consume different numbers of raw u64s. If the
        // bank resolved rejection in lockstep (stepping all lanes until
        // everyone accepts), lanes would drift off their standalone
        // streams after the first uneven rejection — sustained exact
        // equality across many rounds pins the per-lane resolution.
        let bound = (1u64 << 63) + 12_345;
        let seeds = bank_seeds(32);
        let mut bank = RngBank::new();
        bank.reseed_with(seeds.len(), |l| seeds[l]);
        let mut solo: Vec<Xoshiro256> = seeds.iter().map(|&s| Xoshiro256::seeded(s)).collect();
        let mut out = vec![0u64; seeds.len()];
        for round in 0..100 {
            bank.next_below_each(bound, &mut out);
            for (l, r) in solo.iter_mut().enumerate() {
                let want = r.next_below(bound);
                assert!(want < bound);
                assert_eq!(out[l], want, "lane {l} round {round}");
            }
        }
    }

    #[test]
    fn rng_bank_reseed_replaces_all_lanes() {
        let mut bank = RngBank::new();
        bank.reseed_with(8, |l| l as u64);
        let mut a = vec![0u64; 8];
        bank.next_u64_into(&mut a);
        // Re-seeding with the same seeds restarts every stream; with a
        // different lane count it reshapes the bank.
        bank.reseed_with(8, |l| l as u64);
        let mut b = vec![0u64; 8];
        bank.next_u64_into(&mut b);
        assert_eq!(a, b);
        bank.reseed_with(3, |l| l as u64 ^ 0xFF);
        assert_eq!(bank.len(), 3);
    }
}
