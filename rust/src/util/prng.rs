//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we implement the two standard
//! small generators ourselves: SplitMix64 (seeding / stream splitting) and
//! xoshiro256** (bulk generation). Both match the published reference
//! implementations (Blackman & Vigna), which we verify in the tests below
//! against known vectors.
//!
//! Determinism matters here: every experiment in EXPERIMENTS.md is keyed
//! by an explicit seed so results are exactly reproducible.

/// FNV-1a over a string: the repo's cheap *stable* hash for deriving
/// seeds and routing keys from names. Stability matters — per-row seed
/// derivation (`runtime::interp`) and app→shard routing (`serve::pool`)
/// must not depend on `RandomState`.
pub fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the repo-wide bulk PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference recommendation.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (e.g. one per worker / per lane).
    pub fn split(&mut self, stream: u64) -> Self {
        let mix = self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        Self::seeded(mix)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, bound) without modulo bias (Lemire rejection).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn next_index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fill a slice with uniform f64s.
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.next_f64();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference vector for seed 1234567 (first outputs of the
        // canonical C implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        // Known first two outputs for seed 0.
        assert_eq!(a, 0xE220_A839_7B1D_CDAF);
        assert_eq!(b, 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn xoshiro_distinct_streams() {
        let mut root = Xoshiro256::seeded(42);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut rng = Xoshiro256::seeded(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased_bounds() {
        let mut rng = Xoshiro256::seeded(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Xoshiro256::seeded(5);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn determinism_same_seed() {
        let mut a = Xoshiro256::seeded(99);
        let mut b = Xoshiro256::seeded(99);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
