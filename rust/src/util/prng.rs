//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we implement the two standard
//! small generators ourselves: SplitMix64 (seeding / stream splitting) and
//! xoshiro256** (bulk generation). Both match the published reference
//! implementations (Blackman & Vigna), which we verify in the tests below
//! against known vectors.
//!
//! Determinism matters here: every experiment in EXPERIMENTS.md is keyed
//! by an explicit seed so results are exactly reproducible.

/// FNV-1a over a string: the repo's cheap *stable* hash for deriving
/// seeds and routing keys from names. Stability matters — per-row seed
/// derivation (`runtime::interp`) and app→shard routing (`serve::pool`)
/// must not depend on `RandomState`.
pub fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// SplitMix64's Weyl-sequence increment (the golden-ratio gamma from the
/// reference implementation). Shared by the sequential [`SplitMix64`]
/// walker and the counter-addressed [`CounterRng`], which must agree on
/// it exactly for seek ≡ sequential-stream identity.
pub const GOLDEN_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// SplitMix64's avalanche finalizer (Stafford variant 13): a bijective
/// mix of a 64-bit word. Exposed on its own because the counter RNG,
/// stream keying, and the fault mask source are all "finalize a
/// structured coordinate word" applications of this one function.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN_GAMMA);
        mix64(self.state)
    }
}

/// Which generator feeds the SNG draw path of the lane engine.
///
/// `Counter` is the default: the stateless counter generator below,
/// O(1)-seekable and step-major/SIMD friendly. `Xoshiro` is the pinned
/// compatibility path (the original lockstep [`RngBank`]), kept
/// bit-exact so historical outputs stay reproducible. Selected per wave
/// via `STOCH_IMC_RNG=counter|xoshiro` or the explicit tuned APIs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RngMode {
    #[default]
    Counter,
    Xoshiro,
}

/// Domain-separation constant for the node half-key (the same odd
/// multiplier `Xoshiro256::split` uses for stream separation).
const NODE_PHI: u64 = 0xA076_1D64_78BD_642F;

/// Derive the node (SNG input site) half of a counter stream key. The
/// full key is `lane_part.wrapping_add(counter_node_part(node))`; the
/// split lets the lane half be computed once per lane per block and the
/// node half once per input per block.
#[inline]
pub fn counter_node_part(node: u64) -> u64 {
    mix64(node.wrapping_mul(GOLDEN_GAMMA) ^ NODE_PHI)
}

/// Counter-based stateless generator: draw `t` of the stream keyed by
/// `key` is `mix64(key + GOLDEN_GAMMA·(t+1))` — i.e. the stream *is* a
/// SplitMix64 sequence seeded at `key`, but addressed by counter instead
/// of walked by mutation. Any draw is O(1)-computable in any order, so
/// lanes, nodes and steps can be generated in whatever stride is fastest
/// (the lane engine uses step-major strides across a whole lane word).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterRng {
    key: u64,
}

impl CounterRng {
    /// Stream addressed directly by a raw key (draw `t` equals
    /// `SplitMix64::new(key)`'s `t+1`-th output).
    pub fn from_key(key: u64) -> Self {
        Self { key }
    }

    /// Stream for SNG input site `node` of the row seeded `row_seed` —
    /// the composition the lane engine uses: lane half-key from the
    /// row seed, node half-key from [`counter_node_part`].
    pub fn keyed(row_seed: u64, node: u64) -> Self {
        Self { key: mix64(row_seed ^ GOLDEN_GAMMA).wrapping_add(counter_node_part(node)) }
    }

    /// Raw draw `t` (0-indexed) of this stream.
    #[inline]
    pub fn draw_at(&self, t: u64) -> u64 {
        mix64(self.key.wrapping_add(GOLDEN_GAMMA.wrapping_mul(t.wrapping_add(1))))
    }

    /// Uniform f64 in [0, 1) at position `t`, same 53-bit conversion as
    /// [`Xoshiro256::next_f64`].
    #[inline]
    pub fn f64_at(&self, t: u64) -> f64 {
        (self.draw_at(t) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// A bank of per-lane counter-stream half-keys: the stateless analogue
/// of [`RngBank`]. Where the xoshiro bank holds 4×`n` words of mutable
/// state and must be stepped in draw order, this holds one immutable
/// half-key per lane, and [`CounterBank::draws_at_into`] computes any
/// step of every lane directly — the generation loop is a pure
/// map over lanes with no loop-carried dependence, which is what lets
/// the compiler (or the explicit `simd` feature path) vectorize it.
#[derive(Debug, Clone, Default)]
pub struct CounterBank {
    lane_keys: Vec<u64>,
}

impl CounterBank {
    /// An empty bank; call [`CounterBank::reseed_with`] before drawing.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of lanes currently keyed.
    pub fn len(&self) -> usize {
        self.lane_keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lane_keys.is_empty()
    }

    /// Re-key the bank to `n` lanes, lane `l` from `seed_of(l)` — the
    /// same per-lane seed contract as [`RngBank::reseed_with`], but the
    /// expansion is a single mix instead of a 4-word SplitMix64 walk.
    pub fn reseed_with(&mut self, n: usize, seed_of: impl Fn(usize) -> u64) {
        self.lane_keys.clear();
        for l in 0..n {
            self.lane_keys.push(mix64(seed_of(l) ^ GOLDEN_GAMMA));
        }
    }

    /// The standalone stream for lane `l` at node half-key `node_part`
    /// (from [`counter_node_part`]). Lane `l`'s draws via
    /// [`CounterBank::draws_at_into`] are bit-identical to this stream —
    /// the bank/solo equivalence the scalar reference path relies on.
    pub fn stream(&self, l: usize, node_part: u64) -> CounterRng {
        CounterRng::from_key(self.lane_keys[l].wrapping_add(node_part))
    }

    /// Compute draw `t` of node `node_part`'s stream for every lane:
    /// `out[l]` gets lane `l`'s draw. The per-step counter term is
    /// hoisted so the loop body is add-then-mix over a contiguous key
    /// array.
    #[inline]
    pub fn draws_at_into(&self, node_part: u64, t: u64, out: &mut [u64]) {
        assert_eq!(out.len(), self.len(), "lane count mismatch");
        let ctr = GOLDEN_GAMMA.wrapping_mul(t.wrapping_add(1)).wrapping_add(node_part);
        #[cfg(feature = "simd")]
        {
            simd::draws_at(&self.lane_keys, ctr, out);
        }
        #[cfg(not(feature = "simd"))]
        for (slot, &k) in out.iter_mut().zip(self.lane_keys.iter()) {
            *slot = mix64(k.wrapping_add(ctr));
        }
    }
}

/// Explicit `std::simd` lanes for the counter draw kernel (nightly-only
/// `simd` feature; the scalar loop above is the bit-identical default).
#[cfg(feature = "simd")]
mod simd {
    use std::simd::u64x8;

    /// [`super::mix64`] over 8 lanes at once. `Simd` integer ops wrap on
    /// overflow, matching the scalar `wrapping_mul`/`wrapping_add`.
    #[inline]
    fn mix64x8(mut z: u64x8) -> u64x8 {
        z = (z ^ (z >> u64x8::splat(30))) * u64x8::splat(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> u64x8::splat(27))) * u64x8::splat(0x94D0_49BB_1331_11EB);
        z ^ (z >> u64x8::splat(31))
    }

    #[inline]
    pub fn draws_at(keys: &[u64], ctr: u64, out: &mut [u64]) {
        let ctrv = u64x8::splat(ctr);
        let mut chunks = keys.chunks_exact(8);
        let mut outs = out.chunks_exact_mut(8);
        for (k, o) in (&mut chunks).zip(&mut outs) {
            let v = mix64x8(u64x8::from_slice(k) + ctrv);
            o.copy_from_slice(&v.to_array());
        }
        for (slot, &k) in outs.into_remainder().iter_mut().zip(chunks.remainder()) {
            *slot = super::mix64(k.wrapping_add(ctr));
        }
    }
}

/// xoshiro256**: the repo-wide bulk PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference recommendation.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (e.g. one per worker / per lane).
    pub fn split(&mut self, stream: u64) -> Self {
        let mix = self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        Self::seeded(mix)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, bound) without modulo bias (Lemire rejection).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn next_index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fill a slice with uniform f64s.
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.next_f64();
        }
    }
}

/// A bank of independent [`Xoshiro256`] streams in structure-of-arrays
/// layout, stepped in lockstep — the software analogue of one SNG
/// comparator per subarray row all firing in the same cycle.
///
/// Lane `l`'s draw sequence is **bit-identical** to a standalone
/// `Xoshiro256::seeded(seed_of(l))` stream: seeding expands each lane's
/// seed through SplitMix64 exactly as [`Xoshiro256::seeded`] does, and
/// the lockstep step applies the reference xoshiro256** update per
/// lane. That equivalence is what lets the lane-major SNG pipeline
/// (which draws uniforms via [`RngBank::next_f64_into`]) replace
/// per-row generation without changing a single output bit.
/// [`RngBank::next_below_each`] extends the same contract to bounded
/// draws for per-lane counter circuits: Lemire rejection is resolved
/// *per lane* (a rejecting lane redraws alone; the others do not
/// step), so rejection never couples lanes.
#[derive(Debug, Clone, Default)]
pub struct RngBank {
    s0: Vec<u64>,
    s1: Vec<u64>,
    s2: Vec<u64>,
    s3: Vec<u64>,
}

impl RngBank {
    /// An empty bank; call [`RngBank::reseed_with`] before drawing.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of lanes (independent streams) currently seeded.
    pub fn len(&self) -> usize {
        self.s0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.s0.is_empty()
    }

    /// Re-seed the bank to `n` lanes, lane `l` from `seed_of(l)`,
    /// exactly as `Xoshiro256::seeded(seed_of(l))` would. Reuses the
    /// state allocations, so a per-block reseed costs only the
    /// SplitMix64 expansion.
    pub fn reseed_with(&mut self, n: usize, seed_of: impl Fn(usize) -> u64) {
        self.s0.clear();
        self.s1.clear();
        self.s2.clear();
        self.s3.clear();
        for l in 0..n {
            let mut sm = SplitMix64::new(seed_of(l));
            self.s0.push(sm.next_u64());
            self.s1.push(sm.next_u64());
            self.s2.push(sm.next_u64());
            self.s3.push(sm.next_u64());
        }
    }

    /// One xoshiro256** step for lane `l` (reference update order).
    #[inline(always)]
    fn step_lane(&mut self, l: usize) -> u64 {
        let s1 = self.s1[l];
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s1 << 17;
        self.s2[l] ^= self.s0[l];
        self.s3[l] ^= s1;
        self.s1[l] = s1 ^ self.s2[l];
        self.s0[l] ^= self.s3[l];
        self.s2[l] ^= t;
        self.s3[l] = self.s3[l].rotate_left(45);
        result
    }

    /// Step every lane once: `out[l]` gets lane `l`'s next u64. The SoA
    /// state walk is a flat loop over four contiguous arrays, which is
    /// what lets the compiler vectorize the whole bank step.
    #[inline]
    pub fn next_u64_into(&mut self, out: &mut [u64]) {
        assert_eq!(out.len(), self.len(), "lane count mismatch");
        for (l, slot) in out.iter_mut().enumerate() {
            *slot = self.step_lane(l);
        }
    }

    /// Step every lane once: `out[l]` gets lane `l`'s next uniform f64
    /// in [0, 1), identical to [`Xoshiro256::next_f64`] per lane.
    #[inline]
    pub fn next_f64_into(&mut self, out: &mut [f64]) {
        assert_eq!(out.len(), self.len(), "lane count mismatch");
        for (l, slot) in out.iter_mut().enumerate() {
            *slot = (self.step_lane(l) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        }
    }

    /// Per-lane `next_below`: `out[l]` gets lane `l`'s next uniform u64
    /// in [0, bound) via Lemire rejection. Rejection is resolved inside
    /// each lane's own stream — a rejecting lane consumes extra raw
    /// draws exactly like a standalone [`Xoshiro256::next_below`], and
    /// the other lanes' states are untouched by it.
    pub fn next_below_each(&mut self, bound: u64, out: &mut [u64]) {
        assert!(bound > 0, "next_below_each(0)");
        assert_eq!(out.len(), self.len(), "lane count mismatch");
        for l in 0..out.len() {
            out[l] = loop {
                let x = self.step_lane(l);
                let m = (x as u128) * (bound as u128);
                let (hi, lo) = ((m >> 64) as u64, m as u64);
                if lo >= bound || lo >= bound.wrapping_neg() % bound {
                    break hi;
                }
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference vector for seed 1234567 (first outputs of the
        // canonical C implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        // Known first two outputs for seed 0.
        assert_eq!(a, 0xE220_A839_7B1D_CDAF);
        assert_eq!(b, 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn xoshiro_distinct_streams() {
        let mut root = Xoshiro256::seeded(42);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut rng = Xoshiro256::seeded(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased_bounds() {
        let mut rng = Xoshiro256::seeded(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Xoshiro256::seeded(5);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn determinism_same_seed() {
        let mut a = Xoshiro256::seeded(99);
        let mut b = Xoshiro256::seeded(99);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    /// Seeds spanning several lanes, deliberately non-uniform so lane
    /// index and seed are distinguishable in failures.
    fn bank_seeds(n: usize) -> Vec<u64> {
        (0..n).map(|l| 0xD1CE_u64 ^ ((l as u64) << 7) ^ ((l as u64).wrapping_mul(0x9E37))).collect()
    }

    #[test]
    fn rng_bank_u64_matches_independent_streams_exactly() {
        // The whole contract: lane l of the bank == a standalone
        // Xoshiro256 seeded the same way, u64 for u64.
        let seeds = bank_seeds(67);
        let mut bank = RngBank::new();
        bank.reseed_with(seeds.len(), |l| seeds[l]);
        assert_eq!(bank.len(), 67);
        assert!(!bank.is_empty());
        let mut solo: Vec<Xoshiro256> = seeds.iter().map(|&s| Xoshiro256::seeded(s)).collect();
        let mut out = vec![0u64; seeds.len()];
        for step in 0..200 {
            bank.next_u64_into(&mut out);
            for (l, r) in solo.iter_mut().enumerate() {
                assert_eq!(out[l], r.next_u64(), "lane {l} step {step}");
            }
        }
    }

    #[test]
    fn rng_bank_f64_matches_independent_streams_exactly() {
        let seeds = bank_seeds(64);
        let mut bank = RngBank::new();
        bank.reseed_with(seeds.len(), |l| seeds[l]);
        let mut solo: Vec<Xoshiro256> = seeds.iter().map(|&s| Xoshiro256::seeded(s)).collect();
        let mut out = vec![0.0f64; seeds.len()];
        for step in 0..100 {
            bank.next_f64_into(&mut out);
            for (l, r) in solo.iter_mut().enumerate() {
                // Exact bit equality, not approximate: same raw u64,
                // same conversion.
                assert_eq!(out[l].to_bits(), r.next_f64().to_bits(), "lane {l} step {step}");
            }
        }
    }

    #[test]
    fn rng_bank_lemire_rejection_diverges_per_lane() {
        // A bound just above 2^63 rejects ≈ half the raw draws, so
        // different lanes consume different numbers of raw u64s. If the
        // bank resolved rejection in lockstep (stepping all lanes until
        // everyone accepts), lanes would drift off their standalone
        // streams after the first uneven rejection — sustained exact
        // equality across many rounds pins the per-lane resolution.
        let bound = (1u64 << 63) + 12_345;
        let seeds = bank_seeds(32);
        let mut bank = RngBank::new();
        bank.reseed_with(seeds.len(), |l| seeds[l]);
        let mut solo: Vec<Xoshiro256> = seeds.iter().map(|&s| Xoshiro256::seeded(s)).collect();
        let mut out = vec![0u64; seeds.len()];
        for round in 0..100 {
            bank.next_below_each(bound, &mut out);
            for (l, r) in solo.iter_mut().enumerate() {
                let want = r.next_below(bound);
                assert!(want < bound);
                assert_eq!(out[l], want, "lane {l} round {round}");
            }
        }
    }

    #[test]
    fn counter_rng_is_seekable_splitmix() {
        // The whole design: CounterRng::from_key(k) addressed at t is
        // SplitMix64::new(k)'s (t+1)-th output. Sequential walk and
        // O(1) seek must agree draw-for-draw, in any access order.
        for key in [0u64, 1, 0xDEAD_BEEF, u64::MAX] {
            let mut sm = SplitMix64::new(key);
            let ctr = CounterRng::from_key(key);
            let seq: Vec<u64> = (0..64).map(|_| sm.next_u64()).collect();
            for t in (0..64).rev() {
                assert_eq!(ctr.draw_at(t as u64), seq[t], "key {key:#x} t {t}");
            }
        }
        // Pinned reference vector (seed 0, canonical SplitMix64).
        let c = CounterRng::from_key(0);
        assert_eq!(c.draw_at(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(c.draw_at(1), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn counter_f64_matches_xoshiro_conversion() {
        let c = CounterRng::from_key(77);
        for t in 0..1000 {
            let f = c.f64_at(t);
            assert!((0.0..1.0).contains(&f));
            let expect = (c.draw_at(t) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            assert_eq!(f.to_bits(), expect.to_bits());
        }
    }

    #[test]
    fn counter_bank_matches_standalone_streams() {
        let seeds = bank_seeds(67);
        let mut bank = CounterBank::new();
        bank.reseed_with(seeds.len(), |l| seeds[l]);
        assert_eq!(bank.len(), 67);
        assert!(!bank.is_empty());
        let node = counter_node_part(0x1234);
        let mut out = vec![0u64; seeds.len()];
        for t in 0..100 {
            bank.draws_at_into(node, t, &mut out);
            for l in 0..seeds.len() {
                assert_eq!(out[l], bank.stream(l, node).draw_at(t), "lane {l} t {t}");
                // And the composed keying matches CounterRng::keyed.
                let keyed = CounterRng::keyed(seeds[l], 0x1234);
                assert_eq!(out[l], keyed.draw_at(t), "lane {l} t {t}");
            }
        }
    }

    #[test]
    fn counter_adjacent_keys_distinct() {
        // Adjacent (node, lane, step) coordinates must give unrelated
        // draws: collect a window around a base coordinate in every
        // direction and require all values distinct.
        let mut seen = std::collections::HashSet::new();
        let base = CounterRng::keyed(42, 7);
        for t in 0..32 {
            assert!(seen.insert(base.draw_at(t)));
        }
        for node in 0..32 {
            assert!(seen.insert(CounterRng::keyed(42, node).draw_at(100)));
        }
        for row_seed in 0..32 {
            assert!(seen.insert(CounterRng::keyed(row_seed, 7).draw_at(100)));
        }
    }

    #[test]
    fn rng_bank_reseed_replaces_all_lanes() {
        let mut bank = RngBank::new();
        bank.reseed_with(8, |l| l as u64);
        let mut a = vec![0u64; 8];
        bank.next_u64_into(&mut a);
        // Re-seeding with the same seeds restarts every stream; with a
        // different lane count it reshapes the bank.
        bank.reseed_with(8, |l| l as u64);
        let mut b = vec![0u64; 8];
        bank.next_u64_into(&mut b);
        assert_eq!(a, b);
        bank.reseed_with(3, |l| l as u64 ^ 0xFF);
        assert_eq!(bank.len(), 3);
    }
}
