//! S10 — energy model (paper Eqs 3–4 and the §5.1 SPICE constants).
//!
//!   E_total = BL × E_computation + E_peripheral               (Eq 3)
//!   E_computation = N_preset·E_preset + N_SBG·E_SBG + Σ_g N_g·E_g (Eq 4)
//!
//! The per-gate energies are the paper's SPICE-extracted values. E_SBG
//! is a calibrated aJ-scale constant (see DESIGN.md §6: the physical
//! V²t/R value of the §2.3 pulse is fJ-scale, which would contradict the
//! paper's own Fig 10 breakdown; the paper's accounting evidently uses a
//! device-level aJ-scale stochastic-write energy, so we do too and keep
//! it configurable).

use std::collections::HashMap;

use crate::netlist::graph::GateKind;
use crate::scheduler::schedule::Schedule;

/// Per-operation energies in joules.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyParams {
    pub e_not: f64,
    pub e_buff: f64,
    pub e_nand: f64,
    pub e_nor: f64,
    pub e_maj3: f64,
    pub e_maj5: f64,
    pub e_preset: f64,
    /// Stochastic bit generation (one stochastic input write).
    pub e_sbg: f64,
    /// Deterministic binary write (one input cell).
    pub e_write: f64,
    /// Local accumulator op (1-bit add into ⌊log m⌋+1-bit register).
    pub e_acc_local: f64,
    /// Global accumulator op.
    pub e_acc_global: f64,
    /// Subarray peripheral circuitry per active subarray-cycle
    /// (SL/BL drivers, modified SA driver).
    pub e_driver_cycle: f64,
    /// One BtoS memory lookup (binary value → pulse parameters).
    pub e_btos_lookup: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self {
            // §5.1 SPICE-extracted gate energies.
            e_not: 30.7e-18,
            e_buff: 73.8e-18,
            e_nand: 28.7e-18,
            e_nor: 8.4e-18,
            e_maj3: 7.6e-18,
            e_maj5: 6.3e-18,
            e_preset: 26.1e-18,
            // Calibrated (DESIGN.md §6).
            e_sbg: 25.0e-18,
            e_write: 40.0e-18,
            // 15nm Nangate-scale accumulators / peripherals (DESIGN.md §6).
            e_acc_local: 0.8e-15,
            e_acc_global: 2.4e-15,
            e_driver_cycle: 1.1e-15,
            e_btos_lookup: 0.05e-15,
        }
    }
}

impl EnergyParams {
    pub fn gate_energy(&self, kind: GateKind) -> f64 {
        match kind {
            GateKind::Not => self.e_not,
            GateKind::Buff => self.e_buff,
            GateKind::Nand => self.e_nand,
            GateKind::Nor => self.e_nor,
            GateKind::Maj3Inv => self.e_maj3,
            GateKind::Maj5Inv => self.e_maj5,
            // AND/OR realized as NAND/NOR + NOT in the builders; a bare
            // And/Or op is charged as its two-gate realization.
            GateKind::And => self.e_nand + self.e_not,
            GateKind::Or => self.e_nor + self.e_not,
        }
    }
}

/// Energy breakdown of one computation (Fig 10 categories).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub logic: f64,
    pub preset: f64,
    pub input_init: f64,
    pub peripheral: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.logic + self.preset + self.input_init + self.peripheral
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.logic += other.logic;
        self.preset += other.preset;
        self.input_init += other.input_init;
        self.peripheral += other.peripheral;
    }

    pub fn scaled(&self, k: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            logic: self.logic * k,
            preset: self.preset * k,
            input_init: self.input_init * k,
            peripheral: self.peripheral * k,
        }
    }

    /// Percentages per Fig 10 (logic, preset/reset, input init,
    /// peripheral).
    pub fn percentages(&self) -> [f64; 4] {
        let t = self.total().max(1e-300);
        [
            100.0 * self.logic / t,
            100.0 * self.preset / t,
            100.0 * self.input_init / t,
            100.0 * self.peripheral / t,
        ]
    }
}

/// Dynamic operation counters accumulated by the lane-major executor as
/// a wave runs — Eq 4's `N_*` terms counted at *firing* granularity
/// (one firing = one gate evaluation / cell write on one lane at one
/// bit position). The static model (`computation_energy`, below) counts
/// the same quantities from a `scheduler::Schedule`; the cross-check
/// test in `tests/fault.rs` keeps the two from drifting.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCounters {
    /// Gate firings, indexed by [`GateKind::index`].
    pub gates: [u64; GateKind::COUNT],
    /// ADDIE integrator steps (one per square-root lane per bit). Like
    /// the static model, these are *not* charged as logic energy — the
    /// ADDIE macro is charged at readout via the accumulator path — but
    /// they are write traffic, so the wear model counts them.
    pub addie_steps: u64,
    /// Output-cell presets (one per gate firing plus one per SBG write
    /// — the 2T-1MTJ destructive-logic preset of Eq 4).
    pub presets: u64,
    /// Stochastic input-bit writes (SBG firings, one per generated
    /// input-stream bit).
    pub sbg_writes: u64,
    /// StoB conversions through the accumulator path (one per stage
    /// output per lane — §4.3's local-accumulator readout).
    pub stob_reads: u64,
}

impl OpCounters {
    pub fn add(&mut self, other: &OpCounters) {
        for (a, b) in self.gates.iter_mut().zip(&other.gates) {
            *a += b;
        }
        self.addie_steps += other.addie_steps;
        self.presets += other.presets;
        self.sbg_writes += other.sbg_writes;
        self.stob_reads += other.stob_reads;
    }

    /// Total gate firings across every kind (ADDIE steps excluded).
    pub fn gate_total(&self) -> u64 {
        self.gates.iter().sum()
    }

    /// Total cell-write traffic: the wear model's `B` contribution of
    /// these counters (gates + presets + SBG + ADDIE steps).
    pub fn write_total(&self) -> u64 {
        self.gate_total() + self.presets + self.sbg_writes + self.addie_steps
    }

    /// Price the counters with Eq 4 (+ the accumulator readout as the
    /// peripheral share): the executor-side energy breakdown.
    pub fn energy(&self, params: &EnergyParams) -> EnergyBreakdown {
        let mut logic = 0.0;
        for kind in GateKind::ALL {
            logic += params.gate_energy(kind) * self.gates[kind.index()] as f64;
        }
        EnergyBreakdown {
            logic,
            preset: self.presets as f64 * params.e_preset,
            input_init: self.sbg_writes as f64 * (params.e_sbg + params.e_btos_lookup),
            peripheral: self.stob_reads as f64 * params.e_acc_local,
        }
    }
}

/// Computation-phase energy of a schedule execution (`passes` passes of
/// the scheduled sub-bitstream — Eq 3's BL multiplier appears through
/// the pass count × per-pass op counts).
pub fn computation_energy(
    params: &EnergyParams,
    sched: &Schedule,
    passes: usize,
) -> EnergyBreakdown {
    let mut logic = 0.0;
    for (kind, n) in sched.op_histogram() {
        // ADDIE macro lanes are charged at readout via the accumulator
        // path; its in-array share is the tap BUFFs already in `steps`.
        logic += params.gate_energy(kind) * n as f64;
    }
    let preset = sched.preset_count() as f64 * params.e_preset;
    let input_init = sched.sbg_count as f64 * (params.e_sbg + params.e_btos_lookup)
        + sched.binary_write_count as f64 * params.e_write;
    EnergyBreakdown {
        logic: logic * passes as f64,
        preset: preset * passes as f64,
        input_init: input_init * passes as f64,
        peripheral: 0.0, // added by the architecture model
    }
}

/// Peripheral energy of the [n,m] architecture's StoB accumulation:
/// n×m local accumulator ops + n global ops per result, plus the driver
/// energy of active subarray-cycles (§4.3 / Eq 3).
pub fn peripheral_energy(
    params: &EnergyParams,
    n_groups: usize,
    m_subarrays: usize,
    results: usize,
    active_subarray_cycles: u64,
) -> f64 {
    let acc = results as f64
        * (n_groups as f64 * m_subarrays as f64 * params.e_acc_local
            + n_groups as f64 * params.e_acc_global);
    acc + active_subarray_cycles as f64 * params.e_driver_cycle
}

/// Count a breakdown per gate-kind histogram directly (used by the
/// SC-CRAM baseline model which has no Schedule).
pub fn histogram_energy(
    params: &EnergyParams,
    hist: &HashMap<GateKind, usize>,
    presets: usize,
    sbg: usize,
    writes: usize,
) -> EnergyBreakdown {
    let logic = hist
        .iter()
        .map(|(k, n)| params.gate_energy(*k) * *n as f64)
        .sum();
    EnergyBreakdown {
        logic,
        preset: presets as f64 * params.e_preset,
        input_init: sbg as f64 * params.e_sbg + writes as f64 * params.e_write,
        peripheral: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{ops, replicate::replicate};
    use crate::scheduler::algorithm1::{schedule, Options};

    #[test]
    fn gate_energies_match_paper() {
        let p = EnergyParams::default();
        assert_eq!(p.e_not, 30.7e-18);
        assert_eq!(p.e_preset, 26.1e-18);
        assert_eq!(p.gate_energy(GateKind::Maj5Inv), 6.3e-18);
    }

    #[test]
    fn multiply_energy_scales_with_lanes_and_passes() {
        let p = EnergyParams::default();
        let s64 = schedule(&replicate(&ops::multiply(), 64), &Options::default());
        let s128 = schedule(&replicate(&ops::multiply(), 128), &Options::default());
        let e64 = computation_energy(&p, &s64, 4).total();
        let e128 = computation_energy(&p, &s128, 2).total();
        // Same total work (256 bits) either way.
        assert!((e64 - e128).abs() / e64 < 1e-9, "e64={e64} e128={e128}");
    }

    #[test]
    fn breakdown_components_positive() {
        let p = EnergyParams::default();
        let s = schedule(&replicate(&ops::scaled_add(), 256), &Options::default());
        let b = computation_energy(&p, &s, 1);
        assert!(b.logic > 0.0 && b.preset > 0.0 && b.input_init > 0.0);
        let pct = b.percentages();
        assert!((pct.iter().sum::<f64>() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn op_counters_price_like_eq4() {
        let p = EnergyParams::default();
        let mut gates = [0u64; GateKind::COUNT];
        gates[GateKind::Nand.index()] = 10;
        gates[GateKind::Not.index()] = 4;
        // addie_steps are wear-only traffic — they must not change energy.
        let c = OpCounters { gates, addie_steps: 100, presets: 14, sbg_writes: 6, stob_reads: 2 };
        let e = c.energy(&p);
        assert!((e.logic - (10.0 * p.e_nand + 4.0 * p.e_not)).abs() < 1e-30);
        assert!((e.preset - 14.0 * p.e_preset).abs() < 1e-30);
        assert!((e.input_init - 6.0 * (p.e_sbg + p.e_btos_lookup)).abs() < 1e-30);
        assert!((e.peripheral - 2.0 * p.e_acc_local).abs() < 1e-30);
        assert_eq!(c.gate_total(), 14);
        assert_eq!(c.write_total(), 14 + 14 + 6 + 100);
        let mut d = c;
        d.add(&c);
        assert_eq!(d.gate_total(), 28);
        assert_eq!(d.addie_steps, 200);
    }

    #[test]
    fn peripheral_energy_formula() {
        let p = EnergyParams::default();
        let e = peripheral_energy(&p, 16, 16, 1, 0);
        let want = 256.0 * p.e_acc_local + 16.0 * p.e_acc_global;
        assert!((e - want).abs() < 1e-24);
    }
}
