//! Physical parameters of the MTJ element (paper Table 1) plus the
//! thermal-switching model constants calibrated in DESIGN.md §6.

/// MTJ device parameters. Defaults reproduce paper Table 1.
#[derive(Debug, Clone, PartialEq)]
pub struct MtjParams {
    /// Low (parallel-state) resistance, ohms. Paper: 12.7 kΩ.
    pub r_p: f64,
    /// High (anti-parallel-state) resistance, ohms. Paper: 76.3 kΩ.
    pub r_ap: f64,
    /// Tunneling magnetoresistance ratio. Paper: 500% (=(R_AP-R_P)/R_P).
    pub tmr: f64,
    /// Critical switching current, amps. Paper: 0.79 µA.
    pub i_c: f64,
    /// Deterministic switching time, seconds. Paper: 1 ns.
    pub t_switching: f64,
    /// Thermal stability factor Δ (Eq 2). Not tabulated by the paper;
    /// calibrated (DESIGN.md §6).
    pub delta: f64,
    /// Thermal attempt time τ0 at 0 K, seconds (Eq 2).
    pub tau_0: f64,
    /// Critical switching voltage V_c0, volts (Eq 2). Calibrated so that
    /// P_sw(310 mV, 4 ns) = 0.7, the anchor the paper states in §2.3.
    pub v_c0: f64,
}

impl Default for MtjParams {
    fn default() -> Self {
        Self {
            r_p: 12.7e3,
            r_ap: 76.3e3,
            tmr: 5.0,
            i_c: 0.79e-6,
            t_switching: 1e-9,
            delta: 40.0,
            tau_0: 1e-9,
            v_c0: calibrated_v_c0(40.0, 1e-9),
        }
    }
}

impl MtjParams {
    /// Average resistance seen during a stochastic switching event (the
    /// cell transits P→AP); used for SBG energy, E = V_p^2 t_p / R̄.
    pub fn r_avg(&self) -> f64 {
        0.5 * (self.r_p + self.r_ap)
    }
}

/// Solve V_c0 from the paper's anchor P_sw(V_p=310 mV, t_p=4 ns) = 0.7:
///   τ* = -t_p / ln(1 - P)   and   τ* = τ0 e^{Δ(1 - V_p/V_c0)}
///   ⇒ V_c0 = V_p / (1 - ln(τ*/τ0)/Δ)
pub fn calibrated_v_c0(delta: f64, tau_0: f64) -> f64 {
    let v_p = 0.310;
    let t_p = 4e-9;
    let p = 0.7;
    let tau_star = -t_p / (1.0 - p as f64).ln();
    v_p / (1.0 - (tau_star / tau_0).ln() / delta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults() {
        let p = MtjParams::default();
        assert_eq!(p.r_p, 12.7e3);
        assert_eq!(p.r_ap, 76.3e3);
        assert_eq!(p.i_c, 0.79e-6);
        // TMR consistency: (R_AP - R_P)/R_P ≈ 5.0 (500%)
        assert!(((p.r_ap - p.r_p) / p.r_p - p.tmr).abs() < 0.01);
    }

    #[test]
    fn v_c0_calibration_plausible() {
        let v = calibrated_v_c0(40.0, 1e-9);
        assert!(v > 0.25 && v < 0.45, "v_c0={v}");
    }
}
