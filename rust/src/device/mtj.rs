//! MTJ thermal-switching model: paper Eqs (1)–(2), the stochastic-write
//! (SBG, stochastic bit generation) pulse solver, and its energy model.
//!
//! Eq (1):  P_sw = 1 - exp(-t_p / τ)
//! Eq (2):  τ = τ0 · exp(Δ (1 - V_p / V_c0))
//!
//! The BtoS memory of the architecture (§4.3) stores, per 8-bit binary
//! value, the (V_p, t_p) pulse that switches with the matching
//! probability; `pulse_for_probability` is the generator of that table.

use super::params::MtjParams;

/// A write pulse: amplitude (V) and duration (s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pulse {
    pub v_p: f64,
    pub t_p: f64,
}

/// Characteristic switching time τ for a pulse amplitude (Eq 2).
pub fn tau(params: &MtjParams, v_p: f64) -> f64 {
    params.tau_0 * (params.delta * (1.0 - v_p / params.v_c0)).exp()
}

/// Switching probability for a pulse (Eq 1 + Eq 2).
pub fn switching_probability(params: &MtjParams, pulse: Pulse) -> f64 {
    1.0 - (-pulse.t_p / tau(params, pulse.v_p)).exp()
}

/// Invert Eq (1)–(2): amplitude that yields switching probability `p`
/// for a fixed duration `t_p`. `p` must be in (0, 1).
pub fn amplitude_for(params: &MtjParams, p: f64, t_p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "amplitude_for: p={p} out of (0,1)");
    // τ* = -t_p / ln(1-p); V_p = V_c0 (1 - ln(τ*/τ0)/Δ)
    let tau_star = -t_p / (1.0 - p).ln();
    params.v_c0 * (1.0 - (tau_star / params.tau_0).ln() / params.delta)
}

/// Energy of a stochastic write pulse, E = V_p² · t_p / R̄ (paper §5.1,
/// citing [33]); R̄ is the average resistance during the P→AP transit.
pub fn pulse_energy(params: &MtjParams, pulse: Pulse) -> f64 {
    pulse.v_p * pulse.v_p * pulse.t_p / params.r_avg()
}

/// Find the minimum-energy (V_p, t_p) pulse achieving probability `p`,
/// searching t_p over the paper's 3–10 ns range (§2.3 / Fig 3).
/// Returns the pulse and its energy in joules.
pub fn pulse_for_probability(params: &MtjParams, p: f64) -> (Pulse, f64) {
    assert!(p > 0.0 && p < 1.0, "pulse_for_probability: p={p}");
    let mut best: Option<(Pulse, f64)> = None;
    // 0.1 ns grid over [3ns, 10ns] — fine enough; energy is smooth in t_p.
    let steps = 70;
    for i in 0..=steps {
        let t_p = 3e-9 + (i as f64) * (7e-9 / steps as f64);
        let v_p = amplitude_for(params, p, t_p);
        if v_p <= 0.0 {
            continue;
        }
        let pulse = Pulse { v_p, t_p };
        let e = pulse_energy(params, pulse);
        if best.map_or(true, |(_, be)| e < be) {
            best = Some((pulse, e));
        }
    }
    best.expect("no feasible pulse")
}

/// Clamp a probability to the open interval the pulse solver accepts.
/// Exact 0 / 1 are realized without a stochastic pulse (keep preset /
/// deterministic write), so callers use this only for the stochastic path.
pub fn clamp_probability(p: f64) -> f64 {
    p.clamp(1.0 / 65536.0, 1.0 - 1.0 / 65536.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn params() -> MtjParams {
        MtjParams::default()
    }

    #[test]
    fn anchor_point_reproduced() {
        // Paper §2.3: 310 mV / 4 ns ⇒ P_sw = 0.7.
        let p = switching_probability(&params(), Pulse { v_p: 0.310, t_p: 4e-9 });
        assert!((p - 0.7).abs() < 1e-6, "p={p}");
    }

    #[test]
    fn probability_monotone_in_amplitude() {
        let ps = params();
        let mut last = 0.0;
        for i in 1..40 {
            let v = 0.20 + i as f64 * 0.005;
            let p = switching_probability(&ps, Pulse { v_p: v, t_p: 5e-9 });
            assert!(p >= last, "non-monotone at v={v}");
            last = p;
        }
    }

    #[test]
    fn probability_monotone_in_duration() {
        let ps = params();
        let mut last = 0.0;
        for i in 3..=10 {
            let t = i as f64 * 1e-9;
            let p = switching_probability(&ps, Pulse { v_p: 0.3, t_p: t });
            assert!(p >= last);
            last = p;
        }
    }

    #[test]
    fn amplitude_for_inverts_probability() {
        forall(0xA11CE, 200, |g| {
            let p = g.f64_in(0.01, 0.99);
            let t_p = g.f64_in(3e-9, 10e-9);
            let v = amplitude_for(&params(), p, t_p);
            let back = switching_probability(&params(), Pulse { v_p: v, t_p });
            assert!((back - p).abs() < 1e-9, "p={p} back={back}");
        });
    }

    #[test]
    fn optimal_pulse_achieves_target() {
        for &p in &[0.1, 0.3, 0.5, 0.7, 0.9] {
            let (pulse, e) = pulse_for_probability(&params(), p);
            let got = switching_probability(&params(), pulse);
            assert!((got - p).abs() < 1e-9);
            assert!(e > 0.0);
            assert!(pulse.t_p >= 3e-9 && pulse.t_p <= 10e-9);
        }
    }

    #[test]
    fn optimal_pulse_energy_is_femto_scale() {
        // V≈0.31V, t≈3ns, R̄≈44.5kΩ ⇒ E ≈ 0.31²·3e-9/4.45e4 ≈ 6.4 fJ.
        // (The *accounting* E_SBG is a calibrated aJ-scale constant —
        // see DESIGN.md §6; this physical value drives Fig 3 only.)
        let (pulse, e) = pulse_for_probability(&params(), 0.5);
        assert!(e > 1e-16 && e < 1e-13, "e={e}");
        assert!(pulse.t_p <= 4e-9, "optimizer should favour short pulses");
    }

    #[test]
    fn clamp_probability_bounds() {
        assert!(clamp_probability(0.0) > 0.0);
        assert!(clamp_probability(1.0) < 1.0);
        assert_eq!(clamp_probability(0.5), 0.5);
    }
}
