//! Binary-IMC implementations of the six Table 2 arithmetic operations,
//! at the paper's 8-bit fixed-point resolution (§5.1): ripple-carry
//! addition, Wallace multiplication, full subtraction, non-restoring
//! division, three Newton–Raphson square-root steps, and the 5th-order
//! Maclaurin exponential.

use crate::netlist::binary::BinaryBuilder;
use crate::netlist::Netlist;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinaryOp {
    Add,
    Multiply,
    Subtract,
    Divide,
    Sqrt,
    Exp,
}

impl BinaryOp {
    pub const ALL: [BinaryOp; 6] = [
        BinaryOp::Add,
        BinaryOp::Multiply,
        BinaryOp::Subtract,
        BinaryOp::Divide,
        BinaryOp::Sqrt,
        BinaryOp::Exp,
    ];

    pub fn name(self) -> &'static str {
        match self {
            BinaryOp::Add => "scaled_addition",
            BinaryOp::Multiply => "multiplication",
            BinaryOp::Subtract => "abs_subtraction",
            BinaryOp::Divide => "scaled_division",
            BinaryOp::Sqrt => "square_root",
            BinaryOp::Exp => "exponential",
        }
    }
}

/// Build the 8-bit binary netlist of an operation. `row_budget` caps the
/// rows the builder spreads over (bit-significance layout).
pub fn binary_op_netlist(op: BinaryOp, bits: usize, row_budget: usize) -> Netlist {
    let mut b = BinaryBuilder::new(row_budget);
    match op {
        BinaryOp::Add => {
            let wa = b.input_word("a", bits, true);
            let wb = b.input_word("b", bits, true);
            let cin = b.const0();
            let (sum, cout) = b.adder(&wa, &wb, cin);
            for (k, bit) in sum.bits.iter().enumerate() {
                b.nl.mark_output(&format!("s{k}"), bit.id);
            }
            b.nl.mark_output("cout", cout.id);
        }
        BinaryOp::Multiply => {
            let wa = b.input_word("a", bits, false);
            let wb = b.input_word("b", bits, false);
            let p = b.multiplier(&wa, &wb);
            for (k, bit) in p.bits.iter().enumerate() {
                b.nl.mark_output(&format!("p{k}"), bit.id);
            }
        }
        BinaryOp::Subtract => {
            let wa = b.input_word("a", bits, false);
            let wb = b.input_word("b", bits, false);
            let (d, _) = b.subtractor(&wa, &wb);
            for (k, bit) in d.bits.iter().enumerate() {
                b.nl.mark_output(&format!("d{k}"), bit.id);
            }
        }
        BinaryOp::Divide => {
            let wa = b.input_word("a", bits, false);
            let wd = b.input_word("d", bits, false);
            let q = b.divider(&wa, &wd);
            for (k, bit) in q.bits.iter().enumerate() {
                b.nl.mark_output(&format!("q{k}"), bit.id);
            }
        }
        BinaryOp::Sqrt => {
            let wa = b.input_word("a", bits, false);
            let s = b.sqrt_newton(&wa);
            for (k, bit) in s.bits.iter().enumerate() {
                b.nl.mark_output(&format!("s{k}"), bit.id);
            }
        }
        BinaryOp::Exp => {
            let wx = b.input_word("x", bits, false);
            let e = b.exp_maclaurin(&wx, 1.0);
            for (k, bit) in e.bits.iter().enumerate() {
                b.nl.mark_output(&format!("e{k}"), bit.id);
            }
        }
    }
    b.nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::algorithm1::{schedule, Options};

    #[test]
    fn complexity_ordering_matches_paper() {
        // Table 2's binary column: add ≪ mult ≪ exp < sqrt in cost.
        let cycles = |op| {
            let nl = binary_op_netlist(op, 8, 32);
            schedule(&nl, &Options::default()).logic_cycles()
        };
        let add = cycles(BinaryOp::Add);
        let mul = cycles(BinaryOp::Multiply);
        let div = cycles(BinaryOp::Divide);
        let sqrt = cycles(BinaryOp::Sqrt);
        let exp = cycles(BinaryOp::Exp);
        assert!(add < mul && add < div, "add={add} mul={mul} div={div}");
        assert!(mul < sqrt && div < sqrt, "mul={mul} div={div} sqrt={sqrt}");
        assert!(exp > mul, "exp={exp} mul={mul}");
    }

    #[test]
    fn adder_8bit_is_17_cycles() {
        // 2(n−1)+3 for even n (paper §4.1): 8-bit ⇒ 17.
        let nl = binary_op_netlist(BinaryOp::Add, 8, 8);
        let s = schedule(&nl, &Options::default());
        assert_eq!(s.logic_cycles(), 17, "got {}", s.logic_cycles());
    }

    #[test]
    fn adder_4bit_is_9_cycles_fig7() {
        let nl = binary_op_netlist(BinaryOp::Add, 4, 4);
        let s = schedule(&nl, &Options::default());
        assert_eq!(s.logic_cycles(), 9, "Fig 7a: got {}", s.logic_cycles());
    }

    #[test]
    fn sqrt_and_exp_are_the_largest_circuits() {
        // Paper Table 2: sqrt (32×1413) and exp (17×1255) dwarf the rest.
        let sizes: Vec<usize> = BinaryOp::ALL
            .iter()
            .map(|&op| binary_op_netlist(op, 8, 32).gate_count())
            .collect();
        for i in 0..4 {
            assert!(sizes[4] > 4 * sizes[i], "sizes={sizes:?}");
            assert!(sizes[5] > 4 * sizes[i], "sizes={sizes:?}");
        }
    }
}
