//! S9 — model of the in-memory SC baseline, SC-CRAM [22] (Zink et al.).
//!
//! Characteristics the paper attributes to [22] (§3, §5):
//! * bit-serial: the per-bit stochastic circuit is repeated BL times in
//!   a *single* subarray — no bit-parallel rows;
//! * no result-storage / StoB mechanism (the paper notes one "has not
//!   been provided"), so no accumulator energy or steps are charged;
//! * the same per-bit circuit implementation as Stoch-IMC (the paper
//!   says the per-bit energies "may be in the same order").
//!
//! Cell reuse across bits concentrates write traffic on the one circuit
//! footprint — the cause of the ~216× lifetime gap in Fig 11.

use crate::energy::{histogram_energy, EnergyBreakdown, EnergyParams};
use crate::lifetime::WearProfile;
use crate::netlist::graph::{InputClass, Netlist, Node};
use crate::scheduler::algorithm1::{schedule, Options, ADDIE_CYCLES};

/// Cost summary of SC-CRAM executing one circuit over a workload.
#[derive(Debug, Clone, PartialEq)]
pub struct ScCramCost {
    pub cycles: u64,
    pub energy: EnergyBreakdown,
    pub used_cells: u64,
    pub min_subarray: (usize, usize),
    pub wear: WearProfile,
}

/// Cost `instances` runs of the single-lane circuit at bitstream length
/// `bl`, executed bit-serially.
pub fn run(
    energy: &EnergyParams,
    base: &Netlist,
    bl: u64,
    instances: u64,
) -> ScCramCost {
    // Schedule the single-lane circuit once; repeat per bit.
    let sched = schedule(base, &Options::default());
    let per_bit_logic = sched.logic_cycles() as u64;
    // Per bit: preset pass + stochastic init of the input cells + logic.
    let per_bit = 1 + 1 + per_bit_logic;
    let cycles = per_bit * bl * instances;

    let mut hist = sched.op_histogram();
    // ADDIE lanes appear once here (single lane).
    let _ = ADDIE_CYCLES;
    for n in hist.values_mut() {
        *n *= (bl * instances) as usize;
    }
    let sbg_cells = base
        .nodes
        .iter()
        .filter(|n| {
            matches!(
                n,
                Node::Input { class: InputClass::Stochastic, .. }
                    | Node::Input { class: InputClass::Correlated(_), .. }
                    | Node::Input { class: InputClass::ConstStream, .. }
            )
        })
        .count() as u64;
    let presets = (sched.preset_count() as u64) * bl * instances;
    let e = histogram_energy(
        energy,
        &hist,
        presets as usize,
        (sbg_cells * bl * instances) as usize,
        0,
    );

    let used = sched.used_cells() as u64;
    // Every bit reuses the same cells: the hottest cell (the output of
    // the deepest gate) is written twice (preset+logic) per bit.
    let wear = WearProfile {
        used_cells: used,
        writes: sched.write_traffic().values().sum::<u64>() * bl * instances,
        max_cell_writes: 2 * bl * instances,
    };
    ScCramCost {
        cycles,
        energy: e,
        used_cells: used,
        min_subarray: sched.min_array(),
        wear,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::ops;

    #[test]
    fn bit_serial_cycles_scale_with_bl() {
        let e = EnergyParams::default();
        let c256 = run(&e, &ops::multiply(), 256, 1);
        let c512 = run(&e, &ops::multiply(), 512, 1);
        assert_eq!(c512.cycles, 2 * c256.cycles);
        // multiply: 2 logic + 2 init/preset per bit = 4×256.
        assert_eq!(c256.cycles, 4 * 256);
    }

    #[test]
    fn footprint_is_single_lane() {
        let e = EnergyParams::default();
        let c = run(&e, &ops::multiply(), 256, 1);
        assert_eq!(c.min_subarray, (1, 4)); // Table 2: [22] mult = 1×4
        assert_eq!(c.used_cells, 4);
    }

    #[test]
    fn wear_concentrates_on_reused_cells() {
        let e = EnergyParams::default();
        let c = run(&e, &ops::scaled_add(), 256, 1);
        assert_eq!(c.wear.max_cell_writes, 512);
        assert_eq!(c.wear.used_cells, 7);
    }
}
