//! S5/S9 — baselines: the binary-IMC cost builders (over
//! `netlist::binary`) and the SC-CRAM [22] bit-serial model.

pub mod binary_ops;
pub mod sc_cram;

pub use binary_ops::{binary_op_netlist, BinaryOp};
pub use sc_cram::{run as run_sc_cram, ScCramCost};
