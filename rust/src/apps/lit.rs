//! Local image thresholding (LIT, Fig 9a / Eqs 5–6 — Sauvola [38]):
//! for each window, T = mean(A) × (σ_A + 1)/2 with
//! σ_A = sqrt(|mean(A²) − mean(A)²|).
//!
//! Window substitution (DESIGN.md §2): we use 8×8 (64-pixel) windows
//! instead of the paper's 9×9 so the MUX mean tree is exact
//! (power-of-two fan-in); the circuit structure is otherwise Fig 9a's.
//!
//! Staging (DESIGN.md §7): the |mean(A²) − mean(A)²| subtraction needs
//! *correlated* operands, and the √ integrator needs two independent
//! copies of its operand — both intermediate values. The architecture's
//! StoB accumulators + BtoS memory regenerate streams between stages:
//!   stage 1: mean tree, mean² (two independent mean trees ANDed),
//!            mean-of-squares tree (squares = AND of two pixel copies);
//!   stage 2: correlated regeneration → XOR → σ²;
//!   stage 3: two independent regenerations → ADDIE √ → scaled-add with
//!            the all-ones stream → AND with regenerated mean ⇒ T.

use super::{bindings_from, bq, flip, mean_tree, mean_tree_netlist, out_idx, App, Instance};
use crate::netlist::graph::InputClass;
use crate::netlist::ops::{and_rel, mux_into, sqrt_into, xor_into, ADDIE_BITS_APP};
use crate::netlist::{Binding, Netlist, StagedPlan};
use crate::sc::bitstream::Bitstream;
use crate::sc::encode::encode_correlated;
use crate::sc::ops as sc_ops;
use crate::util::prng::Xoshiro256;

pub struct Lit {
    /// Window side (8 ⇒ 64 pixels).
    pub side: usize,
    /// Synthetic image side used for the workload.
    pub image_side: usize,
}

impl Default for Lit {
    fn default() -> Self {
        Self { side: 8, image_side: 64 }
    }
}

impl Lit {
    pub fn pixels(&self) -> usize {
        self.side * self.side
    }

    /// Synthetic degraded-document image: dark strokes on a bright,
    /// unevenly-lit background with additive noise (values in [0,1]).
    pub fn synth_image(&self, seed: u64) -> Vec<f64> {
        let n = self.image_side;
        let mut rng = Xoshiro256::seeded(seed);
        let mut img = vec![0.0; n * n];
        for y in 0..n {
            for x in 0..n {
                // Illumination gradient + vignette.
                let fx = x as f64 / n as f64;
                let fy = y as f64 / n as f64;
                let illum = 0.55 + 0.35 * fx - 0.15 * fy;
                img[y * n + x] = (illum + 0.06 * (rng.next_f64() - 0.5)).clamp(0.0, 1.0);
            }
        }
        // Strokes: dark horizontal/vertical runs ("text").
        for _ in 0..(n * n / 48) {
            let x0 = rng.next_index(n);
            let y0 = rng.next_index(n);
            let len = 3 + rng.next_index(6);
            let horiz = rng.bernoulli(0.5);
            for k in 0..len {
                let (x, y) = if horiz { (x0 + k, y0) } else { (x0, y0 + k) };
                if x < n && y < n {
                    img[y * n + x] = (0.08 + 0.08 * rng.next_f64()).clamp(0.0, 1.0);
                }
            }
        }
        img
    }

    /// Compile the three-stage LIT pipeline into a [`StagedPlan`] the
    /// word-parallel engine runs lane-major end to end: the
    /// [`App::stoch_cost_netlists`] stages wired through StoB→BtoS
    /// regeneration edges. Stage 1 samples four independent copies of
    /// every pixel (x/y for the two mean trees, u/v for the squares)
    /// and accumulates mean, mean², and mean-of-squares; stage 2
    /// regenerates the latter two *correlated* and XORs them into σ²;
    /// stage 3 regenerates σ² twice for the ADDIE √, folds in the
    /// all-ones stream via the (σ+1)/2 MUX, and ANDs with a
    /// regenerated mean ⇒ T. All MUX selects are 0.5-valued constant
    /// streams. The value model matches [`App::stoch_value`]
    /// statistically (identical circuit structure); the bit-level
    /// contract is the staged reference
    /// ([`StagedPlan::eval_row_scalar`]).
    pub fn staged_plan(&self) -> StagedPlan {
        let mut stages = self.stoch_cost_netlists();
        let s3 = stages.pop().expect("LIT stage 3");
        let s2 = stages.pop().expect("LIT stage 2");
        let s1 = stages.pop().expect("LIT stage 1");
        // Stage-1 names: x/y/u/v{pixel} are the four independent pixel
        // copies, s{k} the tree selects.
        let b1 = bindings_from(&s1, |name| match name.as_bytes()[0] {
            b'x' | b'y' | b'u' | b'v' => {
                Binding::Input(name[1..].parse().expect("pixel index"))
            }
            b's' => Binding::Const(0.5),
            // Mean-tree zero pads (only for non-power-of-two windows).
            b'z' => Binding::Const(0.0),
            _ => unreachable!("unknown LIT stage-1 input `{name}`"),
        });
        let mean = out_idx(&s1, "out");
        let mean2sq = out_idx(&s1, "mean2sq");
        let meansq = out_idx(&s1, "meansq");
        let b2 = bindings_from(&s2, |name| match name {
            "meansq" => Binding::Regen { stage: 0, output: meansq },
            "mean2sq" => Binding::Regen { stage: 0, output: mean2sq },
            _ => unreachable!("unknown LIT stage-2 input `{name}`"),
        });
        let var = out_idx(&s2, "var");
        let b3 = bindings_from(&s3, |name| match name {
            "var1" | "var2" => Binding::Regen { stage: 1, output: var },
            "ones" => Binding::Const(1.0),
            "sel" => Binding::Const(0.5),
            "mean" => Binding::Regen { stage: 0, output: mean },
            _ => unreachable!("unknown LIT stage-3 input `{name}`"),
        });
        StagedPlan::compile(self.pixels(), vec![(s1, b1), (s2, b2), (s3, b3)], "t")
            .expect("LIT staged plan compiles")
    }
}

impl App for Lit {
    fn name(&self) -> &'static str {
        "lit"
    }

    /// Instances are image windows (non-overlapping tiling of the
    /// synthetic image, wrapping when more are requested).
    fn workload(&self, n: usize, seed: u64) -> Vec<Instance> {
        let img = self.synth_image(seed);
        let tiles = self.image_side / self.side;
        let mut out = Vec::with_capacity(n);
        for k in 0..n {
            let t = k % (tiles * tiles);
            let (tx, ty) = (t % tiles, t / tiles);
            let mut w = Vec::with_capacity(self.pixels());
            for dy in 0..self.side {
                for dx in 0..self.side {
                    let x = tx * self.side + dx;
                    let y = ty * self.side + dy;
                    w.push(img[y * self.image_side + x]);
                }
            }
            out.push(w);
        }
        out
    }

    fn float_ref(&self, x: &[f64]) -> f64 {
        let n = x.len() as f64;
        let mean = x.iter().sum::<f64>() / n;
        let mean_sq = x.iter().map(|v| v * v).sum::<f64>() / n;
        let sigma = (mean_sq - mean * mean).abs().sqrt();
        mean * (sigma + 1.0) / 2.0
    }

    fn stoch_value(&self, x: &[f64], bl: usize, rng: &mut Xoshiro256, fr: f64) -> f64 {
        // ---- Stage 1: in-array trees.
        let sample_set = |rng: &mut Xoshiro256| -> Vec<Bitstream> {
            x.iter().map(|&v| Bitstream::sample(v, bl, rng)).collect()
        };
        let set1 = sample_set(rng);
        let set2 = sample_set(rng);
        let set3 = sample_set(rng);
        let set4 = sample_set(rng);
        // Fault injection follows the paper's model: at the I/O nodes of
        // the arithmetic *operations* (mean, multiply, subtract, sqrt,
        // add), not at every internal tree level.
        let mean1 = flip(&mean_tree(&set1, bl, rng, 0.0), fr, rng);
        let mean2 = flip(&mean_tree(&set2, bl, rng, 0.0), fr, rng);
        // squares from two further independent copies.
        let squares: Vec<Bitstream> = set3
            .iter()
            .zip(&set4)
            .map(|(a, b)| sc_ops::multiply(a, b))
            .collect();
        let mean_sq = flip(&mean_tree(&squares, bl, rng, 0.0), fr, rng);
        let mean2sq = flip(&sc_ops::multiply(&mean1, &mean2), fr, rng);
        // StoB: accumulate stage-1 results.
        let v_mean = mean1.value();
        let v_meansq = mean_sq.value();
        let v_mean2 = mean2sq.value();

        // ---- Stage 2: correlated regeneration → |σ²|.
        let corr = encode_correlated(&[v_meansq, v_mean2], bl, rng);
        let var = flip(&sc_ops::abs_subtract_correlated(&corr[0], &corr[1]), fr, rng);
        let v_var = var.value();

        // ---- Stage 3: √ then T = mean·(σ+1)/2.
        let a1 = flip(&Bitstream::sample(v_var, bl, rng), fr, rng);
        let a2 = flip(&Bitstream::sample(v_var, bl, rng), fr, rng);
        let sigma = flip(&sc_ops::square_root_with(&a1, &a2, ADDIE_BITS_APP, 0x11F7), fr, rng);
        let ones = Bitstream::ones(bl);
        let sel = Bitstream::sample(0.5, bl, rng);
        let half = flip(&sc_ops::scaled_add(&sigma, &ones, &sel), fr, rng);
        let mean_r = flip(&Bitstream::sample(v_mean, bl, rng), fr, rng);
        let t = flip(&sc_ops::multiply(&mean_r, &half), fr, rng);
        t.value()
    }

    fn binary_value(&self, x: &[f64], bits: u32, rng: &mut Xoshiro256, fr: f64) -> f64 {
        // Quantize after every arithmetic step (bit-exact circuit model).
        let n = x.len() as f64;
        let mut sum = 0.0;
        let mut sum_sq = 0.0;
        for &v in x {
            let q = bq(v, bits, fr, rng);
            sum += q;
            sum_sq += bq(q * q, bits, fr, rng);
        }
        let mean = bq(sum / n, bits, fr, rng);
        let mean_sq = bq(sum_sq / n, bits, fr, rng);
        let m2 = bq(mean * mean, bits, fr, rng);
        let var = bq((mean_sq - m2).abs(), bits, fr, rng);
        let sigma = bq(var.sqrt(), bits, fr, rng);
        bq(mean * (sigma + 1.0) / 2.0, bits, fr, rng)
    }

    fn stoch_cost_netlists(&self) -> Vec<Netlist> {
        let p = self.pixels();
        // Stage 1: two mean trees over p inputs + squares tree.
        let mut s1 = mean_tree_netlist(p);
        {
            // second mean tree + squares + mean² inside the same stage.
            let ins2: Vec<_> = (0..p)
                .map(|i| s1.input(&format!("y{i}"), 0, 1, InputClass::Stochastic))
                .collect();
            let mut level = ins2;
            let mut sel = 1000usize;
            while level.len() > 1 {
                let mut next = Vec::new();
                for pair in level.chunks(2) {
                    let s = s1.input(&format!("s{sel}"), 0, 1, InputClass::ConstStream);
                    sel += 1;
                    next.push(mux_into(&mut s1, s, pair[0], pair[1]));
                }
                level = next;
            }
            let mean2 = level.pop().unwrap();
            // squares tree over ANDs of further copies.
            let mut sq = Vec::new();
            for i in 0..p {
                let a = s1.input(&format!("u{i}"), 0, 1, InputClass::Stochastic);
                let b = s1.input(&format!("v{i}"), 0, 1, InputClass::Stochastic);
                sq.push(and_rel(&mut s1, a, b));
            }
            let mut level = sq;
            while level.len() > 1 {
                let mut next = Vec::new();
                for pair in level.chunks(2) {
                    let s = s1.input(&format!("s{sel}"), 0, 1, InputClass::ConstStream);
                    sel += 1;
                    next.push(mux_into(&mut s1, s, pair[0], pair[1]));
                }
                level = next;
            }
            let meansq = level.pop().unwrap();
            let m1 = s1.outputs[0].1;
            let m2sq = and_rel(&mut s1, m1, mean2);
            s1.mark_output("mean2sq", m2sq);
            s1.mark_output("meansq", meansq);
        }
        // Stage 2: correlated XOR.
        let mut s2 = Netlist::new();
        let a = s2.input("meansq", 0, 1, InputClass::Correlated(0));
        let b = s2.input("mean2sq", 0, 1, InputClass::Correlated(0));
        let var = xor_into(&mut s2, a, b);
        s2.mark_output("var", var);
        // Stage 3: √, (σ+1)/2, ×mean.
        let mut s3 = Netlist::new();
        let a1 = s3.input("var1", 0, 1, InputClass::Stochastic);
        let a2 = s3.input("var2", 0, 1, InputClass::Stochastic);
        let sigma = sqrt_into(&mut s3, a1, a2, ADDIE_BITS_APP);
        let ones = s3.input("ones", 0, 1, InputClass::ConstStream);
        let sel = s3.input("sel", 0, 1, InputClass::ConstStream);
        let half = mux_into(&mut s3, sel, sigma, ones);
        let mean_r = s3.input("mean", 0, 1, InputClass::Stochastic);
        let t = and_rel(&mut s3, mean_r, half);
        s3.mark_output("t", t);
        vec![s1, s2, s3]
    }

    fn binary_cost_netlist(&self) -> Netlist {
        // Scaled-down representative circuit: a 16-pixel window with the
        // full pipeline (sum trees, squares, sqrt, final multiply). The
        // Table 3 bench scales counts to the full window analytically —
        // scheduling the full 64-pixel binary netlist (≈100k gates) is
        // possible but needlessly slow for a cost model that is linear
        // in the tree sizes.
        let p = 16usize;
        let mut b = crate::netlist::binary::BinaryBuilder::new(64);
        let words: Vec<_> = (0..p).map(|i| b.input_word(&format!("x{i}"), 8, false)).collect();
        // Sum tree (widths grow by 1 per level).
        let mut level = words.clone();
        while level.len() > 1 {
            let mut next = Vec::new();
            for pair in level.chunks(2) {
                let z = b.const0();
                let mut a = pair[0].clone();
                let mut c = pair[1].clone();
                a.bits.push(z);
                c.bits.push(z);
                let (s, _) = b.adder(&a, &c, z);
                next.push(s);
            }
            level = next;
        }
        let mean = level.pop().unwrap().slice(4, 12); // /16 ⇒ Q0.8
        // Squares + their sum tree.
        let mut sq = Vec::new();
        for w in &words {
            sq.push(b.fixmul(w, w, 8));
        }
        let mut level = sq;
        while level.len() > 1 {
            let mut next = Vec::new();
            for pair in level.chunks(2) {
                let z = b.const0();
                let mut a = pair[0].clone();
                let mut c = pair[1].clone();
                a.bits.push(z);
                c.bits.push(z);
                let (s, _) = b.adder(&a, &c, z);
                next.push(s);
            }
            level = next;
        }
        let mean_sq = level.pop().unwrap().slice(4, 12);
        let m2 = b.fixmul(&mean, &mean, 8);
        let (var, _) = b.subtractor(&mean_sq, &m2);
        let sigma = b.sqrt_newton(&var);
        let t = b.fixmul(&mean, &sigma, 8);
        for (k, bit) in t.bits.iter().enumerate() {
            b.nl.mark_output(&format!("o{k}"), bit.id);
        }
        b.nl
    }

    fn binary_cost_scale(&self) -> f64 {
        // Representative slice uses 16 pixels; trees/mults scale
        // linearly in pixel count.
        self.pixels() as f64 / 16.0
    }

    fn eval_instances(&self) -> usize {
        (self.image_side / self.side).pow(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stochastic_tracks_float() {
        let app = Lit::default();
        let windows = app.workload(4, 11);
        for w in &windows {
            let mut rng = Xoshiro256::seeded(21);
            let s = app.stoch_value(w, 4096, &mut rng, 0.0);
            let f = app.float_ref(w);
            assert!((s - f).abs() < 0.08, "s={s} f={f}");
        }
    }

    #[test]
    fn binary_tracks_float() {
        let app = Lit::default();
        let windows = app.workload(4, 13);
        let mut rng = Xoshiro256::seeded(1);
        for w in &windows {
            let b = app.binary_value(w, 8, &mut rng, 0.0);
            let f = app.float_ref(w);
            assert!((b - f).abs() < 0.03, "b={b} f={f}");
        }
    }

    #[test]
    fn synth_image_has_contrast() {
        let app = Lit::default();
        let img = app.synth_image(5);
        let lo = img.iter().cloned().fold(1.0f64, f64::min);
        let hi = img.iter().cloned().fold(0.0f64, f64::max);
        assert!(lo < 0.2 && hi > 0.6, "lo={lo} hi={hi}");
    }

    #[test]
    fn three_stages() {
        let app = Lit::default();
        let stages = app.stoch_cost_netlists();
        assert_eq!(stages.len(), 3);
        // Stage 1 dominates: two 64-input mean trees + 64 squares.
        assert!(stages[0].gate_count() > 400);
        assert_eq!(stages[1].gate_count(), 5); // XOR
    }

    #[test]
    fn staged_plan_shape() {
        let app = Lit::default();
        let plan = app.staged_plan();
        assert_eq!(plan.stages().len(), 3);
        assert_eq!(plan.n_inputs(), app.pixels());
        // Stage 1 binds four independent copies of every pixel plus the
        // tree selects; stage 2 is the two regenerated correlated
        // operands; stage 3 regenerates var twice and mean once.
        assert!(plan.stages()[0].bindings.len() > 4 * app.pixels());
        assert_eq!(plan.stages()[1].bindings.len(), 2);
        assert_eq!(plan.stages()[2].bindings.len(), 5);
        let regen = |s: usize| {
            plan.stages()[s]
                .bindings
                .iter()
                .filter(|b| matches!(b, Binding::Regen { .. }))
                .count()
        };
        assert_eq!(regen(0), 0);
        assert_eq!(regen(1), 2);
        assert_eq!(regen(2), 3);
    }

    #[test]
    fn staged_reference_tracks_float() {
        // The staged-netlist scalar reference (the engine's bit-level
        // contract) approximates the same Sauvola threshold as
        // stoch_value, just with the netlist stage structure.
        let app = Lit::default();
        let plan = app.staged_plan();
        let windows = app.workload(2, 17);
        for (k, w) in windows.iter().enumerate() {
            let mut rng = Xoshiro256::seeded(31 + k as u64);
            let s = plan.eval_row_scalar(w, 4096, &mut rng);
            let f = app.float_ref(w);
            assert!((s - f).abs() < 0.1, "window {k}: staged={s} float={f}");
        }
    }
}
