//! Kernel density estimation (KDE, Fig 9d / Eq 10 — [37]): per pixel,
//!   PDF(X_t) = (1/N) Σ_{i=1..N} e^{−4|X_t − X_{t−i}|}
//! over an N-frame history. e^{−4x} exceeds unipolar range at c=4, so —
//! exactly as the paper does (§5.3.2) — it is computed as the product of
//! five e^{−(4/5)x} stages, each the 5th-order Maclaurin circuit.
//!
//! Staging: |X_t − X_{t−i}| is a correlated XOR of *primary inputs*
//! (stage 1, pure in-array); each exponential stage needs five
//! independent copies of d_i, provided by StoB→BtoS regeneration
//! (stage 2), as in LIT.

use super::{bindings_from, bq, flip, mean_tree, out_idx, App, Instance};
use crate::netlist::graph::InputClass;
use crate::netlist::ops::{and_rel, exp_constants, exp_into, xor_into};
use crate::netlist::{Binding, Netlist, StagedPlan};
use crate::sc::bitstream::Bitstream;
use crate::sc::encode::encode_correlated;
use crate::sc::ops as sc_ops;
use crate::util::prng::Xoshiro256;

pub struct Kde {
    /// History depth N.
    pub history: usize,
    /// Exponent constant (4 in Eq 10), factored as 5 stages of c/5.
    pub c: f64,
}

impl Default for Kde {
    fn default() -> Self {
        Self { history: 8, c: 4.0 }
    }
}

impl Kde {
    /// The 5th-order Maclaurin value of e^{−cx} (the circuit's target —
    /// baseline approximation error shows against the true exponential).
    fn maclaurin(c: f64, x: f64) -> f64 {
        let u = c * x;
        1.0 - u * (1.0 - (u / 2.0) * (1.0 - (u / 3.0) * (1.0 - (u / 4.0) * (1.0 - u / 5.0))))
    }

    /// Compile the two-stage KDE pipeline into a [`StagedPlan`] the
    /// word-parallel engine runs lane-major end to end. Stage 1 is the
    /// pure in-array part: one correlated XOR per history frame
    /// (d_i = |X_t − X_{t−i}|, groups 0..N−1 each sharing uniforms
    /// between the X_t and X_{t−i} copies). Stage 2 regenerates five
    /// independent copies of each d_i per exponential instance
    /// (StoB→BtoS), feeds the 5-stage e^{−(c/5)d} Maclaurin product
    /// chains, and means the N frames through the MUX tree. The value
    /// model matches [`App::stoch_value`] statistically; the engine's
    /// bit-level contract is the staged reference
    /// ([`StagedPlan::eval_row_scalar`]) — `stoch_value` interleaves
    /// its draws per frame, the staged pipeline per stage.
    pub fn staged_plan(&self) -> StagedPlan {
        let mut stages = self.stoch_cost_netlists();
        let s2 = stages.pop().expect("KDE stage 2");
        let s1 = stages.pop().expect("KDE stage 1");
        let b1 = bindings_from(&s1, |name| {
            if name.starts_with("xt_") {
                Binding::Input(0)
            } else if let Some(i) =
                name.strip_prefix("xh_").and_then(|s| s.parse::<usize>().ok())
            {
                Binding::Input(i + 1)
            } else {
                unreachable!("unknown KDE stage-1 input `{name}`")
            }
        });
        let consts = exp_constants(self.c / 5.0);
        let d_out: Vec<usize> =
            (0..self.history).map(|i| out_idx(&s1, &format!("d{i}"))).collect();
        // Stage-2 names: d{i}_{s}_{k} = copy k of frame i's distance in
        // exponential instance s; c{i}_{s}_{k} = the C_k constant;
        // sel{j} = mean-tree selects.
        let b2 = bindings_from(&s2, |name| {
            if let Some(rest) = name.strip_prefix('d') {
                let i = rest
                    .split('_')
                    .next()
                    .and_then(|s| s.parse::<usize>().ok())
                    .expect("frame index");
                Binding::Regen { stage: 0, output: d_out[i] }
            } else if name.starts_with("sel") {
                Binding::Const(0.5)
            } else if let Some(rest) = name.strip_prefix('c') {
                let k = rest
                    .rsplit('_')
                    .next()
                    .and_then(|s| s.parse::<usize>().ok())
                    .expect("constant index");
                Binding::Const(consts[k])
            } else {
                unreachable!("unknown KDE stage-2 input `{name}`")
            }
        });
        StagedPlan::compile(self.history + 1, vec![(s1, b1), (s2, b2)], "pdf")
            .expect("KDE staged plan compiles")
    }
}

impl App for Kde {
    fn name(&self) -> &'static str {
        "kde"
    }

    /// Instance = [X_t, X_{t−1}, ..., X_{t−N}]: a pixel's recent history
    /// — a slowly varying background value with occasional foreground
    /// jumps (the surveillance scenario KDE background-modeling serves).
    fn workload(&self, n: usize, seed: u64) -> Vec<Instance> {
        let mut rng = Xoshiro256::seeded(seed);
        (0..n)
            .map(|_| {
                let background = 0.2 + 0.6 * rng.next_f64();
                let mut hist = Vec::with_capacity(self.history + 1);
                let mut v = background;
                for _ in 0..=self.history {
                    // AR(1)-style drift + rare foreground object.
                    v = (0.9 * v + 0.1 * background + 0.04 * (rng.next_f64() - 0.5))
                        .clamp(0.0, 1.0);
                    let sample =
                        if rng.bernoulli(0.08) { (v + 0.5).min(1.0) } else { v };
                    hist.push(sample);
                }
                hist
            })
            .collect()
    }

    fn float_ref(&self, x: &[f64]) -> f64 {
        let xt = x[0];
        let n = self.history as f64;
        x[1..=self.history]
            .iter()
            .map(|&xi| (-self.c * (xt - xi).abs()).exp())
            .sum::<f64>()
            / n
    }

    fn stoch_value(&self, x: &[f64], bl: usize, rng: &mut Xoshiro256, fr: f64) -> f64 {
        let xt = x[0];
        let cs = self.c / 5.0;
        let mut frame_streams = Vec::with_capacity(self.history);
        for i in 1..=self.history {
            // Stage 1: correlated XOR of the two primary inputs.
            let corr = encode_correlated(&[xt, x[i]], bl, rng);
            let d = flip(&sc_ops::abs_subtract_correlated(&corr[0], &corr[1]), fr, rng);
            let v_d = d.value(); // StoB

            // Stage 2: five e^{−(c/5)d} instances (each over 5 fresh
            // copies of d), multiplied together.
            let mut prod: Option<Bitstream> = None;
            for _ in 0..5 {
                let copies = sc_ops::independent_copies(v_d, bl, rng);
                let consts = sc_ops::exp_constant_streams(cs, bl, rng);
                let e = flip(&sc_ops::exponential(&copies, &consts), fr, rng);
                prod = Some(match prod {
                    None => e,
                    Some(p) => flip(&sc_ops::multiply(&p, &e), fr, rng),
                });
            }
            frame_streams.push(prod.unwrap());
        }
        // Mean over the N frames (MUX tree; N is a power of two here).
        // Injection at the op output, not per tree level (paper model).
        flip(&mean_tree(&frame_streams, bl, rng, 0.0), fr, rng).value()
    }

    fn binary_value(&self, x: &[f64], bits: u32, rng: &mut Xoshiro256, fr: f64) -> f64 {
        let xt = bq(x[0], bits, fr, rng);
        let cs = self.c / 5.0;
        let mut sum = 0.0;
        for i in 1..=self.history {
            let xi = bq(x[i], bits, fr, rng);
            let d = bq((xt - xi).abs(), bits, fr, rng);
            // Same 5-stage Maclaurin factorization as the circuit.
            let mut prod = 1.0;
            for _ in 0..5 {
                let e = bq(Self::maclaurin(cs, d).clamp(0.0, 1.0), bits, fr, rng);
                prod = bq(prod * e, bits, fr, rng);
            }
            sum += prod;
        }
        bq(sum / self.history as f64, bits, fr, rng)
    }

    fn stoch_cost_netlists(&self) -> Vec<Netlist> {
        // Stage 1: N correlated XORs.
        let mut s1 = Netlist::new();
        for i in 0..self.history {
            let a = s1.input(&format!("xt_{i}"), 0, 1, InputClass::Correlated(i as u32));
            let b = s1.input(&format!("xh_{i}"), 0, 1, InputClass::Correlated(i as u32));
            let d = xor_into(&mut s1, a, b);
            s1.mark_output(&format!("d{i}"), d);
        }
        // Stage 2: per frame, 5 exponential circuits + product chain;
        // then the mean tree.
        let mut s2 = Netlist::new();
        let mut frame_outs = Vec::new();
        for i in 0..self.history {
            let mut prod: Option<_> = None;
            for s in 0..5 {
                let copies: Vec<_> = (0..5)
                    .map(|k| {
                        s2.input(&format!("d{i}_{s}_{k}"), 0, 1, InputClass::Stochastic)
                    })
                    .collect();
                let consts: Vec<_> = (0..5)
                    .map(|k| {
                        s2.input(&format!("c{i}_{s}_{k}"), 0, 1, InputClass::ConstStream)
                    })
                    .collect();
                let e = exp_into(&mut s2, &copies, &consts);
                prod = Some(match prod {
                    None => e,
                    Some(p) => and_rel(&mut s2, p, e),
                });
            }
            frame_outs.push(prod.unwrap());
        }
        let mut level = frame_outs;
        let mut sel = 0usize;
        while level.len() > 1 {
            let mut next = Vec::new();
            for pair in level.chunks(2) {
                let s = s2.input(&format!("sel{sel}"), 0, 1, InputClass::ConstStream);
                sel += 1;
                next.push(crate::netlist::ops::mux_into(&mut s2, s, pair[0], pair[1]));
            }
            level = next;
        }
        s2.mark_output("pdf", level.pop().unwrap());
        vec![s1, s2]
    }

    fn binary_cost_netlist(&self) -> Netlist {
        // Representative slice: two history frames of the full pipeline
        // (|Δ| + 5-stage Maclaurin product) + the combining adder; the
        // bench scales linearly to N frames (DESIGN.md §7).
        let mut b = crate::netlist::binary::BinaryBuilder::new(64);
        let xt = b.input_word("xt", 8, false);
        let mut frames = Vec::new();
        for i in 0..2usize {
            let xi = b.input_word(&format!("x{i}"), 8, false);
            let (d, _) = b.subtractor(&xt, &xi); // |Δ| modeled as sub
            let d8 = d.slice(0, 8);
            let mut prod = b.constant_word(255, 8);
            for _ in 0..2 {
                // two of the five stages in the representative slice
                let e = b.exp_maclaurin(&d8, self.c / 5.0);
                prod = b.fixmul(&prod, &e, 8);
            }
            frames.push(prod);
        }
        let z = b.const0();
        let mut a = frames[0].clone();
        let mut c = frames[1].clone();
        a.bits.push(z);
        c.bits.push(z);
        let (s, _) = b.adder(&a, &c, z);
        for (k, bit) in s.bits.iter().enumerate() {
            b.nl.mark_output(&format!("o{k}"), bit.id);
        }
        b.nl
    }

    fn binary_cost_scale(&self) -> f64 {
        // Slice: 2 of N frames × 2 of 5 Maclaurin stages.
        (self.history as f64 / 2.0) * (5.0 / 2.0)
    }

    fn eval_instances(&self) -> usize {
        1024 // pixels × one history window each
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stochastic_tracks_float() {
        let app = Kde::default();
        let insts = app.workload(3, 31);
        for x in &insts {
            let mut rng = Xoshiro256::seeded(41);
            let s = app.stoch_value(x, 8192, &mut rng, 0.0);
            let f = app.float_ref(x);
            // Maclaurin truncation + SC noise: generous but bounded.
            assert!((s - f).abs() < 0.1, "s={s} f={f}");
        }
    }

    #[test]
    fn binary_tracks_float() {
        let app = Kde::default();
        let insts = app.workload(8, 33);
        let mut rng = Xoshiro256::seeded(1);
        for x in &insts {
            let b = app.binary_value(x, 8, &mut rng, 0.0);
            let f = app.float_ref(x);
            assert!((b - f).abs() < 0.08, "b={b} f={f}");
        }
    }

    #[test]
    fn maclaurin_five_stage_factorization_is_accurate() {
        for x in [0.0, 0.1, 0.3, 0.5, 0.8, 1.0] {
            let five = Kde::maclaurin(0.8, x).powi(5);
            let want = (-4.0 * x).exp();
            assert!((five - want).abs() < 0.03, "x={x} five={five} want={want}");
        }
    }

    #[test]
    fn stage2_is_the_wide_netlist() {
        let app = Kde::default();
        let stages = app.stoch_cost_netlists();
        assert_eq!(stages.len(), 2);
        // 8 frames × 5 exp instances × 13 gates + products + tree.
        assert!(stages[1].gate_count() > 500, "got {}", stages[1].gate_count());
    }

    #[test]
    fn staged_plan_shape() {
        let app = Kde::default();
        let plan = app.staged_plan();
        assert_eq!(plan.stages().len(), 2);
        assert_eq!(plan.n_inputs(), app.history + 1);
        // Stage 1: one correlated pair per frame; stage 2: 5 copies × 5
        // exp instances per frame (regenerated) + 5×5 constants per
        // frame + 7 tree selects.
        assert_eq!(plan.stages()[0].bindings.len(), 2 * app.history);
        let regen = plan.stages()[1]
            .bindings
            .iter()
            .filter(|b| matches!(b, Binding::Regen { .. }))
            .count();
        assert_eq!(regen, app.history * 25);
        assert_eq!(plan.stages()[1].bindings.len(), app.history * 50 + 7);
    }

    #[test]
    fn staged_reference_tracks_float() {
        // The staged-netlist scalar reference (the engine's bit-level
        // contract) approximates the same PDF as stoch_value, just with
        // the per-stage draw order.
        let app = Kde::default();
        let plan = app.staged_plan();
        let insts = app.workload(2, 37);
        for (k, x) in insts.iter().enumerate() {
            let mut rng = Xoshiro256::seeded(51 + k as u64);
            let s = plan.eval_row_scalar(x, 4096, &mut rng);
            let f = app.float_ref(x);
            assert!((s - f).abs() < 0.1, "instance {k}: staged={s} float={f}");
        }
    }
}
