//! S13 — the paper's four evaluation applications (§5.3.1, Fig 9):
//! local image thresholding (LIT), Bayesian object location (OL),
//! heart-disaster prediction (HDP), and kernel density estimation (KDE).
//!
//! Each application provides three value models and two cost models:
//! * `float_ref`    — exact f64 golden function;
//! * `stoch_value`  — bitstream-exact staged stochastic evaluation
//!   (with optional bitflip injection at every operation boundary,
//!   Table 4's fault model);
//! * `binary_value` — 8-bit fixed-point evaluation quantizing after
//!   every operation (the exact behaviour of the binary-IMC circuits,
//!   which are bit-exact), same injection points;
//! * `stoch_cost_netlists` — single-lane netlists per in-memory stage
//!   (multi-stage apps use the architecture's StoB→BtoS regeneration
//!   between stages — DESIGN.md §7);
//! * `binary_cost_netlist` — the full binary circuit for cost accounting.

pub mod hdp;
pub mod kde;
pub mod lit;
pub mod ol;

use crate::netlist::{Binding, Netlist, Node};
use crate::sc::bitstream::Bitstream;
use crate::util::prng::Xoshiro256;

/// One workload instance: the application's input values, all in [0,1].
pub type Instance = Vec<f64>;

pub trait App: Send + Sync {
    fn name(&self) -> &'static str;
    /// Generate `n` synthetic workload instances (deterministic in seed).
    fn workload(&self, n: usize, seed: u64) -> Vec<Instance>;
    fn float_ref(&self, x: &[f64]) -> f64;
    /// Stochastic evaluation at bitstream length `bl`, flipping each
    /// stream bit at operation boundaries with probability `flip`.
    fn stoch_value(&self, x: &[f64], bl: usize, rng: &mut Xoshiro256, flip: f64) -> f64;
    /// Binary fixed-point evaluation at `bits` resolution, flipping each
    /// value bit at operation boundaries with probability `flip`.
    fn binary_value(&self, x: &[f64], bits: u32, rng: &mut Xoshiro256, flip: f64) -> f64;
    /// Per-stage single-lane stochastic netlists (cost model).
    fn stoch_cost_netlists(&self) -> Vec<Netlist>;
    /// Full binary circuit (cost model). May be a representative slice;
    /// [`App::binary_cost_scale`] scales its counts to the full workload.
    fn binary_cost_netlist(&self) -> Netlist;
    /// Analytic multiplier from the representative binary slice to the
    /// full per-instance circuit (1.0 when the netlist is complete).
    fn binary_cost_scale(&self) -> f64 {
        1.0
    }
    /// Workload instances used in the Table 3 evaluation.
    fn eval_instances(&self) -> usize;
}

/// All four applications.
pub fn all_apps() -> Vec<Box<dyn App>> {
    vec![
        Box::new(lit::Lit::default()),
        Box::new(ol::Ol::default()),
        Box::new(hdp::Hdp),
        Box::new(kde::Kde::default()),
    ]
}

// ---- shared stochastic helpers -----------------------------------------

/// Inject a node-level fault on a stream (no-op at rate 0): with
/// probability `rate` one random bit of the operand flips (Table 4's
/// fault model — see fault/mod.rs).
pub(crate) fn flip(bs: &Bitstream, rate: f64, rng: &mut Xoshiro256) -> Bitstream {
    crate::fault::inject_stream_node(bs, rate, rng)
}

/// Balanced MUX mean tree: pads to the next power of two with zero
/// streams; output value = Σ values / 2^depth.
pub(crate) fn mean_tree(
    streams: &[Bitstream],
    bl: usize,
    rng: &mut Xoshiro256,
    flip_rate: f64,
) -> Bitstream {
    assert!(!streams.is_empty());
    let mut level: Vec<Bitstream> = streams.to_vec();
    let target = level.len().next_power_of_two();
    while level.len() < target {
        level.push(Bitstream::zeros(bl));
    }
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len() / 2);
        for pair in level.chunks(2) {
            let s = Bitstream::sample(0.5, bl, rng);
            let m = crate::sc::ops::scaled_add(&pair[0], &pair[1], &s);
            next.push(flip(&m, flip_rate, rng));
        }
        level = next;
    }
    level.pop().unwrap()
}

/// Build a MUX mean-tree netlist over `n` external stochastic inputs
/// named `x0..x{n-1}` (padded internally with const-0 streams); returns
/// the netlist with output "out". Used by the cost models.
pub(crate) fn mean_tree_netlist(n: usize) -> Netlist {
    use crate::netlist::graph::InputClass;
    use crate::netlist::ops::mux_into;
    let mut nl = Netlist::new();
    let mut level: Vec<_> = (0..n)
        .map(|i| nl.input(&format!("x{i}"), 0, 1, InputClass::Stochastic))
        .collect();
    let target = n.next_power_of_two();
    for i in level.len()..target {
        level.push(nl.input(&format!("z{i}"), 0, 1, InputClass::ConstStream));
    }
    let mut sel = 0usize;
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len() / 2);
        for pair in level.chunks(2) {
            let s = nl.input(&format!("s{sel}"), 0, 1, InputClass::ConstStream);
            sel += 1;
            next.push(mux_into(&mut nl, s, pair[0], pair[1]));
        }
        level = next;
    }
    let out = level.pop().unwrap();
    nl.mark_output("out", out);
    nl
}

/// Map every primary input of `nl`, in node-id (binding) order, through
/// the app's name→[`Binding`] convention — the glue between an app's
/// `stoch_cost_netlists` input naming and the runtime's compiled staged
/// pipelines.
pub(crate) fn bindings_from(nl: &Netlist, mut f: impl FnMut(&str) -> Binding) -> Vec<Binding> {
    nl.nodes
        .iter()
        .filter_map(|n| match n {
            Node::Input { name, .. } => Some(f(name)),
            _ => None,
        })
        .collect()
}

/// Fallible [`bindings_from`]: the runtime's load path maps unknown
/// input names to a contextual error instead of a panic, so a malformed
/// kernel definition fails `Engine::load` cleanly.
pub(crate) fn try_bindings_from(
    nl: &Netlist,
    mut f: impl FnMut(&str) -> crate::error::Result<Binding>,
) -> crate::error::Result<Vec<Binding>> {
    nl.nodes
        .iter()
        .filter_map(|n| match n {
            Node::Input { name, .. } => Some(f(name)),
            _ => None,
        })
        .collect()
}

/// Index of output `name` in `nl`'s output order (regeneration edges
/// reference stage outputs positionally).
pub(crate) fn out_idx(nl: &Netlist, name: &str) -> usize {
    nl.outputs
        .iter()
        .position(|(n, _)| n == name)
        .unwrap_or_else(|| panic!("netlist has no output `{name}`"))
}

/// Quantize + optionally node-level fault-inject a binary value.
pub(crate) fn bq(v: f64, bits: u32, rate: f64, rng: &mut Xoshiro256) -> f64 {
    let q = crate::sc::encode::quantize(v, bits);
    if rate > 0.0 {
        crate::fault::inject_binary_node(q, bits, rate, rng)
    } else {
        q
    }
}

/// Mean output-error (%) of a method against the float reference over a
/// workload — the Table 4 metric.
pub fn output_error_pct(
    app: &dyn App,
    instances: &[Instance],
    bl: usize,
    bits: u32,
    flip_rate: f64,
    stochastic: bool,
    seed: u64,
) -> f64 {
    let mut rng = Xoshiro256::seeded(seed);
    let mut refs = Vec::with_capacity(instances.len());
    let mut got = Vec::with_capacity(instances.len());
    for x in instances {
        refs.push(app.float_ref(x));
        got.push(if stochastic {
            app.stoch_value(x, bl, &mut rng, flip_rate)
        } else {
            app.binary_value(x, bits, &mut rng, flip_rate)
        });
    }
    crate::util::stats::range_error_pct(&refs, &got)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_tree_value() {
        let mut rng = Xoshiro256::seeded(5);
        let bl = 65536;
        let streams: Vec<Bitstream> =
            [0.2, 0.4, 0.6, 0.8].iter().map(|&p| Bitstream::sample(p, bl, &mut rng)).collect();
        let m = mean_tree(&streams, bl, &mut rng, 0.0);
        assert!((m.value() - 0.5).abs() < 0.02);
    }

    #[test]
    fn mean_tree_pads_with_zeros() {
        let mut rng = Xoshiro256::seeded(6);
        let bl = 65536;
        let streams: Vec<Bitstream> =
            [0.8, 0.8, 0.8].iter().map(|&p| Bitstream::sample(p, bl, &mut rng)).collect();
        let m = mean_tree(&streams, bl, &mut rng, 0.0);
        assert!((m.value() - 2.4 / 4.0).abs() < 0.02); // padded to 4
    }

    #[test]
    fn mean_tree_netlist_shape() {
        let nl = mean_tree_netlist(4);
        // 3 MUXes × 4 gates.
        assert_eq!(nl.gate_count(), 12);
        let nl5 = mean_tree_netlist(5);
        assert_eq!(nl5.gate_count(), 7 * 4); // padded to 8 ⇒ 7 MUXes
    }

    #[test]
    fn all_apps_present() {
        let apps = all_apps();
        let names: Vec<_> = apps.iter().map(|a| a.name()).collect();
        assert_eq!(names, vec!["lit", "ol", "hdp", "kde"]);
    }
}
