//! Heart-disaster prediction (HDP, Fig 9c / Eqs 8–9): a Bayesian belief
//! network. Inputs (8 values): P(BP), P(CP), P(E), P(D) and the four
//! conditional table entries t_ED, t_ED̄, t_ĒD, t_ĒD̄ of Eq 9.
//!
//!   h  = [t_ED·P(D) + t_ED̄·P(D̄)]·P(E) + [t_ĒD·P(D) + t_ĒD̄·P(D̄)]·P(Ē)
//!      = MUX(E; MUX(D; t_ED, t_ED̄), MUX(D; t_ĒD, t_ĒD̄))   — exact in SC
//!   P(HD) = N / (N + M),  N = P(BP)·P(CP)·h,  M = P(B̄P)·P(C̄P)·(1−h)
//!
//! The final division is the JK feedback divider (a/(a+b)), the
//! operation Table 2 calls scaled division.

use super::{bq, flip, App, Instance};
use crate::netlist::graph::InputClass;
use crate::netlist::ops::{and_rel, divide_into, mux_into};
use crate::netlist::Netlist;
use crate::sc::bitstream::Bitstream;
use crate::sc::ops as sc_ops;
use crate::util::prng::Xoshiro256;

pub struct Hdp;

/// HDP input order — shared with the interpreter backend's bindings.
pub(crate) const NAMES: [&str; 8] = ["bp", "cp", "e", "d", "t_ed", "t_end", "t_ned", "t_nend"];

impl App for Hdp {
    fn name(&self) -> &'static str {
        "hdp"
    }

    fn workload(&self, n: usize, seed: u64) -> Vec<Instance> {
        let mut rng = Xoshiro256::seeded(seed);
        (0..n)
            .map(|_| {
                // Plausible clinical priors: moderate evidence probs,
                // conditional table skewed by risk factors.
                vec![
                    0.2 + 0.6 * rng.next_f64(), // P(BP)
                    0.2 + 0.6 * rng.next_f64(), // P(CP)
                    0.3 + 0.5 * rng.next_f64(), // P(E)
                    0.3 + 0.5 * rng.next_f64(), // P(D)
                    0.05 + 0.3 * rng.next_f64(), // t_ED  (low risk)
                    0.2 + 0.4 * rng.next_f64(),  // t_ED̄
                    0.2 + 0.4 * rng.next_f64(),  // t_ĒD
                    0.5 + 0.45 * rng.next_f64(), // t_ĒD̄ (high risk)
                ]
            })
            .collect()
    }

    fn float_ref(&self, x: &[f64]) -> f64 {
        let (bp, cp, e, d) = (x[0], x[1], x[2], x[3]);
        let h = (x[4] * d + x[5] * (1.0 - d)) * e + (x[6] * d + x[7] * (1.0 - d)) * (1.0 - e);
        let n = bp * cp * h;
        let m = (1.0 - bp) * (1.0 - cp) * (1.0 - h);
        n / (n + m)
    }

    fn stoch_value(&self, x: &[f64], bl: usize, rng: &mut Xoshiro256, fr: f64) -> f64 {
        let s = |v: f64, rng: &mut Xoshiro256| Bitstream::sample(v, bl, rng);
        let bp = flip(&s(x[0], rng), fr, rng);
        let cp = flip(&s(x[1], rng), fr, rng);
        let e = flip(&s(x[2], rng), fr, rng);
        let d = flip(&s(x[3], rng), fr, rng);
        let t: Vec<Bitstream> = (4..8).map(|i| flip(&s(x[i], rng), fr, rng)).collect();
        // h = MUX(E; MUX(D; t_ED, t_ED̄), MUX(D; t_ĒD, t_ĒD̄)).
        let hi = flip(&Bitstream::mux(&d, &t[0], &t[1]), fr, rng);
        let lo = flip(&Bitstream::mux(&d, &t[2], &t[3]), fr, rng);
        let h = flip(&Bitstream::mux(&e, &hi, &lo), fr, rng);
        let n = flip(&sc_ops::multiply(&sc_ops::multiply(&bp, &cp), &h), fr, rng);
        let m = flip(
            &sc_ops::multiply(&sc_ops::multiply(&bp.not(), &cp.not()), &h.not()),
            fr,
            rng,
        );
        let out = flip(&sc_ops::scaled_divide(&n, &m), fr, rng);
        out.value()
    }

    fn binary_value(&self, x: &[f64], bits: u32, rng: &mut Xoshiro256, fr: f64) -> f64 {
        let q = |v: f64, rng: &mut Xoshiro256| bq(v, bits, fr, rng);
        let (bp, cp, e, d) = (q(x[0], rng), q(x[1], rng), q(x[2], rng), q(x[3], rng));
        let t: Vec<f64> = (4..8).map(|i| q(x[i], rng)).collect();
        let hi = q(t[0] * d + t[1] * (1.0 - d), rng);
        let lo = q(t[2] * d + t[3] * (1.0 - d), rng);
        let h = q(hi * e + lo * (1.0 - e), rng);
        let n = q(q(bp * cp, rng) * h, rng);
        let m = q(q((1.0 - bp) * (1.0 - cp), rng) * (1.0 - h), rng);
        if n + m < 1.0 / (1u64 << bits) as f64 {
            return 0.0;
        }
        q(n / (n + m), rng)
    }

    fn stoch_cost_netlists(&self) -> Vec<Netlist> {
        let mut nl = Netlist::new();
        let ids: Vec<_> = NAMES
            .iter()
            .map(|n| nl.input(n, 0, 1, InputClass::Stochastic))
            .collect();
        let (bp, cp, e, d) = (ids[0], ids[1], ids[2], ids[3]);
        let hi = mux_into(&mut nl, d, ids[4], ids[5]);
        let lo = mux_into(&mut nl, d, ids[6], ids[7]);
        let h = mux_into(&mut nl, e, hi, lo);
        let bc = and_rel(&mut nl, bp, cp);
        let n = and_rel(&mut nl, bc, h);
        let bp_n = nl.gate(crate::netlist::GateKind::Not, 0, vec![bp]);
        let cp_n = nl.gate(crate::netlist::GateKind::Not, 0, vec![cp]);
        let h_n = nl.gate(crate::netlist::GateKind::Not, 0, vec![h]);
        let bcn = and_rel(&mut nl, bp_n, cp_n);
        let m = and_rel(&mut nl, bcn, h_n);
        let out = divide_into(&mut nl, n, m);
        nl.mark_output("out", out);
        vec![nl]
    }

    fn binary_cost_netlist(&self) -> Netlist {
        let mut b = crate::netlist::binary::BinaryBuilder::new(16);
        let words: Vec<_> =
            NAMES.iter().map(|n| b.input_word(n, 8, false)).collect();
        let (bp, cp, e, d) = (&words[0], &words[1], &words[2], &words[3]);
        let d_c = d.complement();
        let e_c = e.complement();
        // hi = t_ED·d + t_ED̄·(1−d), etc.
        let p1 = b.fixmul(&words[4], d, 8);
        let p2 = b.fixmul(&words[5], &d_c, 8);
        let z0 = b.const0();
        let (hi, _) = b.adder(&p1, &p2, z0);
        let p3 = b.fixmul(&words[6], d, 8);
        let p4 = b.fixmul(&words[7], &d_c, 8);
        let z = b.const0();
        let (lo, _) = b.adder(&p3, &p4, z);
        let he = b.fixmul(&hi, e, 8);
        let le = b.fixmul(&lo, &e_c, 8);
        let z2 = b.const0();
        let (h, _) = b.adder(&he, &le, z2);
        let bc = b.fixmul(bp, cp, 8);
        let n = b.fixmul(&bc, &h, 8);
        let bc_n = {
            let bpc = bp.complement();
            let cpc = cp.complement();
            b.fixmul(&bpc, &cpc, 8)
        };
        let h_c = h.complement();
        let m = b.fixmul(&bc_n, &h_c, 8);
        let (den, _) = {
            let z3 = b.const0();
            b.adder(&n, &m, z3)
        };
        let q = b.divider(&n, &den);
        for (k, bit) in q.bits.iter().enumerate() {
            b.nl.mark_output(&format!("o{k}"), bit.id);
        }
        b.nl
    }

    fn eval_instances(&self) -> usize {
        256 // a batch of belief-network queries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn stochastic_tracks_float() {
        let app = Hdp;
        let insts = app.workload(8, 3);
        for x in &insts {
            let mut rng = Xoshiro256::seeded(17);
            let s = app.stoch_value(x, 65536, &mut rng, 0.0);
            let f = app.float_ref(x);
            assert!((s - f).abs() < 0.05, "s={s} f={f} x={x:?}");
        }
    }

    #[test]
    fn binary_tracks_float() {
        let app = Hdp;
        forall(0x42, 20, |g| {
            let x: Vec<f64> = (0..8).map(|_| g.f64_in(0.1, 0.9)).collect();
            let mut rng = Xoshiro256::seeded(1);
            let b = app.binary_value(&x, 8, &mut rng, 0.0);
            assert!((b - app.float_ref(&x)).abs() < 0.03);
        });
    }

    #[test]
    fn probability_always_in_unit_interval() {
        let app = Hdp;
        for x in app.workload(64, 9) {
            let f = app.float_ref(&x);
            assert!((0.0..=1.0).contains(&f));
        }
    }

    #[test]
    fn stoch_netlist_has_divider_state() {
        let app = Hdp;
        let nl = &app.stoch_cost_netlists()[0];
        let delays = nl
            .nodes
            .iter()
            .filter(|n| matches!(n, crate::netlist::Node::Delay { .. }))
            .count();
        assert_eq!(delays, 1);
        assert!(nl.gate_count() > 20);
    }
}
