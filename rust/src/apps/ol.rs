//! Object location (OL, Fig 9b / Eq 7): a Bayesian inference over a
//! 64×64 2-D grid with three (distance, bearing) sensors:
//!   p(x,y) = Π_i p(B_i|x,y) · p(D_i|x,y)      (6 likelihood factors)
//! Stochastic realization: a 6-input AND tree (products of independent
//! unipolar SNs). The workload generator synthesizes Gaussian sensor
//! likelihood fields over the grid, mimicking [36]'s setup.

use super::{bq, flip, App, Instance};
use crate::netlist::graph::InputClass;
use crate::netlist::ops::and_rel;
use crate::netlist::Netlist;
use crate::sc::bitstream::Bitstream;
use crate::util::prng::Xoshiro256;

pub struct Ol {
    pub grid: usize,
    pub sensors: usize,
}

impl Default for Ol {
    fn default() -> Self {
        Self { grid: 64, sensors: 3 }
    }
}

impl Ol {
    fn factors(&self) -> usize {
        2 * self.sensors
    }

    /// Full row-major grid sweep (index k ↔ cell (k%grid, k/grid)) plus
    /// the hidden object position — the localization-demo workload.
    pub fn grid_workload(&self, seed: u64) -> (Vec<Instance>, (usize, usize)) {
        let mut rng = Xoshiro256::seeded(seed);
        let g = self.grid as f64;
        let obj = (rng.next_f64() * g, rng.next_f64() * g);
        let sensors: Vec<(f64, f64)> =
            (0..self.sensors).map(|_| (rng.next_f64() * g, rng.next_f64() * g)).collect();
        let mut out = Vec::with_capacity(self.grid * self.grid);
        for idx in 0..self.grid * self.grid {
            let (px, py) = ((idx % self.grid) as f64, (idx / self.grid) as f64);
            out.push(self.factors_at(px, py, obj, &sensors));
        }
        (out, (obj.0.round() as usize, obj.1.round() as usize))
    }

    fn factors_at(
        &self,
        px: f64,
        py: f64,
        obj: (f64, f64),
        sensors: &[(f64, f64)],
    ) -> Instance {
        let g = self.grid as f64;
        let mut inst = Vec::with_capacity(self.factors());
        for &(sx, sy) in sensors {
            let d_point = ((px - sx).powi(2) + (py - sy).powi(2)).sqrt();
            let d_obj = ((obj.0 - sx).powi(2) + (obj.1 - sy).powi(2)).sqrt();
            let sigma_d = 0.15 * g;
            let p_d = (-((d_point - d_obj).powi(2)) / (2.0 * sigma_d * sigma_d)).exp();
            let b_point = (py - sy).atan2(px - sx);
            let b_obj = (obj.1 - sy).atan2(obj.0 - sx);
            let mut db = (b_point - b_obj).abs();
            if db > std::f64::consts::PI {
                db = 2.0 * std::f64::consts::PI - db;
            }
            let sigma_b = 0.6;
            let p_b = (-(db * db) / (2.0 * sigma_b * sigma_b)).exp();
            inst.push(p_d.clamp(0.0, 1.0));
            inst.push(p_b.clamp(0.0, 1.0));
        }
        inst
    }
}

impl App for Ol {
    fn name(&self) -> &'static str {
        "ol"
    }

    /// Each instance = the 6 likelihood factors at one grid point,
    /// sampled around the hidden object (the posterior-refinement
    /// region, where probabilities are non-vanishing — error metrics on
    /// the far-field would divide by ~0). The full-grid sweep for the
    /// localization demo is [`Ol::grid_workload`].
    fn workload(&self, n: usize, seed: u64) -> Vec<Instance> {
        let mut rng = Xoshiro256::seeded(seed);
        let g = self.grid as f64;
        // Hidden object + three fixed sensors.
        let obj = (rng.next_f64() * g, rng.next_f64() * g);
        let sensors: Vec<(f64, f64)> =
            (0..self.sensors).map(|_| (rng.next_f64() * g, rng.next_f64() * g)).collect();
        let mut out = Vec::with_capacity(n);
        for _k in 0..n {
            // Gaussian sample around the object, clamped to the grid.
            let px = (obj.0 + 0.25 * g * (rng.next_f64() + rng.next_f64() - 1.0))
                .clamp(0.0, g - 1.0)
                .round();
            let py = (obj.1 + 0.25 * g * (rng.next_f64() + rng.next_f64() - 1.0))
                .clamp(0.0, g - 1.0)
                .round();
            out.push(self.factors_at(px, py, obj, &sensors));
        }
        out
    }

    fn float_ref(&self, x: &[f64]) -> f64 {
        x.iter().product()
    }

    fn stoch_value(&self, x: &[f64], bl: usize, rng: &mut Xoshiro256, fr: f64) -> f64 {
        // AND-tree over independently generated streams.
        let mut acc: Option<Bitstream> = None;
        for &v in x {
            let s = flip(&Bitstream::sample(v, bl, rng), fr, rng);
            acc = Some(match acc {
                None => s,
                Some(a) => flip(&crate::sc::ops::multiply(&a, &s), fr, rng),
            });
        }
        acc.unwrap().value()
    }

    fn binary_value(&self, x: &[f64], bits: u32, rng: &mut Xoshiro256, fr: f64) -> f64 {
        let mut acc = bq(x[0], bits, fr, rng);
        for &v in &x[1..] {
            acc = bq(acc * bq(v, bits, fr, rng), bits, fr, rng);
        }
        acc
    }

    fn stoch_cost_netlists(&self) -> Vec<Netlist> {
        // Single stage: chained AND (NAND+NOT) tree over 6 inputs.
        let mut nl = Netlist::new();
        let ins: Vec<_> = (0..self.factors())
            .map(|i| nl.input(&format!("p{i}"), 0, 1, InputClass::Stochastic))
            .collect();
        let mut acc = ins[0];
        for &i in &ins[1..] {
            acc = and_rel(&mut nl, acc, i);
        }
        nl.mark_output("out", acc);
        vec![nl]
    }

    fn binary_cost_netlist(&self) -> Netlist {
        // Five chained 8-bit fixed-point multiplications.
        let mut b = crate::netlist::binary::BinaryBuilder::new(16);
        let mut acc = b.input_word("p0", 8, false);
        for i in 1..self.factors() {
            let w = b.input_word(&format!("p{i}"), 8, false);
            acc = b.fixmul(&acc, &w, 8);
        }
        for (k, bit) in acc.bits.iter().enumerate() {
            b.nl.mark_output(&format!("o{k}"), bit.id);
        }
        b.nl
    }

    fn eval_instances(&self) -> usize {
        self.grid * self.grid
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn stochastic_tracks_float() {
        let app = Ol::default();
        forall(0x01, 10, |g| {
            let x: Vec<f64> = (0..6).map(|_| g.f64_in(0.3, 1.0)).collect();
            let mut rng = Xoshiro256::seeded(g.u64_below(1 << 62));
            let s = app.stoch_value(&x, 65536, &mut rng, 0.0);
            let f = app.float_ref(&x);
            assert!((s - f).abs() < 0.03, "s={s} f={f}");
        });
    }

    #[test]
    fn binary_is_near_exact_at_8bit() {
        let app = Ol::default();
        let mut rng = Xoshiro256::seeded(1);
        let x = vec![0.9, 0.8, 0.95, 0.7, 0.85, 0.6];
        let b = app.binary_value(&x, 8, &mut rng, 0.0);
        assert!((b - app.float_ref(&x)).abs() < 0.02);
    }

    #[test]
    fn workload_is_deterministic_and_valid() {
        let app = Ol::default();
        let w1 = app.workload(100, 7);
        let w2 = app.workload(100, 7);
        assert_eq!(w1, w2);
        for inst in &w1 {
            assert_eq!(inst.len(), 6);
            assert!(inst.iter().all(|v| (0.0..=1.0).contains(v)));
        }
    }

    #[test]
    fn cost_netlist_shapes() {
        let app = Ol::default();
        let s = &app.stoch_cost_netlists()[0];
        assert_eq!(s.gate_count(), 10); // 5 AND = 5×(NAND+NOT)
        assert_eq!(s.len(), 16); // +6 inputs → paper Table 3 "1×16"
        let b = app.binary_cost_netlist();
        assert!(b.gate_count() > 1000); // 5 Wallace multipliers
    }
}
