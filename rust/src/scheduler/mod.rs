//! S7 — co-scheduling and mapping (paper Algorithm 1 + ASAP refinement)
//! and schedule validation.

pub mod algorithm1;
pub mod schedule;
pub mod validate;

pub use algorithm1::{schedule, Mode, Options, ADDIE_CYCLES};
pub use schedule::{CellRef, Schedule, ScheduledOp, Step};
