//! Schedule invariant validation — used by tests, the property-test
//! suite, and (in debug builds) the architecture executor before running
//! a schedule on the subarray simulator.

use std::collections::{HashMap, HashSet};

use super::schedule::{CellRef, Schedule};
use crate::netlist::graph::{Netlist, Node};

/// A violated invariant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    MixedKinds { step: usize },
    SharedInputCell { step: usize, cell: CellRef },
    RowReuse { step: usize, row: u32 },
    InputColumnMisaligned { step: usize },
    OutputColumnMisaligned { step: usize },
    DependencyOrder { node: usize, dep: usize },
    UnscheduledGate { node: usize },
    OutOfBounds { cell: CellRef, rows: usize, cols: usize },
    OutputCellClash { cell: CellRef },
}

/// Check every invariant of a schedule against its netlist and an array
/// bound. Returns all violations (empty ⇒ valid).
pub fn validate(nl: &Netlist, s: &Schedule, max_rows: usize, max_cols: usize) -> Vec<Violation> {
    let mut v = Vec::new();

    // Per-step constraints.
    for (si, step) in s.steps.iter().enumerate() {
        let kind = step.ops[0].kind;
        let mut rows = HashSet::new();
        let mut cells = HashSet::new();
        let in_cols: Vec<u32> = {
            let mut c: Vec<u32> = step.ops[0].ins.iter().map(|c| c.col).collect();
            c.sort_unstable();
            c
        };
        let out_col = step.ops[0].out.col;
        for op in &step.ops {
            if op.kind != kind {
                v.push(Violation::MixedKinds { step: si });
            }
            if !rows.insert(op.out.row) {
                v.push(Violation::RowReuse { step: si, row: op.out.row });
            }
            let mut c: Vec<u32> = op.ins.iter().map(|c| c.col).collect();
            c.sort_unstable();
            if c != in_cols {
                v.push(Violation::InputColumnMisaligned { step: si });
            }
            if op.out.col != out_col {
                v.push(Violation::OutputColumnMisaligned { step: si });
            }
            for cell in &op.ins {
                if !cells.insert(*cell) {
                    v.push(Violation::SharedInputCell { step: si, cell: *cell });
                }
            }
        }
    }

    // Dependency order + completeness.
    for (id, node) in nl.nodes.iter().enumerate() {
        if let Node::Gate { ins, .. } = node {
            match s.t_of_node.get(&id) {
                None => v.push(Violation::UnscheduledGate { node: id }),
                Some(&t) => {
                    for &d in ins {
                        if matches!(nl.nodes[d], Node::Gate { .. }) {
                            if let Some(&td) = s.t_of_node.get(&d) {
                                if td >= t {
                                    v.push(Violation::DependencyOrder { node: id, dep: d });
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    // Bounds + output cell uniqueness (no two ops write the same cell).
    let mut outs: HashMap<CellRef, usize> = HashMap::new();
    for step in &s.steps {
        for op in &step.ops {
            if op.out.row as usize >= max_rows || op.out.col as usize >= max_cols {
                v.push(Violation::OutOfBounds { cell: op.out, rows: max_rows, cols: max_cols });
            }
            *outs.entry(op.out).or_insert(0) += 1;
        }
    }
    for (cell, n) in outs {
        if n > 1 {
            v.push(Violation::OutputCellClash { cell });
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{ops, replicate::replicate};
    use crate::scheduler::algorithm1::{schedule, Mode, Options};

    #[test]
    fn all_op_schedules_validate() {
        for (name, nl) in [
            ("mul", replicate(&ops::multiply(), 64)),
            ("add", replicate(&ops::scaled_add(), 64)),
            ("sub", replicate(&ops::abs_subtract(), 64)),
            ("div", replicate(&ops::scaled_divide(), 64)),
            ("sqrt", replicate(&ops::square_root(6), 64)),
            ("exp", replicate(&ops::exponential(), 64)),
        ] {
            for mode in [Mode::Asap, Mode::LayerStrict] {
                let s = schedule(&nl, &Options { mode });
                let viol = validate(&nl, &s, 1 << 20, 1 << 20);
                assert!(viol.is_empty(), "{name} {mode:?}: {viol:?}");
            }
        }
    }

    #[test]
    fn bounds_violation_detected() {
        let nl = replicate(&ops::multiply(), 64);
        let s = schedule(&nl, &Options::default());
        let viol = validate(&nl, &s, 8, 8); // way too small
        assert!(viol.iter().any(|x| matches!(x, Violation::OutOfBounds { .. })));
    }
}
