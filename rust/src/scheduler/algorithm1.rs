//! Algorithm 1 — in-memory co-scheduling and mapping for the 2T-1MTJ IMC
//! method (paper §4.2), plus an ASAP (list-scheduling) refinement.
//!
//! Both modes enforce the three parallelization constraints of §4.2:
//!   1. gates in one cycle are of the same type,
//!   2. gates in one cycle do not share an input cell,
//!   3. gates in one cycle are input-column-aligned (and, for the shared
//!      column-line electrical reason discussed in DESIGN.md §7, output-
//!      column-aligned and in distinct rows).
//!
//! `LayerStrict` follows the paper's pseudocode literally: process the
//! netlist layer by layer, forming subsets per layer, sorted by the
//! average inverse-topological-order (lines 10–31). `Asap` relaxes the
//! layer barrier: any ready gate may be grouped, which recovers the
//! hand-schedules of Fig 7 (9 cycles for the 4-bit binary RCA, 4 for the
//! stochastic adder). The two are compared by the scheduler ablation
//! bench; all paper tables use `Asap` for both Stoch-IMC *and* the
//! binary baseline (fairness: same scheduler).
//!
//! Mapping (shared by both modes, lines 5–8 and 24–30):
//!   * each PI occupies one column across its row span (vertical layout);
//!   * a gate's output goes to the next available column in its row;
//!   * a gate whose inputs live in other rows first copies them (BUFF,
//!     one cycle each unless groupable) into its own row (lines 15–22).

use std::collections::HashMap;

use super::schedule::{CellRef, Schedule, ScheduledOp, Step};
use crate::netlist::graph::{GateKind, InputClass, Netlist, Node, NodeId};

/// Cycles charged per ADDIE macro lane (its per-bit compare/update work,
/// comparable to the JK divider's gate depth — DESIGN.md §7).
pub const ADDIE_CYCLES: usize = 4;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Paper pseudocode: strict layer-by-layer subsets.
    LayerStrict,
    /// Ready-list scheduling with the same constraints (default).
    Asap,
}

#[derive(Debug, Clone)]
pub struct Options {
    pub mode: Mode,
}

impl Default for Options {
    fn default() -> Self {
        Self { mode: Mode::Asap }
    }
}

/// Per-row column allocator implementing the mapping rules.
#[derive(Debug, Default)]
struct Mapper {
    next_col: Vec<usize>,
    max_col: usize,
}

impl Mapper {
    fn ensure_rows(&mut self, rows: usize) {
        if self.next_col.len() < rows {
            self.next_col.resize(rows, 0);
        }
    }

    /// Allocate one column spanning `row..row+rows` (PI vertical layout).
    fn alloc_column(&mut self, row: usize, rows: usize) -> usize {
        self.ensure_rows(row + rows);
        let col = (row..row + rows).map(|r| self.next_col[r]).max().unwrap();
        for r in row..row + rows {
            self.next_col[r] = col + 1;
        }
        self.max_col = self.max_col.max(col + 1);
        col
    }

    /// Allocate the next available cell in `row`.
    fn alloc_cell(&mut self, row: usize) -> CellRef {
        self.ensure_rows(row + 1);
        let col = self.next_col[row];
        self.next_col[row] = col + 1;
        self.max_col = self.max_col.max(col + 1);
        CellRef::new(row, col)
    }

    /// Allocate a block of `cols` columns in `row` (ADDIE macro).
    fn alloc_block(&mut self, row: usize, cols: usize) -> CellRef {
        self.ensure_rows(row + 1);
        let col = self.next_col[row];
        self.next_col[row] += cols;
        self.max_col = self.max_col.max(col + cols);
        CellRef::new(row, col)
    }
}

/// A candidate operation for the current cycle.
#[derive(Debug, Clone)]
struct Cand {
    node: Option<NodeId>, // None ⇒ alignment copy
    kind: GateKind,
    ins: Vec<CellRef>,
    out_row: usize,
    priority: f64,
    /// For copies: (source cell, dest row) key.
    copy_key: Option<(CellRef, usize)>,
}

/// Schedule + map `nl`. Panics on combinational cycles (Delay breaks
/// feedback). See module docs for the two modes.
pub fn schedule(nl: &Netlist, opts: &Options) -> Schedule {
    let order = nl.topological_order();
    let inv = nl.inverse_topo_order();
    let layers = nl.layers();
    let max_layer = nl.depth();

    let mut mapper = Mapper::default();
    let mut sched = Schedule::default();

    // ---- Source placement: PIs (lines 5–8), Delay cells, ADDIE blocks.
    for (id, node) in nl.nodes.iter().enumerate() {
        match node {
            Node::Input { row, rows, class, .. } => {
                let col = mapper.alloc_column(*row, *rows);
                sched.placement.insert(id, CellRef::new(*row, col));
                match class {
                    InputClass::BinaryBit => sched.binary_write_count += rows,
                    _ => sched.sbg_count += rows,
                }
            }
            Node::Delay { row, .. } => {
                let cell = mapper.alloc_cell(*row);
                sched.placement.insert(id, cell);
            }
            Node::Addie { row, cols, .. } => {
                let cell = mapper.alloc_block(*row, *cols);
                sched.placement.insert(id, cell);
                sched.addie_cycles += ADDIE_CYCLES;
            }
            Node::Gate { .. } => {}
        }
    }

    // ---- Dependency bookkeeping over combinational gate→gate edges.
    let mut remaining: HashMap<NodeId, usize> = HashMap::new();
    let mut dependents: HashMap<NodeId, Vec<NodeId>> = HashMap::new();
    for (id, node) in nl.nodes.iter().enumerate() {
        if let Node::Gate { ins, .. } = node {
            let mut cnt = 0;
            for &d in ins {
                if matches!(nl.nodes[d], Node::Gate { .. }) {
                    cnt += 1;
                    dependents.entry(d).or_default().push(id);
                }
            }
            remaining.insert(id, cnt);
        }
    }
    let total_gates = remaining.len();
    let mut ready: Vec<NodeId> = order
        .iter()
        .copied()
        .filter(|&id| matches!(nl.nodes[id], Node::Gate { .. }) && remaining[&id] == 0)
        .collect();

    // Completed alignment copies: (source cell, dest row) → copied cell.
    let mut copy_done: HashMap<(CellRef, usize), CellRef> = HashMap::new();
    let mut scheduled_count = 0usize;
    let mut current_layer = 1usize;

    while scheduled_count < total_gates {
        // ---- Build this cycle's candidates.
        let mut cands: Vec<Cand> = Vec::new();
        let mut copy_requests: Vec<(CellRef, usize, f64)> = Vec::new();

        for &id in &ready {
            if opts.mode == Mode::LayerStrict && layers[id] > current_layer {
                continue;
            }
            let Node::Gate { kind, ins, .. } = &nl.nodes[id] else { unreachable!() };
            let row = nl.nodes[id].row();
            // Resolve input cells into this gate's row.
            let mut cells = Vec::with_capacity(ins.len());
            let mut blocked = false;
            for &d in ins {
                let src = sched.placement[&d];
                let cell = match &nl.nodes[d] {
                    Node::Input { row: r0, rows, .. }
                        if row >= *r0 && row < r0 + rows =>
                    {
                        CellRef::new(row, src.col as usize)
                    }
                    _ => src,
                };
                if cell.row as usize == row {
                    cells.push(cell);
                } else if let Some(&copied) = copy_done.get(&(cell, row)) {
                    cells.push(copied);
                } else {
                    blocked = true;
                    if !copy_requests.iter().any(|(s, r, _)| *s == cell && *r == row) {
                        copy_requests.push((cell, row, inv[id] as f64 + 0.5));
                    }
                }
            }
            if !blocked {
                cands.push(Cand {
                    node: Some(id),
                    kind: *kind,
                    ins: cells,
                    out_row: row,
                    priority: inv[id] as f64,
                    copy_key: None,
                });
            }
        }
        for (src, dest_row, prio) in copy_requests {
            cands.push(Cand {
                node: None,
                kind: GateKind::Buff,
                ins: vec![src],
                out_row: dest_row,
                priority: prio,
                copy_key: Some((src, dest_row)),
            });
        }

        if cands.is_empty() {
            if opts.mode == Mode::LayerStrict && current_layer < max_layer {
                current_layer += 1;
                continue;
            }
            panic!("scheduler stalled: {scheduled_count}/{total_gates} gates scheduled");
        }

        // ---- Group by (kind, sorted input columns): constraints 1+3.
        let mut groups: HashMap<(GateKind, Vec<u32>), Vec<usize>> = HashMap::new();
        for (i, c) in cands.iter().enumerate() {
            let mut cols: Vec<u32> = c.ins.iter().map(|cell| cell.col).collect();
            cols.sort_unstable();
            groups.entry((c.kind, cols)).or_default().push(i);
        }

        // Highest average priority group first (paper lines 12–13).
        let best_key = groups
            .iter()
            .max_by(|(ka, ma), (kb, mb)| {
                let pa: f64 =
                    ma.iter().map(|&i| cands[i].priority).sum::<f64>() / ma.len() as f64;
                let pb: f64 =
                    mb.iter().map(|&i| cands[i].priority).sum::<f64>() / mb.len() as f64;
                pa.partial_cmp(&pb)
                    .unwrap()
                    .then_with(|| kb.1.cmp(&ka.1)) // deterministic tie-break
                    .then_with(|| format!("{:?}", kb.0).cmp(&format!("{:?}", ka.0)))
            })
            .map(|(k, _)| k.clone())
            .unwrap();
        let mut chosen = groups.remove(&best_key).unwrap();
        // Execute highest-priority members first so the output-column
        // alignment (set by the first executed op) favours the critical
        // path.
        chosen.sort_by(|&a, &b| cands[b].priority.partial_cmp(&cands[a].priority).unwrap());

        // ---- Execute the group as one step (distinct rows, disjoint
        // input cells — constraint 2 — and aligned output column).
        let mut step = Step::default();
        let mut used_rows: Vec<usize> = Vec::new();
        let mut used_cells: Vec<CellRef> = Vec::new();
        let mut expected_out_col: Option<u32> = None;
        for idx in chosen {
            let c = &cands[idx];
            if used_rows.contains(&c.out_row)
                || c.ins.iter().any(|cell| used_cells.contains(cell))
            {
                continue; // left for a later cycle
            }
            mapper.ensure_rows(c.out_row + 1);
            let next = mapper.next_col[c.out_row] as u32;
            if let Some(e) = expected_out_col {
                if next != e {
                    continue; // output column would misalign
                }
            }
            expected_out_col = Some(next);
            let out = mapper.alloc_cell(c.out_row);
            used_rows.push(c.out_row);
            used_cells.extend(c.ins.iter().copied());
            step.ops.push(ScheduledOp { node: c.node, kind: c.kind, ins: c.ins.clone(), out });

            match (c.node, c.copy_key) {
                (Some(id), _) => {
                    sched.placement.insert(id, out);
                    scheduled_count += 1;
                    ready.retain(|&g| g != id);
                    if let Some(deps) = dependents.get(&id) {
                        for &g in deps {
                            let r = remaining.get_mut(&g).unwrap();
                            *r -= 1;
                            if *r == 0 {
                                ready.push(g);
                            }
                        }
                    }
                }
                (None, Some(key)) => {
                    copy_done.insert(key, out);
                    sched.copy_count += 1;
                }
                _ => unreachable!(),
            }
        }
        assert!(!step.ops.is_empty(), "empty step");
        sched.steps.push(step);
        let t = sched.steps.len();
        for op in &sched.steps[t - 1].ops {
            if let Some(id) = op.node {
                sched.t_of_node.insert(id, t);
            }
        }
    }

    sched.rows_used = mapper.next_col.len();
    sched.cols_used = mapper.max_col;
    sched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{ops, replicate::replicate};

    fn asap() -> Options {
        Options { mode: Mode::Asap }
    }

    #[test]
    fn stochastic_add_is_4_cycles_any_lanes() {
        // Paper Fig 7b: NOT, AND, AND, OR ⇒ 4 cycles regardless of q.
        for q in [1, 4, 64, 256] {
            let nl = replicate(&ops::scaled_add(), q);
            let s = schedule(&nl, &asap());
            assert_eq!(s.logic_cycles(), 4, "q={q}");
            assert_eq!(s.rows_used, q);
        }
    }

    #[test]
    fn stochastic_multiply_is_2_cycles() {
        let nl = replicate(&ops::multiply(), 256);
        let s = schedule(&nl, &asap());
        assert_eq!(s.logic_cycles(), 2); // NAND + NOT
        assert_eq!(s.min_array(), (256, 4)); // Table 2: 256×4
    }

    #[test]
    fn abs_subtract_cycles_scale_free() {
        let s1 = schedule(&replicate(&ops::abs_subtract(), 1), &asap());
        let s256 = schedule(&replicate(&ops::abs_subtract(), 256), &asap());
        assert_eq!(s1.logic_cycles(), s256.logic_cycles());
    }

    #[test]
    fn layer_mode_never_faster_than_asap() {
        for nl in [
            replicate(&ops::scaled_add(), 8),
            replicate(&ops::exponential(), 8),
            replicate(&ops::scaled_divide(), 8),
        ] {
            let a = schedule(&nl, &Options { mode: Mode::Asap });
            let l = schedule(&nl, &Options { mode: Mode::LayerStrict });
            assert!(a.logic_cycles() <= l.logic_cycles());
        }
    }

    #[test]
    fn all_gates_scheduled_exactly_once() {
        let nl = replicate(&ops::exponential(), 16);
        let s = schedule(&nl, &asap());
        let scheduled: usize = s
            .steps
            .iter()
            .flat_map(|st| &st.ops)
            .filter(|o| o.node.is_some())
            .count();
        assert_eq!(scheduled, nl.gate_count());
    }

    #[test]
    fn deps_complete_before_use() {
        let nl = replicate(&ops::exponential(), 4);
        let s = schedule(&nl, &asap());
        for (id, node) in nl.nodes.iter().enumerate() {
            if let crate::netlist::Node::Gate { ins, .. } = node {
                let t = s.t_of_node[&id];
                for &d in ins {
                    if let crate::netlist::Node::Gate { .. } = nl.nodes[d] {
                        assert!(s.t_of_node[&d] < t, "dep {d} not before {id}");
                    }
                }
            }
        }
    }

    #[test]
    fn divide_schedule_small_and_lane_parallel() {
        let nl = replicate(&ops::scaled_divide(), 32);
        let s = schedule(&nl, &asap());
        assert!(s.logic_cycles() <= 6, "got {}", s.logic_cycles());
        assert_eq!(s.rows_used, 32);
    }

    #[test]
    fn sqrt_charges_addie_cycles() {
        let nl = replicate(&ops::square_root(6), 8);
        let s = schedule(&nl, &asap());
        assert_eq!(s.addie_cycles, 8 * ADDIE_CYCLES);
        // Footprint per lane: 2 inputs + 7 macro cols ≈ Table 2's "×10".
        assert!(s.cols_used >= 9 && s.cols_used <= 11, "cols={}", s.cols_used);
    }

    #[test]
    fn steps_obey_constraints() {
        let nl = replicate(&ops::exponential(), 8);
        let s = schedule(&nl, &asap());
        for step in &s.steps {
            let kind = step.ops[0].kind;
            let mut rows = Vec::new();
            let mut cells = Vec::new();
            let cols0: Vec<u32> = {
                let mut c: Vec<u32> = step.ops[0].ins.iter().map(|c| c.col).collect();
                c.sort_unstable();
                c
            };
            let out_col = step.ops[0].out.col;
            for op in &step.ops {
                assert_eq!(op.kind, kind, "mixed kinds in step");
                assert!(!rows.contains(&op.out.row), "row reuse in step");
                rows.push(op.out.row);
                assert_eq!(op.out.col, out_col, "output column misaligned");
                let mut c: Vec<u32> = op.ins.iter().map(|c| c.col).collect();
                c.sort_unstable();
                assert_eq!(c, cols0, "input columns misaligned");
                for cell in &op.ins {
                    assert!(!cells.contains(cell), "shared input cell");
                    cells.push(*cell);
                }
            }
        }
    }
}
