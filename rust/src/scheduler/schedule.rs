//! Schedule data model produced by the co-scheduling/mapping algorithms.
//!
//! One [`Step`] is one 2T-1MTJ logic cycle: a set of gate instances of
//! the *same kind*, reading the *same input columns* and writing the
//! *same output column*, each in a *distinct row* — the conditions under
//! which one V_SL application fires all of them simultaneously (§4.2's
//! three parallelization constraints plus the shared-column electrical
//! argument of DESIGN.md §7).

use std::collections::HashMap;

use crate::netlist::graph::{GateKind, NodeId};

/// A mapped memory cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CellRef {
    pub row: u32,
    pub col: u32,
}

impl CellRef {
    pub fn new(row: usize, col: usize) -> Self {
        Self { row: row as u32, col: col as u32 }
    }
}

/// One scheduled gate execution. `node` is `None` for copy operations
/// inserted by the mapper (Algorithm 1 lines 15–22).
#[derive(Debug, Clone)]
pub struct ScheduledOp {
    pub node: Option<NodeId>,
    pub kind: GateKind,
    pub ins: Vec<CellRef>,
    pub out: CellRef,
}

/// One logic cycle.
#[derive(Debug, Clone, Default)]
pub struct Step {
    pub ops: Vec<ScheduledOp>,
}

/// The result of co-scheduling + mapping a netlist.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    pub steps: Vec<Step>,
    /// Final cell of each netlist node (gates: their output cell;
    /// inputs/delays/addie: their storage cell).
    pub placement: HashMap<NodeId, CellRef>,
    /// Completion cycle (1-based step index) per gate node.
    pub t_of_node: HashMap<NodeId, usize>,
    pub rows_used: usize,
    pub cols_used: usize,
    /// Copy (BUFF) operations inserted for row alignment.
    pub copy_count: usize,
    /// Extra cycles charged for ADDIE macro nodes (see DESIGN.md §7).
    pub addie_cycles: usize,
    /// Stochastic bit generations: stochastically-written input cells.
    pub sbg_count: usize,
    /// Deterministically-written (binary) input cells.
    pub binary_write_count: usize,
}

impl Schedule {
    /// Logic cycles: scheduled steps + ADDIE macro charge.
    pub fn logic_cycles(&self) -> usize {
        self.steps.len() + self.addie_cycles
    }

    /// Total cycles including the preset lead-in (output-cell presets
    /// overlap consecutive logic ops except the first batch — §5.3.2)
    /// and input initialization (stochastic: preset pass + pulse pass;
    /// binary: one deterministic write pass).
    pub fn total_cycles(&self) -> usize {
        let init = if self.sbg_count > 0 { 2 } else { 1 };
        1 + init + self.logic_cycles()
    }

    /// Number of executed gate operations (including copies).
    pub fn op_count(&self) -> usize {
        self.steps.iter().map(|s| s.ops.len()).sum()
    }

    /// Output-cell presets = one per executed op (preset before logic).
    pub fn preset_count(&self) -> usize {
        self.op_count() + self.sbg_count // input cells preset to '0' too
    }

    /// Minimum array footprint (rows × cols), paper Table 2 column 1.
    pub fn min_array(&self) -> (usize, usize) {
        (self.rows_used, self.cols_used)
    }

    /// Utilized cell count (paper's area metric: number of used cells).
    pub fn used_cells(&self) -> usize {
        // Placed nodes + copy destination cells.
        self.placement.len() + self.copy_count
    }

    /// Histogram of executed op kinds (energy model input).
    pub fn op_histogram(&self) -> HashMap<GateKind, usize> {
        let mut h = HashMap::new();
        for s in &self.steps {
            for op in &s.ops {
                *h.entry(op.kind).or_insert(0) += 1;
            }
        }
        h
    }

    /// Write-traffic per cell (for the lifetime model): every op writes
    /// its output cell once (plus its preset); input cells are written
    /// once at initialization (plus preset for stochastic ones).
    pub fn write_traffic(&self) -> HashMap<CellRef, u64> {
        let mut w: HashMap<CellRef, u64> = HashMap::new();
        for s in &self.steps {
            for op in &s.ops {
                *w.entry(op.out).or_insert(0) += 2; // preset + logic result
            }
        }
        for cell in self.placement.values() {
            *w.entry(*cell).or_insert(0) += 1; // initialization write
        }
        w
    }
}
