//! Minimal error type with `anyhow`-style ergonomics (the offline crate
//! set has no `anyhow`). Provides [`Error`], [`Result`], the [`Context`]
//! extension trait for `Result`/`Option`, and the crate-root `bail!` /
//! `ensure!` macros. Context is accumulated as a `": "`-joined message
//! chain, so `{e}` and `{e:#}` both print the full story.

use std::fmt;

/// A message-chain error. Construct via [`Error::msg`], the `bail!` /
/// `ensure!` macros, or [`Context`] adapters.
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style adapters: attach a message to the error path
/// of a `Result` (any `Display` error) or turn an `Option` into a
/// `Result` with a message.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::error::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        "nope".parse::<u32>().context("parsing the answer")
    }

    #[test]
    fn context_chains_messages() {
        let e = fails().with_context(|| "loading config").unwrap_err();
        let s = format!("{e:#}");
        assert!(s.starts_with("loading config: parsing the answer:"), "{s}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too large: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
        assert_eq!(f(11).unwrap_err().to_string(), "x too large: 11");
    }
}
