//! S12 — bitflip fault injection (paper §5.3.2 "Bitflip", Table 4).
//!
//! Faults are injected at the input/output nodes of the arithmetic
//! operations, exactly as the paper describes: for the stochastic
//! methods a fraction `rate` of stream bits flip; for the 8-bit binary
//! baseline each of the 8 bits of a value flips with probability
//! `rate` (bit significance makes the damage asymmetric — the effect
//! Table 4 demonstrates).
//!
//! Two generations of models live here:
//!
//! * the scalar-model injectors below (`inject_*`), used by the apps'
//!   `stoch_value`/`binary_value` Table 4 evaluation;
//! * [`FaultPlan`], the lane-engine fault model: a *stateless*,
//!   counter-based mask source addressed by `(site, row, t)`. Because a
//!   mask bit is a pure function of its coordinates (one SplitMix64
//!   finalizer evaluation, thresholded exactly like the integer SNG in
//!   `sc::sng`), the gate-major scalar reference path and the
//!   time-major lane-word path compute *identical* masks in any
//!   evaluation order, and the fault source never perturbs the SNG
//!   draw order — the property the differential suite in
//!   `tests/fault.rs` pins.

use crate::sc::bitstream::Bitstream;
use crate::sc::sng::cutoff;
use crate::util::prng::Xoshiro256;

// ---- lane-engine fault model -------------------------------------------

/// Injection-site classes of the lane engine (packed into the high bits
/// of a [`site`] id).
const CLASS_SNG: u64 = 1;
const CLASS_GATE: u64 = 2;
const CLASS_STOB: u64 = 3;

/// Odd multiplier keys decorrelating the three mask coordinates before
/// the finalizer (same constant family as `util::prng::SplitMix64`).
const K_SITE: u64 = 0x9E37_79B9_7F4A_7C15;
const K_ROW: u64 = 0xBF58_476D_1CE4_E5B9;
const K_T: u64 = 0x94D0_49BB_1331_11EB;

/// SplitMix64 finalizer: a bijective avalanche mix of the combined
/// coordinate word. Statistical quality is pinned by
/// `tests/fault.rs::mask_flip_rate_matches_configured_rate`.
#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Pack an injection site id from (class, stage, index). 20 stage bits
/// and 40 index bits — far beyond any compiled pipeline.
#[inline]
fn site(class: u64, stage: usize, index: usize) -> u64 {
    (class << 60) | ((stage as u64) << 40) | index as u64
}

/// Per-wave fault-injection plan for the lane-major engine: independent
/// per-bit flip probabilities at the three insertion points of a staged
/// wave (SNG output streams, gate-instruction outputs, StoB readout
/// streams), plus the mask seed. `Copy` so it travels inside the serve
/// layer's `WaveKnobs` without allocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Per-bit flip probability on every generated SNG input stream.
    pub sng_rate: f64,
    /// Per-bit flip probability on every gate (and ADDIE) output.
    pub gate_rate: f64,
    /// Per-bit flip probability on every stage-output stream as it is
    /// read out by the StoB vertical counter.
    pub stob_rate: f64,
    /// Mask seed; independent of the wave's SNG seed.
    pub seed: u64,
}

impl FaultPlan {
    /// Same flip rate at every insertion point.
    pub fn uniform(rate: f64, seed: u64) -> Self {
        Self { sng_rate: rate, gate_rate: rate, stob_rate: rate, seed }
    }

    /// True when every rate thresholds to a zero cutoff — the plan can
    /// never flip a bit (rate-0.0 instrumentation).
    pub fn is_noop(&self) -> bool {
        let c = self.cutoffs();
        c.sng == 0 && c.gate == 0 && c.stob == 0
    }

    /// Resolve the rates into integer SNG-style cutoffs once per wave.
    pub fn cutoffs(&self) -> FaultCutoffs {
        FaultCutoffs {
            seed: self.seed,
            sng: cutoff(self.sng_rate),
            gate: cutoff(self.gate_rate),
            stob: cutoff(self.stob_rate),
        }
    }
}

/// A [`FaultPlan`] with its rates pre-thresholded to the integer
/// cutoffs the mask generator compares against (`flip ⇔ (mix(..) >> 11)
/// < cutoff`, exactly the `sc::sng` comparison, so a rate maps to the
/// same flip probability an SNG input of that value would have).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultCutoffs {
    pub seed: u64,
    pub sng: u64,
    pub gate: u64,
    pub stob: u64,
}

impl FaultCutoffs {
    #[inline]
    pub fn sng_site(&self, stage: usize, input: usize) -> u64 {
        site(CLASS_SNG, stage, input)
    }

    #[inline]
    pub fn gate_site(&self, stage: usize, slot: usize) -> u64 {
        site(CLASS_GATE, stage, slot)
    }

    #[inline]
    pub fn stob_site(&self, stage: usize, output: usize) -> u64 {
        site(CLASS_STOB, stage, output)
    }

    /// The mask bit for one `(site, row, t)` coordinate: a pure
    /// function, identical no matter which engine path asks.
    #[inline]
    pub fn mask_bit(&self, cutoff: u64, site: u64, row: u64, t: u64) -> bool {
        if cutoff == 0 {
            return false;
        }
        let z = self.seed
            ^ site.wrapping_mul(K_SITE)
            ^ row.wrapping_mul(K_ROW)
            ^ t.wrapping_mul(K_T);
        (mix(z) >> 11) < cutoff
    }

    /// Lane-word masks for one time step of a `u64×W` lane block: bit
    /// `l-64w` of word `w` is the mask bit of block lane `l` (global
    /// row `row0 + l`). Dead lanes (`l >= lanes`) stay zero.
    #[inline]
    pub fn mask_words<const W: usize>(
        &self,
        cutoff: u64,
        site: u64,
        row0: usize,
        lanes: usize,
        t: usize,
    ) -> [u64; W] {
        let mut out = [0u64; W];
        if cutoff == 0 {
            return out;
        }
        for (w, word) in out.iter_mut().enumerate() {
            let lo = w * 64;
            for l in lo..lanes.min(lo + 64) {
                if self.mask_bit(cutoff, site, (row0 + l) as u64, t as u64) {
                    *word |= 1u64 << (l - lo);
                }
            }
        }
        out
    }

    /// Flip the masked bits of a scalar-path stream in place (the
    /// scalar reference's counterpart of the lane-word XOR).
    pub fn apply_to_stream(&self, bs: &mut Bitstream, cutoff: u64, site: u64, row: u64) {
        if cutoff == 0 {
            return;
        }
        for t in 0..bs.len() {
            if self.mask_bit(cutoff, site, row, t as u64) {
                bs.flip(t);
            }
        }
    }
}

// ---- scalar-model injectors (Table 4 node-level model) ------------------

/// Node-level fault model (the Table 4 interpretation): with probability
/// `rate`, the node's stored value suffers ONE random bitflip. For a
/// 256-bit SN that perturbs the value by 1/256; for an 8-bit binary word
/// it can flip the MSB — the asymmetry Table 4 demonstrates.
pub fn inject_stream_node(bs: &Bitstream, rate: f64, rng: &mut Xoshiro256) -> Bitstream {
    let mut out = bs.clone();
    if rate > 0.0 && rng.bernoulli(rate) {
        out.flip(rng.next_index(bs.len()));
    }
    out
}

/// Node-level single-bit flip on a fixed-point value in [0,1].
pub fn inject_binary_node(value: f64, bits: u32, rate: f64, rng: &mut Xoshiro256) -> f64 {
    let steps = 1u64 << bits;
    let mut q = ((value.clamp(0.0, 1.0) * steps as f64).round() as u64).min(steps - 1);
    if rate > 0.0 && rng.bernoulli(rate) {
        q ^= 1 << rng.next_below(bits as u64);
    }
    q as f64 / steps as f64
}

/// Flip each bit of a bitstream independently with probability `rate`
/// (the *saturation* fault model; Table 4 uses the node-level model
/// above — see the module docs).
pub fn inject_stream(bs: &Bitstream, rate: f64, rng: &mut Xoshiro256) -> Bitstream {
    let mut out = bs.clone();
    if rate <= 0.0 {
        return out;
    }
    for i in 0..bs.len() {
        if rng.bernoulli(rate) {
            out.flip(i);
        }
    }
    out
}

/// Flip each of the `bits` bits of a fixed-point value (in [0,1), with
/// `bits` fractional bits) independently with probability `rate`.
pub fn inject_binary(value: f64, bits: u32, rate: f64, rng: &mut Xoshiro256) -> f64 {
    let steps = 1u64 << bits;
    let mut q = ((value.clamp(0.0, 1.0) * steps as f64).round() as u64).min(steps - 1);
    for k in 0..bits {
        if rng.bernoulli(rate) {
            q ^= 1 << k;
        }
    }
    q as f64 / steps as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_is_identity() {
        let mut rng = Xoshiro256::seeded(1);
        let bs = Bitstream::sample(0.5, 1024, &mut rng);
        assert_eq!(inject_stream(&bs, 0.0, &mut rng), bs);
        assert_eq!(inject_binary(0.625, 8, 0.0, &mut rng), 0.625);
    }

    #[test]
    fn stream_flip_rate_statistical() {
        let mut rng = Xoshiro256::seeded(2);
        let bs = Bitstream::zeros(100_000);
        let flipped = inject_stream(&bs, 0.1, &mut rng);
        let rate = flipped.popcount() as f64 / 100_000.0;
        assert!((rate - 0.1).abs() < 0.005, "rate={rate}");
    }

    #[test]
    fn stream_value_shift_is_bounded() {
        // A flipped unipolar stream of value p moves toward 0.5:
        // E[value'] = p(1-r) + (1-p)r.
        let mut rng = Xoshiro256::seeded(3);
        let bs = Bitstream::sample(0.8, 65536, &mut rng);
        let f = inject_stream(&bs, 0.2, &mut rng);
        let want = 0.8 * 0.8 + 0.2 * 0.2;
        assert!((f.value() - want).abs() < 0.01);
    }

    #[test]
    fn binary_flip_can_be_catastrophic() {
        // MSB flip changes the value by 0.5 — the binary fragility the
        // paper's Table 4 shows.
        let mut rng = Xoshiro256::seeded(4);
        let mut worst: f64 = 0.0;
        for _ in 0..200 {
            let v = inject_binary(0.0, 8, 0.15, &mut rng);
            worst = worst.max(v);
        }
        assert!(worst >= 0.5, "worst={worst}");
    }
}

#[cfg(test)]
mod plan_tests {
    use super::*;

    #[test]
    fn zero_rate_plan_is_noop_and_all_masks_zero() {
        let p = FaultPlan::uniform(0.0, 0xDEAD);
        assert!(p.is_noop());
        let c = p.cutoffs();
        assert_eq!((c.sng, c.gate, c.stob), (0, 0, 0));
        let w: [u64; 4] = c.mask_words(c.sng, c.sng_site(0, 0), 0, 256, 7);
        assert_eq!(w, [0u64; 4]);
        // Negative and NaN rates saturate to cutoff 0 too (sng::cutoff).
        assert!(FaultPlan::uniform(-1.0, 1).is_noop());
        assert!(FaultPlan::uniform(f64::NAN, 1).is_noop());
    }

    #[test]
    fn lane_words_agree_with_scalar_mask_bits() {
        // The lane-word builder must pack exactly the per-(row, t)
        // scalar mask bits — the property that makes the faulty lane
        // path and faulty scalar reference bit-identical.
        let c = FaultPlan::uniform(0.25, 99).cutoffs();
        let site = c.gate_site(2, 5);
        let (row0, lanes) = (64usize, 130usize);
        for t in 0..32usize {
            let words: [u64; 4] = c.mask_words(c.gate, site, row0, lanes, t);
            for l in 0..256usize {
                let want = l < lanes && c.mask_bit(c.gate, site, (row0 + l) as u64, t as u64);
                let got = (words[l / 64] >> (l % 64)) & 1 == 1;
                assert_eq!(got, want, "t={t} l={l}");
            }
        }
    }

    #[test]
    fn sites_and_seeds_decorrelate_masks() {
        let c = FaultPlan::uniform(0.5, 7).cutoffs();
        let c2 = FaultPlan::uniform(0.5, 8).cutoffs();
        let a: [u64; 1] = c.mask_words(c.sng, c.sng_site(0, 0), 0, 64, 0);
        let b: [u64; 1] = c.mask_words(c.sng, c.sng_site(0, 1), 0, 64, 0);
        let d: [u64; 1] = c2.mask_words(c2.sng, c2.sng_site(0, 0), 0, 64, 0);
        assert_ne!(a, b, "site must change the mask");
        assert_ne!(a, d, "seed must change the mask");
    }

    #[test]
    fn apply_to_stream_matches_mask_bits() {
        let c = FaultPlan::uniform(0.3, 41).cutoffs();
        let site = c.stob_site(1, 0);
        let mut bs = Bitstream::zeros(200);
        c.apply_to_stream(&mut bs, c.stob, site, 9);
        for t in 0..200usize {
            assert_eq!(bs.get(t), c.mask_bit(c.stob, site, 9, t as u64), "t={t}");
        }
    }
}

#[cfg(test)]
mod node_tests {
    use super::*;

    #[test]
    fn node_flip_perturbs_stream_by_one_bit_at_most() {
        let mut rng = Xoshiro256::seeded(9);
        let bs = Bitstream::sample(0.5, 256, &mut rng);
        for _ in 0..100 {
            let f = inject_stream_node(&bs, 1.0, &mut rng);
            let diff = f.xor(&bs).popcount();
            assert_eq!(diff, 1);
        }
        let same = inject_stream_node(&bs, 0.0, &mut rng);
        assert_eq!(same, bs);
    }

    #[test]
    fn node_flip_on_binary_can_hit_msb() {
        let mut rng = Xoshiro256::seeded(10);
        let mut seen_large = false;
        for _ in 0..200 {
            let v = inject_binary_node(0.0, 8, 1.0, &mut rng);
            if v >= 0.5 {
                seen_large = true;
            }
        }
        assert!(seen_large, "MSB flip never observed");
    }
}
