//! S12 — bitflip fault injection (paper §5.3.2 "Bitflip", Table 4).
//!
//! Faults are injected at the input/output nodes of the arithmetic
//! operations, exactly as the paper describes: for the stochastic
//! methods a fraction `rate` of stream bits flip; for the 8-bit binary
//! baseline each of the 8 bits of a value flips with probability
//! `rate` (bit significance makes the damage asymmetric — the effect
//! Table 4 demonstrates).

use crate::sc::bitstream::Bitstream;
use crate::util::prng::Xoshiro256;

/// Node-level fault model (the Table 4 interpretation): with probability
/// `rate`, the node's stored value suffers ONE random bitflip. For a
/// 256-bit SN that perturbs the value by 1/256; for an 8-bit binary word
/// it can flip the MSB — the asymmetry Table 4 demonstrates.
pub fn inject_stream_node(bs: &Bitstream, rate: f64, rng: &mut Xoshiro256) -> Bitstream {
    let mut out = bs.clone();
    if rate > 0.0 && rng.bernoulli(rate) {
        out.flip(rng.next_index(bs.len()));
    }
    out
}

/// Node-level single-bit flip on a fixed-point value in [0,1].
pub fn inject_binary_node(value: f64, bits: u32, rate: f64, rng: &mut Xoshiro256) -> f64 {
    let steps = 1u64 << bits;
    let mut q = ((value.clamp(0.0, 1.0) * steps as f64).round() as u64).min(steps - 1);
    if rate > 0.0 && rng.bernoulli(rate) {
        q ^= 1 << rng.next_below(bits as u64);
    }
    q as f64 / steps as f64
}

/// Flip each bit of a bitstream independently with probability `rate`
/// (the *saturation* fault model; Table 4 uses the node-level model
/// above — see the module docs).
pub fn inject_stream(bs: &Bitstream, rate: f64, rng: &mut Xoshiro256) -> Bitstream {
    let mut out = bs.clone();
    if rate <= 0.0 {
        return out;
    }
    for i in 0..bs.len() {
        if rng.bernoulli(rate) {
            out.flip(i);
        }
    }
    out
}

/// Flip each of the `bits` bits of a fixed-point value (in [0,1), with
/// `bits` fractional bits) independently with probability `rate`.
pub fn inject_binary(value: f64, bits: u32, rate: f64, rng: &mut Xoshiro256) -> f64 {
    let steps = 1u64 << bits;
    let mut q = ((value.clamp(0.0, 1.0) * steps as f64).round() as u64).min(steps - 1);
    for k in 0..bits {
        if rng.bernoulli(rate) {
            q ^= 1 << k;
        }
    }
    q as f64 / steps as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_is_identity() {
        let mut rng = Xoshiro256::seeded(1);
        let bs = Bitstream::sample(0.5, 1024, &mut rng);
        assert_eq!(inject_stream(&bs, 0.0, &mut rng), bs);
        assert_eq!(inject_binary(0.625, 8, 0.0, &mut rng), 0.625);
    }

    #[test]
    fn stream_flip_rate_statistical() {
        let mut rng = Xoshiro256::seeded(2);
        let bs = Bitstream::zeros(100_000);
        let flipped = inject_stream(&bs, 0.1, &mut rng);
        let rate = flipped.popcount() as f64 / 100_000.0;
        assert!((rate - 0.1).abs() < 0.005, "rate={rate}");
    }

    #[test]
    fn stream_value_shift_is_bounded() {
        // A flipped unipolar stream of value p moves toward 0.5:
        // E[value'] = p(1-r) + (1-p)r.
        let mut rng = Xoshiro256::seeded(3);
        let bs = Bitstream::sample(0.8, 65536, &mut rng);
        let f = inject_stream(&bs, 0.2, &mut rng);
        let want = 0.8 * 0.8 + 0.2 * 0.2;
        assert!((f.value() - want).abs() < 0.01);
    }

    #[test]
    fn binary_flip_can_be_catastrophic() {
        // MSB flip changes the value by 0.5 — the binary fragility the
        // paper's Table 4 shows.
        let mut rng = Xoshiro256::seeded(4);
        let mut worst: f64 = 0.0;
        for _ in 0..200 {
            let v = inject_binary(0.0, 8, 0.15, &mut rng);
            worst = worst.max(v);
        }
        assert!(worst >= 0.5, "worst={worst}");
    }
}

#[cfg(test)]
mod node_tests {
    use super::*;

    #[test]
    fn node_flip_perturbs_stream_by_one_bit_at_most() {
        let mut rng = Xoshiro256::seeded(9);
        let bs = Bitstream::sample(0.5, 256, &mut rng);
        for _ in 0..100 {
            let f = inject_stream_node(&bs, 1.0, &mut rng);
            let diff = f.xor(&bs).popcount();
            assert_eq!(diff, 1);
        }
        let same = inject_stream_node(&bs, 0.0, &mut rng);
        assert_eq!(same, bs);
    }

    #[test]
    fn node_flip_on_binary_can_hit_msb() {
        let mut rng = Xoshiro256::seeded(10);
        let mut seen_large = false;
        for _ in 0..200 {
            let v = inject_binary_node(0.0, 8, 1.0, &mut rng);
            if v >= 0.5 {
                seen_large = true;
            }
        }
        assert!(seen_large, "MSB flip never observed");
    }
}
