//! S11 — lifetime model (paper Eq 11 and §5.3.2 "Lifetime").
//!
//!   Lifetime ∝ E_max × C / B
//!
//! with endurance E_max (technology constant, >10^15 for STT-MRAM),
//! C the *utilized* cell count (the paper replaces total capacity with
//! used cells since no wear-leveling is modeled), and B the write
//! traffic. Comparing two methods on the same technology cancels E_max,
//! so relative lifetime = (C₁/B₁)/(C₂/B₂).

/// Write-traffic + capacity summary of one method executing one workload.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WearProfile {
    /// Cells ever written (utilized capacity C).
    pub used_cells: u64,
    /// Total write operations (traffic B), including presets.
    pub writes: u64,
    /// Peak per-cell write count (hot-spot pressure; reported for the
    /// bit-serial [22] comparison, which stresses single cells).
    pub max_cell_writes: u64,
}

impl WearProfile {
    /// Lifetime figure-of-merit C/B (unitless; relative use only).
    /// `None` when no writes were recorded — an empty profile has no
    /// lifetime to speak of (used to panic; serving-layer profiles are
    /// legitimately empty before the first wave).
    pub fn merit(&self) -> Option<f64> {
        if self.writes == 0 {
            return None;
        }
        Some(self.used_cells as f64 / self.writes as f64)
    }

    /// A stricter merit using the hottest cell: C / (max_cell_writes ×
    /// used_cells) ∝ 1/max_cell_writes — the first-cell-to-die model.
    /// The paper's Eq 11 assumes uniform distribution over used cells;
    /// the hot-spot variant is reported alongside (Fig 11 discussion
    /// attributes [22]'s deficiency to "access stress" on certain cells).
    /// `None` when no cell was ever written.
    pub fn hotspot_merit(&self) -> Option<f64> {
        if self.max_cell_writes == 0 {
            return None;
        }
        Some(1.0 / self.max_cell_writes as f64)
    }

    /// Fold one more wave of the *same* workload into this profile: the
    /// wave re-writes the same subarray cells, so capacity is the max,
    /// traffic sums, and the hottest cell keeps accumulating.
    pub fn absorb_wave(&mut self, wave: &WearProfile) {
        self.used_cells = self.used_cells.max(wave.used_cells);
        self.writes += wave.writes;
        self.max_cell_writes += wave.max_cell_writes;
    }

    /// Fold a profile of *disjoint* cells (another app / another bank)
    /// into this one: capacity and traffic sum; the pool's hottest cell
    /// is the max of the parts.
    pub fn merge(&mut self, other: &WearProfile) {
        self.used_cells += other.used_cells;
        self.writes += other.writes;
        self.max_cell_writes = self.max_cell_writes.max(other.max_cell_writes);
    }
}

/// Relative lifetime improvement of `a` over `b` (Eq 11 ratio); `None`
/// if either profile recorded no writes.
pub fn improvement(a: &WearProfile, b: &WearProfile) -> Option<f64> {
    Some(a.merit()? / b.merit()?)
}

/// Hot-spot (first-death) lifetime improvement of `a` over `b`; `None`
/// if either profile never wrote a cell.
pub fn hotspot_improvement(a: &WearProfile, b: &WearProfile) -> Option<f64> {
    Some(a.hotspot_merit()? / b.hotspot_merit()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merit_ratio() {
        let a = WearProfile { used_cells: 1000, writes: 100, max_cell_writes: 1 };
        let b = WearProfile { used_cells: 100, writes: 1000, max_cell_writes: 100 };
        assert!((improvement(&a, &b).unwrap() - 100.0).abs() < 1e-12);
        assert!((hotspot_improvement(&a, &b).unwrap() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn uniform_spread_beats_hotspot() {
        // Same traffic, same capacity; concentrated writes lose on the
        // hot-spot metric.
        let spread = WearProfile { used_cells: 256, writes: 1024, max_cell_writes: 4 };
        let hot = WearProfile { used_cells: 256, writes: 1024, max_cell_writes: 512 };
        assert_eq!(improvement(&spread, &hot), Some(1.0));
        assert!(hotspot_improvement(&spread, &hot).unwrap() > 100.0);
    }

    #[test]
    fn empty_profiles_yield_none_not_panics() {
        // The zero-write / zero-cell edges (a serving profile before its
        // first wave) must be `None`, not an assert.
        let empty = WearProfile::default();
        assert_eq!(empty.merit(), None);
        assert_eq!(empty.hotspot_merit(), None);
        let live = WearProfile { used_cells: 8, writes: 2, max_cell_writes: 1 };
        assert_eq!(improvement(&live, &empty), None);
        assert_eq!(improvement(&empty, &live), None);
        assert_eq!(hotspot_improvement(&empty, &live), None);
        // Zero writes but nonzero capacity is still merit-less.
        let unused = WearProfile { used_cells: 64, writes: 0, max_cell_writes: 0 };
        assert_eq!(unused.merit(), None);
    }

    #[test]
    fn wave_absorb_vs_disjoint_merge() {
        // Absorbing a second wave of the same app: same cells (max),
        // summed traffic, hottest cell accumulates.
        let wave = WearProfile { used_cells: 128, writes: 1000, max_cell_writes: 512 };
        let mut app = WearProfile::default();
        app.absorb_wave(&wave);
        app.absorb_wave(&wave);
        assert_eq!(app, WearProfile { used_cells: 128, writes: 2000, max_cell_writes: 1024 });
        // Merging another app's (disjoint) cells: capacity sums, the
        // pool's hottest cell is the max of the parts.
        let mut pool = WearProfile::default();
        pool.merge(&app);
        pool.merge(&WearProfile { used_cells: 64, writes: 100, max_cell_writes: 9999 });
        assert_eq!(
            pool,
            WearProfile { used_cells: 192, writes: 2100, max_cell_writes: 9999 }
        );
    }
}
