//! S11 — lifetime model (paper Eq 11 and §5.3.2 "Lifetime").
//!
//!   Lifetime ∝ E_max × C / B
//!
//! with endurance E_max (technology constant, >10^15 for STT-MRAM),
//! C the *utilized* cell count (the paper replaces total capacity with
//! used cells since no wear-leveling is modeled), and B the write
//! traffic. Comparing two methods on the same technology cancels E_max,
//! so relative lifetime = (C₁/B₁)/(C₂/B₂).

/// Write-traffic + capacity summary of one method executing one workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WearProfile {
    /// Cells ever written (utilized capacity C).
    pub used_cells: u64,
    /// Total write operations (traffic B), including presets.
    pub writes: u64,
    /// Peak per-cell write count (hot-spot pressure; reported for the
    /// bit-serial [22] comparison, which stresses single cells).
    pub max_cell_writes: u64,
}

impl WearProfile {
    /// Lifetime figure-of-merit C/B (unitless; relative use only).
    pub fn merit(&self) -> f64 {
        assert!(self.writes > 0, "no writes recorded");
        self.used_cells as f64 / self.writes as f64
    }

    /// A stricter merit using the hottest cell: C / (max_cell_writes ×
    /// used_cells) ∝ 1/max_cell_writes — the first-cell-to-die model.
    /// The paper's Eq 11 assumes uniform distribution over used cells;
    /// the hot-spot variant is reported alongside (Fig 11 discussion
    /// attributes [22]'s deficiency to "access stress" on certain cells).
    pub fn hotspot_merit(&self) -> f64 {
        assert!(self.max_cell_writes > 0);
        1.0 / self.max_cell_writes as f64
    }
}

/// Relative lifetime improvement of `a` over `b` (Eq 11 ratio).
pub fn improvement(a: &WearProfile, b: &WearProfile) -> f64 {
    a.merit() / b.merit()
}

/// Hot-spot (first-death) lifetime improvement of `a` over `b`.
pub fn hotspot_improvement(a: &WearProfile, b: &WearProfile) -> f64 {
    a.hotspot_merit() / b.hotspot_merit()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merit_ratio() {
        let a = WearProfile { used_cells: 1000, writes: 100, max_cell_writes: 1 };
        let b = WearProfile { used_cells: 100, writes: 1000, max_cell_writes: 100 };
        assert!((improvement(&a, &b) - 100.0).abs() < 1e-12);
        assert!((hotspot_improvement(&a, &b) - 100.0).abs() < 1e-12);
    }

    #[test]
    fn uniform_spread_beats_hotspot() {
        // Same traffic, same capacity; concentrated writes lose on the
        // hot-spot metric.
        let spread = WearProfile { used_cells: 256, writes: 1024, max_cell_writes: 4 };
        let hot = WearProfile { used_cells: 256, writes: 1024, max_cell_writes: 512 };
        assert_eq!(improvement(&spread, &hot), 1.0);
        assert!(hotspot_improvement(&spread, &hot) > 100.0);
    }
}
