//! S6 — 2T-1MTJ subarray simulator.
//!
//! A digital, cycle-level model of the IMC-A array of §2.2: cells hold
//! P/AP state; memory mode presets/writes cells (deterministic or
//! stochastic via the §2.3 pulse); logic mode executes one gate per
//! cycle across aligned rows, with the output preset semantics of the
//! gate tables ([3,8]). Executing a schedule here validates that the
//! mapping of Algorithm 1 computes the same bitstreams as the functional
//! evaluator — the cross-layer check of DESIGN.md S6↔S7.

pub mod subarray;

pub use subarray::{execute_replicated, ExecStats, Subarray};
