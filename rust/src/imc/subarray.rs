//! Cell-level subarray state machine and the schedule executor.

use std::collections::HashMap;

use crate::netlist::graph::{InputClass, Netlist, Node, NodeId};
use crate::sc::bitstream::Bitstream;
use crate::sc::ops::{Addie, ADDIE_SEED};
use crate::scheduler::schedule::{CellRef, Schedule};
use crate::util::prng::Xoshiro256;

/// Dynamic execution statistics (should agree with the static counts the
/// schedule reports; asserted in tests).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExecStats {
    pub presets: u64,
    pub stochastic_writes: u64,
    pub deterministic_writes: u64,
    pub logic_ops: u64,
    pub logic_cycles: u64,
    pub passes: u64,
}

/// A rows×cols 2T-1MTJ subarray.
#[derive(Debug, Clone)]
pub struct Subarray {
    pub rows: usize,
    pub cols: usize,
    state: Vec<bool>,
    /// Per-cell write counter (endurance / lifetime model input).
    pub write_counts: Vec<u64>,
}

impl Subarray {
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols, state: vec![false; rows * cols], write_counts: vec![0; rows * cols] }
    }

    #[inline]
    fn idx(&self, c: CellRef) -> usize {
        debug_assert!((c.row as usize) < self.rows && (c.col as usize) < self.cols);
        c.row as usize * self.cols + c.col as usize
    }

    #[inline]
    pub fn read(&self, c: CellRef) -> bool {
        self.state[self.idx(c)]
    }

    /// Memory-mode deterministic write.
    pub fn write(&mut self, c: CellRef, v: bool) {
        let i = self.idx(c);
        self.state[i] = v;
        self.write_counts[i] += 1;
    }

    /// Preset (a write of the gate's required output preset value).
    pub fn preset(&mut self, c: CellRef, v: bool) {
        self.write(c, v);
    }

    /// Stochastic write: the cell is preset to '0' then a pulse with
    /// switching probability `p` is applied (§2.3). One physical write.
    pub fn stochastic_write(&mut self, c: CellRef, p: f64, rng: &mut Xoshiro256) {
        let i = self.idx(c);
        self.state[i] = rng.bernoulli(p);
        self.write_counts[i] += 1;
    }

    /// Inject a bitflip (soft error / disturb) — no write counted.
    pub fn flip(&mut self, c: CellRef) {
        let i = self.idx(c);
        self.state[i] = !self.state[i];
    }

    /// Total writes across cells.
    pub fn total_writes(&self) -> u64 {
        self.write_counts.iter().sum()
    }

    /// Number of cells written at least once ("used cells" area metric).
    pub fn used_cells(&self) -> usize {
        self.write_counts.iter().filter(|&&w| w > 0).count()
    }
}

/// Execute a scheduled, lane-replicated netlist on a subarray over full
/// input bitstreams, in ⌈BL/q⌉ passes of q lanes (the pipeline approach
/// of §4.3 within one subarray).
///
/// * `base` — the single-lane netlist the replication came from.
/// * `rep` — the replicated netlist that `sched` was produced from.
/// * `sched` — Algorithm 1 output for `rep`.
/// * `inputs` — full-length bitstreams keyed by base PI name.
///
/// Returns the output bitstreams (keyed by base output name) plus stats.
///
/// Feedback handling: circuits containing `Delay` nodes are executed
/// lane-sequentially within each pass (the JK state chains along the
/// bit order); `Addie` macros integrate over the full stream in bit
/// order at readout (the local-accumulator realization — DESIGN.md §7).
pub fn execute_replicated(
    base: &Netlist,
    rep: &Netlist,
    sched: &Schedule,
    inputs: &HashMap<String, Bitstream>,
    q: usize,
    array: &mut Subarray,
    rng: &mut Xoshiro256,
) -> (HashMap<String, Bitstream>, ExecStats) {
    let bl = inputs.values().next().expect("no inputs").len();
    for b in inputs.values() {
        assert_eq!(b.len(), bl);
    }
    let passes = bl.div_ceil(q);
    let mut stats = ExecStats::default();

    let has_delay = rep.nodes.iter().any(|n| matches!(n, Node::Delay { .. }));
    // Map replicated output names "name@lane" → (base name, lane).
    let mut outs: HashMap<String, Bitstream> = base
        .outputs
        .iter()
        .map(|(n, _)| (n.clone(), Bitstream::zeros(bl)))
        .collect();

    // Delay state carried across lanes and passes, per base-delay chain.
    // Keyed by the replicated delay node's *column* signature: all lanes
    // of one base delay share a column. value = latest q_next.
    let mut delay_carry: HashMap<u32, bool> = HashMap::new();
    for (id, node) in rep.nodes.iter().enumerate() {
        if let Node::Delay { init, .. } = node {
            let cell = sched.placement[&id];
            delay_carry.entry(cell.col).or_insert(*init);
        }
    }

    // Addie taps: (base addie) → collected x1/x2 streams for readout.
    let mut addie_taps: Vec<(NodeId, Bitstream, Bitstream)> = base
        .nodes
        .iter()
        .enumerate()
        .filter_map(|(id, n)| match n {
            Node::Addie { .. } => Some((id, Bitstream::zeros(bl), Bitstream::zeros(bl))),
            _ => None,
        })
        .collect();

    for pass in 0..passes {
        stats.passes += 1;
        let lanes = q.min(bl - pass * q);

        // ---- Input initialization: preset + stochastic/deterministic
        // write of each PI cell for this pass's lanes.
        for (id, node) in rep.nodes.iter().enumerate() {
            if let Node::Input { name, row: r0, rows, class, .. } = node {
                let base_name = name.as_str();
                let stream = inputs
                    .get(base_name)
                    .unwrap_or_else(|| panic!("missing input '{base_name}'"));
                let col = sched.placement[&id].col;
                for lane in 0..lanes.min(*rows) {
                    let t = pass * q + lane;
                    if t >= bl {
                        break;
                    }
                    let cell = CellRef::new(r0 + lane, col as usize);
                    match class {
                        InputClass::BinaryBit => {
                            array.write(cell, stream.get(t));
                            stats.deterministic_writes += 1;
                        }
                        _ => {
                            // Preset to '0' then stochastic pulse. The
                            // realized bit is the *given* stream's bit
                            // (the stream was already sampled with the
                            // right probability by the caller).
                            array.preset(cell, false);
                            stats.presets += 1;
                            array.write(cell, stream.get(t));
                            stats.stochastic_writes += 1;
                        }
                    }
                }
            }
        }

        // ---- Logic: execute scheduled steps. For feedback circuits the
        // lanes run sequentially (bit order); otherwise all lanes of a
        // step fire in one cycle.
        let lane_range: Box<dyn Iterator<Item = Option<usize>>> = if has_delay {
            Box::new((0..lanes).map(Some))
        } else {
            Box::new(std::iter::once(None))
        };
        for lane_filter in lane_range {
            // Refresh delay cells for this lane (or all lanes at once
            // for feed-forward circuits — no delay cells exist then).
            for (id, node) in rep.nodes.iter().enumerate() {
                if let Node::Delay { row, .. } = node {
                    if lane_filter.map_or(true, |l| *row == l) {
                        let cell = sched.placement[&id];
                        let v = delay_carry[&cell.col];
                        array.write(cell, v);
                        stats.deterministic_writes += 1;
                    }
                }
            }
            for step in &sched.steps {
                let mut fired = false;
                for op in &step.ops {
                    if let Some(l) = lane_filter {
                        if op.out.row as usize != l {
                            continue;
                        }
                    }
                    if op.out.row as usize >= lanes {
                        continue; // tail pass: lane not active
                    }
                    // Preset output, then logic.
                    array.preset(op.out, op.kind.preset_value());
                    stats.presets += 1;
                    let ins: Vec<bool> = op.ins.iter().map(|&c| array.read(c)).collect();
                    array.write(op.out, op.kind.eval(&ins));
                    stats.logic_ops += 1;
                    fired = true;
                }
                if fired {
                    stats.logic_cycles += 1;
                }
            }
            // Latch q_next for each delay chain from this lane's value.
            for (id, node) in rep.nodes.iter().enumerate() {
                if let Node::Delay { input, row, .. } = node {
                    if lane_filter.map_or(true, |l| *row == l) && *row < lanes {
                        let cell = sched.placement[&id];
                        let next = array.read(sched.placement[input]);
                        delay_carry.insert(cell.col, next);
                    }
                }
            }
        }

        // ---- Readout: collect outputs and ADDIE taps for this pass.
        for (name, oid) in &rep.outputs {
            let (base_name, lane) = name
                .rsplit_once('@')
                .map(|(n, l)| (n.to_string(), l.parse::<usize>().unwrap()))
                .unwrap_or_else(|| (name.clone(), 0));
            if lane >= lanes {
                continue;
            }
            let t = pass * q + lane;
            if t >= bl {
                continue;
            }
            // Addie outputs are produced at readout below, not in-array.
            if matches!(rep.nodes[*oid], Node::Addie { .. }) {
                continue;
            }
            let v = array.read(sched.placement[oid]);
            if v {
                outs.get_mut(&base_name).unwrap().set(t, true);
            }
        }
        for (base_id, x1s, x2s) in addie_taps.iter_mut() {
            let Node::Addie { x1, x2, .. } = &base.nodes[*base_id] else { unreachable!() };
            // Find the replicated tap cells per lane: the replicated
            // netlist orders lanes contiguously; taps share columns.
            for lane in 0..lanes {
                let t = pass * q + lane;
                if t >= bl {
                    break;
                }
                // Locate replicated x1/x2 nodes for this lane by (row,
                // column of base placement): same column across lanes.
                let (c1, c2) = addie_tap_cells(base, rep, sched, *x1, *x2, lane);
                if array.read(c1) {
                    x1s.set(t, true);
                }
                if array.read(c2) {
                    x2s.set(t, true);
                }
            }
        }
    }

    // ---- ADDIE readout integration (local-accumulator realization).
    for (base_id, x1s, x2s) in &addie_taps {
        let Some((name, _)) = base.outputs.iter().find(|(_, oid)| oid == base_id) else {
            continue;
        };
        let mut addie = Addie::new(
            match base.nodes[*base_id] {
                Node::Addie { counter_bits, .. } => counter_bits,
                _ => unreachable!(),
            },
            ADDIE_SEED,
        );
        let out = outs.get_mut(name).unwrap();
        for t in 0..bl {
            let x = if t % 2 == 0 { x1s.get(t) } else { x2s.get(t) };
            out.set(t, addie.step(x));
        }
    }

    let _ = rng;
    (outs, stats)
}

/// Find the cells of the replicated instances of base nodes `x1`,`x2` in
/// `lane`. Relies on replicate()'s structure: lane-l instance of base
/// node i is the node with the same "shape position" in lane l; we
/// recover it by matching (row == lane) among nodes whose base column
/// matches — placements of replicated instances share columns.
fn addie_tap_cells(
    _base: &Netlist,
    rep: &Netlist,
    sched: &Schedule,
    x1: NodeId,
    x2: NodeId,
    lane: usize,
) -> (CellRef, CellRef) {
    // Lane-0 instance ids in `rep` for base gate ids are not tracked
    // directly; instead use column identity: all lanes of one base node
    // map to the same column (uniform per-lane structure).
    let col_of_lane0 = |base_like: NodeId| -> u32 {
        // The base netlist and lane-0 of the replicated netlist have the
        // same structure; node ids differ. We find lane-0's instance by
        // scanning rep nodes in row 0 in id order and counting non-input
        // nodes — but a simpler, robust approach: the k-th non-input
        // node of the base corresponds to the k-th row-0 non-input node
        // of rep.
        let base_nodes: Vec<NodeId> = (0.._base.len())
            .filter(|&i| !matches!(_base.nodes[i], Node::Input { .. }))
            .collect();
        let k = base_nodes.iter().position(|&i| i == base_like);
        match k {
            Some(k) => {
                let rep_row0: Vec<NodeId> = (0..rep.len())
                    .filter(|&i| {
                        !matches!(rep.nodes[i], Node::Input { .. }) && rep.nodes[i].row() == 0
                    })
                    .collect();
                sched.placement[&rep_row0[k]].col
            }
            None => {
                // Base node is an Input: its column is shared already.
                let name = match &_base.nodes[base_like] {
                    Node::Input { name, .. } => name.clone(),
                    _ => unreachable!(),
                };
                let rep_input = (0..rep.len())
                    .find(|&i| matches!(&rep.nodes[i], Node::Input { name: n, .. } if *n == name))
                    .expect("replicated input");
                sched.placement[&rep_input].col
            }
        }
    };
    let c1 = CellRef::new(lane, col_of_lane0(x1) as usize);
    let c2 = CellRef::new(lane, col_of_lane0(x2) as usize);
    (c1, c2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{eval::eval_stochastic, ops, replicate::replicate};
    use crate::scheduler::algorithm1::{schedule, Options};

    fn run_op(
        base: &Netlist,
        inputs: &[(&str, f64)],
        correlated: bool,
        q: usize,
        bl: usize,
        seed: u64,
    ) -> (HashMap<String, Bitstream>, HashMap<String, Bitstream>, ExecStats) {
        let mut rng = Xoshiro256::seeded(seed);
        let mut ins: HashMap<String, Bitstream> = HashMap::new();
        if correlated {
            let values: Vec<f64> = inputs.iter().map(|(_, v)| *v).collect();
            let streams = crate::sc::encode::encode_correlated(&values, bl, &mut rng);
            for ((n, _), s) in inputs.iter().zip(streams) {
                ins.insert(n.to_string(), s);
            }
        } else {
            for (n, v) in inputs {
                ins.insert(n.to_string(), Bitstream::sample(*v, bl, &mut rng));
            }
        }
        let rep = replicate(base, q);
        let sched = schedule(&rep, &Options::default());
        let mut array = Subarray::new(q.max(1), sched.cols_used.max(1));
        let (got, stats) =
            execute_replicated(base, &rep, &sched, &ins, q, &mut array, &mut rng);
        let want = eval_stochastic(base, &ins);
        (got, want, stats)
    }

    #[test]
    fn array_matches_eval_multiply() {
        for q in [1, 16, 64] {
            let (got, want, _) =
                run_op(&ops::multiply(), &[("a", 0.6), ("b", 0.3)], false, q, 256, 11);
            assert_eq!(got["out"], want["out"], "q={q}");
        }
    }

    #[test]
    fn array_matches_eval_scaled_add() {
        let (got, want, _) = run_op(
            &ops::scaled_add(),
            &[("a", 0.2), ("b", 0.9), ("s", 0.5)],
            false,
            32,
            256,
            13,
        );
        assert_eq!(got["out"], want["out"]);
    }

    #[test]
    fn array_matches_eval_abs_subtract_correlated() {
        let (got, want, _) =
            run_op(&ops::abs_subtract(), &[("a", 0.75), ("b", 0.3)], true, 64, 512, 17);
        assert_eq!(got["out"], want["out"]);
    }

    #[test]
    fn array_matches_eval_divide_feedback() {
        for q in [1, 8, 64] {
            let (got, want, _) =
                run_op(&ops::scaled_divide(), &[("a", 0.4), ("b", 0.5)], false, q, 256, 19);
            assert_eq!(got["out"], want["out"], "q={q}");
        }
    }

    #[test]
    fn array_matches_eval_exponential() {
        let base = ops::exponential();
        let mut inputs = Vec::new();
        let names: Vec<String> = (1..=5)
            .map(|k| format!("a{k}"))
            .chain((1..=5).map(|k| format!("c{k}")))
            .collect();
        for (i, n) in names.iter().enumerate() {
            let v = if i < 5 { 0.5 } else { 0.8 / (i as f64 - 3.0) };
            inputs.push((n.as_str(), v));
        }
        let (got, want, _) = run_op(&base, &inputs, false, 32, 256, 23);
        assert_eq!(got["out"], want["out"]);
    }

    #[test]
    fn array_sqrt_value_converges() {
        // ADDIE readout path: value-level check (bit-exact with eval
        // would require identical seeds; eval mixes node id into seed).
        let (got, _, _) =
            run_op(&ops::square_root(10), &[("a1", 0.49), ("a2", 0.49)], false, 64, 65536, 29);
        assert!((got["out"].value() - 0.7).abs() < 0.05, "got {}", got["out"].value());
    }

    #[test]
    fn exec_stats_match_schedule_counts() {
        let base = ops::scaled_add();
        let q = 64;
        let bl = 256; // 4 passes
        let rep = replicate(&base, q);
        let sched = schedule(&rep, &Options::default());
        let mut rng = Xoshiro256::seeded(31);
        let ins: HashMap<String, Bitstream> = [("a", 0.5), ("b", 0.5), ("s", 0.5)]
            .iter()
            .map(|(n, v)| (n.to_string(), Bitstream::sample(*v, bl, &mut rng)))
            .collect();
        let mut array = Subarray::new(q, sched.cols_used);
        let (_, stats) = execute_replicated(&base, &rep, &sched, &ins, q, &mut array, &mut rng);
        let passes = (bl / q) as u64;
        assert_eq!(stats.passes, passes);
        assert_eq!(stats.logic_ops, sched.op_count() as u64 * passes);
        assert_eq!(stats.stochastic_writes, sched.sbg_count as u64 * passes);
        assert_eq!(stats.logic_cycles, sched.steps.len() as u64 * passes);
    }

    #[test]
    fn write_counts_accumulate() {
        let base = ops::multiply();
        let q = 16;
        let rep = replicate(&base, q);
        let sched = schedule(&rep, &Options::default());
        let mut rng = Xoshiro256::seeded(37);
        let ins: HashMap<String, Bitstream> = [("a", 0.5), ("b", 0.5)]
            .iter()
            .map(|(n, v)| (n.to_string(), Bitstream::sample(*v, 64, &mut rng)))
            .collect();
        let mut array = Subarray::new(q, sched.cols_used);
        let _ = execute_replicated(&base, &rep, &sched, &ins, q, &mut array, &mut rng);
        assert!(array.total_writes() > 0);
        assert_eq!(array.used_cells(), q * 4); // 2 PIs + NAND + NOT per lane
    }
}
