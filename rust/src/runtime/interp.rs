//! Pure-Rust bit-plane interpreter backend for the artifact registry.
//!
//! Executes the same `(values f32[B, n], seed i32) → f32[B]` contract as
//! the PJRT backend, but with no external toolchain: each manifest entry
//! is mapped to the crate's own circuit model — SNG (stochastic number
//! generation) → bit-level circuit → StoB popcount, exactly the wave
//! one subarray group performs.
//!
//! Every artifact is compiled once at load into a
//! [`StagedPlan`](crate::netlist::StagedPlan) — the six `op_*` kernels
//! and the single-stage apps (`app_ol`, `app_hdp`) as one-stage plans,
//! the multi-stage apps (`app_lit`, `app_kde`) as chains of gate plans
//! wired through StoB→BtoS regeneration edges — and evaluated
//! **word-parallel** over a fully lane-major pipeline: one generator
//! stream per batch row — by default the stateless counter generator
//! ([`CounterBank`], draws addressed by `(lane, node, step)`, cacheable
//! and seekable), or the lockstep xoshiro [`RngBank`] compatibility
//! path (`STOCH_IMC_RNG=xoshiro`) — feeds the lane-major SNG
//! ([`crate::sc::sng`], integer-threshold comparisons), which packs
//! each time step's bits straight into `u64×W` lane words
//! ([`LaneBlock`](crate::sc::LaneBlock), `W ∈ {1, 2, 4, 8}` →
//! 64/128/256/512 rows per block), each stage's compiled gate program
//! executes every instruction for all lanes at once, and the
//! vertical-counter StoB readout produces every row's count without
//! leaving the lane domain. On the counter path, freshly generated
//! input blocks are additionally memoized in an engine-level
//! [`SngCache`](crate::sc::sng::SngCache): re-executing the same
//! `(seed, artifact, rows, values)` wave reuses the packed words
//! instead of regenerating them (hit/miss counters ride along in
//! [`WaveStats`]).
//! Between stages the per-lane counts become the per-lane SNG
//! thresholds of the next stage's regenerated inputs (correlated
//! groups included) — the regeneration never leaves the lane domain
//! either, so no per-row bitstreams and no transposes exist anywhere
//! on the wave hot path: the software realization of the paper's
//! bit-parallel subarray rows, staged applications included (§5.3).
//!
//! Outputs are bit-identical to the retained scalar golden path
//! ([`StagedPlan::eval_row_scalar`] /
//! [`StagedPlan::eval_row_scalar_counter`], reachable via
//! [`InterpEngine::execute_rows_scalar`]) because each lane draws the
//! same per-row stream — in the same per-stage order on the xoshiro
//! path, at the same `(node, step)` addresses on the counter path —
//! and the plans evaluate each lane exactly as the golden model does. For the flat
//! kernels this is the same golden contract as before the staged
//! engine; for `app_lit`/`app_kde` the bit-level reference is the
//! staged-netlist model (see `netlist::staged` — the legacy
//! `apps::{lit,kde}::stoch_value` evaluators interleave draws
//! differently and remain statistical references only). Lane width is
//! auto-sized to the wave (or pinned via `STOCH_IMC_LANE_WIDTH` /
//! [`InterpEngine::execute_rows_wide`]).
//!
//! Only `manifest.txt` is required in the artifact directory; `.hlo.txt`
//! files are ignored by this backend.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use crate::apps::{hdp::Hdp, kde::Kde, lit::Lit, ol::Ol, App};
use crate::bail;
use crate::energy::OpCounters;
use crate::error::{Context, Result};
use crate::fault::{FaultCutoffs, FaultPlan};
use crate::lifetime::WearProfile;
use crate::netlist::{ops, Binding, InputClass, Netlist, PlanScratch, StagedPlan};
use crate::obs::StageSpans;
use crate::sc::bitplane::{LaneBlock, LANES};
use crate::sc::sng;
use crate::util::prng::{fnv1a, mix64, CounterBank, RngBank, RngMode, Xoshiro256};

use super::artifacts::{load_manifest, ArtifactSpec};

/// Everything one wave needs, bundled so the block workers take a
/// single shareable reference.
struct Wave<'a> {
    name: &'a str,
    spec: &'a ArtifactSpec,
    kernel: &'a StagedPlan,
    values: &'a [f32],
    seed: i32,
    /// Effective bitstream length for this wave — the manifest BL, or a
    /// shorter ladder step when the serving layer degrades under
    /// overload ([`effective_bl`]). Row streams are addressed by
    /// `(seed, name, row)` only, so a degraded wave is bit-identical to
    /// full execution of a manifest compiled at this BL.
    bl: usize,
    /// Which generator feeds the SNG (counter default; xoshiro compat).
    rng: RngMode,
    /// SNG-cache epoch: fingerprints `(artifact, seed)` so a reseeded
    /// or cross-artifact wave can never hit another wave's blocks.
    epoch: u64,
    /// Precomputed fault-mask cutoffs when this wave is fault-injected
    /// (`None` for clean waves and no-op plans — the hot path then
    /// compiles to the uninstrumented loops).
    fault: Option<&'a FaultCutoffs>,
}

/// Per-wave instrumentation the executor accumulates *as it runs*: the
/// Eq 4 operation counters (price them with
/// [`OpCounters::energy`](crate::energy::OpCounters::energy)), the
/// Eq 11 wear profile of the subarray rows the wave touched, and the
/// wall-clock spans per engine stage. Returned by
/// [`InterpEngine::execute_rows_instrumented`]; the serving layer
/// folds one of these per wave into its per-shard
/// [`Metrics`](crate::coordinator::Metrics).
///
/// `ops` and `wear` are deterministic wave invariants (same totals for
/// any worker split or lane width); `spans` is measured wall-clock and
/// varies run to run — comparisons asserting determinism must compare
/// the invariant fields, not the whole struct.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WaveStats {
    /// Gate fires, presets, SBG writes, StoB reads, ADDIE steps.
    pub ops: OpCounters,
    /// One wave's write traffic over the rows it utilized: `used_cells`
    /// is slots × live lanes, `writes` is the Eq 4 write total, and the
    /// hottest cell takes one preset + one write per time step
    /// (`2 × BL`).
    pub wear: WearProfile,
    /// Monotonic-clock nanoseconds per engine stage (SNG / gates /
    /// regen / StoB), sampled once per stage per lane block and summed
    /// across workers — CPU-time-like, so shares are the signal.
    pub spans: StageSpans,
    /// SNG block-cache and cutoff-memo hit/miss counters for this wave
    /// (all zero on the xoshiro path, which cannot cache).
    pub cache: sng::SngCacheStats,
}

/// The interpreter engine: artifact specs plus per-artifact compiled
/// staged plans, and the engine-level packed-word SNG block cache
/// (counter path only — see [`sng::SngCache`] for why hits require the
/// stateless generator).
pub struct InterpEngine {
    specs: HashMap<String, ArtifactSpec>,
    kernels: HashMap<String, StagedPlan>,
    sng_cache: sng::SngCache,
}

/// Compile-time value binding for one primary input of a single-stage
/// kernel. Input naming follows the netlist builders (`netlist::ops`,
/// `apps::*::stoch_cost_netlists`); the staged apps carry their own
/// binding conventions (`Lit::staged_plan`, `Kde::staged_plan`).
fn binding_for(artifact: &str, input: &str) -> Option<Binding> {
    Some(match artifact {
        "op_multiply" | "op_scaled_divide" | "op_abs_subtract" => match input {
            "a" => Binding::Input(0),
            "b" => Binding::Input(1),
            _ => return None,
        },
        "op_scaled_add" => match input {
            "a" => Binding::Input(0),
            "b" => Binding::Input(1),
            "s" => Binding::Const(0.5),
            _ => return None,
        },
        // Two independently generated copies of the same operand.
        "op_square_root" => match input {
            "a1" | "a2" => Binding::Input(0),
            _ => return None,
        },
        // e^{-cA} with c = 1: a1..a5 are copies of A, c1..c5 carry c/k.
        "op_exponential" => {
            if let Some(k) = input.strip_prefix('a').and_then(|s| s.parse::<u32>().ok()) {
                if (1..=5).contains(&k) {
                    return Some(Binding::Input(0));
                }
            }
            if let Some(k) = input.strip_prefix('c').and_then(|s| s.parse::<usize>().ok()) {
                if (1..=5).contains(&k) {
                    return Some(Binding::Const(ops::exp_constants(1.0)[k - 1]));
                }
            }
            return None;
        }
        "app_ol" => {
            let i = input.strip_prefix('p').and_then(|s| s.parse::<usize>().ok())?;
            Binding::Input(i)
        }
        "app_hdp" => {
            let i = crate::apps::hdp::NAMES.iter().position(|n| *n == input)?;
            Binding::Input(i)
        }
        _ => return None,
    })
}

/// Resolve every primary input of a built-in single-stage kernel to its
/// [`Binding`], once at load — the per-wave hot path never parses an
/// input name again. A name with no binding is a malformed kernel
/// definition: reported as an error (with the artifact and input named)
/// so [`InterpEngine::load`] fails cleanly instead of panicking.
fn compile_bindings(artifact: &str, nl: &Netlist) -> Result<Vec<Binding>> {
    crate::apps::try_bindings_from(nl, |name| {
        binding_for(artifact, name)
            .with_context(|| format!("artifact `{artifact}`: no value binding for input `{name}`"))
    })
}

/// Compile the staged gate-plan pipeline once per kernel at load; every
/// wave reuses it. `Ok(None)` = no built-in kernel for this name (the
/// caller skips the artifact); `Err` = the kernel definition itself is
/// inconsistent (unknown arity, unbound input, malformed plan) — a
/// load-time error, never a panic.
fn kernel_for(name: &str) -> Result<Option<StagedPlan>> {
    fn single(name: &str, nl: Netlist) -> Result<StagedPlan> {
        let n = expected_arity(name)
            .with_context(|| format!("kernel `{name}`: no known instance arity"))?;
        let bindings = compile_bindings(name, &nl)?;
        StagedPlan::single(n, nl, bindings, "out").with_context(|| format!("kernel `{name}`"))
    }
    Ok(Some(match name {
        "op_multiply" => single(name, ops::multiply())?,
        "op_scaled_add" => single(name, ops::scaled_add())?,
        "op_abs_subtract" => single(name, ops::abs_subtract())?,
        "op_scaled_divide" => single(name, ops::scaled_divide())?,
        "op_square_root" => single(name, ops::square_root(ops::ADDIE_BITS_APP))?,
        "op_exponential" => single(name, ops::exponential())?,
        "app_ol" => single(name, Ol::default().stoch_cost_netlists().remove(0))?,
        "app_hdp" => single(name, Hdp.stoch_cost_netlists().remove(0))?,
        "app_lit" => Lit::default().staged_plan(),
        "app_kde" => Kde::default().staged_plan(),
        _ => return Ok(None),
    }))
}

/// Instance arity each kernel consumes (the artifact contract's `n`).
/// Distinct from the netlist's input-node count: e.g. `op_square_root`
/// has two netlist inputs (a1, a2) but a 1-value instance.
fn expected_arity(name: &str) -> Option<usize> {
    Some(match name {
        "op_multiply" | "op_scaled_add" | "op_abs_subtract" | "op_scaled_divide" => 2,
        "op_square_root" | "op_exponential" => 1,
        "app_ol" => 2 * Ol::default().sensors,
        "app_hdp" => crate::apps::hdp::NAMES.len(),
        "app_lit" => Lit::default().pixels(),
        "app_kde" => Kde::default().history + 1,
        _ => return None,
    })
}

/// Seed of one batch row's PRNG stream: mixes the wave seed, the
/// artifact-name hash, and the batch row so rows and artifacts draw
/// independent streams and a different wave seed resamples everything.
/// Shared by the scalar path ([`row_rng`]) and the lane-major
/// [`RngBank`] seeding so both derive bit-identical streams.
fn row_seed(seed: i32, name_hash: u64, row: usize) -> u64 {
    name_hash ^ (seed as u32 as u64) ^ ((row as u64) << 32)
}

/// Deterministic per-row PRNG (the scalar golden path's generator).
fn row_rng(seed: i32, name: &str, row: usize) -> Xoshiro256 {
    Xoshiro256::seeded(row_seed(seed, fnv1a(name), row))
}

impl InterpEngine {
    /// Register every artifact listed in `dir/manifest.txt`. Names
    /// without a built-in interpreter kernel, and names whose manifest
    /// arity disagrees with the kernel's instance shape, are skipped
    /// (with a warning) — callers, notably the coordinator, then reject
    /// them at submit time instead of failing waves later, and the
    /// interpreter can never silently compute over a different input
    /// layout than the PJRT artifact of the same name.
    pub fn load(dir: &Path) -> Result<Self> {
        let mut specs = HashMap::new();
        let mut kernels = HashMap::new();
        for spec in load_manifest(dir)? {
            let Some(k) =
                kernel_for(&spec.name).with_context(|| format!("loading artifact `{}`", spec.name))?
            else {
                eprintln!(
                    "interp backend: skipping artifact `{}` — no interpreter kernel \
                     (build HLO artifacts and use the xla-runtime backend for custom graphs)",
                    spec.name
                );
                continue;
            };
            let expected = expected_arity(&spec.name)
                .with_context(|| format!("artifact `{}`: kernel has no known arity", spec.name))?;
            if spec.n_inputs != expected {
                eprintln!(
                    "interp backend: skipping artifact `{}` — manifest declares {} inputs \
                     but the interpreter kernel expects {expected}",
                    spec.name, spec.n_inputs
                );
                continue;
            }
            kernels.insert(spec.name.clone(), k);
            specs.insert(spec.name.clone(), spec);
        }
        Ok(Self { specs, kernels, sng_cache: sng::SngCache::new() })
    }

    pub fn platform(&self) -> String {
        "interp".to_string()
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.specs.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// Execute one batch: `values` is row-major [batch, n_inputs]
    /// (padded by the caller); returns the [batch] outputs. Only the
    /// first `live` rows are evaluated — padding rows (whose outputs
    /// the caller discards) come back as 0.0 without paying for a
    /// netlist evaluation. Rows are split across the auto worker count
    /// (see [`default_row_threads`]).
    pub fn execute(&self, name: &str, values: &[f32], seed: i32, live: usize) -> Result<Vec<f32>> {
        self.execute_rows(name, values, seed, live, 0)
    }

    /// [`InterpEngine::execute`] with an explicit worker count (`0` =
    /// auto via [`default_row_threads`]). Every kernel — staged apps
    /// included — runs the **word-parallel** path: live rows are packed
    /// into lane blocks (one row per bit lane of a `u64×W` lane word,
    /// auto-width) and the blocks are split across `threads` scoped
    /// workers; each compiled gate instruction then evaluates a whole
    /// block at once, and staged kernels regenerate between stages
    /// in-lane. Outputs are bit-identical for every worker count, lane
    /// width, block grouping, and path — each row draws from its own
    /// [`row_rng`] stream and the plans evaluate each lane exactly as
    /// the golden model does — so the split is purely a wall-clock
    /// optimization, the way a subarray group fires all its rows in
    /// one cycle.
    pub fn execute_rows(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
    ) -> Result<Vec<f32>> {
        Ok(self.execute_impl(name, values, seed, live, threads, 0, true, None, None, 0)?.0)
    }

    /// [`InterpEngine::execute_rows`] with an explicit lane width:
    /// `64`, `128`, `256`, or `512` rows per lane block (`u64×{1,2,4,8}`
    /// lane words); `0` = auto (`STOCH_IMC_LANE_WIDTH` if set, else
    /// sized to the wave and worker count — see `resolve_lane_width`).
    /// Any other value falls back to auto. Purely a throughput knob —
    /// outputs are bit-identical across widths.
    pub fn execute_rows_wide(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
        lane_width: usize,
    ) -> Result<Vec<f32>> {
        Ok(self.execute_impl(name, values, seed, live, threads, lane_width, true, None, None, 0)?.0)
    }

    /// The fully tuned wave entry point: everything
    /// [`InterpEngine::execute_rows_instrumented`] offers plus an
    /// explicit generator selection. `rng = None` resolves the
    /// `STOCH_IMC_RNG` env var and then the counter default; explicit
    /// `Some(..)` pins the path regardless of environment (what the
    /// serving layer and the differential suites use — tests must never
    /// mutate process-global env).
    #[allow(clippy::too_many_arguments)]
    pub fn execute_rows_tuned(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
        lane_width: usize,
        rng: Option<RngMode>,
        fault: Option<&FaultPlan>,
    ) -> Result<(Vec<f32>, WaveStats)> {
        self.execute_impl(name, values, seed, live, threads, lane_width, true, rng, fault, 0)
    }

    /// [`InterpEngine::execute_rows_tuned`] with a degradation level:
    /// the wave runs at `effective_bl(manifest BL, bl_shift)` — each
    /// shift halves the bitstream (floored at [`MIN_DEGRADED_BL`]), the
    /// serving layer's graceful-degradation ladder. `bl_shift = 0` is
    /// exactly the tuned path. Because row streams are addressed by
    /// `(seed, name, row)` and StoB normalizes by the effective BL, a
    /// degraded wave is bit-identical to full execution of the same
    /// artifact compiled at the shorter BL — shorter streams cost
    /// accuracy (variance), never correctness.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_rows_degraded(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
        lane_width: usize,
        rng: Option<RngMode>,
        fault: Option<&FaultPlan>,
        bl_shift: u32,
    ) -> Result<(Vec<f32>, WaveStats)> {
        self.execute_impl(name, values, seed, live, threads, lane_width, true, rng, fault, bl_shift)
    }

    /// [`InterpEngine::execute_rows_wide`] with the paper's reliability
    /// instrumentation: an optional [`FaultPlan`] XORs stateless fault
    /// masks into the lane words at the three paper sites (SNG output,
    /// gate output, StoB read), and the returned [`WaveStats`] carries
    /// the Eq 4 operation counters and Eq 11 wear the wave accumulated
    /// while executing. A `None` (or all-zero-rate) plan takes exactly
    /// the uninstrumented hot path.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_rows_instrumented(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
        lane_width: usize,
        fault: Option<&FaultPlan>,
    ) -> Result<(Vec<f32>, WaveStats)> {
        self.execute_impl(name, values, seed, live, threads, lane_width, true, None, fault, 0)
    }

    /// [`InterpEngine::execute_rows`] forced onto the scalar golden
    /// path: every row is evaluated one bit at a time through
    /// [`StagedPlan::eval_row_scalar`] (xoshiro) or
    /// [`StagedPlan::eval_row_scalar_counter`] (counter), per the
    /// resolved generator mode. Kept public as the reference the
    /// word-parallel path is differentially tested (and benchmarked)
    /// against.
    pub fn execute_rows_scalar(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
    ) -> Result<Vec<f32>> {
        Ok(self.execute_impl(name, values, seed, live, threads, 0, false, None, None, 0)?.0)
    }

    /// [`InterpEngine::execute_rows_scalar`] with an explicit generator
    /// selection (`None` = env, then counter default) — the scalar
    /// reference side of the tuned differential suites.
    pub fn execute_rows_scalar_tuned(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
        rng: Option<RngMode>,
    ) -> Result<Vec<f32>> {
        Ok(self.execute_impl(name, values, seed, live, threads, 0, false, rng, None, 0)?.0)
    }

    /// [`InterpEngine::execute_rows_scalar`] under fault injection —
    /// the scalar golden reference of the instrumented lane path
    /// ([`StagedPlan::eval_row_scalar_fault`] per row). The
    /// differential fault suite pins
    /// [`execute_rows_instrumented`](InterpEngine::execute_rows_instrumented)
    /// bit-identical against this for the same plan and seed.
    pub fn execute_rows_scalar_fault(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
        fault: &FaultPlan,
    ) -> Result<Vec<f32>> {
        Ok(self.execute_impl(name, values, seed, live, threads, 0, false, None, Some(fault), 0)?.0)
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_impl(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
        lane_width: usize,
        word_parallel: bool,
        rng: Option<RngMode>,
        fault: Option<&FaultPlan>,
        bl_shift: u32,
    ) -> Result<(Vec<f32>, WaveStats)> {
        let Some(spec) = self.specs.get(name) else {
            bail!("unknown artifact `{name}`");
        };
        if values.len() != spec.batch * spec.n_inputs {
            bail!(
                "artifact `{name}` expects {}×{} values, got {}",
                spec.batch,
                spec.n_inputs,
                values.len()
            );
        }
        let kernel = self.kernels.get(name).with_context(|| {
            format!("artifact `{name}` has no interpreter kernel (build HLO artifacts \
                     and use the xla-runtime backend for custom graphs)")
        })?;
        // Arity consistency was enforced at load time, so every
        // registered spec matches its kernel's instance shape here.
        let live = live.min(spec.batch);
        let threads = if threads == 0 { default_row_threads() } else { threads };
        let rng = resolve_rng_mode(rng);
        // Degradation ladder: halve the manifest BL per shift step
        // (floored at MIN_DEGRADED_BL). Row streams are addressed by
        // (seed, name, row), never by BL, so a shifted wave's bits are
        // exactly the prefix a shorter-BL manifest would draw.
        let bl = effective_bl(spec.bl, bl_shift);
        // A no-op plan (all rates 0) degrades to the clean path: same
        // bits by construction *and* zero instrumentation overhead.
        let cuts = fault.and_then(|p| if p.is_noop() { None } else { Some(p.cutoffs()) });
        let mut out = vec![0.0f32; spec.batch];
        let mut stats = WaveStats::default();
        if word_parallel {
            let epoch = mix64(fnv1a(name) ^ mix64(seed as u32 as u64));
            let wave =
                Wave { name, spec, kernel, values, seed, bl, rng, epoch, fault: cuts.as_ref() };
            let ops = Mutex::new((
                OpCounters::default(),
                StageSpans::default(),
                sng::SngCacheStats::default(),
            ));
            // Monomorphized per lane width so every per-word loop
            // runs over a compile-time-sized array.
            match resolve_lane_width(lane_width, live, threads) {
                64 => self.execute_blocks::<1>(&wave, &mut out[..live], threads, &ops)?,
                128 => self.execute_blocks::<2>(&wave, &mut out[..live], threads, &ops)?,
                256 => self.execute_blocks::<4>(&wave, &mut out[..live], threads, &ops)?,
                _ => self.execute_blocks::<8>(&wave, &mut out[..live], threads, &ops)?,
            }
            // Worker counters are monotonic sums: recover from a
            // poisoned mutex (a panicked worker) rather than cascading
            // the panic into every later wave of the process.
            (stats.ops, stats.spans, stats.cache) =
                ops.into_inner().unwrap_or_else(|e| e.into_inner());
            if live > 0 {
                // Eq 11 terms for this wave: every stage slot of every
                // live lane is a utilized subarray row; the hottest
                // cell takes one preset + one write per time step (of
                // the *effective* BL — a degraded wave writes less).
                stats.wear = WearProfile {
                    used_cells: (kernel.n_slots_total() * live) as u64,
                    writes: stats.ops.write_total(),
                    max_cell_writes: 2 * bl as u64,
                };
            }
        } else {
            self.execute_scalar_rows(
                name,
                spec,
                kernel,
                values,
                seed,
                bl,
                &mut out[..live],
                threads,
                rng,
                cuts.as_ref(),
            )?;
        }
        Ok((out, stats))
    }

    /// Word-parallel wave at lane width `W`: split the live rows into
    /// `64·W`-row lane blocks and the blocks across scoped workers.
    /// Worker chunks are whole multiples of the block size so block
    /// boundaries are identical for every worker count (grouping is
    /// invisible in the outputs regardless — each lane is evaluated
    /// independently). Each worker owns one [`BlockWorkspace`] and
    /// reuses it for every block it evaluates: zero heap allocations
    /// per block once the workspace is warm.
    fn execute_blocks<const W: usize>(
        &self,
        wave: &Wave,
        out: &mut [f32],
        threads: usize,
        ops: &Mutex<(OpCounters, StageSpans, sng::SngCacheStats)>,
    ) -> Result<()> {
        let live = out.len();
        if live == 0 {
            return Ok(());
        }
        let block_rows = W * LANES;
        let blocks = live.div_ceil(block_rows);
        let workers = threads.min(blocks).max(1);
        parallel_chunks(out, workers, blocks.div_ceil(workers) * block_rows, |start, sub| {
            let mut ws = BlockWorkspace::<W>::default();
            // Worker-local Eq 4 counters, stage spans, and cache
            // counters, folded into the wave total once per worker —
            // the per-block hot path never touches the mutex.
            let mut local = OpCounters::default();
            let mut spans = StageSpans::default();
            let mut cache = sng::SngCacheStats::default();
            for (bj, block_out) in sub.chunks_mut(block_rows).enumerate() {
                self.eval_block(
                    wave,
                    start + bj * block_rows,
                    block_out,
                    &mut ws,
                    &mut local,
                    &mut spans,
                    &mut cache,
                );
            }
            (cache.cutoff_hits, cache.cutoff_misses) = ws.cutcache.counters();
            // Poison recovery: the counters are additive, so folding
            // into a snapshot another worker abandoned mid-update only
            // undercounts that worker's block — never corrupts.
            let mut total = ops.lock().unwrap_or_else(|e| e.into_inner());
            total.0.add(&local);
            total.1.add(&spans);
            total.2.add(&cache);
            Ok(())
        })
    }

    /// One lane block (≤ `64·W` rows starting at `row0`), fully
    /// lane-major through every stage: seed one [`RngBank`] stream per
    /// row (bit-identical to the scalar path's [`row_rng`]), then per
    /// stage generate every primary input's block directly as packed
    /// lane words in netlist node-id order (the staged reference's
    /// draw order), run the stage's compiled gate program once for all
    /// rows, and read every output's StoB count with the vertical
    /// counter. The per-lane counts become the per-lane SNG thresholds
    /// of later stages' `Regen` bindings — in-lane StoB→BtoS
    /// regeneration, never leaving the lane domain. No per-row
    /// bitstreams, no transposes, no allocations beyond the reused
    /// workspace.
    ///
    /// Span timing is coarse on purpose: one monotonic-clock reading
    /// per stage boundary (4 per stage per block — nanoseconds against
    /// the microseconds-to-milliseconds a block takes), so the
    /// clean-path speedup gates are undisturbed. Stage-0 input
    /// generation is attributed to SNG; later stages' input generation
    /// is the inter-stage regeneration span (its `Regen` thresholds
    /// come from the previous stage's StoB values).
    #[allow(clippy::too_many_arguments)]
    fn eval_block<const W: usize>(
        &self,
        w: &Wave,
        row0: usize,
        out: &mut [f32],
        ws: &mut BlockWorkspace<W>,
        ops: &mut OpCounters,
        spans: &mut StageSpans,
        cache: &mut sng::SngCacheStats,
    ) {
        let BlockWorkspace {
            rngs,
            ctr,
            sng: sng_ws,
            cutcache,
            vals,
            instances,
            uniforms,
            filled_groups,
            inputs,
            stage_vals,
            plans,
            planes,
            counts,
        } = ws;
        let bl = w.bl;
        let lanes = out.len();
        let n = w.spec.n_inputs;
        let name_hash = fnv1a(w.name);
        match w.rng {
            RngMode::Xoshiro => rngs.reseed_with(lanes, |l| row_seed(w.seed, name_hash, row0 + l)),
            RngMode::Counter => ctr.reseed_with(lanes, |l| row_seed(w.seed, name_hash, row0 + l)),
        }
        // Clamped instance values, lane-major ([lane][input]).
        instances.clear();
        instances.extend(
            w.values[row0 * n..(row0 + lanes) * n].iter().map(|&v| (v as f64).clamp(0.0, 1.0)),
        );
        let stages = w.kernel.stages();
        if stage_vals.len() != stages.len() {
            stage_vals.clear();
            stage_vals.resize_with(stages.len(), Vec::new);
        }
        if plans.len() != stages.len() {
            plans.clear();
            plans.resize_with(stages.len(), PlanScratch::default);
        }
        // Running (stage, input) slot index for the per-wave cutoff
        // memo — the same position across a wave's blocks compares its
        // values against the previous block's and skips the ⌈v·2⁵³⌉
        // recomputation when they repeat.
        let mut slot = 0usize;
        for (si, stage) in stages.iter().enumerate() {
            // One lane-major block per primary input, generated in
            // netlist node-id order — the binding order of the stage's
            // plan and the exact draw order of the staged reference.
            // The block pool only grows: stages of different widths
            // reuse the same `LaneBlock` allocations.
            if inputs.len() < stage.plan.n_inputs() {
                inputs.resize_with(stage.plan.n_inputs(), || LaneBlock::zeros(0, 0));
            }
            filled_groups.clear();
            let t0 = Instant::now();
            for (i, (binding, class)) in stage.bindings.iter().zip(&stage.classes).enumerate() {
                // Per-lane threshold value for this input.
                vals.clear();
                match *binding {
                    Binding::Input(ix) => {
                        vals.extend((0..lanes).map(|l| instances[l * n + ix]));
                    }
                    Binding::Const(c) => {
                        vals.resize(lanes, c.clamp(0.0, 1.0));
                    }
                    // In-lane regeneration: the StoB values of an
                    // earlier stage's output are this input's per-lane
                    // thresholds.
                    Binding::Regen { stage: s, output: o } => {
                        vals.extend_from_slice(&stage_vals[s][o * lanes..(o + 1) * lanes]);
                    }
                }
                let block = &mut inputs[i];
                let cuts_v = cutcache.cutoffs(slot, vals);
                slot += 1;
                match class {
                    InputClass::Correlated(g) => {
                        let us = uniforms.entry(*g).or_default();
                        if !filled_groups.contains(g) {
                            match w.rng {
                                RngMode::Xoshiro => sng::fill_draw_block(lanes, bl, rngs, us),
                                RngMode::Counter => sng::fill_draw_block_counter(
                                    lanes,
                                    bl,
                                    ctr,
                                    sng::sng_node(sng::NODE_GROUP, si, *g as usize),
                                    us,
                                ),
                            }
                            filled_groups.push(*g);
                        }
                        sng::threshold_block(cuts_v, bl, us.as_slice(), block);
                    }
                    // BinaryBit inputs are rejected at plan compile.
                    _ => match w.rng {
                        RngMode::Xoshiro => sng::sample_block(cuts_v, bl, rngs, sng_ws, block),
                        RngMode::Counter => {
                            // Counter streams are pure functions of
                            // their key, so the packed block can be
                            // reused across executions via the
                            // engine-level cache (stored pre-fault;
                            // masks XOR in below either way).
                            let node = sng::sng_node(sng::NODE_INPUT, si, i);
                            let key = sng::SngKey {
                                epoch: w.epoch,
                                node,
                                row0: row0 as u64,
                                lanes: lanes as u32,
                                bl: bl as u32,
                                w: W as u32,
                            };
                            if self.sng_cache.fetch(&key, cuts_v, block) {
                                cache.hits += 1;
                            } else {
                                cache.misses += 1;
                                sng::sample_block_counter(cuts_v, bl, ctr, node, sng_ws, block);
                                self.sng_cache.store(key, cuts_v, block);
                            }
                        }
                    },
                }
                // SNG-output fault site: flip the freshly generated
                // stream's lane words in place, so the faulted bits
                // feed the gates *and* any correlated reuse exactly as
                // a flipped SBG cell would. Fault masks are stateless
                // (no RNG draws), so the draw order above is untouched.
                if let Some(cuts) = w.fault {
                    let site = cuts.sng_site(si, i);
                    for t in 0..bl {
                        block.xor_word(t, cuts.mask_words::<W>(cuts.sng, site, row0, lanes, t));
                    }
                }
                // Eq 4: one preset + one SBG write per generated cell
                // (every live lane × every time step of this input).
                ops.sbg_writes += (lanes * bl) as u64;
                ops.presets += (lanes * bl) as u64;
            }
            let t1 = Instant::now();
            // Stage-0 generation is fresh SNG; later stages regenerate
            // from the previous stage's StoB values in-lane.
            let gen_ns = t1.duration_since(t0).as_nanos() as u64;
            if si == 0 {
                spans.sng_ns += gen_ns;
            } else {
                spans.regen_ns += gen_ns;
            }
            let outs = match w.fault {
                Some(cuts) => stage.plan.eval_lanes_fault_into(
                    &inputs[..stage.plan.n_inputs()],
                    &mut plans[si],
                    cuts,
                    si,
                    row0,
                ),
                None => stage.plan.eval_lanes_into(&inputs[..stage.plan.n_inputs()], &mut plans[si]),
            };
            let t2 = Instant::now();
            spans.gate_ns += t2.duration_since(t1).as_nanos() as u64;
            // Eq 4: each instruction fires once per lane per time step
            // — a preset of its output row, then the bitline-computed
            // write — and each ADDIE island steps its accumulator.
            let lane_bits = (lanes * bl) as u64;
            let hist = stage.plan.gate_histogram();
            for (g, h) in ops.gates.iter_mut().zip(hist) {
                *g += h * lane_bits;
            }
            ops.presets += hist.iter().sum::<u64>() * lane_bits;
            ops.addie_steps += stage.plan.addie_count() as u64 * lane_bits;
            // Vertical-counter StoB readout for every stage output:
            // all lanes' counts without leaving the lane-major domain.
            let sv = &mut stage_vals[si];
            sv.clear();
            for ob in outs {
                ob.lane_popcounts_into(planes, counts);
                // Same arithmetic as Bitstream::value().
                sv.extend(counts.iter().map(|&c| c as f64 / bl as f64));
                ops.stob_reads += lane_bits;
            }
            spans.stob_ns += t2.elapsed().as_nanos() as u64;
        }
        let (rs, ro) = w.kernel.result();
        let sv = &stage_vals[rs];
        for (l, slot) in out.iter_mut().enumerate() {
            *slot = sv[ro * lanes + l] as f32;
        }
    }

    /// Scalar per-row wave (the golden staged-reference path): chunk
    /// the live rows across scoped workers; each worker reuses one
    /// instance buffer for all its rows.
    #[allow(clippy::too_many_arguments)]
    fn execute_scalar_rows(
        &self,
        name: &str,
        spec: &ArtifactSpec,
        kernel: &StagedPlan,
        values: &[f32],
        seed: i32,
        bl: usize,
        out: &mut [f32],
        threads: usize,
        rng: RngMode,
        fault: Option<&FaultCutoffs>,
    ) -> Result<()> {
        let live = out.len();
        if live == 0 {
            return Ok(());
        }
        let name_hash = fnv1a(name);
        let workers = threads.min(live).max(1);
        parallel_chunks(out, workers, live.div_ceil(workers), |start, sub| {
            let mut x = Vec::with_capacity(spec.n_inputs);
            for (j, slot) in sub.iter_mut().enumerate() {
                let row = start + j;
                clamp_instance_into(values, spec.n_inputs, row, &mut x);
                *slot = match rng {
                    RngMode::Xoshiro => {
                        let mut row_rng = row_rng(seed, name, row);
                        match fault {
                            Some(cuts) => kernel
                                .eval_row_scalar_fault(&x, bl, &mut row_rng, cuts, row as u64)
                                as f32,
                            None => kernel.eval_row_scalar(&x, bl, &mut row_rng) as f32,
                        }
                    }
                    RngMode::Counter => {
                        let rs = row_seed(seed, name_hash, row);
                        match fault {
                            Some(cuts) => kernel
                                .eval_row_scalar_counter_fault(&x, bl, rs, cuts, row as u64)
                                as f32,
                            None => kernel.eval_row_scalar_counter(&x, bl, rs) as f32,
                        }
                    }
                };
            }
            Ok(())
        })
    }
}

/// Per-worker scratch for the lane-major wave path, reused across
/// every lane block the worker evaluates: the RNG bank, the SNG draw /
/// cutoff scratch, per-lane value bindings, the lane-major input
/// blocks, per-stage StoB values, the plan's evaluation scratch, and
/// the vertical-counter readout buffers. A worker allocates once per
/// wave; after the first block every buffer is a cheap reshape.
#[derive(Default)]
struct BlockWorkspace<const W: usize> {
    /// One lockstep xoshiro stream per live lane (reseeded per block;
    /// compatibility path only).
    rngs: RngBank,
    /// One counter half-key per live lane (rekeyed per block; the
    /// default stateless path).
    ctr: CounterBank,
    /// Raw-draw scratch for the lane-major SNG.
    sng: sng::SngScratch,
    /// Per-wave cutoff memo, one slot per (stage, input) position —
    /// repeated values across the worker's blocks skip the per-lane
    /// ⌈v·2⁵³⌉ recomputation.
    cutcache: sng::CutoffCache,
    /// Per-lane threshold for the input currently being generated.
    vals: Vec<f64>,
    /// Clamped instance values, lane-major `[lane][input]`.
    instances: Vec<f64>,
    /// Correlated-group raw draws, lane-major `[t · lanes + l]`.
    uniforms: HashMap<u32, Vec<u64>>,
    /// Groups already drawn for the current stage (reset per stage).
    filled_groups: Vec<u32>,
    /// One lane-major block per netlist primary input (pool shared by
    /// all stages; only grows).
    inputs: Vec<LaneBlock<W>>,
    /// Per-stage StoB values, `[stage][output · lanes + lane]` — the
    /// in-lane regeneration sources.
    stage_vals: Vec<Vec<f64>>,
    /// Slot values / latches / ADDIE islands / output blocks, one
    /// scratch per stage so alternating stage shapes never reallocate.
    plans: Vec<PlanScratch<W>>,
    /// Carry-save counter planes for the StoB readout.
    planes: Vec<[u64; W]>,
    /// Per-lane popcounts from the vertical counter.
    counts: Vec<u32>,
}

/// Never degrade a wave's effective bitstream below this many steps —
/// a 16-step stream still carries a usable (if coarse) estimate, and
/// the floor keeps [`effective_bl`] well-defined for tiny manifests.
pub const MIN_DEGRADED_BL: usize = 16;

/// Effective bitstream length after `shift` degradation-ladder steps:
/// halved per step, floored at [`MIN_DEGRADED_BL`], never above the
/// manifest BL. The single source of truth shared by the engine (which
/// applies it) and the serving layer's overload controller (which picks
/// the step).
pub fn effective_bl(bl: usize, shift: u32) -> usize {
    let full = bl.max(1);
    (full >> shift.min(63)).max(MIN_DEGRADED_BL).min(full)
}

/// The explicit lane-width override from `STOCH_IMC_LANE_WIDTH`:
/// `None` when the var is unset — or not one of 64/128/256/512, which
/// warns and falls back to auto sizing.
pub fn lane_width_override() -> Option<usize> {
    let s = std::env::var("STOCH_IMC_LANE_WIDTH").ok()?;
    match s.trim().parse::<usize>() {
        Ok(w) if w == 64 || w == 128 || w == 256 || w == 512 => Some(w),
        _ => {
            eprintln!("STOCH_IMC_LANE_WIDTH=`{s}` is not one of 64|128|256|512; using auto");
            None
        }
    }
}

/// The explicit generator override from `STOCH_IMC_RNG`: `None` when
/// the var is unset — or not one of counter/xoshiro, which warns and
/// falls back to the counter default.
pub fn rng_mode_override() -> Option<RngMode> {
    let s = std::env::var("STOCH_IMC_RNG").ok()?;
    match s.trim().to_ascii_lowercase().as_str() {
        "counter" => Some(RngMode::Counter),
        "xoshiro" => Some(RngMode::Xoshiro),
        _ => {
            eprintln!("STOCH_IMC_RNG=`{s}` is not one of counter|xoshiro; using counter");
            None
        }
    }
}

/// Resolve the generator mode: an explicit argument wins, then the
/// `STOCH_IMC_RNG` env var, then the counter default.
fn resolve_rng_mode(rng: Option<RngMode>) -> RngMode {
    rng.or_else(rng_mode_override).unwrap_or_default()
}

/// Resolve the lane width for a wave of `live` rows on `threads`
/// workers: an explicit argument wins, then the `STOCH_IMC_LANE_WIDTH`
/// env var, then auto. Auto starts from the narrowest width that
/// covers the wave (≤ 64 rows → 64, ≤ 128 → 128, ≤ 256 → 256, else
/// 512) — so small waves don't drag dead lane words through every gate
/// — and then narrows while the wave would otherwise yield fewer lane
/// blocks than workers: wider words amortize the instruction walk, but
/// never at the price of idling the worker pool.
fn resolve_lane_width(lane_width: usize, live: usize, threads: usize) -> usize {
    let w = match lane_width {
        64 | 128 | 256 | 512 => lane_width,
        _ => lane_width_override().unwrap_or(0),
    };
    match w {
        64 | 128 | 256 | 512 => w,
        _ => {
            let mut width = if live <= 64 {
                64
            } else if live <= 128 {
                128
            } else if live <= 256 {
                256
            } else {
                512
            };
            while width > 64 && live.div_ceil(width) < threads {
                width /= 2;
            }
            width
        }
    }
}

/// Run `body` over `out` split into `chunk`-sized sub-slices across
/// scoped workers; `body` receives each sub-slice's starting row. Runs
/// inline (no spawn) when one worker — or one chunk — covers
/// everything. Shared by the lane-block and scalar wave paths so the
/// spawn/join/panic-mapping scaffolding exists once.
fn parallel_chunks<F>(out: &mut [f32], workers: usize, chunk: usize, body: F) -> Result<()>
where
    F: Fn(usize, &mut [f32]) -> Result<()> + Sync,
{
    if workers <= 1 || out.len() <= chunk {
        return body(0, out);
    }
    let results: Vec<Result<()>> = std::thread::scope(|s| {
        let handles: Vec<_> = out
            .chunks_mut(chunk)
            .enumerate()
            .map(|(ci, sub)| {
                let body = &body;
                s.spawn(move || body(ci * chunk, sub))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(crate::error::Error::msg("wave worker panicked")))
            })
            .collect()
    });
    for r in results {
        r?;
    }
    Ok(())
}

/// One instance's inputs, clamped into the unipolar domain, written
/// into a caller-reused buffer (no per-row allocation on the scalar
/// path).
fn clamp_instance_into(values: &[f32], n_inputs: usize, row: usize, out: &mut Vec<f64>) {
    out.clear();
    out.extend(
        values[row * n_inputs..(row + 1) * n_inputs]
            .iter()
            .map(|&v| (v as f64).clamp(0.0, 1.0)),
    );
}

/// The explicit row-worker override from `STOCH_IMC_ROW_THREADS`:
/// `None` when the var is unset — or unparseable, which warns and falls
/// back to auto rather than silently pinning waves sequential.
pub fn row_threads_override() -> Option<usize> {
    let s = std::env::var("STOCH_IMC_ROW_THREADS").ok()?;
    match s.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Some(n),
        _ => {
            eprintln!("STOCH_IMC_ROW_THREADS=`{s}` is not a positive integer; using auto");
            None
        }
    }
}

/// The auto row-worker count: the `STOCH_IMC_ROW_THREADS` env var when
/// set, else the machine's available parallelism. Benches pin this
/// explicitly to compare the sequential and row-parallel paths.
pub fn default_row_threads() -> usize {
    row_threads_override()
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine_with(manifest: &str, tag: &str) -> InterpEngine {
        let dir = std::env::temp_dir().join(format!("stoch_imc_interp_unit_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
        InterpEngine::load(&dir).expect("engine load")
    }

    #[test]
    fn multiply_values_and_seed_behaviour() {
        let e = engine_with("op_multiply 2 4 4096\n", "mul");
        let spec = e.spec("op_multiply").unwrap().clone();
        let mut values = vec![0.0f32; spec.batch * 2];
        values[0] = 0.5;
        values[1] = 0.5;
        values[2] = 0.9;
        values[3] = 0.8;
        let out = e.execute("op_multiply", &values, 42, spec.batch).unwrap();
        assert_eq!(out.len(), spec.batch);
        assert!((out[0] - 0.25).abs() < 0.04, "out[0]={}", out[0]);
        assert!((out[1] - 0.72).abs() < 0.04, "out[1]={}", out[1]);
        // Different seeds resample streams; values stay close.
        let a = e.execute("op_multiply", &values, 1, spec.batch).unwrap();
        let b = e.execute("op_multiply", &values, 2, spec.batch).unwrap();
        assert_ne!(a, b, "seed must resample");
        assert!((a[0] as f64 - b[0] as f64).abs() < 0.1);
        // Same seed is bit-deterministic.
        assert_eq!(a, e.execute("op_multiply", &values, 1, spec.batch).unwrap());
        // Wrong input size / unknown artifact are rejected.
        assert!(e.execute("op_multiply", &values[..2], 1, 2).is_err());
        assert!(e.execute("nope", &values, 1, spec.batch).is_err());
    }

    #[test]
    fn row_parallel_matches_sequential_bit_exactly() {
        // Each row draws its own row_rng stream, so the worker split is
        // invisible in the outputs — any thread count, same bits.
        let e = engine_with("op_multiply 2 16 1024\n", "rowpar");
        let mut values = vec![0.0f32; 16 * 2];
        for i in 0..16 {
            values[2 * i] = 0.05 * (i + 1) as f32;
            values[2 * i + 1] = 0.5;
        }
        let seq = e.execute_rows("op_multiply", &values, 9, 16, 1).unwrap();
        for t in [2usize, 3, 5, 16, 64] {
            let par = e.execute_rows("op_multiply", &values, 9, 16, t).unwrap();
            assert_eq!(seq, par, "threads={t}");
        }
        // Partial live prefix: padding rows stay 0.0 on every path.
        let partial = e.execute_rows("op_multiply", &values, 9, 5, 4).unwrap();
        assert_eq!(&partial[..5], &seq[..5]);
        assert!(partial[5..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn word_parallel_matches_scalar_golden_path() {
        // The word-parallel lane-block path must be bit-identical to
        // the scalar golden path for ragged live counts (lane blocks of
        // 64, 64, 12) and every thread count. BL=100 also exercises the
        // ragged tail word (100 % 64 != 0).
        let e = engine_with("op_scaled_divide 2 140 100\n", "wordpar");
        let mut values = vec![0.0f32; 140 * 2];
        for i in 0..140 {
            values[2 * i] = 0.1 + 0.005 * i as f32;
            values[2 * i + 1] = 0.9 - 0.005 * i as f32;
        }
        for live in [1usize, 63, 64, 65, 140] {
            let golden = e.execute_rows_scalar("op_scaled_divide", &values, 21, live, 1).unwrap();
            for t in [1usize, 2, 5] {
                let word = e.execute_rows("op_scaled_divide", &values, 21, live, t).unwrap();
                assert_eq!(golden, word, "live={live} threads={t}");
            }
            // Explicit lane widths must all match the golden path too:
            // width only changes how many rows share a lane word.
            for width in [64usize, 128, 256, 512] {
                let word =
                    e.execute_rows_wide("op_scaled_divide", &values, 21, live, 2, width).unwrap();
                assert_eq!(golden, word, "live={live} width={width}");
            }
        }
    }

    #[test]
    fn staged_app_rides_lane_blocks_and_matches_scalar_reference() {
        // The staged KDE pipeline must be bit-identical between the
        // per-row staged reference and the lane-major staged executor
        // for a ragged two-block wave (the full matrix lives in
        // tests/staged.rs; this is the fast in-crate sentinel).
        let e = engine_with("app_kde 9 70 64\n", "staged");
        let mut values = vec![0.0f32; 70 * 9];
        for (i, v) in values.iter_mut().enumerate() {
            *v = 0.05 + 0.9 * ((i * 29) % 97) as f32 / 97.0;
        }
        let golden = e.execute_rows_scalar("app_kde", &values, 17, 70, 1).unwrap();
        for (threads, width) in [(1usize, 64usize), (3, 128), (2, 0)] {
            let word = e.execute_rows_wide("app_kde", &values, 17, 70, threads, width).unwrap();
            assert_eq!(golden, word, "threads={threads} width={width}");
        }
        // Determinism + reseeding on the staged path.
        let again = e.execute_rows("app_kde", &values, 17, 70, 2).unwrap();
        assert_eq!(golden, again);
        let other = e.execute_rows("app_kde", &values, 18, 70, 2).unwrap();
        assert_ne!(golden, other, "seed must resample staged waves");
    }

    #[test]
    fn all_builtin_artifacts_close_to_reference() {
        let e = engine_with(
            "op_multiply 2 1 8192\nop_scaled_add 2 1 8192\nop_abs_subtract 2 1 8192\n\
             op_scaled_divide 2 1 8192\nop_square_root 1 1 8192\nop_exponential 1 1 8192\n",
            "ops",
        );
        let two = [0.7f32, 0.3];
        let one = [0.6f32, 0.0];
        let cases: [(&str, &[f32], usize, f64); 6] = [
            ("op_multiply", &two, 2, 0.7 * 0.3),
            ("op_scaled_add", &two, 2, 0.5 * (0.7 + 0.3)),
            ("op_abs_subtract", &two, 2, 0.4),
            ("op_scaled_divide", &two, 2, 0.7 / (0.7 + 0.3)),
            ("op_square_root", &one[..1], 1, 0.6f64.sqrt()),
            ("op_exponential", &one[..1], 1, (-0.6f64).exp()),
        ];
        for (name, vals, n, want) in cases {
            let out = e.execute(name, &vals[..n], 7, 1).unwrap();
            assert!(
                (out[0] as f64 - want).abs() < 0.05,
                "{name}: got {} want {want}",
                out[0]
            );
        }
    }

    #[test]
    fn app_netlists_match_float_reference() {
        let e = engine_with("app_ol 6 2 4096\napp_hdp 8 2 16384\n", "apps");
        let ol = Ol::default();
        let w = ol.workload(2, 3);
        let mut values = Vec::new();
        for inst in &w {
            values.extend(inst.iter().map(|&v| v as f32));
        }
        let out = e.execute("app_ol", &values, 11, 2).unwrap();
        for (inst, o) in w.iter().zip(&out) {
            let f = ol.float_ref(inst);
            assert!((*o as f64 - f).abs() < 0.06, "ol got {o} want {f}");
        }
        let hdp = Hdp;
        let w = hdp.workload(2, 5);
        let mut values = Vec::new();
        for inst in &w {
            values.extend(inst.iter().map(|&v| v as f32));
        }
        let out = e.execute("app_hdp", &values, 13, 2).unwrap();
        for (inst, o) in w.iter().zip(&out) {
            let f = hdp.float_ref(inst);
            // The N/(N+M) divider amplifies stream noise when N+M is
            // small, hence the long streams and looser bound.
            assert!((*o as f64 - f).abs() < 0.15, "hdp got {o} want {f}");
        }
    }

    #[test]
    fn instrumented_wave_counts_ops_and_matches_clean_bits() {
        // op_multiply is one AND over two generated inputs: per live
        // lane per time step that is 2 SBG writes, 1 gate fire, 1 StoB
        // read, and 3 presets — exact Eq 4 counters for the wave.
        let e = engine_with("op_multiply 2 70 512\n", "instr");
        let mut values = vec![0.0f32; 70 * 2];
        for (i, v) in values.iter_mut().enumerate() {
            *v = 0.05 + 0.9 * ((i * 37) % 89) as f32 / 89.0;
        }
        let clean = e.execute_rows("op_multiply", &values, 5, 70, 2).unwrap();
        // A rate-0 plan degrades to the clean path bit for bit — but
        // the counters still run.
        let zero = FaultPlan::uniform(0.0, 9);
        let (out, stats) = e
            .execute_rows_instrumented("op_multiply", &values, 5, 70, 2, 0, Some(&zero))
            .unwrap();
        assert_eq!(clean, out, "rate-0 plan must not disturb the wave");
        let lb = 70u64 * 512;
        assert_eq!(stats.ops.sbg_writes, 2 * lb);
        assert_eq!(stats.ops.gate_total(), lb);
        assert_eq!(stats.ops.stob_reads, lb);
        assert_eq!(stats.ops.presets, 3 * lb);
        assert_eq!(stats.ops.addie_steps, 0);
        assert_eq!(stats.wear.writes, stats.ops.write_total());
        assert_eq!(stats.wear.max_cell_writes, 2 * 512);
        assert!(stats.wear.used_cells >= 3 * 70, "≥ one slot per node per lane");
        // Counters are wave-invariants: same totals for any worker
        // split or lane width. Spans are measured wall-clock, so only
        // the invariant fields compare equal — the spans just have to
        // be present (a wave that executed took nonzero time).
        let (_, again) = e
            .execute_rows_instrumented("op_multiply", &values, 5, 70, 5, 64, None)
            .unwrap();
        assert_eq!(stats.ops, again.ops);
        assert_eq!(stats.wear, again.wear);
        assert!(stats.spans.total_ns() > 0, "instrumented wave must time its stages");
        assert!(again.spans.total_ns() > 0);
        // A live plan flips bits — and the faulted lane path stays
        // bit-identical to the faulted scalar golden reference.
        let plan = FaultPlan::uniform(0.05, 9);
        let (faulty, _) = e
            .execute_rows_instrumented("op_multiply", &values, 5, 70, 2, 0, Some(&plan))
            .unwrap();
        assert_ne!(clean, faulty, "5% flips must disturb outputs");
        let golden =
            e.execute_rows_scalar_fault("op_multiply", &values, 5, 70, 1, &plan).unwrap();
        assert_eq!(faulty, golden, "faulty lane path vs faulty scalar reference");
    }

    #[test]
    fn rng_modes_are_pinned_and_distinct() {
        let e = engine_with("op_multiply 2 40 512\n", "rngmode");
        let mut values = vec![0.0f32; 40 * 2];
        for i in 0..40 {
            values[2 * i] = 0.1 + 0.02 * i as f32;
            values[2 * i + 1] = 0.9 - 0.02 * i as f32;
        }
        let (ctr, _) = e
            .execute_rows_tuned("op_multiply", &values, 3, 40, 2, 0, Some(RngMode::Counter), None)
            .unwrap();
        let (xos, _) = e
            .execute_rows_tuned("op_multiply", &values, 3, 40, 2, 0, Some(RngMode::Xoshiro), None)
            .unwrap();
        assert_ne!(ctr, xos, "the two generator families must not alias");
        // Each lane path is bit-pinned to its own scalar reference.
        let ctr_ref = e
            .execute_rows_scalar_tuned("op_multiply", &values, 3, 40, 1, Some(RngMode::Counter))
            .unwrap();
        let xos_ref = e
            .execute_rows_scalar_tuned("op_multiply", &values, 3, 40, 1, Some(RngMode::Xoshiro))
            .unwrap();
        assert_eq!(ctr, ctr_ref, "counter lane path vs counter scalar reference");
        assert_eq!(xos, xos_ref, "xoshiro lane path vs xoshiro scalar reference");
        // The env-resolved default is the counter path.
        assert_eq!(ctr, e.execute_rows("op_multiply", &values, 3, 40, 2).unwrap());
    }

    #[test]
    fn counter_sng_cache_hits_on_repeated_waves() {
        // A repeated-value batch re-executed under one seed must reuse
        // the packed SNG words: zero hits the first time (every block
        // is generated and stored), all hits the second.
        let e = engine_with("op_multiply 2 128 256\n", "sngcache");
        let mut values = vec![0.0f32; 128 * 2];
        for i in 0..128 {
            values[2 * i] = 0.6;
            values[2 * i + 1] = 0.3;
        }
        let run = || {
            e.execute_rows_tuned(
                "op_multiply",
                &values,
                3,
                128,
                1,
                64,
                Some(RngMode::Counter),
                None,
            )
            .unwrap()
        };
        let (a, s1) = run();
        assert_eq!(s1.cache.hits, 0, "fresh engine cannot hit");
        assert!(s1.cache.misses > 0);
        // The repeated values also exercise the per-wave cutoff memo:
        // the second 64-row block repeats the first block's value
        // vectors at every input slot.
        assert!(s1.cache.cutoff_hits > 0, "repeated values must hit the cutoff memo");
        let (b, s2) = run();
        assert_eq!(a, b, "cache hits must be bit-identical to regeneration");
        assert!(s2.cache.hits > 0, "repeated wave must hit the SNG block cache");
        assert_eq!(s2.cache.misses, 0, "every block of the repeat is cached");
        assert!(s2.cache.hit_rate() > 0.99);
        // Fault masks XOR in after the cache, so a faulted repeat is
        // deterministic across the generate and fetch paths too.
        let plan = FaultPlan::uniform(0.05, 7);
        let faulted = |p: &FaultPlan| {
            e.execute_rows_tuned(
                "op_multiply",
                &values,
                3,
                128,
                1,
                64,
                Some(RngMode::Counter),
                Some(p),
            )
            .unwrap()
        };
        let (f1, _) = faulted(&plan);
        let (f2, sf) = faulted(&plan);
        assert_eq!(f1, f2);
        assert!(sf.cache.hits > 0);
        assert_ne!(f1, a, "5% flips must disturb outputs");
    }

    #[test]
    fn artifacts_without_kernels_are_skipped_at_load() {
        let e = engine_with("op_mystery 2 1 256\nop_multiply 2 1 256\n", "mystery");
        // The unknown name is not registered, so the coordinator will
        // reject submits against it up front; the known one survives.
        assert!(e.spec("op_mystery").is_none());
        assert_eq!(e.artifact_names(), vec!["op_multiply"]);
        let err = e.execute("op_mystery", &[0.5, 0.5], 1, 1).unwrap_err();
        assert!(format!("{err:#}").contains("unknown artifact"), "{err:#}");
    }

    #[test]
    fn arity_mismatched_artifacts_are_skipped_at_load() {
        // A wrong manifest arity must not silently compute over a
        // different input layout than the PJRT artifact of the same
        // name — such entries are not registered at all.
        let e = engine_with(
            "app_lit 32 1 256\napp_kde 4 1 256\nop_multiply 3 1 256\napp_ol 6 1 256\n",
            "arity",
        );
        assert!(e.spec("app_lit").is_none());
        assert!(e.spec("app_kde").is_none());
        assert!(e.spec("op_multiply").is_none());
        assert_eq!(e.artifact_names(), vec!["app_ol"]);
        let err = e.execute("app_lit", &[0.5; 32], 1, 1).unwrap_err();
        assert!(format!("{err:#}").contains("unknown artifact"), "{err:#}");
    }

    #[test]
    fn degraded_wave_matches_shorter_bl_artifact_bit_exactly() {
        // The graceful-degradation contract: because row streams are
        // addressed by (seed, name, row) — never by BL — and StoB
        // normalizes by the effective BL, a shift-k degraded wave on a
        // BL=B manifest is bit-identical to full execution of the same
        // kernel compiled at BL = B >> k.
        let full = engine_with("op_multiply 2 24 256\n", "deg_full");
        let half = engine_with("op_multiply 2 24 128\n", "deg_half");
        let mut values = vec![0.0f32; 24 * 2];
        for i in 0..24 {
            values[2 * i] = 0.1 + 0.03 * i as f32;
            values[2 * i + 1] = 0.85 - 0.02 * i as f32;
        }
        let run = |e: &InterpEngine, shift: u32| {
            e.execute_rows_degraded("op_multiply", &values, 7, 24, 2, 0, None, None, shift)
                .unwrap()
                .0
        };
        // shift 0 is exactly the tuned path.
        assert_eq!(
            run(&full, 0),
            full.execute_rows("op_multiply", &values, 7, 24, 2).unwrap()
        );
        // One ladder step == the half-BL artifact, bit for bit.
        assert_eq!(run(&full, 1), run(&half, 0), "degraded 256>>1 vs native BL=128");
        // Degradation costs variance, not correctness: both stay near
        // the exact product.
        for (i, o) in run(&full, 1).iter().enumerate() {
            let exact = f64::from(values[2 * i]) * f64::from(values[2 * i + 1]);
            assert!((f64::from(*o) - exact).abs() < 0.15, "row {i}: {o} vs {exact}");
        }
        // The ladder floors at MIN_DEGRADED_BL: a huge shift on BL=256
        // clamps to 16, which equals the native BL=16 artifact.
        let floor = engine_with("op_multiply 2 24 16\n", "deg_floor");
        assert_eq!(effective_bl(256, 60), MIN_DEGRADED_BL);
        assert_eq!(run(&full, 60), run(&floor, 0), "floored shift vs native BL=16");
    }
}
