//! S14 — execution runtime: artifact registry + pluggable execution
//! engine.
//!
//! [`Engine`] is the backend abstraction the coordinator drives. Two
//! backends implement the same `(values f32[B, n], seed i32) → f32[B]`
//! artifact contract:
//!
//! * **interp** (default, always available): the pure-Rust bit-plane
//!   interpreter in [`interp`], which evaluates each artifact through
//!   the crate's own netlist/bitstream models. Needs only
//!   `manifest.txt`.
//! * **pjrt** (`xla-runtime` feature + a vendored `xla` crate): the
//!   PJRT client in `client`, executing the AOT HLO-text artifacts
//!   produced by `python -m compile.aot`. Pattern:
//!   `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile`
//!   → `execute`; HLO *text* is the interchange format (64-bit-id proto
//!   incompatibility — see python/compile/aot.py).
//!
//! Backend selection: `STOCH_IMC_BACKEND=interp|pjrt` (default
//! `interp`).

pub mod artifacts;
pub mod interp;

#[cfg(all(feature = "xla-runtime", xla_available))]
pub mod client;

// The `xla` crate is not vendored in this workspace, so the feature on
// its own cannot link. Fail with one clear message instead of a cascade
// of unresolved-crate errors.
#[cfg(all(feature = "xla-runtime", not(xla_available)))]
compile_error!(
    "the `xla-runtime` feature needs the PJRT `xla` crate, which is not \
     vendored in this workspace. Add `xla = { git = \"...\" }` (or a \
     vendored path) to rust/Cargo.toml and build with \
     RUSTFLAGS=\"--cfg xla_available\" --features xla-runtime. The \
     default build uses the pure-Rust interpreter backend instead."
);

pub use artifacts::{load_manifest, ArtifactSpec};
pub use interp::{
    default_row_threads, effective_bl, lane_width_override, rng_mode_override,
    row_threads_override, InterpEngine, WaveStats, MIN_DEGRADED_BL,
};

use std::path::Path;

use crate::bail;
use crate::error::Result;
use crate::fault::FaultPlan;
use crate::util::prng::RngMode;

/// A loaded execution backend over one artifact directory.
pub enum Engine {
    Interp(InterpEngine),
    #[cfg(all(feature = "xla-runtime", xla_available))]
    Pjrt(client::PjrtEngine),
}

impl Engine {
    /// Load the backend selected by `STOCH_IMC_BACKEND` (default: the
    /// interpreter) over the artifacts in `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let backend = std::env::var("STOCH_IMC_BACKEND").unwrap_or_default();
        match backend.as_str() {
            "" | "interp" => Ok(Engine::Interp(InterpEngine::load(dir)?)),
            #[cfg(all(feature = "xla-runtime", xla_available))]
            "pjrt" => Ok(Engine::Pjrt(client::PjrtEngine::load(dir)?)),
            other => bail!(
                "unknown STOCH_IMC_BACKEND `{other}` (have: interp{}){}",
                if cfg!(all(feature = "xla-runtime", xla_available)) { ", pjrt" } else { "" },
                if other == "pjrt" && !cfg!(all(feature = "xla-runtime", xla_available)) {
                    " — rebuild with --features xla-runtime and a vendored xla crate"
                } else {
                    ""
                }
            ),
        }
    }

    /// Backend/platform name (e.g. `interp`, `cpu`).
    pub fn platform(&self) -> String {
        match self {
            Engine::Interp(e) => e.platform(),
            #[cfg(all(feature = "xla-runtime", xla_available))]
            Engine::Pjrt(e) => e.platform(),
        }
    }

    /// Registered artifact names, sorted.
    pub fn artifact_names(&self) -> Vec<&str> {
        match self {
            Engine::Interp(e) => e.artifact_names(),
            #[cfg(all(feature = "xla-runtime", xla_available))]
            Engine::Pjrt(e) => e.artifact_names(),
        }
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        match self {
            Engine::Interp(e) => e.spec(name),
            #[cfg(all(feature = "xla-runtime", xla_available))]
            Engine::Pjrt(e) => e.spec(name),
        }
    }

    /// Execute one batch: `values` is row-major [batch, n_inputs]
    /// (padded by the caller); returns the [batch] outputs. `live` is
    /// the number of leading non-padding rows: the interpreter skips
    /// the padding (returned as 0.0), while PJRT always runs the full
    /// fixed-shape batch.
    pub fn execute(&self, name: &str, values: &[f32], seed: i32, live: usize) -> Result<Vec<f32>> {
        match self {
            Engine::Interp(e) => e.execute(name, values, seed, live),
            #[cfg(all(feature = "xla-runtime", xla_available))]
            Engine::Pjrt(e) => e.execute(name, values, seed, live),
        }
    }

    /// [`Engine::execute`] with an explicit row-worker count (`0` =
    /// auto, `1` = sequential). The interpreter splits the live batch
    /// rows across scoped workers with bit-identical outputs; PJRT
    /// always runs its fixed-shape batch and ignores the knob.
    pub fn execute_rows(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
    ) -> Result<Vec<f32>> {
        self.execute_rows_wide(name, values, seed, live, threads, 0)
    }

    /// [`Engine::execute_rows`] with an explicit lane width (rows per
    /// lane block: 64, 128, 256, or 512; `0` = auto). The interpreter
    /// monomorphizes its wave over `u64×{1,2,4,8}` lane words with
    /// bit-identical outputs at every width; PJRT always runs its
    /// fixed-shape batch and ignores both knobs.
    pub fn execute_rows_wide(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
        lane_width: usize,
    ) -> Result<Vec<f32>> {
        match self {
            Engine::Interp(e) => e.execute_rows_wide(name, values, seed, live, threads, lane_width),
            #[cfg(all(feature = "xla-runtime", xla_available))]
            Engine::Pjrt(e) => {
                let _ = (threads, lane_width);
                e.execute(name, values, seed, live)
            }
        }
    }

    /// [`Engine::execute_rows_wide`] with reliability instrumentation:
    /// the interpreter injects the optional [`FaultPlan`]'s stateless
    /// masks at the paper's SNG/gate/StoB sites and returns the wave's
    /// Eq 4 / Eq 11 [`WaveStats`] alongside the outputs. PJRT executes
    /// clean and reports empty stats (no circuit model to instrument).
    #[allow(clippy::too_many_arguments)]
    pub fn execute_rows_instrumented(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
        lane_width: usize,
        fault: Option<&FaultPlan>,
    ) -> Result<(Vec<f32>, WaveStats)> {
        match self {
            Engine::Interp(e) => {
                e.execute_rows_instrumented(name, values, seed, live, threads, lane_width, fault)
            }
            #[cfg(all(feature = "xla-runtime", xla_available))]
            Engine::Pjrt(e) => {
                let _ = (threads, lane_width, fault);
                Ok((e.execute(name, values, seed, live)?, WaveStats::default()))
            }
        }
    }

    /// [`Engine::execute_rows_instrumented`] with an explicit RNG mode
    /// (`None` = the `STOCH_IMC_RNG` env default): the interpreter
    /// drives its SNGs from either the counter-based stateless family
    /// (default) or the pinned xoshiro compat bank. PJRT has no
    /// circuit-level SNG model and ignores the knob.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_rows_tuned(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
        lane_width: usize,
        rng: Option<RngMode>,
        fault: Option<&FaultPlan>,
    ) -> Result<(Vec<f32>, WaveStats)> {
        match self {
            Engine::Interp(e) => {
                e.execute_rows_tuned(name, values, seed, live, threads, lane_width, rng, fault)
            }
            #[cfg(all(feature = "xla-runtime", xla_available))]
            Engine::Pjrt(e) => {
                let _ = (threads, lane_width, rng, fault);
                Ok((e.execute(name, values, seed, live)?, WaveStats::default()))
            }
        }
    }

    /// [`Engine::execute_rows_tuned`] with a degradation level: the
    /// interpreter runs the wave at `effective_bl(manifest BL,
    /// bl_shift)` — the serving layer's graceful-degradation ladder
    /// (accuracy traded for latency, bit-identical to a manifest
    /// compiled at the shorter BL). `bl_shift = 0` is exactly the tuned
    /// path. PJRT executes its fixed artifact and ignores the shift.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_rows_degraded(
        &self,
        name: &str,
        values: &[f32],
        seed: i32,
        live: usize,
        threads: usize,
        lane_width: usize,
        rng: Option<RngMode>,
        fault: Option<&FaultPlan>,
        bl_shift: u32,
    ) -> Result<(Vec<f32>, WaveStats)> {
        match self {
            Engine::Interp(e) => e.execute_rows_degraded(
                name, values, seed, live, threads, lane_width, rng, fault, bl_shift,
            ),
            #[cfg(all(feature = "xla-runtime", xla_available))]
            Engine::Pjrt(e) => {
                let _ = (threads, lane_width, rng, fault, bl_shift);
                Ok((e.execute(name, values, seed, live)?, WaveStats::default()))
            }
        }
    }
}

/// Smoke helper kept for the PJRT round-trip integration test: loads a
/// 2×2 matmul HLO artifact and executes it.
#[cfg(all(feature = "xla-runtime", xla_available))]
pub fn smoke(path: &str) -> Result<Vec<f32>> {
    use crate::error::Context;
    let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
    let proto = xla::HloModuleProto::from_text_file(path).context("parsing HLO text")?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).context("compiling")?;
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2]).context("reshape x")?;
    let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2]).context("reshape y")?;
    let result = exe.execute::<xla::Literal>(&[x, y]).context("execute")?[0][0]
        .to_literal_sync()
        .context("fetch result")?;
    result.to_tuple1().context("untuple")?.to_vec::<f32>().context("to_vec")
}
