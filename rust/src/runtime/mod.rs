//! S14 — PJRT runtime: artifact registry + execution engine.
//!
//! Pattern (see /opt/xla-example): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. HLO *text*
//! is the interchange format (64-bit-id proto incompatibility — see
//! python/compile/aot.py).

pub mod artifacts;
pub mod client;

pub use artifacts::{load_manifest, ArtifactSpec};
pub use client::Engine;

use anyhow::Result;

/// Smoke helper kept for the round-trip integration test: loads a 2×2
/// matmul HLO artifact and executes it.
pub fn smoke(path: &str) -> Result<Vec<f32>> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2])?;
    let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2])?;
    let result = exe.execute::<xla::Literal>(&[x, y])?[0][0].to_literal_sync()?;
    Ok(result.to_tuple1()?.to_vec::<f32>()?)
}
