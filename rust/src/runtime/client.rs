//! PJRT execution engine (`xla-runtime` feature + `--cfg xla_available`):
//! loads the AOT HLO-text artifacts and runs them on the CPU PJRT
//! client. This is the only place the request path touches XLA; Python
//! never runs at serving time.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::bail;
use crate::error::{Context, Result};

use super::artifacts::{load_manifest, ArtifactSpec};

pub struct PjrtEngine {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    /// Lazily compiled executables (XLA compilation of the large app
    /// graphs takes tens of seconds; only pay for what runs).
    compiled: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl PjrtEngine {
    /// Create a CPU PJRT client and register every artifact in `dir`.
    /// Compilation happens lazily on first execution per artifact.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut specs = HashMap::new();
        for spec in load_manifest(dir)? {
            specs.insert(spec.name.clone(), spec);
        }
        Ok(Self { client, dir: dir.to_path_buf(), specs, compiled: RefCell::default() })
    }

    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.compiled.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.specs.get(name).with_context(|| format!("unknown artifact `{name}`"))?;
        let path = spec.path(&self.dir);
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        self.compiled.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.specs.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// Execute one batch: `values` is row-major [batch, n_inputs]
    /// (padded by the caller); returns the [batch] outputs. `_live` is
    /// ignored: the compiled executable has a fixed [batch, n] shape,
    /// so padding rows are computed either way.
    pub fn execute(&self, name: &str, values: &[f32], seed: i32, _live: usize) -> Result<Vec<f32>> {
        let Some(spec) = self.specs.get(name) else {
            bail!("unknown artifact `{name}`");
        };
        self.ensure_compiled(name)?;
        if values.len() != spec.batch * spec.n_inputs {
            bail!(
                "artifact `{name}` expects {}×{} values, got {}",
                spec.batch,
                spec.n_inputs,
                values.len()
            );
        }
        let v = xla::Literal::vec1(values)
            .reshape(&[spec.batch as i64, spec.n_inputs as i64])
            .context("reshaping batch")?;
        let s = xla::Literal::from(seed);
        let compiled = self.compiled.borrow();
        let exe = compiled.get(name).expect("compiled above");
        let result = exe
            .execute::<xla::Literal>(&[v, s])
            .context("executing")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let out = result.to_tuple1().context("untupling result")?;
        out.to_vec::<f32>().context("reading result")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Requires `make artifacts` (skipped when absent). Loads a
    // single-artifact manifest so the test compiles one small HLO
    // module, not all ten; the integration suite and the examples
    // exercise the full registry.
    fn engine_with_only(name: &str) -> Option<PjrtEngine> {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !src.join("manifest.txt").exists() {
            return None;
        }
        let manifest = std::fs::read_to_string(src.join("manifest.txt")).ok()?;
        let line = manifest.lines().find(|l| l.starts_with(name))?;
        let dir = std::env::temp_dir().join(format!("stoch_imc_rt_{name}"));
        std::fs::create_dir_all(&dir).ok()?;
        std::fs::write(dir.join("manifest.txt"), format!("{line}\n")).ok()?;
        std::fs::copy(
            src.join(format!("{name}.hlo.txt")),
            dir.join(format!("{name}.hlo.txt")),
        )
        .ok()?;
        Some(PjrtEngine::load(&dir).expect("engine load"))
    }

    #[test]
    fn multiply_artifact_values_and_seed_behaviour() {
        let Some(e) = engine_with_only("op_multiply") else { return };
        let spec = e.spec("op_multiply").unwrap().clone();
        let mut values = vec![0.0f32; spec.batch * 2];
        values[0] = 0.5;
        values[1] = 0.5;
        values[2] = 0.9;
        values[3] = 0.8;
        let out = e.execute("op_multiply", &values, 42, spec.batch).unwrap();
        assert_eq!(out.len(), spec.batch);
        assert!((out[0] - 0.25).abs() < 0.06, "out[0]={}", out[0]);
        assert!((out[1] - 0.72).abs() < 0.07, "out[1]={}", out[1]);
        // Different seeds resample streams; values stay close.
        let a = e.execute("op_multiply", &values, 1, spec.batch).unwrap();
        let b = e.execute("op_multiply", &values, 2, spec.batch).unwrap();
        assert!((a[0] - b[0]).abs() < 0.15);
        // Wrong input size is rejected.
        assert!(e.execute("op_multiply", &values[..2], 1, 2).is_err());
        assert!(e.execute("nope", &values, 1, spec.batch).is_err());
    }
}
