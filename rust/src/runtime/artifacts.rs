//! Artifact registry: the manifest written by `python -m compile.aot`
//! (name, n_inputs, batch, bl per line; `#` comments and blank lines
//! skipped) and artifact path resolution.
//!
//! `bl` is the per-artifact bitstream-length knob: the paper's default
//! is 256 (§5.1), and artifacts whose circuits amplify stream noise
//! (e.g. feedback dividers) can ask for longer streams individually —
//! see the committed `artifacts/manifest.txt`.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::error::{Context, Result};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub n_inputs: usize,
    pub batch: usize,
    pub bl: usize,
}

impl ArtifactSpec {
    pub fn path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.hlo.txt", self.name))
    }
}

/// Parse `manifest.txt` in `dir`.
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
    let mut specs = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 4 {
            bail!("manifest line {}: expected 4 fields, got {}", i + 1, parts.len());
        }
        specs.push(ArtifactSpec {
            name: parts[0].to_string(),
            n_inputs: parts[1].parse().context("n_inputs")?,
            batch: parts[2].parse().context("batch")?,
            bl: parts[3].parse().context("bl")?,
        });
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_lines() {
        let dir = std::env::temp_dir().join("stoch_imc_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# name n_inputs batch bl\nop_multiply 2 64 256\n\napp_ol 6 64 1024\n",
        )
        .unwrap();
        let specs = load_manifest(&dir).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "op_multiply");
        assert_eq!(specs[1].n_inputs, 6);
        // BL is a per-artifact knob: each line carries its own value.
        assert_eq!(specs[0].bl, 256);
        assert_eq!(specs[1].bl, 1024);
        assert_eq!(specs[0].path(&dir).file_name().unwrap(), "op_multiply.hlo.txt");
    }

    #[test]
    fn missing_manifest_is_informative() {
        let err = load_manifest(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
