//! S16 — the sharded bank-parallel serving subsystem.
//!
//! The paper's headline win is *bit-parallel* execution across memory
//! banks/subarray groups (§4, 135.7× over binary IMC): every bank owns a
//! controller that fires whole subarray-group waves independently of the
//! other banks. This module models that bank-level parallelism in the
//! software serving path:
//!
//! * [`shard::Shard`] — one *bank controller*: a batcher + executor
//!   thread behind a **bounded** admission queue. A shard owns the wave
//!   loop for the artifacts routed to it, exactly like the single
//!   controller the coordinator used to run for *all* apps.
//! * [`BankPool`] — owns the N shards and the app → shard routing (one
//!   shard per artifact by default, FNV-hashed when fewer shards than
//!   apps are configured). All shards share one [`runtime::Engine`]
//!   behind an `Arc`, the way banks share the chip's global periphery.
//! * [`Server`] — the front door: `submit` / `try_submit` (admission
//!   control with backpressure), `run_workload`, `drain`, and pool-wide
//!   aggregated [`Metrics`].
//! * [`resilience`] — the failure-domain toolkit: typed replies
//!   (`Result<f32, ServeError>`), supervised executors (`catch_unwind`
//!   + bounded respawns; dead shards are routed around), per-request
//!   deadlines ([`SubmitOpts`]), the adaptive BL-degradation ladder
//!   ([`DegradeConfig`] — the SC-native accuracy-for-latency trade
//!   under overload), and the [`ChaosPlan`] fault injectors pinned by
//!   `tests/chaos.rs`. See ARCHITECTURE.md "Failure domains &
//!   graceful degradation".
//! * [`net`] — the TCP front door: a std-only length-prefixed wire
//!   protocol carrying deadline budgets and the full `ServeError`
//!   taxonomy, a bounded-thread [`net::TcpFront`] with slow-peer
//!   defenses and graceful drain, and a retrying [`net::Client`] with
//!   seeded backoff and a circuit breaker — the same
//!   exactly-one-terminal-outcome contract, across a socket. See
//!   ARCHITECTURE.md "Network front door".
//!
//! Row-level parallelism composes underneath: each wave is evaluated
//! by the word-parallel engine via
//! [`runtime::InterpEngine::execute_rows`] — every kernel packs up
//! to 512 batch rows per `u64×W` lane word (lane-major SNG → staged
//! gate plans with in-lane StoB→BtoS regeneration → vertical-counter
//! StoB, no per-row intermediates) and
//! split the lane blocks across a scoped worker pool — so shard-level
//! (bank) and row-level (subarray row) parallelism mirror the paper's
//! two-level hierarchy. `ServerConfig::lane_width` /
//! `STOCH_IMC_LANE_WIDTH` pins the block width (64/128/256/512;
//! default auto-sizes per wave), and `ServerConfig::rng` /
//! `STOCH_IMC_RNG` selects the SNG generator family (counter-based
//! stateless default, lockstep xoshiro compat).
//!
//! `coordinator::Coordinator` is now a thin single-shard wrapper over
//! [`Server`], kept for its simpler API and for backward compatibility.
//!
//! [`Metrics`]: crate::coordinator::Metrics
//! [`runtime::Engine`]: crate::runtime::Engine
//! [`runtime::InterpEngine::execute_rows`]: crate::runtime::InterpEngine::execute_rows

pub mod net;
pub mod pool;
pub mod resilience;
pub mod server;
pub mod shard;

pub use net::{Client, ClientConfig, NetError, TcpFront, TcpFrontConfig};
pub use pool::BankPool;
pub use resilience::{ChaosPlan, DegradeConfig, NetChaos, Reply, ServeError, SubmitOpts};
pub use server::{Server, ServerConfig};
