//! The bank pool: N controller shards + the app → shard routing table.
//!
//! Routing mirrors the paper's bank assignment: with enough shards every
//! artifact gets its own bank controller (the default), otherwise apps
//! are FNV-hashed onto the available shards. Every shard shares one
//! `Arc<Engine>` and one metrics map (each app lives on exactly one
//! *live* shard, so per-app metrics rarely contend across shards).
//! Every shard also knows every servable app's spec, so when a shard
//! dies (executor restart budget exhausted) [`BankPool::shard_for`]
//! routes its apps to the next live sibling instead of failing them.

use std::collections::HashMap;
use std::sync::atomic::AtomicU64;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::Metrics;
use crate::error::Result;
use crate::runtime::Engine;
use crate::util::prng::fnv1a;

use super::resilience::{lock_unpoisoned, DegradeConfig};
use super::server::ServerConfig;
use super::shard::{Shard, ShardMsg, WaveKnobs};

/// Owns the shards; dropped last by [`super::Server`], which shuts every
/// shard down (draining its partial waves) and joins the threads.
pub struct BankPool {
    shards: Vec<Shard>,
    route: HashMap<String, usize>,
    metrics: Arc<Mutex<HashMap<String, Metrics>>>,
}

/// App → shard assignment over sorted names: identity when every app can
/// have its own shard, FNV-hashed otherwise. Returns the shard count
/// actually needed and the routing table.
pub(crate) fn route_apps(names: &[String], shards: usize) -> (usize, HashMap<String, usize>) {
    let n_apps = names.len();
    let n = if shards == 0 { n_apps.max(1) } else { shards.min(n_apps.max(1)) };
    let mut route = HashMap::new();
    for (i, name) in names.iter().enumerate() {
        let idx = if n >= n_apps { i } else { (fnv1a(name) % n as u64) as usize };
        route.insert(name.clone(), idx);
    }
    (n, route)
}

impl BankPool {
    /// Spawn the pool over the shared engine. `specs` maps every
    /// servable app to `(n_inputs, batch)`; `cfg.shards == 0` means one
    /// shard per artifact.
    pub(crate) fn start(
        engine: Arc<Engine>,
        specs: &HashMap<String, (usize, usize)>,
        cfg: &ServerConfig,
    ) -> Result<Self> {
        let mut names: Vec<String> = specs.keys().cloned().collect();
        names.sort();
        let (n, route) = route_apps(&names, cfg.shards);
        // Resolve the auto row-worker count once, here, hoisting the env
        // lookup off the per-wave path. An explicit STOCH_IMC_ROW_THREADS
        // is honored as-is; only the cores *fallback* is divided across
        // the shards (banks share the chip; N shards × full-core row
        // pools would oversubscribe and thrash).
        let row_threads = if cfg.row_threads == 0 {
            crate::runtime::row_threads_override().unwrap_or_else(|| {
                let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
                (cores / n).max(1)
            })
        } else {
            cfg.row_threads
        };
        // Same hoisting for the lane width: an explicit config value or
        // STOCH_IMC_LANE_WIDTH pins every wave; otherwise 0 lets the
        // engine auto-size each wave to its live row count.
        let lane_width = match cfg.lane_width {
            64 | 128 | 256 | 512 => cfg.lane_width,
            _ => crate::runtime::lane_width_override().unwrap_or(0),
        };
        // And for the generator family: an explicit config mode wins,
        // then STOCH_IMC_RNG, then the counter default. Degradation
        // follows the same pattern (config, then STOCH_IMC_DEGRADE_*,
        // then disabled).
        let rng = cfg.rng.or_else(crate::runtime::rng_mode_override).unwrap_or_default();
        let degrade = cfg.degrade.or_else(DegradeConfig::from_env).unwrap_or_default();
        let knobs = WaveKnobs {
            row_threads,
            lane_width,
            rng,
            fault: cfg.fault,
            degrade,
            chaos: cfg.chaos,
            max_restarts: cfg.max_restarts,
        };
        // Pool-wide injected-panic allowance shared by every shard.
        let chaos_budget =
            Arc::new(AtomicU64::new(cfg.chaos.map_or(0, |c| c.max_panics)));
        let metrics: Arc<Mutex<HashMap<String, Metrics>>> = Arc::default();
        let mut pool_shards = Vec::with_capacity(n);
        for id in 0..n {
            // Every shard gets the FULL spec map (it can absorb traffic
            // routed around a dead sibling) plus its sorted home list
            // (metrics attribution for restarts with no in-flight app).
            let mut home: Vec<String> =
                route.iter().filter(|(_, &s)| s == id).map(|(app, _)| app.clone()).collect();
            home.sort();
            pool_shards.push(Shard::spawn(
                id,
                Arc::clone(&engine),
                specs.clone(),
                home,
                cfg.batcher.clone(),
                cfg.queue_depth,
                knobs,
                Arc::clone(&chaos_budget),
                Arc::clone(&metrics),
            )?);
        }
        Ok(Self { shards: pool_shards, route, metrics })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard serves `app` (None for unknown apps).
    pub fn shard_of(&self, app: &str) -> Option<usize> {
        self.route.get(app).copied()
    }

    /// The live shard serving `app`: its home shard, or — when the home
    /// (or a fallback) is dead — the next live shard in id order. `None`
    /// for unknown apps or when every shard is dead.
    pub(crate) fn shard_for(&self, app: &str) -> Option<&Shard> {
        let home = self.shard_of(app)?;
        let n = self.shards.len();
        (0..n).map(|k| &self.shards[(home + k) % n]).find(|s| !s.is_dead())
    }

    /// Shards marked dead by their supervisor (restart budget spent).
    pub fn dead_shards(&self) -> Vec<usize> {
        self.shards.iter().filter(|s| s.is_dead()).map(|s| s.id()).collect()
    }

    pub(crate) fn metrics_map(&self) -> &Arc<Mutex<HashMap<String, Metrics>>> {
        &self.metrics
    }

    /// Per-app metrics snapshot.
    pub fn metrics(&self, app: &str) -> Metrics {
        lock_unpoisoned(&self.metrics).get(app).cloned().unwrap_or_default()
    }

    /// Pool-wide aggregate across every app on every shard.
    pub fn pool_metrics(&self) -> Metrics {
        let mut total = Metrics::default();
        for app in lock_unpoisoned(&self.metrics).values() {
            total.merge(app);
        }
        total
    }

    /// Flush every shard (close partial waves) and wait for the acks.
    pub(crate) fn flush_all(&self) -> Result<()> {
        let mut acks = Vec::with_capacity(self.shards.len());
        for sh in &self.shards {
            let (tx, rx) = channel();
            sh.send(ShardMsg::Flush(tx))?;
            acks.push(rx);
        }
        for rx in acks {
            let _ = rx.recv();
        }
        Ok(())
    }
}

impl Drop for BankPool {
    fn drop(&mut self) {
        // Signal every shard before joining any: the banks drain their
        // remaining partial waves concurrently, not one after another.
        for sh in &self.shards {
            sh.request_shutdown();
        }
        for sh in &mut self.shards {
            sh.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn one_shard_per_app_by_default() {
        let (n, route) = route_apps(&names(&["a", "b", "c"]), 0);
        assert_eq!(n, 3);
        let mut shards: Vec<usize> = route.values().copied().collect();
        shards.sort();
        assert_eq!(shards, vec![0, 1, 2]);
    }

    #[test]
    fn hashed_routing_when_fewer_shards() {
        let apps = names(&["app_kde", "app_lit", "app_ol", "op_multiply"]);
        let (n, route) = route_apps(&apps, 2);
        assert_eq!(n, 2);
        for app in &apps {
            assert!(route[app] < 2, "{app} routed to shard {}", route[app]);
        }
        // Deterministic: same inputs, same table.
        assert_eq!(route, route_apps(&apps, 2).1);
    }

    #[test]
    fn shard_count_capped_at_app_count() {
        let (n, _) = route_apps(&names(&["a", "b"]), 16);
        assert_eq!(n, 2);
        // Degenerate: no apps still yields one (idle) shard.
        let (n, route) = route_apps(&[], 0);
        assert_eq!(n, 1);
        assert!(route.is_empty());
    }
}
