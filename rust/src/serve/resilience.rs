//! Resilience primitives for the serving layer: typed terminal
//! outcomes, per-request deadlines, the adaptive bitstream-length
//! degradation controller, and the chaos-injection plan.
//!
//! The contract every piece here serves: **every admitted request gets
//! exactly one terminal outcome** — a value, `Err(Timeout)`,
//! `Err(ShardDead)`, or `Err(Exec(..))` — no matter what the executor
//! does (panics included; see `shard::supervisor_loop`). Degradation is
//! the SC-native overload response: stochastic computing trades
//! accuracy for latency by shortening the bitstream, so an overloaded
//! shard halves its effective BL down a bounded ladder instead of
//! shedding, and steps back up when queue waits recover (§3 of the
//! paper frames SC as exactly this approximation dial).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use crate::obs::Histogram;

/// Lock a mutex, recovering from poisoning: a thread that panicked
/// while holding the metrics lock must not poison observability for the
/// whole pool. Safe here because every guarded structure is a bag of
/// monotonic counters/histograms — a partially-applied update is still
/// a usable (merely slightly stale) snapshot.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Why a request terminated without a value. Cloned into every affected
/// responder, so it is cheap and comparable (tests match on variants).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request's deadline expired before a value could be produced
    /// (checked at dequeue, at wave close, and again at completion).
    Timeout,
    /// The owning shard exhausted its executor restart budget; pending
    /// and late-arriving requests are failed fast instead of queued.
    ShardDead,
    /// Wave execution failed — an engine error or an executor panic.
    Exec(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Timeout => write!(f, "request deadline exceeded"),
            ServeError::ShardDead => write!(f, "shard dead (executor restart budget exhausted)"),
            ServeError::Exec(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// The terminal outcome delivered on every request's response channel.
pub type Reply = Result<f32, ServeError>;

/// Per-submit options for [`super::Server::submit_opts`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOpts {
    /// Per-request budget measured from submission. `None` = the
    /// server's default deadline (`ServerConfig::deadline`, else
    /// `STOCH_IMC_DEADLINE_MS`, else unbounded).
    pub deadline: Option<Duration>,
    /// Shed (error immediately) instead of blocking when the shard's
    /// admission queue is full — the `try_submit` behaviour.
    pub shed: bool,
}

/// The `STOCH_IMC_DEADLINE_MS` default request deadline: `None` when
/// unset or `0` (unbounded); unparseable values warn and disable.
pub fn deadline_override() -> Option<Duration> {
    let s = std::env::var("STOCH_IMC_DEADLINE_MS").ok()?;
    match s.trim().parse::<u64>() {
        Ok(0) => None,
        Ok(ms) => Some(Duration::from_millis(ms)),
        Err(_) => {
            eprintln!("STOCH_IMC_DEADLINE_MS=`{s}` is not a non-negative integer; no deadline");
            None
        }
    }
}

/// Adaptive-degradation knobs: when a shard's recent queue-wait p95
/// exceeds `wait_p95_us`, the shard halves its effective bitstream
/// length (one ladder step, e.g. BL 256→128→64), and steps back up
/// once the p95 falls below a quarter of the threshold (hysteresis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DegradeConfig {
    /// Queue-wait p95 threshold in microseconds; `0` disables the
    /// controller entirely (the default — degraded waves change output
    /// values, so the trade is strictly opt-in).
    pub wait_p95_us: u64,
    /// Maximum halvings below the artifact's full BL (the ladder
    /// depth). Effective BL never drops below 16 steps.
    pub max_steps: u32,
    /// Evaluate the wait window every this many waves.
    pub eval_waves: u32,
}

impl Default for DegradeConfig {
    fn default() -> Self {
        Self { wait_p95_us: 0, max_steps: 2, eval_waves: 8 }
    }
}

impl DegradeConfig {
    pub fn enabled(&self) -> bool {
        self.wait_p95_us > 0 && self.max_steps > 0 && self.eval_waves > 0
    }

    /// Resolve the controller from the environment:
    /// `STOCH_IMC_DEGRADE_WAIT_US` (threshold; presence enables),
    /// `STOCH_IMC_DEGRADE_STEPS` (ladder depth, default 2),
    /// `STOCH_IMC_DEGRADE_EVAL_WAVES` (window, default 8). `None` when
    /// the threshold is unset, zero, or unparseable.
    pub fn from_env() -> Option<Self> {
        let s = std::env::var("STOCH_IMC_DEGRADE_WAIT_US").ok()?;
        let wait_p95_us = match s.trim().parse::<u64>() {
            Ok(us) if us > 0 => us,
            Ok(_) => return None,
            Err(_) => {
                eprintln!(
                    "STOCH_IMC_DEGRADE_WAIT_US=`{s}` is not a positive integer; \
                     degradation disabled"
                );
                return None;
            }
        };
        let parse = |var: &str, default: u32| {
            std::env::var(var)
                .ok()
                .and_then(|v| v.trim().parse::<u32>().ok())
                .filter(|&v| v > 0)
                .unwrap_or(default)
        };
        Some(Self {
            wait_p95_us,
            max_steps: parse("STOCH_IMC_DEGRADE_STEPS", 2),
            eval_waves: parse("STOCH_IMC_DEGRADE_EVAL_WAVES", 8),
        })
    }
}

/// Re-exported from the runtime (the engine applies the ladder): the
/// effective-BL map and its floor live next to the wave evaluator so
/// the serving layer and the engine can never disagree on the math.
pub use crate::runtime::{effective_bl, MIN_DEGRADED_BL};

/// Per-shard overload controller. Feed it every request's queue wait
/// ([`DegradeController::record_wait`]) and tick it once per wave
/// ([`DegradeController::on_wave`]); read the current ladder level with
/// [`DegradeController::level`]. All state is shard-local — no locks,
/// no shared windows.
#[derive(Debug)]
pub(crate) struct DegradeController {
    cfg: DegradeConfig,
    level: u32,
    window: Histogram,
    waves_in_window: u32,
}

impl DegradeController {
    pub(crate) fn new(cfg: DegradeConfig) -> Self {
        Self { cfg, level: 0, window: Histogram::default(), waves_in_window: 0 }
    }

    /// Current ladder level (0 = full BL).
    pub(crate) fn level(&self) -> u32 {
        self.level
    }

    pub(crate) fn record_wait_us(&mut self, us: u64) {
        if self.cfg.enabled() {
            self.window.record(us);
        }
    }

    /// Tick after an executed wave; every `eval_waves` waves the window
    /// p95 is compared against the threshold — above it the shard steps
    /// one level down the ladder, below a quarter of it the shard steps
    /// back up (waves with an empty window, e.g. all-timeout drains,
    /// read p95 = 0 and recover). The window resets each evaluation so
    /// old congestion can't pin the level.
    pub(crate) fn on_wave(&mut self) {
        if !self.cfg.enabled() {
            return;
        }
        self.waves_in_window += 1;
        if self.waves_in_window < self.cfg.eval_waves {
            return;
        }
        let p95 = self.window.percentile(95.0);
        if p95 > self.cfg.wait_p95_us {
            self.level = (self.level + 1).min(self.cfg.max_steps);
        } else if p95 * 4 <= self.cfg.wait_p95_us && self.level > 0 {
            self.level -= 1;
        }
        self.window = Histogram::default();
        self.waves_in_window = 0;
    }
}

/// Chaos-injection plan for the resilience harness (`stoch-imc chaos`,
/// `tests/chaos.rs`): deterministic executor panics and artificial wave
/// latency, injected *inside* the shard's wave path so supervision,
/// deadlines, and degradation all see realistic failures. An all-zero
/// plan is exactly the clean path (the disturb hook short-circuits).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ChaosPlan {
    /// Panic the executor on every Nth wave of a shard (`0` = never).
    pub panic_every: u64,
    /// Pool-wide cap on injected panics (a shared budget, so a bounded
    /// chaos run can't exhaust every shard's restart allowance).
    pub max_panics: u64,
    /// Sleep before every Nth wave of a shard (`0` = never).
    pub latency_every: u64,
    /// The injected per-wave latency.
    pub latency: Duration,
    /// Network-layer injectors, applied by `serve::net::TcpFront`
    /// (in-process serving ignores them; the default is a no-op).
    pub net: NetChaos,
}

/// Network chaos injectors for the TCP front door (`tests/net_chaos.rs`
/// and the `flood` CI smoke): each failure mode networks add on top of
/// the in-process ones, on a deterministic cadence. An all-zero plan is
/// exactly the clean wire path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct NetChaos {
    /// Accept every Nth connection, then drop it before reading a
    /// byte (`0` = never) — the classic flaky-LB connect.
    pub accept_drop_every: u64,
    /// Cut every Nth response mid-frame and hard-close (`0` = never):
    /// clients see a truncated frame, never a value.
    pub cut_every: u64,
    /// Trickle every Nth response one byte at a time (`0` = never).
    pub trickle_every: u64,
    /// Inter-byte delay while trickling.
    pub trickle_delay: Duration,
    /// Stall every Nth decoded request before execution (`0` = never):
    /// the server goes quiet with a request in hand.
    pub stall_read_every: u64,
    /// The injected stall.
    pub stall: Duration,
}

impl NetChaos {
    /// True when every injector is disabled (the clean wire path).
    pub fn is_noop(&self) -> bool {
        *self == NetChaos::default()
    }
}

impl ChaosPlan {
    /// Apply the plan at one wave: may panic (counted against the
    /// shared `budget`) or sleep. Called after the wave is parked where
    /// the supervisor can fail it, so an injected panic exercises the
    /// exact recovery path a real executor fault would.
    pub(crate) fn disturb(&self, wave: u64, budget: &AtomicU64) {
        if self.panic_every > 0
            && wave % self.panic_every == 0
            && budget.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1)).is_ok()
        {
            panic!("chaos: injected executor panic at shard wave {wave}");
        }
        if self.latency_every > 0 && wave % self.latency_every == 0 && !self.latency.is_zero() {
            std::thread::sleep(self.latency);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(wait_p95_us: u64, max_steps: u32, eval_waves: u32) -> DegradeController {
        DegradeController::new(DegradeConfig { wait_p95_us, max_steps, eval_waves })
    }

    #[test]
    fn effective_bl_ladder_and_floor() {
        assert_eq!(effective_bl(256, 0), 256);
        assert_eq!(effective_bl(256, 1), 128);
        assert_eq!(effective_bl(256, 2), 64);
        // Floored at MIN_DEGRADED_BL, never above the full BL.
        assert_eq!(effective_bl(256, 10), MIN_DEGRADED_BL);
        assert_eq!(effective_bl(8, 1), 8);
        assert_eq!(effective_bl(0, 0), 1);
        assert_eq!(effective_bl(1 << 20, 63), MIN_DEGRADED_BL);
    }

    #[test]
    fn controller_steps_down_under_load_and_recovers() {
        let mut c = ctl(1000, 2, 4);
        // Four slow waves → one eval → one step down.
        for _ in 0..4 {
            c.record_wait_us(50_000);
            c.on_wave();
        }
        assert_eq!(c.level(), 1);
        // Sustained overload walks the ladder but never past max_steps.
        for _ in 0..12 {
            c.record_wait_us(50_000);
            c.on_wave();
        }
        assert_eq!(c.level(), 2, "bounded by max_steps");
        // Recovery needs p95 ≤ threshold/4 (hysteresis): 300 ≤ 250 is
        // false, so the level holds...
        for _ in 0..4 {
            c.record_wait_us(300);
            c.on_wave();
        }
        assert_eq!(c.level(), 2, "mid-band waits neither step nor recover");
        // ...and genuinely quiet windows step back up to full BL.
        for _ in 0..8 {
            c.record_wait_us(10);
            c.on_wave();
        }
        assert_eq!(c.level(), 0);
    }

    #[test]
    fn controller_window_resets_between_evals() {
        let mut c = ctl(1000, 4, 2);
        // One congested window steps down once.
        for _ in 0..2 {
            c.record_wait_us(100_000);
            c.on_wave();
        }
        assert_eq!(c.level(), 1);
        // The next window is clean — the old samples must not linger
        // and force a second step.
        for _ in 0..2 {
            c.record_wait_us(10);
            c.on_wave();
        }
        assert_eq!(c.level(), 0);
    }

    #[test]
    fn disabled_controller_never_degrades() {
        let mut c = ctl(0, 2, 4);
        for _ in 0..32 {
            c.record_wait_us(u64::MAX);
            c.on_wave();
        }
        assert_eq!(c.level(), 0);
        assert!(!DegradeConfig::default().enabled());
    }

    #[test]
    fn chaos_panic_budget_is_exact() {
        let plan = ChaosPlan { panic_every: 1, max_panics: 2, ..ChaosPlan::default() };
        let budget = AtomicU64::new(plan.max_panics);
        for wave in 1..=2u64 {
            let r = std::panic::catch_unwind(|| plan.disturb(wave, &budget));
            assert!(r.is_err(), "wave {wave} must panic while budget remains");
        }
        // Budget exhausted: the same cadence no longer panics.
        let r = std::panic::catch_unwind(|| plan.disturb(3, &budget));
        assert!(r.is_ok(), "no panic once the shared budget is spent");
        assert_eq!(budget.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn noop_chaos_plan_disturbs_nothing() {
        let plan = ChaosPlan::default();
        let budget = AtomicU64::new(0);
        for wave in 1..=8u64 {
            plan.disturb(wave, &budget); // must neither panic nor sleep
        }
    }

    #[test]
    fn serve_error_display_and_eq() {
        assert_eq!(ServeError::Timeout, ServeError::Timeout);
        assert_ne!(ServeError::Timeout, ServeError::ShardDead);
        assert!(ServeError::ShardDead.to_string().contains("dead"));
        assert!(ServeError::Exec("boom".into()).to_string().contains("boom"));
    }
}
