//! One bank-controller shard: bounded admission queue → per-app batcher
//! → executor loop driving the shared engine.
//!
//! The shard thread is the only consumer of its queue; requests are
//! grouped into artifact-sized waves (the subarray-group capacity) and
//! executed row-parallel on the shared [`Engine`]. The queue is a
//! `sync_channel` of depth `queue_depth`: when a shard falls behind,
//! blocking submitters wait (backpressure) and `try_submit` callers get
//! an immediate "queue full" error — the admission-control contract the
//! front-door [`super::Server`] exposes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::bail;
use crate::coordinator::batcher::{Batcher, BatcherConfig, Pending};
use crate::coordinator::metrics::{Metrics, WaveClose};
use crate::error::{Context, Result};
use crate::fault::FaultPlan;
use crate::runtime::Engine;
use crate::util::prng::RngMode;

/// Per-wave execution knobs, resolved once at pool start (env
/// lookups included) so the wave path never touches the environment.
#[derive(Debug, Clone, Copy)]
pub(crate) struct WaveKnobs {
    /// Worker threads a wave's rows/lane blocks are split across.
    pub row_threads: usize,
    /// Rows per lane block (64/128/256/512; 0 = auto per wave).
    pub lane_width: usize,
    /// SNG generator family (counter default / xoshiro compat),
    /// resolved from config or `STOCH_IMC_RNG` at pool start.
    pub rng: RngMode,
    /// Fault-injection plan applied to every wave (`None` = clean
    /// serving; a no-op plan is equally free).
    pub fault: Option<FaultPlan>,
}

/// Messages accepted by a shard's admission queue.
pub(crate) enum ShardMsg {
    Request {
        app: String,
        inputs: Vec<f32>,
        respond: Sender<f32>,
        /// Submit timestamp — queue wait is measured from here to wave
        /// start, covering the admission channel *and* the batcher.
        enqueued: Instant,
    },
    /// Drain every batcher (partial waves included), then ack.
    Flush(Sender<()>),
    Shutdown,
}

/// Outcome of a depth-tracked admission attempt ([`Shard::admit`]).
/// Carries the queue depth right after the enqueue so the caller can
/// feed the depth distribution without re-reading the counter.
pub(crate) enum Admission {
    /// Enqueued without waiting.
    Accepted(u64),
    /// Enqueued after blocking on a full queue (backpressure).
    AcceptedAfterBlock(u64),
    /// Rejected — queue full on the non-blocking path (load shed).
    Shed,
}

/// One controller shard: the handle side (queue sender + join handle).
pub struct Shard {
    id: usize,
    tx: SyncSender<ShardMsg>,
    /// Requests admitted but not yet dequeued by the shard loop —
    /// blocked submitters included, so depth can briefly exceed the
    /// channel bound under backpressure.
    depth: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

impl Shard {
    /// Spawn the shard thread. `specs` maps each app routed to this
    /// shard to its `(n_inputs, batch)`; the engine is shared across
    /// shards (banks share the chip's periphery, each drives its own
    /// subarray-group waves).
    pub(crate) fn spawn(
        id: usize,
        engine: Arc<Engine>,
        specs: HashMap<String, (usize, usize)>,
        cfg: BatcherConfig,
        queue_depth: usize,
        knobs: WaveKnobs,
        metrics: Arc<Mutex<HashMap<String, Metrics>>>,
    ) -> Result<Self> {
        let (tx, rx) = sync_channel(queue_depth.max(1));
        let depth = Arc::new(AtomicU64::new(0));
        let loop_depth = Arc::clone(&depth);
        let handle = std::thread::Builder::new()
            .name(format!("stoch-imc-shard-{id}"))
            .spawn(move || shard_loop(id, &engine, rx, &loop_depth, &metrics, &specs, &cfg, knobs))
            .with_context(|| format!("spawning shard {id}"))?;
        Ok(Self { id, tx, depth, handle: Some(handle) })
    }

    pub fn id(&self) -> usize {
        self.id
    }

    /// Blocking enqueue: waits when the admission queue is full
    /// (backpressure) and errors only if the shard thread is gone.
    /// Control messages (flush/shutdown) ride this untracked path;
    /// requests go through [`Shard::admit`] so depth telemetry sees
    /// them.
    pub(crate) fn send(&self, msg: ShardMsg) -> Result<()> {
        self.tx.send(msg).ok().with_context(|| format!("shard {} gone", self.id))
    }

    /// Depth-tracked request admission. Blocking mode waits out a full
    /// queue (reported as [`Admission::AcceptedAfterBlock`]); the
    /// non-blocking mode reports [`Admission::Shed`] instead of
    /// waiting. Errors only if the shard thread is gone.
    pub(crate) fn admit(&self, msg: ShardMsg, block: bool) -> Result<Admission> {
        // Count before the send so the shard loop (which decrements on
        // dequeue) can never observe the message before the increment.
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(msg) {
            Ok(()) => Ok(Admission::Accepted(self.depth.load(Ordering::Relaxed))),
            Err(TrySendError::Full(msg)) => {
                if !block {
                    self.depth.fetch_sub(1, Ordering::Relaxed);
                    return Ok(Admission::Shed);
                }
                match self.tx.send(msg) {
                    Ok(()) => {
                        Ok(Admission::AcceptedAfterBlock(self.depth.load(Ordering::Relaxed)))
                    }
                    Err(_) => {
                        self.depth.fetch_sub(1, Ordering::Relaxed);
                        bail!("shard {} gone", self.id)
                    }
                }
            }
            Err(TrySendError::Disconnected(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                bail!("shard {} gone", self.id)
            }
        }
    }

    /// Current admission-queue depth (requests admitted, not yet
    /// dequeued).
    pub fn queue_len(&self) -> u64 {
        self.depth.load(Ordering::Relaxed)
    }

    /// Ask the shard to exit; it drains pending waves first. Pair with
    /// [`Shard::join`] — signalling every shard before joining any lets
    /// the whole pool drain in parallel.
    pub(crate) fn request_shutdown(&self) {
        let _ = self.tx.send(ShardMsg::Shutdown);
    }

    pub(crate) fn join(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The executor loop: one per shard thread. Identical in shape to the
/// old single-controller loop, but scoped to this shard's apps and
/// executing waves row-parallel on the shared engine.
#[allow(clippy::too_many_arguments)]
fn shard_loop(
    id: usize,
    engine: &Engine,
    rx: Receiver<ShardMsg>,
    depth: &AtomicU64,
    metrics: &Arc<Mutex<HashMap<String, Metrics>>>,
    specs: &HashMap<String, (usize, usize)>,
    cfg: &BatcherConfig,
    knobs: WaveKnobs,
) {
    let mut batchers: HashMap<String, Batcher> = HashMap::new();
    // Per-shard wave-seed stream: mixed with the shard id so two shards
    // never replay each other's SNG draws.
    let mut seed: i32 = 0x5eed ^ (id as i32).wrapping_mul(0x9E37_79B9_u32 as i32);
    loop {
        // Wait for work (bounded, so timeouts can close partial waves).
        match rx.recv_timeout(cfg.max_wait) {
            Ok(ShardMsg::Request { app, inputs, respond, enqueued }) => {
                // Dequeue edge: the consumer-side depth sample pairs
                // with the producer-side sample taken at admission.
                let d = depth.fetch_sub(1, Ordering::Relaxed).saturating_sub(1);
                let Some(&(n, batch)) = specs.get(&app) else {
                    // The server validates routing before enqueueing;
                    // drop the responder so the caller sees an error.
                    eprintln!("shard {id}: request for unrouted app `{app}` dropped");
                    continue;
                };
                if let Ok(mut m) = metrics.lock() {
                    m.entry(app.clone()).or_default().record_queue_depth(d);
                }
                let b = batchers.entry(app).or_insert_with(|| {
                    Batcher::new(BatcherConfig { batch, max_wait: cfg.max_wait }, n)
                });
                b.push(Pending { inputs, respond, enqueued });
            }
            Ok(ShardMsg::Flush(ack)) => {
                drain_all(engine, &mut batchers, metrics, &mut seed, knobs);
                let _ = ack.send(());
            }
            Ok(ShardMsg::Shutdown) => {
                drain_all(engine, &mut batchers, metrics, &mut seed, knobs);
                return;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                drain_all(engine, &mut batchers, metrics, &mut seed, knobs);
                return;
            }
        }
        // Close any ready waves (full, or past the batching deadline).
        let now = Instant::now();
        for (app, b) in batchers.iter_mut() {
            while b.ready(now) {
                let close = if b.is_full() { WaveClose::Full } else { WaveClose::Deadline };
                execute_wave(engine, app, b, metrics, &mut seed, knobs, close);
            }
        }
    }
}

fn drain_all(
    engine: &Engine,
    batchers: &mut HashMap<String, Batcher>,
    metrics: &Arc<Mutex<HashMap<String, Metrics>>>,
    seed: &mut i32,
    knobs: WaveKnobs,
) {
    for (app, b) in batchers.iter_mut() {
        while !b.is_empty() {
            // A full wave that happens to drain during a flush still
            // counts as a capacity close; only partial tails are
            // flush-closed.
            let close = if b.is_full() { WaveClose::Full } else { WaveClose::Flush };
            execute_wave(engine, app, b, metrics, seed, knobs, close);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn execute_wave(
    engine: &Engine,
    app: &str,
    b: &mut Batcher,
    metrics: &Arc<Mutex<HashMap<String, Metrics>>>,
    seed: &mut i32,
    knobs: WaveKnobs,
    close: WaveClose,
) {
    let wave = b.drain();
    *seed = seed.wrapping_mul(0x343FD).wrapping_add(0x269EC3);
    let t0 = Instant::now();
    match engine.execute_rows_tuned(
        app,
        &wave.values,
        *seed,
        wave.responders.len(),
        knobs.row_threads,
        knobs.lane_width,
        Some(knobs.rng),
        knobs.fault.as_ref(),
    ) {
        Ok((outs, stats)) => {
            let dt = t0.elapsed();
            for (i, r) in wave.responders.iter().enumerate() {
                let _ = r.send(outs[i]);
            }
            if let Ok(mut m) = metrics.lock() {
                let e = m.entry(app.to_string()).or_default();
                e.record_wave(wave.responders.len(), wave.padded, dt);
                e.record_stats(&stats);
                e.record_drain(close);
                for enq in &wave.enqueued {
                    // Submit → wave start (admission channel + batcher
                    // residence); saturates to zero across threads.
                    e.record_queue_wait(t0.duration_since(*enq));
                }
                for _ in 0..wave.responders.len() {
                    e.record_latency(dt);
                }
            }
        }
        Err(err) => {
            // Surface the failure by dropping responders (recv() errors).
            eprintln!("wave execution failed for `{app}`: {err:#}");
        }
    }
}
