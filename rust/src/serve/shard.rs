//! One bank-controller shard: bounded admission queue → per-app batcher
//! → supervised executor loop driving the shared engine.
//!
//! The shard thread is the only consumer of its queue; requests are
//! grouped into artifact-sized waves (the subarray-group capacity) and
//! executed row-parallel on the shared [`Engine`]. The queue is a
//! `sync_channel` of depth `queue_depth`: when a shard falls behind,
//! blocking submitters wait (backpressure) and `try_submit` callers get
//! an immediate "queue full" error — the admission-control contract the
//! front-door [`super::Server`] exposes.
//!
//! # Supervision
//!
//! The executor loop runs under `catch_unwind` inside a supervisor that
//! owns all loop state ([`ShardCore`]) *outside* the unwind boundary.
//! A panic therefore loses nothing: the in-flight wave is parked in
//! [`ExecState::inflight`] before any panic-prone work, so the
//! supervisor fails exactly its responders with `Err(Exec)`, bumps the
//! `executor_restarts` counter, and re-enters the loop — batched (not
//! yet in-flight) requests survive the restart untouched. After
//! `max_restarts` consecutive panics the shard is marked **dead**: all
//! batched requests are failed `Err(ShardDead)` and a tombstone loop
//! keeps draining the admission queue (fail-fast replies, flush acks,
//! shutdown) so producers and `Drop` never deadlock. [`super::BankPool`]
//! routes new submissions around dead shards.
//!
//! # Deadlines & degradation
//!
//! Request deadlines are enforced at three checkpoints: dequeue (an
//! expired request never enters a batcher), wave close (expired
//! batcher entries are answered before the wave drains), and completion
//! (a slow wave re-checks each row's budget before replying). The
//! per-shard [`DegradeController`] watches queue-wait p95 and steps the
//! effective bitstream length down a bounded ladder under overload —
//! see [`super::resilience`].

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::bail;
use crate::coordinator::batcher::{Batch, Batcher, BatcherConfig, Pending};
use crate::coordinator::metrics::{Metrics, WaveClose};
use crate::error::{Context, Result};
use crate::fault::FaultPlan;
use crate::runtime::Engine;
use crate::serve::resilience::{
    lock_unpoisoned, ChaosPlan, DegradeConfig, DegradeController, Reply, ServeError,
};
use crate::util::prng::RngMode;

/// Per-wave execution knobs, resolved once at pool start (env
/// lookups included) so the wave path never touches the environment.
#[derive(Debug, Clone, Copy)]
pub(crate) struct WaveKnobs {
    /// Worker threads a wave's rows/lane blocks are split across.
    pub row_threads: usize,
    /// Rows per lane block (64/128/256/512; 0 = auto per wave).
    pub lane_width: usize,
    /// SNG generator family (counter default / xoshiro compat),
    /// resolved from config or `STOCH_IMC_RNG` at pool start.
    pub rng: RngMode,
    /// Fault-injection plan applied to every wave (`None` = clean
    /// serving; a no-op plan is equally free).
    pub fault: Option<FaultPlan>,
    /// Overload → BL-ladder controller config (disabled by default).
    pub degrade: DegradeConfig,
    /// Chaos-injection plan (`None` outside the chaos harness).
    pub chaos: Option<ChaosPlan>,
    /// Consecutive executor panics tolerated before the shard is
    /// marked dead and routed around.
    pub max_restarts: u32,
}

/// Messages accepted by a shard's admission queue.
pub(crate) enum ShardMsg {
    Request {
        app: String,
        inputs: Vec<f32>,
        respond: Sender<Reply>,
        /// Submit timestamp — queue wait is measured from here to wave
        /// start, covering the admission channel *and* the batcher.
        enqueued: Instant,
        /// Absolute deadline (submit time + budget); `None` = no limit.
        deadline: Option<Instant>,
    },
    /// Drain every batcher (partial waves included), then ack.
    Flush(Sender<()>),
    Shutdown,
}

/// Outcome of a depth-tracked admission attempt ([`Shard::admit`]).
/// Carries the queue depth right after the enqueue so the caller can
/// feed the depth distribution without re-reading the counter.
pub(crate) enum Admission {
    /// Enqueued without waiting.
    Accepted(u64),
    /// Enqueued after blocking on a full queue (backpressure).
    AcceptedAfterBlock(u64),
    /// Rejected — queue full on the non-blocking path (load shed).
    Shed,
}

/// One controller shard: the handle side (queue sender + join handle).
pub struct Shard {
    id: usize,
    tx: SyncSender<ShardMsg>,
    /// Requests admitted but not yet dequeued by the shard loop —
    /// blocked submitters included, so depth can briefly exceed the
    /// channel bound under backpressure.
    depth: Arc<AtomicU64>,
    /// Set by the supervisor once the restart budget is exhausted; the
    /// pool routes new submissions to a live sibling instead.
    dead: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Shard {
    /// Spawn the shard thread. `specs` maps every servable app to its
    /// `(n_inputs, batch)` — the full map, not just this shard's homes,
    /// so a shard can absorb traffic routed around a dead sibling.
    /// `home` lists the apps primarily routed here (restart metrics
    /// attribution). The engine is shared across shards (banks share
    /// the chip's periphery, each drives its own subarray-group waves);
    /// `chaos_budget` is the pool-wide injected-panic allowance.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn spawn(
        id: usize,
        engine: Arc<Engine>,
        specs: HashMap<String, (usize, usize)>,
        home: Vec<String>,
        cfg: BatcherConfig,
        queue_depth: usize,
        knobs: WaveKnobs,
        chaos_budget: Arc<AtomicU64>,
        metrics: Arc<Mutex<HashMap<String, Metrics>>>,
    ) -> Result<Self> {
        let (tx, rx) = sync_channel(queue_depth.max(1));
        let depth = Arc::new(AtomicU64::new(0));
        let dead = Arc::new(AtomicBool::new(false));
        let loop_depth = Arc::clone(&depth);
        let loop_dead = Arc::clone(&dead);
        let handle = std::thread::Builder::new()
            .name(format!("stoch-imc-shard-{id}"))
            .spawn(move || {
                supervisor_loop(
                    id,
                    &engine,
                    &rx,
                    &loop_depth,
                    &metrics,
                    &specs,
                    &home,
                    &cfg,
                    &knobs,
                    &chaos_budget,
                    &loop_dead,
                )
            })
            .with_context(|| format!("spawning shard {id}"))?;
        Ok(Self { id, tx, depth, dead, handle: Some(handle) })
    }

    pub fn id(&self) -> usize {
        self.id
    }

    /// Whether the supervisor declared this shard dead (restart budget
    /// exhausted). Dead shards still drain their queue — fail-fast
    /// replies, not silence — but the pool stops routing to them.
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Blocking enqueue: waits when the admission queue is full
    /// (backpressure) and errors only if the shard thread is gone.
    /// Control messages (flush/shutdown) ride this untracked path;
    /// requests go through [`Shard::admit`] so depth telemetry sees
    /// them.
    pub(crate) fn send(&self, msg: ShardMsg) -> Result<()> {
        self.tx.send(msg).ok().with_context(|| format!("shard {} gone", self.id))
    }

    /// Depth-tracked request admission. Blocking mode waits out a full
    /// queue (reported as [`Admission::AcceptedAfterBlock`]); the
    /// non-blocking mode reports [`Admission::Shed`] instead of
    /// waiting. Errors only if the shard thread is gone.
    pub(crate) fn admit(&self, msg: ShardMsg, block: bool) -> Result<Admission> {
        // Count before the send so the shard loop (which decrements on
        // dequeue) can never observe the message before the increment.
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(msg) {
            Ok(()) => Ok(Admission::Accepted(self.depth.load(Ordering::Relaxed))),
            Err(TrySendError::Full(msg)) => {
                if !block {
                    self.depth.fetch_sub(1, Ordering::Relaxed);
                    return Ok(Admission::Shed);
                }
                match self.tx.send(msg) {
                    Ok(()) => {
                        Ok(Admission::AcceptedAfterBlock(self.depth.load(Ordering::Relaxed)))
                    }
                    Err(_) => {
                        self.depth.fetch_sub(1, Ordering::Relaxed);
                        bail!("shard {} gone", self.id)
                    }
                }
            }
            Err(TrySendError::Disconnected(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                bail!("shard {} gone", self.id)
            }
        }
    }

    /// Current admission-queue depth (requests admitted, not yet
    /// dequeued).
    pub fn queue_len(&self) -> u64 {
        self.depth.load(Ordering::Relaxed)
    }

    /// Ask the shard to exit; it drains pending waves first. Pair with
    /// [`Shard::join`] — signalling every shard before joining any lets
    /// the whole pool drain in parallel.
    pub(crate) fn request_shutdown(&self) {
        let _ = self.tx.send(ShardMsg::Shutdown);
    }

    pub(crate) fn join(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Wave-execution state that must survive an executor panic. Split out
/// of [`ShardCore`] so `execute_wave` can borrow it disjointly from the
/// batcher map.
struct ExecState {
    /// Per-shard wave-seed stream: mixed with the shard id so two
    /// shards never replay each other's SNG draws. Survives restarts —
    /// the seed stream continues where the panicked wave left off.
    seed: i32,
    /// Waves attempted on this shard (chaos cadence counter).
    waves: u64,
    /// The wave currently being executed, parked here *before* any
    /// panic-prone work so the supervisor can fail its responders.
    inflight: Option<(String, Batch)>,
    /// Overload → BL-ladder controller.
    ctl: DegradeController,
}

/// All executor-loop state, owned by the supervisor outside the unwind
/// boundary: a panic loses the stack, never the pending requests.
struct ShardCore {
    batchers: HashMap<String, Batcher>,
    exec: ExecState,
    /// Set before the final drain so a panic *during* shutdown makes
    /// the supervisor fail the remainder and exit instead of re-entering
    /// a loop whose shutdown signal was already consumed.
    shutdown: bool,
}

impl ShardCore {
    fn new(id: usize, degrade: DegradeConfig) -> Self {
        Self {
            batchers: HashMap::new(),
            exec: ExecState {
                seed: 0x5eed ^ (id as i32).wrapping_mul(0x9E37_79B9_u32 as i32),
                waves: 0,
                inflight: None,
                ctl: DegradeController::new(degrade),
            },
            shutdown: false,
        }
    }
}

/// The supervisor: owns [`ShardCore`], runs the executor loop under
/// `catch_unwind`, converts panics into failed in-flight waves +
/// restarts, and tombstones the shard once the restart budget is spent.
#[allow(clippy::too_many_arguments)]
fn supervisor_loop(
    id: usize,
    engine: &Engine,
    rx: &Receiver<ShardMsg>,
    depth: &AtomicU64,
    metrics: &Arc<Mutex<HashMap<String, Metrics>>>,
    specs: &HashMap<String, (usize, usize)>,
    home: &[String],
    cfg: &BatcherConfig,
    knobs: &WaveKnobs,
    chaos_budget: &AtomicU64,
    dead: &AtomicBool,
) {
    let mut core = ShardCore::new(id, knobs.degrade);
    let mut restarts: u32 = 0;
    loop {
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            shard_loop(id, engine, rx, depth, metrics, specs, cfg, knobs, chaos_budget, &mut core)
        }));
        match outcome {
            // Clean exit (shutdown / producers gone) — nothing pending.
            Ok(()) => return,
            Err(_) => {
                // Fail exactly the wave that was executing; batched
                // requests survive the restart.
                let inflight = core.exec.inflight.take();
                let scope =
                    inflight.as_ref().map(|(app, _)| app.clone()).or_else(|| home.first().cloned());
                if let Some((app, wave)) = inflight {
                    let err = ServeError::Exec(format!("shard {id} executor panicked mid-wave"));
                    fail_wave(&app, &wave, err, metrics);
                }
                restarts += 1;
                if let Some(scope) = scope {
                    lock_unpoisoned(metrics).entry(scope).or_default().executor_restarts += 1;
                }
                if restarts > knobs.max_restarts {
                    eprintln!(
                        "shard {id}: executor panicked {restarts} times \
                         (budget {}); marking shard dead",
                        knobs.max_restarts
                    );
                    dead.store(true, Ordering::SeqCst);
                    fail_all_batched(&mut core, metrics);
                    tombstone_loop(rx, depth, metrics);
                    return;
                }
                if core.shutdown {
                    // The shutdown signal was already consumed; a
                    // respawned loop would block forever on recv.
                    fail_all_batched(&mut core, metrics);
                    return;
                }
                eprintln!(
                    "shard {id}: executor panicked; restarting ({restarts}/{})",
                    knobs.max_restarts
                );
            }
        }
    }
}

/// Fail-fast drain for a dead shard: answer every request
/// `Err(ShardDead)` immediately, keep acking flushes, exit on shutdown.
/// Producers blocked on a full admission queue unblock as this consumes;
/// nothing ever hangs on a dead shard.
fn tombstone_loop(
    rx: &Receiver<ShardMsg>,
    depth: &AtomicU64,
    metrics: &Arc<Mutex<HashMap<String, Metrics>>>,
) {
    while let Ok(msg) = rx.recv() {
        match msg {
            ShardMsg::Request { app, respond, .. } => {
                depth.fetch_sub(1, Ordering::Relaxed);
                let _ = respond.send(Err(ServeError::ShardDead));
                lock_unpoisoned(metrics).entry(app).or_default().failed_requests += 1;
            }
            ShardMsg::Flush(ack) => {
                let _ = ack.send(());
            }
            ShardMsg::Shutdown => return,
        }
    }
}

/// The executor loop: one per shard thread, re-entered by the
/// supervisor after a panic. Identical in shape to the old
/// single-controller loop, but scoped to this shard's apps and
/// executing waves row-parallel on the shared engine.
#[allow(clippy::too_many_arguments)]
fn shard_loop(
    id: usize,
    engine: &Engine,
    rx: &Receiver<ShardMsg>,
    depth: &AtomicU64,
    metrics: &Arc<Mutex<HashMap<String, Metrics>>>,
    specs: &HashMap<String, (usize, usize)>,
    cfg: &BatcherConfig,
    knobs: &WaveKnobs,
    chaos_budget: &AtomicU64,
    core: &mut ShardCore,
) {
    loop {
        // Wait for work (bounded, so timeouts can close partial waves).
        match rx.recv_timeout(cfg.max_wait) {
            Ok(ShardMsg::Request { app, inputs, respond, enqueued, deadline }) => {
                // Dequeue edge: the consumer-side depth sample pairs
                // with the producer-side sample taken at admission.
                let d = depth.fetch_sub(1, Ordering::Relaxed).saturating_sub(1);
                let Some(&(n, batch)) = specs.get(&app) else {
                    // The server validates routing before enqueueing;
                    // answer with an error rather than dropping.
                    eprintln!("shard {id}: request for unknown app `{app}` rejected");
                    let _ = respond
                        .send(Err(ServeError::Exec(format!("app `{app}` unknown to shard {id}"))));
                    continue;
                };
                // Deadline checkpoint 1: dequeue. A request whose
                // budget expired in the admission queue never occupies
                // a batcher slot or a subarray row.
                if deadline.is_some_and(|dl| dl <= Instant::now()) {
                    let _ = respond.send(Err(ServeError::Timeout));
                    let mut m = lock_unpoisoned(metrics);
                    let e = m.entry(app).or_default();
                    e.deadline_timeouts += 1;
                    e.record_queue_depth(d);
                    continue;
                }
                lock_unpoisoned(metrics).entry(app.clone()).or_default().record_queue_depth(d);
                let b = core.batchers.entry(app).or_insert_with(|| {
                    Batcher::new(BatcherConfig { batch, max_wait: cfg.max_wait }, n)
                });
                b.push(Pending { inputs, respond, enqueued, deadline });
            }
            Ok(ShardMsg::Flush(ack)) => {
                drain_all(engine, core, metrics, knobs, chaos_budget);
                let _ = ack.send(());
            }
            Ok(ShardMsg::Shutdown) => {
                core.shutdown = true;
                drain_all(engine, core, metrics, knobs, chaos_budget);
                return;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                core.shutdown = true;
                drain_all(engine, core, metrics, knobs, chaos_budget);
                return;
            }
        }
        // Deadline checkpoint 2 + wave close: expire overdue batcher
        // entries, then close any ready waves (full, or past the
        // batching deadline). Disjoint borrows: batchers vs exec.
        let now = Instant::now();
        let ShardCore { batchers, exec, .. } = core;
        for (app, b) in batchers.iter_mut() {
            let expired = b.expire(now);
            if !expired.is_empty() {
                timeout_pendings(app, expired, metrics);
            }
            while b.ready(now) {
                let close = if b.is_full() { WaveClose::Full } else { WaveClose::Deadline };
                execute_wave(engine, app, b, metrics, exec, knobs, chaos_budget, close);
            }
        }
    }
}

fn drain_all(
    engine: &Engine,
    core: &mut ShardCore,
    metrics: &Arc<Mutex<HashMap<String, Metrics>>>,
    knobs: &WaveKnobs,
    chaos_budget: &AtomicU64,
) {
    let now = Instant::now();
    let ShardCore { batchers, exec, .. } = core;
    for (app, b) in batchers.iter_mut() {
        let expired = b.expire(now);
        if !expired.is_empty() {
            timeout_pendings(app, expired, metrics);
        }
        while !b.is_empty() {
            // A full wave that happens to drain during a flush still
            // counts as a capacity close; only partial tails are
            // flush-closed.
            let close = if b.is_full() { WaveClose::Full } else { WaveClose::Flush };
            execute_wave(engine, app, b, metrics, exec, knobs, chaos_budget, close);
        }
    }
}

/// Answer expired batcher entries `Err(Timeout)` and count them.
fn timeout_pendings(
    app: &str,
    expired: Vec<Pending>,
    metrics: &Arc<Mutex<HashMap<String, Metrics>>>,
) {
    let n = expired.len() as u64;
    for p in expired {
        let _ = p.respond.send(Err(ServeError::Timeout));
    }
    lock_unpoisoned(metrics).entry(app.to_string()).or_default().deadline_timeouts += n;
}

/// Answer every live row of a wave with `err` and count the failures.
fn fail_wave(
    app: &str,
    wave: &Batch,
    err: ServeError,
    metrics: &Arc<Mutex<HashMap<String, Metrics>>>,
) {
    for r in &wave.responders {
        let _ = r.send(Err(err.clone()));
    }
    lock_unpoisoned(metrics).entry(app.to_string()).or_default().failed_requests +=
        wave.responders.len() as u64;
}

/// Dead-shard cleanup: fail everything still batched with `ShardDead`.
fn fail_all_batched(core: &mut ShardCore, metrics: &Arc<Mutex<HashMap<String, Metrics>>>) {
    for (app, b) in core.batchers.iter_mut() {
        while !b.is_empty() {
            let wave = b.drain();
            fail_wave(app, &wave, ServeError::ShardDead, metrics);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn execute_wave(
    engine: &Engine,
    app: &str,
    b: &mut Batcher,
    metrics: &Arc<Mutex<HashMap<String, Metrics>>>,
    exec: &mut ExecState,
    knobs: &WaveKnobs,
    chaos_budget: &AtomicU64,
    close: WaveClose,
) {
    let wave = b.drain();
    exec.seed = exec.seed.wrapping_mul(0x343FD).wrapping_add(0x269EC3);
    exec.waves += 1;
    let seed = exec.seed;
    let wave_no = exec.waves;
    let level = exec.ctl.level();
    // Park the wave where the supervisor can reach it BEFORE any
    // panic-prone work (chaos injection, engine execution): a panic
    // from here on fails exactly these responders, nothing hangs.
    exec.inflight = Some((app.to_string(), wave));
    let ExecState { inflight, ctl, .. } = exec;
    let t0 = Instant::now();
    // Disturb after t0: injected latency reads as wave execution time
    // (it is), not as queue wait — the degradation controller must see
    // real congestion, not the injection itself.
    if let Some(chaos) = &knobs.chaos {
        chaos.disturb(wave_no, chaos_budget);
    }
    let result = {
        let (_, wave) = inflight.as_ref().expect("wave parked above");
        engine.execute_rows_degraded(
            app,
            &wave.values,
            seed,
            wave.responders.len(),
            knobs.row_threads,
            knobs.lane_width,
            Some(knobs.rng),
            knobs.fault.as_ref(),
            level,
        )
    };
    let dt = t0.elapsed();
    let (_, wave) = inflight.take().expect("wave parked above");
    match result {
        Ok((outs, stats)) => {
            // Deadline checkpoint 3: completion. A slow wave can outlive
            // a row's budget — those rows get `Err(Timeout)`, not a
            // value that arrived too late to use.
            let done = Instant::now();
            let mut timeouts = 0u64;
            for (i, r) in wave.responders.iter().enumerate() {
                if wave.deadlines[i].is_some_and(|dl| dl <= done) {
                    timeouts += 1;
                    let _ = r.send(Err(ServeError::Timeout));
                } else {
                    let _ = r.send(Ok(outs[i]));
                }
            }
            let mut m = lock_unpoisoned(metrics);
            let e = m.entry(app.to_string()).or_default();
            e.record_wave(wave.responders.len(), wave.padded, dt);
            e.record_stats(&stats);
            e.record_drain(close);
            e.deadline_timeouts += timeouts;
            e.bl_level = u64::from(level);
            if level > 0 {
                e.degraded_waves += 1;
            }
            for enq in &wave.enqueued {
                // Submit → wave start (admission channel + batcher
                // residence); saturates to zero across threads. The
                // same sample feeds the degradation controller.
                let w = t0.duration_since(*enq);
                e.record_queue_wait(w);
                ctl.record_wait_us(w.as_micros().min(u128::from(u64::MAX)) as u64);
            }
            for _ in 0..wave.responders.len() {
                e.record_latency(dt);
            }
        }
        Err(err) => {
            // Engine errors (including worker-pool panics mapped to
            // errors) fail the wave's rows explicitly — receivers get
            // a typed error, never a silent drop.
            let msg = format!("wave execution failed for `{app}`: {err:#}");
            eprintln!("{msg}");
            for r in &wave.responders {
                let _ = r.send(Err(ServeError::Exec(msg.clone())));
            }
            let mut m = lock_unpoisoned(metrics);
            let e = m.entry(app.to_string()).or_default();
            e.record_drain(close);
            e.failed_requests += wave.responders.len() as u64;
        }
    }
    ctl.on_wave();
}
