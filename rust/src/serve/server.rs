//! The serving front door: request validation, shard routing, admission
//! control, synchronous workload driving, and pool-wide metrics.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

use crate::bail;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::metrics::Metrics;
use crate::error::Result;
use crate::fault::FaultPlan;
use crate::obs::MetricsSnapshot;
use crate::runtime::Engine;
use crate::util::prng::RngMode;

use super::pool::BankPool;
use super::resilience::{
    deadline_override, lock_unpoisoned, ChaosPlan, DegradeConfig, Reply, SubmitOpts,
};
use super::shard::{Admission, ShardMsg};

/// Serving configuration: how many bank shards, how deep each shard's
/// admission queue is, and how waves batch/execute.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Number of controller shards. `0` (default) = one per artifact;
    /// smaller values hash apps onto the available shards; values above
    /// the artifact count are capped.
    pub shards: usize,
    /// Bounded per-shard admission queue depth. `submit` blocks when the
    /// queue is full (backpressure); `try_submit` errors instead.
    pub queue_depth: usize,
    /// Wave batching knobs (`batch` is taken from each artifact's
    /// manifest spec; `max_wait` closes partial waves).
    pub batcher: BatcherConfig,
    /// Wave-level parallelism: worker threads the interpreter splits a
    /// wave across. Every kernel — staged apps included — hands each
    /// worker whole lane blocks (the word-parallel engine evaluates up
    /// to 256 batch rows per `u64×W` lane word). `0`
    /// (default) = auto — the `STOCH_IMC_ROW_THREADS` env var if set
    /// (honored as-is), else the machine's cores divided across the
    /// pool's shards. Resolved once at start, so the per-wave path
    /// never touches the environment. Outputs are bit-identical for
    /// every value.
    pub row_threads: usize,
    /// Rows per lane block in the word-parallel engine: `64`, `128`,
    /// `256`, or `512` (`u64×{1,2,4,8}` lane words). `0` (default) =
    /// auto — the `STOCH_IMC_LANE_WIDTH` env var if set (resolved once
    /// at pool start into a pinned width, like `row_threads`), else
    /// each wave is auto-sized by the engine (narrowest covering
    /// block, narrowed further only so every row worker keeps a
    /// block). Purely a throughput knob: outputs are bit-identical at
    /// every width.
    pub lane_width: usize,
    /// SNG generator family every wave draws from: `None` (default) =
    /// the `STOCH_IMC_RNG` env var if set, else the counter-based
    /// stateless generator. `Some(RngMode::Xoshiro)` pins the legacy
    /// lockstep xoshiro bank (the bit-pinned compat path). Resolved
    /// once at pool start.
    pub rng: Option<RngMode>,
    /// Fault-injection plan every wave executes under (`None` = clean
    /// serving, the default). With a live plan the executor XORs
    /// stateless fault masks into the lane words at the paper's three
    /// sites (SNG output, gate output, StoB read) — the `faults`
    /// campaign drives Table-4-style accuracy-vs-flip-rate sweeps
    /// through the full serving stack with this knob.
    pub fault: Option<FaultPlan>,
    /// Default end-to-end request deadline applied to every submit that
    /// doesn't carry its own ([`SubmitOpts::deadline`] wins). `None`
    /// (default) = the `STOCH_IMC_DEADLINE_MS` env var if set, else
    /// unbounded. Resolved once at start.
    pub deadline: Option<Duration>,
    /// Adaptive graceful-degradation controller (queue-wait p95 → BL
    /// ladder). `None` (default) = the `STOCH_IMC_DEGRADE_*` env vars
    /// if set, else disabled — degraded waves trade accuracy for
    /// latency, so the ladder is strictly opt-in. Resolved once at
    /// start.
    pub degrade: Option<DegradeConfig>,
    /// Chaos-injection plan for the resilience harness (`None` =
    /// production serving; an all-zero plan is bit-identical to it).
    pub chaos: Option<ChaosPlan>,
    /// Consecutive executor panics a shard survives (supervised
    /// respawn) before it is marked dead and routed around.
    pub max_restarts: u32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            shards: 0,
            queue_depth: 1024,
            batcher: BatcherConfig::default(),
            row_threads: 0,
            lane_width: 0,
            rng: None,
            fault: None,
            deadline: None,
            degrade: None,
            chaos: None,
            max_restarts: 8,
        }
    }
}

/// Multi-app serving front door over a [`BankPool`] of controller
/// shards. Shareable across caller threads (`&Server` is enough to
/// submit), like a bank-parallel chip serving many hosts.
pub struct Server {
    pool: BankPool,
    specs: HashMap<String, (usize, usize)>, // name → (n_inputs, batch)
    /// Deadline applied when a submit carries none — config, then
    /// `STOCH_IMC_DEADLINE_MS`, resolved once at start.
    default_deadline: Option<Duration>,
}

impl Server {
    /// Load the artifacts in `dir` once, share the engine across the
    /// pool, and start the shards.
    ///
    /// Unlike the old single-controller coordinator (which constructed
    /// the engine *inside* its thread), the engine is built here and
    /// shared `Arc<Engine>` — which requires the backend to be
    /// `Send + Sync`. The default interpreter backend is; the PJRT
    /// backend's handles are not, and that path cannot link without a
    /// vendored `xla` crate anyway (see `runtime::mod`).
    pub fn start(dir: &Path, cfg: ServerConfig) -> Result<Self> {
        let engine = Arc::new(Engine::load(dir)?);
        let specs: HashMap<String, (usize, usize)> = engine
            .artifact_names()
            .into_iter()
            .filter_map(|n| engine.spec(n).map(|s| (s.name.clone(), (s.n_inputs, s.batch))))
            .collect();
        let default_deadline = cfg.deadline.or_else(deadline_override);
        let pool = BankPool::start(engine, &specs, &cfg)?;
        Ok(Self { pool, specs, default_deadline })
    }

    /// Servable artifact names, sorted.
    pub fn apps(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn n_inputs(&self, app: &str) -> Option<usize> {
        self.specs.get(app).map(|(n, _)| *n)
    }

    pub fn n_shards(&self) -> usize {
        self.pool.n_shards()
    }

    /// Which shard serves `app` (None for unknown apps).
    pub fn shard_of(&self, app: &str) -> Option<usize> {
        self.pool.shard_of(app)
    }

    /// Submit one instance; blocks while the owning shard's admission
    /// queue is full (backpressure). Returns the result receiver: every
    /// admitted request gets exactly one [`Reply`] — a value, or a
    /// typed error (`Timeout` / `ShardDead` / `Exec`).
    pub fn submit(&self, app: &str, inputs: &[f64]) -> Result<Receiver<Reply>> {
        self.submit_opts(app, inputs, SubmitOpts::default())
    }

    /// Non-blocking submit: errors immediately with a "queue full"
    /// message when the shard is saturated, so callers can shed load.
    pub fn try_submit(&self, app: &str, inputs: &[f64]) -> Result<Receiver<Reply>> {
        self.submit_opts(app, inputs, SubmitOpts { shed: true, ..SubmitOpts::default() })
    }

    /// [`Server::submit`] with an explicit end-to-end deadline measured
    /// from now: checked at dequeue, at wave close, and at completion;
    /// an expired request is answered `Err(Timeout)`, never silently
    /// dropped.
    pub fn submit_with_deadline(
        &self,
        app: &str,
        inputs: &[f64],
        deadline: Duration,
    ) -> Result<Receiver<Reply>> {
        self.submit_opts(app, inputs, SubmitOpts { deadline: Some(deadline), shed: false })
    }

    /// The general submission entry point: [`SubmitOpts`] carries the
    /// per-request deadline (defaulting to the server-wide one) and the
    /// shed-vs-block admission policy.
    pub fn submit_opts(
        &self,
        app: &str,
        inputs: &[f64],
        opts: SubmitOpts,
    ) -> Result<Receiver<Reply>> {
        match self.submit_inner(app, inputs, opts)? {
            Some(rx) => Ok(rx),
            None => bail!(
                "shard {} admission queue full (backpressure)",
                self.pool.shard_of(app).unwrap_or(0)
            ),
        }
    }

    /// Shed-aware admission for the TCP front door: `Ok(None)` when
    /// the shard's queue is full, so the wire layer can answer with a
    /// typed `Overloaded` response (retry-safe at the client) instead
    /// of string-matching a formatted error. `Err` remains request
    /// validation (unknown app, arity) — a `BadRequest` on the wire.
    pub fn submit_shedding(
        &self,
        app: &str,
        inputs: &[f64],
        deadline: Option<Duration>,
    ) -> Result<Option<Receiver<Reply>>> {
        self.submit_inner(app, inputs, SubmitOpts { deadline, shed: true })
    }

    fn submit_inner(
        &self,
        app: &str,
        inputs: &[f64],
        opts: SubmitOpts,
    ) -> Result<Option<Receiver<Reply>>> {
        let Some(&(n, _)) = self.specs.get(app) else {
            bail!("unknown app `{app}` (have: {:?})", self.apps());
        };
        if inputs.len() != n {
            bail!("app `{app}` expects {n} inputs, got {}", inputs.len());
        }
        let Some(shard) = self.pool.shard_for(app) else {
            let dead = self.pool.dead_shards();
            if dead.is_empty() {
                bail!("app `{app}` has no shard (pool misrouted)");
            }
            bail!("app `{app}` has no live shard (dead shards: {dead:?})");
        };
        let (rtx, rrx) = channel();
        let deadline =
            opts.deadline.or(self.default_deadline).map(|budget| Instant::now() + budget);
        let msg = ShardMsg::Request {
            app: app.to_string(),
            inputs: inputs.iter().map(|&v| v as f32).collect(),
            respond: rtx,
            enqueued: Instant::now(),
            deadline,
        };
        // Admission telemetry: depth sampled at the enqueue edge,
        // backpressure blocks and sheds counted per app. The lock is a
        // few nanoseconds against millisecond waves.
        match shard.admit(msg, !opts.shed)? {
            Admission::Accepted(depth) => {
                lock_unpoisoned(self.pool.metrics_map())
                    .entry(app.to_string())
                    .or_default()
                    .record_queue_depth(depth);
            }
            Admission::AcceptedAfterBlock(depth) => {
                let mut m = lock_unpoisoned(self.pool.metrics_map());
                let e = m.entry(app.to_string()).or_default();
                e.record_queue_depth(depth);
                e.backpressure_blocks += 1;
            }
            Admission::Shed => {
                let mut m = lock_unpoisoned(self.pool.metrics_map());
                m.entry(app.to_string()).or_default().shed += 1;
                return Ok(None);
            }
        }
        Ok(Some(rrx))
    }

    /// Run a whole workload synchronously; returns outputs in order.
    /// Safe to call concurrently from multiple threads for different
    /// (or the same) apps — that is the multi-bank serving path.
    pub fn run_workload(&self, app: &str, instances: &[Vec<f64>]) -> Result<Vec<f64>> {
        let t0 = Instant::now();
        let receivers: Result<Vec<Receiver<Reply>>> =
            instances.iter().map(|x| self.submit(app, x)).collect();
        let receivers = receivers?;
        // Close the partial tail wave instead of waiting out max_wait.
        if let Some(shard) = self.pool.shard_for(app) {
            let (ack_tx, _ack_rx) = channel();
            shard.send(ShardMsg::Flush(ack_tx))?;
        }
        let mut out = Vec::with_capacity(receivers.len());
        for r in receivers {
            match r.recv() {
                Ok(Ok(v)) => out.push(v as f64),
                Ok(Err(e)) => bail!("request failed for `{app}`: {e}"),
                Err(_) => bail!("result dropped for `{app}`"),
            }
        }
        let dt = t0.elapsed();
        lock_unpoisoned(self.pool.metrics_map()).entry(app.to_string()).or_default().total_time +=
            dt;
        Ok(out)
    }

    /// Block until every shard has executed everything admitted so far
    /// (partial waves included).
    pub fn drain(&self) -> Result<()> {
        self.pool.flush_all()
    }

    /// Per-app metrics snapshot.
    pub fn metrics(&self, app: &str) -> Metrics {
        self.pool.metrics(app)
    }

    /// Aggregate metrics across all apps and shards.
    pub fn pool_metrics(&self) -> Metrics {
        self.pool.pool_metrics()
    }

    /// Flat exposition snapshot of every per-app metrics object plus
    /// the pool aggregate, under `serve_<app>_*` / `serve_pool_*` keys
    /// (see `docs/ARCHITECTURE.md` § Observability for the field map).
    /// Render it with [`MetricsSnapshot::to_flat_json`] or
    /// [`MetricsSnapshot::to_prometheus`].
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::default();
        let mut pool = Metrics::default();
        {
            let m = lock_unpoisoned(self.pool.metrics_map());
            let mut apps: Vec<&String> = m.keys().collect();
            apps.sort();
            for app in apps {
                let e = &m[app];
                e.snapshot_into(app, &mut snap);
                pool.merge(e);
            }
        }
        pool.snapshot_into("pool", &mut snap);
        snap
    }

    /// Shards whose supervisor gave up respawning (restart budget
    /// exhausted); their apps are served by live siblings.
    pub fn dead_shards(&self) -> Vec<usize> {
        self.pool.dead_shards()
    }
}
