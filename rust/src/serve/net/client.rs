//! The retrying TCP client: connection reuse, wire-propagated
//! deadlines, exponential backoff with deterministic seeded jitter,
//! and a per-target circuit breaker.
//!
//! The retry policy only replays *idempotent-safe* outcomes — failures
//! where the request provably did not deliver a result to this caller
//! (connect/transport failures, `ShardDead`, admission sheds, protocol
//! errors, going-away). A delivered value or a terminal serve-layer
//! verdict (`Timeout`, `Exec`, `BadRequest`) is returned exactly once
//! and never re-requested, so one client call can never double-count a
//! result. Backoff jitter derives from `mix64(seed, attempt)` — fully
//! deterministic for a given seed, so tests pin exact schedules
//! without a clock.

use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::serve::resilience::ServeError;
use crate::util::prng::{mix64, GOLDEN_GAMMA};

use super::wire::{self, Control, ReadError, RespBody};

/// One terminal client-side outcome. Every [`Client::call`] returns
/// exactly one `Ok` value or one of these — a request is never left
/// ambiguous.
#[derive(Debug, Clone, PartialEq)]
pub enum NetError {
    /// A serve-layer verdict carried over the wire, variant-preserved.
    Serve(ServeError),
    /// The server shed the request at admission (queue full) or the
    /// connection pool was busy. Retry-safe.
    Overloaded,
    /// The server rejected the request as invalid (unknown app, arity
    /// mismatch). Not retry-safe: the same bytes cannot succeed.
    BadRequest(String),
    /// The peer and this client disagreed about the protocol
    /// (malformed frame, id mismatch, unexpected kind). The connection
    /// is dropped; retry-safe on a fresh connection.
    Protocol(String),
    /// A transport failure: connect, send, or mid-response read. No
    /// result was delivered, so retry-safe; counts toward the breaker.
    Transport(String),
    /// The server announced drain; the connection is closed.
    /// Retry-safe (against a restarted or different server).
    GoingAway,
    /// The circuit breaker is open for this target: fast-fail without
    /// touching the network.
    BreakerOpen,
    /// The retry budget ran out; `last` is the final attempt's error.
    RetriesExhausted { attempts: u32, last: Box<NetError> },
}

impl NetError {
    /// May this outcome be retried without risking a double-counted
    /// result? True exactly when no result was (or could have been)
    /// delivered for the attempt.
    pub fn retry_safe(&self) -> bool {
        match self {
            NetError::Transport(_)
            | NetError::Overloaded
            | NetError::Protocol(_)
            | NetError::GoingAway
            | NetError::Serve(ServeError::ShardDead) => true,
            NetError::Serve(_)
            | NetError::BadRequest(_)
            | NetError::BreakerOpen
            | NetError::RetriesExhausted { .. } => false,
        }
    }

    /// Does this outcome indicate the *transport* (not the server's
    /// application layer) is unhealthy? Only these trip the breaker.
    pub fn is_transport(&self) -> bool {
        matches!(self, NetError::Transport(_))
    }
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Serve(e) => write!(f, "serve error: {e}"),
            NetError::Overloaded => write!(f, "server overloaded (request shed)"),
            NetError::BadRequest(m) => write!(f, "bad request: {m}"),
            NetError::Protocol(m) => write!(f, "protocol error: {m}"),
            NetError::Transport(m) => write!(f, "transport error: {m}"),
            NetError::GoingAway => write!(f, "server going away (drain)"),
            NetError::BreakerOpen => write!(f, "circuit breaker open"),
            NetError::RetriesExhausted { attempts, last } => {
                write!(f, "retries exhausted after {attempts} attempts; last: {last}")
            }
        }
    }
}

impl std::error::Error for NetError {}

/// Exponential backoff with deterministic seeded jitter: attempt `k`
/// sleeps `base·2^k + jitter(seed, k)` where the jitter is uniform in
/// `[0, base)` derived from `mix64` — no clock, no global RNG, so a
/// given seed always produces the same schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries after the first attempt (`0` = never retry).
    pub max: u32,
    /// Base backoff unit.
    pub base: Duration,
    /// Jitter seed; vary per client to decorrelate a retry storm.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max: 3, base: Duration::from_millis(10), seed: 0x5EED }
    }
}

impl RetryPolicy {
    /// Resolve from `STOCH_IMC_RETRY_MAX` / `STOCH_IMC_RETRY_BASE_MS`
    /// over the defaults; unparseable values warn and keep the default.
    pub fn from_env() -> Self {
        let mut p = Self::default();
        if let Ok(s) = std::env::var("STOCH_IMC_RETRY_MAX") {
            match s.trim().parse::<u32>() {
                Ok(n) => p.max = n,
                Err(_) => eprintln!("STOCH_IMC_RETRY_MAX=`{s}` is not an integer; using {}", p.max),
            }
        }
        if let Ok(s) = std::env::var("STOCH_IMC_RETRY_BASE_MS") {
            match s.trim().parse::<u64>() {
                Ok(ms) => p.base = Duration::from_millis(ms),
                Err(_) => {
                    eprintln!("STOCH_IMC_RETRY_BASE_MS=`{s}` is not an integer; keeping default")
                }
            }
        }
        p
    }

    /// The backoff before retry number `attempt` (0-based). Pure —
    /// deterministic in `(seed, attempt)`.
    pub fn delay(&self, attempt: u32) -> Duration {
        let base_ns = self.base.as_nanos().min(u64::MAX as u128) as u64;
        if base_ns == 0 {
            return Duration::ZERO;
        }
        let exp = base_ns.saturating_mul(1u64 << attempt.min(20));
        let jitter = mix64(self.seed ^ u64::from(attempt).wrapping_mul(GOLDEN_GAMMA)) % base_ns;
        Duration::from_nanos(exp.saturating_add(jitter))
    }
}

/// Circuit-breaker knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerConfig {
    /// Consecutive transport failures that open the breaker.
    pub threshold: u32,
    /// How long the breaker stays open before half-opening (one probe
    /// attempt allowed; its outcome closes or re-opens).
    pub cooloff: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self { threshold: 5, cooloff: Duration::from_millis(500) }
    }
}

/// Breaker states, readable for tests and metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: all attempts allowed.
    Closed,
    /// Tripped: attempts fast-fail until the cooloff elapses.
    Open,
    /// Cooloff elapsed: exactly one probe is in flight.
    HalfOpen,
}

/// Per-target circuit breaker. A pure state machine over explicit
/// `Instant`s — callers pass `now`, so tests drive it with a fake
/// clock (synthetic instants) and never sleep.
#[derive(Debug)]
pub struct Breaker {
    cfg: BreakerConfig,
    consecutive_failures: u32,
    /// `Some(when)` = open since `when`; half-open once
    /// `now >= when + cooloff`.
    opened_at: Option<Instant>,
    probing: bool,
}

impl Breaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        Self { cfg, consecutive_failures: 0, opened_at: None, probing: false }
    }

    pub fn state(&self) -> BreakerState {
        match self.opened_at {
            None => BreakerState::Closed,
            Some(_) if self.probing => BreakerState::HalfOpen,
            Some(_) => BreakerState::Open,
        }
    }

    /// May an attempt proceed at `now`? Opening the half-open window
    /// marks a probe, so concurrent callers of a shared breaker would
    /// send exactly one probe per cooloff.
    pub fn allow(&mut self, now: Instant) -> bool {
        match self.opened_at {
            None => true,
            Some(when) => {
                if self.probing {
                    false
                } else if now.saturating_duration_since(when) >= self.cfg.cooloff {
                    self.probing = true;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// A delivered (non-transport-failed) attempt closes the breaker.
    pub fn on_success(&mut self) {
        self.consecutive_failures = 0;
        self.opened_at = None;
        self.probing = false;
    }

    /// A transport failure at `now`: counts toward the threshold; a
    /// failed half-open probe re-opens immediately.
    pub fn on_failure(&mut self, now: Instant) {
        self.consecutive_failures = self.consecutive_failures.saturating_add(1);
        if self.probing || self.consecutive_failures >= self.cfg.threshold {
            self.opened_at = Some(now);
            self.probing = false;
        }
    }
}

/// Client configuration.
#[derive(Debug, Clone, Copy)]
pub struct ClientConfig {
    /// Per-io-operation budget: connect, send, and the tail of a
    /// response read all individually bound by this.
    pub io_timeout: Duration,
    /// Default end-to-end deadline per call (`None` = unbounded; the
    /// response wait is then bounded by `io_timeout` alone). The
    /// remaining budget is re-sent on the wire each attempt.
    pub deadline: Option<Duration>,
    pub retry: RetryPolicy,
    pub breaker: BreakerConfig,
}

impl Default for ClientConfig {
    fn default() -> Self {
        Self {
            io_timeout: Duration::from_secs(2),
            deadline: None,
            retry: RetryPolicy::default(),
            breaker: BreakerConfig::default(),
        }
    }
}

impl ClientConfig {
    /// Defaults with the retry policy resolved from the environment.
    pub fn from_env() -> Self {
        Self { retry: RetryPolicy::from_env(), ..Self::default() }
    }
}

/// Client-side counters, exposed for the flood harness and tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClientStats {
    /// Values delivered.
    pub ok: u64,
    /// Retry attempts performed (not counting first attempts).
    pub retries: u64,
    /// Fresh TCP connects (first connect included).
    pub connects: u64,
    /// Calls fast-failed by the open breaker.
    pub breaker_fast_fails: u64,
    /// Protocol-class failures observed.
    pub protocol_errors: u64,
    /// Transport-class failures observed.
    pub transport_errors: u64,
}

/// A reusable connection to one `TcpFront` target.
///
/// Not `Sync`: one client per thread (the flood harness spawns one per
/// connection lane), mirroring one socket per client.
pub struct Client {
    addr: String,
    cfg: ClientConfig,
    conn: Option<TcpStream>,
    breaker: Breaker,
    next_id: u64,
    stats: ClientStats,
}

impl Client {
    /// Create a client for `addr` (e.g. `127.0.0.1:7117`). Lazy: no
    /// connection is made until the first call.
    pub fn new(addr: impl Into<String>, cfg: ClientConfig) -> Self {
        let breaker = Breaker::new(cfg.breaker);
        // Decorrelate jitter across clients even with a shared config
        // seed: fold the target address into the stream.
        let addr = addr.into();
        let mut cfg = cfg;
        cfg.retry.seed ^= crate::util::prng::fnv1a(&addr);
        Self { addr, cfg, conn: None, breaker, next_id: 1, stats: ClientStats::default() }
    }

    pub fn stats(&self) -> ClientStats {
        self.stats
    }

    pub fn breaker_state(&self) -> BreakerState {
        self.breaker.state()
    }

    /// Call `app(inputs)` under the client's default deadline.
    pub fn call(&mut self, app: &str, inputs: &[f64]) -> Result<f32, NetError> {
        self.call_opt(app, inputs, self.cfg.deadline)
    }

    /// Call with an explicit end-to-end deadline budget covering every
    /// retry and backoff sleep; the remaining budget at each attempt
    /// is propagated on the wire.
    pub fn call_with_deadline(
        &mut self,
        app: &str,
        inputs: &[f64],
        budget: Duration,
    ) -> Result<f32, NetError> {
        self.call_opt(app, inputs, Some(budget))
    }

    fn call_opt(
        &mut self,
        app: &str,
        inputs: &[f64],
        budget: Option<Duration>,
    ) -> Result<f32, NetError> {
        let deadline = budget.map(|b| Instant::now() + b);
        let mut last: Option<NetError> = None;
        let mut attempts = 0u32;
        for attempt in 0..=self.cfg.retry.max {
            if attempt > 0 {
                let delay = self.cfg.retry.delay(attempt - 1);
                if let Some(dl) = deadline {
                    // A sleep that would outlive the deadline cannot
                    // lead to a successful attempt; stop retrying.
                    if Instant::now() + delay >= dl {
                        break;
                    }
                }
                std::thread::sleep(delay);
                self.stats.retries += 1;
            }
            if !self.breaker.allow(Instant::now()) {
                self.stats.breaker_fast_fails += 1;
                return Err(NetError::BreakerOpen);
            }
            attempts += 1;
            match self.attempt(app, inputs, deadline) {
                Ok(v) => {
                    self.breaker.on_success();
                    self.stats.ok += 1;
                    return Ok(v);
                }
                Err(e) => {
                    match &e {
                        NetError::Transport(_) => {
                            self.stats.transport_errors += 1;
                            self.breaker.on_failure(Instant::now());
                        }
                        NetError::Protocol(_) => {
                            self.stats.protocol_errors += 1;
                            self.breaker.on_success(); // transport delivered bytes
                        }
                        _ => self.breaker.on_success(),
                    }
                    if !e.retry_safe() {
                        return Err(e);
                    }
                    last = Some(e);
                }
            }
        }
        let last = last.unwrap_or(NetError::Transport("no attempt was made".into()));
        Err(NetError::RetriesExhausted { attempts, last: Box::new(last) })
    }

    /// One attempt over one (possibly reused) connection. Any failure
    /// drops the connection, so a stale response from a failed attempt
    /// can never be read by a later one — that, plus fresh per-attempt
    /// ids, is what makes the retry loop double-delivery-proof.
    fn attempt(
        &mut self,
        app: &str,
        inputs: &[f64],
        deadline: Option<Instant>,
    ) -> Result<f32, NetError> {
        let remaining = deadline.map(|dl| dl.saturating_duration_since(Instant::now()));
        if let Some(r) = remaining {
            if r.is_zero() {
                return Err(NetError::Serve(ServeError::Timeout));
            }
        }
        self.ensure_connected()?;
        let id = self.next_id;
        self.next_id += 1;
        let req = wire::Request {
            id,
            deadline_budget_us: remaining.map_or(0, |r| r.as_micros().min(u64::MAX as u128) as u64),
            app: app.to_string(),
            inputs: inputs.to_vec(),
        };
        let io = self.cfg.io_timeout;
        let stream = self.conn.as_mut().expect("connected above");
        if let Err(e) = wire::write_frame(stream, &wire::encode_request(&req), io) {
            self.conn = None;
            return Err(NetError::Transport(format!("send failed: {e}")));
        }
        // Response wait: the deadline budget (plus one io grace for the
        // wire hop) when bounded, the io timeout alone when not.
        let wait = remaining.map_or(io, |r| r + io);
        let out = match wire::read_frame(stream, wait, io) {
            Ok((wire::KIND_RESPONSE, payload)) => match wire::decode_response(&payload) {
                Ok(resp) if resp.id == id => match resp.body {
                    RespBody::Value(v) => return Ok(v), // connection stays reusable
                    RespBody::Err(e) => Err(NetError::Serve(e)),
                    RespBody::Overloaded => Err(NetError::Overloaded),
                    RespBody::BadRequest(m) => Err(NetError::BadRequest(m)),
                },
                Ok(resp) => {
                    Err(NetError::Protocol(format!("response id {} for request {id}", resp.id)))
                }
                Err(e) => Err(NetError::Protocol(e.to_string())),
            },
            Ok((wire::KIND_CONTROL, payload)) => match wire::decode_control(&payload) {
                Ok(Control::GoingAway) => Err(NetError::GoingAway),
                Ok(Control::Busy) => Err(NetError::Overloaded),
                Ok(Control::ProtocolError(m)) => {
                    Err(NetError::Protocol(format!("server rejected frame: {m}")))
                }
                Err(e) => Err(NetError::Protocol(e.to_string())),
            },
            Ok((kind, _)) => Err(NetError::Protocol(format!("unexpected frame kind {kind}"))),
            Err(ReadError::Idle) => Err(match deadline {
                // The budget (plus grace) elapsed with no response: a
                // terminal timeout, NOT retried — the server may still
                // deliver, and a retry could double-execute the work.
                Some(_) => NetError::Serve(ServeError::Timeout),
                None => NetError::Transport("response timed out".into()),
            }),
            Err(ReadError::Stalled) => {
                Err(NetError::Transport("response stalled mid-frame".into()))
            }
            Err(ReadError::Closed) => {
                Err(NetError::Transport("connection closed by server".into()))
            }
            Err(ReadError::Io(e)) => Err(NetError::Transport(format!("read failed: {e}"))),
            Err(ReadError::Wire(e)) => Err(NetError::Protocol(e.to_string())),
        };
        // Terminal serve verdicts arrive on a healthy connection; every
        // other path leaves the stream in an unknown framing state.
        if !matches!(out, Err(NetError::Serve(_)) | Err(NetError::Overloaded)) {
            self.conn = None;
        }
        out
    }

    fn ensure_connected(&mut self) -> Result<(), NetError> {
        if self.conn.is_some() {
            return Ok(());
        }
        let sa = self
            .addr
            .to_socket_addrs()
            .map_err(|e| NetError::Transport(format!("resolve {}: {e}", self.addr)))?
            .next()
            .ok_or_else(|| NetError::Transport(format!("no address for {}", self.addr)))?;
        let stream = TcpStream::connect_timeout(&sa, self.cfg.io_timeout)
            .map_err(|e| NetError::Transport(format!("connect {}: {e}", self.addr)))?;
        let _ = stream.set_nodelay(true);
        self.stats.connects += 1;
        self.conn = Some(stream);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_schedule_is_deterministic_and_exponential() {
        let p = RetryPolicy { max: 4, base: Duration::from_millis(10), seed: 7 };
        let q = RetryPolicy { max: 4, base: Duration::from_millis(10), seed: 7 };
        for k in 0..6 {
            // Same seed → the exact same schedule, run to run.
            assert_eq!(p.delay(k), q.delay(k), "attempt {k}");
            // base·2^k ≤ delay < base·2^k + base (jitter bounded).
            let floor = Duration::from_millis(10 * (1 << k));
            assert!(p.delay(k) >= floor, "attempt {k}: {:?} < {floor:?}", p.delay(k));
            assert!(p.delay(k) < floor + Duration::from_millis(10), "attempt {k}");
        }
        // A different seed shifts the jitter (with overwhelming
        // probability over mix64) but keeps the exponential floor.
        let r = RetryPolicy { max: 4, base: Duration::from_millis(10), seed: 8 };
        assert!((0..6).any(|k| r.delay(k) != p.delay(k)));
        // Degenerate base never panics.
        assert_eq!(RetryPolicy { max: 1, base: Duration::ZERO, seed: 1 }.delay(3), Duration::ZERO);
        // Huge attempt numbers saturate instead of overflowing.
        let _ = p.delay(u32::MAX);
    }

    /// Breaker state machine on a fake clock: synthetic `Instant`s are
    /// passed explicitly, so no test time actually elapses.
    #[test]
    fn breaker_opens_half_opens_and_recovers() {
        let cfg = BreakerConfig { threshold: 3, cooloff: Duration::from_secs(10) };
        let mut b = Breaker::new(cfg);
        let t0 = Instant::now();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow(t0));

        // Two failures: still closed (threshold is 3).
        b.on_failure(t0);
        b.on_failure(t0);
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow(t0));

        // Third consecutive failure opens it; attempts fast-fail.
        b.on_failure(t0);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow(t0));
        assert!(!b.allow(t0 + Duration::from_secs(9)), "cooloff not elapsed");

        // Cooloff elapsed: exactly one probe allowed (half-open).
        let t_probe = t0 + Duration::from_secs(10);
        assert!(b.allow(t_probe));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.allow(t_probe), "only one probe per half-open window");

        // Failed probe re-opens immediately (no threshold count).
        b.on_failure(t_probe);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow(t_probe + Duration::from_secs(9)));

        // Next probe succeeds → closed, counters reset.
        let t2 = t_probe + Duration::from_secs(10);
        assert!(b.allow(t2));
        b.on_success();
        assert_eq!(b.state(), BreakerState::Closed);
        // Two fresh failures don't re-open (count restarted).
        b.on_failure(t2);
        b.on_failure(t2);
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn breaker_success_between_failures_resets_the_count() {
        let mut b = Breaker::new(BreakerConfig { threshold: 2, cooloff: Duration::from_secs(1) });
        let t0 = Instant::now();
        for _ in 0..8 {
            b.on_failure(t0);
            b.on_success();
        }
        assert_eq!(b.state(), BreakerState::Closed, "non-consecutive failures never open");
    }

    #[test]
    fn retry_safety_classification() {
        assert!(NetError::Transport("x".into()).retry_safe());
        assert!(NetError::Overloaded.retry_safe());
        assert!(NetError::Protocol("x".into()).retry_safe());
        assert!(NetError::GoingAway.retry_safe());
        assert!(NetError::Serve(ServeError::ShardDead).retry_safe());
        // A delivered verdict is terminal: retrying could double-count.
        assert!(!NetError::Serve(ServeError::Timeout).retry_safe());
        assert!(!NetError::Serve(ServeError::Exec("boom".into())).retry_safe());
        assert!(!NetError::BadRequest("x".into()).retry_safe());
        assert!(!NetError::BreakerOpen.retry_safe());
        // Only transport failures trip the breaker.
        assert!(NetError::Transport("x".into()).is_transport());
        assert!(!NetError::Serve(ServeError::ShardDead).is_transport());
        assert!(!NetError::Overloaded.is_transport());
    }

    #[test]
    fn retry_policy_env_parsing_ignores_garbage() {
        // Pure-default path (env vars are absent in the test runner
        // unless a caller set them; don't mutate process env here).
        let p = RetryPolicy::default();
        assert_eq!(p.max, 3);
        assert_eq!(p.base, Duration::from_millis(10));
    }
}
