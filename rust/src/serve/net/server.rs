//! `TcpFront` — the network front door over a [`Server`].
//!
//! One accept loop feeds a bounded pool of connection threads; every
//! connection gets per-io timeouts, an idle reaper, and a total
//! frame-read deadline (so a slowloris peer trickling bytes can pin at
//! most its own thread, and only until the io timeout). Admission is
//! wired straight to the shard pool's backpressure: a full queue is
//! answered with a typed `Overloaded` response (retry-safe) and a full
//! connection pool with a `Busy` control frame — overload sheds at the
//! edge, it never queues unboundedly. Shutdown is a graceful drain:
//! stop accepting, tell idle connections `GoingAway`, flush in-flight
//! replies, join every thread, then drain the shard pool.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::error::{Context, Result};
use crate::obs::{Histogram, MetricsSnapshot};
use crate::serve::resilience::{lock_unpoisoned, NetChaos, ServeError};
use crate::serve::server::Server;

use super::wire::{self, Control, ReadError, RespBody, Response, WireError};

/// How often blocked loops re-check the shutdown flag.
const POLL: Duration = Duration::from_millis(10);
/// Idle-wait slice inside a connection thread: the reaper accumulates
/// these, and drain is noticed within one slice.
const IDLE_SLICE: Duration = Duration::from_millis(50);

/// Front-door configuration. Environment resolution
/// ([`TcpFrontConfig::from_env`]) reads `STOCH_IMC_TCP_PORT`,
/// `STOCH_IMC_TCP_CONN_THREADS`, `STOCH_IMC_TCP_IO_TIMEOUT_MS`, and
/// `STOCH_IMC_TCP_IDLE_MS` once at start — the accept path never
/// touches the environment.
#[derive(Debug, Clone)]
pub struct TcpFrontConfig {
    /// Bind address. Port `0` picks an ephemeral port (tests/benches);
    /// read the real one back from [`TcpFront::local_addr`].
    pub addr: String,
    /// Connection-thread pool bound: at capacity, new connections are
    /// answered `Busy` and closed instead of queued.
    pub conn_threads: usize,
    /// Per-io budget: a started frame must complete (read side) and a
    /// response must flush (write side) within this.
    pub io_timeout: Duration,
    /// Idle reaper: a connection with no frame for this long is closed.
    pub idle: Duration,
    /// Network chaos injectors (all-zero = clean serving).
    pub chaos: NetChaos,
}

impl Default for TcpFrontConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7117".into(),
            conn_threads: 16,
            io_timeout: Duration::from_secs(2),
            idle: Duration::from_secs(30),
            chaos: NetChaos::default(),
        }
    }
}

impl TcpFrontConfig {
    /// Defaults with the `STOCH_IMC_TCP_*` env overrides applied.
    pub fn from_env() -> Self {
        let mut cfg = Self::default();
        let parse_u64 = |var: &str| {
            std::env::var(var).ok().and_then(|s| match s.trim().parse::<u64>() {
                Ok(v) => Some(v),
                Err(_) => {
                    eprintln!("{var}=`{s}` is not an integer; using the default");
                    None
                }
            })
        };
        if let Some(p) = parse_u64("STOCH_IMC_TCP_PORT") {
            cfg.addr = format!("127.0.0.1:{p}");
        }
        if let Some(n) = parse_u64("STOCH_IMC_TCP_CONN_THREADS") {
            cfg.conn_threads = (n as usize).max(1);
        }
        if let Some(ms) = parse_u64("STOCH_IMC_TCP_IO_TIMEOUT_MS") {
            cfg.io_timeout = Duration::from_millis(ms.max(1));
        }
        if let Some(ms) = parse_u64("STOCH_IMC_TCP_IDLE_MS") {
            cfg.idle = Duration::from_millis(ms.max(1));
        }
        cfg
    }
}

/// Front-door counters. Every key is emitted on every snapshot (the
/// repo-wide stable-schema rule), so `stats --check` can require the
/// `serve_net_*` set whether or not the TCP path ran.
#[derive(Debug, Default)]
pub struct NetMetrics {
    /// Connections accepted (past the chaos accept-drop injector).
    pub connections: AtomicU64,
    /// Connections currently owned by a handler thread.
    pub active: AtomicU64,
    /// Connections refused with `Busy` (thread pool at capacity).
    pub busy_rejected: AtomicU64,
    /// Connections closed by the idle reaper.
    pub idle_reaped: AtomicU64,
    /// Connections killed mid-frame by the io deadline (slowloris).
    pub io_timeouts: AtomicU64,
    /// Request frames decoded.
    pub frames_rx: AtomicU64,
    /// Response frames fully written.
    pub frames_tx: AtomicU64,
    /// Malformed frames answered with a `ProtocolError` control.
    pub protocol_errors: AtomicU64,
    /// Requests shed at admission (answered `Overloaded`).
    pub shed: AtomicU64,
    /// `GoingAway` frames sent during drain.
    pub going_away: AtomicU64,
    /// Chaos: accepted-then-dropped connections.
    pub chaos_accept_drops: AtomicU64,
    /// Chaos: responses cut mid-frame.
    pub chaos_cuts: AtomicU64,
    /// Chaos: responses trickled byte-by-byte.
    pub chaos_trickles: AtomicU64,
    /// Chaos: injected pre-execution stalls.
    pub chaos_stalls: AtomicU64,
    /// Wire latency per request: decode done → response encoded, µs.
    pub wire_latency_us: Mutex<Histogram>,
}

impl NetMetrics {
    /// Flat `serve_net_*` exposition, always the full key set.
    pub fn snapshot_into(&self, out: &mut MetricsSnapshot) {
        let c = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        out.push("serve_net_connections", c(&self.connections));
        out.push("serve_net_active_connections", c(&self.active));
        out.push("serve_net_busy_rejected", c(&self.busy_rejected));
        out.push("serve_net_idle_reaped", c(&self.idle_reaped));
        out.push("serve_net_io_timeouts", c(&self.io_timeouts));
        out.push("serve_net_frames_rx", c(&self.frames_rx));
        out.push("serve_net_frames_tx", c(&self.frames_tx));
        out.push("serve_net_protocol_errors", c(&self.protocol_errors));
        out.push("serve_net_shed", c(&self.shed));
        out.push("serve_net_going_away", c(&self.going_away));
        out.push("serve_net_chaos_accept_drops", c(&self.chaos_accept_drops));
        out.push("serve_net_chaos_cuts", c(&self.chaos_cuts));
        out.push("serve_net_chaos_trickles", c(&self.chaos_trickles));
        out.push("serve_net_chaos_stalls", c(&self.chaos_stalls));
        let h = lock_unpoisoned(&self.wire_latency_us);
        out.push("serve_net_wire_latency_us_p50", h.percentile(50.0) as f64);
        out.push("serve_net_wire_latency_us_p95", h.percentile(95.0) as f64);
        out.push("serve_net_wire_latency_us_p99", h.percentile(99.0) as f64);
        out.push("serve_net_wire_latency_us_max", h.max() as f64);
    }
}

/// Everything the accept loop and connection threads share.
struct FrontShared {
    server: Arc<Server>,
    cfg: TcpFrontConfig,
    metrics: NetMetrics,
    shutdown: AtomicBool,
    /// Accept-order connection counter (chaos accept-drop cadence).
    conn_seq: AtomicU64,
    /// Processed-request counter (chaos stall cadence).
    req_seq: AtomicU64,
    /// Written-response counter (chaos cut/trickle cadence).
    resp_seq: AtomicU64,
}

/// The TCP front door: owns the listener, the accept thread, and (via
/// the accept thread) every connection thread. Dropping it shuts down
/// gracefully; [`TcpFront::shutdown`] does the same explicitly and is
/// idempotent.
pub struct TcpFront {
    shared: Arc<FrontShared>,
    local: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl TcpFront {
    /// Bind and start accepting for `server`.
    pub fn start(server: Arc<Server>, cfg: TcpFrontConfig) -> Result<Self> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind tcp front to {}", cfg.addr))?;
        listener.set_nonblocking(true).context("set listener nonblocking")?;
        let local = listener.local_addr().context("listener local addr")?;
        let shared = Arc::new(FrontShared {
            server,
            cfg,
            metrics: NetMetrics::default(),
            shutdown: AtomicBool::new(false),
            conn_seq: AtomicU64::new(0),
            req_seq: AtomicU64::new(0),
            resp_seq: AtomicU64::new(0),
        });
        let sh = Arc::clone(&shared);
        let accept = thread::Builder::new()
            .name("tcp-front-accept".into())
            .spawn(move || accept_loop(listener, &sh))
            .context("spawn accept thread")?;
        Ok(Self { shared, local, accept: Some(accept) })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Graceful drain: stop accepting, notify idle connections with
    /// `GoingAway`, let in-flight requests flush their responses, join
    /// every connection thread, then drain the shard pool. Idempotent;
    /// returns within roughly one io timeout of the slowest in-flight
    /// request.
    pub fn shutdown(&mut self) {
        if self.shared.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join(); // joins every connection thread first
        }
        let _ = self.shared.server.drain();
    }

    /// Pool + net metrics in one flat snapshot (`serve_*` and
    /// `serve_net_*` keys).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = self.shared.server.snapshot();
        self.shared.metrics.snapshot_into(&mut snap);
        snap
    }

    /// The shared server (for mixed in-process + TCP callers).
    pub fn server(&self) -> &Arc<Server> {
        &self.shared.server
    }
}

impl Drop for TcpFront {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, sh: &Arc<FrontShared>) {
    let mut handles: Vec<JoinHandle<()>> = Vec::new();
    while !sh.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                handles.retain(|h| !h.is_finished());
                let n = sh.conn_seq.fetch_add(1, Ordering::SeqCst) + 1;
                let chaos = sh.cfg.chaos;
                if chaos.accept_drop_every > 0 && n % chaos.accept_drop_every == 0 {
                    // Accept-then-drop injector: the peer sees a
                    // successful connect followed by an abrupt close.
                    sh.metrics.chaos_accept_drops.fetch_add(1, Ordering::Relaxed);
                    drop(stream);
                    continue;
                }
                sh.metrics.connections.fetch_add(1, Ordering::Relaxed);
                if sh.metrics.active.load(Ordering::SeqCst) >= sh.cfg.conn_threads as u64 {
                    reject_busy(stream, sh);
                    continue;
                }
                sh.metrics.active.fetch_add(1, Ordering::SeqCst);
                let sh2 = Arc::clone(sh);
                let spawned = thread::Builder::new()
                    .name(format!("tcp-front-conn-{n}"))
                    .spawn(move || {
                        handle_conn(stream, &sh2);
                        sh2.metrics.active.fetch_sub(1, Ordering::SeqCst);
                    });
                match spawned {
                    Ok(h) => handles.push(h),
                    Err(_) => {
                        sh.metrics.active.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => thread::sleep(POLL),
            Err(_) => thread::sleep(POLL),
        }
    }
    // Drain: every handler notices the flag within one idle slice (or
    // finishes its in-flight request first) and exits; join them all
    // so shutdown() returning means zero threads remain.
    for h in handles {
        let _ = h.join();
    }
}

fn reject_busy(stream: TcpStream, sh: &Arc<FrontShared>) {
    sh.metrics.busy_rejected.fetch_add(1, Ordering::Relaxed);
    let mut stream = stream;
    let _ = stream.set_nonblocking(false);
    let _ = wire::write_frame(
        &mut stream,
        &wire::encode_control(&Control::Busy),
        Duration::from_millis(200),
    );
}

/// Answer a malformed frame with a typed protocol error, then close.
fn protocol_reject(stream: &mut TcpStream, sh: &Arc<FrontShared>, err: &WireError) {
    sh.metrics.protocol_errors.fetch_add(1, Ordering::Relaxed);
    let ctrl = Control::ProtocolError(err.to_string());
    let _ = wire::write_frame(stream, &wire::encode_control(&ctrl), sh.cfg.io_timeout);
}

fn handle_conn(mut stream: TcpStream, sh: &Arc<FrontShared>) {
    // Accepted sockets may inherit the listener's nonblocking flag on
    // some platforms; the handler runs on blocking io + timeouts.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let mut idle = Duration::ZERO;
    loop {
        if sh.shutdown.load(Ordering::SeqCst) {
            sh.metrics.going_away.fetch_add(1, Ordering::Relaxed);
            let _ = wire::write_frame(
                &mut stream,
                &wire::encode_control(&Control::GoingAway),
                sh.cfg.io_timeout,
            );
            return;
        }
        match wire::read_frame(&mut stream, IDLE_SLICE.min(sh.cfg.idle), sh.cfg.io_timeout) {
            Ok((wire::KIND_REQUEST, payload)) => {
                idle = Duration::ZERO;
                match wire::decode_request(&payload) {
                    Ok(req) => {
                        if !handle_request(&mut stream, sh, req) {
                            return;
                        }
                    }
                    Err(e) => {
                        protocol_reject(&mut stream, sh, &e);
                        return;
                    }
                }
            }
            Ok((_, _)) => {
                // Clients have no business sending responses/controls.
                protocol_reject(&mut stream, sh, &WireError::Malformed("unexpected frame kind"));
                return;
            }
            Err(ReadError::Idle) => {
                idle += IDLE_SLICE;
                if idle >= sh.cfg.idle {
                    sh.metrics.idle_reaped.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            }
            Err(ReadError::Closed) => return,
            Err(ReadError::Stalled) => {
                // The slowloris kill: a frame that started but did not
                // finish within the io budget.
                sh.metrics.io_timeouts.fetch_add(1, Ordering::Relaxed);
                return;
            }
            Err(ReadError::Io(_)) => return,
            Err(ReadError::Wire(e)) => {
                protocol_reject(&mut stream, sh, &e);
                return;
            }
        }
    }
}

/// Serve one decoded request; returns whether the connection stays
/// alive for the next frame.
fn handle_request(stream: &mut TcpStream, sh: &Arc<FrontShared>, req: wire::Request) -> bool {
    sh.metrics.frames_rx.fetch_add(1, Ordering::Relaxed);
    let chaos = sh.cfg.chaos;
    if chaos.stall_read_every > 0 {
        let n = sh.req_seq.fetch_add(1, Ordering::SeqCst) + 1;
        if n % chaos.stall_read_every == 0 && !chaos.stall.is_zero() {
            // Stalled-read injector: the server sits on a decoded
            // request, exercising client deadlines/timeouts.
            sh.metrics.chaos_stalls.fetch_add(1, Ordering::Relaxed);
            thread::sleep(chaos.stall);
        }
    }
    let t0 = Instant::now();
    let budget = (req.deadline_budget_us > 0)
        .then(|| Duration::from_micros(req.deadline_budget_us));
    let body = match sh.server.submit_shedding(&req.app, &req.inputs, budget) {
        Err(e) => RespBody::BadRequest(e.to_string()),
        Ok(None) => {
            // Pool backpressure surfaces as a typed, retry-safe shed.
            sh.metrics.shed.fetch_add(1, Ordering::Relaxed);
            RespBody::Overloaded
        }
        Ok(Some(rx)) => {
            // The shard answers every admitted request (the PR 9
            // exactly-once contract); the extra io budget only guards
            // against a wedged executor leaking this thread.
            let wait = budget.map_or(sh.cfg.io_timeout, |b| b + sh.cfg.io_timeout);
            match rx.recv_timeout(wait) {
                Ok(Ok(v)) => RespBody::Value(v),
                Ok(Err(e)) => RespBody::Err(e),
                Err(_) => RespBody::Err(ServeError::Exec(
                    "front door: reply wait exceeded".into(),
                )),
            }
        }
    };
    lock_unpoisoned(&sh.metrics.wire_latency_us)
        .record(t0.elapsed().as_micros().min(u64::MAX as u128) as u64);
    let frame = wire::encode_response(&Response { id: req.id, body });
    write_response(stream, sh, &frame)
}

/// Write a response frame, applying the mid-frame-cut and byte-trickle
/// chaos injectors on their cadences.
fn write_response(stream: &mut TcpStream, sh: &Arc<FrontShared>, frame: &[u8]) -> bool {
    let chaos = sh.cfg.chaos;
    let n = sh.resp_seq.fetch_add(1, Ordering::SeqCst) + 1;
    if chaos.cut_every > 0 && n % chaos.cut_every == 0 {
        // Mid-frame disconnect: half a response, then a hard close.
        sh.metrics.chaos_cuts.fetch_add(1, Ordering::Relaxed);
        let half = frame.len() / 2;
        let _ = wire::write_frame(stream, &frame[..half], sh.cfg.io_timeout);
        let _ = stream.shutdown(Shutdown::Both);
        return false;
    }
    if chaos.trickle_every > 0 && n % chaos.trickle_every == 0 {
        // Byte-trickle slow write: the frame arrives, eventually. The
        // client's total-frame read deadline decides whether that is
        // tolerable; other connections are unaffected (thread-per-
        // connection, no shared writer).
        sh.metrics.chaos_trickles.fetch_add(1, Ordering::Relaxed);
        for b in frame {
            if wire::write_frame(stream, std::slice::from_ref(b), sh.cfg.io_timeout).is_err() {
                return false;
            }
            if !chaos.trickle_delay.is_zero() {
                thread::sleep(chaos.trickle_delay);
            }
        }
        sh.metrics.frames_tx.fetch_add(1, Ordering::Relaxed);
        return true;
    }
    match wire::write_frame(stream, frame, sh.cfg.io_timeout) {
        Ok(()) => {
            sh.metrics.frames_tx.fetch_add(1, Ordering::Relaxed);
            true
        }
        Err(_) => {
            sh.metrics.io_timeouts.fetch_add(1, Ordering::Relaxed);
            false
        }
    }
}
