//! The TCP front door: the serving layer's resilience contract,
//! carried across a wire.
//!
//! PR 9's guarantees stop at the crate boundary — `Reply` is typed,
//! shards are supervised, deadlines are enforced, but only for
//! in-process callers. This module puts a std-only (zero external
//! deps) TCP transport in front of [`Server`](crate::serve::Server)
//! without weakening any of it:
//!
//! * [`wire`] — the length-prefixed binary protocol. Versioned 8-byte
//!   header, bounded strict decoding (no allocation sized by untrusted
//!   bytes, exact-consume payloads), and a response body that carries
//!   the full `ServeError` taxonomy plus the wire-level outcomes
//!   (`Overloaded`, `BadRequest`). Deadlines travel as remaining
//!   *budgets* (µs), re-anchored server-side — no clock sync needed.
//! * [`server`] — [`TcpFront`]: accept loop → bounded connection-
//!   thread pool, per-connection io timeouts, idle reaper, total
//!   frame-read deadlines (slowloris defense), admission wired to
//!   shard backpressure (typed `Overloaded` sheds), graceful
//!   signal-aware drain with `GoingAway` frames.
//! * [`client`] — [`Client`]: connection reuse, wire-propagated
//!   deadlines, exponential backoff with deterministic seeded jitter,
//!   idempotent-safe-only retries, and a per-target circuit breaker.
//!
//! The network failure modes get the same treatment executor panics
//! got: deterministic injectors
//! ([`NetChaos`](crate::serve::resilience::NetChaos)) for
//! accept-then-drop, mid-frame cuts, byte trickles, and stalled
//! reads, with `tests/net_chaos.rs` pinning exactly-one-terminal-
//! outcome per request, no-fault bit-identity with in-process
//! `submit`, slow-peer isolation, and drain leaving zero wedged
//! threads. See ARCHITECTURE.md "Network front door".

pub mod client;
pub mod server;
pub mod wire;

pub use client::{
    Breaker, BreakerConfig, BreakerState, Client, ClientConfig, ClientStats, NetError, RetryPolicy,
};
pub use server::{NetMetrics, TcpFront, TcpFrontConfig};
