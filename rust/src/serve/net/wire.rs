//! The length-prefixed binary wire protocol for the TCP front door.
//!
//! Every frame is an 8-byte header followed by a bounded payload:
//!
//! ```text
//! offset  size  field
//! 0       2     magic  b"SC"
//! 2       1     version (currently 1)
//! 3       1     kind    (1 = request, 2 = response, 3 = control)
//! 4       4     payload length, u32 LE, <= MAX_PAYLOAD
//! ```
//!
//! Decoding is strict and bounded: the payload length is validated
//! against [`MAX_PAYLOAD`] *before* any allocation, every inner length
//! (app name, input count, message) has its own cap, payloads must be
//! consumed exactly (trailing bytes are an error), and every malformed
//! shape maps to a typed [`WireError`] — never a panic, never a hang,
//! never an allocation sized by untrusted bytes. The response body
//! carries the full [`ServeError`] taxonomy plus the two wire-level
//! outcomes (`Overloaded` admission shed, `BadRequest` validation), so
//! the in-process resilience contract survives the hop.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::serve::resilience::ServeError;

/// Frame magic: the first two bytes of every frame.
pub const MAGIC: [u8; 2] = *b"SC";
/// Current protocol version. Unknown versions are rejected with
/// [`WireError::UnknownVersion`] so a future v2 can change anything
/// after the 4-byte prefix.
pub const VERSION: u8 = 1;
/// Fixed frame-header size.
pub const HEADER_LEN: usize = 8;
/// Hard cap on a frame payload. Anything larger is rejected from the
/// header alone ([`WireError::Oversized`]) — the bytes are never read,
/// let alone allocated.
pub const MAX_PAYLOAD: usize = 4096;
/// Cap on the app-name length inside a request.
pub const MAX_APP_LEN: usize = 128;
/// Cap on the input count inside a request.
pub const MAX_INPUTS: usize = 256;
/// Cap on any error/control message carried on the wire; longer
/// messages are truncated at encode time (on a char boundary).
pub const MAX_MSG_LEN: usize = 512;

/// Frame kinds (the `kind` header byte).
pub const KIND_REQUEST: u8 = 1;
pub const KIND_RESPONSE: u8 = 2;
pub const KIND_CONTROL: u8 = 3;

/// A typed decode failure. Every variant is answered by the server
/// with a `Control::ProtocolError` frame and a close — malformed input
/// terminates the connection, not the process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before the fields it promised (truncated
    /// header, or a payload shorter than its inner lengths claim).
    Truncated,
    /// The first two bytes were not [`MAGIC`].
    BadMagic([u8; 2]),
    /// A version byte this decoder does not speak.
    UnknownVersion(u8),
    /// A kind byte outside the known set.
    UnknownKind(u8),
    /// Header declared a payload longer than [`MAX_PAYLOAD`].
    Oversized(u32),
    /// Structurally valid lengths but semantically invalid content.
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            WireError::UnknownVersion(v) => write!(f, "unknown protocol version {v}"),
            WireError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::Oversized(n) => {
                write!(f, "payload length {n} exceeds cap {MAX_PAYLOAD}")
            }
            WireError::Malformed(what) => write!(f, "malformed frame: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

/// One client request: compute `app(inputs)` under an optional
/// deadline budget, echo `id` on the response.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Client-chosen correlation id, echoed verbatim on the response.
    /// A fresh id per attempt lets the client detect stale responses.
    pub id: u64,
    /// Remaining deadline budget in microseconds at send time; `0` =
    /// no deadline. The server re-anchors it on arrival (one-way
    /// budget, not a wall-clock timestamp, so clock skew is harmless).
    pub deadline_budget_us: u64,
    pub app: String,
    pub inputs: Vec<f64>,
}

/// The terminal outcome of one request, as carried on the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum RespBody {
    /// The computed value (status 0).
    Value(f32),
    /// A serve-layer error, variant-preserved (status 1–3).
    Err(ServeError),
    /// Admission shed: the shard's queue was full. Retry-safe — the
    /// request was never enqueued (status 4).
    Overloaded,
    /// Request validation failed (unknown app, arity mismatch). Not
    /// retry-safe: resending the same bytes cannot succeed (status 5).
    BadRequest(String),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub body: RespBody,
}

/// Out-of-band connection-scoped signals.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Control {
    /// Server is draining; the connection closes after this frame.
    GoingAway,
    /// Connection-thread pool is full; the connection closes after
    /// this frame. Retry-safe (nothing was admitted).
    Busy,
    /// The peer sent a malformed frame; the connection closes after
    /// this frame.
    ProtocolError(String),
}

const CTRL_GOING_AWAY: u8 = 1;
const CTRL_BUSY: u8 = 2;
const CTRL_PROTOCOL_ERROR: u8 = 3;

const STATUS_OK: u8 = 0;
const STATUS_TIMEOUT: u8 = 1;
const STATUS_SHARD_DEAD: u8 = 2;
const STATUS_EXEC: u8 = 3;
const STATUS_OVERLOADED: u8 = 4;
const STATUS_BAD_REQUEST: u8 = 5;

/// Truncate a message to [`MAX_MSG_LEN`] bytes on a char boundary so
/// arbitrarily long engine errors can't bloat (or break) a frame.
fn clip(msg: &str) -> &str {
    if msg.len() <= MAX_MSG_LEN {
        return msg;
    }
    let mut end = MAX_MSG_LEN;
    while end > 0 && !msg.is_char_boundary(end) {
        end -= 1;
    }
    &msg[..end]
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    let s = clip(s);
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn frame(kind: u8, payload: Vec<u8>) -> Vec<u8> {
    debug_assert!(payload.len() <= MAX_PAYLOAD);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Encode a request as a complete frame (header included).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut p = Vec::with_capacity(16 + req.app.len() + 8 * req.inputs.len() + 8);
    p.extend_from_slice(&req.id.to_le_bytes());
    p.extend_from_slice(&req.deadline_budget_us.to_le_bytes());
    put_str(&mut p, &req.app);
    p.extend_from_slice(&(req.inputs.len() as u16).to_le_bytes());
    for v in &req.inputs {
        p.extend_from_slice(&v.to_le_bytes());
    }
    frame(KIND_REQUEST, p)
}

/// Encode a response as a complete frame (header included).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut p = Vec::with_capacity(32);
    p.extend_from_slice(&resp.id.to_le_bytes());
    match &resp.body {
        RespBody::Value(v) => {
            p.push(STATUS_OK);
            p.extend_from_slice(&v.to_le_bytes());
        }
        RespBody::Err(ServeError::Timeout) => p.push(STATUS_TIMEOUT),
        RespBody::Err(ServeError::ShardDead) => p.push(STATUS_SHARD_DEAD),
        RespBody::Err(ServeError::Exec(msg)) => {
            p.push(STATUS_EXEC);
            put_str(&mut p, msg);
        }
        RespBody::Overloaded => p.push(STATUS_OVERLOADED),
        RespBody::BadRequest(msg) => {
            p.push(STATUS_BAD_REQUEST);
            put_str(&mut p, msg);
        }
    }
    frame(KIND_RESPONSE, p)
}

/// Encode a control frame (header included).
pub fn encode_control(ctrl: &Control) -> Vec<u8> {
    let mut p = Vec::with_capacity(8);
    match ctrl {
        Control::GoingAway => {
            p.push(CTRL_GOING_AWAY);
            put_str(&mut p, "");
        }
        Control::Busy => {
            p.push(CTRL_BUSY);
            put_str(&mut p, "");
        }
        Control::ProtocolError(msg) => {
            p.push(CTRL_PROTOCOL_ERROR);
            put_str(&mut p, msg);
        }
    }
    frame(KIND_CONTROL, p)
}

/// Validate a frame header; returns `(kind, payload_len)`. The length
/// is checked against [`MAX_PAYLOAD`] here, before any payload byte is
/// read — an attacker-controlled length can reject a frame but can
/// never size an allocation.
pub fn decode_header(h: &[u8; HEADER_LEN]) -> Result<(u8, usize), WireError> {
    if h[0..2] != MAGIC {
        return Err(WireError::BadMagic([h[0], h[1]]));
    }
    if h[2] != VERSION {
        return Err(WireError::UnknownVersion(h[2]));
    }
    let kind = h[3];
    if !(KIND_REQUEST..=KIND_CONTROL).contains(&kind) {
        return Err(WireError::UnknownKind(kind));
    }
    let len = u32::from_le_bytes([h[4], h[5], h[6], h[7]]);
    if len as usize > MAX_PAYLOAD {
        return Err(WireError::Oversized(len));
    }
    Ok((kind, len as usize))
}

/// Bounds-checked payload cursor: every read is validated against the
/// remaining slice, so a lying inner length yields [`WireError::Truncated`]
/// instead of a panic.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::Truncated)?;
        if end > self.buf.len() {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes(b.try_into().expect("4-byte slice")))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    /// A length-prefixed UTF-8 string, bounded by `cap`.
    fn str(&mut self, cap: usize) -> Result<String, WireError> {
        let len = self.u16()? as usize;
        if len > cap {
            return Err(WireError::Malformed("string field exceeds cap"));
        }
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::Malformed("string not UTF-8"))
    }

    /// Every payload must be consumed exactly; trailing bytes mean the
    /// peer and we disagree about the schema.
    fn finish(self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Malformed("trailing bytes after payload"));
        }
        Ok(())
    }
}

/// Decode a request payload (the bytes after the header).
pub fn decode_request(payload: &[u8]) -> Result<Request, WireError> {
    let mut r = Reader::new(payload);
    let id = r.u64()?;
    let deadline_budget_us = r.u64()?;
    let app = r.str(MAX_APP_LEN)?;
    if app.is_empty() {
        return Err(WireError::Malformed("empty app name"));
    }
    let n = r.u16()? as usize;
    if n > MAX_INPUTS {
        return Err(WireError::Malformed("input count exceeds cap"));
    }
    // `n` was validated against MAX_INPUTS above, so this allocation is
    // bounded regardless of what the peer claimed.
    let mut inputs = Vec::with_capacity(n);
    for _ in 0..n {
        inputs.push(r.f64()?);
    }
    r.finish()?;
    Ok(Request { id, deadline_budget_us, app, inputs })
}

/// Decode a response payload (the bytes after the header).
pub fn decode_response(payload: &[u8]) -> Result<Response, WireError> {
    let mut r = Reader::new(payload);
    let id = r.u64()?;
    let body = match r.u8()? {
        STATUS_OK => RespBody::Value(r.f32()?),
        STATUS_TIMEOUT => RespBody::Err(ServeError::Timeout),
        STATUS_SHARD_DEAD => RespBody::Err(ServeError::ShardDead),
        STATUS_EXEC => RespBody::Err(ServeError::Exec(r.str(MAX_MSG_LEN)?)),
        STATUS_OVERLOADED => RespBody::Overloaded,
        STATUS_BAD_REQUEST => RespBody::BadRequest(r.str(MAX_MSG_LEN)?),
        _ => return Err(WireError::Malformed("unknown response status")),
    };
    r.finish()?;
    Ok(Response { id, body })
}

/// Decode a control payload (the bytes after the header).
pub fn decode_control(payload: &[u8]) -> Result<Control, WireError> {
    let mut r = Reader::new(payload);
    let code = r.u8()?;
    let msg = r.str(MAX_MSG_LEN)?;
    r.finish()?;
    match code {
        CTRL_GOING_AWAY => Ok(Control::GoingAway),
        CTRL_BUSY => Ok(Control::Busy),
        CTRL_PROTOCOL_ERROR => Ok(Control::ProtocolError(msg)),
        _ => Err(WireError::Malformed("unknown control code")),
    }
}

/// Decode one complete frame from a byte buffer; returns
/// `(kind, payload)`. Test/offline convenience over the same strict
/// path the streaming reader uses.
pub fn decode_frame_bytes(buf: &[u8]) -> Result<(u8, &[u8]), WireError> {
    if buf.len() < HEADER_LEN {
        return Err(WireError::Truncated);
    }
    let hdr: [u8; HEADER_LEN] = buf[..HEADER_LEN].try_into().expect("8-byte header");
    let (kind, len) = decode_header(&hdr)?;
    let payload = buf.get(HEADER_LEN..HEADER_LEN + len).ok_or(WireError::Truncated)?;
    if buf.len() > HEADER_LEN + len {
        return Err(WireError::Malformed("trailing bytes after frame"));
    }
    Ok((kind, payload))
}

/// How a framed read terminated without a frame.
#[derive(Debug)]
pub enum ReadError {
    /// No first byte arrived within the idle window. Not an error for
    /// a server (the connection is just quiet); a deadline for a
    /// client awaiting a response.
    Idle,
    /// The first byte arrived but the rest of the frame did not within
    /// the total io budget — a trickling or stalled peer. The
    /// connection should be closed.
    Stalled,
    /// The peer closed the connection cleanly at a frame boundary.
    Closed,
    /// A transport-level error.
    Io(std::io::Error),
    /// The header or payload failed validation.
    Wire(WireError),
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::Idle => write!(f, "no frame within the idle window"),
            ReadError::Stalled => write!(f, "frame stalled mid-read (io timeout)"),
            ReadError::Closed => write!(f, "connection closed"),
            ReadError::Io(e) => write!(f, "io error: {e}"),
            ReadError::Wire(e) => write!(f, "{e}"),
        }
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Fill `buf` completely, failing with [`ReadError::Stalled`] once
/// `deadline` passes. The deadline is absolute: a peer trickling one
/// byte per timeout window still cannot hold the read open past it —
/// that is the slowloris defense.
fn read_exact_deadline(
    stream: &mut TcpStream,
    buf: &mut [u8],
    deadline: Instant,
) -> Result<(), ReadError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(ReadError::Stalled);
        }
        stream
            .set_read_timeout(Some(remaining.max(Duration::from_millis(1))))
            .map_err(ReadError::Io)?;
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return Err(ReadError::Wire(WireError::Truncated)),
            Ok(n) => filled += n,
            Err(e) if is_timeout(&e) => return Err(ReadError::Stalled),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(ReadError::Io(e)),
        }
    }
    Ok(())
}

/// Read one frame: wait up to `first_byte_wait` for the frame to
/// start, then require the whole frame within `io_timeout` of the
/// first byte. Returns `(kind, payload)`.
///
/// * A quiet connection yields [`ReadError::Idle`] after
///   `first_byte_wait` — callers slice this to poll shutdown flags and
///   accumulate idle time for the reaper.
/// * A clean EOF at a frame boundary yields [`ReadError::Closed`];
///   EOF mid-frame is [`WireError::Truncated`].
/// * A started-but-unfinished frame yields [`ReadError::Stalled`] once
///   the total budget expires, no matter how steadily the peer
///   trickles bytes.
pub fn read_frame(
    stream: &mut TcpStream,
    first_byte_wait: Duration,
    io_timeout: Duration,
) -> Result<(u8, Vec<u8>), ReadError> {
    let mut hdr = [0u8; HEADER_LEN];
    stream
        .set_read_timeout(Some(first_byte_wait.max(Duration::from_millis(1))))
        .map_err(ReadError::Io)?;
    let got = loop {
        match stream.read(&mut hdr) {
            Ok(0) => return Err(ReadError::Closed),
            Ok(n) => break n,
            Err(e) if is_timeout(&e) => return Err(ReadError::Idle),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(ReadError::Io(e)),
        }
    };
    // The frame has started: everything else must land within the
    // total io budget measured from here.
    let deadline = Instant::now() + io_timeout;
    read_exact_deadline(stream, &mut hdr[got..], deadline)?;
    let (kind, len) = decode_header(&hdr).map_err(ReadError::Wire)?;
    // `len` ≤ MAX_PAYLOAD (validated in decode_header): bounded alloc.
    let mut payload = vec![0u8; len];
    if len > 0 {
        read_exact_deadline(stream, &mut payload, deadline)?;
    }
    Ok((kind, payload))
}

/// Write a complete frame under a write timeout. Frames are tiny
/// (≤ [`MAX_PAYLOAD`] + header) so a healthy peer's socket buffer
/// absorbs them instantly; a peer that stops reading trips the timeout
/// and the connection is closed.
pub fn write_frame(
    stream: &mut TcpStream,
    bytes: &[u8],
    io_timeout: Duration,
) -> std::io::Result<()> {
    stream.set_write_timeout(Some(io_timeout.max(Duration::from_millis(1))))?;
    stream.write_all(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: &Request) -> Request {
        let frame = encode_request(req);
        let (kind, payload) = decode_frame_bytes(&frame).expect("decode");
        assert_eq!(kind, KIND_REQUEST);
        decode_request(payload).expect("request")
    }

    fn roundtrip_response(resp: &Response) -> Response {
        let frame = encode_response(resp);
        let (kind, payload) = decode_frame_bytes(&frame).expect("decode");
        assert_eq!(kind, KIND_RESPONSE);
        decode_response(payload).expect("response")
    }

    #[test]
    fn request_roundtrip_preserves_everything() {
        let req = Request {
            id: 0xDEAD_BEEF_0BAD_F00D,
            deadline_budget_us: 250_000,
            app: "op_multiply".into(),
            inputs: vec![0.25, -0.5, 1.0, 0.0, f64::MIN_POSITIVE],
        };
        assert_eq!(roundtrip_request(&req), req);
        // No deadline and a single input also survive.
        let req = Request { id: 0, deadline_budget_us: 0, app: "x".into(), inputs: vec![0.9] };
        assert_eq!(roundtrip_request(&req), req);
    }

    #[test]
    fn response_roundtrip_over_all_serve_error_variants() {
        // The satellite's property test: every ServeError variant (plus
        // the wire-only outcomes) survives encode→decode, including
        // messages with quotes, newlines, and non-ASCII content.
        let msgs = ["boom", "line1\nline2\t\"quoted\"", "úñíçødé ≤≥ 🦀", "", "x"];
        let mut bodies = vec![
            RespBody::Value(0.4375),
            RespBody::Value(-0.0),
            RespBody::Err(ServeError::Timeout),
            RespBody::Err(ServeError::ShardDead),
            RespBody::Overloaded,
        ];
        for m in msgs {
            bodies.push(RespBody::Err(ServeError::Exec(m.to_string())));
            bodies.push(RespBody::BadRequest(m.to_string()));
        }
        for (i, body) in bodies.into_iter().enumerate() {
            let resp = Response { id: i as u64 * 7 + 1, body };
            assert_eq!(roundtrip_response(&resp), resp, "variant {i}");
        }
        // f32 bit patterns are preserved exactly (the bit-identity
        // invariant rides on this).
        let v = f32::from_bits(0x7F7F_FFFF); // f32::MAX's exact bit pattern
        let got = roundtrip_response(&Response { id: 9, body: RespBody::Value(v) });
        match got.body {
            RespBody::Value(g) => assert_eq!(g.to_bits(), v.to_bits()),
            other => panic!("expected value, got {other:?}"),
        }
    }

    #[test]
    fn control_roundtrip_all_codes() {
        for ctrl in [
            Control::GoingAway,
            Control::Busy,
            Control::ProtocolError("bad frame".into()),
        ] {
            let frame = encode_control(&ctrl);
            let (kind, payload) = decode_frame_bytes(&frame).expect("decode");
            assert_eq!(kind, KIND_CONTROL);
            assert_eq!(decode_control(payload).expect("control"), ctrl);
        }
    }

    #[test]
    fn oversized_messages_are_clipped_not_rejected() {
        let long = "é".repeat(MAX_MSG_LEN); // 2 bytes per char
        let resp = Response { id: 1, body: RespBody::Err(ServeError::Exec(long)) };
        let got = roundtrip_response(&resp);
        match got.body {
            RespBody::Err(ServeError::Exec(m)) => {
                assert!(m.len() <= MAX_MSG_LEN);
                assert!(!m.is_empty());
                assert!(m.chars().all(|c| c == 'é'), "clip landed on a char boundary");
            }
            other => panic!("expected exec error, got {other:?}"),
        }
    }

    /// The satellite's malformed-frame table: every row is a byte
    /// mutation and the exact typed error it must produce. None may
    /// panic, hang, or allocate from the corrupt length.
    #[test]
    fn malformed_frame_table() {
        let good = encode_request(&Request {
            id: 42,
            deadline_budget_us: 1000,
            app: "op_multiply".into(),
            inputs: vec![0.25, 0.75],
        });

        // -- Header-level rejections --------------------------------
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert_eq!(decode_frame_bytes(&bad_magic), Err(WireError::BadMagic([b'X', b'C'])));

        let mut bad_version = good.clone();
        bad_version[2] = 9;
        assert_eq!(decode_frame_bytes(&bad_version), Err(WireError::UnknownVersion(9)));

        let mut bad_kind = good.clone();
        bad_kind[3] = 7;
        assert_eq!(decode_frame_bytes(&bad_kind), Err(WireError::UnknownKind(7)));

        // Truncated header: fewer than 8 bytes can never be a frame.
        for n in 0..HEADER_LEN {
            assert_eq!(decode_frame_bytes(&good[..n]), Err(WireError::Truncated), "len {n}");
        }

        // Length > cap is rejected from the header alone — the payload
        // is untouched, so no allocation is sized by the bad length.
        let mut oversized = good.clone();
        oversized[4..8].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        assert_eq!(
            decode_frame_bytes(&oversized),
            Err(WireError::Oversized(MAX_PAYLOAD as u32 + 1))
        );

        // A header promising more payload than the buffer holds.
        let mut hungry = good.clone();
        let claimed = (good.len() - HEADER_LEN + 9) as u32;
        hungry[4..8].copy_from_slice(&claimed.to_le_bytes());
        assert_eq!(decode_frame_bytes(&hungry), Err(WireError::Truncated));

        // Trailing garbage after a complete frame.
        let mut trailing = good.clone();
        trailing.push(0xAA);
        assert_eq!(
            decode_frame_bytes(&trailing),
            Err(WireError::Malformed("trailing bytes after frame"))
        );

        // -- Payload-level rejections -------------------------------
        let payload = |frame: &[u8]| frame[HEADER_LEN..].to_vec();

        // Truncated payload: cut at every single boundary; each must be
        // a typed error, never a panic.
        let p = payload(&good);
        for cut in 0..p.len() {
            let err = decode_request(&p[..cut]).expect_err("cut payload must fail");
            assert!(
                matches!(err, WireError::Truncated | WireError::Malformed(_)),
                "cut {cut}: {err:?}"
            );
        }

        // Zero-length app name.
        let empty_app = payload(&encode_request(&Request {
            id: 1,
            deadline_budget_us: 0,
            app: String::new(),
            inputs: vec![],
        }));
        assert_eq!(decode_request(&empty_app), Err(WireError::Malformed("empty app name")));

        // App-name length beyond its cap.
        let mut big_app = p.clone();
        big_app[16..18].copy_from_slice(&(MAX_APP_LEN as u16 + 1).to_le_bytes());
        assert_eq!(
            decode_request(&big_app),
            Err(WireError::Malformed("string field exceeds cap"))
        );

        // Input count beyond its cap (bounded alloc guard).
        let mut big_n = p.clone();
        let n_off = 16 + 2 + "op_multiply".len();
        big_n[n_off..n_off + 2].copy_from_slice(&(MAX_INPUTS as u16 + 1).to_le_bytes());
        assert_eq!(
            decode_request(&big_n),
            Err(WireError::Malformed("input count exceeds cap"))
        );

        // Non-UTF-8 app name.
        let mut bad_utf8 = p.clone();
        bad_utf8[18] = 0xFF;
        assert_eq!(decode_request(&bad_utf8), Err(WireError::Malformed("string not UTF-8")));

        // Trailing bytes inside the payload.
        let mut inner_trailing = p.clone();
        inner_trailing.push(0);
        assert_eq!(
            decode_request(&inner_trailing),
            Err(WireError::Malformed("trailing bytes after payload"))
        );

        // Unknown response status byte.
        let mut resp = payload(&encode_response(&Response {
            id: 3,
            body: RespBody::Overloaded,
        }));
        resp[8] = 200;
        assert_eq!(decode_response(&resp), Err(WireError::Malformed("unknown response status")));

        // Unknown control code.
        let mut ctrl = payload(&encode_control(&Control::Busy));
        ctrl[0] = 200;
        assert_eq!(decode_control(&ctrl), Err(WireError::Malformed("unknown control code")));
    }

    #[test]
    fn wire_error_display_is_descriptive() {
        assert!(WireError::Oversized(1 << 30).to_string().contains("cap"));
        assert!(WireError::UnknownVersion(9).to_string().contains('9'));
        assert!(WireError::Malformed("empty app name").to_string().contains("empty app name"));
    }
}
