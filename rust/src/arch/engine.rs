//! Stoch-IMC execution cost engine (§4.3): maps a scheduled stochastic
//! circuit onto the [n, m] architecture and accounts cycles, energy,
//! area, and wear for a workload of W instances × BL bits.
//!
//! Capacity model (two parallelism levels, as the paper's OL example):
//!   * lanes/subarray   = min(subarray_rows, BL) bit lanes (Algorithm 1's
//!     q, the rows of the replicated circuit);
//!   * batch/subarray   = ⌊subarray_cols / circuit_cols⌋ independent
//!     instances side by side;
//!   * the bank's n×m subarrays process waves of (instance, sub-stream)
//!     units; Pipeline reuses the bank across waves, Parallel multiplies
//!     banks (area) to cut waves (§4.3 trade-off).
//!
//! Each wave costs the schedule's total cycles (preset lead-in + input
//! init + logic); each produced result costs one grouped accumulation
//! (n+m steps) for StoB.

use crate::config::{ArchConfig, Policy};
use crate::energy::{computation_energy, EnergyBreakdown, EnergyParams};
use crate::lifetime::WearProfile;
use crate::scheduler::schedule::Schedule;

/// Cost summary of a run (one workload on one method).
#[derive(Debug, Clone, PartialEq)]
pub struct RunCost {
    /// Total sequential cycles (the paper's "total time steps").
    pub cycles: u64,
    /// Computation-only cycles (no StoB accumulation) — Table 2 reports
    /// "the computation part" (§5.2).
    pub comp_cycles: u64,
    pub energy: EnergyBreakdown,
    /// Cells used per subarray-instance (area metric = used cells).
    pub used_cells: u64,
    /// Minimum subarray footprint of one replicated instance.
    pub min_subarray: (usize, usize),
    pub wear: WearProfile,
    /// Waves executed (bank reuses under the pipeline policy).
    pub waves: u64,
    /// Banks needed (>1 only under the parallel policy).
    pub banks_used: u64,
}

/// Cost one stochastic workload: `sched` is the Algorithm 1 schedule of
/// the circuit replicated over `lanes` rows; `instances` is W; the
/// bitstream length comes from `cfg`.
pub fn run_stochastic(
    cfg: &ArchConfig,
    energy: &EnergyParams,
    sched: &Schedule,
    lanes: usize,
    circuit_cols: usize,
    instances: u64,
) -> RunCost {
    let bl = cfg.bitstream_len as u64;
    assert!(lanes <= cfg.subarray_rows, "lanes exceed subarray rows");
    assert!(
        circuit_cols <= cfg.subarray_cols,
        "circuit wider than subarray ({circuit_cols} > {}); partition first",
        cfg.subarray_cols
    );

    // Units of work: one unit = one instance × one `lanes`-bit sub-stream.
    let substreams = bl.div_ceil(lanes as u64);
    let units = instances * substreams;

    // Per-wave capacity.
    let batch = (cfg.subarray_cols / circuit_cols).max(1) as u64;
    let per_subarray = batch; // one unit's lanes occupy the rows
    let per_bank = per_subarray * cfg.total_subarrays() as u64;

    let (waves, banks_used) = match cfg.policy {
        Policy::Pipeline => (units.div_ceil(per_bank), 1),
        Policy::Parallel => {
            let banks = units.div_ceil(per_bank).max(1);
            (1, banks)
        }
    };

    // Cycles: waves × per-wave schedule cycles + one grouped StoB
    // accumulation phase per *result wave* (results of a wave accumulate
    // while the next wave computes only in part — we charge them fully,
    // conservative).
    let acc_steps = (cfg.groups + cfg.subarrays_per_group) as u64;
    let result_waves = instances.div_ceil(per_bank / substreams.max(1)).max(1);
    let comp_cycles = waves * sched.total_cycles() as u64;
    let cycles = comp_cycles + result_waves * acc_steps;

    // Energy: computation per unit × units + peripheral.
    let comp_unit = computation_energy(energy, sched, 1);
    let mut e = comp_unit.scaled(units as f64);
    let active_subarray_cycles = waves.min(units) * sched.logic_cycles() as u64;
    e.peripheral = instances as f64
        * (cfg.total_subarrays() as f64 * energy.e_acc_local
            + cfg.groups as f64 * energy.e_acc_global)
        + active_subarray_cycles as f64 * energy.e_driver_cycle;

    // Area: used cells of one replicated instance (the paper's area
    // metric counts utilized cells of the mapped circuit).
    let used_cells = sched.used_cells() as u64;

    // Wear: writes spread over all cells the workload touches.
    let writes_per_unit: u64 = sched
        .write_traffic()
        .values()
        .sum::<u64>();
    let cells_touched = used_cells * per_bank.min(units).max(1);
    let total_writes = writes_per_unit * units;
    // Hottest cell: a cell is reused once per wave.
    let max_cell_writes = waves.max(1) * 2; // preset + result per wave
    let wear = WearProfile {
        used_cells: cells_touched,
        writes: total_writes,
        max_cell_writes,
    };

    RunCost {
        cycles,
        comp_cycles,
        energy: e,
        used_cells,
        min_subarray: (lanes, circuit_cols),
        wear,
        waves,
        banks_used,
    }
}

/// Cost a *binary* workload mapped on the same architecture: the circuit
/// is not lane-replicated (one instance = `sched` itself). Circuits wider
/// than a subarray are partitioned column-wise: `col_chunks` sequential
/// chunks with intermediate store/reload (one extra cycle per chunk
/// boundary, charged as a BUFF-equivalent write pass).
pub fn run_binary(
    cfg: &ArchConfig,
    energy: &EnergyParams,
    sched: &Schedule,
    instances: u64,
) -> RunCost {
    let (rows, cols) = sched.min_array();
    let row_chunks = rows.div_ceil(cfg.subarray_rows) as u64;
    let col_chunks = cols.div_ceil(cfg.subarray_cols) as u64;
    let chunks = row_chunks * col_chunks;

    // Subarrays each hold one instance-chunk; a full instance needs
    // `chunks` subarray-executions (sequential when chunked: the carry/
    // intermediate values cross chunk boundaries).
    let per_bank_instances = (cfg.total_subarrays() as u64 / chunks.max(1)).max(1);
    let waves = instances.div_ceil(per_bank_instances);

    let chunk_overhead = (chunks.saturating_sub(1)) * 2; // store + reload
    let cycles = waves * (sched.total_cycles() as u64 + chunk_overhead);

    let comp_unit = computation_energy(energy, sched, 1);
    let mut e = comp_unit.scaled(instances as f64);
    // No StoB accumulators in the binary path — peripheral is driver only.
    e.peripheral = (waves * sched.logic_cycles() as u64) as f64 * energy.e_driver_cycle;

    let used_cells = sched.used_cells() as u64;
    let writes_per_instance: u64 = sched.write_traffic().values().sum::<u64>();
    let wear = WearProfile {
        used_cells: used_cells * per_bank_instances.min(instances).max(1),
        writes: writes_per_instance * instances,
        max_cell_writes: waves.max(1) * 2,
    };

    RunCost {
        cycles,
        comp_cycles: cycles,
        energy: e,
        used_cells,
        min_subarray: (rows, cols),
        wear,
        waves,
        banks_used: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::netlist::{ops, replicate::replicate};
    use crate::scheduler::algorithm1::{schedule, Options};

    fn sched_for(base: &crate::netlist::Netlist, lanes: usize) -> (Schedule, usize) {
        let rep = replicate(base, lanes);
        let s = schedule(&rep, &Options::default());
        let cols = s.cols_used;
        (s, cols)
    }

    #[test]
    fn multiply_one_instance_fits_one_wave() {
        let cfg = Config::default();
        let (s, cols) = sched_for(&ops::multiply(), 256);
        let cost = run_stochastic(&cfg.arch, &cfg.energy, &s, 256, cols, 1);
        assert_eq!(cost.waves, 1);
        // Logic 2 + preset 1 + init 2 + accumulation 32.
        assert_eq!(cost.cycles, 5 + 32);
        assert_eq!(cost.comp_cycles, 5);
        assert_eq!(cost.min_subarray, (256, 4));
        assert!(cost.energy.total() > 0.0);
    }

    #[test]
    fn pipeline_waves_scale_with_instances() {
        let cfg = Config::default();
        let (s, cols) = sched_for(&ops::multiply(), 256);
        // batch/subarray = 256/4 = 64; bank = 64×256 = 16384 instances.
        let c1 = run_stochastic(&cfg.arch, &cfg.energy, &s, 256, cols, 16384);
        assert_eq!(c1.waves, 1);
        let c2 = run_stochastic(&cfg.arch, &cfg.energy, &s, 256, cols, 16385);
        assert_eq!(c2.waves, 2);
        assert!(c2.cycles > c1.cycles);
    }

    #[test]
    fn parallel_policy_trades_banks_for_waves() {
        let mut cfg = Config::default();
        cfg.arch.policy = crate::config::Policy::Parallel;
        let (s, cols) = sched_for(&ops::multiply(), 256);
        let c = run_stochastic(&cfg.arch, &cfg.energy, &s, 256, cols, 100_000);
        assert_eq!(c.waves, 1);
        assert!(c.banks_used > 1);
    }

    #[test]
    fn energy_scales_linearly_with_instances() {
        let cfg = Config::default();
        let (s, cols) = sched_for(&ops::scaled_add(), 256);
        let e1 = run_stochastic(&cfg.arch, &cfg.energy, &s, 256, cols, 10).energy.total();
        let e2 = run_stochastic(&cfg.arch, &cfg.energy, &s, 256, cols, 20).energy.total();
        assert!(e2 > 1.9 * e1 && e2 < 2.1 * e1);
    }

    #[test]
    fn binary_chunked_when_oversized() {
        use crate::netlist::binary::BinaryBuilder;
        let cfg = Config::default();
        let mut b = BinaryBuilder::new(16);
        let wa = b.input_word("a", 8, false);
        let wb = b.input_word("b", 8, false);
        let _ = b.multiplier(&wa, &wb);
        let s = schedule(&b.nl, &Options::default());
        let cost = run_binary(&cfg.arch, &cfg.energy, &s, 1);
        assert!(cost.cycles >= s.total_cycles() as u64);
        assert!(cost.min_subarray.0 <= 16);
    }
}
