//! S8 — the Stoch-IMC [n, m] memory architecture (§4.3): BtoS memory,
//! local/global accumulator tree, and the execution-cost engine that
//! maps scheduled circuits onto banks of subarray groups.

pub mod accumulator;
pub mod btos;
pub mod engine;

pub use accumulator::{accumulate, AccumulationResult};
pub use btos::BtosMemory;
pub use engine::{run_binary, run_stochastic, RunCost};
