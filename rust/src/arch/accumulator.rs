//! Local/global accumulator tree (§4.3): each group of m subarrays has a
//! 1-bit-input local accumulator (⌊log m⌋+1-bit register); a global
//! accumulator (⌊log(n·m)⌋+1-bit register) sums the n group partials.
//! Grouping makes the StoB accumulation n+m steps instead of n×m.

/// Accumulation cost/result for one StoB conversion of a result whose
/// bits are spread over the architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccumulationResult {
    /// Number of ones (the binary value numerator).
    pub ones: u64,
    /// Sequential accumulation steps taken.
    pub steps: u64,
    /// Local accumulator operations performed.
    pub local_ops: u64,
    /// Global accumulator operations performed.
    pub global_ops: u64,
}

/// Accumulate the per-subarray popcounts `group_counts[g][s]` (ones of
/// the result bits held in subarray s of group g) through the two-level
/// tree. `grouped = false` models the ungrouped, globally-connected
/// ablation the paper contrasts (n×m steps).
pub fn accumulate(group_counts: &[Vec<u64>], grouped: bool) -> AccumulationResult {
    let n = group_counts.len() as u64;
    let m = group_counts.first().map_or(0, |g| g.len()) as u64;
    let ones: u64 = group_counts.iter().flatten().sum();
    if grouped {
        // m steps of local accumulation (all groups in parallel), then
        // n steps of global accumulation: n + m (§4.3 example: 16+16=32).
        AccumulationResult {
            ones,
            steps: n + m,
            local_ops: n * m,
            global_ops: n,
        }
    } else {
        AccumulationResult {
            ones,
            steps: n * m,
            local_ops: 0,
            global_ops: n * m,
        }
    }
}

/// Register widths of §4.3.
pub fn local_register_bits(m: usize) -> u32 {
    (m as f64).log2().floor() as u32 + 1
}

pub fn global_register_bits(n: usize, m: usize) -> u32 {
    ((n * m) as f64).log2().floor() as u32 + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouped_is_n_plus_m() {
        // Paper §4.3: BL=256, n=m=16 ⇒ 32 steps grouped, 256 ungrouped.
        let counts: Vec<Vec<u64>> = (0..16).map(|_| vec![1u64; 16]).collect();
        let g = accumulate(&counts, true);
        assert_eq!(g.steps, 32);
        assert_eq!(g.ones, 256);
        let u = accumulate(&counts, false);
        assert_eq!(u.steps, 256);
        assert_eq!(u.ones, 256);
    }

    #[test]
    fn register_widths() {
        assert_eq!(local_register_bits(16), 5); // ⌊log 16⌋+1
        assert_eq!(global_register_bits(16, 16), 9); // ⌊log 256⌋+1
    }
}
