//! BtoS (binary→stochastic) memory (§4.3): a 2^resolution-entry table
//! mapping each binary value to the (V_p, t_p) write pulse whose MTJ
//! switching probability equals the value. One lookup per stochastic
//! input write; the pulse is applied to all lanes of the input column.

use crate::device::{pulse_for_probability, MtjParams, Pulse};
#[cfg(test)]
use crate::device::switching_probability;

/// The per-bank BtoS lookup memory.
#[derive(Debug, Clone)]
pub struct BtosMemory {
    pub resolution: u32,
    entries: Vec<Pulse>,
    pub lookups: u64,
}

impl BtosMemory {
    /// Build the table from the device model, choosing the minimum-energy
    /// pulse per §5.1. Values 0 and 2^r−1 use degenerate pulses (keep
    /// preset / deterministic write).
    pub fn build(params: &MtjParams, resolution: u32) -> Self {
        let n = 1usize << resolution;
        let entries = (0..n)
            .map(|i| {
                let p = i as f64 / n as f64;
                if p <= 0.0 {
                    Pulse { v_p: 0.0, t_p: 0.0 }
                } else {
                    pulse_for_probability(params, p.min(1.0 - 1e-9)).0
                }
            })
            .collect();
        Self { resolution, entries, lookups: 0 }
    }

    /// Table size in bytes (§4.3: 2^resolution bytes).
    pub fn size_bytes(&self) -> usize {
        1 << self.resolution
    }

    /// Look up the pulse for a value in [0,1].
    pub fn pulse_for(&mut self, value: f64) -> Pulse {
        self.lookups += 1;
        let n = self.entries.len();
        let idx = ((value.clamp(0.0, 1.0) * n as f64) as usize).min(n - 1);
        self.entries[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_size_matches_resolution() {
        let m = BtosMemory::build(&MtjParams::default(), 8);
        assert_eq!(m.size_bytes(), 256);
        assert_eq!(m.entries.len(), 256);
    }

    #[test]
    fn pulses_realize_their_probabilities() {
        let params = MtjParams::default();
        let mut m = BtosMemory::build(&params, 8);
        for &v in &[0.1, 0.25, 0.5, 0.75, 0.9] {
            let pulse = m.pulse_for(v);
            let p = switching_probability(&params, pulse);
            assert!((p - v).abs() < 0.01, "v={v} p={p}");
        }
        assert_eq!(m.lookups, 5);
    }

    #[test]
    fn zero_value_uses_no_pulse() {
        let mut m = BtosMemory::build(&MtjParams::default(), 8);
        let pulse = m.pulse_for(0.0);
        assert_eq!(pulse.t_p, 0.0);
    }
}
