//! stoch-imc CLI — leader entrypoint.
//!
//! Subcommands (hand-parsed; clap is not in the offline crate set):
//!   info                      config + artifact inventory
//!   fig3 | fig7 | table2 | table3 | table4 | fig10 | fig11
//!                             regenerate a paper table/figure
//!   run <app> [N]             end-to-end workload through the
//!                             coordinator (PJRT artifacts), with
//!                             accuracy vs the float reference
//!   serve [N] [shards]        all apps concurrently through the
//!                             sharded serve::Server (N instances per
//!                             app; shards=0 ⇒ one per artifact)
//!   schedule <op> [lanes]     show Algorithm 1 output for one op
//!   faults [APP] [RATES..]    Table-4-style accuracy-vs-flip-rate
//!                             campaign through the full serve::Server
//!                             with fault injection live in the lane
//!                             engine; writes a flat-JSON snapshot
//!   bench-check [FILE]        CI sanity gate over BENCH_serve.json:
//!                             log all keys, fail if any *_speedup < 1
//!   stats [FILE] [--check] [--prom]
//!                             stats exposition: print a stats snapshot
//!                             (or take a live one by serving a smoke
//!                             workload); --check asserts the queue and
//!                             stage-timing telemetry keys, --prom emits
//!                             Prometheus text instead of flat JSON
//!   chaos [--panics N] [--seconds S] [--shards K]
//!                             resilience harness: flood the server while
//!                             injecting executor panics + wave latency
//!                             under deadlines and the degradation
//!                             ladder; exits nonzero unless every
//!                             admitted request got exactly one terminal
//!                             outcome and the invariants held
//!   serve-tcp [PORT] [shards] [--seconds S] [--accept-drop N] [--cut N]
//!             [--trickle N] [--stall N]
//!                             the TCP front door: length-prefixed wire
//!                             protocol over the sharded server, bounded
//!                             connection threads, slow-peer defenses,
//!                             graceful SIGTERM/SIGINT drain; the
//!                             optional flags enable network chaos
//!                             (`serve --tcp [PORT]` is the same path)
//!   flood <ADDR> [--seconds S] [--conns C] [--rate R]
//!         [--mix poisson|bursty|mixed] [--deadline-ms D] [--seed N]
//!                             loopback storm driver: retrying clients
//!                             with Poisson/bursty arrivals over every
//!                             registered artifact; exits nonzero on any
//!                             client-invariant violation; writes a
//!                             NET_report.json (STOCH_IMC_NET_OUT)

use std::path::{Path, PathBuf};

use stoch_imc::apps::all_apps;
use stoch_imc::bail;
use stoch_imc::config::Config;
use stoch_imc::coordinator::{BatcherConfig, Coordinator};
use stoch_imc::error::{Context, Error, Result};
use stoch_imc::report;
use stoch_imc::util::stats::mean_error_pct;

fn load_config(args: &[String]) -> Result<Config> {
    if let Some(i) = args.iter().position(|a| a == "--config") {
        let path = args.get(i + 1).context("--config needs a path")?;
        Config::from_file(Path::new(path)).map_err(|e| Error::msg(e.to_string()))
    } else {
        let default = Path::new("configs/default.toml");
        if default.exists() {
            Config::from_file(default).map_err(|e| Error::msg(e.to_string()))
        } else {
            Ok(Config::default())
        }
    }
}

fn artifact_dir() -> PathBuf {
    std::env::var("STOCH_IMC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Signal-aware shutdown for `serve-tcp`, with no `libc` crate: a raw
/// `signal(2)` binding installs a handler that only stores an
/// `AtomicBool` (atomic stores are async-signal-safe), and the serve
/// loop polls the flag — so SIGTERM/SIGINT trigger the graceful drain
/// instead of killing in-flight waves. Non-Unix builds compile the
/// polling loop against a flag nothing ever sets.
#[cfg(unix)]
mod signals {
    use std::sync::atomic::{AtomicBool, Ordering};

    static SHUTDOWN: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_sig: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    extern "C" {
        // POSIX `signal(2)`; the real return is the previous handler,
        // opaque here (usize-sized either way).
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }

    pub fn requested() -> bool {
        SHUTDOWN.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod signals {
    pub fn install() {}

    pub fn requested() -> bool {
        false
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cfg = load_config(&args)?;
    match args.first().map(String::as_str) {
        Some("info") => cmd_info(&cfg),
        Some("fig3") => cmd_fig3(&cfg),
        Some("fig7") => cmd_fig7(),
        Some("table2") => cmd_table2(&cfg),
        Some("table3") => cmd_table3(&cfg),
        Some("table4") => cmd_table4(&cfg),
        Some("fig10") => cmd_fig10(&cfg),
        Some("fig11") => cmd_fig11(&cfg),
        Some("run") => cmd_run(&cfg, &args[1..]),
        Some("serve") => cmd_serve(&cfg, &args[1..]),
        Some("schedule") => cmd_schedule(&args[1..]),
        Some("faults") => cmd_faults(&cfg, &args[1..]),
        Some("bench-check") => cmd_bench_check(&args[1..]),
        Some("stats") => cmd_stats(&cfg, &args[1..]),
        Some("chaos") => cmd_chaos(&args[1..]),
        Some("serve-tcp") => cmd_serve_tcp(&args[1..]),
        Some("flood") => cmd_flood(&cfg, &args[1..]),
        other => {
            if let Some(o) = other {
                eprintln!("unknown command `{o}`");
            }
            eprintln!(
                "usage: stoch-imc \
                 <info|fig3|fig7|table2|table3|table4|fig10|fig11|run|serve|schedule|faults|\
                 bench-check|stats|chaos|serve-tcp|flood> [--config FILE]"
            );
            std::process::exit(2);
        }
    }
}

/// CI bench sanity gate: parse a `BENCH_serve.json` snapshot, log every
/// key (markdown, so `tee -a $GITHUB_STEP_SUMMARY` renders a table in
/// the job summary), and fail when any `*_speedup` key is below 1.0 —
/// a word/lane-parallel path slower than its scalar reference is a
/// perf regression, not a tuning choice.
fn cmd_bench_check(args: &[String]) -> Result<()> {
    use stoch_imc::util::benchjson;
    let path = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(benchjson::BENCH_FILE));
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading bench snapshot {}", path.display()))?;
    let entries = benchjson::parse_flat(&text);
    if entries.is_empty() {
        bail!("bench snapshot {} has no keys", path.display());
    }
    println!("### Bench snapshot — {} keys ({})\n", entries.len(), path.display());
    println!("| key | value |");
    println!("|---|---|");
    for (k, v) in &entries {
        println!("| `{k}` | {v:.3} |");
    }
    let regressions: Vec<_> =
        entries.iter().filter(|(k, v)| k.ends_with("_speedup") && *v < 1.0).collect();
    if !regressions.is_empty() {
        println!();
        for (k, v) in &regressions {
            println!(
                "**REGRESSION** `{k}` = {v:.3} — parallel path slower than its scalar reference"
            );
        }
        bail!("{} speedup key(s) below 1.0", regressions.len());
    }
    println!("\nAll `*_speedup` keys ≥ 1.0.");
    Ok(())
}

/// Snapshot keys `stats --check` requires — the queue and stage-timing
/// telemetry the serve smoke in CI asserts on. Every key is emitted
/// unconditionally by `Metrics::snapshot_into`, so a missing key means
/// the exposition schema regressed, not that the workload was idle.
const REQUIRED_STATS_KEYS: &[&str] = &[
    "serve_pool_requests",
    "serve_pool_waves",
    "serve_pool_waves_full",
    "serve_pool_waves_deadline",
    "serve_pool_waves_flush",
    "serve_pool_latency_us_p50",
    "serve_pool_latency_us_p95",
    "serve_pool_latency_us_p99",
    "serve_pool_queue_wait_us_p99",
    "serve_pool_queue_depth_p99",
    "serve_pool_shed_total",
    "serve_pool_backpressure_blocks",
    "serve_pool_stage_sng_share",
    "serve_pool_stage_gate_share",
    "serve_pool_stage_regen_share",
    "serve_pool_stage_stob_share",
    "serve_pool_sng_cache_hits",
    "serve_pool_sng_cache_hit_rate",
    "serve_pool_sng_cutoff_hits",
    "serve_pool_executor_restarts",
    "serve_pool_deadline_timeouts",
    "serve_pool_failed_requests",
    "serve_pool_degraded_waves",
    "serve_pool_bl_level",
    // The TCP front door's exposition set. Always emitted — in-process
    // runs push a zeroed `NetMetrics` (see `with_net_keys`), so a
    // missing key means the wire-layer schema regressed.
    "serve_net_connections",
    "serve_net_active_connections",
    "serve_net_busy_rejected",
    "serve_net_idle_reaped",
    "serve_net_io_timeouts",
    "serve_net_frames_rx",
    "serve_net_frames_tx",
    "serve_net_protocol_errors",
    "serve_net_shed",
    "serve_net_going_away",
    "serve_net_wire_latency_us_p50",
    "serve_net_wire_latency_us_p99",
];

/// Stats exposition: print a stats snapshot — either one previously
/// written as flat JSON (`stats FILE`) or a live one taken by serving a
/// short smoke workload (`stats` with no file). `--prom` renders
/// Prometheus text instead of flat JSON; `--check` fails unless every
/// key in [`REQUIRED_STATS_KEYS`] is present (the CI serve-smoke gate).
fn cmd_stats(cfg: &Config, args: &[String]) -> Result<()> {
    use stoch_imc::obs::MetricsSnapshot;
    use stoch_imc::util::benchjson;

    let check = args.iter().any(|a| a == "--check");
    let prom = args.iter().any(|a| a == "--prom");
    let mut file: Option<PathBuf> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => i += 1, // skip the flag's value too
            a if a.starts_with("--") => {}
            a => file = Some(PathBuf::from(a)),
        }
        i += 1;
    }

    let snap = match &file {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading stats snapshot {}", path.display()))?;
            let entries = benchjson::parse_flat(&text);
            if entries.is_empty() {
                bail!("stats snapshot {} has no keys", path.display());
            }
            MetricsSnapshot::from_entries(&entries)
        }
        None => live_stats_snapshot(cfg)?,
    };

    if prom {
        print!("{}", snap.to_prometheus());
    } else {
        print!("{}", snap.to_flat_json());
    }

    if check {
        let missing: Vec<&str> = REQUIRED_STATS_KEYS
            .iter()
            .copied()
            .filter(|k| snap.get(k).is_none())
            .collect();
        if !missing.is_empty() {
            bail!(
                "stats snapshot missing {} required key(s): {}",
                missing.len(),
                missing.join(", ")
            );
        }
        eprintln!(
            "stats --check OK: all {} required telemetry keys present ({} keys total)",
            REQUIRED_STATS_KEYS.len(),
            snap.len()
        );
    }
    Ok(())
}

/// Serve a short smoke workload (32 instances of every registered
/// `app_*` artifact) and return the live pool snapshot.
fn live_stats_snapshot(cfg: &Config) -> Result<stoch_imc::obs::MetricsSnapshot> {
    use stoch_imc::serve::{Server, ServerConfig};

    let server = Server::start(&artifact_dir(), ServerConfig::default())?;
    let n = 32usize;
    let mut served = 0usize;
    for app in all_apps().iter() {
        let artifact = format!("app_{}", app.name());
        let Some(arity) = server.n_inputs(&artifact) else { continue };
        let instances = app.workload(n, cfg.seed);
        let padded: Vec<Vec<f64>> = instances
            .iter()
            .map(|x| {
                let mut v = x.clone();
                v.resize(arity, 0.0);
                v
            })
            .collect();
        server.run_workload(&artifact, &padded)?;
        served += 1;
    }
    if served == 0 {
        bail!("no app_* artifacts registered under {}", artifact_dir().display());
    }
    server.drain()?;
    Ok(with_net_keys(server.snapshot()))
}

/// Serve snapshots carry the full stable key schema — `serve_net_*`
/// included — whether or not the TCP front ran: in-process runs merge a
/// zeroed [`NetMetrics`](stoch_imc::serve::net::NetMetrics) so
/// `stats --check` gates one schema for both modes.
fn with_net_keys(mut snap: stoch_imc::obs::MetricsSnapshot) -> stoch_imc::obs::MetricsSnapshot {
    if snap.get("serve_net_connections").is_none() {
        stoch_imc::serve::net::NetMetrics::default().snapshot_into(&mut snap);
    }
    snap
}

fn cmd_info(cfg: &Config) -> Result<()> {
    println!("Stoch-IMC — bit-parallel stochastic IMC (STT-MRAM 2T-1MTJ)");
    println!(
        "arch: [{}, {}] groups×subarrays of {}×{}, BL={}, {}-bit, policy={:?}",
        cfg.arch.groups,
        cfg.arch.subarrays_per_group,
        cfg.arch.subarray_rows,
        cfg.arch.subarray_cols,
        cfg.arch.bitstream_len,
        cfg.arch.resolution,
        cfg.arch.policy
    );
    println!("BtoS memory: {} B", cfg.arch.btos_bytes());
    let dir = artifact_dir();
    match stoch_imc::runtime::load_manifest(&dir) {
        Ok(specs) => {
            println!("artifacts ({}):", dir.display());
            for s in specs {
                println!("  {:<18} inputs={:<3} batch={} bl={}", s.name, s.n_inputs, s.batch, s.bl);
            }
        }
        Err(e) => println!("artifacts: not built ({e:#})"),
    }
    Ok(())
}

fn cmd_fig3(cfg: &Config) -> Result<()> {
    println!("# Fig 3 — P_sw vs V_p (Eqs 1-2, Table 1 device)");
    let series = report::fig3(&cfg.device);
    print!("{:>6}", "V_p");
    for (tp, _) in &series {
        print!(" {:>7}", format!("{tp}ns"));
    }
    println!();
    let n = series[0].1.len();
    for i in 0..n {
        print!("{:>6.3}", series[0].1[i].0);
        for (_, s) in &series {
            print!(" {:>7.4}", s[i].1);
        }
        println!();
    }
    Ok(())
}

fn cmd_fig7() -> Result<()> {
    let (b, s) = report::fig7();
    println!("# Fig 7 — 4-bit in-memory addition sequence flow");
    println!("binary (ripple-carry MAJ/BUFF, Fig 7a): {b} cycles (paper: 9)");
    println!("stochastic (MUX over 4 lanes, Fig 7b):  {s} cycles (paper: 4)");
    Ok(())
}

fn cmd_table2(cfg: &Config) -> Result<()> {
    println!("# Table 2 — arithmetic ops (norm. to binary IMC)");
    println!(
        "{:<18} {:>12} {:>10} {:>10} | {:>9} {:>9} | {:>9} {:>9} | {:>9}",
        "op", "bin array", "[22]", "stoch", "area[22]", "areaS", "time[22]", "timeS", "energyS"
    );
    for r in report::table2(cfg) {
        println!(
            "{:<18} {:>12} {:>10} {:>10} | {:>9.3} {:>9.3} | {:>9.3} {:>9.4} | {:>9.3}",
            r.op,
            format!("{}x{}", r.binary_array.0, r.binary_array.1),
            format!("{}x{}", r.sc_cram_array.0, r.sc_cram_array.1),
            format!("{}x{}", r.stoch_array.0, r.stoch_array.1),
            r.area_sc_cram,
            r.area_stoch,
            r.time_sc_cram,
            r.time_stoch,
            r.energy_stoch,
        );
    }
    Ok(())
}

fn cmd_table3(cfg: &Config) -> Result<()> {
    println!("# Table 3 — applications (norm. to binary IMC)");
    println!(
        "{:<6} {:>12} {:>10} | {:>9} {:>9} | {:>10} {:>10} | {:>9} {:>9}",
        "app", "bin subarr", "stoch", "area[22]", "areaS", "time[22]", "timeS", "en[22]", "enS"
    );
    let rows = report::table3(cfg);
    for r in &rows {
        println!(
            "{:<6} {:>12} {:>10} | {:>9.3} {:>9.3} | {:>10.3} {:>10.4} | {:>9.3} {:>9.3}",
            r.app,
            format!("{}x{}", r.binary_subarray.0, r.binary_subarray.1),
            format!("{}x{}", r.stoch_subarray.0, r.stoch_subarray.1),
            r.area_sc_cram,
            r.area_stoch,
            r.time_sc_cram,
            r.time_stoch,
            r.energy_sc_cram,
            r.energy_stoch,
        );
    }
    let (vs_bin, vs_scc, en) = report::headline(&rows);
    println!(
        "\ngeomean speedup vs binary: {vs_bin:.1}x (paper 135.7x); vs [22]: {vs_scc:.1}x \
         (paper 124.2x); energy vs binary: {en:.2}x (paper 1.5x)"
    );
    Ok(())
}

fn cmd_table4(cfg: &Config) -> Result<()> {
    println!("# Table 4 — output error (%) under injected bitflips");
    let rates = [0.0, 0.05, 0.10, 0.15, 0.20];
    let t = report::table4(cfg, &rates, 24);
    println!(
        "{:<6} | {:>35} | {:>35}",
        "app", "binary-IMC (0/5/10/15/20 %)", "Stoch-IMC (0/5/10/15/20 %)"
    );
    for app in ["lit", "ol", "hdp", "kde"] {
        let (b, s) = &t[app];
        let fmt = |v: &Vec<f64>| {
            v.iter().map(|x| format!("{x:6.2}")).collect::<Vec<_>>().join(" ")
        };
        println!("{:<6} | {:>35} | {:>35}", app, fmt(b), fmt(s));
    }
    Ok(())
}

fn cmd_fig10(cfg: &Config) -> Result<()> {
    println!("# Fig 10 — energy breakdown (%)");
    println!(
        "{:<6} {:<9} | {:>7} {:>7} {:>9} {:>11}",
        "app", "method", "logic", "preset", "input", "peripheral"
    );
    for r in report::table3(cfg) {
        for (m, b) in [
            ("binary", &r.binary_energy_breakdown),
            ("[22]", &r.sc_cram_energy_breakdown),
            ("stoch", &r.stoch_energy_breakdown),
        ] {
            let p = b.percentages();
            println!(
                "{:<6} {:<9} | {:>7.1} {:>7.1} {:>9.1} {:>11.1}",
                r.app, m, p[0], p[1], p[2], p[3]
            );
        }
    }
    Ok(())
}

fn cmd_fig11(cfg: &Config) -> Result<()> {
    println!("# Fig 11 — lifetime improvement vs binary IMC (Eq 11)");
    let rows = report::table3(cfg);
    let mut st = Vec::new();
    let mut sc = Vec::new();
    for (app, s, c) in report::fig11(&rows) {
        println!("{app:<6} stoch={s:>10.2}x   [22]={c:>10.4}x");
        st.push(s);
        sc.push(s / c);
    }
    println!(
        "geomean: stoch vs binary {:.1}x (paper 4.9x); stoch vs [22] {:.1}x (paper 216.3x)",
        stoch_imc::util::stats::geomean(&st),
        stoch_imc::util::stats::geomean(&sc),
    );
    Ok(())
}

fn cmd_run(cfg: &Config, args: &[String]) -> Result<()> {
    let app_name = args.first().context("run <app> [instances]")?;
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let apps = all_apps();
    let app = apps
        .iter()
        .find(|a| a.name() == app_name)
        .with_context(|| format!("unknown app `{app_name}` (lit|ol|hdp|kde)"))?;
    let instances = app.workload(n, cfg.seed);

    println!("loading artifacts + starting the coordinator (STOCH_IMC_BACKEND selects interp/pjrt)…");
    let coord = Coordinator::start(&artifact_dir(), BatcherConfig::default())?;
    let artifact = format!("app_{app_name}");
    let arity = coord.n_inputs(&artifact).context("artifact not found")?;
    let padded: Vec<Vec<f64>> = instances
        .iter()
        .map(|x| {
            let mut v = x.clone();
            v.resize(arity, 0.0);
            v
        })
        .collect();

    let t0 = std::time::Instant::now();
    let outs = coord.run_workload(&artifact, &padded)?;
    let dt = t0.elapsed();

    let refs: Vec<f64> = instances.iter().map(|x| app.float_ref(x)).collect();
    let err = mean_error_pct(&refs, &outs);
    let m = coord.metrics(&artifact);
    println!(
        "{} instances in {:.2?} ({:.0}/s) — mean output error vs float ref: {:.2}%",
        outs.len(),
        dt,
        outs.len() as f64 / dt.as_secs_f64(),
        err
    );
    println!("coordinator: {}", m.summary());
    // The gate was tuned at the old BL=1024 registry (15%); the
    // paper-default BL=256 manifest doubles single-stream σ, so the
    // regression bar scales accordingly.
    if err > 25.0 {
        bail!("accuracy regression: {err:.2}%");
    }
    Ok(())
}

/// Serve every app_* artifact concurrently through the bank-parallel
/// `serve::Server` — one caller thread per app, one controller shard per
/// artifact (or `shards` hashed shards) — and report per-app accuracy
/// plus the pool-wide metrics.
fn cmd_serve(cfg: &Config, args: &[String]) -> Result<()> {
    use stoch_imc::serve::{Server, ServerConfig};

    // `--tcp [PORT]` switches to the front-door mode; everything after
    // the flag is forwarded so `serve --tcp 7117 --seconds 30` and
    // `serve-tcp 7117 --seconds 30` share one code path (no duplicated
    // pool/front setup).
    if let Some(i) = args.iter().position(|a| a == "--tcp") {
        let mut fwd: Vec<String> = args.to_vec();
        fwd.remove(i);
        return cmd_serve_tcp(&fwd);
    }
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(128);
    let shards: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let server = Server::start(
        &artifact_dir(),
        ServerConfig { shards, ..ServerConfig::default() },
    )?;
    let apps = all_apps();
    let served: Vec<&Box<dyn stoch_imc::apps::App>> = apps
        .iter()
        .filter(|a| server.n_inputs(&format!("app_{}", a.name())).is_some())
        .collect();
    if served.len() < 2 {
        bail!("serve needs ≥2 app artifacts registered (have {:?})", server.apps());
    }
    println!(
        "serving {} apps over {} shard(s), {} instances each…",
        served.len(),
        server.n_shards(),
        n
    );

    let t0 = std::time::Instant::now();
    let results: Vec<Result<(String, f64, usize)>> = std::thread::scope(|s| {
        let handles: Vec<_> = served
            .iter()
            .map(|app| {
                let server = &server;
                let seed = cfg.seed;
                s.spawn(move || -> Result<(String, f64, usize)> {
                    let artifact = format!("app_{}", app.name());
                    let arity = server.n_inputs(&artifact).context("artifact vanished")?;
                    let instances = app.workload(n, seed);
                    let padded: Vec<Vec<f64>> = instances
                        .iter()
                        .map(|x| {
                            let mut v = x.clone();
                            v.resize(arity, 0.0);
                            v
                        })
                        .collect();
                    let outs = server.run_workload(&artifact, &padded)?;
                    let refs: Vec<f64> = instances.iter().map(|x| app.float_ref(x)).collect();
                    Ok((artifact, mean_error_pct(&refs, &outs), outs.len()))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| Err(Error::msg("serve worker thread panicked")))
            })
            .collect()
    });
    let dt = t0.elapsed();

    let mut total = 0usize;
    for r in results {
        let (artifact, err, count) = r?;
        total += count;
        let shard = server.shard_of(&artifact).unwrap_or(usize::MAX);
        println!(
            "{artifact:<10} shard {shard}: {count} instances, mean err {err:.2}% — {}",
            server.metrics(&artifact).summary()
        );
    }
    println!(
        "pool: {} instances in {:.2?} ({:.0}/s aggregate) — {}",
        total,
        dt,
        total as f64 / dt.as_secs_f64(),
        server.pool_metrics().summary()
    );
    // Stats exposition: the same flat snapshot `stoch-imc stats` checks,
    // printed as a digest and written for the CI artifact (net keys
    // zeroed — this is the in-process path).
    let snap = with_net_keys(server.snapshot());
    print_pool_observability(&snap);
    let out = write_stats_snapshot(&snap)?;
    println!("wrote {} stats keys to {}", snap.len(), out.display());
    Ok(())
}

/// Human-readable pool observability digest from a stats snapshot —
/// the end-of-run report `serve` and `faults` share.
fn print_pool_observability(snap: &stoch_imc::obs::MetricsSnapshot) {
    let g = |k: &str| snap.get(k).unwrap_or(0.0);
    println!(
        "pool latency µs: p50={:.0} p95={:.0} p99={:.0} p99.9={:.0} max={:.0}",
        g("serve_pool_latency_us_p50"),
        g("serve_pool_latency_us_p95"),
        g("serve_pool_latency_us_p99"),
        g("serve_pool_latency_us_p999"),
        g("serve_pool_latency_us_max"),
    );
    println!(
        "pool queue: wait µs p50={:.0} p99={:.0}, depth p50={:.0} max={:.0}, \
         backpressure={:.0}, shed={:.0}",
        g("serve_pool_queue_wait_us_p50"),
        g("serve_pool_queue_wait_us_p99"),
        g("serve_pool_queue_depth_p50"),
        g("serve_pool_queue_depth_max"),
        g("serve_pool_backpressure_blocks"),
        g("serve_pool_shed_total"),
    );
    println!(
        "pool stages: sng={:.1}% gates={:.1}% regen={:.1}% stob={:.1}% \
         ({:.1} ms summed across workers)",
        100.0 * g("serve_pool_stage_sng_share"),
        100.0 * g("serve_pool_stage_gate_share"),
        100.0 * g("serve_pool_stage_regen_share"),
        100.0 * g("serve_pool_stage_stob_share"),
        g("serve_pool_stage_total_ms"),
    );
    println!(
        "pool waves: full={:.0} deadline={:.0} flush={:.0}",
        g("serve_pool_waves_full"),
        g("serve_pool_waves_deadline"),
        g("serve_pool_waves_flush"),
    );
}

/// Write a stats snapshot as flat JSON to `STOCH_IMC_STATS_OUT` (else
/// `SERVE_stats.json`) and return the path.
fn write_stats_snapshot(snap: &stoch_imc::obs::MetricsSnapshot) -> Result<PathBuf> {
    let out = std::env::var("STOCH_IMC_STATS_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("SERVE_stats.json"));
    std::fs::write(&out, snap.to_flat_json())
        .with_context(|| format!("writing stats snapshot {}", out.display()))?;
    Ok(out)
}

/// Table-4-style reliability campaign through the full serving stack:
/// for each flip rate, start a `serve::Server` whose every wave executes
/// under a uniform [`FaultPlan`](stoch_imc::fault::FaultPlan) — stateless
/// masks XORed into the lane words at the SNG/gate/StoB sites — measure
/// each app's output error against its float reference, and put the
/// 8-bit binary-IMC baseline under the same flip rate next to it. Also
/// reports the executor-side Eq 4 energy and Eq 11 wear the campaign's
/// waves accumulated, and writes everything as a flat-JSON snapshot
/// (`STOCH_IMC_FAULTS_OUT`, else `docs/experiments/faults-campaign.json`
/// when that directory exists, else `FAULTS_campaign.json`).
fn cmd_faults(cfg: &Config, args: &[String]) -> Result<()> {
    use stoch_imc::fault::FaultPlan;
    use stoch_imc::serve::{Server, ServerConfig};
    use stoch_imc::util::benchjson;
    use stoch_imc::util::stats::range_error_pct;

    let mut names: Vec<String> = Vec::new();
    let mut rates: Vec<f64> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--config" {
            i += 2;
            continue;
        }
        if let Ok(r) = args[i].parse::<f64>() {
            if !(0.0..=1.0).contains(&r) {
                bail!("flip rate {r} outside [0, 1]");
            }
            rates.push(r);
        } else {
            names.push(args[i].trim_start_matches("app_").to_string());
        }
        i += 1;
    }
    if rates.is_empty() {
        rates = vec![0.0, 0.05, 0.10, 0.15, 0.20];
    }
    let all = all_apps();
    let apps: Vec<_> = all
        .iter()
        .filter(|a| names.is_empty() || names.iter().any(|n| n == a.name()))
        .collect();
    if apps.is_empty() {
        bail!("no such app (have lit|ol|hdp|kde)");
    }
    let n = 64usize;
    let dir = artifact_dir();

    println!("# faults — output error (%) through the serving stack under injected bitflips");
    println!("rates {rates:?}, {n} instances per app, seed {}", cfg.seed);
    let mut entries: Vec<(String, f64)> = Vec::new();
    // Pool observability from the last rate's server — the campaign's
    // end-of-run stage/queue digest (counters are rate-independent).
    let mut last_snap: Option<stoch_imc::obs::MetricsSnapshot> = None;
    // Per app: (name, binary errors per rate, stochastic errors per rate).
    let mut table: Vec<(String, Vec<f64>, Vec<f64>)> =
        apps.iter().map(|a| (a.name().to_string(), Vec::new(), Vec::new())).collect();
    for (ri, &rate) in rates.iter().enumerate() {
        // One server per rate: every wave of every app runs under the
        // same uniform plan, through the full shard/batcher path.
        let server = Server::start(
            &dir,
            ServerConfig {
                fault: Some(FaultPlan::uniform(rate, cfg.seed ^ 0xFA)),
                ..ServerConfig::default()
            },
        )?;
        for (ai, app) in apps.iter().enumerate() {
            let artifact = format!("app_{}", app.name());
            let Some(arity) = server.n_inputs(&artifact) else {
                if ri == 0 {
                    eprintln!("skipping `{artifact}` — not in the artifact manifest");
                }
                continue;
            };
            let instances = app.workload(n, cfg.seed);
            let padded: Vec<Vec<f64>> = instances
                .iter()
                .map(|x| {
                    let mut v = x.clone();
                    v.resize(arity, 0.0);
                    v
                })
                .collect();
            let outs = server.run_workload(&artifact, &padded)?;
            let refs: Vec<f64> = instances.iter().map(|x| app.float_ref(x)).collect();
            let stoch = range_error_pct(&refs, &outs);
            // The 8-bit binary-IMC baseline under the same flip rate —
            // the Table 4 comparison column (MSB-exposed, so it
            // collapses where the stochastic path degrades gracefully).
            let binary = stoch_imc::apps::output_error_pct(
                app.as_ref(),
                &instances,
                cfg.arch.bitstream_len,
                cfg.arch.resolution,
                rate,
                false,
                cfg.seed ^ 0xB1,
            );
            table[ai].1.push(binary);
            table[ai].2.push(stoch);
            entries.push((format!("faults_{}_rate_{rate}_binary_err_pct", app.name()), binary));
            entries.push((format!("faults_{}_rate_{rate}_stoch_err_pct", app.name()), stoch));
            if ri == 0 {
                // Executor-side Eq 4 / Eq 11 instrumentation from this
                // rate's waves (counters are rate-independent).
                let m = server.metrics(&artifact);
                entries.push((
                    format!("faults_{}_energy_pj", app.name()),
                    m.energy(&cfg.energy).total() * 1e12,
                ));
                entries
                    .push((format!("faults_{}_wear_writes", app.name()), m.wear.writes as f64));
                if let Some(merit) = m.wear.merit() {
                    entries.push((format!("faults_{}_wear_merit", app.name()), merit));
                }
            }
        }
        last_snap = Some(server.snapshot());
    }
    let fmt = |v: &[f64]| v.iter().map(|x| format!("{x:7.2}")).collect::<Vec<_>>().join(" ");
    println!("\n{:<6} | binary-IMC | Stoch-IMC   (per rate)", "app");
    for (name, b, s) in &table {
        println!("{name:<6} | {} | {}", fmt(b), fmt(s));
    }
    if let Some(snap) = &last_snap {
        println!();
        print_pool_observability(snap);
    }
    let out = std::env::var("STOCH_IMC_FAULTS_OUT").map(PathBuf::from).unwrap_or_else(|_| {
        let d = Path::new("docs/experiments");
        if d.is_dir() {
            d.join("faults-campaign.json")
        } else {
            PathBuf::from("FAULTS_campaign.json")
        }
    });
    benchjson::merge_and_write(&out, &entries)
        .with_context(|| format!("writing {}", out.display()))?;
    println!("\nwrote {} keys to {}", entries.len(), out.display());
    Ok(())
}

/// The chaos harness: flood every servable artifact through a server
/// configured with injected executor panics (supervised restarts),
/// artificial wave latency, request deadlines, and the BL degradation
/// ladder — then assert the resilience invariants: every admitted
/// request received exactly one terminal outcome (a value or a typed
/// error), nothing deadlocked, injected panics never exceeded their
/// budget, degradation stayed within the ladder, and the server still
/// answers cleanly once the storm has passed. Writes a flat-JSON report
/// to `STOCH_IMC_CHAOS_OUT` (else `CHAOS_report.json`).
fn cmd_chaos(args: &[String]) -> Result<()> {
    use std::collections::VecDeque;
    use std::sync::mpsc::Receiver;
    use std::time::{Duration, Instant};

    use stoch_imc::serve::{ChaosPlan, DegradeConfig, Reply, ServeError, Server, ServerConfig};
    use stoch_imc::util::benchjson;

    let mut panics: u64 = 3;
    let mut seconds: u64 = 5;
    let mut shards: usize = 0;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--panics" => {
                panics = args.get(i + 1).and_then(|s| s.parse().ok()).context("--panics N")?;
                i += 1;
            }
            "--seconds" => {
                seconds = args.get(i + 1).and_then(|s| s.parse().ok()).context("--seconds S")?;
                i += 1;
            }
            "--shards" => {
                shards = args.get(i + 1).and_then(|s| s.parse().ok()).context("--shards K")?;
                i += 1;
            }
            "--config" => i += 1,
            other => bail!("chaos: unknown argument `{other}`"),
        }
        i += 1;
    }

    let degrade = DegradeConfig { wait_p95_us: 10_000, max_steps: 2, eval_waves: 8 };
    let server = Server::start(
        &artifact_dir(),
        ServerConfig {
            shards,
            // batch is taken from each artifact's manifest spec; the
            // 1ms max_wait keeps partial waves (and the storm) moving.
            batcher: BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() },
            deadline: Some(Duration::from_millis(250)),
            degrade: Some(degrade),
            chaos: Some(ChaosPlan {
                panic_every: 5,
                max_panics: panics,
                latency_every: 7,
                latency: Duration::from_millis(2),
                ..ChaosPlan::default()
            }),
            // Injected panics must never kill a shard on their own; the
            // shared budget caps them at `panics` < this allowance.
            max_restarts: (panics + 4).min(u64::from(u32::MAX)) as u32,
            ..ServerConfig::default()
        },
    )?;
    let apps = server.apps();
    if apps.is_empty() {
        bail!("no artifacts registered under {}", artifact_dir().display());
    }
    println!(
        "chaos: {} app(s) over {} shard(s) for {seconds}s — panic every 5th wave \
         (budget {panics}), +2ms every 7th wave, 250ms deadlines, BL ladder ≤{} steps",
        apps.len(),
        server.n_shards(),
        degrade.max_steps
    );

    #[derive(Default, Clone, Copy)]
    struct Tally {
        admitted: u64,
        submit_err: u64,
        ok: u64,
        timeout: u64,
        exec: u64,
        dead: u64,
        dropped: u64,
    }
    impl Tally {
        fn absorb(&mut self, reply: std::result::Result<Reply, std::sync::mpsc::RecvTimeoutError>) {
            match reply {
                Ok(Ok(_)) => self.ok += 1,
                Ok(Err(ServeError::Timeout)) => self.timeout += 1,
                Ok(Err(ServeError::ShardDead)) => self.dead += 1,
                Ok(Err(ServeError::Exec(_))) => self.exec += 1,
                Err(_) => self.dropped += 1,
            }
        }
        fn terminal(&self) -> u64 {
            self.ok + self.timeout + self.exec + self.dead
        }
    }

    // One flooding thread per app; each keeps ≤512 requests in flight
    // (tallying the oldest as it goes) and drains its tail when time is
    // up. The 10s recv timeout only trips on a genuine deadlock — every
    // admitted request is owed a terminal reply.
    let until = Instant::now() + Duration::from_secs(seconds);
    let recv_limit = Duration::from_secs(10);
    let tallies: Vec<Tally> = std::thread::scope(|s| {
        let handles: Vec<_> = apps
            .iter()
            .map(|app| {
                let server = &server;
                s.spawn(move || {
                    let inputs = vec![0.5f64; server.n_inputs(app).unwrap_or(1)];
                    let mut t = Tally::default();
                    let mut pending: VecDeque<Receiver<Reply>> = VecDeque::new();
                    while Instant::now() < until {
                        match server.submit(app, &inputs) {
                            Ok(rx) => {
                                t.admitted += 1;
                                pending.push_back(rx);
                            }
                            Err(_) => t.submit_err += 1,
                        }
                        if pending.len() >= 512 {
                            let rx = pending.pop_front().expect("nonempty");
                            t.absorb(rx.recv_timeout(recv_limit));
                        }
                    }
                    for rx in pending {
                        t.absorb(rx.recv_timeout(recv_limit));
                    }
                    t
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| panic!("chaos submitter thread panicked")))
            .collect()
    });
    server.drain()?;

    let mut total = Tally::default();
    for t in &tallies {
        total.admitted += t.admitted;
        total.submit_err += t.submit_err;
        total.ok += t.ok;
        total.timeout += t.timeout;
        total.exec += t.exec;
        total.dead += t.dead;
        total.dropped += t.dropped;
    }
    let pm = server.pool_metrics();
    let snap = server.snapshot();
    let bl_level = snap.get("serve_pool_bl_level").unwrap_or(0.0);
    println!(
        "chaos: {} admitted → ok={} timeout={} exec_err={} shard_dead={} dropped={} \
         (submit errors {})",
        total.admitted,
        total.ok,
        total.timeout,
        total.exec,
        total.dead,
        total.dropped,
        total.submit_err
    );
    println!(
        "chaos: restarts={} deadline_timeouts={} failed_requests={} degraded_waves={} \
         bl_level={bl_level} dead_shards={:?}",
        pm.executor_restarts,
        pm.deadline_timeouts,
        pm.failed_requests,
        pm.degraded_waves,
        server.dead_shards()
    );

    // Invariant 1: exactly one terminal outcome per admitted request.
    if total.dropped > 0 {
        bail!(
            "{} request(s) dropped without a terminal reply (deadlock or lost wave)",
            total.dropped
        );
    }
    if total.terminal() != total.admitted {
        bail!("terminal outcomes {} != admitted {}", total.terminal(), total.admitted);
    }
    if total.ok == 0 {
        bail!("no request ever succeeded under chaos");
    }
    // Invariant 2: injected panics never exceed their budget, and the
    // supervisor never let one kill a shard (budget < restart allowance).
    if pm.executor_restarts > panics {
        bail!("{} restarts exceed the injected-panic budget {panics}", pm.executor_restarts);
    }
    if !server.dead_shards().is_empty() {
        bail!("shard(s) {:?} died under a bounded panic budget", server.dead_shards());
    }
    // Invariant 3: degradation stays on the configured ladder.
    if bl_level > f64::from(degrade.max_steps) {
        bail!("bl_level {bl_level} beyond the {}-step ladder", degrade.max_steps);
    }
    // Invariant 4: the server still serves cleanly after the storm.
    let calm = &apps[0];
    let inputs = vec![0.5f64; server.n_inputs(calm).unwrap_or(1)];
    for k in 0..8 {
        let rx = server.submit(calm, &inputs)?;
        match rx.recv_timeout(recv_limit) {
            Ok(Ok(_)) | Ok(Err(ServeError::Timeout)) => {}
            Ok(Err(e)) => bail!("post-chaos request {k} failed: {e}"),
            Err(_) => bail!("post-chaos request {k} got no reply"),
        }
    }

    let entries = vec![
        ("chaos_submitted".to_string(), total.admitted as f64),
        ("chaos_ok".to_string(), total.ok as f64),
        ("chaos_timeouts".to_string(), total.timeout as f64),
        ("chaos_exec_errors".to_string(), total.exec as f64),
        ("chaos_shard_dead".to_string(), total.dead as f64),
        ("chaos_submit_errors".to_string(), total.submit_err as f64),
        ("chaos_restarts".to_string(), pm.executor_restarts as f64),
        ("chaos_deadline_timeouts".to_string(), pm.deadline_timeouts as f64),
        ("chaos_degraded_waves".to_string(), pm.degraded_waves as f64),
        ("chaos_bl_level".to_string(), bl_level),
    ];
    let out = std::env::var("STOCH_IMC_CHAOS_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("CHAOS_report.json"));
    benchjson::merge_and_write(&out, &entries)
        .with_context(|| format!("writing {}", out.display()))?;
    println!("chaos: all invariants held; wrote {} keys to {}", entries.len(), out.display());
    Ok(())
}

/// The TCP front door (`serve-tcp [PORT] [shards]`, also reached via
/// `serve --tcp`): start the sharded server, put a `TcpFront` on a
/// loopback port, and serve until SIGTERM/SIGINT (or `--seconds`)
/// triggers the graceful drain. The `--accept-drop/--cut/--trickle/
/// --stall N` flags enable the network chaos injectors on every Nth
/// connection/response/request — the CI loopback storm runs with them
/// live. Ends by writing the stats snapshot (pool + `serve_net_*`).
fn cmd_serve_tcp(args: &[String]) -> Result<()> {
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use stoch_imc::serve::{NetChaos, Server, ServerConfig, TcpFront, TcpFrontConfig};

    let mut pos: Vec<u64> = Vec::new();
    let mut seconds: Option<u64> = None;
    let mut net = NetChaos::default();
    let mut i = 0;
    let take = |args: &[String], i: usize, what: &str| -> Result<u64> {
        args.get(i + 1).and_then(|s| s.parse().ok()).with_context(|| format!("{what} N"))
    };
    while i < args.len() {
        match args[i].as_str() {
            "--seconds" => {
                seconds = Some(take(args, i, "--seconds")?);
                i += 1;
            }
            "--accept-drop" => {
                net.accept_drop_every = take(args, i, "--accept-drop")?;
                i += 1;
            }
            "--cut" => {
                net.cut_every = take(args, i, "--cut")?;
                i += 1;
            }
            "--trickle" => {
                net.trickle_every = take(args, i, "--trickle")?;
                net.trickle_delay = Duration::from_millis(1);
                i += 1;
            }
            "--stall" => {
                net.stall_read_every = take(args, i, "--stall")?;
                net.stall = Duration::from_millis(50);
                i += 1;
            }
            "--config" => i += 1,
            a if a.starts_with("--") => bail!("serve-tcp: unknown flag `{a}`"),
            a => pos.push(a.parse().with_context(|| format!("bad positional `{a}`"))?),
        }
        i += 1;
    }
    let port: Option<u16> = pos.first().map(|&p| p as u16);
    let shards: usize = pos.get(1).map(|&s| s as usize).unwrap_or(0);

    signals::install();
    let scfg = ServerConfig { shards, ..ServerConfig::default() };
    let server = Arc::new(Server::start(&artifact_dir(), scfg)?);
    let mut fcfg = TcpFrontConfig::from_env();
    if let Some(p) = port {
        fcfg.addr = format!("127.0.0.1:{p}");
    }
    fcfg.chaos = net;
    let mut front = TcpFront::start(Arc::clone(&server), fcfg)?;
    println!(
        "serve-tcp: {} app(s) over {} shard(s) on {} — SIGTERM/SIGINT drains{}{}",
        server.apps().len(),
        server.n_shards(),
        front.local_addr(),
        seconds.map(|s| format!(", auto-drain after {s}s")).unwrap_or_default(),
        if net.is_noop() { String::new() } else { format!("; net chaos {net:?}") },
    );

    let t0 = Instant::now();
    loop {
        if signals::requested() {
            println!("serve-tcp: signal received — draining…");
            break;
        }
        if let Some(s) = seconds {
            if t0.elapsed() >= Duration::from_secs(s) {
                println!("serve-tcp: {s}s elapsed — draining…");
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    front.shutdown();
    let snap = front.snapshot();
    print_pool_observability(&snap);
    print_net_observability(&snap);
    let out = write_stats_snapshot(&snap)?;
    println!("serve-tcp: drained cleanly; wrote {} stats keys to {}", snap.len(), out.display());
    Ok(())
}

/// Wire-layer digest from a stats snapshot — the `serve-tcp` sibling
/// of [`print_pool_observability`].
fn print_net_observability(snap: &stoch_imc::obs::MetricsSnapshot) {
    let g = |k: &str| snap.get(k).unwrap_or(0.0);
    println!(
        "net: conns={:.0} (active {:.0}, busy-rejected {:.0}, idle-reaped {:.0}, \
         io-timeouts {:.0})",
        g("serve_net_connections"),
        g("serve_net_active_connections"),
        g("serve_net_busy_rejected"),
        g("serve_net_idle_reaped"),
        g("serve_net_io_timeouts"),
    );
    println!(
        "net: frames rx={:.0} tx={:.0}, protocol-errors={:.0}, shed={:.0}, going-away={:.0}",
        g("serve_net_frames_rx"),
        g("serve_net_frames_tx"),
        g("serve_net_protocol_errors"),
        g("serve_net_shed"),
        g("serve_net_going_away"),
    );
    println!(
        "net: wire latency µs p50={:.0} p95={:.0} p99={:.0} max={:.0}; chaos: drops={:.0} \
         cuts={:.0} trickles={:.0} stalls={:.0}",
        g("serve_net_wire_latency_us_p50"),
        g("serve_net_wire_latency_us_p95"),
        g("serve_net_wire_latency_us_p99"),
        g("serve_net_wire_latency_us_max"),
        g("serve_net_chaos_accept_drops"),
        g("serve_net_chaos_cuts"),
        g("serve_net_chaos_trickles"),
        g("serve_net_chaos_stalls"),
    );
}

/// The loopback storm driver: `--conns` client threads flood `<ADDR>`
/// with Poisson/bursty arrival mixes over every registered artifact,
/// each through a retrying [`Client`](stoch_imc::serve::net::Client)
/// with per-request deadlines. Exits nonzero unless every request
/// reached exactly one terminal outcome, at least one value was
/// delivered, and no well-formed request was rejected as malformed.
/// Writes a flat-JSON report to `STOCH_IMC_NET_OUT` (else
/// `NET_report.json`).
fn cmd_flood(cfg: &Config, args: &[String]) -> Result<()> {
    use std::time::{Duration, Instant};

    use stoch_imc::serve::net::{Client, ClientConfig, NetError, RetryPolicy};
    use stoch_imc::serve::ServeError;
    use stoch_imc::util::benchjson;
    use stoch_imc::util::prng::{mix64, GOLDEN_GAMMA};

    let mut addr: Option<String> = None;
    let mut seconds: u64 = 5;
    let mut conns: u64 = 4;
    let mut rate: f64 = 200.0;
    let mut mix = String::from("mixed");
    let mut deadline_ms: u64 = 250;
    let mut seed: u64 = cfg.seed ^ 0xF100D;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--seconds" => {
                seconds = args.get(i + 1).and_then(|s| s.parse().ok()).context("--seconds S")?;
                i += 1;
            }
            "--conns" => {
                conns = args.get(i + 1).and_then(|s| s.parse().ok()).context("--conns C")?;
                i += 1;
            }
            "--rate" => {
                rate = args.get(i + 1).and_then(|s| s.parse().ok()).context("--rate R")?;
                i += 1;
            }
            "--mix" => {
                mix = args.get(i + 1).cloned().context("--mix poisson|bursty|mixed")?;
                i += 1;
            }
            "--deadline-ms" => {
                deadline_ms =
                    args.get(i + 1).and_then(|s| s.parse().ok()).context("--deadline-ms D")?;
                i += 1;
            }
            "--seed" => {
                seed = args.get(i + 1).and_then(|s| s.parse().ok()).context("--seed N")?;
                i += 1;
            }
            "--config" => i += 1,
            a if a.starts_with("--") => bail!("flood: unknown flag `{a}`"),
            a => addr = Some(a.to_string()),
        }
        i += 1;
    }
    let addr = addr.context(
        "flood <ADDR> [--seconds S] [--conns C] [--rate R] [--mix poisson|bursty|mixed] \
         [--deadline-ms D] [--seed N]",
    )?;
    if !matches!(mix.as_str(), "poisson" | "bursty" | "mixed") {
        bail!("flood: --mix must be poisson|bursty|mixed, got `{mix}`");
    }
    let conns = conns.max(1);
    let rate = if rate.is_finite() && rate > 0.0 { rate } else { 200.0 };

    // App names + arities from the local manifest: the storm cycles
    // through every registered artifact.
    let specs = stoch_imc::runtime::load_manifest(&artifact_dir())?;
    if specs.is_empty() {
        bail!("no artifacts registered under {}", artifact_dir().display());
    }
    println!(
        "flood: {} → {} conn(s) × ~{rate:.0} req/s for {seconds}s, mix={mix}, \
         deadline {deadline_ms}ms, {} app(s), seed {seed}",
        addr,
        conns,
        specs.len()
    );

    /// Terminal-outcome tally; one increment per completed call, so
    /// `terminal()` == calls made is the exactly-once invariant.
    #[derive(Default, Clone, Copy)]
    struct Tally {
        sent: u64,
        ok: u64,
        timeout: u64,
        exec: u64,
        shard_dead: u64,
        overloaded: u64,
        transport: u64,
        protocol: u64,
        bad_request: u64,
        going_away: u64,
        breaker: u64,
        exhausted: u64,
    }
    impl Tally {
        fn absorb(&mut self, r: &std::result::Result<f32, NetError>) {
            match r {
                Ok(_) => self.ok += 1,
                Err(NetError::Serve(ServeError::Timeout)) => self.timeout += 1,
                Err(NetError::Serve(ServeError::ShardDead)) => self.shard_dead += 1,
                Err(NetError::Serve(ServeError::Exec(_))) => self.exec += 1,
                Err(NetError::Overloaded) => self.overloaded += 1,
                Err(NetError::Transport(_)) => self.transport += 1,
                Err(NetError::Protocol(_)) => self.protocol += 1,
                Err(NetError::BadRequest(_)) => self.bad_request += 1,
                Err(NetError::GoingAway) => self.going_away += 1,
                Err(NetError::BreakerOpen) => self.breaker += 1,
                Err(NetError::RetriesExhausted { .. }) => self.exhausted += 1,
            }
        }
        fn terminal(&self) -> u64 {
            self.ok
                + self.timeout
                + self.exec
                + self.shard_dead
                + self.overloaded
                + self.transport
                + self.protocol
                + self.bad_request
                + self.going_away
                + self.breaker
                + self.exhausted
        }
        fn merge(&mut self, o: &Tally) {
            self.sent += o.sent;
            self.ok += o.ok;
            self.timeout += o.timeout;
            self.exec += o.exec;
            self.shard_dead += o.shard_dead;
            self.overloaded += o.overloaded;
            self.transport += o.transport;
            self.protocol += o.protocol;
            self.bad_request += o.bad_request;
            self.going_away += o.going_away;
            self.breaker += o.breaker;
            self.exhausted += o.exhausted;
        }
    }

    let until = Instant::now() + Duration::from_secs(seconds);
    let t0 = Instant::now();
    let per_conn: Vec<(Tally, stoch_imc::serve::net::ClientStats)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..conns)
            .map(|k| {
                let addr = addr.clone();
                let specs = &specs;
                let mix = mix.as_str();
                s.spawn(move || {
                    let mut client = Client::new(
                        addr,
                        ClientConfig {
                            deadline: Some(Duration::from_millis(deadline_ms)),
                            retry: RetryPolicy {
                                seed: seed ^ k.wrapping_mul(GOLDEN_GAMMA),
                                base: Duration::from_millis(5),
                                ..RetryPolicy::from_env()
                            },
                            ..ClientConfig::from_env()
                        },
                    );
                    // Arrival mix per lane: Poisson (exponential gaps)
                    // or bursty (16 back-to-back, then one long gap).
                    let poisson = match mix {
                        "poisson" => true,
                        "bursty" => false,
                        _ => k % 2 == 0,
                    };
                    let mut t = Tally::default();
                    let mut ctr = 0u64;
                    let mut req = 0u64;
                    while Instant::now() < until {
                        let spec = &specs[((k + req) % specs.len() as u64) as usize];
                        let inputs = vec![0.5f64; spec.n_inputs];
                        t.sent += 1;
                        t.absorb(&client.call(&spec.name, &inputs));
                        req += 1;
                        let gap = if poisson {
                            ctr += 1;
                            let bits = mix64(seed ^ k ^ ctr.wrapping_mul(GOLDEN_GAMMA));
                            let u = (((bits >> 11) as f64) / ((1u64 << 53) as f64)).max(1e-12);
                            Duration::from_secs_f64((-u.ln() / rate).min(1.0))
                        } else if req % 16 == 0 {
                            Duration::from_secs_f64((16.0 / rate).min(1.0))
                        } else {
                            Duration::ZERO
                        };
                        if !gap.is_zero() {
                            std::thread::sleep(gap);
                        }
                    }
                    (t, client.stats())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| panic!("flood client thread panicked")))
            .collect()
    });
    let dt = t0.elapsed();

    let mut total = Tally::default();
    let mut retries = 0u64;
    let mut connects = 0u64;
    let mut breaker_fast_fails = 0u64;
    for (t, cs) in &per_conn {
        total.merge(t);
        retries += cs.retries;
        connects += cs.connects;
        breaker_fast_fails += cs.breaker_fast_fails;
    }
    let rps = total.sent as f64 / dt.as_secs_f64().max(1e-9);
    println!(
        "flood: {} sent in {:.2?} ({rps:.0}/s) → ok={} timeout={} exec={} shard_dead={} \
         overloaded={} transport={} protocol={} going_away={} breaker={} exhausted={} \
         bad_request={}",
        total.sent,
        dt,
        total.ok,
        total.timeout,
        total.exec,
        total.shard_dead,
        total.overloaded,
        total.transport,
        total.protocol,
        total.going_away,
        total.breaker,
        total.exhausted,
        total.bad_request,
    );
    println!(
        "flood: client side — {retries} retries, {connects} connects, \
         {breaker_fast_fails} breaker fast-fails"
    );

    let entries = vec![
        ("flood_sent".to_string(), total.sent as f64),
        ("flood_ok".to_string(), total.ok as f64),
        ("flood_timeouts".to_string(), total.timeout as f64),
        ("flood_exec_errors".to_string(), total.exec as f64),
        ("flood_shard_dead".to_string(), total.shard_dead as f64),
        ("flood_overloaded".to_string(), total.overloaded as f64),
        ("flood_transport_errors".to_string(), total.transport as f64),
        ("flood_protocol_errors".to_string(), total.protocol as f64),
        ("flood_going_away".to_string(), total.going_away as f64),
        ("flood_breaker_fast_fails".to_string(), breaker_fast_fails as f64),
        ("flood_retries_exhausted".to_string(), total.exhausted as f64),
        ("flood_bad_requests".to_string(), total.bad_request as f64),
        ("flood_client_retries".to_string(), retries as f64),
        ("flood_client_connects".to_string(), connects as f64),
        ("flood_rate_rps".to_string(), rps),
    ];
    let out = std::env::var("STOCH_IMC_NET_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("NET_report.json"));
    benchjson::merge_and_write(&out, &entries)
        .with_context(|| format!("writing {}", out.display()))?;
    println!("flood: wrote {} keys to {}", entries.len(), out.display());

    // Invariant 1: every request reached exactly one terminal outcome.
    if total.terminal() != total.sent {
        bail!("terminal outcomes {} != sent {} (a call vanished)", total.terminal(), total.sent);
    }
    // Invariant 2: the storm actually delivered values.
    if total.ok == 0 {
        bail!("no request ever succeeded against {addr}");
    }
    // Invariant 3: every frame we send is well-formed, so a BadRequest
    // means the server misdecoded (or the codec regressed).
    if total.bad_request > 0 {
        bail!("{} well-formed request(s) rejected as bad", total.bad_request);
    }
    println!("flood: all client invariants held");
    Ok(())
}

fn cmd_schedule(args: &[String]) -> Result<()> {
    use stoch_imc::netlist::{ops, replicate::replicate};
    use stoch_imc::scheduler::algorithm1::{schedule, Mode, Options};
    let op = args.first().map(String::as_str).unwrap_or("multiply");
    let lanes: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let base = match op {
        "multiply" => ops::multiply(),
        "scaled_add" => ops::scaled_add(),
        "abs_subtract" => ops::abs_subtract(),
        "scaled_divide" => ops::scaled_divide(),
        "square_root" => ops::square_root(6),
        "exponential" => ops::exponential(),
        other => bail!("unknown op `{other}`"),
    };
    let rep = replicate(&base, lanes);
    for mode in [Mode::Asap, Mode::LayerStrict] {
        let s = schedule(&rep, &Options { mode });
        println!(
            "{op} × {lanes} lanes, {mode:?}: {} logic cycles, array {}×{}, {} copies",
            s.logic_cycles(),
            s.rows_used,
            s.cols_used,
            s.copy_count
        );
        if mode == Mode::Asap {
            for (t, step) in s.steps.iter().enumerate() {
                println!(
                    "  t{:<3} {:<8} ×{:<4} in_cols={:?} out_col={}",
                    t + 1,
                    format!("{:?}", step.ops[0].kind),
                    step.ops.len(),
                    step.ops[0].ins.iter().map(|c| c.col).collect::<Vec<_>>(),
                    step.ops[0].out.col
                );
            }
        }
    }
    Ok(())
}
