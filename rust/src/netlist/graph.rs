//! Gate-level netlist IR consumed by Algorithm 1 (S7) and the functional
//! evaluator (`eval.rs`).
//!
//! Conventions (paper §4.2, Fig 7):
//! * **Rows are bit lanes.** A stochastic circuit replicated over a
//!   q-bit sub-bitstream instantiates its gates once per lane; a binary
//!   circuit places bit significance k in row k.
//! * **Primary inputs are columns.** A PI with bit-width q occupies one
//!   column across rows 1..q (Algorithm 1 lines 5–8). Gates read the PI
//!   cell in their own row.
//! * **Delay nodes** carry feedback state (scaled division's Q). They
//!   break combinational cycles: `value(bit i) = input(bit i-1)`, with a
//!   defined initial value. For scheduling they are state *cells*
//!   (columns), not logic steps — see DESIGN.md §7 for the fidelity
//!   discussion.
//! * **Addie nodes** model the counter-based integrator of the square
//!   root circuit (Fig 5e) as a macro with a documented column footprint.

use std::collections::HashMap;

pub type NodeId = usize;

/// Primitive gates of the 2T-1MTJ method (§2.2). The paper's reliable
/// subset for Stoch-IMC is {NOT, BUFF, NAND} (§5.1); the binary baseline
/// additionally uses the inverted majority gates of the CRAM full adder.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GateKind {
    Buff,
    Not,
    And,
    Nand,
    Or,
    Nor,
    /// NOT(MAJ3(a,b,c)) — CRAM carry: C̄out = MAJ3̄(A,B,C).
    Maj3Inv,
    /// NOT(MAJ5(a..e)) — CRAM sum: S̄ = MAJ5̄(A,B,C,C̄out,C̄out); the paper
    /// uses MAJ5 with the complemented carry twice, yielding S directly.
    Maj5Inv,
}

impl GateKind {
    /// Every gate kind, in [`GateKind::index`] order — lets counters use
    /// flat arrays instead of `HashMap<GateKind, _>` on hot-ish paths
    /// (the executor's energy accounting, `energy::OpCounters`).
    pub const ALL: [GateKind; GateKind::COUNT] = [
        GateKind::Buff,
        GateKind::Not,
        GateKind::And,
        GateKind::Nand,
        GateKind::Or,
        GateKind::Nor,
        GateKind::Maj3Inv,
        GateKind::Maj5Inv,
    ];

    /// Number of gate kinds (length of [`GateKind::ALL`]).
    pub const COUNT: usize = 8;

    /// Dense index of this kind into [`GateKind::ALL`].
    pub fn index(self) -> usize {
        match self {
            GateKind::Buff => 0,
            GateKind::Not => 1,
            GateKind::And => 2,
            GateKind::Nand => 3,
            GateKind::Or => 4,
            GateKind::Nor => 5,
            GateKind::Maj3Inv => 6,
            GateKind::Maj5Inv => 7,
        }
    }

    pub fn arity(self) -> usize {
        match self {
            GateKind::Buff | GateKind::Not => 1,
            GateKind::And | GateKind::Nand | GateKind::Or | GateKind::Nor => 2,
            GateKind::Maj3Inv => 3,
            GateKind::Maj5Inv => 5,
        }
    }

    /// Truth function.
    pub fn eval(self, ins: &[bool]) -> bool {
        debug_assert_eq!(ins.len(), self.arity());
        match self {
            GateKind::Buff => ins[0],
            GateKind::Not => !ins[0],
            GateKind::And => ins[0] & ins[1],
            GateKind::Nand => !(ins[0] & ins[1]),
            GateKind::Or => ins[0] | ins[1],
            GateKind::Nor => !(ins[0] | ins[1]),
            GateKind::Maj3Inv => {
                let c = ins.iter().filter(|&&b| b).count();
                !(c >= 2)
            }
            GateKind::Maj5Inv => {
                let c = ins.iter().filter(|&&b| b).count();
                !(c >= 3)
            }
        }
    }

    /// Output-cell preset value required by the 2T-1MTJ method for this
    /// gate ([3,8]: AND/NAND-family presets differ from OR-family).
    pub fn preset_value(self) -> bool {
        match self {
            // AND-like gates preset the output to '1', OR-like to '0'
            // (per the CRAM gate tables; NAND example in Fig 2 presets 0).
            GateKind::And => true,
            GateKind::Nand | GateKind::Buff => false,
            GateKind::Or => false,
            GateKind::Nor | GateKind::Not => true,
            GateKind::Maj3Inv | GateKind::Maj5Inv => true,
        }
    }
}

/// How a primary input's bitstream is generated (drives energy accounting
/// and the correlated-generation requirement of absolute-value subtract).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputClass {
    /// Independent stochastic draw of the input's value.
    Stochastic,
    /// Stochastic draw sharing uniforms with other inputs of the same
    /// correlation group (abs-value subtraction needs SCC=+1).
    Correlated(u32),
    /// Constant-valued stream (e.g. S=0.5 in scaled addition, C_k in the
    /// exponential). Still a stochastic write in-memory.
    ConstStream,
    /// Deterministic binary bit (binary-IMC baseline inputs).
    BinaryBit,
}

#[derive(Debug, Clone)]
pub enum Node {
    /// Primary input occupying one column across `rows` rows
    /// (rows == 1 for binary PIs placed at an explicit `row`).
    Input {
        name: String,
        row: usize,
        rows: usize,
        class: InputClass,
    },
    /// A logic gate instance in row `row`.
    Gate {
        kind: GateKind,
        row: usize,
        ins: Vec<NodeId>,
    },
    /// Feedback state cell: value(bit i) = input(bit i−1), `init` at i=0.
    Delay {
        input: NodeId,
        init: bool,
        row: usize,
    },
    /// Counter-integrator macro (square root, Fig 5e). `x1`, `x2` are the
    /// two independently generated copies of the operand; `cols` is the
    /// documented cell footprint of the macro.
    Addie {
        x1: NodeId,
        x2: NodeId,
        counter_bits: u32,
        cols: usize,
        row: usize,
    },
}

impl Node {
    pub fn row(&self) -> usize {
        match self {
            Node::Input { row, .. }
            | Node::Gate { row, .. }
            | Node::Delay { row, .. }
            | Node::Addie { row, .. } => *row,
        }
    }

    pub fn inputs(&self) -> Vec<NodeId> {
        match self {
            Node::Input { .. } => vec![],
            Node::Gate { ins, .. } => ins.clone(),
            Node::Delay { input, .. } => vec![*input],
            Node::Addie { x1, x2, .. } => vec![*x1, *x2],
        }
    }
}

/// A gate-level netlist with named outputs.
#[derive(Debug, Clone, Default)]
pub struct Netlist {
    pub nodes: Vec<Node>,
    pub outputs: Vec<(String, NodeId)>,
}

impl Netlist {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, node: Node) -> NodeId {
        self.nodes.push(node);
        self.nodes.len() - 1
    }

    pub fn input(&mut self, name: &str, row: usize, rows: usize, class: InputClass) -> NodeId {
        self.add(Node::Input { name: name.into(), row, rows, class })
    }

    pub fn gate(&mut self, kind: GateKind, row: usize, ins: Vec<NodeId>) -> NodeId {
        assert_eq!(ins.len(), kind.arity(), "arity mismatch for {kind:?}");
        self.add(Node::Gate { kind, row, ins })
    }

    pub fn delay(&mut self, input: NodeId, init: bool, row: usize) -> NodeId {
        self.add(Node::Delay { input, init, row })
    }

    pub fn mark_output(&mut self, name: &str, id: NodeId) {
        self.outputs.push((name.into(), id));
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn gate_count(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Gate { .. })).count()
    }

    pub fn input_ids(&self) -> Vec<NodeId> {
        (0..self.nodes.len())
            .filter(|&i| matches!(self.nodes[i], Node::Input { .. }))
            .collect()
    }

    /// Count gates per kind (energy model input).
    pub fn gate_histogram(&self) -> HashMap<GateKind, usize> {
        let mut h = HashMap::new();
        for n in &self.nodes {
            if let Node::Gate { kind, .. } = n {
                *h.entry(*kind).or_insert(0) += 1;
            }
        }
        h
    }

    /// Topological order over the *combinational* graph: `Delay` nodes
    /// are sources (their value is previous-bit state), so feedback
    /// through a Delay does not create a cycle. Panics on a true
    /// combinational cycle.
    pub fn topological_order(&self) -> Vec<NodeId> {
        let n = self.nodes.len();
        let mut indegree = vec![0usize; n];
        let mut succs: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for (id, node) in self.nodes.iter().enumerate() {
            // Delay reads previous-bit state: no combinational dependency.
            if matches!(node, Node::Delay { .. }) {
                continue;
            }
            for dep in node.inputs() {
                succs[dep].push(id);
                indegree[id] += 1;
            }
        }
        let mut queue: Vec<NodeId> =
            (0..n).filter(|&i| indegree[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for &v in &succs[u] {
                indegree[v] -= 1;
                if indegree[v] == 0 {
                    queue.push(v);
                }
            }
        }
        assert_eq!(order.len(), n, "combinational cycle in netlist");
        order
    }

    /// Layer index per node: inputs/delays at 0, gates at
    /// 1 + max(layer of combinational inputs). The netlist depth L of
    /// Algorithm 1 line 2 is `max(layers) `.
    pub fn layers(&self) -> Vec<usize> {
        let order = self.topological_order();
        let mut layer = vec![0usize; self.nodes.len()];
        for &id in &order {
            let node = &self.nodes[id];
            if matches!(node, Node::Input { .. } | Node::Delay { .. }) {
                continue;
            }
            layer[id] = node
                .inputs()
                .iter()
                .map(|&d| {
                    if matches!(self.nodes[d], Node::Delay { .. }) {
                        0
                    } else {
                        layer[d]
                    }
                })
                .max()
                .map_or(1, |m| m + 1);
        }
        layer
    }

    /// Inverse topological order value: distance (in gate levels) from a
    /// node to the farthest primary output it feeds. Algorithm 1 sorts
    /// parallel subsets by the average of this (lines 12–13).
    pub fn inverse_topo_order(&self) -> Vec<usize> {
        let order = self.topological_order();
        let mut dist = vec![0usize; self.nodes.len()];
        for &id in order.iter().rev() {
            let node = &self.nodes[id];
            if matches!(node, Node::Delay { .. }) {
                continue;
            }
            for dep in node.inputs() {
                dist[dep] = dist[dep].max(dist[id] + 1);
            }
        }
        dist
    }

    /// Netlist depth (number of gate layers).
    pub fn depth(&self) -> usize {
        self.layers().into_iter().max().unwrap_or(0)
    }

    /// Highest row index used + 1.
    pub fn row_extent(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| match n {
                Node::Input { row, rows, .. } => row + rows,
                other => other.row() + 1,
            })
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Netlist {
        // out = NAND(NAND(a,b), NOT a), single lane.
        let mut nl = Netlist::new();
        let a = nl.input("a", 0, 1, InputClass::Stochastic);
        let b = nl.input("b", 0, 1, InputClass::Stochastic);
        let n1 = nl.gate(GateKind::Nand, 0, vec![a, b]);
        let n2 = nl.gate(GateKind::Not, 0, vec![a]);
        let out = nl.gate(GateKind::Nand, 0, vec![n1, n2]);
        nl.mark_output("out", out);
        nl
    }

    #[test]
    fn topo_order_respects_deps() {
        let nl = tiny();
        let order = nl.topological_order();
        let pos: Vec<usize> = {
            let mut p = vec![0; nl.len()];
            for (i, &id) in order.iter().enumerate() {
                p[id] = i;
            }
            p
        };
        for (id, node) in nl.nodes.iter().enumerate() {
            for dep in node.inputs() {
                assert!(pos[dep] < pos[id], "dep {dep} after {id}");
            }
        }
    }

    #[test]
    fn layers_and_depth() {
        let nl = tiny();
        let layers = nl.layers();
        assert_eq!(layers[0], 0); // input a
        assert_eq!(layers[2], 1); // NAND(a,b)
        assert_eq!(layers[4], 2); // final NAND
        assert_eq!(nl.depth(), 2);
    }

    #[test]
    fn inverse_topo_distances() {
        let nl = tiny();
        let inv = nl.inverse_topo_order();
        assert_eq!(inv[4], 0); // output gate
        assert_eq!(inv[2], 1); // feeds output
        assert_eq!(inv[0], 2); // a feeds NAND(a,b) at distance 2
    }

    #[test]
    fn delay_breaks_cycles() {
        // q' = NAND(a, delay(q')) — a feedback loop through Delay.
        let mut nl = Netlist::new();
        let a = nl.input("a", 0, 1, InputClass::Stochastic);
        // Reserve the gate id by building with a placeholder then fixing:
        let d = nl.add(Node::Delay { input: 0, init: false, row: 0 });
        let q = nl.gate(GateKind::Nand, 0, vec![a, d]);
        if let Node::Delay { input, .. } = &mut nl.nodes[d] {
            *input = q;
        }
        nl.mark_output("q", q);
        let order = nl.topological_order();
        assert_eq!(order.len(), 3); // no panic, all nodes ordered
    }

    #[test]
    fn maj_gates_truth() {
        assert!(!GateKind::Maj3Inv.eval(&[true, true, false]));
        assert!(GateKind::Maj3Inv.eval(&[true, false, false]));
        assert!(!GateKind::Maj5Inv.eval(&[true, true, true, false, false]));
        assert!(GateKind::Maj5Inv.eval(&[true, true, false, false, false]));
    }

    #[test]
    fn gate_histogram_counts() {
        let nl = tiny();
        let h = nl.gate_histogram();
        assert_eq!(h[&GateKind::Nand], 2);
        assert_eq!(h[&GateKind::Not], 1);
    }
}
