//! Gate-level netlist builders for the six stochastic arithmetic
//! operations (paper Fig 5), restricted to the maximum-reliability gate
//! subset {NOT, BUFF, NAND} the paper uses for Stoch-IMC (§5.1).
//!
//! All builders produce *single-lane* circuits (row 0): functionally a
//! stochastic circuit is one sequential lane; bit-parallel replication
//! across subarray rows is a mapping concern handled by
//! [`super::replicate::replicate`] before scheduling.
//!
//! Gate-count identities used (derived in sc::ops):
//! * multiply      = NOT(NAND(a,b))                             (2 gates)
//! * scaled add    = NAND(NAND(s,a), NAND(NOT s, b))            (4 gates)
//! * abs subtract  = NAND(NAND(a, NOT b), NAND(NOT a, b))       (5 gates)
//! * scaled divide = JK: Q' = NAND(NAND(a, NOT Q), NAND(NOT b, Q))
//!                   with Q a Delay cell                (5 gates + state)
//! * square root   = ADDIE macro on two copies of A     (macro, 7 cells)
//! * exponential   = 5-stage Horner of NAND/NOT                (13 gates)

use super::graph::{GateKind, InputClass, Netlist, Node, NodeId};

/// Footprint (columns) charged for the ADDIE macro, calibrated so the
/// whole sqrt circuit occupies 10 columns per lane as in paper Table 2.
pub const ADDIE_COLS: usize = 7;

/// Default ADDIE integrator resolution for application bitstreams
/// (BL=256): small enough to converge within the stream.
pub const ADDIE_BITS_APP: u32 = 6;

fn nand(nl: &mut Netlist, a: NodeId, b: NodeId) -> NodeId {
    nl.gate(GateKind::Nand, 0, vec![a, b])
}

fn not(nl: &mut Netlist, a: NodeId) -> NodeId {
    nl.gate(GateKind::Not, 0, vec![a])
}

/// AND via the reliable subset: NOT(NAND(a,b)).
pub fn and_rel(nl: &mut Netlist, a: NodeId, b: NodeId) -> NodeId {
    let n = nand(nl, a, b);
    not(nl, n)
}

/// Multiplication: out = a·b.
pub fn multiply() -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input("a", 0, 1, InputClass::Stochastic);
    let b = nl.input("b", 0, 1, InputClass::Stochastic);
    let out = and_rel(&mut nl, a, b);
    nl.mark_output("out", out);
    nl
}

/// Scaled addition: out = s·a + (1−s)·b (s defaults to a 0.5 stream).
pub fn scaled_add() -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input("a", 0, 1, InputClass::Stochastic);
    let b = nl.input("b", 0, 1, InputClass::Stochastic);
    let s = nl.input("s", 0, 1, InputClass::ConstStream);
    let out = mux_into(&mut nl, s, a, b);
    nl.mark_output("out", out);
    nl
}

/// MUX subcircuit: out = s·a + s̄·b = NAND(NAND(s,a), NAND(s̄,b)).
pub fn mux_into(nl: &mut Netlist, s: NodeId, a: NodeId, b: NodeId) -> NodeId {
    let s_bar = not(nl, s);
    let n1 = nand(nl, s, a);
    let n2 = nand(nl, s_bar, b);
    nand(nl, n1, n2)
}

/// Absolute-value subtraction: out = |a−b| with *correlated* inputs
/// (XOR = NAND(NAND(a, b̄), NAND(ā, b))).
pub fn abs_subtract() -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input("a", 0, 1, InputClass::Correlated(0));
    let b = nl.input("b", 0, 1, InputClass::Correlated(0));
    let out = xor_into(&mut nl, a, b);
    nl.mark_output("out", out);
    nl
}

/// XOR subcircuit over the reliable set (5 gates).
pub fn xor_into(nl: &mut Netlist, a: NodeId, b: NodeId) -> NodeId {
    let a_bar = not(nl, a);
    let b_bar = not(nl, b);
    let n1 = nand(nl, a, b_bar);
    let n2 = nand(nl, a_bar, b);
    nand(nl, n1, n2)
}

/// Scaled division: out = a/(a+b) via the JK feedback circuit
/// (Q' = a·Q̄ + b̄·Q, Q₀=0; output is Q).
pub fn scaled_divide() -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input("a", 0, 1, InputClass::Stochastic);
    let b = nl.input("b", 0, 1, InputClass::Stochastic);
    let out = divide_into(&mut nl, a, b);
    nl.mark_output("out", out);
    nl
}

/// JK divider subcircuit; returns the Q (state) node = output.
pub fn divide_into(nl: &mut Netlist, a: NodeId, b: NodeId) -> NodeId {
    // Placeholder delay; re-pointed at q_next below.
    let q = nl.add(Node::Delay { input: 0, init: false, row: 0 });
    let q_bar = not(nl, q);
    let b_bar = not(nl, b);
    let n1 = nand(nl, a, q_bar);
    let n2 = nand(nl, b_bar, q);
    let q_next = nand(nl, n1, n2);
    if let Node::Delay { input, .. } = &mut nl.nodes[q] {
        *input = q_next;
    }
    q
}

/// Square root: out = √A from two independently generated copies of A
/// (ADDIE macro; `counter_bits` trades convergence speed vs resolution).
pub fn square_root(counter_bits: u32) -> Netlist {
    let mut nl = Netlist::new();
    let a1 = nl.input("a1", 0, 1, InputClass::Stochastic);
    let a2 = nl.input("a2", 0, 1, InputClass::Stochastic);
    let out = sqrt_into(&mut nl, a1, a2, counter_bits);
    nl.mark_output("out", out);
    nl
}

/// ADDIE sqrt macro node.
pub fn sqrt_into(nl: &mut Netlist, x1: NodeId, x2: NodeId, counter_bits: u32) -> NodeId {
    nl.add(Node::Addie { x1, x2, counter_bits, cols: ADDIE_COLS, row: 0 })
}

/// Exponential e^{−cA} (5th-order Maclaurin, Fig 5f). Inputs: five
/// independent copies a1..a5 of A and five constant streams c1..c5 of
/// value c/k.
pub fn exponential() -> Netlist {
    let mut nl = Netlist::new();
    let a: Vec<NodeId> = (0..5)
        .map(|k| nl.input(&format!("a{}", k + 1), 0, 1, InputClass::Stochastic))
        .collect();
    let c: Vec<NodeId> = (0..5)
        .map(|k| nl.input(&format!("c{}", k + 1), 0, 1, InputClass::ConstStream))
        .collect();
    let out = exp_into(&mut nl, &a, &c);
    nl.mark_output("out", out);
    nl
}

/// Exponential subcircuit. `a[k]`/`c[k]` are the k-th independent copy /
/// constant stream (k = 0..5). Horner from the innermost stage:
/// acc₅ = NAND(a₅,c₅); acc_k = NAND(NOT(NAND(a_k,c_k)), acc_{k+1}).
pub fn exp_into(nl: &mut Netlist, a: &[NodeId], c: &[NodeId]) -> NodeId {
    assert_eq!(a.len(), 5);
    assert_eq!(c.len(), 5);
    // Innermost stage: 1 − u₅·1 = NOT(u₅) = NAND(a₅, c₅).
    let mut acc = nand(nl, a[4], c[4]);
    for k in (0..4).rev() {
        let u = and_rel(nl, a[k], c[k]); // u_k = a_k·c_k
        acc = nand(nl, u, acc); // 1 − u_k·acc
    }
    acc
}

/// Values of the exponential constant streams for a given c.
pub fn exp_constants(c: f64) -> [f64; 5] {
    std::array::from_fn(|k| c / (k as f64 + 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::graph::Node;

    #[test]
    fn multiply_shape() {
        let nl = multiply();
        assert_eq!(nl.gate_count(), 2); // NAND + NOT
        assert_eq!(nl.len(), 4); // +2 inputs → Table 2 "1×4" per lane
        assert_eq!(nl.depth(), 2);
    }

    #[test]
    fn scaled_add_shape() {
        let nl = scaled_add();
        assert_eq!(nl.gate_count(), 4);
        assert_eq!(nl.len(), 7); // Table 2 "1×7" per lane
    }

    #[test]
    fn abs_subtract_shape() {
        let nl = abs_subtract();
        assert_eq!(nl.gate_count(), 5);
        assert_eq!(nl.len(), 7);
    }

    #[test]
    fn divide_has_feedback_state() {
        let nl = scaled_divide();
        assert_eq!(nl.gate_count(), 5);
        let delays = nl.nodes.iter().filter(|n| matches!(n, Node::Delay { .. })).count();
        assert_eq!(delays, 1);
        // Topological order must still succeed (Delay breaks the cycle).
        assert_eq!(nl.topological_order().len(), nl.len());
    }

    #[test]
    fn sqrt_uses_addie_macro() {
        let nl = square_root(10);
        let addies = nl.nodes.iter().filter(|n| matches!(n, Node::Addie { .. })).count();
        assert_eq!(addies, 1);
        // 2 inputs + macro ⇒ 2 + ADDIE_COLS + output cell ≈ Table 2 "1×10".
    }

    #[test]
    fn exponential_shape() {
        let nl = exponential();
        assert_eq!(nl.gate_count(), 13); // 1 + 4×3
        assert_eq!(nl.len(), 23); // 10 inputs + 13 gates
        assert_eq!(nl.depth(), 6);
    }

    #[test]
    fn reliable_gate_subset_only() {
        for nl in [multiply(), scaled_add(), abs_subtract(), scaled_divide(), exponential()] {
            for node in &nl.nodes {
                if let Node::Gate { kind, .. } = node {
                    assert!(
                        matches!(kind, GateKind::Nand | GateKind::Not | GateKind::Buff),
                        "non-reliable gate {kind:?}"
                    );
                }
            }
        }
    }
}
