//! S3/S4/S5 — gate-level netlist IR, the stochastic operation circuits
//! (Fig 5), binary baseline circuits, lane replication, functional
//! evaluation, the compiled word-parallel gate programs (`plan`) the
//! runtime's wave engine executes up to 256 batch rows at a time
//! (`u64×W` lane words, W ∈ {1, 2, 4}), and the staged pipelines
//! (`staged`) chaining gate plans through StoB→BtoS regeneration.

pub mod binary;
pub mod eval;
pub mod graph;
pub mod ops;
pub mod plan;
pub mod replicate;
pub mod staged;

pub use graph::{GateKind, InputClass, Netlist, Node, NodeId};
pub use plan::{GatePlan, PlanScratch};
pub use staged::{Binding, Stage, StagedPlan};

/// XOR over the reliable gate set at an explicit row (5 gates):
/// NAND(NAND(a, NOT b), NAND(NOT a, b)). Used by binary circuits where
/// gates are spread across rows by bit significance.
pub fn ops_xor_at(nl: &mut Netlist, a: NodeId, b: NodeId, row: usize) -> NodeId {
    let a_bar = nl.gate(GateKind::Not, row, vec![a]);
    let b_bar = nl.gate(GateKind::Not, row, vec![b]);
    let n1 = nl.gate(GateKind::Nand, row, vec![a, b_bar]);
    let n2 = nl.gate(GateKind::Nand, row, vec![a_bar, b]);
    nl.gate(GateKind::Nand, row, vec![n1, n2])
}
