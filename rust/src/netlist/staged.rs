//! Staged gate-plan compilation: multi-stage stochastic pipelines with
//! StoB→BtoS regeneration between stages (paper §5.3, Fig 9).
//!
//! The architecture never leaves the memory path between stages: stage
//! k's output streams are accumulated by the StoB counters, the counts
//! become binary values in the BtoS memory, and the BtoS write
//! regenerates fresh (independent or correlated) streams for stage k+1
//! in the same subarray rows. A [`StagedPlan`] is the compiled software
//! analogue: a chain of [`GatePlan`] stages, each input carrying a
//! [`Binding`] that says where its SNG threshold value comes from — a
//! primary instance value, a compile-time constant, or the StoB value
//! of an earlier stage's output (the regeneration edge).
//!
//! Single-stage kernels are the degenerate case ([`StagedPlan::single`]),
//! so the runtime evaluates *every* artifact through one code path; the
//! multi-stage apps (`app_lit`, `app_kde`) compile their
//! `stoch_cost_netlists` stages into plans the word-parallel wave engine
//! executes lane-major end to end.
//!
//! ## The staged-reference contract
//!
//! [`StagedPlan::eval_row_scalar`] is the scalar golden model of a
//! staged pipeline: per stage, it binds every primary input in netlist
//! node-id order (drawing `bl` uniforms per independent/const input,
//! `bl` *shared* uniforms per correlated group at its first input),
//! evaluates the stage through
//! [`eval_stochastic`](super::eval::eval_stochastic), and reads every
//! output's StoB value (`popcount / bl`), which later stages' `Regen`
//! bindings consume as thresholds. The word-parallel staged executor
//! (`runtime::interp`) replays exactly this draw order through the
//! lockstep RNG bank, so its outputs are **bit-identical** per lane —
//! the same contract the flat kernels have had since the word-parallel
//! engine landed. (The staged apps' legacy per-row evaluators,
//! `apps::{lit,kde}::stoch_value`, interleave their draws differently
//! — per-frame for KDE — and remain as *statistical* references only;
//! the bit-level reference for the engine is this staged-netlist
//! model.)

use std::collections::HashMap;

use super::eval::{eval_stochastic, eval_stochastic_fault};
use super::graph::{InputClass, Netlist, Node};
use super::plan::GatePlan;
use crate::bail;
use crate::error::Result;
use crate::fault::FaultCutoffs;
use crate::sc::bitstream::Bitstream;
use crate::util::prng::Xoshiro256;

/// Where one primary input's SNG threshold value comes from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Binding {
    /// Index into the instance's input values (`x[i]`).
    Input(usize),
    /// Compile-time constant (MUX selects, exponential C_k streams,
    /// the all-ones stream, …). Still generated as a stream in-memory.
    Const(f64),
    /// StoB value of output `output` of earlier stage `stage` — the
    /// in-memory StoB→BtoS regeneration edge. Never produced for
    /// single-stage plans.
    Regen { stage: usize, output: usize },
}

/// One compiled pipeline stage: the source netlist (kept for the scalar
/// golden evaluator and for the Input-node metadata), its compiled gate
/// program, and one binding + input class per primary input, all in
/// netlist node-id order (the SNG draw order).
#[derive(Debug, Clone)]
pub struct Stage {
    /// Source netlist (scalar golden evaluation, input names).
    pub nl: Netlist,
    /// Compiled word-parallel gate program.
    pub plan: GatePlan,
    /// Per-input value bindings, in `plan` input (node-id) order.
    pub bindings: Vec<Binding>,
    /// Per-input generation classes, same order (precomputed so the
    /// wave hot path never walks the node list).
    pub classes: Vec<InputClass>,
}

/// A compiled staged pipeline: stages executed in order, values flowing
/// through StoB→BtoS regeneration bindings, with a designated result
/// output on the final stage.
#[derive(Debug, Clone)]
pub struct StagedPlan {
    stages: Vec<Stage>,
    /// `(stage, output)` of the pipeline result (stage is always the
    /// last one).
    result: (usize, usize),
    /// Instance arity every `Binding::Input` index was validated
    /// against.
    n_inputs: usize,
}

impl StagedPlan {
    /// Compile a pipeline from `(netlist, bindings)` stages. `n_inputs`
    /// is the instance arity (`x.len()`) that `Binding::Input` indices
    /// must stay below; `result` names the final stage's output that is
    /// the pipeline value. Validates the whole regeneration graph up
    /// front so the wave hot path can index without checks.
    pub fn compile(
        n_inputs: usize,
        stages: Vec<(Netlist, Vec<Binding>)>,
        result: &str,
    ) -> Result<Self> {
        if stages.is_empty() {
            bail!("staged plan needs at least one stage");
        }
        let mut compiled: Vec<Stage> = Vec::with_capacity(stages.len());
        for (si, (nl, bindings)) in stages.into_iter().enumerate() {
            let plan = GatePlan::compile(&nl);
            let classes: Vec<InputClass> = nl
                .nodes
                .iter()
                .filter_map(|n| match n {
                    Node::Input { class, .. } => Some(*class),
                    _ => None,
                })
                .collect();
            if classes.is_empty() {
                bail!("stage {si}: netlist has no primary inputs");
            }
            if bindings.len() != plan.n_inputs() {
                bail!(
                    "stage {si}: {} bindings for {} netlist inputs",
                    bindings.len(),
                    plan.n_inputs()
                );
            }
            for (i, (b, class)) in bindings.iter().zip(&classes).enumerate() {
                if matches!(class, InputClass::BinaryBit) {
                    bail!("stage {si} input {i}: binary inputs are not stochastic stages");
                }
                match *b {
                    Binding::Input(ix) if ix >= n_inputs => {
                        bail!("stage {si} input {i}: instance index {ix} out of {n_inputs}")
                    }
                    Binding::Regen { stage, output } => {
                        if stage >= si {
                            bail!(
                                "stage {si} input {i}: regeneration from stage {stage} \
                                 is not an earlier stage"
                            );
                        }
                        let have = compiled[stage].nl.outputs.len();
                        if output >= have {
                            bail!(
                                "stage {si} input {i}: stage {stage} has {have} outputs, \
                                 regeneration asks for output {output}"
                            );
                        }
                    }
                    _ => {}
                }
            }
            compiled.push(Stage { nl, plan, bindings, classes });
        }
        let last = compiled.len() - 1;
        let Some(out) = compiled[last].plan.output_index(result) else {
            bail!("final stage has no output `{result}`");
        };
        Ok(Self { stages: compiled, result: (last, out), n_inputs })
    }

    /// The degenerate single-stage pipeline (the six `op_*` kernels and
    /// the single-stage apps): one netlist, no regeneration edges.
    pub fn single(
        n_inputs: usize,
        nl: Netlist,
        bindings: Vec<Binding>,
        result: &str,
    ) -> Result<Self> {
        Self::compile(n_inputs, vec![(nl, bindings)], result)
    }

    /// Stages in execution order.
    pub fn stages(&self) -> &[Stage] {
        &self.stages
    }

    /// `(stage, output)` of the pipeline result.
    pub fn result(&self) -> (usize, usize) {
        self.result
    }

    /// Instance arity the plan was compiled against.
    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Total executed instructions per time step across all stages
    /// (reporting only).
    pub fn instr_count(&self) -> usize {
        self.stages.iter().map(|s| s.plan.instr_count()).sum()
    }

    /// Total value slots (subarray rows touched per lane) across all
    /// stages — the per-lane utilized-capacity term of the Eq 11 wear
    /// model.
    pub fn n_slots_total(&self) -> usize {
        self.stages.iter().map(|s| s.plan.n_slots()).sum()
    }

    /// Scalar golden evaluation of one instance (see the module docs
    /// for the staged-reference contract). `x` is the clamped instance
    /// (`x.len() >= n_inputs`), `rng` the row's PRNG stream; returns
    /// the result output's StoB value.
    pub fn eval_row_scalar(&self, x: &[f64], bl: usize, rng: &mut Xoshiro256) -> f64 {
        self.eval_row_scalar_core(x, bl, rng, None)
    }

    /// [`eval_row_scalar`] under fault injection — the scalar golden
    /// model of the instrumented lane path. The RNG draw order is
    /// *identical* to the clean evaluator (fault masks are stateless and
    /// consume no draws), so a rate-0 plan reproduces `eval_row_scalar`
    /// bit for bit. Faults hit the three paper sites: SNG output (each
    /// generated input stream, by binding position), gate output (every
    /// gate/ADDIE node inside [`eval_stochastic_fault`]), and StoB read
    /// (each output stream, by output position, before its count is
    /// taken). `row` is the wave-global batch row of this instance.
    pub fn eval_row_scalar_fault(
        &self,
        x: &[f64],
        bl: usize,
        rng: &mut Xoshiro256,
        cuts: &FaultCutoffs,
        row: u64,
    ) -> f64 {
        self.eval_row_scalar_core(x, bl, rng, Some((cuts, row)))
    }

    fn eval_row_scalar_core(
        &self,
        x: &[f64],
        bl: usize,
        rng: &mut Xoshiro256,
        fault: Option<(&FaultCutoffs, u64)>,
    ) -> f64 {
        debug_assert!(x.len() >= self.n_inputs, "instance shorter than plan arity");
        // Per stage: one StoB value per netlist output, in output order.
        let mut stage_vals: Vec<Vec<f64>> = Vec::with_capacity(self.stages.len());
        for (si, stage) in self.stages.iter().enumerate() {
            let mut group_uniforms: HashMap<u32, Vec<f64>> = HashMap::new();
            let mut inputs: HashMap<String, Bitstream> = HashMap::new();
            let mut i = 0;
            for node in &stage.nl.nodes {
                let Node::Input { name, class, .. } = node else { continue };
                let v = resolve(&stage.bindings[i], x, &stage_vals).clamp(0.0, 1.0);
                let mut bs = match class {
                    InputClass::Correlated(g) => {
                        let us = group_uniforms.entry(*g).or_insert_with(|| {
                            let mut u = vec![0.0; bl];
                            rng.fill_f64(&mut u);
                            u
                        });
                        Bitstream::from_uniforms(v, us)
                    }
                    // BinaryBit was rejected at compile time.
                    _ => Bitstream::sample(v, bl, rng),
                };
                if let Some((cuts, row)) = fault {
                    cuts.apply_to_stream(&mut bs, cuts.sng, cuts.sng_site(si, i), row);
                }
                inputs.insert(name.clone(), bs);
                i += 1;
            }
            let mut outs = match fault {
                Some((cuts, row)) => eval_stochastic_fault(&stage.nl, &inputs, cuts, si, row),
                None => eval_stochastic(&stage.nl, &inputs),
            };
            stage_vals.push(
                stage
                    .nl
                    .outputs
                    .iter()
                    .enumerate()
                    .map(|(o, (name, _))| {
                        let bs = outs.get_mut(name).expect("stage output stream");
                        if let Some((cuts, row)) = fault {
                            cuts.apply_to_stream(bs, cuts.stob, cuts.stob_site(si, o), row);
                        }
                        bs.value()
                    })
                    .collect(),
            );
        }
        let (s, o) = self.result;
        stage_vals[s][o]
    }
}

impl StagedPlan {
    /// Scalar golden evaluation of one instance under the **counter**
    /// generator: input site `(si, i)`'s stream is
    /// `CounterRng::keyed(row_seed, sng_node(..))`, thresholded with the
    /// integer [`cutoff`] comparison — the addressing contract the
    /// counter lane path implements, so this is its bit-exact reference.
    /// `row_seed` is the row's lane seed (`runtime`'s `row_seed(seed,
    /// name_hash, row)`), the same value that seeds the row's xoshiro
    /// stream on the compatibility path.
    pub fn eval_row_scalar_counter(&self, x: &[f64], bl: usize, row_seed: u64) -> f64 {
        self.eval_row_scalar_counter_core(x, bl, row_seed, None)
    }

    /// [`eval_row_scalar_counter`] under fault injection; masks are
    /// stateless and consume no draws, exactly as on the xoshiro path.
    pub fn eval_row_scalar_counter_fault(
        &self,
        x: &[f64],
        bl: usize,
        row_seed: u64,
        cuts: &FaultCutoffs,
        row: u64,
    ) -> f64 {
        self.eval_row_scalar_counter_core(x, bl, row_seed, Some((cuts, row)))
    }

    fn eval_row_scalar_counter_core(
        &self,
        x: &[f64],
        bl: usize,
        row_seed: u64,
        fault: Option<(&FaultCutoffs, u64)>,
    ) -> f64 {
        use crate::sc::sng::{cutoff, sng_node, NODE_GROUP, NODE_INPUT};
        use crate::util::prng::CounterRng;
        debug_assert!(x.len() >= self.n_inputs, "instance shorter than plan arity");
        let mut stage_vals: Vec<Vec<f64>> = Vec::with_capacity(self.stages.len());
        for (si, stage) in self.stages.iter().enumerate() {
            // Correlated groups share their stage-local draw stream;
            // materialized once per group at first touch, like the
            // xoshiro path's shared uniforms.
            let mut group_draws: HashMap<u32, Vec<u64>> = HashMap::new();
            let mut inputs: HashMap<String, Bitstream> = HashMap::new();
            let mut i = 0;
            for node in &stage.nl.nodes {
                let Node::Input { name, class, .. } = node else { continue };
                let v = resolve(&stage.bindings[i], x, &stage_vals).clamp(0.0, 1.0);
                let c = cutoff(v);
                let bits: Vec<bool> = match class {
                    InputClass::Correlated(g) => {
                        let draws = group_draws.entry(*g).or_insert_with(|| {
                            let node = sng_node(NODE_GROUP, si, *g as usize);
                            let s = CounterRng::keyed(row_seed, node);
                            (0..bl).map(|t| s.draw_at(t as u64)).collect()
                        });
                        draws.iter().map(|&d| (d >> 11) < c).collect()
                    }
                    // BinaryBit was rejected at compile time.
                    _ => {
                        let s = CounterRng::keyed(row_seed, sng_node(NODE_INPUT, si, i));
                        (0..bl).map(|t| (s.draw_at(t as u64) >> 11) < c).collect()
                    }
                };
                let mut bs = Bitstream::from_bits(&bits);
                if let Some((cuts, row)) = fault {
                    cuts.apply_to_stream(&mut bs, cuts.sng, cuts.sng_site(si, i), row);
                }
                inputs.insert(name.clone(), bs);
                i += 1;
            }
            let mut outs = match fault {
                Some((cuts, row)) => eval_stochastic_fault(&stage.nl, &inputs, cuts, si, row),
                None => eval_stochastic(&stage.nl, &inputs),
            };
            stage_vals.push(
                stage
                    .nl
                    .outputs
                    .iter()
                    .enumerate()
                    .map(|(o, (name, _))| {
                        let bs = outs.get_mut(name).expect("stage output stream");
                        if let Some((cuts, row)) = fault {
                            cuts.apply_to_stream(bs, cuts.stob, cuts.stob_site(si, o), row);
                        }
                        bs.value()
                    })
                    .collect(),
            );
        }
        let (s, o) = self.result;
        stage_vals[s][o]
    }
}

/// Resolve a binding against the instance and the already-computed
/// stage values (`prior[stage][output]` layout for the scalar path).
fn resolve(b: &Binding, x: &[f64], prior: &[Vec<f64>]) -> f64 {
    match *b {
        Binding::Input(i) => x[i],
        Binding::Const(c) => c,
        Binding::Regen { stage, output } => prior[stage][output],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::ops;

    const BL: usize = 16384;

    /// multiply → sqrt over a regenerated intermediate: √(a·b).
    fn mul_sqrt_plan() -> StagedPlan {
        let s1 = ops::multiply();
        let b1 = vec![Binding::Input(0), Binding::Input(1)];
        let s2 = ops::square_root(ops::ADDIE_BITS_APP);
        let b2 = vec![
            Binding::Regen { stage: 0, output: 0 },
            Binding::Regen { stage: 0, output: 0 },
        ];
        StagedPlan::compile(2, vec![(s1, b1), (s2, b2)], "out").expect("mul→sqrt plan")
    }

    #[test]
    fn two_stage_regeneration_tracks_float() {
        let plan = mul_sqrt_plan();
        assert_eq!(plan.stages().len(), 2);
        assert_eq!(plan.result(), (1, 0));
        assert!(plan.instr_count() > 2);
        let mut rng = Xoshiro256::seeded(11);
        let got = plan.eval_row_scalar(&[0.6, 0.6], BL, &mut rng);
        assert!((got - 0.6).abs() < 0.07, "√(0.36) got {got}");
        let mut rng = Xoshiro256::seeded(12);
        let got = plan.eval_row_scalar(&[0.9, 0.4], BL, &mut rng);
        let want = (0.9f64 * 0.4).sqrt();
        assert!((got - want).abs() < 0.07, "got {got} want {want}");
    }

    #[test]
    fn correlated_regenerated_stage_is_exact_abs_difference() {
        // Stage 2's correlated XOR consumes a regenerated value against
        // a constant: |a·b − 0.25| with shared uniforms is exact up to
        // stream noise on the regenerated operand.
        let s1 = ops::multiply();
        let b1 = vec![Binding::Input(0), Binding::Input(1)];
        let s2 = ops::abs_subtract();
        let b2 = vec![Binding::Regen { stage: 0, output: 0 }, Binding::Const(0.25)];
        let plan = StagedPlan::compile(2, vec![(s1, b1), (s2, b2)], "out").unwrap();
        let mut rng = Xoshiro256::seeded(21);
        let got = plan.eval_row_scalar(&[0.9, 0.9], BL, &mut rng);
        let want = (0.81f64 - 0.25).abs();
        assert!((got - want).abs() < 0.03, "got {got} want {want}");
    }

    #[test]
    fn scalar_reference_is_seed_deterministic() {
        let plan = mul_sqrt_plan();
        let a = plan.eval_row_scalar(&[0.5, 0.7], BL, &mut Xoshiro256::seeded(5));
        let b = plan.eval_row_scalar(&[0.5, 0.7], BL, &mut Xoshiro256::seeded(5));
        let c = plan.eval_row_scalar(&[0.5, 0.7], BL, &mut Xoshiro256::seeded(6));
        assert_eq!(a, b, "same seed must replay the same bits");
        assert_ne!(a, c, "different seed must resample");
    }

    #[test]
    fn counter_reference_is_deterministic_and_tracks_float() {
        let plan = mul_sqrt_plan();
        let a = plan.eval_row_scalar_counter(&[0.9, 0.4], BL, 0xFEED);
        let b = plan.eval_row_scalar_counter(&[0.9, 0.4], BL, 0xFEED);
        let c = plan.eval_row_scalar_counter(&[0.9, 0.4], BL, 0xFEED + 1);
        assert_eq!(a, b, "same row seed must replay the same bits");
        assert_ne!(a, c, "different row seed must resample");
        let want = (0.9f64 * 0.4).sqrt();
        assert!((a - want).abs() < 0.07, "got {a} want {want}");
        // And it is a genuinely different stream family from xoshiro:
        // across several instances at the same seed, at least one
        // result must differ (a single-value compare could collide on
        // the 1/BL StoB grid).
        let cases = [[0.9, 0.4], [0.5, 0.7], [0.3, 0.3], [0.8, 0.2], [0.6, 0.9]];
        let ctr: Vec<f64> =
            cases.iter().map(|x| plan.eval_row_scalar_counter(x, BL, 0xFEED)).collect();
        let xos: Vec<f64> = cases
            .iter()
            .map(|x| plan.eval_row_scalar(x, BL, &mut Xoshiro256::seeded(0xFEED)))
            .collect();
        assert_ne!(ctr, xos, "counter and xoshiro stream families should differ");
    }

    #[test]
    fn compile_rejects_malformed_pipelines() {
        let two = || vec![Binding::Input(0), Binding::Input(1)];
        // Binding count mismatch.
        assert!(StagedPlan::compile(2, vec![(ops::multiply(), vec![Binding::Input(0)])], "out")
            .is_err());
        // Instance index out of arity.
        assert!(StagedPlan::compile(
            1,
            vec![(ops::multiply(), vec![Binding::Input(0), Binding::Input(1)])],
            "out"
        )
        .is_err());
        // Regeneration from a non-earlier stage.
        let self_regen = vec![Binding::Regen { stage: 0, output: 0 }, Binding::Input(1)];
        assert!(StagedPlan::compile(2, vec![(ops::multiply(), self_regen)], "out").is_err());
        // Regeneration output out of range.
        assert!(StagedPlan::compile(
            2,
            vec![
                (ops::multiply(), two()),
                (
                    ops::multiply(),
                    vec![Binding::Regen { stage: 0, output: 3 }, Binding::Input(1)]
                ),
            ],
            "out"
        )
        .is_err());
        // Missing result output.
        assert!(StagedPlan::compile(2, vec![(ops::multiply(), two())], "nope").is_err());
        // Empty pipeline.
        assert!(StagedPlan::compile(2, vec![], "out").is_err());
        // A well-formed single stage compiles.
        assert!(StagedPlan::single(2, ops::multiply(), two(), "out").is_ok());
    }
}
