//! Bit-parallel lane replication (paper §4.1/Fig 7b).
//!
//! A stochastic circuit is authored single-lane (`row == 0` everywhere);
//! to compute a q-bit sub-bitstream bit-parallel, the circuit's gates are
//! instantiated once per lane (row), while each primary input becomes a
//! single *column* spanning q rows — one stochastically-written cell per
//! bit, exactly the vertical PI layout of Algorithm 1 lines 5–8.

use super::graph::{Netlist, Node, NodeId};

/// Replicate a single-lane netlist across `q` rows. Outputs are renamed
/// `"<name>@<lane>"`. Input nodes are shared (one column, `rows = q`).
pub fn replicate(nl: &Netlist, q: usize) -> Netlist {
    assert!(q >= 1);
    for node in &nl.nodes {
        assert_eq!(node.row(), 0, "replicate() requires a single-lane netlist");
    }
    let mut out = Netlist::new();
    // Shared PI columns spanning q rows.
    let mut input_map: Vec<Option<NodeId>> = vec![None; nl.len()];
    for (id, node) in nl.nodes.iter().enumerate() {
        if let Node::Input { name, class, .. } = node {
            input_map[id] = Some(out.input(name, 0, q, *class));
        }
    }
    // Per-lane gate instances.
    for lane in 0..q {
        let mut lane_map: Vec<Option<NodeId>> = input_map.clone();
        // Two passes: allocate ids for Delay placeholders first so
        // feedback (which points forward) can resolve.
        for (id, node) in nl.nodes.iter().enumerate() {
            if let Node::Delay { init, .. } = node {
                lane_map[id] =
                    Some(out.add(Node::Delay { input: usize::MAX, init: *init, row: lane }));
            }
        }
        for (id, node) in nl.nodes.iter().enumerate() {
            match node {
                Node::Input { .. } | Node::Delay { .. } => {}
                Node::Gate { kind, ins, .. } => {
                    let ins2 = ins.iter().map(|&i| lane_map[i].expect("fwd ref")).collect();
                    lane_map[id] = Some(out.gate(*kind, lane, ins2));
                }
                Node::Addie { x1, x2, counter_bits, cols, .. } => {
                    let id2 = out.add(Node::Addie {
                        x1: lane_map[*x1].expect("addie x1"),
                        x2: lane_map[*x2].expect("addie x2"),
                        counter_bits: *counter_bits,
                        cols: *cols,
                        row: lane,
                    });
                    lane_map[id] = Some(id2);
                }
            }
        }
        // Resolve Delay feedback targets now that all lane nodes exist.
        for (id, node) in nl.nodes.iter().enumerate() {
            if let Node::Delay { input, .. } = node {
                let new_id = lane_map[id].unwrap();
                let target = lane_map[*input].expect("delay target");
                if let Node::Delay { input: slot, .. } = &mut out.nodes[new_id] {
                    *slot = target;
                }
            }
        }
        for (name, oid) in &nl.outputs {
            let new_oid = lane_map[*oid].expect("output mapped");
            out.mark_output(&format!("{name}@{lane}"), new_oid);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::ops;

    #[test]
    fn replicate_multiply_shapes() {
        let base = ops::multiply();
        let q = 8;
        let rep = replicate(&base, q);
        assert_eq!(rep.gate_count(), base.gate_count() * q);
        assert_eq!(rep.input_ids().len(), 2); // shared PI columns
        assert_eq!(rep.outputs.len(), q);
        assert_eq!(rep.row_extent(), q);
    }

    #[test]
    fn replicate_divide_keeps_feedback_per_lane() {
        let base = ops::scaled_divide();
        let rep = replicate(&base, 4);
        // Each lane owns a Delay; feedback resolves within the lane.
        let delays: Vec<_> = rep
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| match n {
                Node::Delay { input, row, .. } => Some((i, *input, *row)),
                _ => None,
            })
            .collect();
        assert_eq!(delays.len(), 4);
        for (_, input, row) in delays {
            assert_ne!(input, usize::MAX);
            assert_eq!(rep.nodes[input].row(), row, "feedback crosses lanes");
        }
        // Still topologically sortable.
        assert_eq!(rep.topological_order().len(), rep.len());
    }

    #[test]
    fn replicate_depth_unchanged() {
        let base = ops::exponential();
        let rep = replicate(&base, 16);
        assert_eq!(rep.depth(), base.depth());
    }
}
