//! Binary in-memory arithmetic netlists — the binary-IMC baseline
//! ([3,8], paper §5.1): ripple-carry addition, Wallace-tree
//! multiplication, subtraction (two's complement), non-restoring
//! division, Newton–Raphson square root, and Maclaurin exponential.
//!
//! The CRAM full adder is C̄out = MAJ3̄(A,B,C), S = MAJ5(A,B,C,C̄out,C̄out)
//! (paper §4.1). We keep the complement bookkeeping of Fig 7(a) as
//! explicit *polarity tracking*: a [`Bit`] records whether its cell holds
//! the value or its complement. Because MAJ gates are self-dual, MAJ3̄
//! over all-complemented inputs yields the *true* carry, so a ripple
//! chain whose stages alternate input polarity needs no carry inverters —
//! this is why odd rows of Fig 7(a) store Ā, B̄, and it is what makes the
//! 4-bit adder 9 cycles (verified in tests and the Fig 7 bench).
//!
//! All circuits use the IMC gate set {NAND, NOT, BUFF, MAJ3̄, MAJ5̄}.

use super::graph::{GateKind, InputClass, Netlist, NodeId};

/// A mapped bit: a cell plus its polarity (true ⇒ cell stores complement).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bit {
    pub id: NodeId,
    pub pol: bool,
}

impl Bit {
    pub fn new(id: NodeId) -> Self {
        Self { id, pol: false }
    }

    /// Logical complement — free: just flip the polarity flag.
    pub fn complement(self) -> Self {
        Self { id: self.id, pol: !self.pol }
    }
}

/// A fixed-point word, LSB first.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Word {
    pub bits: Vec<Bit>,
}

impl Word {
    pub fn width(&self) -> usize {
        self.bits.len()
    }

    /// One's complement (free, polarity flip per bit).
    pub fn complement(&self) -> Word {
        Word { bits: self.bits.iter().map(|b| b.complement()).collect() }
    }

    /// Take bits `lo..hi` (truncation / shift wiring — zero cost).
    pub fn slice(&self, lo: usize, hi: usize) -> Word {
        Word { bits: self.bits[lo..hi].to_vec() }
    }
}

/// Builder for binary circuits over a shared netlist.
pub struct BinaryBuilder {
    pub nl: Netlist,
    zero: Option<NodeId>,
    /// Rows available; gate row = bit significance mod row budget.
    pub row_budget: usize,
}

impl BinaryBuilder {
    pub fn new(row_budget: usize) -> Self {
        Self { nl: Netlist::new(), zero: None, row_budget }
    }

    fn row(&self, k: usize) -> usize {
        k % self.row_budget.max(1)
    }

    /// Constant 0 cell (preset; shared).
    pub fn const0(&mut self) -> Bit {
        if self.zero.is_none() {
            self.zero = Some(self.nl.input("__zero", 0, 1, InputClass::BinaryBit));
        }
        Bit::new(self.zero.unwrap())
    }

    /// Constant 1: complement-polarity view of the shared 0 cell.
    pub fn const1(&mut self) -> Bit {
        self.const0().complement()
    }

    /// Declare an n-bit input word. `prepolarize` stores odd-significance
    /// bits complemented at write time (the Fig 7a layout) — free, since
    /// the deterministic write can store either polarity.
    pub fn input_word(&mut self, name: &str, width: usize, prepolarize: bool) -> Word {
        let bits = (0..width)
            .map(|k| {
                let row = self.row(k);
                let id = self.nl.input(&format!("{name}{k}"), row, 1, InputClass::BinaryBit);
                Bit { id, pol: prepolarize && k % 2 == 1 }
            })
            .collect();
        Word { bits }
    }

    /// Constant word of `value` (shared const cells + polarity).
    pub fn constant_word(&mut self, value: u64, width: usize) -> Word {
        let bits = (0..width)
            .map(|k| if (value >> k) & 1 == 1 { self.const1() } else { self.const0() })
            .collect();
        Word { bits }
    }

    /// Materialize `bit` at polarity `pol` in `row`, inserting a NOT when
    /// the stored polarity differs.
    pub fn normalize(&mut self, bit: Bit, pol: bool, row: usize) -> Bit {
        if bit.pol == pol {
            bit
        } else {
            Bit { id: self.nl.gate(GateKind::Not, row, vec![bit.id]), pol }
        }
    }

    /// CRAM full adder at stage polarity `p` in `row`: inputs are
    /// normalized to polarity `p`; returns (sum, carry), each at
    /// polarity `!p` (self-duality of the MAJ gates).
    pub fn full_adder(&mut self, a: Bit, b: Bit, c: Bit, p: bool, row: usize) -> (Bit, Bit) {
        let a = self.normalize(a, p, row);
        let b = self.normalize(b, p, row);
        let c = self.normalize(c, p, row);
        let m3 = self.nl.gate(GateKind::Maj3Inv, row, vec![a.id, b.id, c.id]);
        // MAJ5 needs two distinct copies of the carry cell.
        let dup = self.nl.gate(GateKind::Buff, row, vec![m3]);
        let m5 = self.nl.gate(GateKind::Maj5Inv, row, vec![a.id, b.id, c.id, m3, dup]);
        (Bit { id: m5, pol: !p }, Bit { id: m3, pol: !p })
    }

    /// Half adder: sum = XOR (5 gates, polarity handled by normalize),
    /// carry = NAND at complement polarity (1 gate).
    pub fn half_adder(&mut self, a: Bit, b: Bit, row: usize) -> (Bit, Bit) {
        let an = self.normalize(a, false, row);
        let bn = self.normalize(b, false, row);
        let sum = super::ops_xor_at(&mut self.nl, an.id, bn.id, row);
        let carry = self.nl.gate(GateKind::Nand, row, vec![an.id, bn.id]);
        (Bit::new(sum), Bit { id: carry, pol: true })
    }

    /// Ripple-carry adder: a + b + cin. Stage k runs at polarity k%2 (the
    /// Fig 7a alternating layout). Sum bit k comes out at polarity
    /// !(k%2) — callers track polarity. Returns (sum word, carry out).
    pub fn adder(&mut self, a: &Word, b: &Word, cin: Bit) -> (Word, Bit) {
        assert_eq!(a.width(), b.width());
        let mut carry = cin;
        let mut bits = Vec::with_capacity(a.width());
        for k in 0..a.width() {
            let p = k % 2 == 1;
            let row = self.row(k);
            let (s, c) = self.full_adder(a.bits[k], b.bits[k], carry, p, row);
            bits.push(s);
            carry = c;
        }
        (Word { bits }, carry)
    }

    /// Subtraction a − b = a + b̄ + 1 (complement is free).
    pub fn subtractor(&mut self, a: &Word, b: &Word) -> (Word, Bit) {
        let one = self.const1();
        let bc = b.complement();
        self.adder(a, &bc, one)
    }

    /// Unsigned multiplier (Wallace reduction): a (n bits) × b (m bits)
    /// → n+m bits. Partial products are single NAND cells carried at
    /// complement polarity (polarity tracking absorbs the inversion).
    pub fn multiplier(&mut self, a: &Word, b: &Word) -> Word {
        let (n, m) = (a.width(), b.width());
        let out_w = n + m;
        // Column buckets of partial-product bits by significance.
        let mut cols: Vec<Vec<Bit>> = vec![Vec::new(); out_w];
        for i in 0..n {
            for j in 0..m {
                let row = self.row(i + j);
                let ai = self.normalize(a.bits[i], false, row);
                let bj = self.normalize(b.bits[j], false, row);
                let pp = self.nl.gate(GateKind::Nand, row, vec![ai.id, bj.id]);
                cols[i + j].push(Bit { id: pp, pol: true });
            }
        }
        // Wallace reduction to ≤2 bits per column.
        loop {
            let max_h = cols.iter().map(|c| c.len()).max().unwrap();
            if max_h <= 2 {
                break;
            }
            let mut next: Vec<Vec<Bit>> = vec![Vec::new(); out_w];
            for k in 0..out_w {
                let col = std::mem::take(&mut cols[k]);
                let row = self.row(k);
                let mut iter = col.into_iter();
                while let Some(x) = iter.next() {
                    match (iter.next(), iter.next()) {
                        (Some(y), Some(z)) => {
                            let (s, c) = self.full_adder(x, y, z, false, row);
                            next[k].push(s);
                            if k + 1 < out_w {
                                next[k + 1].push(c);
                            }
                        }
                        (Some(y), None) => {
                            let (s, c) = self.half_adder(x, y, row);
                            next[k].push(s);
                            if k + 1 < out_w {
                                next[k + 1].push(c);
                            }
                        }
                        _ => next[k].push(x),
                    }
                }
            }
            cols = next;
        }
        // Final carry-propagate add of the two remaining rows.
        let zero = self.const0();
        let wa = Word {
            bits: (0..out_w).map(|k| *cols[k].first().unwrap_or(&zero)).collect(),
        };
        let wb = Word {
            bits: (0..out_w).map(|k| *cols[k].get(1).unwrap_or(&zero)).collect(),
        };
        let (sum, _) = self.adder(&wa, &wb, zero);
        sum
    }

    /// Conditional ±: if `ctl` then a − b else a + b (non-restoring
    /// division step): per-bit b_k ⊕ ctl, cin = ctl.
    pub fn add_sub(&mut self, a: &Word, b: &Word, ctl: Bit) -> (Word, Bit) {
        let mut bx = Vec::with_capacity(b.width());
        for k in 0..b.width() {
            let row = self.row(k);
            let bn = self.normalize(b.bits[k], false, row);
            let cn = self.normalize(ctl, false, row);
            let x = super::ops_xor_at(&mut self.nl, bn.id, cn.id, row);
            bx.push(Bit::new(x));
        }
        self.adder(a, &Word { bits: bx }, ctl)
    }

    /// Unsigned non-restoring divider: n-bit dividend / n-bit divisor →
    /// n-bit integer quotient. Remainder register is n+1 bits wide.
    pub fn divider(&mut self, dividend: &Word, divisor: &Word) -> Word {
        let n = dividend.width();
        assert_eq!(divisor.width(), n);
        let zero = self.const0();
        let mut d_ext = divisor.clone();
        d_ext.bits.push(zero); // n+1-bit divisor
        let mut r: Word = Word { bits: vec![zero; n + 1] };
        let mut sub_next = self.const1(); // first step subtracts
        let mut q_bits = vec![zero; n];
        for step in 0..n {
            let k = n - 1 - step;
            // Shift remainder left, bringing in dividend bit k.
            let mut shifted = vec![dividend.bits[k]];
            shifted.extend_from_slice(&r.bits[..n]);
            let r_shift = Word { bits: shifted };
            let (r_new, _) = self.add_sub(&r_shift, &d_ext, sub_next);
            // MSB sign: 0 ⇒ R ≥ 0 ⇒ quotient bit 1 and subtract next.
            let sign = r_new.bits[n];
            let row = self.row(k);
            let q = self.normalize(sign.complement(), false, row);
            q_bits[k] = q;
            sub_next = q;
            r = r_new;
        }
        Word { bits: q_bits }
    }

    /// Fixed-point multiply with `frac` fractional bits: full product
    /// then >> frac (wiring), truncated to the wider operand's width.
    pub fn fixmul(&mut self, a: &Word, b: &Word, frac: usize) -> Word {
        let full = self.multiplier(a, b);
        let w = a.width().max(b.width());
        full.slice(frac, (frac + w).min(full.width()))
    }

    /// Fixed-point square root via Newton–Raphson on y = 1/√a:
    /// y_{k+1} = y_k(3 − a·y_k²)/2, then √a = a·y. Three iterations from
    /// y₀ = 1.5 (paper §5.1: "three steps of the Newton–Raphson method").
    /// Input Q0.w in [0.25, 1); internal Q2.w on w+2 bits.
    pub fn sqrt_newton(&mut self, a: &Word) -> Word {
        let w = a.width();
        let iw = w + 2; // Q2.w
        let zero = self.const0();
        let mut a_i = a.clone();
        a_i.bits.push(zero);
        a_i.bits.push(zero);
        // y0 = 1.5 in Q2.w (decent seed across [0.25, 1)).
        let mut y = self.constant_word(3u64 << (w - 1), iw);
        let three = self.constant_word(3u64 << w, iw);
        for _ in 0..3 {
            let y2 = self.fixmul(&y, &y, w); // y², Q2.w
            let ay2 = self.fixmul(&a_i, &y2, w); // a·y²
            let (t, _) = self.subtractor(&three, &ay2); // 3 − a·y²
            let ty = self.fixmul(&y, &t, w);
            // Divide by 2: shift right (wiring only).
            let mut bits = ty.bits[1..].to_vec();
            bits.push(zero);
            y = Word { bits };
        }
        // √a = a·y, back to Q0.w.
        let s = self.fixmul(&a_i, &y, w);
        s.slice(0, w)
    }

    /// Fixed-point e^{−cx} via the same 5th-order Maclaurin/Horner form
    /// the stochastic circuit uses: acc ← 1 − (c/k)·x·acc, k = 5..1.
    /// Input x in Q0.w; output Q0.w.
    pub fn exp_maclaurin(&mut self, x: &Word, c: f64) -> Word {
        let w = x.width();
        let iw = w + 2;
        let zero = self.const0();
        let mut x_i = x.clone();
        x_i.bits.push(zero);
        x_i.bits.push(zero);
        let one = self.constant_word(1u64 << w, iw);
        let to_fix = |v: f64| ((v * (1u64 << w) as f64).round() as u64).min((1u64 << iw) - 1);
        let mut acc = one.clone();
        for k in (1..=5).rev() {
            let ck = self.constant_word(to_fix(c / k as f64), iw);
            let cx = self.fixmul(&ck, &x_i, w);
            let t = self.fixmul(&cx, &acc, w);
            let (next, _) = self.subtractor(&one, &t);
            acc = next;
        }
        // Saturate to Q0.w: if an integer bit is set (acc ≥ 1.0, e.g.
        // x = 0 ⇒ acc = 1.0 exactly), clamp the output to all-ones.
        let sat = self.or_bit(acc.bits[w], acc.bits[w + 1], 0);
        let bits = (0..w)
            .map(|k| self.or_bit(acc.bits[k], sat, self.row(k)))
            .collect();
        Word { bits }
    }

    /// OR over the reliable set: OR(a,b) = NAND(ā, b̄); the complements
    /// come free via polarity normalization.
    pub fn or_bit(&mut self, a: Bit, b: Bit, row: usize) -> Bit {
        // Cells holding ā / b̄ (a NOT is only inserted when the stored
        // polarity is not already complemented).
        let an = self.normalize(a.complement(), false, row);
        let bn = self.normalize(b.complement(), false, row);
        Bit::new(self.nl.gate(GateKind::Nand, row, vec![an.id, bn.id]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::eval::eval_combinational;
    use std::collections::HashMap;

    /// Evaluate a builder's netlist on integer inputs; read a word back.
    fn run(
        b: &BinaryBuilder,
        inputs: &[(&str, u64, usize, bool)], // (name, value, width, prepolarized)
        out: &Word,
    ) -> u64 {
        let mut ins: HashMap<String, bool> = HashMap::new();
        ins.insert("__zero".into(), false);
        for (name, value, width, prepol) in inputs {
            for k in 0..*width {
                let v = (value >> k) & 1 == 1;
                let stored = if *prepol && k % 2 == 1 { !v } else { v };
                ins.insert(format!("{name}{k}"), stored);
            }
        }
        let vals = {
            let mut nl = b.nl.clone();
            for (i, bit) in out.bits.iter().enumerate() {
                nl.mark_output(&format!("__o{i}"), bit.id);
            }
            eval_combinational(&nl, &ins)
        };
        let mut acc = 0u64;
        for (i, bit) in out.bits.iter().enumerate() {
            if vals[&format!("__o{i}")] ^ bit.pol {
                acc |= 1 << i;
            }
        }
        acc
    }

    #[test]
    fn adder_exhaustive_4bit() {
        for a in 0u64..16 {
            for bv in 0u64..16 {
                let mut b = BinaryBuilder::new(4);
                let wa = b.input_word("a", 4, true);
                let wb = b.input_word("b", 4, true);
                let cin = b.const0();
                let (sum, cout) = b.adder(&wa, &wb, cin);
                let mut out = sum.clone();
                out.bits.push(cout);
                let got = run(&b, &[("a", a, 4, true), ("b", bv, 4, true)], &out);
                assert_eq!(got, a + bv, "a={a} b={bv}");
            }
        }
    }

    #[test]
    fn adder_needs_no_polarity_nots_when_prepolarized() {
        let mut b = BinaryBuilder::new(4);
        let wa = b.input_word("a", 4, true);
        let wb = b.input_word("b", 4, true);
        let cin = b.const0();
        let _ = b.adder(&wa, &wb, cin);
        let h = b.nl.gate_histogram();
        assert!(!h.contains_key(&GateKind::Not), "prepolarized RCA should be NOT-free: {h:?}");
        assert_eq!(h[&GateKind::Maj3Inv], 4);
        assert_eq!(h[&GateKind::Maj5Inv], 4);
        assert_eq!(h[&GateKind::Buff], 4);
    }

    #[test]
    fn subtractor_exhaustive_4bit() {
        for a in 0u64..16 {
            for bv in 0u64..=a {
                let mut b = BinaryBuilder::new(4);
                let wa = b.input_word("a", 4, false);
                let wb = b.input_word("b", 4, false);
                let (diff, _) = b.subtractor(&wa, &wb);
                let got = run(&b, &[("a", a, 4, false), ("b", bv, 4, false)], &diff);
                assert_eq!(got, a - bv, "a={a} b={bv}");
            }
        }
    }

    #[test]
    fn multiplier_exhaustive_4x4() {
        for a in 0u64..16 {
            for bv in 0u64..16 {
                let mut b = BinaryBuilder::new(8);
                let wa = b.input_word("a", 4, false);
                let wb = b.input_word("b", 4, false);
                let prod = b.multiplier(&wa, &wb);
                let got = run(&b, &[("a", a, 4, false), ("b", bv, 4, false)], &prod);
                assert_eq!(got, a * bv, "a={a} b={bv}");
            }
        }
    }

    #[test]
    fn multiplier_8x8_spot() {
        for (a, bv) in [(0u64, 0u64), (255, 255), (200, 131), (17, 3), (128, 2)] {
            let mut b = BinaryBuilder::new(16);
            let wa = b.input_word("a", 8, false);
            let wb = b.input_word("b", 8, false);
            let prod = b.multiplier(&wa, &wb);
            let got = run(&b, &[("a", a, 8, false), ("b", bv, 8, false)], &prod);
            assert_eq!(got, a * bv, "a={a} b={bv}");
        }
    }

    #[test]
    fn divider_quotients() {
        for (a, d) in [(100u64, 7u64), (255, 16), (13, 13), (0, 5), (255, 1), (37, 5)] {
            let mut b = BinaryBuilder::new(8);
            let wa = b.input_word("a", 8, false);
            let wd = b.input_word("d", 8, false);
            let q = b.divider(&wa, &wd);
            let got = run(&b, &[("a", a, 8, false), ("d", d, 8, false)], &q);
            assert_eq!(got, a / d, "a={a} d={d}");
        }
    }

    #[test]
    fn sqrt_newton_accuracy() {
        for av in [0.25f64, 0.36, 0.5, 0.64, 0.81, 0.9] {
            let a_fix = (av * 256.0).round() as u64;
            let mut b = BinaryBuilder::new(32);
            let wa = b.input_word("a", 8, false);
            let s = b.sqrt_newton(&wa);
            let got = run(&b, &[("a", a_fix, 8, false)], &s) as f64 / 256.0;
            assert!((got - av.sqrt()).abs() < 0.05, "a={av} got={got} want={}", av.sqrt());
        }
    }

    #[test]
    fn exp_maclaurin_accuracy() {
        for xv in [0.0f64, 0.25, 0.5, 0.75] {
            let x_fix = (xv * 256.0).round() as u64;
            let mut b = BinaryBuilder::new(32);
            let wx = b.input_word("x", 8, false);
            let e = b.exp_maclaurin(&wx, 0.8);
            let got = run(&b, &[("x", x_fix, 8, false)], &e) as f64 / 256.0;
            let want = (-0.8 * xv).exp();
            assert!((got - want).abs() < 0.05, "x={xv} got={got} want={want}");
        }
    }

    #[test]
    fn gate_set_is_imc_only() {
        let mut b = BinaryBuilder::new(8);
        let wa = b.input_word("a", 8, false);
        let wb = b.input_word("b", 8, false);
        let _ = b.multiplier(&wa, &wb);
        for n in &b.nl.nodes {
            if let crate::netlist::Node::Gate { kind, .. } = n {
                assert!(matches!(
                    kind,
                    GateKind::Nand
                        | GateKind::Not
                        | GateKind::Buff
                        | GateKind::Maj3Inv
                        | GateKind::Maj5Inv
                ));
            }
        }
    }
}
