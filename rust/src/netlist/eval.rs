//! Functional evaluation of netlists.
//!
//! Two modes:
//! * [`eval_stochastic`] — bit-sequential evaluation of a single-lane
//!   stochastic circuit over input bitstreams, maintaining Delay/ADDIE
//!   state across bit positions. This is the golden model the scheduled
//!   in-memory execution (S6+S7) and the JAX artifacts must match.
//! * [`eval_combinational`] — one-shot boolean evaluation (binary-IMC
//!   netlists); Delay/ADDIE nodes are not allowed.
//!
//! The ADDIE macro shares `sc::ops::Addie` with the functional oracle so
//! oracle and netlist evaluation are bit-identical for identical seeds.

use std::collections::HashMap;

use super::graph::{Netlist, Node, NodeId};
use crate::fault::FaultCutoffs;
use crate::sc::bitstream::Bitstream;
use crate::sc::ops::{Addie, ADDIE_SEED};

/// Evaluate a single-lane stochastic netlist over `len`-bit inputs.
/// `inputs` maps PI names to bitstreams (all of equal length).
/// Returns the named output bitstreams.
pub fn eval_stochastic(
    nl: &Netlist,
    inputs: &HashMap<String, Bitstream>,
) -> HashMap<String, Bitstream> {
    eval_stochastic_core(nl, inputs, None)
}

/// [`eval_stochastic`] with gate-site fault injection: every gate and
/// ADDIE node's value is XORed with its stateless mask bit right after
/// evaluation, so downstream gates, delay latches, and outputs see the
/// faulted value — the scalar reference of the lane engine's
/// `GatePlan::eval_lanes_fault_into`. Node ids are the mask site
/// indices (they equal the lane path's instruction output slots).
/// `row` is the wave-global batch row this lane evaluates.
pub fn eval_stochastic_fault(
    nl: &Netlist,
    inputs: &HashMap<String, Bitstream>,
    cuts: &FaultCutoffs,
    stage: usize,
    row: u64,
) -> HashMap<String, Bitstream> {
    eval_stochastic_core(nl, inputs, Some((cuts, stage, row)))
}

fn eval_stochastic_core(
    nl: &Netlist,
    inputs: &HashMap<String, Bitstream>,
    fault: Option<(&FaultCutoffs, usize, u64)>,
) -> HashMap<String, Bitstream> {
    let len = inputs
        .values()
        .next()
        .map(|b| b.len())
        .expect("eval_stochastic: no inputs");
    for bs in inputs.values() {
        assert_eq!(bs.len(), len, "input bitstream length mismatch");
    }

    let order = nl.topological_order();
    let mut values = vec![false; nl.len()];
    // Fixed gate-operand scratch: gates never exceed MAX_ARITY inputs,
    // so the hot loop performs no per-gate allocation.
    let mut scratch = [false; super::plan::MAX_ARITY];
    // Persistent state.
    let mut delay_state: HashMap<NodeId, bool> = HashMap::new();
    let mut addie_state: HashMap<NodeId, Addie> = HashMap::new();
    for (id, node) in nl.nodes.iter().enumerate() {
        match node {
            Node::Delay { init, .. } => {
                delay_state.insert(id, *init);
            }
            Node::Addie { counter_bits, .. } => {
                addie_state.insert(id, Addie::new(*counter_bits, ADDIE_SEED ^ id as u64));
            }
            _ => {}
        }
    }

    let mut outs: HashMap<String, Bitstream> = nl
        .outputs
        .iter()
        .map(|(name, _)| (name.clone(), Bitstream::zeros(len)))
        .collect();

    for t in 0..len {
        // Phase 1: combinational evaluation in topological order.
        for &id in &order {
            let mut v = match &nl.nodes[id] {
                Node::Input { name, .. } => inputs
                    .get(name)
                    .unwrap_or_else(|| panic!("missing input '{name}'"))
                    .get(t),
                Node::Gate { kind, ins, .. } => {
                    for (s, &i) in scratch.iter_mut().zip(ins) {
                        *s = values[i];
                    }
                    kind.eval(&scratch[..ins.len()])
                }
                Node::Delay { .. } => delay_state[&id],
                Node::Addie { x1, x2, .. } => {
                    // Alternate the two independent copies, matching
                    // sc::ops::square_root_with.
                    let x = if t % 2 == 0 { values[*x1] } else { values[*x2] };
                    addie_state.get_mut(&id).unwrap().step(x)
                }
            };
            // Gate-site fault: only computing nodes flip (inputs carry
            // SNG-site faults; delays latch already-faulted sources).
            if let Some((cuts, stage, row)) = fault {
                if matches!(&nl.nodes[id], Node::Gate { .. } | Node::Addie { .. })
                    && cuts.mask_bit(cuts.gate, cuts.gate_site(stage, id), row, t as u64)
                {
                    v = !v;
                }
            }
            values[id] = v;
        }
        // Phase 2: latch delay state from this bit's combinational values.
        for (&id, state) in delay_state.iter_mut() {
            if let Node::Delay { input, .. } = &nl.nodes[id] {
                *state = values[*input];
            }
        }
        for (name, out_id) in &nl.outputs {
            if values[*out_id] {
                outs.get_mut(name).unwrap().set(t, true);
            }
        }
    }
    outs
}

/// Evaluate a combinational (binary) netlist once. Inputs are named bits.
pub fn eval_combinational(
    nl: &Netlist,
    inputs: &HashMap<String, bool>,
) -> HashMap<String, bool> {
    let order = nl.topological_order();
    let mut values = vec![false; nl.len()];
    let mut scratch = [false; super::plan::MAX_ARITY];
    for &id in &order {
        values[id] = match &nl.nodes[id] {
            Node::Input { name, .. } => *inputs
                .get(name)
                .unwrap_or_else(|| panic!("missing input '{name}'")),
            Node::Gate { kind, ins, .. } => {
                for (s, &i) in scratch.iter_mut().zip(ins) {
                    *s = values[i];
                }
                kind.eval(&scratch[..ins.len()])
            }
            Node::Delay { .. } | Node::Addie { .. } => {
                panic!("sequential node in combinational netlist")
            }
        };
    }
    nl.outputs
        .iter()
        .map(|(name, id)| (name.clone(), values[*id]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::ops;
    use crate::sc::ops as sc_ops;
    use crate::util::check::forall;
    use crate::util::prng::Xoshiro256;

    const LEN: usize = 16384;

    fn streams(pairs: &[(&str, Bitstream)]) -> HashMap<String, Bitstream> {
        pairs.iter().map(|(n, b)| (n.to_string(), b.clone())).collect()
    }

    #[test]
    fn netlist_multiply_matches_oracle_exactly() {
        forall(0x90, 20, |g| {
            let (pa, pb) = (g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0));
            let mut rng = Xoshiro256::seeded(g.u64_below(1 << 62));
            let a = Bitstream::sample(pa, LEN, &mut rng);
            let b = Bitstream::sample(pb, LEN, &mut rng);
            let nl = ops::multiply();
            let got = eval_stochastic(&nl, &streams(&[("a", a.clone()), ("b", b.clone())]));
            assert_eq!(got["out"], sc_ops::multiply(&a, &b));
        });
    }

    #[test]
    fn netlist_scaled_add_matches_oracle_exactly() {
        let mut rng = Xoshiro256::seeded(1);
        let a = Bitstream::sample(0.3, LEN, &mut rng);
        let b = Bitstream::sample(0.8, LEN, &mut rng);
        let s = Bitstream::sample(0.5, LEN, &mut rng);
        let nl = ops::scaled_add();
        let got = eval_stochastic(
            &nl,
            &streams(&[("a", a.clone()), ("b", b.clone()), ("s", s.clone())]),
        );
        assert_eq!(got["out"], sc_ops::scaled_add(&a, &b, &s));
    }

    #[test]
    fn netlist_abs_subtract_matches_oracle_exactly() {
        let mut rng = Xoshiro256::seeded(2);
        let vs = crate::sc::encode::encode_correlated(&[0.7, 0.25], LEN, &mut rng);
        let nl = ops::abs_subtract();
        let got =
            eval_stochastic(&nl, &streams(&[("a", vs[0].clone()), ("b", vs[1].clone())]));
        assert_eq!(got["out"], sc_ops::abs_subtract_correlated(&vs[0], &vs[1]));
    }

    #[test]
    fn netlist_divide_matches_oracle_exactly() {
        forall(0x91, 10, |g| {
            let (pa, pb) = (g.f64_in(0.1, 0.9), g.f64_in(0.1, 0.9));
            let mut rng = Xoshiro256::seeded(g.u64_below(1 << 62));
            let a = Bitstream::sample(pa, LEN, &mut rng);
            let b = Bitstream::sample(pb, LEN, &mut rng);
            let nl = ops::scaled_divide();
            let got = eval_stochastic(&nl, &streams(&[("a", a.clone()), ("b", b.clone())]));
            assert_eq!(got["out"], sc_ops::scaled_divide(&a, &b));
        });
    }

    #[test]
    fn netlist_sqrt_converges() {
        // Seeds differ between the oracle (raw ADDIE_SEED) and netlist
        // (id-mixed), so compare values, not bits.
        let mut rng = Xoshiro256::seeded(3);
        let p = 0.6;
        let a1 = Bitstream::sample(p, LEN, &mut rng);
        let a2 = Bitstream::sample(p, LEN, &mut rng);
        let nl = ops::square_root(10);
        let got = eval_stochastic(&nl, &streams(&[("a1", a1), ("a2", a2)]));
        assert!((got["out"].value() - p.sqrt()).abs() < 0.05);
    }

    #[test]
    fn netlist_exponential_matches_oracle_value() {
        let mut rng = Xoshiro256::seeded(4);
        let p = 0.5;
        let c = 0.8;
        let a = sc_ops::independent_copies(p, LEN, &mut rng);
        let cs = sc_ops::exp_constant_streams(c, LEN, &mut rng);
        let nl = ops::exponential();
        let mut inputs = HashMap::new();
        for k in 0..5 {
            inputs.insert(format!("a{}", k + 1), a[k].clone());
            inputs.insert(format!("c{}", k + 1), cs[k].clone());
        }
        let got = eval_stochastic(&nl, &inputs);
        assert_eq!(got["out"], sc_ops::exponential(&a, &cs));
    }

    #[test]
    fn combinational_eval_simple() {
        use crate::netlist::graph::{GateKind, InputClass, Netlist};
        let mut nl = Netlist::new();
        let a = nl.input("a", 0, 1, InputClass::BinaryBit);
        let b = nl.input("b", 1, 1, InputClass::BinaryBit);
        let g = nl.gate(GateKind::Nand, 0, vec![a, b]);
        nl.mark_output("y", g);
        for (va, vb) in [(false, false), (false, true), (true, false), (true, true)] {
            let mut ins = HashMap::new();
            ins.insert("a".to_string(), va);
            ins.insert("b".to_string(), vb);
            let out = eval_combinational(&nl, &ins);
            assert_eq!(out["y"], !(va & vb));
        }
    }
}
