//! Netlist → flat gate program compilation for word-parallel waves.
//!
//! [`eval_stochastic`](super::eval::eval_stochastic) is the golden
//! model: one lane, one bit at a time, `HashMap` lookups per bit. This
//! module compiles a [`Netlist`] once into a [`GatePlan`] — a
//! topologically ordered, struct-of-arrays instruction list with
//! pre-resolved value slots — and evaluates it over transposed
//! [`LaneBlock`] inputs, up to `64·W` batch rows per `[u64; W]` lane
//! word per instruction (`W ∈ {1, 2, 4}` → 64/128/256-row blocks; the
//! per-instruction word loops are over contiguous fixed-size arrays,
//! so they autovectorize).
//!
//! Time stays sequential (the outer loop walks bit positions), which is
//! what keeps the stateful nodes exact:
//!
//! * **Delay** feedback latches one lane word per node at the end of
//!   each step, so every lane sees its own previous-bit state.
//! * **ADDIE** runs as a per-lane scalar island (`AddieLanes`): the
//!   scalar [`Addie`](crate::sc::ops::Addie) draws two `next_below`
//!   samples per step from a seed that depends only on the node id —
//!   never the batch row — and Lemire rejection consumes a
//!   lane-independent number of raw draws, so all lanes share one
//!   RNG stream and differ only in their saturating counters. The
//!   word-parallel output is bit-identical to `64·W` scalar ADDIEs.
//!
//! Combinational gates execute as single bitwise ops across all lanes;
//! dead lanes (ragged `live % (64·W) != 0` blocks) compute garbage that
//! is masked at the output boundary and can never contaminate live
//! lanes (no instruction mixes lanes).
//!
//! Evaluation scratch (slot values, delay latches, ADDIE counters,
//! output blocks) lives in a caller-owned [`PlanScratch`], so a wave
//! worker allocates once and reuses it for every lane block it
//! evaluates ([`GatePlan::eval_lanes_into`]); [`GatePlan::eval_lanes`]
//! is the allocating convenience wrapper.

use super::graph::{GateKind, Netlist, Node};
use crate::fault::FaultCutoffs;
use crate::sc::bitplane::{LaneBlock, LANES};
use crate::sc::ops::ADDIE_SEED;
use crate::util::prng::Xoshiro256;

/// Widest gate fan-in ([`GateKind::Maj5Inv`]).
pub const MAX_ARITY: usize = 5;

/// One word-parallel instruction opcode. Gate opcodes mirror
/// [`GateKind`]; `Addie` dispatches into the plan's per-lane counter
/// island.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Buff,
    Not,
    And,
    Nand,
    Or,
    Nor,
    Maj3Inv,
    Maj5Inv,
    /// Index into the plan's ADDIE table.
    Addie(u32),
}

impl Op {
    fn from_kind(kind: GateKind) -> Self {
        match kind {
            GateKind::Buff => Op::Buff,
            GateKind::Not => Op::Not,
            GateKind::And => Op::And,
            GateKind::Nand => Op::Nand,
            GateKind::Or => Op::Or,
            GateKind::Nor => Op::Nor,
            GateKind::Maj3Inv => Op::Maj3Inv,
            GateKind::Maj5Inv => Op::Maj5Inv,
        }
    }
}

/// One instruction: opcode, fixed-width input slot array (no per-gate
/// `Vec`), output slot. Slots index the flat value array.
#[derive(Debug, Clone)]
struct Instr {
    op: Op,
    out: u32,
    ins: [u32; MAX_ARITY],
}

/// Delay feedback cell: `slot` reads last step's latch at the top of
/// each step; `src` is latched at the bottom.
#[derive(Debug, Clone)]
struct DelaySlot {
    slot: u32,
    src: u32,
    init: bool,
}

/// ADDIE macro instance: operand slots, counter resolution, and the
/// node-id-mixed seed that matches the golden model exactly.
#[derive(Debug, Clone)]
struct AddieSlot {
    counter_bits: u32,
    seed: u64,
}

/// Lane-word bitwise helpers: each is one bitwise op per `u64` of the
/// lane word, over a fixed-size array the compiler unrolls/vectorizes.
#[inline(always)]
fn wand<const W: usize>(a: [u64; W], b: [u64; W]) -> [u64; W] {
    std::array::from_fn(|k| a[k] & b[k])
}

#[inline(always)]
fn wor<const W: usize>(a: [u64; W], b: [u64; W]) -> [u64; W] {
    std::array::from_fn(|k| a[k] | b[k])
}

#[inline(always)]
fn wxor<const W: usize>(a: [u64; W], b: [u64; W]) -> [u64; W] {
    std::array::from_fn(|k| a[k] ^ b[k])
}

#[inline(always)]
fn wnot<const W: usize>(a: [u64; W]) -> [u64; W] {
    std::array::from_fn(|k| !a[k])
}

/// A compiled, reusable gate program. Compile once per kernel at load
/// time, evaluate per lane block with no allocations or map lookups
/// inside the time loop.
#[derive(Debug, Clone)]
pub struct GatePlan {
    n_slots: usize,
    instrs: Vec<Instr>,
    /// Primary inputs as (name, slot), in netlist node-id order — the
    /// same order the per-row SNG draws streams in, so callers can bind
    /// generated streams positionally.
    inputs: Vec<(String, u32)>,
    outputs: Vec<(String, u32)>,
    delays: Vec<DelaySlot>,
    addies: Vec<AddieSlot>,
}

/// Caller-owned evaluation scratch for [`GatePlan::eval_lanes_into`]:
/// slot values, delay latches, ADDIE islands, and the output blocks,
/// all reusable across lane blocks (and cheap no-op resizes once
/// warm). One instance per wave worker.
#[derive(Debug, Default)]
pub struct PlanScratch<const W: usize> {
    values: Vec<[u64; W]>,
    latches: Vec<[u64; W]>,
    addies: Vec<AddieLanes<W>>,
    outs: Vec<LaneBlock<W>>,
}

impl<const W: usize> PlanScratch<W> {
    /// The output blocks of the most recent
    /// [`GatePlan::eval_lanes_into`] call, in netlist output order.
    pub fn outputs(&self) -> &[LaneBlock<W>] {
        &self.outs
    }
}

impl GatePlan {
    /// Compile `nl` into a flat instruction list (topological order,
    /// one value slot per node).
    pub fn compile(nl: &Netlist) -> Self {
        let mut inputs = Vec::new();
        let mut delays = Vec::new();
        for (id, node) in nl.nodes.iter().enumerate() {
            match node {
                Node::Input { name, .. } => inputs.push((name.clone(), id as u32)),
                Node::Delay { input, init, .. } => delays.push(DelaySlot {
                    slot: id as u32,
                    src: *input as u32,
                    init: *init,
                }),
                _ => {}
            }
        }
        let mut instrs = Vec::with_capacity(nl.len());
        let mut addies = Vec::new();
        for id in nl.topological_order() {
            match &nl.nodes[id] {
                // Inputs and delays are loaded at the top of each time
                // step, not executed as instructions.
                Node::Input { .. } | Node::Delay { .. } => {}
                Node::Gate { kind, ins, .. } => {
                    let mut slots = [0u32; MAX_ARITY];
                    for (s, &i) in slots.iter_mut().zip(ins) {
                        *s = i as u32;
                    }
                    instrs.push(Instr { op: Op::from_kind(*kind), out: id as u32, ins: slots });
                }
                Node::Addie { x1, x2, counter_bits, .. } => {
                    let idx = addies.len() as u32;
                    addies.push(AddieSlot {
                        counter_bits: *counter_bits,
                        seed: ADDIE_SEED ^ id as u64,
                    });
                    let mut slots = [0u32; MAX_ARITY];
                    slots[0] = *x1 as u32;
                    slots[1] = *x2 as u32;
                    instrs.push(Instr { op: Op::Addie(idx), out: id as u32, ins: slots });
                }
            }
        }
        let outputs =
            nl.outputs.iter().map(|(name, id)| (name.clone(), *id as u32)).collect();
        Self { n_slots: nl.len(), instrs, inputs, outputs, delays, addies }
    }

    /// Primary-input names in binding order (netlist node-id order).
    pub fn input_names(&self) -> impl Iterator<Item = &str> {
        self.inputs.iter().map(|(n, _)| n.as_str())
    }

    pub fn n_inputs(&self) -> usize {
        self.inputs.len()
    }

    /// Index of output `name` into the evaluated output blocks.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|(n, _)| n == name)
    }

    /// Executed instructions per time step (gates + ADDIE macros).
    pub fn instr_count(&self) -> usize {
        self.instrs.len()
    }

    /// Value slots (== netlist nodes): the per-lane cell footprint the
    /// wear model charges as utilized capacity.
    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// Output streams this plan produces (StoB conversions per lane).
    pub fn n_outputs(&self) -> usize {
        self.outputs.len()
    }

    /// ADDIE macro instances (counter islands) in this plan.
    pub fn addie_count(&self) -> usize {
        self.addies.len()
    }

    /// Per-kind gate-instruction counts (ADDIE macros excluded — they
    /// are counted by [`GatePlan::addie_count`]). One firing per
    /// instruction per lane per bit, which is what the executor's
    /// `energy::OpCounters` accumulates.
    pub fn gate_histogram(&self) -> [u64; GateKind::COUNT] {
        let mut hist = [0u64; GateKind::COUNT];
        for instr in &self.instrs {
            let kind = match instr.op {
                Op::Buff => GateKind::Buff,
                Op::Not => GateKind::Not,
                Op::And => GateKind::And,
                Op::Nand => GateKind::Nand,
                Op::Or => GateKind::Or,
                Op::Nor => GateKind::Nor,
                Op::Maj3Inv => GateKind::Maj3Inv,
                Op::Maj5Inv => GateKind::Maj5Inv,
                Op::Addie(_) => continue,
            };
            hist[kind.index()] += 1;
        }
        hist
    }

    /// Evaluate all lanes of a block: `inputs[i]` is the transposed
    /// stream block bound to `self.inputs[i]` (equal lengths, equal
    /// lane counts). Returns one [`LaneBlock`] per netlist output, in
    /// netlist output order. Each lane's bits are identical to running
    /// [`eval_stochastic`](super::eval::eval_stochastic) on that lane's
    /// streams alone. Allocating wrapper over
    /// [`GatePlan::eval_lanes_into`].
    pub fn eval_lanes<const W: usize>(&self, inputs: &[LaneBlock<W>]) -> Vec<LaneBlock<W>> {
        let mut ws = PlanScratch::default();
        self.eval_lanes_into(inputs, &mut ws);
        ws.outs
    }

    /// [`GatePlan::eval_lanes`] into a caller-owned [`PlanScratch`]:
    /// no allocations once the scratch is warm, so a wave worker can
    /// evaluate many lane blocks back to back. Returns the output
    /// blocks (also reachable via [`PlanScratch::outputs`]).
    pub fn eval_lanes_into<'ws, const W: usize>(
        &self,
        inputs: &[LaneBlock<W>],
        ws: &'ws mut PlanScratch<W>,
    ) -> &'ws [LaneBlock<W>] {
        // `FAULTY = false` compiles to exactly the pre-instrumentation
        // hot loop: the fault branches are `if false` and fold away.
        self.eval_core::<W, false>(inputs, ws, None)
    }

    /// Fault-instrumented [`GatePlan::eval_lanes_into`]: after every
    /// gate/ADDIE instruction the stage's gate-site mask is XORed into
    /// the produced lane word (so downstream gates, delay latches, and
    /// outputs all see the faulted value — same visibility as the
    /// scalar reference), and every output stream is XORed with its
    /// StoB-site mask as it is read out. `stage`/`row0` locate this
    /// evaluation inside the wave for the stateless mask source.
    pub fn eval_lanes_fault_into<'ws, const W: usize>(
        &self,
        inputs: &[LaneBlock<W>],
        ws: &'ws mut PlanScratch<W>,
        cuts: &FaultCutoffs,
        stage: usize,
        row0: usize,
    ) -> &'ws [LaneBlock<W>] {
        self.eval_core::<W, true>(inputs, ws, Some((cuts, stage, row0)))
    }

    fn eval_core<'ws, const W: usize, const FAULTY: bool>(
        &self,
        inputs: &[LaneBlock<W>],
        ws: &'ws mut PlanScratch<W>,
        fault: Option<(&FaultCutoffs, usize, usize)>,
    ) -> &'ws [LaneBlock<W>] {
        assert_eq!(inputs.len(), self.inputs.len(), "input block count mismatch");
        let len = inputs.first().map_or(0, |m| m.len());
        let lanes = inputs.first().map_or(0, |m| m.lanes());
        for m in inputs {
            assert_eq!(m.len(), len, "input block length mismatch");
            assert_eq!(m.lanes(), lanes, "input block lane-count mismatch");
        }
        // (Re)shape the scratch; every piece below is overwritten
        // before it is read, so stale values from the previous block
        // are harmless.
        ws.values.resize(self.n_slots, [0u64; W]);
        ws.latches.clear();
        ws.latches
            .extend(self.delays.iter().map(|d| if d.init { [u64::MAX; W] } else { [0u64; W] }));
        if ws.addies.len() == self.addies.len() {
            for (a, spec) in ws.addies.iter_mut().zip(&self.addies) {
                a.reset(spec);
            }
        } else {
            ws.addies.clear();
            ws.addies.extend(self.addies.iter().map(AddieLanes::new));
        }
        if ws.outs.len() == self.outputs.len() {
            for o in ws.outs.iter_mut() {
                o.reset(len, lanes);
            }
        } else {
            ws.outs.clear();
            ws.outs.extend(self.outputs.iter().map(|_| LaneBlock::zeros(len, lanes)));
        }
        for t in 0..len {
            for (m, (_, slot)) in inputs.iter().zip(&self.inputs) {
                ws.values[*slot as usize] = m.word(t);
            }
            for (latch, d) in ws.latches.iter().zip(&self.delays) {
                ws.values[d.slot as usize] = *latch;
            }
            for instr in &self.instrs {
                let a = ws.values[instr.ins[0] as usize];
                let v = match instr.op {
                    Op::Buff => a,
                    Op::Not => wnot(a),
                    Op::And => wand(a, ws.values[instr.ins[1] as usize]),
                    Op::Nand => wnot(wand(a, ws.values[instr.ins[1] as usize])),
                    Op::Or => wor(a, ws.values[instr.ins[1] as usize]),
                    Op::Nor => wnot(wor(a, ws.values[instr.ins[1] as usize])),
                    Op::Maj3Inv => {
                        let b = ws.values[instr.ins[1] as usize];
                        let c = ws.values[instr.ins[2] as usize];
                        wnot(wor(wor(wand(a, b), wand(a, c)), wand(b, c)))
                    }
                    Op::Maj5Inv => {
                        // Bit-sliced count of five one-bit addends via a
                        // two-full-adder chain: count = s + 2(c1 + c2).
                        let b = ws.values[instr.ins[1] as usize];
                        let c = ws.values[instr.ins[2] as usize];
                        let d = ws.values[instr.ins[3] as usize];
                        let e = ws.values[instr.ins[4] as usize];
                        let s1 = wxor(wxor(a, b), c);
                        let c1 = wor(wand(a, b), wand(c, wxor(a, b)));
                        let s2 = wxor(wxor(s1, d), e);
                        let c2 = wor(wand(s1, d), wand(e, wxor(s1, d)));
                        // count ≥ 3 ⇔ both carries, or one carry + sum.
                        wnot(wor(wand(c1, c2), wand(wor(c1, c2), s2)))
                    }
                    Op::Addie(k) => {
                        let x = if t % 2 == 0 { a } else { ws.values[instr.ins[1] as usize] };
                        ws.addies[k as usize].step(x)
                    }
                };
                let v = if FAULTY {
                    let (cuts, stage, row0) = fault.expect("fault context");
                    let site = cuts.gate_site(stage, instr.out as usize);
                    wxor(v, cuts.mask_words::<W>(cuts.gate, site, row0, lanes, t))
                } else {
                    v
                };
                ws.values[instr.out as usize] = v;
            }
            for (latch, d) in ws.latches.iter_mut().zip(&self.delays) {
                *latch = ws.values[d.src as usize];
            }
            for (o, (out, (_, slot))) in ws.outs.iter_mut().zip(&self.outputs).enumerate() {
                out.set_word(t, ws.values[*slot as usize]);
                if FAULTY {
                    let (cuts, stage, row0) = fault.expect("fault context");
                    let site = cuts.stob_site(stage, o);
                    out.xor_word(t, cuts.mask_words::<W>(cuts.stob, site, row0, lanes, t));
                }
            }
        }
        &ws.outs
    }
}

/// `64·W` independent ADDIE counters sharing one RNG stream (see the
/// module docs for why sharing is exact): per step, two `next_below`
/// draws are compared against every lane's own counter.
#[derive(Debug, Clone)]
struct AddieLanes<const W: usize> {
    max: u64,
    c: Vec<u64>,
    rng: Xoshiro256,
}

impl<const W: usize> AddieLanes<W> {
    fn new(spec: &AddieSlot) -> Self {
        let max = 1u64 << spec.counter_bits;
        Self { max, c: vec![max / 2; W * LANES], rng: Xoshiro256::seeded(spec.seed) }
    }

    /// Rewind to the start-of-block state (counters at midpoint, RNG at
    /// the node seed), reusing the counter allocation.
    fn reset(&mut self, spec: &AddieSlot) {
        self.max = 1u64 << spec.counter_bits;
        self.c.clear();
        self.c.resize(W * LANES, self.max / 2);
        self.rng = Xoshiro256::seeded(spec.seed);
    }

    /// One time step across all lanes: bit `l` of `x` is lane `l`'s
    /// input; returns lane `l`'s output in bit `l`. Mirrors
    /// [`Addie::step`](crate::sc::ops::Addie::step) per lane.
    fn step(&mut self, x: [u64; W]) -> [u64; W] {
        let d1 = self.rng.next_below(self.max);
        let d2 = self.rng.next_below(self.max);
        let mut y = [0u64; W];
        for (l, c) in self.c.iter_mut().enumerate() {
            let y1 = d1 < *c;
            let y2 = d2 < *c;
            if (x[l / LANES] >> (l % LANES)) & 1 == 1 && *c < self.max {
                *c += 1;
            }
            if y1 && y2 && *c > 0 {
                *c -= 1;
            }
            if y1 {
                y[l / LANES] |= 1u64 << (l % LANES);
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    use super::*;
    use crate::netlist::eval::eval_stochastic;
    use crate::netlist::graph::InputClass;
    use crate::netlist::ops;
    use crate::sc::bitstream::Bitstream;

    const SEED_BASE: u64 = 0x9E37_79B9;

    /// Run `nl` through both paths on random per-lane streams and
    /// assert bit-exact equality lane by lane, at lane width `W`.
    fn assert_paths_agree_at<const W: usize>(nl: &Netlist, bl: usize, lanes: usize, seed: u64) {
        let plan = GatePlan::compile(nl);
        let mut rng = Xoshiro256::seeded(seed);
        // PI specs in node-id order — the same binding order as
        // `plan.inputs`.
        let input_specs: Vec<(String, InputClass)> = nl
            .nodes
            .iter()
            .filter_map(|n| match n {
                Node::Input { name, class, .. } => Some((name.clone(), *class)),
                _ => None,
            })
            .collect();
        assert_eq!(input_specs.len(), plan.n_inputs());
        // Per-lane streams, generated lane-major so correlated groups
        // can share uniforms within a lane.
        let mut rows: Vec<Vec<Bitstream>> = vec![Vec::new(); input_specs.len()];
        let mut lane_inputs: Vec<HashMap<String, Bitstream>> = Vec::new();
        for _ in 0..lanes {
            let mut by_name = HashMap::new();
            let mut group_uniforms: HashMap<u32, Vec<f64>> = HashMap::new();
            for (i, (name, class)) in input_specs.iter().enumerate() {
                let p = 0.1 + 0.8 * rng.next_f64();
                let bs = match class {
                    InputClass::Correlated(g) => {
                        let us = group_uniforms.entry(*g).or_insert_with(|| {
                            let mut u = vec![0.0; bl];
                            rng.fill_f64(&mut u);
                            u
                        });
                        Bitstream::from_uniforms(p, us)
                    }
                    _ => Bitstream::sample(p, bl, &mut rng),
                };
                rows[i].push(bs.clone());
                by_name.insert(name.clone(), bs);
            }
            lane_inputs.push(by_name);
        }
        let blocks: Vec<LaneBlock<W>> =
            rows.iter().map(|r| LaneBlock::<W>::from_rows(r)).collect();
        // Evaluate twice through one scratch: reuse must not leak state
        // between blocks.
        let mut ws = PlanScratch::default();
        plan.eval_lanes_into(&blocks, &mut ws);
        plan.eval_lanes_into(&blocks, &mut ws);
        let outs = ws.outputs();
        for (l, inputs) in lane_inputs.iter().enumerate() {
            let golden = eval_stochastic(nl, inputs);
            for (k, (name, _)) in nl.outputs.iter().enumerate() {
                assert_eq!(
                    outs[k].lane(l),
                    golden[name],
                    "output `{name}` lane {l} (W={W} bl={bl} lanes={lanes})"
                );
            }
        }
    }

    fn assert_paths_agree(nl: &Netlist, bl: usize, lanes: usize, seed: u64) {
        assert_paths_agree_at::<1>(nl, bl, lanes.min(64), seed);
        assert_paths_agree_at::<2>(nl, bl, lanes.min(128), seed ^ 0x2);
        assert_paths_agree_at::<4>(nl, bl, lanes, seed ^ 0x4);
    }

    #[test]
    fn all_op_netlists_match_golden_model() {
        let cases: Vec<(&str, Netlist)> = vec![
            ("multiply", ops::multiply()),
            ("scaled_add", ops::scaled_add()),
            ("abs_subtract", ops::abs_subtract()),
            ("scaled_divide", ops::scaled_divide()),
            ("square_root", ops::square_root(6)),
            ("exponential", ops::exponential()),
        ];
        for (i, (name, nl)) in cases.iter().enumerate() {
            for (j, &(bl, lanes)) in [(100usize, 64usize), (256, 17), (64, 1)].iter().enumerate() {
                let seed = SEED_BASE ^ ((i * 8 + j) as u64);
                eprintln!("case {name} bl={bl} lanes={lanes}");
                assert_paths_agree(nl, bl, lanes, seed);
            }
        }
    }

    #[test]
    fn wide_lane_blocks_match_golden_model() {
        // Lane counts past one word (65..256) exercise the multi-word
        // paths: per-word masking, Maj5 slicing, ADDIE counters above
        // lane 64, and ragged last words.
        let div = ops::scaled_divide();
        assert_paths_agree_at::<2>(&div, 100, 128, SEED_BASE ^ 0x10);
        assert_paths_agree_at::<2>(&div, 65, 65, SEED_BASE ^ 0x11);
        assert_paths_agree_at::<4>(&div, 100, 256, SEED_BASE ^ 0x12);
        let sqrt = ops::square_root(6);
        assert_paths_agree_at::<4>(&sqrt, 128, 200, SEED_BASE ^ 0x13);
        let mul = ops::multiply();
        assert_paths_agree_at::<4>(&mul, 256, 129, SEED_BASE ^ 0x14);
    }

    #[test]
    fn maj_gates_match_golden_model() {
        let mut nl = Netlist::new();
        let ids: Vec<_> =
            (0..5).map(|i| nl.input(&format!("i{i}"), 0, 1, InputClass::Stochastic)).collect();
        let m3 = nl.gate(GateKind::Maj3Inv, 0, ids[..3].to_vec());
        let m5 = nl.gate(GateKind::Maj5Inv, 0, ids.clone());
        let both = nl.gate(GateKind::And, 0, vec![m3, m5]);
        let or2 = nl.gate(GateKind::Or, 0, vec![ids[0], m5]);
        let b = nl.gate(GateKind::Buff, 0, vec![or2]);
        let nor2 = nl.gate(GateKind::Nor, 0, vec![b, m3]);
        nl.mark_output("m3", m3);
        nl.mark_output("m5", m5);
        nl.mark_output("both", both);
        nl.mark_output("nor", nor2);
        assert_paths_agree(&nl, 200, 64, SEED_BASE ^ 1);
        assert_paths_agree(&nl, 65, 33, SEED_BASE ^ 2);
        assert_paths_agree_at::<4>(&nl, 96, 250, SEED_BASE ^ 3);
    }

    #[test]
    fn app_netlists_match_golden_model() {
        use crate::apps::{hdp::Hdp, ol::Ol, App};
        let ol = Ol::default().stoch_cost_netlists().remove(0);
        let hdp = Hdp.stoch_cost_netlists().remove(0);
        assert_paths_agree(&ol, 128, 64, SEED_BASE ^ 4);
        assert_paths_agree(&hdp, 100, 63, SEED_BASE ^ 5);
        assert_paths_agree_at::<4>(&hdp, 100, 150, SEED_BASE ^ 6);
    }

    #[test]
    fn plan_shape_is_flat_and_complete() {
        let nl = ops::exponential();
        let plan = GatePlan::compile(&nl);
        assert_eq!(plan.n_inputs(), 10); // a1..a5, c1..c5
        assert_eq!(plan.instr_count(), nl.gate_count());
        assert_eq!(plan.output_index("out"), Some(0));
        assert_eq!(plan.output_index("nope"), None);
        // Instructions are topologically ordered over slots: every
        // operand is an input/delay slot or written earlier.
        let mut written: Vec<bool> = vec![false; plan.n_slots];
        for (_, slot) in &plan.inputs {
            written[*slot as usize] = true;
        }
        for d in &plan.delays {
            written[d.slot as usize] = true;
        }
        for instr in &plan.instrs {
            let arity = match instr.op {
                Op::Buff | Op::Not => 1,
                Op::Maj3Inv => 3,
                Op::Maj5Inv => 5,
                _ => 2,
            };
            for &s in &instr.ins[..arity] {
                assert!(written[s as usize], "slot {s} read before write");
            }
            written[instr.out as usize] = true;
        }
        assert!(written.iter().all(|&w| w));
    }
}
