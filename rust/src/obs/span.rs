//! Per-stage wall-clock spans for the wave engine.
//!
//! [`StageSpans`] splits a wave's execution time across the four
//! pipeline stages of the lane-major engine: SNG bitstream generation,
//! gate-program evaluation, StoB vertical-counter readout, and the
//! in-lane StoB→BtoS regeneration between `StagedPlan` stages. The
//! engine takes one monotonic-clock reading per stage boundary per
//! lane block (coarse — nanoseconds of overhead against microseconds
//! to milliseconds of work), so the clean-path speedup gates are not
//! disturbed.
//!
//! Spans from worker threads **sum** — the totals are CPU-time-like,
//! so with N workers the total can exceed the wave's wall-clock. The
//! per-stage *shares* are the meaningful signal, and those are
//! invariant under the summing.

/// Nanoseconds of wall-clock attributed to each engine stage.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageSpans {
    /// Stage-0 input bitstream generation (SNG sampling + cutoffs).
    pub sng_ns: u64,
    /// Gate-program evaluation over lane words.
    pub gate_ns: u64,
    /// Inter-stage StoB→BtoS regeneration (stages > 0 of a `StagedPlan`).
    pub regen_ns: u64,
    /// Vertical-counter StoB readout of stage outputs.
    pub stob_ns: u64,
}

impl StageSpans {
    /// Sum another span set in (worker fold / wave accumulation).
    pub fn add(&mut self, other: &StageSpans) {
        self.sng_ns += other.sng_ns;
        self.gate_ns += other.gate_ns;
        self.regen_ns += other.regen_ns;
        self.stob_ns += other.stob_ns;
    }

    /// Total attributed nanoseconds across all four stages.
    pub fn total_ns(&self) -> u64 {
        self.sng_ns + self.gate_ns + self.regen_ns + self.stob_ns
    }

    /// Fractional share of each stage `[sng, gate, regen, stob]`;
    /// all zeros when nothing has been timed.
    pub fn shares(&self) -> [f64; 4] {
        let total = self.total_ns();
        if total == 0 {
            return [0.0; 4];
        }
        let t = total as f64;
        [
            self.sng_ns as f64 / t,
            self.gate_ns as f64 / t,
            self.regen_ns as f64 / t,
            self.stob_ns as f64 / t,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sums_fields_and_shares_normalize() {
        let mut a = StageSpans { sng_ns: 10, gate_ns: 20, regen_ns: 0, stob_ns: 10 };
        let b = StageSpans { sng_ns: 5, gate_ns: 10, regen_ns: 5, stob_ns: 0 };
        a.add(&b);
        assert_eq!(a, StageSpans { sng_ns: 15, gate_ns: 30, regen_ns: 5, stob_ns: 10 });
        assert_eq!(a.total_ns(), 60);
        let s = a.shares();
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((s[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_spans_share_zero() {
        assert_eq!(StageSpans::default().shares(), [0.0; 4]);
        assert_eq!(StageSpans::default().total_ns(), 0);
    }
}
