//! Observability primitives: fixed-memory histograms, per-stage span
//! timing, and the flat snapshot exposition layer.
//!
//! Dependency-free, like the rest of the crate. Three pieces:
//!
//! * [`Histogram`] — log-bucketed, O(1)-record, exactly-mergeable
//!   distribution of `u64` samples with bounded percentile error
//!   (≤ 1/32 relative). Backs request latency, queue wait, queue
//!   depth and wave-size distributions in `coordinator::Metrics`.
//! * [`StageSpans`] — monotonic-clock nanoseconds attributed to the
//!   SNG / gate / regen / StoB stages of the lane engine, accumulated
//!   per wave into `runtime::WaveStats`.
//! * [`MetricsSnapshot`] — a flat `key → f64` exposition map rendered
//!   as flat JSON (`util::benchjson`) or Prometheus text; produced by
//!   `serve::Server::snapshot()` and the `stoch-imc stats` subcommand.

mod hist;
mod snapshot;
mod span;

pub use hist::{Histogram, N_BUCKETS, SUBBUCKETS};
pub use snapshot::MetricsSnapshot;
pub use span::StageSpans;
