//! Flat metrics snapshot and its exposition formats.
//!
//! [`MetricsSnapshot`] is the bridge between the in-process `Metrics`
//! (histograms, counters, spans) and the outside world: a flat,
//! ordered `key → f64` map rendered either as the same flat JSON the
//! bench harnesses use (`util::benchjson`) or as Prometheus-style
//! text. Keys follow `serve_<scope>_<metric>` where `<scope>` is an
//! app name (e.g. `app_kde`) or `pool`; the full field map lives in
//! `docs/ARCHITECTURE.md` § Observability.

use std::collections::BTreeMap;

use crate::util::benchjson;

/// A flat, ordered snapshot of every exported metric.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    entries: BTreeMap<String, f64>,
}

impl MetricsSnapshot {
    /// Insert (or overwrite) one metric.
    pub fn push(&mut self, key: impl Into<String>, value: f64) {
        self.entries.insert(key.into(), value);
    }

    /// Look one metric up.
    pub fn get(&self, key: &str) -> Option<f64> {
        self.entries.get(key).copied()
    }

    /// Number of exported metrics.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate `(key, value)` in sorted key order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Build from parsed flat-JSON entries (`benchjson::parse_flat`).
    pub fn from_entries(entries: &[(String, f64)]) -> Self {
        Self { entries: entries.iter().cloned().collect() }
    }

    /// Render as the flat JSON object shared with the bench harnesses.
    pub fn to_flat_json(&self) -> String {
        benchjson::render(&self.entries)
    }

    /// Render as Prometheus text exposition: one
    /// `stoch_imc_<key> <value>` line per metric, keys sanitized to
    /// the `[a-zA-Z0-9_:]` metric-name alphabet.
    pub fn to_prometheus(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.entries {
            s.push_str("stoch_imc_");
            for c in k.chars() {
                if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                    s.push(c);
                } else {
                    s.push('_');
                }
            }
            s.push(' ');
            s.push_str(&format!("{v}"));
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_json_round_trips_through_benchjson() {
        let mut snap = MetricsSnapshot::default();
        snap.push("serve_pool_latency_us_p50", 123.0);
        snap.push("serve_app_kde_requests", 64.0);
        let text = snap.to_flat_json();
        let back = MetricsSnapshot::from_entries(&benchjson::parse_flat(&text));
        assert_eq!(back.get("serve_pool_latency_us_p50"), Some(123.0));
        assert_eq!(back.get("serve_app_kde_requests"), Some(64.0));
        assert_eq!(back.len(), 2);
    }

    #[test]
    fn prometheus_lines_are_sanitized_and_sorted() {
        let mut snap = MetricsSnapshot::default();
        snap.push("serve_pool_latency_us_p99.9", 7.5);
        snap.push("a-key with spaces", 1.0);
        let text = snap.to_prometheus();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "stoch_imc_a_key_with_spaces 1");
        assert_eq!(lines[1], "stoch_imc_serve_pool_latency_us_p99_9 7.5");
    }
}
