//! Fixed-memory, mergeable, log-bucketed histogram (HDR-style).
//!
//! [`Histogram`] records non-negative `u64` samples in O(1) into a
//! fixed bucket table: values below [`SUBBUCKETS`] land in exact
//! unit-width buckets, and every power-of-two range `[2^e, 2^{e+1})`
//! above that is split into [`SUBBUCKETS`] equal sub-buckets — the
//! HdrHistogram layout at 5 significant bits. The table is
//! [`N_BUCKETS`] = 1920 `u64` counters (15 KiB), covering the whole
//! `u64` domain with no saturation cliff, so a `Metrics` holding a few
//! of these stays bounded no matter how many samples stream through
//! (unlike the per-sample `Vec<u64>` it replaced).
//!
//! Guarantees:
//! * **O(1) record** — one `leading_zeros`, two shifts, one add.
//! * **Exact-count merge** — bucket tables add elementwise, so
//!   `merge(a, b)` holds exactly the union of the samples `a` and `b`
//!   saw: merging per-shard histograms ≡ one histogram fed the
//!   concatenated stream (the pool-aggregation invariant, pinned by
//!   `tests/obs.rs`).
//! * **Bounded percentile error** — a percentile query returns the
//!   midpoint of the bucket holding the target rank, clamped into the
//!   exact `[min, max]` seen. Values `< 32` are exact; above that the
//!   bucket is at most `value/32` wide, so the estimate is within
//!   **3.125 %** relative error of the true order statistic (midpoint
//!   reporting halves the typical error to ~1.6 %).

/// Sub-bucket resolution bits: 32 sub-buckets per power-of-two range.
const SUB_BITS: u32 = 5;
/// Sub-buckets per octave; also the width of the exact linear region.
pub const SUBBUCKETS: usize = 1 << SUB_BITS;
/// Total bucket count: the linear region plus `64 - SUB_BITS` octaves
/// of `SUBBUCKETS` each — covers all of `u64` in 1920 counters.
pub const N_BUCKETS: usize = SUBBUCKETS + (64 - SUB_BITS as usize) * SUBBUCKETS;

/// Log-bucketed fixed-memory histogram of `u64` samples.
///
/// `Default` is an empty histogram that owns no bucket table; the
/// table is allocated on the first [`Histogram::record`] (or merge
/// from a non-empty peer), so idle `Metrics` stay a few words.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self { counts: Vec::new(), count: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

impl Histogram {
    /// Bucket holding `v`: exact below [`SUBBUCKETS`], then
    /// `SUBBUCKETS` sub-buckets per octave keyed by the top
    /// `SUB_BITS` mantissa bits.
    fn bucket_index(v: u64) -> usize {
        if v < SUBBUCKETS as u64 {
            return v as usize;
        }
        let e = 63 - v.leading_zeros(); // floor(log2 v) ≥ SUB_BITS
        let sub = ((v >> (e - SUB_BITS)) as usize) & (SUBBUCKETS - 1);
        SUBBUCKETS * (e - SUB_BITS) as usize + sub + SUBBUCKETS
    }

    /// `(low, width)` of bucket `i` — the half-open value range
    /// `[low, low + width)` it covers.
    fn bucket_bounds(i: usize) -> (u64, u64) {
        if i < SUBBUCKETS {
            return (i as u64, 1);
        }
        let g = ((i - SUBBUCKETS) / SUBBUCKETS) as u32; // e - SUB_BITS
        let sub = ((i - SUBBUCKETS) % SUBBUCKETS) as u64;
        ((SUBBUCKETS as u64 + sub) << g, 1u64 << g)
    }

    /// Record one sample. O(1); allocates the bucket table on first use.
    pub fn record(&mut self, v: u64) {
        if self.counts.is_empty() {
            self.counts = vec![0; N_BUCKETS];
        }
        self.counts[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold `other` in. Exact: the result's bucket table (and count /
    /// sum / min / max) is identical to one histogram having recorded
    /// both sample streams.
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; N_BUCKETS];
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact smallest recorded sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min }
    }

    /// Exact largest recorded sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean of the recorded samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    /// Percentile estimate (`p` clamped into `[0, 100]`): the value at
    /// nearest rank `round(p/100 · (count−1))` of the conceptual
    /// sorted sample list — the same convention an exact sort uses —
    /// reported as its bucket midpoint clamped into the exact
    /// `[min, max]`. `p ≤ 0` and `p ≥ 100` return the exact min/max.
    /// Relative error is bounded by the bucket resolution (≤ 1/32).
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let p = if p.is_nan() { 0.0 } else { p.clamp(0.0, 100.0) };
        if p <= 0.0 {
            return self.min;
        }
        if p >= 100.0 {
            return self.max;
        }
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                let (low, width) = Self::bucket_bounds(i);
                return (low + width / 2).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_covers_u64() {
        // Index 0 ↔ value 0; the linear region is exact; every octave
        // boundary continues the previous range without gap or overlap.
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(31), 31);
        assert_eq!(Histogram::bucket_index(32), 32);
        assert_eq!(Histogram::bucket_index(u64::MAX), N_BUCKETS - 1);
        let mut last = 0usize;
        for e in 5..64u32 {
            for v in [1u64 << e, (1u64 << e) + 1, (1u64 << e) * 2 - 1] {
                let i = Histogram::bucket_index(v);
                assert!(i >= last, "index not monotone at v={v}");
                last = i;
                let (low, width) = Histogram::bucket_bounds(i);
                assert!(low <= v && (v - low) < width, "v={v} outside bucket {i}");
            }
        }
    }

    #[test]
    fn linear_region_is_exact() {
        let mut h = Histogram::default();
        for v in 0..32u64 {
            h.record(v);
        }
        for v in 0..32u64 {
            let p = 100.0 * v as f64 / 31.0;
            assert_eq!(h.percentile(p), v, "p={p}");
        }
    }

    #[test]
    fn empty_and_edge_percentiles() {
        let h = Histogram::default();
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
        let mut h = Histogram::default();
        h.record(7);
        h.record(1_000_000);
        // Out-of-range p clamps; exact min/max at the edges.
        assert_eq!(h.percentile(-5.0), 7);
        assert_eq!(h.percentile(250.0), 1_000_000);
        assert_eq!(h.percentile(f64::NAN), 7);
    }

    #[test]
    fn saturation_edge_holds_u64_max() {
        let mut h = Histogram::default();
        h.record(u64::MAX);
        h.record(0);
        assert_eq!(h.max(), u64::MAX);
        assert_eq!(h.min(), 0);
        assert_eq!(h.percentile(100.0), u64::MAX);
        assert_eq!(h.percentile(0.0), 0);
    }

    #[test]
    fn merge_is_exact_bucket_addition() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        let mut both = Histogram::default();
        for i in 0..500u64 {
            let v = i * i % 10_007;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            both.record(v);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, both, "merge must equal the concatenated stream");
        // Merging into an empty histogram clones the peer's contents.
        let mut empty = Histogram::default();
        empty.merge(&both);
        assert_eq!(empty, both);
        // Merging an empty peer is a no-op (and allocates nothing).
        let snap = merged.clone();
        merged.merge(&Histogram::default());
        assert_eq!(merged, snap);
    }

    #[test]
    fn percentile_error_is_bounded_vs_exact_sort() {
        // Deterministic pseudo-random samples spanning several octaves.
        let mut h = Histogram::default();
        let mut exact: Vec<u64> = Vec::new();
        let mut x = 0x9E37_79B9_7F4A_7C15u64;
        for _ in 0..4000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let v = x % 5_000_000;
            h.record(v);
            exact.push(v);
        }
        exact.sort_unstable();
        for p in [0.0, 1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
            let idx = ((p / 100.0) * (exact.len() - 1) as f64).round() as usize;
            let want = exact[idx];
            let got = h.percentile(p);
            let err = got.abs_diff(want) as f64;
            assert!(
                err <= want as f64 / 32.0 + 1.0,
                "p{p}: got {got} want {want} (err {err})"
            );
        }
        assert_eq!(h.count(), 4000);
        assert_eq!(h.min(), exact[0]);
        assert_eq!(h.max(), *exact.last().unwrap());
        let mean_exact = exact.iter().map(|&v| v as f64).sum::<f64>() / exact.len() as f64;
        assert!((h.mean() - mean_exact).abs() < 1e-6, "mean is tracked exactly");
    }
}
