//! Experiment report generators — shared by the bench harnesses
//! (rust/benches/*) and the CLI. Each function regenerates one paper
//! table/figure and returns printable rows (EXPERIMENTS.md records the
//! outputs).

use std::collections::HashMap;

use crate::apps::{all_apps, output_error_pct};
use crate::arch::{run_binary, run_stochastic, RunCost};
use crate::baseline::{binary_op_netlist, run_sc_cram, BinaryOp, ScCramCost};
use crate::config::Config;
use crate::device::{switching_probability, MtjParams, Pulse};
use crate::netlist::{ops, replicate::replicate, Netlist};
use crate::scheduler::algorithm1::{schedule, Options};
use crate::scheduler::Schedule;
use crate::util::stats::geomean;

/// Fig 3 — P_sw vs V_p for t_p ∈ 3..10 ns. Returns (t_p ns, Vec<(V_p, P)>).
pub fn fig3(params: &MtjParams) -> Vec<(f64, Vec<(f64, f64)>)> {
    let mut out = Vec::new();
    for tp_ns in [3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 10.0] {
        let mut series = Vec::new();
        let mut v = 0.20;
        while v <= 0.4501 {
            let p = switching_probability(params, Pulse { v_p: v, t_p: tp_ns * 1e-9 });
            series.push((v, p));
            v += 0.01;
        }
        out.push((tp_ns, series));
    }
    out
}

/// Fig 7 — 4-bit addition cycle counts: (binary, stochastic).
pub fn fig7() -> (usize, usize) {
    let bin = binary_op_netlist(BinaryOp::Add, 4, 4);
    let b = schedule(&bin, &Options::default());
    let sto = replicate(&ops::scaled_add(), 4);
    let s = schedule(&sto, &Options::default());
    (b.logic_cycles(), s.logic_cycles())
}

/// One Table 2 row.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub op: &'static str,
    pub binary_array: (usize, usize),
    pub sc_cram_array: (usize, usize),
    pub stoch_array: (usize, usize),
    /// Normalized to binary (=1.0).
    pub area_sc_cram: f64,
    pub area_stoch: f64,
    pub time_sc_cram: f64,
    pub time_stoch: f64,
    pub energy_stoch: f64,
}

fn stoch_op_netlist(op: BinaryOp) -> Netlist {
    match op {
        BinaryOp::Add => ops::scaled_add(),
        BinaryOp::Multiply => ops::multiply(),
        BinaryOp::Subtract => ops::abs_subtract(),
        BinaryOp::Divide => ops::scaled_divide(),
        BinaryOp::Sqrt => ops::square_root(ops::ADDIE_BITS_APP),
        BinaryOp::Exp => ops::exponential(),
    }
}

fn schedule_lanes(base: &Netlist, lanes: usize) -> (Schedule, usize) {
    let rep = replicate(base, lanes);
    let s = schedule(&rep, &Options::default());
    let cols = s.cols_used;
    (s, cols)
}

/// Table 2 — the six arithmetic operations, normalized to binary IMC.
pub fn table2(cfg: &Config) -> Vec<Table2Row> {
    let bl = cfg.arch.bitstream_len as u64;
    let lanes = cfg.arch.subarray_rows.min(cfg.arch.bitstream_len);
    let mut rows = Vec::new();
    for op in BinaryOp::ALL {
        // Binary: 8-bit circuit, one instance.
        let bin_nl = binary_op_netlist(op, cfg.arch.resolution as usize, 32);
        let bin_sched = schedule(&bin_nl, &Options::default());
        let bin = run_binary(&cfg.arch, &cfg.energy, &bin_sched, 1);
        // Stoch-IMC: bit-parallel over `lanes` rows.
        let base = stoch_op_netlist(op);
        let (s, cols) = schedule_lanes(&base, lanes);
        let sto = run_stochastic(&cfg.arch, &cfg.energy, &s, lanes, cols, 1);
        // SC-CRAM [22]: bit-serial single lane.
        let scc: ScCramCost = run_sc_cram(&cfg.energy, &base, bl, 1);

        rows.push(Table2Row {
            op: op.name(),
            binary_array: bin.min_subarray,
            sc_cram_array: scc.min_subarray,
            stoch_array: sto.min_subarray,
            area_sc_cram: scc.used_cells as f64 / bin.used_cells as f64,
            area_stoch: (sto.used_cells) as f64 / bin.used_cells as f64,
            time_sc_cram: scc.cycles as f64 / bin.comp_cycles as f64,
            time_stoch: sto.comp_cycles as f64 / bin.comp_cycles as f64,
            energy_stoch: sto.energy.total() / bin.energy.total(),
        });
    }
    rows
}

/// One Table 3 row (plus the Fig 10/11 inputs captured along the way).
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub app: &'static str,
    pub binary_subarray: (usize, usize),
    pub stoch_subarray: (usize, usize),
    pub area_stoch: f64,
    pub area_sc_cram: f64,
    pub time_stoch: f64,
    pub time_sc_cram: f64,
    pub energy_stoch: f64,
    pub energy_sc_cram: f64,
    pub binary: RunCost,
    pub stoch_energy_breakdown: crate::energy::EnergyBreakdown,
    pub binary_energy_breakdown: crate::energy::EnergyBreakdown,
    pub sc_cram_energy_breakdown: crate::energy::EnergyBreakdown,
    pub stoch_wear: crate::lifetime::WearProfile,
    pub binary_wear: crate::lifetime::WearProfile,
    pub sc_cram_wear: crate::lifetime::WearProfile,
}

/// Table 3 — the four applications.
pub fn table3(cfg: &Config) -> Vec<Table3Row> {
    let bl = cfg.arch.bitstream_len as u64;
    let lanes = cfg.arch.subarray_rows.min(cfg.arch.bitstream_len);
    let mut rows = Vec::new();
    for app in all_apps() {
        let instances = app.eval_instances() as u64;
        // Stoch-IMC: sum per-stage costs.
        let mut sto_cycles = 0u64;
        let mut sto_energy = crate::energy::EnergyBreakdown::default();
        let mut sto_cells = 0u64;
        let mut sto_sub = (0usize, 0usize);
        let mut sto_wear = crate::lifetime::WearProfile {
            used_cells: 0,
            writes: 0,
            max_cell_writes: 1,
        };
        for stage in app.stoch_cost_netlists() {
            let (s, cols) = schedule_lanes(&stage, lanes);
            // Wide stages are partitioned column-wise across subarrays.
            let chunks = cols.div_ceil(cfg.arch.subarray_cols) as u64;
            let eff_cols = cols.min(cfg.arch.subarray_cols);
            let c = run_stochastic(&cfg.arch, &cfg.energy, &s, lanes, eff_cols, instances);
            sto_cycles += c.cycles * chunks.max(1);
            sto_energy.add(&c.energy);
            sto_cells += c.used_cells;
            sto_sub = (
                sto_sub.0.max(lanes.min(s.rows_used)),
                sto_sub.1.max(eff_cols),
            );
            sto_wear.used_cells += c.wear.used_cells;
            sto_wear.writes += c.wear.writes;
            sto_wear.max_cell_writes = sto_wear.max_cell_writes.max(c.wear.max_cell_writes);
        }
        // Binary (scaled from the representative slice when needed).
        let bin_nl = app.binary_cost_netlist();
        let bin_sched = schedule(&bin_nl, &Options::default());
        let mut bin = run_binary(&cfg.arch, &cfg.energy, &bin_sched, instances);
        let k = app.binary_cost_scale();
        if k != 1.0 {
            bin.cycles = (bin.cycles as f64 * k) as u64;
            bin.comp_cycles = (bin.comp_cycles as f64 * k) as u64;
            bin.energy = bin.energy.scaled(k);
            bin.used_cells = (bin.used_cells as f64 * k) as u64;
            bin.wear.used_cells = (bin.wear.used_cells as f64 * k) as u64;
            bin.wear.writes = (bin.wear.writes as f64 * k) as u64;
        }
        // SC-CRAM: bit-serial on each stage.
        let mut scc_cycles = 0u64;
        let mut scc_energy = crate::energy::EnergyBreakdown::default();
        let mut scc_cells = 0u64;
        let mut scc_wear = crate::lifetime::WearProfile {
            used_cells: 0,
            writes: 0,
            max_cell_writes: 1,
        };
        for stage in app.stoch_cost_netlists() {
            let c = run_sc_cram(&cfg.energy, &stage, bl, instances);
            scc_cycles += c.cycles;
            scc_energy.add(&c.energy);
            scc_cells += c.used_cells;
            scc_wear.used_cells += c.wear.used_cells;
            scc_wear.writes += c.wear.writes;
            scc_wear.max_cell_writes = scc_wear.max_cell_writes.max(c.wear.max_cell_writes);
        }

        rows.push(Table3Row {
            app: app.name(),
            binary_subarray: bin.min_subarray,
            stoch_subarray: sto_sub,
            area_stoch: sto_cells as f64 / bin.used_cells as f64,
            area_sc_cram: scc_cells as f64 / bin.used_cells as f64,
            time_stoch: sto_cycles as f64 / bin.cycles as f64,
            time_sc_cram: scc_cycles as f64 / bin.cycles as f64,
            energy_stoch: sto_energy.total() / bin.energy.total(),
            energy_sc_cram: scc_energy.total() / bin.energy.total(),
            binary: bin.clone(),
            stoch_energy_breakdown: sto_energy,
            binary_energy_breakdown: bin.energy.clone(),
            sc_cram_energy_breakdown: scc_energy,
            stoch_wear: sto_wear,
            binary_wear: bin.wear,
            sc_cram_wear: scc_wear,
        });
    }
    rows
}

/// Geometric-mean speedups of Table 3 (the paper's headline numbers).
pub fn headline(rows: &[Table3Row]) -> (f64, f64, f64) {
    let vs_binary: Vec<f64> = rows.iter().map(|r| 1.0 / r.time_stoch).collect();
    let vs_sc_cram: Vec<f64> =
        rows.iter().map(|r| r.time_sc_cram / r.time_stoch).collect();
    let energy_vs_binary: Vec<f64> = rows.iter().map(|r| 1.0 / r.energy_stoch).collect();
    (geomean(&vs_binary), geomean(&vs_sc_cram), geomean(&energy_vs_binary))
}

/// Table 4 — output error (%) under bitflip injection.
pub fn table4(
    cfg: &Config,
    rates: &[f64],
    instances_per_app: usize,
) -> HashMap<&'static str, (Vec<f64>, Vec<f64>)> {
    let mut out = HashMap::new();
    for app in all_apps() {
        let w = app.workload(instances_per_app, cfg.seed);
        let mut binary = Vec::new();
        let mut stoch = Vec::new();
        for &r in rates {
            binary.push(output_error_pct(
                app.as_ref(),
                &w,
                cfg.arch.bitstream_len,
                cfg.arch.resolution,
                r,
                false,
                cfg.seed ^ 0xB1,
            ));
            stoch.push(output_error_pct(
                app.as_ref(),
                &w,
                cfg.arch.bitstream_len,
                cfg.arch.resolution,
                r,
                true,
                cfg.seed ^ 0x5C,
            ));
        }
        out.insert(app.name(), (binary, stoch));
    }
    out
}

/// Fig 11 — lifetime improvement (Eq 11 merit ratios vs binary).
pub fn fig11(rows: &[Table3Row]) -> Vec<(&'static str, f64, f64)> {
    rows.iter()
        .map(|r| {
            // Table 3 profiles always record writes; NaN (never silently
            // plausible) would surface a broken cost model downstream.
            (
                r.app,
                crate::lifetime::improvement(&r.stoch_wear, &r.binary_wear)
                    .unwrap_or(f64::NAN),
                crate::lifetime::improvement(&r.sc_cram_wear, &r.binary_wear)
                    .unwrap_or(f64::NAN),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_anchor_and_monotonicity() {
        let series = fig3(&MtjParams::default());
        // 4ns series contains the 0.31 V ⇒ 0.7 anchor.
        let four_ns = &series.iter().find(|(t, _)| *t == 4.0).unwrap().1;
        let near = four_ns
            .iter()
            .min_by(|a, b| {
                (a.0 - 0.31).abs().partial_cmp(&(b.0 - 0.31).abs()).unwrap()
            })
            .unwrap();
        assert!((near.1 - 0.7).abs() < 0.03, "p={}", near.1);
        // Longer pulses dominate at fixed V.
        let three = &series[0].1;
        let ten = series.last().unwrap();
        for (a, b) in three.iter().zip(&ten.1) {
            assert!(b.1 >= a.1);
        }
    }

    #[test]
    fn fig7_is_9_vs_4() {
        assert_eq!(fig7(), (9, 4));
    }

    #[test]
    fn table2_shape_holds() {
        let cfg = Config::default();
        let rows = table2(&cfg);
        assert_eq!(rows.len(), 6);
        for r in &rows {
            // Stoch-IMC beats binary on time for every op (Table 2).
            assert!(r.time_stoch < 1.0, "{}: {}", r.op, r.time_stoch);
            // SC-CRAM is bit-serial: slower than Stoch-IMC everywhere.
            assert!(r.time_sc_cram > r.time_stoch, "{}", r.op);
        }
        // Specific paper shapes: add/sub area overhead >1, sqrt/exp ≪1.
        let by_name: HashMap<&str, &Table2Row> =
            rows.iter().map(|r| (r.op, r)).collect();
        assert!(by_name["scaled_addition"].area_stoch > 1.0);
        assert!(by_name["square_root"].area_stoch < 0.5);
        assert!(by_name["exponential"].area_stoch < 0.5);
        assert!(by_name["multiplication"].time_stoch < 0.05);
    }
}
