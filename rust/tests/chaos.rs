//! Chaos acceptance — the resilience contract of `serve::Server` under
//! injected executor panics, artificial wave latency, request
//! deadlines, and the BL degradation ladder:
//!
//! * every admitted request gets exactly one terminal outcome — a
//!   value, `Err(Timeout)`, `Err(ShardDead)`, or `Err(Exec(..))` —
//!   panics included; nothing ever deadlocks or drops a receiver;
//! * supervised shards restart after a panic (batched requests
//!   survive), die only past their restart budget, and dead shards are
//!   routed around by the pool;
//! * a no-op chaos plan is bit-identical to clean serving;
//! * degradation stays on the configured ladder and recovers.

use std::path::PathBuf;
use std::time::Duration;

use stoch_imc::coordinator::BatcherConfig;
use stoch_imc::serve::{ChaosPlan, DegradeConfig, ServeError, Server, ServerConfig};

fn manifest_dir(tag: &str, lines: &str) -> PathBuf {
    // Pin the default backend (see tests/interp_engine.rs for why this
    // is safe in this binary).
    std::env::remove_var("STOCH_IMC_BACKEND");
    let dir = std::env::temp_dir().join(format!("stoch_imc_it_chaos_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), lines).unwrap();
    dir
}

#[test]
fn noop_chaos_plan_is_bit_identical_to_clean_serving() {
    // An all-zero ChaosPlan must take exactly the clean path: same
    // manifest, same workload, same wave composition (single caller
    // thread + full batches ⇒ deterministic FIFO waves) — bit-equal
    // outputs.
    let dir = manifest_dir("noop", "op_multiply 2 8 2048\n");
    let work: Vec<Vec<f64>> = (0..16).map(|i| vec![(i as f64 + 1.0) / 20.0, 0.7]).collect();
    let cfg = || ServerConfig {
        shards: 1,
        batcher: BatcherConfig { max_wait: Duration::from_secs(600), ..Default::default() },
        row_threads: 1,
        ..ServerConfig::default()
    };
    let clean = Server::start(&dir, cfg()).unwrap();
    let a = clean.run_workload("op_multiply", &work).unwrap();
    drop(clean);
    let chaotic =
        Server::start(&dir, ServerConfig { chaos: Some(ChaosPlan::default()), ..cfg() }).unwrap();
    let b = chaotic.run_workload("op_multiply", &work).unwrap();
    assert_eq!(a, b, "a no-op chaos plan must not change a single bit");
}

#[test]
fn injected_panic_fails_inflight_wave_and_shard_recovers() {
    // One injected panic: the in-flight wave's requests get Err(Exec),
    // the supervisor restarts the executor, and the very next wave
    // serves values again. Long max_wait ⇒ only full (batch=4) waves
    // close, so the failure set is exactly one wave.
    let dir = manifest_dir("panic", "op_multiply 2 4 1024\n");
    let server = Server::start(
        &dir,
        ServerConfig {
            shards: 1,
            batcher: BatcherConfig { max_wait: Duration::from_secs(600), ..Default::default() },
            chaos: Some(ChaosPlan { panic_every: 1, max_panics: 1, ..Default::default() }),
            max_restarts: 4,
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let mut first = Vec::new();
    for _ in 0..4 {
        first.push(server.submit("op_multiply", &[0.5, 0.5]).unwrap());
    }
    for rx in first {
        match rx.recv().expect("panicked wave still answers") {
            Err(ServeError::Exec(msg)) => {
                assert!(msg.contains("panicked"), "unexpected exec error: {msg}");
            }
            other => panic!("expected Err(Exec) from the panicked wave, got {other:?}"),
        }
    }
    // Budget spent: the respawned executor serves the next wave clean.
    let mut second = Vec::new();
    for _ in 0..4 {
        second.push(server.submit("op_multiply", &[0.5, 0.5]).unwrap());
    }
    for rx in second {
        let v = rx.recv().expect("answered").expect("post-restart wave serves values") as f64;
        assert!((v - 0.25).abs() < 0.07, "got {v}");
    }

    let m = server.metrics("op_multiply");
    assert_eq!(m.executor_restarts, 1, "exactly one supervised restart");
    assert_eq!(m.failed_requests, 4, "exactly the panicked wave's rows failed");
    assert!(server.dead_shards().is_empty(), "one panic must not kill a shard");
}

#[test]
fn exhausted_restart_budget_marks_shard_dead_and_fails_fast() {
    // max_restarts = 0: the first panic tombstones the only shard. Its
    // in-flight wave gets Err(Exec); later submits are rejected up
    // front with a dead-shard error instead of queueing forever.
    let dir = manifest_dir("dead", "op_multiply 2 4 1024\n");
    let server = Server::start(
        &dir,
        ServerConfig {
            shards: 1,
            batcher: BatcherConfig { max_wait: Duration::from_secs(600), ..Default::default() },
            chaos: Some(ChaosPlan { panic_every: 1, max_panics: u64::MAX, ..Default::default() }),
            max_restarts: 0,
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let mut rxs = Vec::new();
    for _ in 0..4 {
        rxs.push(server.submit("op_multiply", &[0.5, 0.5]).unwrap());
    }
    for rx in rxs {
        assert!(
            matches!(rx.recv().expect("answered"), Err(ServeError::Exec(_))),
            "in-flight wave of the dying shard fails with Exec"
        );
    }
    // The dead flag is set just after the in-flight wave is failed;
    // wait out the tiny race before asserting on it.
    let t0 = std::time::Instant::now();
    while server.dead_shards().is_empty() && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(server.dead_shards(), vec![0]);
    let err = server.submit("op_multiply", &[0.5, 0.5]).unwrap_err();
    assert!(format!("{err:#}").contains("no live shard"), "{err:#}");
    assert_eq!(server.metrics("op_multiply").executor_restarts, 1);
}

#[test]
fn dead_shard_is_routed_around_by_a_live_sibling() {
    // Two apps on two shards; the shared panic budget kills op_multiply's
    // home shard (shard 0, sorted order) on its first wave. Every shard
    // knows every spec, so the pool reroutes op_multiply to shard 1 and
    // serving continues.
    let dir = manifest_dir("route", "op_multiply 2 1 512\nop_scaled_add 2 1 512\n");
    let server = Server::start(
        &dir,
        ServerConfig {
            shards: 2,
            chaos: Some(ChaosPlan { panic_every: 1, max_panics: 1, ..Default::default() }),
            max_restarts: 0,
            ..ServerConfig::default()
        },
    )
    .unwrap();
    assert_eq!(server.shard_of("op_multiply"), Some(0));

    let rx = server.submit("op_multiply", &[0.6, 0.5]).unwrap();
    assert!(
        matches!(rx.recv().expect("answered"), Err(ServeError::Exec(_))),
        "first wave takes the injected panic"
    );
    let t0 = std::time::Instant::now();
    while server.dead_shards().is_empty() && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(server.dead_shards(), vec![0]);

    // Rerouted serving: the panic budget is spent, shard 1 is live and
    // has op_multiply's spec even though it never was its home.
    let out = server.run_workload("op_multiply", &[vec![0.6, 0.5]]).unwrap();
    assert!((out[0] - 0.30).abs() < 0.12, "rerouted value {}", out[0]);
    let add = server.run_workload("op_scaled_add", &[vec![0.2, 0.6]]).unwrap();
    assert!((add[0] - 0.40).abs() < 0.12, "sibling's own app still serves: {}", add[0]);
    assert_eq!(server.pool_metrics().executor_restarts, 1);
}

#[test]
fn deadlines_time_out_slow_waves_with_typed_errors() {
    // 30ms injected latency per wave vs 5ms budgets: every request
    // terminates promptly as Err(Timeout) — at dequeue for the queued
    // tail, at completion for the wave that did execute — and a
    // no-deadline request afterwards still gets its value.
    let dir = manifest_dir("deadline", "op_multiply 2 1 512\n");
    let server = Server::start(
        &dir,
        ServerConfig {
            shards: 1,
            batcher: BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() },
            chaos: Some(ChaosPlan {
                latency_every: 1,
                latency: Duration::from_millis(30),
                ..Default::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let budget = Duration::from_millis(5);
    let mut rxs = Vec::new();
    for _ in 0..16 {
        rxs.push(server.submit_with_deadline("op_multiply", &[0.5, 0.5], budget).unwrap());
    }
    for rx in rxs {
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("terminal outcome owed");
        assert_eq!(reply, Err(ServeError::Timeout), "5ms budget vs 30ms waves");
    }
    let m = server.metrics("op_multiply");
    assert_eq!(m.deadline_timeouts, 16, "every timeout counted exactly once");

    // The server default is unbounded; a fresh request rides out the
    // injected latency and succeeds.
    let rx = server.submit("op_multiply", &[0.5, 0.5]).unwrap();
    let v = rx.recv().expect("answered").expect("no deadline ⇒ value") as f64;
    assert!((v - 0.25).abs() < 0.1, "got {v}");
}

#[test]
fn degradation_steps_down_the_ladder_under_load_and_recovers() {
    // Flooding a shard whose waves each take ≥10ms drives queue-wait
    // p95 far past the 5ms threshold: the controller walks BL down the
    // ladder (never past max_steps), marks waves degraded, and — once
    // load returns to sequential request-reply — climbs back to full BL.
    let dir = manifest_dir("degrade", "op_multiply 2 1 256\n");
    let server = Server::start(
        &dir,
        ServerConfig {
            shards: 1,
            batcher: BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() },
            degrade: Some(DegradeConfig { wait_p95_us: 5_000, max_steps: 2, eval_waves: 4 }),
            chaos: Some(ChaosPlan {
                latency_every: 1,
                latency: Duration::from_millis(10),
                ..Default::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    // Overload: 24 requests queued at once against 10ms waves.
    let mut rxs = Vec::new();
    for _ in 0..24 {
        rxs.push(server.submit("op_multiply", &[0.5, 0.5]).unwrap());
    }
    for rx in rxs {
        let v = rx.recv().expect("answered").expect("degraded waves still serve values") as f64;
        assert!((v - 0.25).abs() < 0.25, "degraded estimate {v} off the rails");
    }
    let m = server.metrics("op_multiply");
    assert!(m.degraded_waves > 0, "sustained overload must degrade some waves");
    assert!(
        (1..=2).contains(&m.bl_level),
        "ladder level {} outside the configured 2-step ladder",
        m.bl_level
    );

    // Recovery: sequential request-reply keeps queue waits tiny; the
    // controller steps back up to full BL within a few eval windows.
    for _ in 0..40 {
        let rx = server.submit("op_multiply", &[0.5, 0.5]).unwrap();
        let _ = rx.recv().expect("answered").expect("value");
    }
    let m = server.metrics("op_multiply");
    assert_eq!(m.bl_level, 0, "quiet load must return the shard to full BL");
    let snap = server.snapshot();
    assert_eq!(snap.get("serve_pool_bl_level"), Some(0.0));
    assert!(snap.get("serve_pool_degraded_waves").unwrap_or(0.0) > 0.0);
}

#[test]
fn chaos_storm_yields_exactly_one_terminal_outcome_per_request() {
    // The kitchen sink: panics (supervised, within budget), latency
    // spikes, 50ms deadlines, and the degradation ladder, driven by two
    // concurrent producers. The only hard promises: submit never fails
    // (shards outlive the bounded panic budget), every request gets
    // exactly one terminal outcome, and the pool finishes alive.
    let dir = manifest_dir("storm", "op_multiply 2 4 512\nop_scaled_add 2 4 512\n");
    let server = Server::start(
        &dir,
        ServerConfig {
            shards: 2,
            batcher: BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() },
            deadline: Some(Duration::from_millis(50)),
            degrade: Some(DegradeConfig { wait_p95_us: 2_000, max_steps: 2, eval_waves: 4 }),
            chaos: Some(ChaosPlan {
                panic_every: 3,
                max_panics: 5,
                latency_every: 2,
                latency: Duration::from_millis(1),
                ..Default::default()
            }),
            max_restarts: 20,
            ..ServerConfig::default()
        },
    )
    .unwrap();

    const PER_APP: usize = 50;
    let (ok, errs) = std::thread::scope(|s| {
        let handles: Vec<_> = ["op_multiply", "op_scaled_add"]
            .into_iter()
            .map(|app| {
                let server = &server;
                s.spawn(move || {
                    let rxs: Vec<_> = (0..PER_APP)
                        .map(|_| server.submit(app, &[0.5, 0.5]).expect("live pool admits"))
                        .collect();
                    let (mut ok, mut errs) = (0u64, 0u64);
                    for rx in rxs {
                        match rx.recv_timeout(Duration::from_secs(5)) {
                            Ok(Ok(_)) => ok += 1,
                            Ok(Err(_)) => errs += 1,
                            Err(_) => panic!("request dropped without a terminal outcome"),
                        }
                    }
                    (ok, errs)
                })
            })
            .collect();
        let (mut ok, mut errs) = (0u64, 0u64);
        for h in handles {
            let (o, e) = h.join().expect("producer thread");
            ok += o;
            errs += e;
        }
        (ok, errs)
    });
    assert_eq!(ok + errs, 2 * PER_APP as u64, "one terminal outcome per admitted request");
    assert!(server.dead_shards().is_empty(), "20-restart budget outlives 5 injected panics");
    let pm = server.pool_metrics();
    assert!(pm.executor_restarts <= 5, "restarts capped by the shared panic budget");
    assert!(pm.bl_level <= 2, "degradation stayed on the ladder");
    // And the pool still serves clean values after the storm.
    let out = server.run_workload("op_multiply", &[vec![0.6, 0.5]]).unwrap();
    assert!((out[0] - 0.30).abs() < 0.15, "post-storm value {}", out[0]);
}
