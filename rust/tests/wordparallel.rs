//! Differential property suite for the word-parallel wave engine: every
//! registered interpreter artifact must produce **bit-identical** outputs
//! through the scalar golden path (`execute_rows_scalar`, one row at a
//! time through the staged reference `StagedPlan::eval_row_scalar` →
//! `netlist::eval::eval_stochastic` per stage) and the lane-major
//! word-parallel path (`execute_rows` / `execute_rows_wide`, up to 512
//! rows per `u64×W` lane word), across lane widths {64, 128, 256, 512}
//! and auto, bitstream lengths (including BL % 64 != 0), ragged
//! live-row counts (live % width != 0), worker counts, and seeds. Both
//! paths resolve the same env-default RNG mode, so this suite pins
//! whichever generator family is serving; the explicit per-mode matrix
//! lives in `tests/rng_differential.rs`, the staged apps' dedicated
//! matrix in `tests/staged.rs`.

use stoch_imc::runtime::InterpEngine;
use stoch_imc::util::prng::{fnv1a, Xoshiro256};

/// Batch dimension for every artifact in the differential manifests —
/// large enough for multi-block waves with a ragged tail at every lane
/// width: live=200 splits into 64-row blocks of 64+64+64+8, 128-row
/// blocks of 128+72, and one ragged 256-row block.
const BATCH: usize = 200;

/// Every lane width the engine monomorphizes, plus 0 = auto sizing.
const WIDTHS: [usize; 5] = [64, 128, 256, 512, 0];

const OPS: [&str; 6] = [
    "op_multiply",
    "op_scaled_add",
    "op_abs_subtract",
    "op_scaled_divide",
    "op_square_root",
    "op_exponential",
];

fn engine(bl: usize, tag: &str) -> InterpEngine {
    let dir = std::env::temp_dir().join(format!("stoch_imc_wordparallel_{tag}_{bl}"));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = format!(
        "op_multiply 2 {b} {bl}\nop_scaled_add 2 {b} {bl}\nop_abs_subtract 2 {b} {bl}\n\
         op_scaled_divide 2 {b} {bl}\nop_square_root 1 {b} {bl}\nop_exponential 1 {b} {bl}\n\
         app_ol 6 {b} {bl}\napp_hdp 8 {b} {bl}\napp_lit 64 {b} {bl}\napp_kde 9 {b} {bl}\n",
        b = BATCH,
    );
    std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
    InterpEngine::load(&dir).expect("differential engine load")
}

/// Random full-batch instance values for `name`, deterministic per
/// (artifact, seed) so failures reproduce.
fn values_for(e: &InterpEngine, name: &str, seed: i32) -> Vec<f32> {
    let n = e.spec(name).unwrap().n_inputs;
    let mut rng = Xoshiro256::seeded(fnv1a(name) ^ seed as u32 as u64);
    (0..BATCH * n).map(|_| rng.next_f64() as f32).collect()
}

/// Assert scalar and word-parallel outputs are bit-identical (exact f32
/// equality, padding rows included) for every lane width and requested
/// thread count.
fn assert_paths_equal(e: &InterpEngine, name: &str, bl: usize, live: usize, seed: i32) {
    let values = values_for(e, name, seed);
    let golden = e.execute_rows_scalar(name, &values, seed, live, 1).unwrap();
    for width in WIDTHS {
        for threads in [1usize, 3, 16] {
            let word = e.execute_rows_wide(name, &values, seed, live, threads, width).unwrap();
            assert_eq!(
                golden, word,
                "artifact={name} bl={bl} live={live} width={width} threads={threads} seed={seed}"
            );
        }
    }
}

#[test]
fn ops_bit_identical_across_bl_and_ragged_live() {
    // Ragged and aligned BLs × ragged and aligned live prefixes. The
    // live set walks the lane-word boundaries (1, 63, 64, 65, 128) and
    // a multi-block wave with a ragged tail at every width (200).
    for (bl, lives) in [(100usize, &[1usize, 63, 200][..]), (256, &[64, 65, 128][..])] {
        let e = engine(bl, "ops");
        for (i, name) in OPS.iter().enumerate() {
            for (j, &live) in lives.iter().enumerate() {
                let seed = (bl * 31 + i * 7 + j + 1) as i32;
                assert_paths_equal(&e, name, bl, live, seed);
            }
        }
    }
}

#[test]
fn stateful_ops_bit_identical_at_long_bl() {
    // The feedback circuits (JK divider Delay state, ADDIE counters)
    // carry state across all 1024 bit positions; one drifted lane or a
    // shared-RNG mismatch would diverge long before the stream ends —
    // at every lane width (ADDIE counters above lane 64 included:
    // live=129 puts rows in the third lane word at width 256).
    let e = engine(1024, "long");
    for (k, name) in ["op_scaled_divide", "op_square_root"].iter().enumerate() {
        assert_paths_equal(&e, name, 1024, 65, 7700 + k as i32);
        assert_paths_equal(&e, name, 1024, 129, 7800 + k as i32);
    }
}

#[test]
fn apps_bit_identical_through_both_paths() {
    // All four apps ride the word-parallel path now — the single-stage
    // netlists (app_ol, app_hdp) and the staged pipelines (app_lit,
    // app_kde, in-lane StoB→BtoS regeneration between stages); each
    // must match its scalar staged reference bit for bit.
    let e = engine(100, "apps");
    for (name, live, seed) in [
        ("app_ol", 65, 41),
        ("app_hdp", 63, 42),
        ("app_hdp", 130, 45),
        ("app_lit", 65, 43),
        ("app_kde", 65, 44),
    ] {
        assert_paths_equal(&e, name, 100, live, seed);
    }
}

#[test]
fn seeds_resample_but_paths_stay_locked() {
    // Across several wave seeds the two paths must track each other
    // exactly while producing different bits per seed.
    let e = engine(256, "seeds");
    let mut last: Option<Vec<f32>> = None;
    for seed in [1, 2, 3, 999] {
        let values = values_for(&e, "op_multiply", 5);
        let golden = e.execute_rows_scalar("op_multiply", &values, seed, 200, 1).unwrap();
        let word = e.execute_rows("op_multiply", &values, seed, 200, 4).unwrap();
        assert_eq!(golden, word, "seed={seed}");
        if let Some(prev) = &last {
            assert_ne!(prev, &word, "seed {seed} must resample streams");
        }
        last = Some(word);
    }
}

#[test]
fn widths_agree_with_each_other_on_full_batches() {
    // Direct width-vs-width equality on a full multi-block wave (no
    // scalar reference in the loop, so this also catches a bug that
    // breaks scalar and word paths identically per width).
    let e = engine(100, "widths");
    for name in ["op_multiply", "op_scaled_divide", "app_ol"] {
        let values = values_for(&e, name, 77);
        let base = e.execute_rows_wide(name, &values, 77, BATCH, 2, 64).unwrap();
        for width in [128usize, 256, 512, 0] {
            let other = e.execute_rows_wide(name, &values, 77, BATCH, 3, width).unwrap();
            assert_eq!(base, other, "artifact={name} width={width}");
        }
    }
}
