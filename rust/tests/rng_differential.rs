//! Per-mode differential matrix for the two SNG generator families.
//!
//! `tests/wordparallel.rs` pins scalar ≡ word-parallel through the
//! env-default mode; this suite pins each family *explicitly* via the
//! tuned APIs, so the counter path (default) and the xoshiro compat
//! path (`STOCH_IMC_RNG=xoshiro`) each stay bit-identical across
//! scalar reference × lane widths {64, 128, 256, 512, auto} × worker
//! counts {1, 3, 16} — and never alias each other. No test mutates the
//! environment (explicit `RngMode` parameters only), so the suite is
//! safe under the parallel test runner.

use stoch_imc::runtime::InterpEngine;
use stoch_imc::util::prng::{fnv1a, RngMode, Xoshiro256};

const BATCH: usize = 200;
const WIDTHS: [usize; 5] = [64, 128, 256, 512, 0];
const MODES: [RngMode; 2] = [RngMode::Counter, RngMode::Xoshiro];

fn engine(bl: usize, tag: &str) -> InterpEngine {
    let dir = std::env::temp_dir().join(format!("stoch_imc_rngdiff_{tag}_{bl}"));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = format!(
        "op_multiply 2 {b} {bl}\nop_scaled_divide 2 {b} {bl}\nop_exponential 1 {b} {bl}\n\
         app_ol 6 {b} {bl}\napp_hdp 8 {b} {bl}\napp_lit 64 {b} {bl}\napp_kde 9 {b} {bl}\n",
        b = BATCH,
    );
    std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
    InterpEngine::load(&dir).expect("differential engine load")
}

fn values_for(e: &InterpEngine, name: &str, seed: i32) -> Vec<f32> {
    let n = e.spec(name).unwrap().n_inputs;
    let mut rng = Xoshiro256::seeded(fnv1a(name) ^ seed as u32 as u64);
    (0..BATCH * n).map(|_| rng.next_f64() as f32).collect()
}

/// Assert the explicit-mode scalar reference and every (width, threads)
/// wide configuration agree bit-for-bit, per mode; return both modes'
/// outputs so callers can assert the families differ.
fn assert_mode_matrix(
    e: &InterpEngine,
    name: &str,
    values: &[f32],
    live: usize,
    seed: i32,
) -> [Vec<f32>; 2] {
    MODES.map(|mode| {
        let golden = e.execute_rows_scalar_tuned(name, values, seed, live, 1, Some(mode)).unwrap();
        for width in WIDTHS {
            for threads in [1usize, 3, 16] {
                let (wide, _) = e
                    .execute_rows_tuned(name, values, seed, live, threads, width, Some(mode), None)
                    .unwrap();
                assert_eq!(
                    golden, wide,
                    "artifact={name} mode={mode:?} live={live} width={width} \
                     threads={threads} seed={seed}"
                );
            }
        }
        golden
    })
}

#[test]
fn ops_pinned_per_mode_across_widths_and_threads() {
    // Ragged BL (100) and ragged live counts walk the lane-word
    // boundaries; 200 live rows make a multi-block wave with a ragged
    // tail at every width.
    let e = engine(100, "ops");
    for (i, name) in ["op_multiply", "op_scaled_divide", "op_exponential"].iter().enumerate() {
        for (j, live) in [1usize, 65, 200].into_iter().enumerate() {
            let seed = (i * 7 + j + 1) as i32;
            let values = values_for(&e, name, seed);
            let [ctr, xos] = assert_mode_matrix(&e, name, &values, live, seed);
            // A single row on the 1/BL StoB grid can coincide across
            // families by chance; only multi-row waves make aliasing
            // all but impossible.
            if live > 1 {
                assert_ne!(ctr, xos, "artifact={name} live={live}: generator families alias");
            }
        }
    }
}

#[test]
fn apps_pinned_per_mode_including_staged_regeneration() {
    // The staged pipelines (app_lit, app_kde) regenerate between
    // stages and draw correlated groups — the counter path's group
    // keying (NODE_GROUP) and per-stage node tagging must survive the
    // full pipeline in both families.
    let e = engine(100, "apps");
    for (name, live, seed) in
        [("app_ol", 65, 41), ("app_hdp", 63, 42), ("app_lit", 65, 43), ("app_kde", 65, 44)]
    {
        let values = values_for(&e, name, seed);
        let [ctr, xos] = assert_mode_matrix(&e, name, &values, live, seed);
        assert_ne!(ctr, xos, "artifact={name}: generator families alias");
    }
}

#[test]
fn repeated_value_batches_pin_the_cutoff_hoist_and_block_cache() {
    // A batch where every row repeats the same inputs maximizes both
    // per-wave cutoff-memo hits and (on re-execution) SNG block-cache
    // hits; outputs must stay bit-identical to the scalar reference
    // through all of it — the identity pin for the hoisted cutoffs.
    let e = engine(256, "repeat");
    let mut values = vec![0.0f32; BATCH * 2];
    for i in 0..BATCH {
        values[2 * i] = 0.7;
        values[2 * i + 1] = 0.35;
    }
    assert_mode_matrix(&e, "op_multiply", &values, BATCH, 9);
    // Re-execute the identical wave: the engine-level cache serves the
    // blocks, and the outputs still match the scalar reference.
    let golden =
        e.execute_rows_scalar_tuned("op_multiply", &values, 9, BATCH, 1, Some(RngMode::Counter));
    let (again, stats) = e
        .execute_rows_tuned("op_multiply", &values, 9, BATCH, 2, 0, Some(RngMode::Counter), None)
        .unwrap();
    assert_eq!(golden.unwrap(), again);
    assert!(stats.cache.hits > 0, "repeated wave must be served from the SNG block cache");
    assert!(stats.cache.cutoff_hits > 0, "repeated values must hit the cutoff memo");
}

#[test]
fn seeds_resample_both_families_without_unlocking_them() {
    let e = engine(256, "seeds");
    let values = values_for(&e, "op_multiply", 5);
    for mode in MODES {
        let mut last: Option<Vec<f32>> = None;
        for seed in [1, 2, 999] {
            let golden =
                e.execute_rows_scalar_tuned("op_multiply", &values, seed, 200, 1, Some(mode));
            let (wide, _) = e
                .execute_rows_tuned("op_multiply", &values, seed, 200, 4, 0, Some(mode), None)
                .unwrap();
            assert_eq!(golden.unwrap(), wide, "mode={mode:?} seed={seed}");
            if let Some(prev) = &last {
                assert_ne!(prev, &wide, "mode={mode:?} seed {seed} must resample streams");
            }
            last = Some(wide);
        }
    }
}
