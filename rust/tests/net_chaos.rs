//! Network chaos acceptance — the TCP front door must carry the serve
//! layer's resilience contract across a socket:
//!
//! * with no fault injection, the TCP path is **bit-identical** to the
//!   in-process `submit` path at the same seeds;
//! * malformed frames get a typed `ProtocolError` control and a close —
//!   never a panic, never a hang;
//! * a slowloris peer pins at most its own connection thread, and only
//!   until the io deadline; concurrent good connections are unaffected;
//! * graceful drain answers idle connections `GoingAway`, returns
//!   promptly, and leaves zero wedged threads;
//! * the retrying client reconnects through mid-frame cuts and delivers
//!   each result exactly once; the circuit breaker fast-fails a dead
//!   target and half-opens on its timer;
//! * a multi-client storm under the full injector set still yields
//!   exactly one terminal outcome per request.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use stoch_imc::coordinator::BatcherConfig;
use stoch_imc::serve::net::wire;
use stoch_imc::serve::net::{
    BreakerConfig, BreakerState, Client, ClientConfig, NetError, RetryPolicy,
};
use stoch_imc::serve::{ChaosPlan, NetChaos, Server, ServerConfig, TcpFront, TcpFrontConfig};

fn manifest_dir(tag: &str, lines: &str) -> PathBuf {
    // Pin the default backend (see tests/interp_engine.rs for why this
    // is safe in this binary).
    std::env::remove_var("STOCH_IMC_BACKEND");
    let dir = std::env::temp_dir().join(format!("stoch_imc_it_net_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), lines).unwrap();
    dir
}

/// A front on an ephemeral port over a deterministic single-shard,
/// single-row-thread server (batch=1 ⇒ every request is its own wave,
/// so sequential callers reproduce the exact wave sequence).
fn start_front(tag: &str, front_cfg: TcpFrontConfig) -> TcpFront {
    start_front_over(tag, ServerConfig::default(), front_cfg)
}

fn start_front_over(tag: &str, server_cfg: ServerConfig, front_cfg: TcpFrontConfig) -> TcpFront {
    let dir = manifest_dir(tag, "op_multiply 2 1 512\n");
    let cfg = ServerConfig { shards: 1, row_threads: 1, ..server_cfg };
    let server = Arc::new(Server::start(&dir, cfg).unwrap());
    let front_cfg = TcpFrontConfig { addr: "127.0.0.1:0".into(), ..front_cfg };
    TcpFront::start(server, front_cfg).unwrap()
}

fn client_for(front: &TcpFront, cfg: ClientConfig) -> Client {
    Client::new(front.local_addr().to_string(), cfg)
}

#[test]
fn no_fault_tcp_path_is_bit_identical_to_in_process_submit() {
    // Same manifest, same sequential workload, batch=1 single-shard
    // single-row-thread servers: the wave sequence is identical, so the
    // TCP hop must not change a single bit of any result.
    let dir = manifest_dir("bitident", "op_multiply 2 1 512\n");
    let work: Vec<Vec<f64>> = (0..16).map(|i| vec![(i as f64 + 1.0) / 20.0, 0.7]).collect();
    let cfg = || ServerConfig { shards: 1, row_threads: 1, ..ServerConfig::default() };

    let in_proc = Server::start(&dir, cfg()).unwrap();
    let mut want = Vec::new();
    for x in &work {
        let rx = in_proc.submit("op_multiply", x).unwrap();
        want.push(rx.recv().unwrap().expect("clean serving yields values"));
    }
    drop(in_proc);

    let front = TcpFront::start(
        Arc::new(Server::start(&dir, cfg()).unwrap()),
        TcpFrontConfig { addr: "127.0.0.1:0".into(), ..TcpFrontConfig::default() },
    )
    .unwrap();
    let mut client = client_for(&front, ClientConfig::default());
    for (x, want) in work.iter().zip(&want) {
        let got = client.call("op_multiply", x).expect("no-fault TCP call succeeds");
        assert_eq!(got.to_bits(), want.to_bits(), "TCP result differs from in-process submit");
    }
    let snap = front.snapshot();
    assert_eq!(snap.get("serve_net_frames_rx"), Some(16.0));
    assert_eq!(snap.get("serve_net_frames_tx"), Some(16.0));
    assert_eq!(snap.get("serve_net_protocol_errors"), Some(0.0));
    // One connection reused across all 16 calls.
    assert_eq!(snap.get("serve_net_connections"), Some(1.0));
    assert_eq!(client.stats().connects, 1, "clean serving never reconnects");
}

#[test]
fn malformed_frames_get_a_typed_protocol_error_then_close() {
    // Raw-socket abuse: every malformed frame is answered with a
    // `ProtocolError` control frame and a close — no panic, no hang,
    // and the front keeps serving afterwards.
    let front = start_front("malformed", TcpFrontConfig::default());
    let addr = front.local_addr();

    let mut oversized = vec![b'S', b'C', wire::VERSION, wire::KIND_REQUEST];
    oversized.extend_from_slice(&(wire::MAX_PAYLOAD as u32 + 1).to_le_bytes());
    // A syntactically valid header whose payload is garbage.
    let mut bad_payload = vec![b'S', b'C', wire::VERSION, wire::KIND_REQUEST];
    bad_payload.extend_from_slice(&4u32.to_le_bytes());
    bad_payload.extend_from_slice(&[0xDE, 0xAD, 0xBE, 0xEF]);
    let cases: Vec<(&str, Vec<u8>)> = vec![
        ("bad magic", vec![b'X', b'C', wire::VERSION, wire::KIND_REQUEST, 0, 0, 0, 0]),
        ("unknown version", vec![b'S', b'C', 9, wire::KIND_REQUEST, 0, 0, 0, 0]),
        ("unknown kind", vec![b'S', b'C', wire::VERSION, 7, 0, 0, 0, 0]),
        ("oversized length", oversized),
        ("garbage payload", bad_payload),
    ];
    for (name, bytes) in &cases {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(bytes).unwrap();
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).unwrap_or_else(|e| panic!("{name}: read: {e}"));
        let (kind, payload) =
            wire::decode_frame_bytes(&buf).unwrap_or_else(|e| panic!("{name}: reply frame: {e}"));
        assert_eq!(kind, wire::KIND_CONTROL, "{name}");
        match wire::decode_control(payload) {
            Ok(wire::Control::ProtocolError(msg)) => {
                assert!(!msg.is_empty(), "{name}: empty diagnostic");
            }
            other => panic!("{name}: expected ProtocolError control, got {other:?}"),
        }
    }
    // The front survived all of it and still serves values.
    let mut client = client_for(&front, ClientConfig::default());
    assert!(client.call("op_multiply", &[0.5, 0.5]).is_ok(), "front wedged by malformed frames");
    let snap = front.snapshot();
    assert_eq!(snap.get("serve_net_protocol_errors"), Some(5.0));
}

#[test]
fn slow_peer_is_killed_by_the_io_deadline_without_stalling_others() {
    // A slowloris peer sends 3 bytes of a header and stops. The total
    // frame-read deadline kills it within ~io_timeout, and a healthy
    // client on a sibling connection is answered promptly throughout.
    let io = Duration::from_millis(300);
    let front = start_front(
        "slowpeer",
        TcpFrontConfig { io_timeout: io, ..TcpFrontConfig::default() },
    );
    let mut slow = TcpStream::connect(front.local_addr()).unwrap();
    slow.write_all(&[b'S', b'C', wire::VERSION]).unwrap();
    let t0 = Instant::now();

    // While the slow peer dangles, a good client gets quick answers.
    let mut client = client_for(&front, ClientConfig::default());
    for _ in 0..5 {
        let t = Instant::now();
        client.call("op_multiply", &[0.5, 0.5]).expect("healthy lane serves");
        assert!(t.elapsed() < Duration::from_secs(5), "healthy lane stalled behind slow peer");
    }

    // The slow connection is closed within the io budget (plus grace).
    slow.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 16];
    let closed = matches!(slow.read(&mut buf), Ok(0) | Err(_));
    assert!(closed, "slowloris connection outlived the io deadline");
    assert!(
        t0.elapsed() < io + Duration::from_secs(5),
        "stall kill took {:?}, io budget {:?}",
        t0.elapsed(),
        io
    );
    let snap = front.snapshot();
    assert!(snap.get("serve_net_io_timeouts").unwrap_or(0.0) >= 1.0, "stall not counted");
}

#[test]
fn drain_answers_going_away_and_leaves_zero_wedged_threads() {
    // An idle connection at drain time is told `GoingAway`; shutdown
    // joins every thread and returns promptly; post-drain the metrics
    // show zero active connections.
    let mut front = start_front("drain", TcpFrontConfig::default());
    let mut idle = TcpStream::connect(front.local_addr()).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    // Serve one request first so the connection is fully established.
    let mut client = client_for(&front, ClientConfig::default());
    client.call("op_multiply", &[0.5, 0.5]).unwrap();

    let t0 = Instant::now();
    front.shutdown();
    assert!(t0.elapsed() < Duration::from_secs(10), "drain wedged: {:?}", t0.elapsed());

    // The idle peer received the GoingAway control before the close.
    let mut buf = Vec::new();
    idle.read_to_end(&mut buf).unwrap();
    let (kind, payload) = wire::decode_frame_bytes(&buf).expect("drain notice is a clean frame");
    assert_eq!(kind, wire::KIND_CONTROL);
    assert!(matches!(wire::decode_control(payload), Ok(wire::Control::GoingAway)));

    let snap = front.snapshot();
    assert_eq!(snap.get("serve_net_active_connections"), Some(0.0), "threads left behind");
    assert!(snap.get("serve_net_going_away").unwrap_or(0.0) >= 1.0);
    // A second shutdown is an idempotent no-op.
    front.shutdown();
}

#[test]
fn client_retries_through_mid_frame_cuts_and_delivers_exactly_once() {
    // Every second response is cut mid-frame and the socket slammed
    // shut. Cuts are transport failures (no result delivered), so the
    // client retries on a fresh connection and every call still lands
    // exactly one value — never zero, never two.
    let front = start_front(
        "cuts",
        TcpFrontConfig {
            chaos: NetChaos { cut_every: 2, ..NetChaos::default() },
            ..TcpFrontConfig::default()
        },
    );
    let mut client = client_for(
        &front,
        ClientConfig {
            retry: RetryPolicy { max: 4, base: Duration::from_millis(1), seed: 42 },
            ..ClientConfig::default()
        },
    );
    const CALLS: usize = 12;
    for i in 0..CALLS {
        let v = client.call("op_multiply", &[0.5, 0.5]).unwrap_or_else(|e| {
            panic!("call {i} should retry through the cut: {e}");
        });
        assert!((f64::from(v) - 0.25).abs() < 0.1, "call {i}: value {v}");
    }
    let stats = client.stats();
    assert_eq!(stats.ok as usize, CALLS, "exactly one delivery per call");
    assert!(stats.retries >= (CALLS / 2) as u64, "cut responses must have been retried");
    assert!(stats.connects > 1, "cut connections must reconnect");
    let snap = front.snapshot();
    assert!(snap.get("serve_net_chaos_cuts").unwrap_or(0.0) >= (CALLS / 2) as f64);
}

#[test]
fn breaker_fast_fails_a_dead_target_and_half_opens_on_its_timer() {
    // Reserve an ephemeral port, then free it: connects are refused.
    let addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let cooloff = Duration::from_millis(200);
    let mut client = Client::new(
        addr,
        ClientConfig {
            io_timeout: Duration::from_millis(200),
            retry: RetryPolicy { max: 0, base: Duration::from_millis(1), seed: 1 },
            breaker: BreakerConfig { threshold: 2, cooloff },
            ..ClientConfig::default()
        },
    );
    // Two transport failures trip the breaker…
    for i in 0..2 {
        match client.call("op_multiply", &[0.5, 0.5]) {
            Err(NetError::RetriesExhausted { last, .. }) => {
                assert!(matches!(*last, NetError::Transport(_)), "call {i}: {last:?}");
            }
            other => panic!("call {i}: expected exhausted transport error, got {other:?}"),
        }
    }
    assert_eq!(client.breaker_state(), BreakerState::Open);
    // …so the next call fast-fails without touching the network.
    let connects_before = client.stats().connects;
    assert!(matches!(client.call("op_multiply", &[0.5, 0.5]), Err(NetError::BreakerOpen)));
    assert_eq!(client.stats().connects, connects_before, "fast-fail must not dial");
    assert_eq!(client.stats().breaker_fast_fails, 1);
    // After the cooloff the breaker half-opens: exactly one probe goes
    // out (a real connect attempt), fails, and re-opens the breaker.
    std::thread::sleep(cooloff + Duration::from_millis(50));
    let probe = client.call("op_multiply", &[0.5, 0.5]);
    assert!(matches!(probe, Err(NetError::RetriesExhausted { .. })), "{probe:?}");
    assert_eq!(client.stats().connects, connects_before, "refused connects never complete");
    assert_eq!(client.breaker_state(), BreakerState::Open, "failed probe re-opens");
}

#[test]
fn overload_is_shed_as_typed_overloaded_not_queued_unboundedly() {
    // queue_depth=1 against 20ms waves: concurrent callers overrun the
    // admission queue and the overflow is answered with a typed,
    // retry-safe `Overloaded` — the front never queues unboundedly.
    let front = start_front_over(
        "shed",
        ServerConfig {
            queue_depth: 1,
            batcher: BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() },
            chaos: Some(ChaosPlan {
                latency_every: 1,
                latency: Duration::from_millis(20),
                ..Default::default()
            }),
            ..ServerConfig::default()
        },
        TcpFrontConfig::default(),
    );
    let addr = front.local_addr().to_string();
    let (ok, overloaded) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut client = Client::new(
                        addr,
                        ClientConfig {
                            retry: RetryPolicy { max: 0, base: Duration::ZERO, seed: 3 },
                            ..ClientConfig::default()
                        },
                    );
                    let (mut ok, mut overloaded) = (0u64, 0u64);
                    for _ in 0..10 {
                        match client.call("op_multiply", &[0.5, 0.5]) {
                            Ok(_) => ok += 1,
                            Err(NetError::RetriesExhausted { last, .. })
                                if *last == NetError::Overloaded =>
                            {
                                overloaded += 1;
                            }
                            Err(e) => panic!("unexpected outcome under overload: {e}"),
                        }
                    }
                    (ok, overloaded)
                })
            })
            .collect();
        let (mut ok, mut overloaded) = (0u64, 0u64);
        for h in handles {
            let (o, v) = h.join().expect("client thread");
            ok += o;
            overloaded += v;
        }
        (ok, overloaded)
    });
    assert_eq!(ok + overloaded, 80, "every call terminal");
    assert!(ok > 0, "some calls must get through");
    assert!(overloaded > 0, "queue_depth=1 under 8 concurrent callers must shed");
    let snap = front.snapshot();
    assert_eq!(snap.get("serve_net_shed"), Some(overloaded as f64), "sheds counted exactly");
}

#[test]
fn storm_under_full_net_chaos_yields_one_terminal_outcome_per_call() {
    // The kitchen sink: accept-then-drop, mid-frame cuts, byte
    // trickles, and stalled reads, against four concurrent retrying
    // clients with real deadlines. The promises: every call returns
    // exactly one terminal outcome, values still flow, and the front
    // drains clean afterwards.
    let net = NetChaos {
        accept_drop_every: 5,
        cut_every: 7,
        trickle_every: 5,
        trickle_delay: Duration::from_millis(1),
        stall_read_every: 9,
        stall: Duration::from_millis(30),
    };
    let mut front = start_front_over(
        "storm",
        ServerConfig {
            batcher: BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() },
            ..ServerConfig::default()
        },
        TcpFrontConfig {
            chaos: net,
            io_timeout: Duration::from_millis(500),
            ..TcpFrontConfig::default()
        },
    );
    let addr = front.local_addr().to_string();
    const THREADS: u64 = 4;
    const PER: u64 = 25;
    let (ok, errs) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|k| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut client = Client::new(
                        addr,
                        ClientConfig {
                            io_timeout: Duration::from_millis(500),
                            retry: RetryPolicy {
                                max: 3,
                                base: Duration::from_millis(2),
                                seed: 0xBAD ^ k,
                            },
                            ..ClientConfig::default()
                        },
                    );
                    let (mut ok, mut errs) = (0u64, 0u64);
                    for _ in 0..PER {
                        match client.call_with_deadline(
                            "op_multiply",
                            &[0.5, 0.5],
                            Duration::from_millis(800),
                        ) {
                            Ok(v) => {
                                assert!((f64::from(v) - 0.25).abs() < 0.1, "storm value {v}");
                                ok += 1;
                            }
                            Err(_) => errs += 1,
                        }
                    }
                    (ok, errs)
                })
            })
            .collect();
        let (mut ok, mut errs) = (0u64, 0u64);
        for h in handles {
            let (o, e) = h.join().expect("storm client thread");
            ok += o;
            errs += e;
        }
        (ok, errs)
    });
    assert_eq!(ok + errs, THREADS * PER, "exactly one terminal outcome per call");
    assert!(ok > 0, "the storm must still deliver values");

    let t0 = Instant::now();
    front.shutdown();
    assert!(t0.elapsed() < Duration::from_secs(10), "post-storm drain wedged");
    let snap = front.snapshot();
    assert_eq!(snap.get("serve_net_active_connections"), Some(0.0), "wedged threads post-drain");
    assert!(snap.get("serve_net_chaos_cuts").unwrap_or(0.0) > 0.0, "cut injector never fired");
    assert!(
        snap.get("serve_net_chaos_accept_drops").unwrap_or(0.0) > 0.0,
        "accept-drop injector never fired"
    );
}
