//! Tier-1 smoke: the default (interpreter) runtime path end-to-end,
//! plus the PJRT HLO round-trip when the xla backend is linked.
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]

#[test]
fn interp_engine_smoke_multiply() {
    std::env::remove_var("STOCH_IMC_BACKEND");
    let dir = std::env::temp_dir().join("stoch_imc_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "op_multiply 2 2 4096\n").unwrap();
    let e = stoch_imc::runtime::Engine::load(&dir).unwrap();
    assert_eq!(e.platform(), "interp");
    assert_eq!(e.artifact_names(), vec!["op_multiply"]);
    let spec = e.spec("op_multiply").unwrap();
    assert_eq!((spec.n_inputs, spec.batch, spec.bl), (2, 2, 4096));
    let out = e.execute("op_multiply", &[0.5, 0.5, 0.9, 0.8], 7, 2).unwrap();
    assert_eq!(out.len(), 2);
    assert!((out[0] - 0.25).abs() < 0.05, "{}", out[0]);
    assert!((out[1] - 0.72).abs() < 0.05, "{}", out[1]);
}

// PJRT HLO round-trip: needs the xla crate linked (`xla-runtime` +
// `--cfg xla_available`) and `artifacts/smoke.hlo.txt` built.
#[cfg(all(feature = "xla-runtime", xla_available))]
#[test]
fn hlo_roundtrip() {
    let v = stoch_imc::runtime::smoke("artifacts/smoke.hlo.txt").unwrap();
    assert_eq!(v, vec![5f32, 5., 9., 9.]);
}
