#[test]
fn hlo_roundtrip() {
    let v = stoch_imc::runtime::smoke("artifacts/smoke.hlo.txt").unwrap();
    assert_eq!(v, vec![5f32, 5., 9., 9.]);
}
