//! Interpreter-backend coverage: round-trip apps/ops through
//! `Coordinator::start` → `submit`/`run_workload` → shutdown on the
//! pure-Rust engine, asserting values against the float references and
//! that the batching metrics are recorded.

use std::path::PathBuf;
use std::time::Duration;

use stoch_imc::apps::{ol::Ol, App};
use stoch_imc::coordinator::{BatcherConfig, Coordinator};

fn manifest_dir(tag: &str, lines: &str) -> PathBuf {
    // Pin the default backend: a stray STOCH_IMC_BACKEND must not
    // redirect these interpreter tests elsewhere. Safe here: every env
    // access in this binary goes through std::env, which serializes
    // internally; no foreign code calls getenv concurrently.
    std::env::remove_var("STOCH_IMC_BACKEND");
    let dir = std::env::temp_dir().join(format!("stoch_imc_it_interp_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), lines).unwrap();
    dir
}

#[test]
fn coordinator_round_trips_app_ol_and_records_metrics() {
    let dir = manifest_dir("ol", "app_ol 6 8 2048\n");
    let coord = Coordinator::start(&dir, BatcherConfig::default()).unwrap();
    assert_eq!(coord.apps(), vec!["app_ol".to_string()]);
    assert_eq!(coord.n_inputs("app_ol"), Some(6));

    let app = Ol::default();
    let w = app.workload(20, 7);
    let outs = coord.run_workload("app_ol", &w).unwrap();
    assert_eq!(outs.len(), 20);
    for (x, o) in w.iter().zip(&outs) {
        let f = app.float_ref(x);
        assert!((o - f).abs() < 0.1, "interp {o} vs float {f}");
    }

    // Batching metrics: every request accounted, waves of 8, padding
    // conserved (live + padded slots = waves × batch).
    let m = coord.metrics("app_ol");
    assert_eq!(m.requests, 20);
    assert!(m.waves >= 3, "20 requests at batch 8 need ≥3 waves, got {}", m.waves);
    assert_eq!(m.padded_slots, m.waves * 8 - 20);
    assert!(m.latency_us(50.0) > 0);
    assert!(m.throughput() > 0.0);
    assert!(!m.summary().is_empty());

    // Dropping the coordinator sends Shutdown and joins the controller.
    drop(coord);
}

#[test]
fn submit_then_shutdown_drains_pending_requests() {
    // A partial wave left in the batcher must still be answered when the
    // coordinator shuts down (drain-on-shutdown).
    let dir = manifest_dir("drain", "op_multiply 2 64 1024\n");
    let coord = Coordinator::start(
        &dir,
        BatcherConfig { batch: 64, max_wait: Duration::from_secs(600) },
    )
    .unwrap();
    let rx = coord.submit("op_multiply", &[0.6, 0.7]).unwrap();
    drop(coord); // Shutdown drains the partial wave.
    let out =
        rx.recv().expect("pending request answered on shutdown").expect("drained with a value")
            as f64;
    assert!((out - 0.42).abs() < 0.1, "got {out}");
}

#[test]
fn submit_rejects_bad_requests() {
    let dir = manifest_dir("reject", "op_multiply 2 4 256\n");
    let coord = Coordinator::start(&dir, BatcherConfig::default()).unwrap();
    assert!(coord.submit("op_multiply", &[0.5]).is_err(), "wrong arity");
    assert!(coord.submit("no_such_app", &[0.5, 0.5]).is_err(), "unknown app");
    assert_eq!(coord.n_inputs("no_such_app"), None);
}

#[test]
fn missing_manifest_fails_start_with_context() {
    std::env::remove_var("STOCH_IMC_BACKEND");
    let err = Coordinator::start(
        std::path::Path::new("/nonexistent_stoch_imc"),
        BatcherConfig::default(),
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("manifest"), "{err:#}");
}
