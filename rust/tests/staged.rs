//! Differential + statistical suite for the staged lane-major pipeline:
//! `app_lit` and `app_kde` (the multi-stage StoB→BtoS regeneration
//! apps) must produce **bit-identical** outputs through the scalar
//! staged reference (`execute_rows_scalar` →
//! `StagedPlan::eval_row_scalar`, one row at a time through
//! `eval_stochastic` per stage) and the lane-major staged executor
//! (`execute_rows` / `execute_rows_wide`, in-lane regeneration between
//! stages), across lane widths {64, 128, 256, auto}, thread counts,
//! ragged live-row counts, and seeds — the same contract the flat
//! kernels have in `tests/wordparallel.rs` — and must track the float
//! references statistically.

use stoch_imc::apps::{kde::Kde, lit::Lit, App};
use stoch_imc::runtime::InterpEngine;
use stoch_imc::util::prng::{fnv1a, Xoshiro256};

/// Batch dimension: 200 keeps a ragged tail at every lane width
/// (64-row blocks of 64+64+64+8, 128-row blocks of 128+72, one ragged
/// 256-row block).
const BATCH: usize = 200;

/// Every lane width the engine monomorphizes, plus 0 = auto sizing.
const WIDTHS: [usize; 4] = [64, 128, 256, 0];

const APPS: [&str; 2] = ["app_lit", "app_kde"];

fn engine(bl: usize, tag: &str) -> InterpEngine {
    let dir = std::env::temp_dir().join(format!("stoch_imc_staged_{tag}_{bl}"));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = format!("app_lit 64 {b} {bl}\napp_kde 9 {b} {bl}\n", b = BATCH);
    std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
    InterpEngine::load(&dir).expect("staged engine load")
}

/// Random full-batch instance values for `name`, deterministic per
/// (artifact, seed) so failures reproduce.
fn values_for(e: &InterpEngine, name: &str, seed: i32) -> Vec<f32> {
    let n = e.spec(name).unwrap().n_inputs;
    let mut rng = Xoshiro256::seeded(fnv1a(name) ^ seed as u32 as u64);
    (0..BATCH * n).map(|_| rng.next_f64() as f32).collect()
}

#[test]
fn staged_apps_bit_identical_across_widths_threads_and_ragged_live() {
    // The acceptance matrix: every lane width × thread count against
    // the scalar staged reference, at live counts walking the
    // lane-word boundaries (1, one short of a word, into the third
    // word at width 256). BL=100 also exercises the ragged tail word
    // of every stream (100 % 64 != 0).
    let bl = 100usize;
    let e = engine(bl, "matrix");
    for (a, name) in APPS.iter().enumerate() {
        for (j, &live) in [1usize, 63, 130].iter().enumerate() {
            let seed = (a * 17 + j * 5 + 1) as i32;
            let values = values_for(&e, name, seed);
            let golden = e.execute_rows_scalar(name, &values, seed, live, 1).unwrap();
            for width in WIDTHS {
                for threads in [1usize, 3, 16] {
                    let word =
                        e.execute_rows_wide(name, &values, seed, live, threads, width).unwrap();
                    assert_eq!(
                        golden, word,
                        "artifact={name} live={live} width={width} threads={threads}"
                    );
                }
            }
        }
    }
}

#[test]
fn staged_apps_bit_identical_on_full_multiblock_waves() {
    // Full 200-row waves: multi-block at every width with a ragged
    // tail block, and a scalar reference computed multi-threaded (the
    // scalar split must be invisible too).
    let bl = 64usize;
    let e = engine(bl, "full");
    for (a, name) in APPS.iter().enumerate() {
        let seed = 900 + a as i32;
        let values = values_for(&e, name, seed);
        let golden = e.execute_rows_scalar(name, &values, seed, BATCH, 3).unwrap();
        for (width, threads) in [(64usize, 16usize), (128, 3), (256, 1), (0, 4)] {
            let word = e.execute_rows_wide(name, &values, seed, BATCH, threads, width).unwrap();
            assert_eq!(golden, word, "artifact={name} width={width} threads={threads}");
        }
    }
}

#[test]
fn staged_seeds_resample_but_paths_stay_locked() {
    let bl = 64usize;
    let e = engine(bl, "seeds");
    let values = values_for(&e, "app_kde", 5);
    let mut last: Option<Vec<f32>> = None;
    for seed in [1, 2, 999] {
        let golden = e.execute_rows_scalar("app_kde", &values, seed, 70, 1).unwrap();
        let word = e.execute_rows("app_kde", &values, seed, 70, 4).unwrap();
        assert_eq!(golden, word, "seed={seed}");
        if let Some(prev) = &last {
            assert_ne!(prev, &word, "seed {seed} must resample staged streams");
        }
        last = Some(word);
    }
}

#[test]
fn staged_lane_pipeline_tracks_float_references() {
    // The engine's staged outputs must approximate the float models —
    // the statistical half of the staged-reference contract (the
    // bit-level half is the differential tests above). BL=1024 keeps
    // per-stream noise ≈ sqrt(p(1-p)/1024) ≤ 0.016; the staged
    // pipelines chain a handful of streams plus the ADDIE √ (LIT) and
    // the Maclaurin truncation (KDE), hence the wider bounds.
    let dir = std::env::temp_dir().join("stoch_imc_staged_float");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "app_lit 64 8 1024\napp_kde 9 8 1024\n").unwrap();
    let e = InterpEngine::load(&dir).expect("staged float engine");

    let lit = Lit::default();
    let w = lit.workload(8, 23);
    let mut values = Vec::new();
    for inst in &w {
        values.extend(inst.iter().map(|&v| v as f32));
    }
    let out = e.execute("app_lit", &values, 7, 8).unwrap();
    let mut worst = 0.0f64;
    for (inst, o) in w.iter().zip(&out) {
        let f = lit.float_ref(inst);
        worst = worst.max((*o as f64 - f).abs());
        assert!((*o as f64 - f).abs() < 0.2, "lit got {o} want {f}");
    }
    assert!(worst < 0.2, "lit worst error {worst}");

    let kde = Kde::default();
    let w = kde.workload(8, 29);
    let mut values = Vec::new();
    for inst in &w {
        values.extend(inst.iter().map(|&v| v as f32));
    }
    let out = e.execute("app_kde", &values, 9, 8).unwrap();
    for (inst, o) in w.iter().zip(&out) {
        let f = kde.float_ref(inst);
        assert!((*o as f64 - f).abs() < 0.2, "kde got {o} want {f}");
    }
}
