//! Property-based tests on the coordinator-facing invariants: scheduler
//! correctness over random netlists, value-model agreement, batcher
//! conservation, and fault-injection monotonicity.

use std::collections::HashMap;

use stoch_imc::netlist::{
    eval::eval_stochastic, graph::InputClass, ops, replicate::replicate, GateKind, Netlist,
};
use stoch_imc::sc::bitstream::Bitstream;
use stoch_imc::scheduler::algorithm1::{schedule, Mode, Options};
use stoch_imc::scheduler::validate::validate;
use stoch_imc::util::check::{forall, Gen};
use stoch_imc::util::prng::Xoshiro256;

/// Random feed-forward netlist over the reliable gate set.
fn random_netlist(g: &mut Gen) -> Netlist {
    let mut nl = Netlist::new();
    let n_inputs = g.usize_in(2, 6);
    let mut pool: Vec<usize> = (0..n_inputs)
        .map(|i| nl.input(&format!("x{i}"), 0, 1, InputClass::Stochastic))
        .collect();
    let n_gates = g.usize_in(3, 25);
    for _ in 0..n_gates {
        let a = *g.choose(&pool);
        let kind = *g.choose(&[GateKind::Nand, GateKind::Not, GateKind::Buff]);
        let id = match kind {
            GateKind::Nand => {
                let b = *g.choose(&pool);
                if b == a {
                    nl.gate(GateKind::Not, 0, vec![a]) // avoid same-cell NAND
                } else {
                    nl.gate(GateKind::Nand, 0, vec![a, b])
                }
            }
            k => nl.gate(k, 0, vec![a]),
        };
        pool.push(id);
    }
    let out = *pool.last().unwrap();
    nl.mark_output("out", out);
    nl
}

#[test]
fn prop_scheduler_valid_on_random_netlists() {
    forall(0x5EED1, 60, |g| {
        let base = random_netlist(g);
        let q = g.usize_in(1, 32);
        let rep = replicate(&base, q);
        for mode in [Mode::Asap, Mode::LayerStrict] {
            let s = schedule(&rep, &Options { mode });
            let viol = validate(&rep, &s, 1 << 20, 1 << 20);
            assert!(viol.is_empty(), "{mode:?}: {viol:?}");
            assert_eq!(s.rows_used, q.max(1));
        }
    });
}

#[test]
fn prop_array_execution_matches_eval_on_random_netlists() {
    forall(0x5EED2, 25, |g| {
        let base = random_netlist(g);
        let q = g.usize_in(1, 16);
        let rep = replicate(&base, q);
        let s = schedule(&rep, &Options::default());
        let mut rng = Xoshiro256::seeded(g.u64_below(1 << 62));
        let mut inputs = HashMap::new();
        for (_i, node) in base.nodes.iter().enumerate() {
            if let stoch_imc::netlist::Node::Input { name, .. } = node {
                inputs.insert(name.clone(), Bitstream::sample(rng.next_f64(), 64, &mut rng));
            }
        }
        let mut array = stoch_imc::imc::Subarray::new(q, s.cols_used);
        let (got, _) = stoch_imc::imc::execute_replicated(
            &base, &rep, &s, &inputs, q, &mut array, &mut rng,
        );
        let want = eval_stochastic(&base, &inputs);
        assert_eq!(got["out"], want["out"]);
    });
}

#[test]
fn prop_lane_count_never_changes_values() {
    // Bit-parallelism is value-transparent: executing with q=1 or q=32
    // lanes computes the same bitstream.
    forall(0x5EED3, 20, |g| {
        let base = ops::scaled_add();
        let mut rng = Xoshiro256::seeded(g.u64_below(1 << 62));
        let mut inputs = HashMap::new();
        for n in ["a", "b", "s"] {
            inputs.insert(n.to_string(), Bitstream::sample(rng.next_f64(), 128, &mut rng));
        }
        let mut outs = Vec::new();
        for q in [1usize, 8, 32] {
            let rep = replicate(&base, q);
            let s = schedule(&rep, &Options::default());
            let mut array = stoch_imc::imc::Subarray::new(q, s.cols_used);
            let mut rng2 = Xoshiro256::seeded(1);
            let (got, _) = stoch_imc::imc::execute_replicated(
                &base, &rep, &s, &inputs, q, &mut array, &mut rng2,
            );
            outs.push(got["out"].clone());
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
    });
}

#[test]
fn prop_fault_rate_degrades_monotonically_on_average() {
    // More injected faults ⇒ larger expected error (averaged over apps
    // and instances; individual cases may fluctuate).
    use stoch_imc::apps::{all_apps, output_error_pct};
    let apps = all_apps();
    for app in &apps {
        let w = app.workload(12, 5);
        let e0 = output_error_pct(app.as_ref(), &w, 256, 8, 0.0, true, 1);
        let e20 = output_error_pct(app.as_ref(), &w, 256, 8, 0.20, true, 1);
        // Stochastic error may stay FLAT (that is the robustness claim);
        // it must not mysteriously shrink by more than noise.
        assert!(
            e20 + 2.0 > e0,
            "{}: error shrank under faults ({e0:.2}% → {e20:.2}%)",
            app.name()
        );
        // The paper's headline robustness: ≤ ~7% at 20% bitflips.
        assert!(e20 < 16.0, "{}: stochastic error too large: {e20:.2}%", app.name());
    }
}

#[test]
fn prop_schedule_copy_count_zero_for_single_row_span_circuits() {
    // Replicated single-lane circuits never need row-alignment copies.
    forall(0x5EED4, 30, |g| {
        let base = random_netlist(g);
        let rep = replicate(&base, g.usize_in(1, 16));
        let s = schedule(&rep, &Options::default());
        assert_eq!(s.copy_count, 0);
    });
}
