//! Statistical-quality smoke tests for the counter-based stateless
//! generator (`CounterRng`, the default SNG driver since PR 8). These
//! are not a PractRand substitute — they are fast 5σ sanity gates at
//! pinned seeds that would catch a broken mixer, a dropped finalizer
//! round, or accidental key/stream aliasing long before an accuracy
//! regression shows up in the apps:
//!
//! * per-bit equidistribution of one stream's output words,
//! * avalanche on single key-bit flips (≈ 32/64 output bits change),
//! * exact O(1)-seek ≡ sequential-SplitMix64 identity,
//! * cross-key independence (adjacent lanes / nodes / counters).
//!
//! Every test is deterministic: pinned keys, fixed sample counts, 5σ
//! bounds (false-failure odds ≪ 1e-6 per assertion, and the draws are
//! a pure function of the pinned keys anyway).

use stoch_imc::util::prng::{counter_node_part, CounterRng, SplitMix64, GOLDEN_GAMMA};

/// 5σ band half-width for a Binomial(n, 1/2) count around n/2.
fn five_sigma(n: u64) -> f64 {
    5.0 * (n as f64).sqrt() / 2.0
}

#[test]
fn per_bit_equidistribution_within_5_sigma() {
    const N: u64 = 1 << 16;
    for key in [0u64, 1, 0xDEAD_BEEF, GOLDEN_GAMMA, u64::MAX] {
        let rng = CounterRng::from_key(key);
        let mut ones = [0u64; 64];
        for t in 0..N {
            let x = rng.draw_at(t);
            for (b, slot) in ones.iter_mut().enumerate() {
                *slot += (x >> b) & 1;
            }
        }
        let band = five_sigma(N);
        for (b, &c) in ones.iter().enumerate() {
            let dev = (c as f64 - N as f64 / 2.0).abs();
            assert!(
                dev <= band,
                "key={key:#x} bit {b}: {c} ones of {N} (dev {dev:.0} > {band:.0})"
            );
        }
    }
}

#[test]
fn avalanche_on_key_bit_flips() {
    // Flipping any single key bit must flip ≈ half of the 64 output
    // bits on average. Per draw the Hamming distance is ~Binomial(64,
    // 1/2) (σ = 4); averaged over 64 bits × 64 counters = 4096 samples
    // the mean carries σ ≈ 0.0625, so ±0.5 is an 8σ band.
    for base in [0u64, 0x0123_4567_89AB_CDEF, !0 >> 1] {
        let mut dist_sum = 0u64;
        let mut samples = 0u64;
        for bit in 0..64 {
            let a = CounterRng::from_key(base);
            let b = CounterRng::from_key(base ^ (1u64 << bit));
            for t in 0..64 {
                dist_sum += (a.draw_at(t) ^ b.draw_at(t)).count_ones() as u64;
                samples += 1;
            }
        }
        let mean = dist_sum as f64 / samples as f64;
        assert!((mean - 32.0).abs() < 0.5, "base={base:#x}: avalanche mean {mean:.3} ∉ 32 ± 0.5");
    }
}

#[test]
fn seek_is_exactly_sequential_splitmix() {
    // The whole point of the counter design: draw_at(t) at any t, in
    // any order, equals the (t+1)-th output of a sequential SplitMix64
    // seeded with the key — bit-exact, no statistical band.
    for key in [0u64, 42, 0x9E37_79B9, u64::MAX - 1] {
        let rng = CounterRng::from_key(key);
        let mut seq = SplitMix64::new(key);
        let forward: Vec<u64> = (0..257).map(|_| seq.next_u64()).collect();
        // Backwards and strided access must agree with the forward run.
        for t in (0..257u64).rev() {
            assert_eq!(rng.draw_at(t), forward[t as usize], "key={key:#x} t={t}");
        }
        for t in (0..257u64).step_by(17) {
            assert_eq!(rng.draw_at(t), forward[t as usize], "key={key:#x} strided t={t}");
        }
    }
}

#[test]
fn cross_key_streams_are_independent_within_5_sigma() {
    // Adjacent lanes, adjacent SNG nodes, and identical counters across
    // keys must look pairwise independent: the fraction of matching
    // bits between two streams stays in 1/2 ± 5σ. This is the property
    // that lets every (lane, node) pair share one global counter `t`.
    const N: u64 = 1 << 12; // 4096 draws × 64 bits = 262144 bit pairs
    let band = five_sigma(N * 64);
    let pairs = [
        // Same node, adjacent row seeds (lane neighbours in a wave).
        (CounterRng::keyed(7, 1), CounterRng::keyed(8, 1)),
        // Same row seed, adjacent nodes (two inputs of one row).
        (CounterRng::keyed(7, 1), CounterRng::keyed(7, 2)),
        // Raw keys differing by the counter stride — the aliasing
        // hazard of an additive-counter design: key+Γ at t must not
        // track key at t+1 (mix64 input collides only at shifted t).
        (CounterRng::from_key(1000), CounterRng::from_key(1000 + GOLDEN_GAMMA)),
        // Node-part derivation for adjacent fault/SNG site ids.
        (
            CounterRng::from_key(counter_node_part(5)),
            CounterRng::from_key(counter_node_part(6)),
        ),
    ];
    for (i, (a, b)) in pairs.iter().enumerate() {
        let mut matches = 0u64;
        for t in 0..N {
            matches += (!(a.draw_at(t) ^ b.draw_at(t))).count_ones() as u64;
        }
        let expect = (N * 64) as f64 / 2.0;
        let dev = (matches as f64 - expect).abs();
        assert!(dev <= band, "pair {i}: {matches} matching bits (dev {dev:.0} > {band:.0})");
        // The shifted-counter aliasing check from the comment above,
        // explicitly: stream a at t+1 vs stream (a.key + Γ) at t.
        if i == 2 {
            let mut shifted = 0u64;
            for t in 0..N {
                shifted += (!(a.draw_at(t + 1) ^ b.draw_at(t))).count_ones() as u64;
            }
            // These two sequences ARE identical by construction
            // (mix64(key + Γ·(t+2)) both ways) — assert it so nobody
            // "fixes" the key derivation into relying on raw-key
            // offsets for independence. Lane/node keys avoid this by
            // passing through mix64 first (the pairs above).
            assert_eq!(shifted, N * 64, "pair {i}: shifted-counter identity lost");
        }
    }
}

#[test]
fn f64_conversion_stays_in_unit_interval_and_unbiased() {
    let rng = CounterRng::keyed(0xABCD, 3);
    const N: u64 = 1 << 14;
    let mut sum = 0.0f64;
    for t in 0..N {
        let u = rng.f64_at(t);
        assert!((0.0..1.0).contains(&u), "t={t}: {u} out of [0,1)");
        sum += u;
    }
    let mean = sum / N as f64;
    // Uniform(0,1) mean: σ = 1/(12·N)^0.5 ≈ 0.00226 at N=16384.
    assert!((mean - 0.5).abs() < 5.0 * 0.00226, "mean {mean:.4} drifted from 1/2");
}
