//! Differential reliability suite for the instrumented lane-major
//! engine: the fault/energy/wear models must never perturb clean
//! execution and must agree exactly between the scalar golden path and
//! the word-parallel path.
//!
//! Four pins, one per satellite of the reliability PR:
//!
//! * A rate-0.0 [`FaultPlan`] is **bit-identical** to the uninstrumented
//!   paths for every registered artifact, lane width, and worker count
//!   (the plan degrades to the literal clean code path via `is_noop`).
//! * A live plan through the lane engine matches the faulty scalar
//!   reference exactly at a fixed seed — the stateless counter-based
//!   masks are order-independent, so the gate-major scalar evaluator
//!   and the time-major lane evaluator flip the same bits.
//! * The mask generator is statistically honest: empirical flip rates
//!   track the configured per-bit rate within a derived sigma bound,
//!   and the word generator agrees bit-for-bit with the scalar one.
//! * The executor's dynamic `OpCounters` reproduce the static
//!   `scheduler::Schedule` firing counts (Eq 4) for the six
//!   single-stage ops: same gates, same SBG writes, same presets —
//!   modulo the alignment copies only the spatial scheduler inserts.

use std::collections::HashMap;

use stoch_imc::energy::EnergyParams;
use stoch_imc::fault::FaultPlan;
use stoch_imc::netlist::{ops, GateKind, Netlist};
use stoch_imc::runtime::InterpEngine;
use stoch_imc::scheduler::{schedule, Options};
use stoch_imc::util::prng::{fnv1a, Xoshiro256};

/// Batch dimension for every artifact — large enough for multi-block
/// waves with a ragged tail at every lane width (see
/// `tests/wordparallel.rs`).
const BATCH: usize = 200;

/// Every lane width the engine monomorphizes, plus 0 = auto sizing.
const WIDTHS: [usize; 4] = [64, 128, 256, 0];

const THREADS: [usize; 3] = [1, 3, 16];

/// All ten registered artifacts: six ops, two single-stage apps, two
/// staged pipelines (whose in-lane StoB→BtoS regeneration must carry
/// fault masks across stage boundaries too).
const ARTIFACTS: [&str; 10] = [
    "op_multiply",
    "op_scaled_add",
    "op_abs_subtract",
    "op_scaled_divide",
    "op_square_root",
    "op_exponential",
    "app_ol",
    "app_hdp",
    "app_lit",
    "app_kde",
];

fn engine(bl: usize, tag: &str) -> InterpEngine {
    let dir = std::env::temp_dir().join(format!("stoch_imc_fault_{tag}_{bl}"));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = format!(
        "op_multiply 2 {b} {bl}\nop_scaled_add 2 {b} {bl}\nop_abs_subtract 2 {b} {bl}\n\
         op_scaled_divide 2 {b} {bl}\nop_square_root 1 {b} {bl}\nop_exponential 1 {b} {bl}\n\
         app_ol 6 {b} {bl}\napp_hdp 8 {b} {bl}\napp_lit 64 {b} {bl}\napp_kde 9 {b} {bl}\n",
        b = BATCH,
    );
    std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
    InterpEngine::load(&dir).expect("fault-suite engine load")
}

/// Random full-batch instance values, deterministic per (artifact,
/// seed) so failures reproduce.
fn values_for(e: &InterpEngine, name: &str, seed: i32) -> Vec<f32> {
    let n = e.spec(name).unwrap().n_inputs;
    let mut rng = Xoshiro256::seeded(fnv1a(name) ^ seed as u32 as u64);
    (0..BATCH * n).map(|_| rng.next_f64() as f32).collect()
}

/// Satellite: a rate-0.0 plan must be bit-identical to the
/// uninstrumented paths everywhere — word-parallel at every lane width
/// and worker count, and the scalar golden path.
#[test]
fn rate_zero_plan_is_bit_identical_to_clean_paths() {
    let e = engine(100, "zero");
    let plan = FaultPlan::uniform(0.0, 0xDEAD_BEEF);
    assert!(plan.is_noop(), "rate-0 plan must degrade to the clean path");
    for (i, name) in ARTIFACTS.iter().enumerate() {
        let seed = 900 + i as i32;
        let values = values_for(&e, name, seed);
        let live = 130; // ragged at width 64 and 128, partial at 256
        for width in WIDTHS {
            for threads in THREADS {
                let clean = e.execute_rows_wide(name, &values, seed, live, threads, width).unwrap();
                let (faulted, _) = e
                    .execute_rows_instrumented(name, &values, seed, live, threads, width, Some(&plan))
                    .unwrap();
                assert_eq!(
                    clean, faulted,
                    "rate-0 diverged: artifact={name} width={width} threads={threads}"
                );
            }
        }
        let golden = e.execute_rows_scalar(name, &values, seed, live, 1).unwrap();
        let scalar_faulted =
            e.execute_rows_scalar_fault(name, &values, seed, live, 1, &plan).unwrap();
        assert_eq!(golden, scalar_faulted, "rate-0 diverged on scalar path: artifact={name}");
    }
}

/// Tentpole pin: with a live plan the word-parallel path must match the
/// faulty scalar reference exactly — same masks at the same (site, row,
/// t) coordinates regardless of evaluation order, lane width, or worker
/// count — and must actually differ from the clean run.
#[test]
fn faulty_lane_path_matches_faulty_scalar_reference() {
    let e = engine(100, "diff");
    let plan = FaultPlan::uniform(0.08, 0x5EED_FA11);
    for (i, name) in ARTIFACTS.iter().enumerate() {
        let seed = 40 + i as i32;
        let values = values_for(&e, name, seed);
        let live = if i % 2 == 0 { 65 } else { 130 };
        let golden = e.execute_rows_scalar_fault(name, &values, seed, live, 1, &plan).unwrap();
        for width in WIDTHS {
            for threads in THREADS {
                let (word, _) = e
                    .execute_rows_instrumented(name, &values, seed, live, threads, width, Some(&plan))
                    .unwrap();
                assert_eq!(
                    golden, word,
                    "faulty paths diverged: artifact={name} width={width} threads={threads}"
                );
            }
        }
        let clean = e.execute_rows_scalar(name, &values, seed, live, 1).unwrap();
        assert_ne!(golden, clean, "8% flip rate left `{name}` outputs untouched");
    }
}

/// Satellite: the stateless mask generator is statistically honest —
/// over a large (lanes × bl) grid the empirical flip rate lands within
/// 5σ of the configured per-bit rate (σ = √(r(1−r)/N), pinned seeds) —
/// and the word generator agrees bit-for-bit with the scalar one.
#[test]
fn mask_flip_rate_tracks_configured_rate() {
    let lanes = 256usize;
    let bl = 4096usize;
    let n = (lanes * bl) as f64;
    for &(rate, seed) in &[(0.05f64, 0xA1u64), (0.15, 0xB2), (0.5, 0xC3)] {
        let cuts = FaultPlan::uniform(rate, seed).cutoffs();
        let site = cuts.gate_site(0, 3);
        let mut ones = 0u64;
        for t in 0..bl {
            let words = cuts.mask_words::<4>(cuts.gate, site, 0, lanes, t);
            ones += words.iter().map(|w| w.count_ones() as u64).sum::<u64>();
        }
        let p = ones as f64 / n;
        let sigma = (rate * (1.0 - rate) / n).sqrt();
        assert!(
            (p - rate).abs() < 5.0 * sigma,
            "rate={rate}: empirical {p} off by more than 5σ ({sigma})"
        );
        // Word and scalar generators must be the same function of
        // (site, row, t): the lane/scalar differential rests on this.
        for t in [0usize, 63, 1000] {
            let words = cuts.mask_words::<4>(cuts.gate, site, 0, lanes, t);
            for lane in 0..lanes {
                let word_bit = (words[lane / 64] >> (lane % 64)) & 1 == 1;
                let scalar_bit = cuts.mask_bit(cuts.gate, site, lane as u64, t as u64);
                assert_eq!(word_bit, scalar_bit, "rate={rate} lane={lane} t={t}");
            }
        }
    }
    // Degenerate cutoffs: rate 0 flips nothing, rate 1 flips everything.
    let cuts0 = FaultPlan::uniform(0.0, 7).cutoffs();
    assert_eq!(cuts0.mask_words::<1>(cuts0.sng, cuts0.sng_site(0, 0), 0, 64, 9), [0u64]);
    let cuts1 = FaultPlan::uniform(1.0, 7).cutoffs();
    assert_eq!(cuts1.mask_words::<1>(cuts1.gate, cuts1.gate_site(0, 0), 0, 64, 9), [u64::MAX]);
}

/// Satellite: the executor's dynamic per-wave `OpCounters` must
/// reproduce the static `scheduler::Schedule` firing counts (Eq 4) for
/// each single-stage op. The only legitimate difference is the
/// scheduler's alignment copies (Buff ops with no netlist node): the
/// lane engine never materializes them, so its Buff firings and preset
/// count are lower by exactly `copy_count` per lane-bit.
#[test]
fn executor_counters_match_static_schedule_eq4() {
    let live = 100usize;
    let bl = 64usize;
    let lane_bits = (live * bl) as u64;
    let e = engine(bl, "energy");
    let cases: Vec<(&str, Netlist)> = vec![
        ("op_multiply", ops::multiply()),
        ("op_scaled_add", ops::scaled_add()),
        ("op_abs_subtract", ops::abs_subtract()),
        ("op_scaled_divide", ops::scaled_divide()),
        ("op_square_root", ops::square_root(ops::ADDIE_BITS_APP)),
        ("op_exponential", ops::exponential()),
    ];
    for (name, nl) in cases {
        let sched = schedule(&nl, &Options::default());
        let values = values_for(&e, name, 5);
        let (_, stats) = e.execute_rows_instrumented(name, &values, 5, live, 3, 0, None).unwrap();
        let dynamic = stats.ops;

        let hist: HashMap<GateKind, usize> = sched.op_histogram();
        for kind in GateKind::ALL {
            let mut firings = *hist.get(&kind).unwrap_or(&0) as u64;
            if kind == GateKind::Buff {
                firings -= sched.copy_count as u64;
            }
            assert_eq!(
                dynamic.gates[kind.index()],
                firings * lane_bits,
                "{name}: {kind:?} firings disagree with the static schedule"
            );
        }
        assert_eq!(
            dynamic.sbg_writes,
            sched.sbg_count as u64 * lane_bits,
            "{name}: SBG writes disagree with the schedule's stochastic input cells"
        );
        assert_eq!(
            dynamic.presets,
            (sched.preset_count() - sched.copy_count) as u64 * lane_bits,
            "{name}: presets disagree (schedule presets minus alignment copies)"
        );
        // ADDIE macros are counted apart from gates on both sides: the
        // schedule charges `addie_cycles` with no step ops, the
        // executor counts one `addie_steps` per macro per lane-bit.
        let n_addie = if name == "op_square_root" { 1 } else { 0 };
        assert_eq!(dynamic.addie_steps, n_addie * lane_bits, "{name}: ADDIE step count");
        assert_eq!(dynamic.stob_reads, lane_bits, "{name}: one StoB read per lane-bit");

        // Priced through Eq 4 the counters yield a positive, finite
        // energy with live logic and input-init shares.
        let br = dynamic.energy(&EnergyParams::default());
        assert!(br.total().is_finite() && br.total() > 0.0, "{name}: Eq 4 energy");
        assert!(br.logic > 0.0 && br.input_init > 0.0, "{name}: Eq 4 shares");
    }
}

/// Wear accounting rides the same instrumented path: a wave's profile
/// must charge `writes == OpCounters::write_total()` against a
/// `2·BL`-per-pass endurance budget, and scale its utilized cells with
/// the live row count.
#[test]
fn wave_wear_profile_tracks_counters_and_live_rows() {
    let bl = 64usize;
    let e = engine(bl, "wear");
    let values = values_for(&e, "op_multiply", 11);
    let (_, small) = e.execute_rows_instrumented("op_multiply", &values, 11, 10, 1, 0, None).unwrap();
    let (_, large) =
        e.execute_rows_instrumented("op_multiply", &values, 11, 100, 1, 0, None).unwrap();
    for stats in [&small, &large] {
        assert_eq!(stats.wear.writes, stats.ops.write_total());
        assert_eq!(stats.wear.max_cell_writes, 2 * bl as u64);
    }
    assert_eq!(large.wear.used_cells, 10 * small.wear.used_cells);
    assert_eq!(large.wear.writes, 10 * small.wear.writes);
    assert!(small.wear.merit().unwrap() > 0.0);
}
