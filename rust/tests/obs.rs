//! Observability acceptance: the instrumentation layer must be free
//! (bit-identical outputs with spans/counters on), the fixed-memory
//! histograms must aggregate exactly across shards, and the exposition
//! layer must surface the full telemetry schema from a live server.
//!
//! Three pins:
//!
//! * **Instrumentation is free** — `execute_rows_instrumented` with no
//!   fault plan returns exactly the clean path's bits for every lane
//!   width {64, 128, 256, auto} and worker count {1, 3, 16}, while
//!   still accumulating stage spans and op counters. (The rate-0 fault
//!   differential lives in `tests/fault.rs`.)
//! * **Merge ≡ concatenation** — per-shard `Metrics` merged into a pool
//!   answer every percentile identically to one `Metrics` fed the
//!   concatenated sample stream (the histogram exact-merge invariant
//!   promised in `obs::hist`).
//! * **Exposition end-to-end** — a live `serve::Server` snapshot
//!   carries the stable key schema (`stats --check` contract), stage
//!   shares that sum to 1, and survives the flat-JSON and Prometheus
//!   renderings.

use std::path::PathBuf;
use std::time::Duration;

use stoch_imc::coordinator::{Metrics, WaveClose};
use stoch_imc::obs::MetricsSnapshot;
use stoch_imc::runtime::InterpEngine;
use stoch_imc::serve::{Server, ServerConfig};
use stoch_imc::util::benchjson;
use stoch_imc::util::prng::{fnv1a, Xoshiro256};

const BATCH: usize = 200;
const WIDTHS: [usize; 4] = [64, 128, 256, 0];
const THREADS: [usize; 3] = [1, 3, 16];

fn engine(tag: &str) -> InterpEngine {
    let dir = std::env::temp_dir().join(format!("stoch_imc_obs_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest =
        format!("op_multiply 2 {BATCH} 100\napp_ol 6 {BATCH} 100\napp_kde 9 {BATCH} 100\n");
    std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
    InterpEngine::load(&dir).expect("obs-suite engine load")
}

fn values_for(e: &InterpEngine, name: &str, seed: i32) -> Vec<f32> {
    let n = e.spec(name).unwrap().n_inputs;
    let mut rng = Xoshiro256::seeded(fnv1a(name) ^ seed as u32 as u64);
    (0..BATCH * n).map(|_| rng.next_f64() as f32).collect()
}

fn manifest_dir(tag: &str, lines: &str) -> PathBuf {
    std::env::remove_var("STOCH_IMC_BACKEND");
    let dir = std::env::temp_dir().join(format!("stoch_imc_it_obs_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), lines).unwrap();
    dir
}

/// Span timing and op counting must never perturb the computed bits:
/// the instrumented path with no fault plan is the clean path plus
/// observation, at every lane width and worker count.
#[test]
fn instrumentation_is_bit_identical_to_clean_path() {
    let e = engine("free");
    for (i, name) in ["op_multiply", "app_ol", "app_kde"].iter().enumerate() {
        let seed = 700 + i as i32;
        let values = values_for(&e, name, seed);
        let live = 130; // ragged at 64/128, partial at 256
        for width in WIDTHS {
            for threads in THREADS {
                let clean = e.execute_rows_wide(name, &values, seed, live, threads, width).unwrap();
                let (instr, stats) = e
                    .execute_rows_instrumented(name, &values, seed, live, threads, width, None)
                    .unwrap();
                assert_eq!(
                    clean, instr,
                    "instrumentation changed bits: artifact={name} width={width} threads={threads}"
                );
                // ...while the observation itself is live.
                assert!(
                    stats.spans.total_ns() > 0,
                    "no stage time recorded: artifact={name} width={width} threads={threads}"
                );
                assert!(stats.ops.stob_reads > 0, "no op counters: artifact={name}");
                let shares = stats.spans.shares();
                let sum: f64 = shares.iter().sum();
                assert!((sum - 1.0).abs() < 1e-9, "{name}: stage shares sum to {sum}");
            }
        }
    }
}

/// The pool-aggregation invariant: merging per-shard metrics answers
/// every percentile exactly as one metrics object fed the concatenated
/// sample stream would — histograms merge by bucket addition, so the
/// two are the *same* histogram, not merely close.
#[test]
fn shard_merge_equals_concatenated_stream() {
    let mut shards = [Metrics::default(), Metrics::default(), Metrics::default()];
    let mut whole = Metrics::default();
    let mut x = 0xDEC0_DE00_1234_5678u64;
    for i in 0..3000usize {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        let us = x % 2_000_000; // spans several octaves
        let s = &mut shards[i % 3];
        s.record_latency(Duration::from_micros(us));
        s.record_queue_wait(Duration::from_micros(us / 3));
        s.record_queue_depth(x % 97);
        whole.record_latency(Duration::from_micros(us));
        whole.record_queue_wait(Duration::from_micros(us / 3));
        whole.record_queue_depth(x % 97);
    }
    shards[0].record_drain(WaveClose::Full);
    shards[1].record_drain(WaveClose::Deadline);
    shards[2].record_drain(WaveClose::Flush);
    let mut pool = Metrics::default();
    for s in &shards {
        pool.merge(s);
    }
    for p in [0.0, 1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
        assert_eq!(pool.latency_us(p), whole.latency_us(p), "latency p{p}");
        assert_eq!(pool.queue_wait_us(p), whole.queue_wait_us(p), "queue wait p{p}");
        assert_eq!(pool.queue_depth(p), whole.queue_depth(p), "queue depth p{p}");
    }
    assert_eq!(pool.waves_full + pool.waves_deadline + pool.waves_flush, 3);
}

/// End-to-end exposition: a live server's snapshot carries the stable
/// schema `stats --check` gates on, internally consistent values, and
/// round-trips through both exposition formats.
#[test]
fn server_snapshot_schema_and_round_trip() {
    let dir = manifest_dir("snap", "op_multiply 2 8 2048\nop_scaled_add 2 8 2048\n");
    let server = Server::start(&dir, ServerConfig::default()).unwrap();
    let mul: Vec<Vec<f64>> = (0..24).map(|i| vec![(i as f64 + 1.0) / 30.0, 0.5]).collect();
    let add: Vec<Vec<f64>> = (0..24).map(|i| vec![(i as f64 + 1.0) / 30.0, 0.25]).collect();
    server.run_workload("op_multiply", &mul).unwrap();
    server.run_workload("op_scaled_add", &add).unwrap();
    server.drain().unwrap();

    let snap = server.snapshot();
    // The `stats --check` key contract, for the pool scope and every
    // app scope (snapshot_into emits the same schema per scope).
    for scope in ["pool", "op_multiply", "op_scaled_add"] {
        for metric in [
            "requests",
            "waves",
            "waves_full",
            "waves_deadline",
            "waves_flush",
            "latency_us_p50",
            "latency_us_p95",
            "latency_us_p99",
            "latency_us_p999",
            "latency_us_max",
            "queue_wait_us_p99",
            "queue_depth_p99",
            "shed_total",
            "backpressure_blocks",
            "stage_sng_share",
            "stage_gate_share",
            "stage_regen_share",
            "stage_stob_share",
            "stage_total_ms",
            "wave_live_rows_max",
            "wear_writes",
            "sng_cache_hits",
            "sng_cache_hit_rate",
            "sng_cutoff_hits",
        ] {
            let key = format!("serve_{scope}_{metric}");
            assert!(snap.get(&key).is_some(), "missing {key}");
        }
    }
    // Internal consistency: counts, ordering, shares.
    assert_eq!(snap.get("serve_pool_requests"), Some(48.0));
    assert_eq!(snap.get("serve_op_multiply_requests"), Some(24.0));
    let p50 = snap.get("serve_pool_latency_us_p50").unwrap();
    let p99 = snap.get("serve_pool_latency_us_p99").unwrap();
    let max = snap.get("serve_pool_latency_us_max").unwrap();
    assert!(p50 <= p99 && p99 <= max, "percentiles out of order: {p50} {p99} {max}");
    let shares: f64 = ["sng", "gate", "regen", "stob"]
        .iter()
        .map(|s| snap.get(&format!("serve_pool_stage_{s}_share")).unwrap())
        .sum();
    assert!((shares - 1.0).abs() < 1e-9, "stage shares sum to {shares}");
    assert!(snap.get("serve_pool_stage_total_ms").unwrap() > 0.0);

    // Flat JSON round-trip through the shared benchjson writer/reader.
    let text = snap.to_flat_json();
    let back = MetricsSnapshot::from_entries(&benchjson::parse_flat(&text));
    assert_eq!(back.len(), snap.len(), "keys lost in flat JSON");
    for (k, v) in snap.iter() {
        let got = back.get(k).unwrap_or_else(|| panic!("key {k} lost"));
        assert!((got - v).abs() < 1e-3, "{k}: {got} vs {v}");
    }
    // Prometheus text: one sanitized line per metric.
    let prom = snap.to_prometheus();
    assert_eq!(prom.lines().count(), snap.len());
    for line in prom.lines() {
        assert!(line.starts_with("stoch_imc_serve_"), "bad line {line}");
    }
}
